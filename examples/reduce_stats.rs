//! ReduceDPP demo (paper §IV-C): max, min, sum and mean of a matrix with a
//! SINGLE pass over the data — the paper's motivating example for the second
//! Data Parallel Pattern.
//!
//! ```sh
//! make artifacts && cargo run --release --example reduce_stats
//! ```

use fkl::cv::Context;
use fkl::exec::EngineSelect;
use fkl::proplite::Rng;
use fkl::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    // drives a named AOT artifact: pin the XLA backend
    let ctx = Context::with_select(EngineSelect::Xla, None)?;
    let mut rng = Rng::new(4);
    let x = Tensor::from_f32(&rng.vec_f32(512 * 512, -100.0, 100.0), &[512, 512]);

    // one fused launch computing all four statistics
    let name = "reduce_stats_f32_512x512_pallas";
    let out = ctx.fused()?.executor().run(name, &[&x])?;
    let s = out.as_f32().unwrap().to_vec();
    println!(
        "one-pass ReduceDPP: max={:.3} min={:.3} sum={:.1} mean={:.4}",
        s[0], s[1], s[2], s[3]
    );

    // oracle check
    let [mx, mn, sum, mean] = fkl::hostref::reduce_stats(&x);
    assert!((s[0] as f64 - mx).abs() < 1e-3);
    assert!((s[1] as f64 - mn).abs() < 1e-3);
    assert!((s[2] as f64 - sum).abs() < sum.abs() * 1e-4 + 1.0);
    assert!((s[3] as f64 - mean).abs() < 1e-3);
    println!("matches hostref oracle");

    // the naive alternative sweeps the matrix four times on host; compare:
    let reps = 20;
    let exec = ctx.fused()?.executor();
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        std::hint::black_box(exec.run(name, &[&x])?);
    }
    let one_pass = t0.elapsed().as_secs_f64() / reps as f64;
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        let v = x.as_f32().unwrap();
        let mx = v.iter().copied().fold(f32::MIN, f32::max);
        let mn = v.iter().copied().fold(f32::MAX, f32::min);
        let sum: f32 = v.iter().sum();
        let mean = sum / v.len() as f32;
        std::hint::black_box((mx, mn, sum, mean));
    }
    let four_pass = t0.elapsed().as_secs_f64() / reps as f64;
    println!("fused one-pass {:.3}ms vs 4-sweep host {:.3}ms", one_pass * 1e3, four_pass * 1e3);
    Ok(())
}
