//! END-TO-END DRIVER (DESIGN.md: the system's E2E validation).
//!
//! Drives the full stack on a realistic workload trace: a synthetic
//! multi-camera video analytics service. N cameras emit crops at different
//! rates; each crop goes through the normalization chain. The coordinator
//! dynamically batches same-signature requests into horizontally-fused
//! launches on the PJRT runtime (L3 -> artifact registry -> L2/L1 fused
//! kernels). Reports the paper's headline metric — fused vs per-op speedup —
//! plus serving latency/throughput, and verifies numerics against hostref.
//!
//! ```sh
//! make artifacts && cargo run --release --example streaming_service
//! ```

use std::time::{Duration, Instant};

use fkl::chain::{Chain, ConvertTo, Div, Mul, Sub, F32, U8};
use fkl::coordinator::{BatchPolicy, Service, ServiceConfig};
use fkl::ops::Pipeline;
use fkl::proplite::Rng;
use fkl::tensor::Tensor;

fn normalize_pipeline() -> Pipeline {
    // the normalization chain through the compile-time-checked front door;
    // the coordinator consumes the lowered IR (same signature, same plans)
    Chain::read::<U8>(&[60, 120])
        .map(ConvertTo)
        .map(Mul(0.5))
        .map(Sub(3.0))
        .map(Div(1.7))
        .cast::<F32>()
        .write()
        .into_pipeline()
}

fn main() -> anyhow::Result<()> {
    let total_requests = 2000usize;
    let cameras = 8usize;

    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 4096,
        policy: BatchPolicy { max_batch: 50, window: Duration::from_micros(800) },
        ..ServiceConfig::default()
    });
    let p = normalize_pipeline();

    // workload trace: cameras emit in bursts
    let mut rng = Rng::new(777);
    let mut pending = Vec::with_capacity(total_requests);
    let mut inputs = Vec::with_capacity(total_requests);
    let t0 = Instant::now();
    let mut submitted = 0;
    while submitted < total_requests {
        // a burst: each camera emits 1-4 crops
        for _cam in 0..cameras {
            let burst = rng.usize(1, 5);
            for _ in 0..burst {
                if submitted >= total_requests {
                    break;
                }
                let item = Tensor::from_u8(&rng.vec_u8(60 * 120), &[1, 60, 120]);
                inputs.push(item.clone());
                match svc.submit(p.clone(), item) {
                    Ok(rx) => pending.push(Some(rx)),
                    Err(e) => {
                        eprintln!("backpressure: {e}");
                        pending.push(None);
                    }
                }
                submitted += 1;
            }
        }
        // inter-burst gap
        std::thread::sleep(Duration::from_micros(200));
    }

    // collect + verify a sample against the host oracle
    let mut ok = 0;
    let mut verified = 0;
    for (i, rx) in pending.iter().enumerate() {
        let Some(rx) = rx else { continue };
        match rx.recv() {
            Ok(Ok(out)) => {
                ok += 1;
                if i % 97 == 0 {
                    let want = fkl::hostref::run_pipeline(&p, &inputs[i]);
                    let (g, w) = (out.to_f64_vec(), want.to_f64_vec());
                    let err = g.iter().zip(&w).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
                    assert!(err < 1e-3, "request {i}: max err {err}");
                    verified += 1;
                }
            }
            other => eprintln!("request {i} failed: {other:?}"),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = svc.metrics().unwrap();
    println!("=== streaming_service E2E ===");
    println!(
        "served {ok}/{total_requests} requests in {wall:.2}s = {:.0} req/s",
        ok as f64 / wall
    );
    println!("verified {verified} sampled results against hostref oracle");
    println!(
        "HF batching: {} launches, mean batch {:.1}, padded planes {}",
        m.launches,
        m.mean_batch(),
        m.padded_planes
    );
    println!(
        "fusion coverage: {:.0}% fused ({} unfused fallbacks; tiers exact={} staticloop={} interp={} host={})",
        m.fused_coverage() * 100.0,
        m.unfused_fallbacks,
        m.planner.exact,
        m.planner.staticloop,
        m.planner.interp,
        m.planner.host
    );
    println!(
        "latency us: p50={} p95={} p99={} max={}",
        m.latency.p50, m.latency.p95, m.latency.p99, m.latency.max
    );

    // headline comparison: the same trace WITHOUT HF (batch=1 launches)
    let svc1 = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 4096,
        policy: BatchPolicy { max_batch: 1, window: Duration::ZERO },
        ..ServiceConfig::default()
    });
    let t0 = Instant::now();
    let mut pend1 = Vec::new();
    for _ in 0..total_requests.min(500) {
        let item = Tensor::from_u8(&rng.vec_u8(60 * 120), &[1, 60, 120]);
        if let Ok(rx) = svc1.submit(p.clone(), item) {
            pend1.push(rx);
        }
    }
    let mut ok1 = 0;
    for rx in pend1 {
        if rx.recv().map(|r| r.is_ok()).unwrap_or(false) {
            ok1 += 1;
        }
    }
    let wall1 = t0.elapsed().as_secs_f64();
    let rps_hf = ok as f64 / wall;
    let rps_nohf = ok1 as f64 / wall1;
    println!(
        "throughput: {:.0} req/s with HF vs {:.0} req/s without -> {:.1}x",
        rps_hf,
        rps_nohf,
        rps_hf / rps_nohf
    );
    svc1.shutdown();
    svc.shutdown();
    Ok(())
}
