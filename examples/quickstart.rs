//! Quickstart: compose library calls, get ONE fused pass.
//!
//! The paper's core promise: write OpenCV-style code, and the library fuses
//! the whole chain into a single launch with intermediates in registers.
//! `Context::new()` performs Auto backend selection, so this runs on ANY
//! machine: the XLA fused engine when `make artifacts` has been run, the
//! single-pass host fused engine otherwise.
//!
//! ```sh
//! cargo run --release --example quickstart            # host backend
//! make artifacts && cargo run --release --example quickstart   # XLA backend
//! ```

use fkl::chain::{Chain, Div, Mul, Sub, F32, U8};
use fkl::cv::{self, Context};
use fkl::exec::Engine;
use fkl::tensor::{DType, Tensor};

fn main() -> anyhow::Result<()> {
    let ctx = Context::new()?;
    println!("backend: {}", ctx.backend());

    // a batch of 50 tiny camera crops (u8), like the paper's AutomaticTV feed
    let input = Tensor::from_u8(&vec![128u8; 50 * 60 * 120], &[50, 60, 120]);

    // OpenCV-style calls — each returns a lazy typed stage, nothing launches
    let iops = [
        cv::convert_to(), // 8U -> 32F
        cv::multiply(1.0 / 255.0),
        cv::subtract(0.45),
        cv::divide(0.226), // standard normalization
    ];

    // ... until the executor fuses the chain into ONE pass
    let out = cv::execute_operations(&ctx, &input, DType::F32, &iops)?;
    println!("output: {:?} {:?}", out.dtype(), out.shape());
    println!("sample: {:?}", &out.as_f32().unwrap()[..4]);

    // the same chain through the compile-time-checked builder: an illegal
    // chain (missing write, wrong dtype boundary) would not have compiled
    let typed = Chain::read::<U8>(&[60, 120])
        .batch(50)
        .map(cv::convert_to())
        .map(Mul(1.0 / 255.0))
        .map(Sub(0.45))
        .map(Div(0.226))
        .cast::<F32>()
        .write();
    let host_out = typed.run_host(ctx.host(), &input)?;
    println!("typed chain via monomorphized host loop: {:?}", host_out.shape());

    let p = cv::build_pipeline(&input, DType::F32, &iops)?;
    match ctx.fused() {
        Ok(fused) => {
            // what did the planner do?
            let plan = fused.plan_for(&p)?;
            println!("plan tier: {} ({} launch)", plan.tier(), plan.launches());

            // versus the way stock OpenCV-CUDA would run the same chain
            let t0 = std::time::Instant::now();
            let _ = cv::execute_operations(&ctx, &input, DType::F32, &iops)?;
            let fused_t = t0.elapsed();
            let t0 = std::time::Instant::now();
            let _ = cv::execute_operations_opencv_style(&ctx, &input, DType::F32, &iops)?;
            let unfused_t = t0.elapsed();
            println!(
                "fused {:.2}ms vs per-op {:.2}ms -> {:.1}x ({} launches saved)",
                fused_t.as_secs_f64() * 1e3,
                unfused_t.as_secs_f64() * 1e3,
                unfused_t.as_secs_f64() / fused_t.as_secs_f64(),
                ctx.unfused()?.last_launches() - 1,
            );
        }
        Err(_) => {
            // artifact-free machine: the host backend still demonstrates VF —
            // one fused pass vs one whole-buffer sweep per op
            let t0 = std::time::Instant::now();
            let _ = ctx.host().run(&p, &input)?;
            let fused_t = t0.elapsed();
            let t0 = std::time::Instant::now();
            let _ = fkl::hostref::run_pipeline(&p, &input);
            let sweep_t = t0.elapsed();
            println!(
                "host fused {:.2}ms vs op-at-a-time {:.2}ms -> {:.1}x",
                fused_t.as_secs_f64() * 1e3,
                sweep_t.as_secs_f64() * 1e3,
                sweep_t.as_secs_f64() / fused_t.as_secs_f64(),
            );
        }
    }

    // and the device memory VF avoids allocating
    let r = fkl::fusion::memsave::report(&p);
    println!("device memory saved: {} KB", r.saved() / 1024);
    Ok(())
}
