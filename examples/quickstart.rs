//! Quickstart: compose library calls, get ONE fused kernel.
//!
//! The paper's core promise: write OpenCV-style code, and the library fuses
//! the whole chain into a single launch with intermediates in registers.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use fkl::cv::{self, Context};
use fkl::exec::Engine;
use fkl::tensor::{DType, Tensor};

fn main() -> anyhow::Result<()> {
    let ctx = Context::new()?;

    // a batch of 50 tiny camera crops (u8), like the paper's AutomaticTV feed
    let input = Tensor::from_u8(&vec![128u8; 50 * 60 * 120], &[50, 60, 120]);

    // OpenCV-style calls — each returns a lazy IOp, nothing launches yet
    let iops = [
        cv::convert_to(), // 8U -> 32F
        cv::multiply(1.0 / 255.0),
        cv::subtract(0.45),
        cv::divide(0.226), // standard normalization
    ];

    // ... until the executor fuses the chain into ONE kernel launch
    let out = cv::execute_operations(&ctx, &input, DType::F32, &iops)?;
    println!("output: {:?} {:?}", out.dtype(), out.shape());
    println!("sample: {:?}", &out.as_f32().unwrap()[..4]);

    // what did the planner do?
    let p = cv::build_pipeline(&input, DType::F32, &iops)?;
    let plan = ctx.fused.plan_for(&p)?;
    println!("plan tier: {} ({} launch)", plan.tier(), plan.launches());

    // versus the way stock OpenCV-CUDA would run the same chain
    let t0 = std::time::Instant::now();
    let _ = cv::execute_operations(&ctx, &input, DType::F32, &iops)?;
    let fused_t = t0.elapsed();
    let t0 = std::time::Instant::now();
    let _ = cv::execute_operations_opencv_style(&ctx, &input, DType::F32, &iops)?;
    let unfused_t = t0.elapsed();
    println!(
        "fused {:.2}ms vs per-op {:.2}ms -> {:.1}x ({} launches saved)",
        fused_t.as_secs_f64() * 1e3,
        unfused_t.as_secs_f64() * 1e3,
        unfused_t.as_secs_f64() / fused_t.as_secs_f64(),
        ctx.unfused.last_launches() - 1,
    );

    // and the device memory VF avoids allocating
    let r = fkl::fusion::memsave::report(&p);
    println!("device memory saved: {} KB", r.saved() / 1024);
    Ok(())
}
