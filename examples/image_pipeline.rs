//! The paper's production workload (Fig. 25): batched
//! Crop -> Resize -> ColorConvert -> Multiply -> Subtract -> Divide -> Split
//! on a real (synthetic) 720p video frame, comparing the NPP-style per-call
//! execution with the fused FastNPP-style single kernel — including the
//! syntax the paper advertises. The second half runs the NORMALIZE stage:
//! the same crops with DATA-DERIVED per-channel statistics (one fused
//! reduce-while-reading pass per crop, then the preproc chain with μ/σ
//! bound) — the full crop -> resize -> normalize -> split workload.
//!
//! Runs on ANY machine: with artifacts the fused arm is one AOT kernel
//! launch; without them the host fused engine executes the same structured
//! chain in one pass per crop (bilinear gather while reading, split while
//! writing) against the NPP-style materialized-step baseline.
//!
//! ```sh
//! cargo run --release --example image_pipeline              # host backend
//! make artifacts && cargo run --release --example image_pipeline  # XLA
//! ```

use fkl::cv::Context;
use fkl::npp::{PreprocPipeline, ResizeBatchSpec};
use fkl::tensor::{make_frame, Rect};

fn main() -> anyhow::Result<()> {
    // Auto backend selection: the flagship workload is servable everywhere
    let ctx = Context::new()?;
    println!("backend: {}", ctx.backend());
    let frame = make_frame(720, 1280, 2024);

    // 50 detection boxes from the "previous frame" (the paper's use case:
    // preprocess person crops for a neural net)
    let rects: Vec<Rect> =
        (0..50).map(|i| Rect::new((i * 23) % 1100, (i * 11) % 640, 120, 60)).collect();

    // FastNPP syntax: one executeOperations-style call for the whole batch
    let mut pipe = PreprocPipeline::new(
        ResizeBatchSpec { rects, dst_h: 128, dst_w: 64 },
        [1.0 / 255.0; 3],      // MulC: to [0,1]
        [0.485, 0.456, 0.406], // SubC: imagenet mean
        [0.229, 0.224, 0.225], // DivC: imagenet std
    );

    // warmup (XLA compiles on first use)
    let out = pipe.run(&ctx, &frame)?;
    println!("fused output: {:?} {:?} (planar f32)", out.dtype(), out.shape());
    let _ = pipe.run_npp_style(&ctx, &frame)?;

    // measured comparison
    let reps = 10;
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        std::hint::black_box(pipe.run(&ctx, &frame)?);
    }
    let fused_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;

    pipe.precompute();
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        std::hint::black_box(pipe.run_precomputed(&ctx, &frame)?);
    }
    let pre_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;

    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        std::hint::black_box(pipe.run_npp_style(&ctx, &frame)?);
    }
    let npp_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;

    println!("NPP-style (400 launches): {npp_ms:.2} ms/frame");
    println!("FastNPP fused:            {fused_ms:.2} ms/frame ({:.1}x)", npp_ms / fused_ms);
    println!("FastNPP precomputed:      {pre_ms:.2} ms/frame ({:.1}x)", npp_ms / pre_ms);

    // numerics check against the pure-Rust oracle
    let want = fkl::hostref::preproc(
        &frame,
        &pipe.spec.rects,
        [1.0 / 255.0; 3],
        [0.485, 0.456, 0.406],
        [0.229, 0.224, 0.225],
        128,
        64,
    );
    let got = pipe.run(&ctx, &frame)?;
    let (g, w) = (got.to_f64_vec(), want.to_f64_vec());
    let max_err = g.iter().zip(&w).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
    println!("max abs error vs hostref oracle: {max_err:.2e}");
    assert!(max_err < 1e-2);

    // --- the normalize stage: crop -> resize -> normalize -> split --------
    // per-channel μ/σ measured from THIS batch's scaled crops (one fused
    // reduce-while-reading pass per crop — the resized crops never
    // materialize), then the preproc chain runs with the statistics bound
    let (mu, sigma) = pipe.channel_mean_std(&ctx, &frame)?;
    println!("derived stats: μ={mu:.3?} σ={sigma:.3?}");
    let normalized = pipe.run_normalized_with(&ctx, &frame, mu, sigma)?;
    println!("normalized output: {:?} {:?}", normalized.dtype(), normalized.shape());

    // the workload's defining property: each output channel lands at mean 0
    // and unit variance across the whole batch
    let v = normalized.as_f32().expect("planar f32 output");
    let plane = 128 * 64;
    for c in 0..3 {
        let mut lane = Vec::with_capacity(50 * plane);
        for bi in 0..50 {
            let base = bi * 3 * plane + c * plane;
            lane.extend(v[base..base + plane].iter().map(|&x| x as f64));
        }
        let n = lane.len() as f64;
        let mean: f64 = lane.iter().sum::<f64>() / n;
        let var: f64 = lane.iter().map(|x| x * x).sum::<f64>() / n;
        println!("channel {c}: mean {mean:+.2e}, var {var:.6}");
        assert!(mean.abs() < 1e-3, "channel {c} mean {mean}");
        assert!((var - 1.0).abs() < 1e-2, "channel {c} var {var}");
    }
    Ok(())
}
