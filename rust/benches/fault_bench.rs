//! Fault-tolerance overhead benchmark: what does the serving core's safety
//! machinery cost when nothing is failing?
//!
//! Three configurations drive the same CMSD traffic through the service:
//!
//! * `baseline`  — no fault plan, no deadlines (the pre-robustness path);
//! * `armed-idle` — a [`FaultPlan`] is installed whose single rule can never
//!   match the traffic (wrong signature substring), so every launch consults
//!   the injector and every consult declines. This prices the "armed but
//!   quiet" path — it should be indistinguishable from baseline;
//! * `deadline`  — every request carries a generous deadline, so admission
//!   control, expiry partitioning and margin accounting all run on the hot
//!   path but nothing is actually shed or expired.
//!
//! Writes `BENCH_faults.json` at the repo root and enforces the acceptance
//! bar: armed-idle throughput >= 0.85x baseline (the injector must be close
//! to free when it never fires).
//!
//! ```sh
//! cargo bench --bench fault_bench
//! FKL_BENCH_FAST=1 cargo bench --bench fault_bench   # trimmed
//! FKL_BENCH_SOFT=1 ...                               # miss -> warning
//! ```

use std::time::{Duration, Instant};

use fkl::chain::{Chain, ConvertTo, Div, Mul, Sub, F32, U8};
use fkl::coordinator::{BatchPolicy, MetricsSnapshot, Service, ServiceConfig};
use fkl::faults::FaultPlan;
use fkl::jsonlite::Value;
use fkl::ops::Pipeline;
use fkl::proplite::Rng;
use fkl::tensor::Tensor;

fn pipeline() -> Pipeline {
    Chain::read::<U8>(&[60, 120])
        .map(ConvertTo)
        .map(Mul(0.5))
        .map(Sub(3.0))
        .map(Div(1.7))
        .cast::<F32>()
        .write()
        .into_pipeline()
}

struct Point {
    label: &'static str,
    rps: f64,
    p50_us: u64,
    p99_us: u64,
    metrics: MetricsSnapshot,
}

impl Point {
    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("label", Value::str(self.label)),
            ("req_per_s", Value::num(self.rps)),
            ("p50_us", Value::num(self.p50_us as f64)),
            ("p99_us", Value::num(self.p99_us as f64)),
            ("launches", Value::num(self.metrics.launches as f64)),
            ("shed", Value::num(self.metrics.shed as f64)),
            ("expired", Value::num(self.metrics.expired as f64)),
            ("failed", Value::num(self.metrics.failed as f64)),
            ("margin_p50_us", Value::num(self.metrics.deadline_margin.p50 as f64)),
        ])
    }
}

fn drive(
    label: &'static str,
    faults: Option<FaultPlan>,
    deadline: Option<Duration>,
    n: usize,
) -> Point {
    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 8192,
        policy: BatchPolicy { max_batch: 50, window: Duration::from_micros(500), ..Default::default() },
        default_deadline: deadline,
        faults,
        ..ServiceConfig::default()
    });
    let p = pipeline();
    let mut rng = Rng::new(3);
    // warmup (backend construction + first launch)
    let w = svc.submit(p.clone(), Tensor::from_u8(&rng.vec_u8(7200), &[1, 60, 120])).unwrap();
    let _ = w.recv();

    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(n);
    for _ in 0..n {
        let item = Tensor::from_u8(&rng.vec_u8(7200), &[1, 60, 120]);
        if let Ok(rx) = svc.submit(p.clone(), item) {
            pending.push(rx);
        }
    }
    let mut ok = 0usize;
    for rx in pending {
        if rx.recv().map(|r| r.is_ok()).unwrap_or(false) {
            ok += 1;
        }
    }
    let rps = ok as f64 / t0.elapsed().as_secs_f64();
    let m = svc.metrics().unwrap();
    svc.shutdown();
    assert_eq!(ok, n, "{label}: every request must be served (nothing should fire/shed)");
    Point { label, rps, p50_us: m.latency.p50, p99_us: m.latency.p99, metrics: m }
}

fn main() {
    let fast = std::env::var("FKL_BENCH_FAST").is_ok();
    let n = if fast { 600 } else { 3000 };
    println!("# fault_bench (CMSD 60x120 u8->f32, max_batch 50, window 500us, n={n})");
    println!("{:>12} | {:>10} {:>8} {:>8}", "config", "req/s", "p50_us", "p99_us");

    // the rule is well-formed but its signature substring never occurs in a
    // CMSD stream key, so the injector is consulted at every launch and
    // declines every time — the pure cost of being armed
    let idle_plan = FaultPlan::parse("sig=never-matches,tier=any,launch=*,action=err")
        .expect("idle rule parses");

    let points = [
        drive("baseline", None, None, n),
        drive("armed-idle", Some(idle_plan), None, n),
        drive("deadline", None, Some(Duration::from_secs(30)), n),
    ];
    for pt in &points {
        println!("{:>12} | {:>10.0} {:>8} {:>8}", pt.label, pt.rps, pt.p50_us, pt.p99_us);
    }

    let baseline = points[0].rps;
    let armed = points[1].rps;
    let ratio = armed / baseline;
    let accept_pass = ratio >= 0.85;
    println!(
        "\nacceptance: armed-idle/baseline = {ratio:.3}x (target >= 0.85x): {}",
        if accept_pass { "PASS" } else { "FAIL" }
    );

    let report = Value::obj(vec![
        ("bench", Value::str("faults")),
        ("traffic", Value::str("CMSD 60x120 u8->f32 single-item requests")),
        ("fast_mode", Value::Bool(fast)),
        ("requests", Value::num(n as f64)),
        (
            "acceptance",
            Value::obj(vec![
                (
                    "criterion",
                    Value::str("armed-but-idle injector >= 0.85x baseline throughput"),
                ),
                ("ratio", Value::num(ratio)),
                ("pass", Value::Bool(accept_pass)),
            ]),
        ),
        ("series", Value::Arr(points.iter().map(Point::to_json).collect())),
    ]);

    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_faults.json"))
        .unwrap_or_else(|| "BENCH_faults.json".into());
    std::fs::write(&root, report.to_json()).expect("write BENCH_faults.json");
    println!("wrote {}", root.display());

    // wall-clock ratios flake on shared CI runners; FKL_BENCH_SOFT keeps the
    // signal as a warning there while local runs enforce the bar
    if !accept_pass && std::env::var("FKL_BENCH_SOFT").is_ok() {
        eprintln!("WARNING: acceptance criterion not met: {ratio:.3}x < 0.85x (soft mode)");
        return;
    }
    assert!(accept_pass, "acceptance criterion not met: {ratio:.3}x < 0.85x");
}
