//! Open-loop load harness for the (sharded) coordinator.
//!
//! Closed-loop benches (`coordinator_bench`) hide queueing collapse: the
//! client waits for replies, so the offered rate politely tracks capacity
//! and the tail never shows. This bench drives OPEN-LOOP traffic — Poisson
//! arrivals at a fixed offered rate, submitted on schedule whether or not
//! earlier requests finished — over a mixed stream set (dense CMSD
//! variants, a structured resize read, a reduce terminator), every request
//! carrying a deadline. It sweeps offered load below and beyond capacity
//! for `shards = 1` and `shards = 4` and reports served throughput,
//! p50/p99/p999 latency, and shed rate.
//!
//! Writes `BENCH_serve.json` at the repo root. Acceptance (the sharding
//! tentpole): at ~3x capacity offered, 4 shards serve >= 2x the 1-shard
//! throughput at equal-or-better p99. The gate downgrades to a warning
//! when the host has fewer than 4 cores (shards cannot run in parallel)
//! or under `FKL_BENCH_SOFT=1` (shared CI runners).
//!
//! ```sh
//! cargo bench --bench serve_bench
//! FKL_BENCH_FAST=1 cargo bench --bench serve_bench   # trimmed
//! FKL_BENCH_SOFT=1 ...                               # miss -> warning
//! ```

use std::time::{Duration, Instant};

use fkl::chain::{Chain, ConvertTo, CvtColor, Div, Mul, Sub, F32, U8};
use fkl::coordinator::{BatchPolicy, MetricsSnapshot, Service, ServiceConfig, SubmitError};
use fkl::jsonlite::Value;
use fkl::ops::{Pipeline, ReduceKind};
use fkl::proplite::Rng;
use fkl::tensor::{make_frame, Rect, Tensor};

/// Per-request serve-by budget. Generous against the ~ms batch window but
/// tight against queueing collapse: past capacity the queue estimate grows
/// and admission control starts shedding instead of serving stale work.
const DEADLINE: Duration = Duration::from_millis(50);

fn dense(w: usize) -> Pipeline {
    Chain::read::<U8>(&[60, w])
        .map(ConvertTo)
        .map(Mul(0.5))
        .map(Sub(3.0))
        .map(Div(1.7))
        .cast::<F32>()
        .write()
        .into_pipeline()
}

/// The mixed stream set: six distinct stream keys so a 4-shard router has
/// something to spread, weighted toward the dense streams.
fn streams(rng: &mut Rng) -> Vec<(Pipeline, Tensor)> {
    let mut out: Vec<(Pipeline, Tensor)> = (0..4)
        .map(|k| {
            let w = 120 + k;
            (dense(w), Tensor::from_u8(&rng.vec_u8(60 * w), &[1, 60, w]))
        })
        .collect();
    let structured = Chain::read_resize::<U8>(Rect::new(3, 2, 20, 14), 10, 6)
        .map(CvtColor)
        .cast::<F32>()
        .write_split()
        .into_pipeline();
    out.push((structured, make_frame(40, 50, 12)));
    let reduce = Chain::read::<U8>(&[8, 9])
        .map(Mul(0.5))
        .reduce_per_channel(ReduceKind::Mean)
        .into_pipeline();
    out.push((reduce, Tensor::from_u8(&rng.vec_u8(72), &[1, 8, 9])));
    out
}

fn service(shards: usize) -> Service {
    Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 4096,
        policy: BatchPolicy { max_batch: 50, window: Duration::from_micros(500), ..Default::default() },
        shards,
        ..ServiceConfig::default()
    })
}

/// Closed-loop burst on one shard: a capacity estimate to anchor the
/// open-loop sweep's offered rates.
fn calibrate(n: usize) -> f64 {
    let svc = service(1);
    let mut rng = Rng::new(7);
    let set = streams(&mut rng);
    let w = svc.submit(set[0].0.clone(), set[0].1.clone()).unwrap();
    let _ = w.recv();
    let t0 = Instant::now();
    let pending: Vec<_> = (0..n)
        .filter_map(|i| {
            let (p, t) = &set[i % set.len()];
            svc.submit(p.clone(), t.clone()).ok()
        })
        .collect();
    let ok = pending.into_iter().filter(|rx| matches!(rx.recv(), Ok(Ok(_)))).count();
    let rps = ok as f64 / t0.elapsed().as_secs_f64();
    svc.shutdown();
    rps
}

struct Point {
    shards: usize,
    offered_rps: f64,
    served_rps: f64,
    ok: usize,
    client_rejected: usize,
    shed_rate: f64,
    p50_us: u64,
    p99_us: u64,
    p999_us: u64,
    metrics: MetricsSnapshot,
}

impl Point {
    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("shards", Value::num(self.shards as f64)),
            ("offered_rps", Value::num(self.offered_rps)),
            ("served_rps", Value::num(self.served_rps)),
            ("completed", Value::num(self.ok as f64)),
            ("client_rejected", Value::num(self.client_rejected as f64)),
            ("shed_rate", Value::num(self.shed_rate)),
            ("p50_us", Value::num(self.p50_us as f64)),
            ("p99_us", Value::num(self.p99_us as f64)),
            ("p999_us", Value::num(self.p999_us as f64)),
            ("server_shed", Value::num(self.metrics.shed as f64)),
            ("server_expired", Value::num(self.metrics.expired as f64)),
            ("steals", Value::num(self.metrics.steals as f64)),
            ("stolen_requests", Value::num(self.metrics.stolen_requests as f64)),
        ])
    }
}

/// One open-loop run: `n` Poisson arrivals at `offered_rps`, every request
/// deadlined. Submissions happen on the arrival clock — a full queue is a
/// client-side shed (`QueueFull`), never a stall.
fn drive(shards: usize, offered_rps: f64, n: usize, seed: u64) -> Point {
    let svc = service(shards);
    let mut rng = Rng::new(seed);
    let set = streams(&mut rng);
    // warm every stream (backend construction + first plans) on its shard
    let warm: Vec<_> =
        set.iter().filter_map(|(p, t)| svc.submit(p.clone(), t.clone()).ok()).collect();
    for rx in warm {
        let _ = rx.recv();
    }

    let t0 = Instant::now();
    let mut next = t0;
    let mut pending = Vec::with_capacity(n);
    let mut client_rejected = 0usize;
    for i in 0..n {
        // exponential inter-arrival gap (u in [0,1); 1-u avoids ln(0))
        let gap = -(1.0 - rng.f64(0.0, 1.0)).ln() / offered_rps;
        next += Duration::from_secs_f64(gap);
        let now = Instant::now();
        if next > now {
            std::thread::sleep(next - now);
        }
        let (p, t) = &set[i % set.len()];
        match svc.submit_with_deadline(p.clone(), t.clone(), DEADLINE) {
            Ok(rx) => pending.push(rx),
            Err(SubmitError::QueueFull) => client_rejected += 1,
            Err(SubmitError::Stopped) => break,
        }
    }
    let submit_elapsed = t0.elapsed().as_secs_f64();
    let ok = pending.into_iter().filter(|rx| matches!(rx.recv(), Ok(Ok(_)))).count();
    let m = svc.metrics().expect("snapshot");
    svc.shutdown();

    let shed_rate = (client_rejected as u64 + m.shed + m.expired) as f64 / n as f64;
    Point {
        shards,
        offered_rps: n as f64 / submit_elapsed,
        served_rps: ok as f64 / submit_elapsed,
        ok,
        client_rejected,
        shed_rate,
        p50_us: m.latency.p50,
        p99_us: m.latency.p99,
        p999_us: m.latency.p999,
        metrics: m,
    }
}

fn main() {
    let fast = std::env::var("FKL_BENCH_FAST").is_ok();
    let soft = std::env::var("FKL_BENCH_SOFT").is_ok();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let n = if fast { 400 } else { 1200 };

    let capacity = calibrate(if fast { 200 } else { 500 });
    println!("# serve_bench (open-loop Poisson, mixed dense/structured/reduce, deadline 50ms)");
    println!("calibrated 1-shard capacity: {capacity:.0} req/s ({cores} core(s))");
    println!(
        "{:>6} {:>12} | {:>10} {:>9} {:>8} {:>8} {:>8} {:>7}",
        "shards", "offered", "served", "shed_rate", "p50_us", "p99_us", "p999_us", "steals"
    );

    let mut points: Vec<Point> = Vec::new();
    for &shards in &[1usize, 4] {
        for (i, &mult) in [0.5f64, 1.5, 3.0].iter().enumerate() {
            let pt = drive(shards, capacity * mult, n, 100 + i as u64);
            println!(
                "{:>6} {:>12.0} | {:>10.0} {:>9.3} {:>8} {:>8} {:>8} {:>7}",
                pt.shards,
                pt.offered_rps,
                pt.served_rps,
                pt.shed_rate,
                pt.p50_us,
                pt.p99_us,
                pt.p999_us,
                pt.metrics.steals
            );
            points.push(pt);
        }
    }

    // acceptance: the overload points (3x capacity) — sharding must buy
    // throughput without giving back the tail
    let over1 = &points[2];
    let over4 = &points[5];
    let tput_ratio = over4.served_rps / over1.served_rps.max(1e-9);
    let tput_pass = tput_ratio >= 2.0;
    let p99_pass = over4.p99_us <= over1.p99_us;
    let accept_pass = tput_pass && p99_pass;
    println!(
        "\nacceptance @3x offered: 4-shard/1-shard served = {tput_ratio:.2}x (target >= 2x): {}; \
         p99 {}us vs {}us (target <=): {}",
        if tput_pass { "PASS" } else { "FAIL" },
        over4.p99_us,
        over1.p99_us,
        if p99_pass { "PASS" } else { "FAIL" }
    );

    let report = Value::obj(vec![
        ("bench", Value::str("serve")),
        (
            "traffic",
            Value::str("open-loop Poisson, 6 streams (4 dense CMSD widths, resize-split, reduce)"),
        ),
        ("fast_mode", Value::Bool(fast)),
        ("cores", Value::num(cores as f64)),
        ("requests_per_point", Value::num(n as f64)),
        ("deadline_ms", Value::num(DEADLINE.as_millis() as f64)),
        ("calibrated_capacity_rps", Value::num(capacity)),
        (
            "acceptance",
            Value::obj(vec![
                (
                    "criterion",
                    Value::str("@3x capacity: 4-shard >= 2x 1-shard served rps, p99 <="),
                ),
                ("throughput_ratio", Value::num(tput_ratio)),
                ("p99_1shard_us", Value::num(over1.p99_us as f64)),
                ("p99_4shard_us", Value::num(over4.p99_us as f64)),
                ("pass", Value::Bool(accept_pass)),
            ]),
        ),
        ("series", Value::Arr(points.iter().map(Point::to_json).collect())),
    ]);
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_serve.json"))
        .unwrap_or_else(|| "BENCH_serve.json".into());
    std::fs::write(&root, report.to_json()).expect("write BENCH_serve.json");
    println!("wrote {}", root.display());

    if !accept_pass {
        if cores < 4 {
            eprintln!(
                "WARNING: acceptance not met ({tput_ratio:.2}x) — only {cores} core(s), \
                 shards cannot run in parallel here; gate downgraded"
            );
            return;
        }
        if soft {
            eprintln!("WARNING: acceptance criterion not met ({tput_ratio:.2}x) (soft mode)");
            return;
        }
    }
    assert!(accept_pass, "acceptance: 4-shard {tput_ratio:.2}x < 2x or p99 regressed");
}
