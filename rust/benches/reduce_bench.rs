//! Reduction benchmark: the register-resident normalize workload — fused
//! map+reduce+normalize vs the materialized two-pass baseline — on the host
//! tier. NO artifacts required, runs on any machine.
//!
//! The workload is per-channel mean/std normalize of batched 1080p RGB
//! frames (`u8 -> scale -> (x-μ)/σ -> f32`). Two arms:
//!
//! * **fused** — the `chain::Normalize` preset: pass 1 folds mean AND
//!   sum-of-squares WHILE reading (one pass over the input, statistics in
//!   registers), pass 2 maps `(x-μ)/σ` with the statistics bound as
//!   scalars. Two memory passes total; nothing materializes in between.
//! * **materialized** — the op-at-a-time pattern the op vocabulary forced
//!   before the reduce subsystem: materialize the mapped tensor (one step
//!   kernel), sweep it once per statistic, then two more materialized
//!   per-channel steps (SubC, DivC) — five whole-buffer passes with a
//!   widening at every step boundary (the `run_npp_style` sweep idiom).
//!
//! Writes `BENCH_reduce.json` at the repo root and enforces the acceptance
//! bar: fused >= 2x the materialized baseline at batch 8 @ 1080p.
//!
//! ```sh
//! cargo bench --bench reduce_bench            # full sweep
//! FKL_BENCH_FAST=1 cargo bench --bench reduce_bench   # trimmed
//! ```

use std::time::Duration;

use fkl::bench::time_fn;
use fkl::chain::{Chain, Mul, U8};
use fkl::exec::HostFusedEngine;
use fkl::jsonlite::Value;
use fkl::ops::{kernel, Opcode, ReduceAxis, ScalarOp};
use fkl::proplite::Rng;
use fkl::tensor::{DType, Tensor};

const H: usize = 1080;
const W: usize = 1920;
const SCALE: f64 = 1.0 / 255.0;
const EPS: f64 = 1e-12;

/// One materialized op-at-a-time step: whole-buffer sweep in the f64
/// domain, result materialized back to f32 — the step-kernel boundary of
/// the original libraries.
fn sweep(t: &Tensor, op: ScalarOp) -> Tensor {
    let mut vals = t.to_f64_vec();
    op.apply_slice_f64(&mut vals, 0);
    Tensor::from_f64_cast(&vals, t.shape(), DType::F32)
}

/// The materialized two-pass baseline: the mapped tensor exists in memory,
/// each statistic is its own sweep over it, and the normalize is two more
/// materialized steps.
fn baseline_normalize(input: &Tensor) -> Tensor {
    // pass 1a: materialize the mapped tensor (convert + MulC as one step)
    let mapped = sweep(input, ScalarOp::Scalar { op: Opcode::Mul, param: SCALE });
    // pass 1b / 1c: one whole-buffer sweep per statistic
    let vals = mapped.to_f64_vec();
    let lane_n = (vals.len() / 3) as f64;
    let mut mu = [0f64; 3];
    for (i, &v) in vals.iter().enumerate() {
        mu[i % 3] += v;
    }
    for m in mu.iter_mut() {
        *m /= lane_n;
    }
    let mut sumsq = [0f64; 3];
    for (i, &v) in vals.iter().enumerate() {
        sumsq[i % 3] += v * v;
    }
    let mut sigma = [0f32; 3];
    for c in 0..3 {
        sigma[c] = kernel::normalize_sigma(mu[c], sumsq[c], vals.len() / 3, EPS) as f32;
    }
    let muf = [mu[0] as f32, mu[1] as f32, mu[2] as f32];
    drop(vals); // the widened copy dies at the step boundary
    // pass 2: two materialized per-channel steps (SubC, DivC)
    let sub = sweep(&mapped, ScalarOp::PerLane { op: Opcode::Sub, param: muf });
    sweep(&sub, ScalarOp::PerLane { op: Opcode::Div, param: sigma })
}

struct Point {
    label: String,
    batch: usize,
    materialized_ms: f64,
    fused_ms: f64,
}

impl Point {
    fn speedup(&self) -> f64 {
        self.materialized_ms / self.fused_ms
    }

    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("label", Value::str(&self.label)),
            ("batch", Value::num(self.batch as f64)),
            ("materialized_ms", Value::num(self.materialized_ms)),
            ("fused_ms", Value::num(self.fused_ms)),
            ("speedup_fused", Value::num(self.speedup())),
        ])
    }
}

fn measure(eng: &HostFusedEngine, b: usize, reps: usize, budget: Duration) -> Point {
    let mut rng = Rng::new(2024 + b as u64);
    let input = Tensor::from_u8(&rng.vec_u8(b * H * W * 3), &[b, H, W, 3]);
    let norm = Chain::normalize::<U8>(&[H, W, 3], ReduceAxis::PerChannel).batch(b).map(Mul(SCALE));

    // correctness guard: a benchmark of a wrong answer is meaningless —
    // fused must match the materialized baseline within float epsilon (the
    // two arms fold in different orders, so bitwise equality is the
    // ORACLE's job, not the baseline's)
    let fused = norm.run_host(eng, &input).expect("fused normalize on the host tier");
    let want = baseline_normalize(&input);
    assert_eq!(fused.shape(), want.shape());
    for (i, (a, w)) in fused.to_f64_vec().iter().zip(want.to_f64_vec()).enumerate() {
        assert!(
            (a - w).abs() <= 1e-3 + 1e-3 * w.abs(),
            "b{b} elem {i}: fused diverged from baseline ({a} vs {w})"
        );
    }

    let mat = time_fn(reps, budget, || baseline_normalize(&input));
    let fsd = time_fn(reps, budget, || norm.run_host(eng, &input).unwrap());
    let pt = Point {
        label: format!("normalize/b{b}/1080p"),
        batch: b,
        materialized_ms: mat.mean_s * 1e3,
        fused_ms: fsd.mean_s * 1e3,
    };
    println!(
        "{:24} | materialized {:>9.3} ms | fused {:>9.3} ms | {:>5.2}x",
        pt.label,
        pt.materialized_ms,
        pt.fused_ms,
        pt.speedup()
    );
    pt
}

fn main() {
    let fast = std::env::var("FKL_BENCH_FAST").is_ok();
    let (reps, budget) =
        if fast { (3, Duration::from_millis(900)) } else { (8, Duration::from_secs(3)) };
    // the host tier is the point of this bench: zero artifacts anywhere
    let eng = HostFusedEngine::new();
    println!("# reduce_bench — fused map+reduce normalize vs materialized two-pass (1080p)");

    let points: Vec<Point> = [1usize, 8].iter().map(|&b| measure(&eng, b, reps, budget)).collect();

    let accept = points.iter().find(|p| p.batch == 8).expect("sweep includes batch 8");
    let (accept_label, accept_speedup) = (accept.label.clone(), accept.speedup());
    let accept_pass = accept_speedup >= 2.0;
    println!(
        "\nacceptance: {accept_label} -> {accept_speedup:.2}x (target >= 2x): {}",
        if accept_pass { "PASS" } else { "FAIL" }
    );

    let report = Value::obj(vec![
        ("bench", Value::str("reduce")),
        ("frame", Value::str("1080x1920x3 u8, per-channel normalize")),
        ("fast_mode", Value::Bool(fast)),
        (
            "acceptance",
            Value::obj(vec![
                (
                    "criterion",
                    Value::str("fused >= 2x materialized two-pass baseline, batch 8 @ 1080p"),
                ),
                ("point", Value::str(&accept_label)),
                ("speedup", Value::num(accept_speedup)),
                ("pass", Value::Bool(accept_pass)),
            ]),
        ),
        ("series", Value::Arr(points.iter().map(Point::to_json).collect())),
    ]);

    // repo root (= parent of the crate dir), plus cwd as a convenience copy
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_reduce.json"))
        .unwrap_or_else(|| "BENCH_reduce.json".into());
    std::fs::write(&root, report.to_json()).expect("write BENCH_reduce.json");
    println!("wrote {}", root.display());

    // FKL_BENCH_SOFT turns the acceptance gate into a warning — wall-clock
    // asserts on shared CI runners are a flake source; local/bench runs keep
    // the hard gate
    if !accept_pass && std::env::var("FKL_BENCH_SOFT").is_ok() {
        eprintln!("WARNING: acceptance criterion not met: {accept_speedup:.2}x < 2x (soft mode)");
        return;
    }
    assert!(accept_pass, "acceptance criterion not met: {accept_speedup:.2}x < 2x");
}
