//! SIMD lane-blocking benchmark: register-blocked fused loops vs the
//! scalar arm.
//!
//! Both sides run the SAME single-pass fused engine — the ablation is the
//! register-block width alone (`HostFusedEngine::with_lane_width(1)` forces
//! the pre-SIMD scalar loops; the production engine runs each plan at its
//! compiled `vectorization` width). Measured at 1 thread so the speedup is
//! the pure lane effect, with a multi-thread column to show the two effects
//! compose.
//!
//! Sweeps the dense f32 fast arm (16-wide blocks), the oracle-exact u8 f64
//! arm (8-wide), the lane-group C3 arm (8 pixels = 24 lanes) and the
//! striped full-axis reduce — and writes `BENCH_simd.json` at the repo
//! root.
//!
//! ```sh
//! cargo bench --bench simd_bench            # full sweep
//! FKL_BENCH_FAST=1 cargo bench --bench simd_bench   # trimmed
//! ```

use std::time::Duration;

use fkl::bench::time_fn;
use fkl::chain::{build_erased_opcodes, Chain, CvtColor, Mul, MulC3, F32};
use fkl::exec::{Engine, HostFusedEngine};
use fkl::ops::{kernel, Opcode, Pipeline, ReduceKind};
use fkl::proplite::Rng;
use fkl::tensor::{DType, Tensor};

/// Contractive mixed chain (same shape as the host fusion bench's): values
/// stay tame at any depth, so the f32 epsilon guard is meaningful.
fn chain(k: usize) -> Vec<(Opcode, f64)> {
    let cycle = [
        (Opcode::Mul, 0.999),
        (Opcode::Add, 0.001),
        (Opcode::Sub, 0.0005),
        (Opcode::Max, -1000.0),
    ];
    (0..k).map(|i| cycle[i % cycle.len()]).collect()
}

struct Point {
    label: String,
    chain_len: usize,
    dtin: &'static str,
    elems: usize,
    lane_width: u8,
    scalar_1t_ms: f64,
    vector_1t_ms: f64,
    vector_mt_ms: f64,
}

impl Point {
    fn speedup_1t(&self) -> f64 {
        self.scalar_1t_ms / self.vector_1t_ms
    }

    fn to_json(&self) -> fkl::jsonlite::Value {
        use fkl::jsonlite::Value;
        Value::obj(vec![
            ("label", Value::str(&self.label)),
            ("chain_len", Value::num(self.chain_len as f64)),
            ("dtin", Value::str(self.dtin)),
            ("elems", Value::num(self.elems as f64)),
            ("lane_width", Value::num(self.lane_width as f64)),
            ("scalar_1t_ms", Value::num(self.scalar_1t_ms)),
            ("vector_1t_ms", Value::num(self.vector_1t_ms)),
            ("vector_mt_ms", Value::num(self.vector_mt_ms)),
            ("speedup_vector_1t", Value::num(self.speedup_1t())),
        ])
    }
}

fn measure(label: &str, p: &Pipeline, x: &Tensor, reps: usize, budget: Duration) -> Point {
    let scalar = HostFusedEngine::with_threads(1).with_lane_width(1);
    let vector = HostFusedEngine::with_threads(1);
    let vector_mt = HostFusedEngine::new();

    // correctness guard: width must be invisible in the results — bitwise
    // on f64-accumulated paths, float-epsilon on the f32 fast arm
    let s_out = scalar.run(p, x).expect("scalar-arm run");
    let v_out = vector.run(p, x).expect("vectorized run");
    let narrow = p.dtout == DType::F32;
    for (i, (a, b)) in s_out.to_f64_vec().iter().zip(v_out.to_f64_vec()).enumerate() {
        if narrow {
            assert!(
                (a - b).abs() <= 1e-4 + 1e-4 * b.abs(),
                "{label}: scalar vs vector diverged at {i} ({a} vs {b})"
            );
        } else {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{label}: f64 path must be bit-equal across widths ({a} vs {b})"
            );
        }
    }
    let width = vector.vector_width();

    let s1 = time_fn(reps, budget, || scalar.run(p, x).unwrap());
    let v1 = time_fn(reps, budget, || vector.run(p, x).unwrap());
    let vm = time_fn(reps, budget, || vector_mt.run(p, x).unwrap());
    let pt = Point {
        label: label.to_string(),
        chain_len: p.body().len(),
        dtin: p.dtin.name(),
        elems: p.batch * p.item_elems(),
        lane_width: width,
        scalar_1t_ms: s1.mean_s * 1e3,
        vector_1t_ms: v1.mean_s * 1e3,
        vector_mt_ms: vm.mean_s * 1e3,
    };
    println!(
        "{label:28} k={:<2} {:>9} elems | lanes {:>2} | scalar 1t {:>8.3} ms | vector 1t {:>8.3} ms ({:>5.2}x) | vector {}t {:>8.3} ms",
        pt.chain_len,
        pt.elems,
        pt.lane_width,
        pt.scalar_1t_ms,
        pt.vector_1t_ms,
        pt.speedup_1t(),
        vector_mt.threads(),
        pt.vector_mt_ms,
    );
    pt
}

fn main() {
    let fast = std::env::var("FKL_BENCH_FAST").is_ok();
    let (reps, budget) =
        if fast { (5, Duration::from_millis(200)) } else { (15, Duration::from_millis(700)) };
    let mut rng = Rng::new(7);
    println!(
        "# simd_bench — register-blocked vs scalar fused loops (simd: {}, f32 lanes {}, f64 lanes {})",
        kernel::simd_capability(),
        kernel::LANE_WIDTH_F32,
        kernel::LANE_WIDTH_F64,
    );

    let mut points: Vec<Point> = Vec::new();
    let (h, w) = (1080usize, 1920usize);
    let f32_frame = Tensor::from_f32(&rng.vec_f32(h * w, -2.0, 2.0), &[1, h, w]);
    let u8_frame = Tensor::from_u8(&rng.vec_u8(h * w), &[1, h, w]);

    // --- the acceptance point: f32 chain of 5 @ 1080p ----------------------
    let lens: &[usize] = if fast { &[5] } else { &[1, 2, 5, 8, 12] };
    for &k in lens {
        let p = build_erased_opcodes(&chain(k), &[h, w], 1, DType::F32, DType::F32);
        points.push(measure(&format!("f32/1080p/chain{k}"), &p, &f32_frame, reps, budget));
    }

    // --- oracle-exact f64 arm (8-wide blocks) ------------------------------
    let p = build_erased_opcodes(&chain(6), &[h, w], 1, DType::U8, DType::U8);
    points.push(measure("u8/1080p/chain6", &p, &u8_frame, reps, budget));

    // --- lane-group arm: C3 body over packed pixels (24-lane blocks) -------
    let (ph, pw) = (720usize, 960usize);
    let px_frame = Tensor::from_f32(&rng.vec_f32(ph * pw * 3, -2.0, 2.0), &[1, ph, pw, 3]);
    let p = Chain::read::<F32>(&[ph, pw, 3])
        .map(CvtColor)
        .map(MulC3([0.9, 1.05, 1.1]))
        .map(Mul(0.5))
        .cast::<fkl::chain::F64>()
        .write()
        .into_pipeline();
    points.push(measure("f32/720p/c3group", &p, &px_frame, reps, budget));

    // --- striped full-axis reduce ------------------------------------------
    let p = Chain::read::<F32>(&[h, w])
        .map(Mul(0.5))
        .reduce_pair(ReduceKind::Mean, ReduceKind::SumSq)
        .into_pipeline();
    points.push(measure("f32/1080p/meansumsq", &p, &f32_frame, reps, budget));

    // --- acceptance: vectorized >= 1.5x scalar on the f32 chain-5 ----------
    let accept = points
        .iter()
        .find(|pt| pt.dtin == "f32" && pt.chain_len == 5 && pt.elems >= 1 << 20)
        .expect("sweep includes the acceptance point");
    let accept_speedup = accept.speedup_1t();
    let accept_pass = accept_speedup >= 1.5;
    println!(
        "\nacceptance: f32 chain5 @ {} elems, lanes {} -> {accept_speedup:.2}x (target >= 1.5x): {}",
        accept.elems,
        accept.lane_width,
        if accept_pass { "PASS" } else { "FAIL" }
    );

    use fkl::jsonlite::Value;
    let report = Value::obj(vec![
        ("bench", Value::str("simd")),
        ("simd_capability", Value::str(kernel::simd_capability())),
        ("lane_width_f32", Value::num(kernel::LANE_WIDTH_F32 as f64)),
        ("lane_width_f64", Value::num(kernel::LANE_WIDTH_F64 as f64)),
        ("fast_mode", Value::Bool(fast)),
        (
            "acceptance",
            Value::obj(vec![
                ("criterion", Value::str("vectorized >= 1.5x scalar, f32 chain of 5 ops @ 1080p, 1t")),
                ("elems", Value::num(accept.elems as f64)),
                ("speedup", Value::num(accept_speedup)),
                ("pass", Value::Bool(accept_pass)),
            ]),
        ),
        ("series", Value::Arr(points.iter().map(Point::to_json).collect())),
    ]);

    // repo root (= parent of the crate dir)
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_simd.json"))
        .unwrap_or_else(|| "BENCH_simd.json".into());
    std::fs::write(&root, report.to_json()).expect("write BENCH_simd.json");
    println!("wrote {}", root.display());

    // FKL_BENCH_SOFT turns the acceptance gate into a warning — wall-clock
    // asserts on shared CI runners are a flake source
    if !accept_pass && std::env::var("FKL_BENCH_SOFT").is_ok() {
        eprintln!("WARNING: acceptance criterion not met: {accept_speedup:.2}x < 1.5x (soft mode)");
        return;
    }
    assert!(accept_pass, "acceptance criterion not met: {accept_speedup:.2}x < 1.5x");
}
