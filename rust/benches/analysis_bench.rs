//! Static-analysis overhead + canonicalization ablation benchmark.
//!
//! Two questions, one report (`BENCH_analysis.json`):
//!
//! * **Is analysis cheap enough for ingress?** `lint` + `canonicalize` run
//!   on EVERY admission when `ServiceConfig::canonicalize` is on, so the
//!   pair must stay well under the per-request serving cost. The gate: both
//!   together on a chain-12 pipeline in **< 5 us** per call.
//! * **What does canonicalization buy?** The same traffic — four
//!   syntactically distinct but bit-equivalent chain variants, round-robin
//!   — served with the ingress canonicalizer on vs off. On: every variant
//!   collapses to one canonical stream, so the engine compiles plans for
//!   ONE signature and stacked HF engages across variants. Off: every raw
//!   signature compiles its own plans and only same-variant requests stack.
//!
//! ```sh
//! cargo bench --bench analysis_bench
//! FKL_BENCH_FAST=1 cargo bench --bench analysis_bench   # trimmed
//! FKL_BENCH_SOFT=1 ...                                  # miss -> warning
//! ```

use std::time::{Duration, Instant};

use fkl::coordinator::{BatchPolicy, EngineSelect, MetricsSnapshot, Service, ServiceConfig};
use fkl::jsonlite::Value;
use fkl::ops::{Opcode, Pipeline};
use fkl::proplite::Rng;
use fkl::tensor::{DType, Tensor};

/// A 12-op chain salted with canonicalizer work (identities, a Neg;Neg
/// pair) — the analyzer's worst common case at ingress.
fn chain12() -> Pipeline {
    let ops: Vec<(Opcode, f64)> = vec![
        (Opcode::Nop, 0.0),
        (Opcode::Mul, 0.5),
        (Opcode::Mul, 1.0),
        (Opcode::Add, 3.0),
        (Opcode::Neg, 0.0),
        (Opcode::Neg, 0.0),
        (Opcode::Sub, 0.0),
        (Opcode::Div, 1.7),
        (Opcode::Sqrt, 0.0),
        (Opcode::Min, 200.0),
        (Opcode::Max, 0.0),
        (Opcode::Clamp01, 0.0),
    ];
    Pipeline::from_opcodes(&ops, &[60, 120], 1, DType::U8, DType::F32).unwrap()
}

/// Four bit-equivalent u8->f64 variants of one dense chain (the e2e test's
/// acceptance shape, sized up for throughput driving).
fn variants() -> Vec<Pipeline> {
    [
        vec![(Opcode::Mul, 0.5), (Opcode::Add, 1.0)],
        vec![(Opcode::Mul, 0.5), (Opcode::Mul, 1.0), (Opcode::Add, 1.0)],
        vec![(Opcode::Mul, 0.5), (Opcode::Neg, 0.0), (Opcode::Neg, 0.0), (Opcode::Add, 1.0)],
        vec![(Opcode::Nop, 0.0), (Opcode::Mul, 0.5), (Opcode::Add, 1.0), (Opcode::Sub, 0.0)],
    ]
    .iter()
    .map(|ops| Pipeline::from_opcodes(ops, &[24, 32], 1, DType::U8, DType::F64).unwrap())
    .collect()
}

fn drive(canonicalize: bool, n: usize) -> (f64, MetricsSnapshot) {
    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 8192,
        policy: BatchPolicy { max_batch: 50, window: Duration::from_micros(500), ..Default::default() },
        engine: EngineSelect::HostFused,
        canonicalize,
        ..ServiceConfig::default()
    });
    let ps = variants();
    let mut rng = Rng::new(7);
    // warmup (backend construction + first launch)
    let w = svc.submit(ps[0].clone(), Tensor::from_u8(&rng.vec_u8(24 * 32), &[1, 24, 32]));
    let _ = w.unwrap().recv();

    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(n);
    for i in 0..n {
        let item = Tensor::from_u8(&rng.vec_u8(24 * 32), &[1, 24, 32]);
        if let Ok(rx) = svc.submit(ps[i % ps.len()].clone(), item) {
            pending.push(rx);
        }
    }
    let mut ok = 0usize;
    for rx in pending {
        if rx.recv().map(|r| r.is_ok()).unwrap_or(false) {
            ok += 1;
        }
    }
    let rps = ok as f64 / t0.elapsed().as_secs_f64();
    let m = svc.metrics().unwrap();
    svc.shutdown();
    assert_eq!(ok, n, "canonicalize={canonicalize}: every request must serve");
    (rps, m)
}

fn ablation_json(label: &str, rps: f64, m: &MetricsSnapshot) -> Value {
    Value::obj(vec![
        ("label", Value::str(label)),
        ("req_per_s", Value::num(rps)),
        ("plan_cache", Value::num(m.planner.plan_cache as f64)),
        ("mean_batch", Value::num(m.mean_batch())),
        ("lints_emitted", Value::num(m.lints_emitted as f64)),
        ("rewrites_applied", Value::num(m.rewrites_applied as f64)),
        ("canonical_cache_hits", Value::num(m.canonical_cache_hits as f64)),
    ])
}

fn main() {
    let fast = std::env::var("FKL_BENCH_FAST").is_ok();

    // part 1: lint + canonicalize per-call cost on the chain-12 pipeline
    let p = chain12();
    let iters = if fast { 20_000 } else { 100_000 };
    let mut sink = 0usize; // consume results so the loop cannot be elided
    for _ in 0..1_000 {
        sink += fkl::analysis::lint(&p).len();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        let diags = fkl::analysis::lint(&p);
        let (canon, rewrites) = fkl::analysis::canonicalize(p.clone());
        sink += diags.len() + rewrites.len() + canon.body().len();
    }
    let per_call_us = t0.elapsed().as_secs_f64() * 1e6 / iters as f64;
    assert!(sink > 0);
    println!("# analysis_bench (chain-12 u8->f32, {iters} iters)");
    println!("lint+canonicalize: {per_call_us:.3} us/call (gate < 5 us)");

    // part 2: plan-cache ablation — identical traffic, canonicalizer on/off
    let n = if fast { 800 } else { 3000 };
    let (rps_off, m_off) = drive(false, n);
    let (rps_on, m_on) = drive(true, n);
    println!("\n{:>6} | {:>10} {:>10} {:>10} {:>10}", "canon", "req/s", "plans", "mean_b", "hits");
    println!(
        "{:>6} | {:>10.0} {:>10} {:>10.1} {:>10}",
        "off",
        rps_off,
        m_off.planner.plan_cache,
        m_off.mean_batch(),
        m_off.canonical_cache_hits
    );
    println!(
        "{:>6} | {:>10.0} {:>10} {:>10.1} {:>10}",
        "on",
        rps_on,
        m_on.planner.plan_cache,
        m_on.mean_batch(),
        m_on.canonical_cache_hits
    );

    let gate_pass = per_call_us < 5.0;
    let cache_pass = m_on.planner.plan_cache < m_off.planner.plan_cache;
    let hit_rate = m_on.canonical_cache_hits as f64 / n as f64;
    println!(
        "\nacceptance: {per_call_us:.3} us/call (< 5 us): {}; plan_cache {} < {}: {}; \
         canonical hit rate {hit_rate:.3}",
        if gate_pass { "PASS" } else { "FAIL" },
        m_on.planner.plan_cache,
        m_off.planner.plan_cache,
        if cache_pass { "PASS" } else { "FAIL" }
    );

    let report = Value::obj(vec![
        ("bench", Value::str("analysis")),
        ("traffic", Value::str("4 equivalent u8->f64 chain variants, round-robin")),
        ("fast_mode", Value::Bool(fast)),
        ("requests", Value::num(n as f64)),
        ("lint_canon_us_per_call", Value::num(per_call_us)),
        ("canonical_hit_rate", Value::num(hit_rate)),
        (
            "acceptance",
            Value::obj(vec![
                (
                    "criterion",
                    Value::str(
                        "lint+canonicalize < 5us per chain-12 call AND canon-on compiles \
                         fewer plans than canon-off",
                    ),
                ),
                ("per_call_us", Value::num(per_call_us)),
                ("pass", Value::Bool(gate_pass && cache_pass)),
            ]),
        ),
        (
            "series",
            Value::Arr(vec![
                ablation_json("canon-off", rps_off, &m_off),
                ablation_json("canon-on", rps_on, &m_on),
            ]),
        ),
    ]);

    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_analysis.json"))
        .unwrap_or_else(|| "BENCH_analysis.json".into());
    std::fs::write(&root, report.to_json()).expect("write BENCH_analysis.json");
    println!("wrote {}", root.display());

    // wall-clock gates flake on shared CI runners; FKL_BENCH_SOFT keeps the
    // signal as a warning there while local runs enforce the bar
    let pass = gate_pass && cache_pass;
    if !pass && std::env::var("FKL_BENCH_SOFT").is_ok() {
        eprintln!(
            "WARNING: acceptance not met (soft mode): per_call={per_call_us:.3}us \
             plans on/off={}/{}",
            m_on.planner.plan_cache, m_off.planner.plan_cache
        );
        return;
    }
    assert!(
        pass,
        "acceptance not met: per_call={per_call_us:.3}us (< 5us), plans on/off={}/{}",
        m_on.planner.plan_cache,
        m_off.planner.plan_cache
    );
}
