//! Preproc benchmark: the paper's flagship NPP comparison (Fig. 24/25)
//! measured on the host tier — NO artifacts required, runs on any machine.
//!
//! The workload is the production preprocessing pipeline
//! Batch(Crop+Resize -> ColorConvert -> MulC -> SubC -> DivC -> Split) over
//! a 1080p frame, at several target sizes. Two arms:
//!
//! * **fused** — `PreprocPipeline::run` on the host fused engine: per crop,
//!   ONE pass that gathers bilinearly while reading, folds the chain in
//!   registers and scatters planar while writing; no intermediate ever
//!   touches memory;
//! * **npp-style** — `run_npp_style`: one whole-buffer pass per step per
//!   crop (crop, convert, resize, cvtcolor, mulc, subc, divc, split), every
//!   intermediate materialized — the op-at-a-time traffic pattern of the
//!   original libraries.
//!
//! Writes `BENCH_preproc.json` at the repo root and enforces the acceptance
//! bar: fused >= 2x op-at-a-time on the canonical point (batch 8 @ 128x128).
//!
//! ```sh
//! cargo bench --bench preproc_bench            # full sweep
//! FKL_BENCH_FAST=1 cargo bench --bench preproc_bench   # trimmed
//! ```

use std::time::Duration;

use fkl::bench::time_fn;
use fkl::cv::Context;
use fkl::exec::EngineSelect;
use fkl::hostref;
use fkl::jsonlite::Value;
use fkl::npp::{PreprocPipeline, ResizeBatchSpec};
use fkl::tensor::{make_frame, Rect, Tensor};

struct Point {
    label: String,
    batch: usize,
    dst_h: usize,
    dst_w: usize,
    npp_style_ms: f64,
    fused_ms: f64,
}

impl Point {
    fn speedup(&self) -> f64 {
        self.npp_style_ms / self.fused_ms
    }

    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("label", Value::str(&self.label)),
            ("batch", Value::num(self.batch as f64)),
            ("dst_h", Value::num(self.dst_h as f64)),
            ("dst_w", Value::num(self.dst_w as f64)),
            ("npp_style_ms", Value::num(self.npp_style_ms)),
            ("fused_ms", Value::num(self.fused_ms)),
            ("speedup_fused", Value::num(self.speedup())),
        ])
    }
}

fn rects_for(b: usize) -> Vec<Rect> {
    (0..b)
        .map(|i| Rect::new((i as i32 * 131) % 1600, (i as i32 * 71) % 900, 240, 120))
        .collect()
}

fn measure(
    ctx: &Context,
    frame: &Tensor,
    b: usize,
    dh: usize,
    dw: usize,
    reps: usize,
    budget: Duration,
) -> Point {
    let rects = rects_for(b);
    let pipe = PreprocPipeline::new(
        ResizeBatchSpec { rects: rects.clone(), dst_h: dh, dst_w: dw },
        [0.9, 1.0, 1.1],
        [0.5, 0.4, 0.3],
        [2.0, 2.1, 2.2],
    );

    // correctness guard: a benchmark of a wrong answer is meaningless —
    // fused must match the independent Fig. 25 oracle within epsilon
    let fused = pipe.run(ctx, frame).expect("fused preproc on the host tier");
    let want =
        hostref::preproc(frame, &rects, [0.9, 1.0, 1.1], [0.5, 0.4, 0.3], [2.0, 2.1, 2.2], dh, dw);
    assert_eq!(fused.shape(), want.shape());
    for (i, (a, w)) in fused.to_f64_vec().iter().zip(want.to_f64_vec()).enumerate() {
        assert!(
            (a - w).abs() <= 1e-3 + 1e-3 * w.abs(),
            "b{b} {dh}x{dw} elem {i}: fused diverged from oracle ({a} vs {w})"
        );
    }

    let npp = time_fn(reps, budget, || pipe.run_npp_style(ctx, frame).unwrap());
    let fsd = time_fn(reps, budget, || pipe.run(ctx, frame).unwrap());
    let pt = Point {
        label: format!("preproc/b{b}/{dh}x{dw}"),
        batch: b,
        dst_h: dh,
        dst_w: dw,
        npp_style_ms: npp.mean_s * 1e3,
        fused_ms: fsd.mean_s * 1e3,
    };
    println!(
        "{:28} | npp-style {:>9.3} ms | fused {:>9.3} ms | {:>5.2}x",
        pt.label,
        pt.npp_style_ms,
        pt.fused_ms,
        pt.speedup()
    );
    pt
}

fn main() {
    let fast = std::env::var("FKL_BENCH_FAST").is_ok();
    let (reps, budget) =
        if fast { (5, Duration::from_millis(300)) } else { (15, Duration::from_millis(900)) };
    // the host tier is the point of this bench: zero artifacts anywhere
    let ctx = Context::with_select(EngineSelect::HostFused, None)
        .expect("host backend always comes up");
    let frame = make_frame(1080, 1920, 42);
    println!("# preproc_bench — fused host preproc vs NPP-style op-at-a-time (1080p frame)");

    let mut points: Vec<Point> = Vec::new();
    let sizes: &[(usize, usize)] =
        if fast { &[(64, 64), (128, 128)] } else { &[(64, 64), (128, 128), (224, 224)] };
    let batches: &[usize] = if fast { &[8] } else { &[2, 8, 32] };
    for &(dh, dw) in sizes {
        for &b in batches {
            points.push(measure(&ctx, &frame, b, dh, dw, reps, budget));
        }
    }
    // the acceptance point is part of every sweep shape
    if !points.iter().any(|p| p.batch == 8 && p.dst_h == 128) {
        points.push(measure(&ctx, &frame, 8, 128, 128, reps, budget));
    }

    let accept = points
        .iter()
        .find(|p| p.batch == 8 && p.dst_h == 128 && p.dst_w == 128)
        .expect("sweep includes the acceptance point");
    let (accept_label, accept_speedup) = (accept.label.clone(), accept.speedup());
    let accept_pass = accept_speedup >= 2.0;
    println!(
        "\nacceptance: {accept_label} -> {accept_speedup:.2}x (target >= 2x): {}",
        if accept_pass { "PASS" } else { "FAIL" }
    );

    let report = Value::obj(vec![
        ("bench", Value::str("preproc")),
        ("frame", Value::str("1080x1920x3 u8")),
        ("fast_mode", Value::Bool(fast)),
        (
            "acceptance",
            Value::obj(vec![
                ("criterion", Value::str("fused >= 2x npp-style op-at-a-time, batch 8 @ 128x128")),
                ("point", Value::str(&accept_label)),
                ("speedup", Value::num(accept_speedup)),
                ("pass", Value::Bool(accept_pass)),
            ]),
        ),
        ("series", Value::Arr(points.iter().map(Point::to_json).collect())),
    ]);

    // repo root (= parent of the crate dir), plus cwd as a convenience copy
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_preproc.json"))
        .unwrap_or_else(|| "BENCH_preproc.json".into());
    std::fs::write(&root, report.to_json()).expect("write BENCH_preproc.json");
    println!("wrote {}", root.display());

    // FKL_BENCH_SOFT turns the acceptance gate into a warning — wall-clock
    // asserts on shared CI runners are a flake source; local/bench runs keep
    // the hard gate
    if !accept_pass && std::env::var("FKL_BENCH_SOFT").is_ok() {
        eprintln!("WARNING: acceptance criterion not met: {accept_speedup:.2}x < 2x (soft mode)");
        return;
    }
    assert!(accept_pass, "acceptance criterion not met: {accept_speedup:.2}x < 2x");
}
