//! Host fusion benchmark: the paper's VF claim measured on the CPU.
//!
//! Compares three executions of the same chain over 1080p-scale buffers:
//!
//! * **op-at-a-time** — `hostref::run_pipeline`: widen the whole buffer, one
//!   read+write sweep per op (the unfused memory traffic pattern);
//! * **fused (1 thread)** — `HostFusedEngine::with_threads(1)`: one memory
//!   pass, intermediates in registers — the pure VF effect;
//! * **fused (N threads)** — `HostFusedEngine::new()`: the same pass with
//!   the element range chunked across cores — VF + the HF analog.
//!
//! Sweeps chain lengths 1..=16 (paper Fig. 17: speedup grows with chain
//! depth because fused traffic is constant while unfused traffic is linear
//! in k) and writes `BENCH_host_fusion.json` at the repo root.
//!
//! ```sh
//! cargo bench --bench host_fusion_bench            # full sweep
//! FKL_BENCH_FAST=1 cargo bench --bench host_fusion_bench   # trimmed
//! ```

use std::time::Duration;

use fkl::bench::time_fn;
use fkl::chain::{build_erased_opcodes, Chain, ConvertTo, Div, Mul, Sub, F32, U8};
use fkl::exec::{Engine, HostFusedEngine};
use fkl::hostref;
use fkl::jsonlite::Value;
use fkl::ops::{Opcode, Pipeline};
use fkl::proplite::Rng;
use fkl::tensor::{DType, Tensor};

/// Contractive mixed chain: values stay in a tame range at any depth.
fn chain(k: usize) -> Vec<(Opcode, f64)> {
    let cycle = [
        (Opcode::Mul, 0.999),
        (Opcode::Add, 0.001),
        (Opcode::Sub, 0.0005),
        (Opcode::Max, -1000.0),
    ];
    (0..k).map(|i| cycle[i % cycle.len()]).collect()
}

struct Point {
    label: String,
    chain_len: usize,
    dtin: &'static str,
    dtout: &'static str,
    elems: usize,
    batch: usize,
    op_at_a_time_ms: f64,
    fused_1t_ms: f64,
    fused_mt_ms: f64,
}

impl Point {
    fn speedup_1t(&self) -> f64 {
        self.op_at_a_time_ms / self.fused_1t_ms
    }

    fn speedup_mt(&self) -> f64 {
        self.op_at_a_time_ms / self.fused_mt_ms
    }

    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("label", Value::str(&self.label)),
            ("chain_len", Value::num(self.chain_len as f64)),
            ("dtin", Value::str(self.dtin)),
            ("dtout", Value::str(self.dtout)),
            ("elems", Value::num(self.elems as f64)),
            ("batch", Value::num(self.batch as f64)),
            ("op_at_a_time_ms", Value::num(self.op_at_a_time_ms)),
            ("fused_1t_ms", Value::num(self.fused_1t_ms)),
            ("fused_mt_ms", Value::num(self.fused_mt_ms)),
            ("speedup_fused_1t", Value::num(self.speedup_1t())),
            ("speedup_fused_mt", Value::num(self.speedup_mt())),
        ])
    }
}

#[allow(clippy::too_many_arguments)]
fn measure(
    label: &str,
    p: &Pipeline,
    x: &Tensor,
    eng_1t: &HostFusedEngine,
    eng_mt: &HostFusedEngine,
    reps: usize,
    budget: Duration,
) -> Point {
    // correctness guard: a benchmark of a wrong answer is meaningless
    let fused = eng_1t.run(p, x).expect("fused run");
    let want = hostref::run_pipeline(p, x);
    for (a, b) in fused.to_f64_vec().iter().zip(want.to_f64_vec()) {
        assert!(
            (a - b).abs() <= 1e-4 + 1e-4 * b.abs(),
            "{label}: fused diverged from oracle ({a} vs {b})"
        );
    }

    let base = time_fn(reps, budget, || hostref::run_pipeline(p, x));
    let f1 = time_fn(reps, budget, || eng_1t.run(p, x).unwrap());
    let fm = time_fn(reps, budget, || eng_mt.run(p, x).unwrap());
    let pt = Point {
        label: label.to_string(),
        chain_len: p.body().len(),
        dtin: p.dtin.name(),
        dtout: p.dtout.name(),
        elems: p.batch * p.item_elems(),
        batch: p.batch,
        op_at_a_time_ms: base.mean_s * 1e3,
        fused_1t_ms: f1.mean_s * 1e3,
        fused_mt_ms: fm.mean_s * 1e3,
    };
    println!(
        "{label:32} k={:<2} {:>9} elems | op-at-a-time {:>8.3} ms | fused 1t {:>8.3} ms ({:>5.2}x) | fused {}t {:>8.3} ms ({:>5.2}x)",
        pt.chain_len,
        pt.elems,
        pt.op_at_a_time_ms,
        pt.fused_1t_ms,
        pt.speedup_1t(),
        eng_mt.threads(),
        pt.fused_mt_ms,
        pt.speedup_mt(),
    );
    pt
}

fn main() {
    let fast = std::env::var("FKL_BENCH_FAST").is_ok();
    let (reps, budget) =
        if fast { (5, Duration::from_millis(200)) } else { (15, Duration::from_millis(700)) };
    let eng_1t = HostFusedEngine::with_threads(1);
    let eng_mt = HostFusedEngine::new();
    let mut rng = Rng::new(1);
    println!(
        "# host_fusion_bench — single-pass fused vs op-at-a-time (threads: {})",
        eng_mt.threads()
    );

    let mut points: Vec<Point> = Vec::new();

    // --- chain-length sweep on a 1080p f32 frame ---------------------------
    let (h, w) = (1080usize, 1920usize);
    let f32_frame = Tensor::from_f32(&rng.vec_f32(h * w, -2.0, 2.0), &[1, h, w]);
    let lens: &[usize] = if fast { &[1, 5, 16] } else { &[1, 2, 3, 4, 5, 6, 8, 12, 16] };
    for &k in lens {
        let p = build_erased_opcodes(&chain(k), &[h, w], 1, DType::F32, DType::F32);
        points.push(measure(
            &format!("f32/1080p/chain{k}"),
            &p,
            &f32_frame,
            &eng_1t,
            &eng_mt,
            reps,
            budget,
        ));
    }

    // --- the acceptance point: f32, 5 ops, >= 1M elements ------------------
    let (accept_elems, accept_speedup) = {
        let pt = points
            .iter()
            .find(|pt| pt.dtin == "f32" && pt.chain_len == 5 && pt.elems >= 1 << 20)
            .expect("sweep includes the acceptance point");
        (pt.elems, pt.speedup_mt().max(pt.speedup_1t()))
    };
    let accept_pass = accept_speedup >= 2.0;

    // --- u8 -> f32 normalization (the paper's production preprocessing) ----
    let u8_frame = Tensor::from_u8(&rng.vec_u8(h * w), &[1, h, w]);
    let p = Chain::read::<U8>(&[h, w])
        .map(ConvertTo)
        .map(Mul(1.0 / 255.0))
        .map(Sub(0.45))
        .map(Div(0.226))
        .cast::<F32>()
        .write()
        .into_pipeline();
    points.push(measure("u8f32/1080p/normalize", &p, &u8_frame, &eng_1t, &eng_mt, reps, budget));

    // --- u8 -> u8 (oracle-exact f64 accumulation path) ---------------------
    let p = build_erased_opcodes(&chain(6), &[h, w], 1, DType::U8, DType::U8);
    points.push(measure("u8/1080p/chain6", &p, &u8_frame, &eng_1t, &eng_mt, reps, budget));

    // --- HF analog: batch of 64 camera crops -------------------------------
    let (bh, bw, b) = (256usize, 256usize, 64usize);
    let batch_in = Tensor::from_f32(&rng.vec_f32(b * bh * bw, -2.0, 2.0), &[b, bh, bw]);
    let p = build_erased_opcodes(&chain(5), &[bh, bw], b, DType::F32, DType::F32);
    points.push(measure("f32/batch64x256x256/chain5", &p, &batch_in, &eng_1t, &eng_mt, reps, budget));

    // --- report ------------------------------------------------------------
    println!(
        "\nacceptance: f32 chain5 @ {accept_elems} elems -> {accept_speedup:.2}x (target >= 2x): {}",
        if accept_pass { "PASS" } else { "FAIL" }
    );

    let report = Value::obj(vec![
        ("bench", Value::str("host_fusion")),
        ("threads", Value::num(eng_mt.threads() as f64)),
        ("fast_mode", Value::Bool(fast)),
        (
            "acceptance",
            Value::obj(vec![
                ("criterion", Value::str("fused >= 2x op-at-a-time, f32 chain of 5 ops, >= 1M elems")),
                ("elems", Value::num(accept_elems as f64)),
                ("speedup", Value::num(accept_speedup)),
                ("pass", Value::Bool(accept_pass)),
            ]),
        ),
        ("series", Value::Arr(points.iter().map(Point::to_json).collect())),
    ]);

    // repo root (= parent of the crate dir), plus cwd as a convenience copy
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_host_fusion.json"))
        .unwrap_or_else(|| "BENCH_host_fusion.json".into());
    std::fs::write(&root, report.to_json()).expect("write BENCH_host_fusion.json");
    println!("wrote {}", root.display());

    // FKL_BENCH_SOFT turns the acceptance gate into a warning — wall-clock
    // asserts on shared CI runners are a flake source; local/bench runs keep
    // the hard gate
    if !accept_pass && std::env::var("FKL_BENCH_SOFT").is_ok() {
        eprintln!("WARNING: acceptance criterion not met: {accept_speedup:.2}x < 2x (soft mode)");
        return;
    }
    assert!(accept_pass, "acceptance criterion not met: {accept_speedup:.2}x < 2x");
}
