//! Tracing overhead benchmark: what does the span recorder cost when armed?
//!
//! Two configurations drive the same CMSD traffic through the service:
//!
//! * `tracing-off` — `ServiceConfig::tracing` is `None`, so the serving hot
//!   path carries no tracing code at all (the `Option` pattern);
//! * `tracing-on`  — a [`Tracer`] records the full span tree of every
//!   request (seven fixed-size slot writes per request into the
//!   preallocated ring — no allocation, one short mutex each).
//!
//! Writes `BENCH_trace.json` at the repo root and enforces the acceptance
//! bar: tracing-armed throughput >= 0.95x tracing-off (a flight recorder
//! that taxes the flight is a bad instrument).
//!
//! ```sh
//! cargo bench --bench trace_bench
//! FKL_BENCH_FAST=1 cargo bench --bench trace_bench   # trimmed
//! FKL_BENCH_SOFT=1 ...                               # miss -> warning
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use fkl::chain::{Chain, ConvertTo, Div, Mul, Sub, F32, U8};
use fkl::coordinator::{BatchPolicy, MetricsSnapshot, Service, ServiceConfig};
use fkl::jsonlite::Value;
use fkl::ops::Pipeline;
use fkl::proplite::Rng;
use fkl::tensor::Tensor;
use fkl::trace::Tracer;

fn pipeline() -> Pipeline {
    Chain::read::<U8>(&[60, 120])
        .map(ConvertTo)
        .map(Mul(0.5))
        .map(Sub(3.0))
        .map(Div(1.7))
        .cast::<F32>()
        .write()
        .into_pipeline()
}

struct Point {
    label: &'static str,
    rps: f64,
    p50_us: u64,
    p99_us: u64,
    spans: usize,
    metrics: MetricsSnapshot,
}

impl Point {
    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("label", Value::str(self.label)),
            ("req_per_s", Value::num(self.rps)),
            ("p50_us", Value::num(self.p50_us as f64)),
            ("p99_us", Value::num(self.p99_us as f64)),
            ("spans_recorded", Value::num(self.spans as f64)),
            ("launches", Value::num(self.metrics.launches as f64)),
            ("fusion_efficiency", Value::num(self.metrics.fusion_efficiency())),
            ("tier_plan_us", Value::num(self.metrics.tier_time_us.plan as f64)),
        ])
    }
}

fn drive(label: &'static str, tracer: Option<Arc<Tracer>>, n: usize) -> Point {
    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 8192,
        policy: BatchPolicy { max_batch: 50, window: Duration::from_micros(500), ..Default::default() },
        tracing: tracer.clone(),
        ..ServiceConfig::default()
    });
    let p = pipeline();
    let mut rng = Rng::new(3);
    // warmup (backend construction + first launch)
    let w = svc.submit(p.clone(), Tensor::from_u8(&rng.vec_u8(7200), &[1, 60, 120])).unwrap();
    let _ = w.recv();

    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(n);
    for _ in 0..n {
        let item = Tensor::from_u8(&rng.vec_u8(7200), &[1, 60, 120]);
        if let Ok(rx) = svc.submit(p.clone(), item) {
            pending.push(rx);
        }
    }
    let mut ok = 0usize;
    for rx in pending {
        if rx.recv().map(|r| r.is_ok()).unwrap_or(false) {
            ok += 1;
        }
    }
    let rps = ok as f64 / t0.elapsed().as_secs_f64();
    let m = svc.metrics().unwrap();
    svc.shutdown();
    assert_eq!(ok, n, "{label}: every request must be served");
    let spans = tracer.map(|tr| tr.span_count()).unwrap_or(0);
    Point { label, rps, p50_us: m.latency.p50, p99_us: m.latency.p99, spans, metrics: m }
}

fn main() {
    let fast = std::env::var("FKL_BENCH_FAST").is_ok();
    let n = if fast { 600 } else { 3000 };
    println!("# trace_bench (CMSD 60x120 u8->f32, max_batch 50, window 500us, n={n})");
    println!("{:>12} | {:>10} {:>8} {:>8} {:>8}", "config", "req/s", "p50_us", "p99_us", "spans");

    // a ring big enough that nothing is overwritten mid-run: the recorder
    // pays its full slot-write cost for every one of the ~7(n+1) spans
    let tracer = Arc::new(Tracer::with_capacity(8 * (n + 8)));
    let points = [drive("tracing-off", None, n), drive("tracing-on", Some(tracer.clone()), n)];
    for pt in &points {
        println!(
            "{:>12} | {:>10.0} {:>8} {:>8} {:>8}",
            pt.label, pt.rps, pt.p50_us, pt.p99_us, pt.spans
        );
    }
    // every request closes at least root/admit/queue/tier/reply plus the
    // launch (the plan span depends on which backend served)
    assert!(
        points[1].spans >= 6 * n,
        "tracing-on recorded the whole session: {} spans",
        points[1].spans
    );

    let baseline = points[0].rps;
    let armed = points[1].rps;
    let ratio = armed / baseline;
    let accept_pass = ratio >= 0.95;
    println!(
        "\nacceptance: tracing-on/tracing-off = {ratio:.3}x (target >= 0.95x): {}",
        if accept_pass { "PASS" } else { "FAIL" }
    );

    let report = Value::obj(vec![
        ("bench", Value::str("trace")),
        ("traffic", Value::str("CMSD 60x120 u8->f32 single-item requests")),
        ("fast_mode", Value::Bool(fast)),
        ("requests", Value::num(n as f64)),
        (
            "acceptance",
            Value::obj(vec![
                ("criterion", Value::str("tracing-armed >= 0.95x tracing-off throughput")),
                ("ratio", Value::num(ratio)),
                ("pass", Value::Bool(accept_pass)),
            ]),
        ),
        ("series", Value::Arr(points.iter().map(Point::to_json).collect())),
    ]);

    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_trace.json"))
        .unwrap_or_else(|| "BENCH_trace.json".into());
    std::fs::write(&root, report.to_json()).expect("write BENCH_trace.json");
    println!("wrote {}", root.display());

    // wall-clock ratios flake on shared CI runners; FKL_BENCH_SOFT keeps the
    // signal as a warning there while local runs enforce the bar
    if !accept_pass && std::env::var("FKL_BENCH_SOFT").is_ok() {
        eprintln!("WARNING: acceptance criterion not met: {ratio:.3}x < 0.95x (soft mode)");
        return;
    }
    assert!(accept_pass, "acceptance criterion not met: {ratio:.3}x < 0.95x");
}
