//! Divergent-HF benchmark: a MIXED 1080p serving window — different crops,
//! resizes, normalize-map chains and a reduce, no stackable company — per
//! item vs ONE divergent pass. NO artifacts required, runs on any machine.
//!
//! The workload is the paper's AutomaticTV shape: many small regions of
//! interest cut from a shared 1080p frame, each through its OWN pipeline.
//! Every item is below the engine's per-run threading threshold, so
//! per-item serving is inherently serial — exactly the traffic the
//! identical-signature HF tier cannot help with (nothing stacks) and the
//! divergent tier exists for: the window chunks across worker lanes and
//! the whole machine fills with independent fused lanes.
//!
//! Writes `BENCH_divergent.json` at the repo root and enforces the
//! acceptance bar: divergent >= 1.5x per-item serving at window 8.
//!
//! ```sh
//! cargo bench --bench divergent_bench            # full sweep
//! FKL_BENCH_FAST=1 cargo bench --bench divergent_bench   # trimmed
//! FKL_BENCH_SOFT=1 ...                           # downgrade a miss to a warning
//! ```

use std::time::Duration;

use fkl::bench::time_fn;
use fkl::chain::{Add, Chain, CvtColor, DivC3, Mul, MulC3, SubC3, F32, U8};
use fkl::exec::{Engine, HostFusedEngine};
use fkl::jsonlite::Value;
use fkl::ops::{Pipeline, ReduceKind};
use fkl::proplite::Rng;
use fkl::tensor::{make_frame, Rect, Tensor};

const FRAME_H: usize = 1080;
const FRAME_W: usize = 1920;

/// The mixed window: four signature families cycled with per-index params
/// and rects (the crop family shares a signature but never params — rects
/// are runtime parameters — so nothing in the window stacks).
fn window(n: usize, frame: &Tensor, rng: &mut Rng) -> Vec<(Pipeline, Tensor)> {
    (0..n)
        .map(|i| {
            let x = (17 * i % (FRAME_W - 200)) as i32;
            let y = (29 * i % (FRAME_H - 200)) as i32;
            match i % 4 {
                0 => {
                    // crop -> scalar math -> f32
                    let p = Chain::read_crop::<U8>(Rect::new(x, y, 96, 96))
                        .map(Mul(1.0 / 255.0))
                        .map(Add(0.01 * i as f64))
                        .cast::<F32>()
                        .write()
                        .into_pipeline();
                    (p, frame.clone())
                }
                1 => {
                    // resize -> preproc chain -> planar f32 (the flagship)
                    let p = Chain::read_resize::<U8>(Rect::new(x, y, 180, 120), 64, 64)
                        .map(CvtColor)
                        .map(MulC3([1.0 / 255.0; 3]))
                        .map(SubC3([0.485, 0.456, 0.406]))
                        .map(DivC3([0.229, 0.224, 0.225]))
                        .cast::<F32>()
                        .write_split()
                        .into_pipeline();
                    (p, frame.clone())
                }
                2 => {
                    // dense normalize-map pass over a private tile
                    let p = Chain::read::<U8>(&[64, 64, 3])
                        .map(Mul(1.0 / 255.0))
                        .map(SubC3([0.5, 0.4, 0.3]))
                        .map(DivC3([0.2, 0.25, 0.3]))
                        .cast::<F32>()
                        .write()
                        .into_pipeline();
                    (p, Tensor::from_u8(&rng.vec_u8(64 * 64 * 3), &[1, 64, 64, 3]))
                }
                _ => {
                    // crop -> per-channel stats in the same sweep
                    let p = Chain::read_crop::<U8>(Rect::new(x, y, 96, 96))
                        .map(Mul(1.0 / 255.0))
                        .reduce_pair_per_channel(ReduceKind::Mean, ReduceKind::SumSq)
                        .into_pipeline();
                    (p, frame.clone())
                }
            }
        })
        .collect()
}

struct Point {
    label: String,
    window: usize,
    per_item_ms: f64,
    divergent_ms: f64,
    lanes: usize,
    occupancy: f64,
}

impl Point {
    fn speedup(&self) -> f64 {
        self.per_item_ms / self.divergent_ms
    }

    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("label", Value::str(&self.label)),
            ("window", Value::num(self.window as f64)),
            ("per_item_ms", Value::num(self.per_item_ms)),
            ("divergent_ms", Value::num(self.divergent_ms)),
            ("speedup_divergent", Value::num(self.speedup())),
            ("lanes", Value::num(self.lanes as f64)),
            ("occupancy", Value::num(self.occupancy)),
        ])
    }
}

fn measure(eng: &HostFusedEngine, n: usize, reps: usize, budget: Duration) -> Point {
    let mut rng = Rng::new(1080 + n as u64);
    let frame = make_frame(FRAME_H, FRAME_W, 7);
    let reqs = window(n, &frame, &mut rng);
    let refs: Vec<(&Pipeline, &Tensor)> = reqs.iter().map(|(p, t)| (p, t)).collect();

    // correctness guard: a benchmark of a wrong answer is meaningless — the
    // divergent pass must be BIT-equal to per-item serving on every item
    let out = eng.run_divergent(&refs);
    let (lanes, occupancy) = (out.lanes, out.occupancy());
    for (i, ((p, t), res)) in refs.iter().zip(&out.results).enumerate() {
        let alone = eng.run(p, t).expect("per-item serving works");
        assert_eq!(res.as_ref().unwrap(), &alone, "w{n} item {i}: divergent != per-item");
    }

    let per = time_fn(reps, budget, || {
        for (p, t) in &refs {
            eng.run(p, t).unwrap();
        }
    });
    let div = time_fn(reps, budget, || {
        let out = eng.run_divergent(&refs);
        assert!(out.results.iter().all(|r| r.is_ok()));
    });
    let pt = Point {
        label: format!("mixed1080p/w{n}"),
        window: n,
        per_item_ms: per.mean_s * 1e3,
        divergent_ms: div.mean_s * 1e3,
        lanes,
        occupancy,
    };
    println!(
        "{:18} | per-item {:>8.3} ms | divergent {:>8.3} ms | {:>5.2}x | lanes {} occ {:.2}",
        pt.label,
        pt.per_item_ms,
        pt.divergent_ms,
        pt.speedup(),
        pt.lanes,
        pt.occupancy
    );
    pt
}

fn main() {
    let fast = std::env::var("FKL_BENCH_FAST").is_ok();
    let (reps, budget) =
        if fast { (5, Duration::from_millis(900)) } else { (12, Duration::from_secs(3)) };
    let eng = HostFusedEngine::new();
    println!(
        "# divergent_bench — mixed 1080p window (crop/resize/normalize/reduce variants), \
         {} worker threads",
        eng.threads()
    );

    let windows: &[usize] = if fast { &[2, 8] } else { &[2, 4, 8, 16] };
    let points: Vec<Point> =
        windows.iter().map(|&n| measure(&eng, n, reps, budget)).collect();

    let accept = points.iter().find(|p| p.window == 8).expect("sweep includes window 8");
    let (accept_label, accept_speedup) = (accept.label.clone(), accept.speedup());
    let accept_pass = accept_speedup >= 1.5;
    println!(
        "\nacceptance: {accept_label} -> {accept_speedup:.2}x (target >= 1.5x): {}",
        if accept_pass { "PASS" } else { "FAIL" }
    );

    let report = Value::obj(vec![
        ("bench", Value::str("divergent")),
        ("frame", Value::str("1080x1920x3 u8 shared frame, mixed pipeline window")),
        ("fast_mode", Value::Bool(fast)),
        ("threads", Value::num(eng.threads() as f64)),
        (
            "acceptance",
            Value::obj(vec![
                (
                    "criterion",
                    Value::str("divergent-HF >= 1.5x per-item serving, mixed window 8"),
                ),
                ("point", Value::str(&accept_label)),
                ("speedup", Value::num(accept_speedup)),
                ("pass", Value::Bool(accept_pass)),
            ]),
        ),
        ("series", Value::Arr(points.iter().map(Point::to_json).collect())),
    ]);

    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_divergent.json"))
        .unwrap_or_else(|| "BENCH_divergent.json".into());
    std::fs::write(&root, report.to_json()).expect("write BENCH_divergent.json");
    println!("wrote {}", root.display());

    // FKL_BENCH_SOFT turns the acceptance gate into a warning — wall-clock
    // asserts on shared CI runners (often 1-2 cores) are a flake source;
    // local/bench runs keep the hard gate
    if !accept_pass && std::env::var("FKL_BENCH_SOFT").is_ok() {
        eprintln!(
            "WARNING: acceptance criterion not met: {accept_speedup:.2}x < 1.5x (soft mode)"
        );
        return;
    }
    assert!(accept_pass, "acceptance criterion not met: {accept_speedup:.2}x < 1.5x");
}
