//! Coordinator benchmarks: serving throughput/latency vs batching policy.
//!
//! The dynamic batcher trades latency for HF width (paper Fig. 17 at the
//! serving layer). This bench sweeps window and max_batch and reports
//! req/s + latency percentiles + achieved batch width.

use std::time::{Duration, Instant};

use fkl::chain::{Chain, ConvertTo, Div, Mul, Sub, F32, U8};
use fkl::coordinator::{BatchPolicy, Service, ServiceConfig};
use fkl::ops::Pipeline;
use fkl::proplite::Rng;
use fkl::tensor::Tensor;

fn pipeline() -> Pipeline {
    // the canonical CMSD chain through the compile-time-checked front door
    Chain::read::<U8>(&[60, 120])
        .map(ConvertTo)
        .map(Mul(0.5))
        .map(Sub(3.0))
        .map(Div(1.7))
        .cast::<F32>()
        .write()
        .into_pipeline()
}

fn drive(policy: BatchPolicy, n: usize) -> (f64, fkl::coordinator::MetricsSnapshot) {
    let svc = Service::start(ServiceConfig { artifact_dir: None, queue_cap: 8192, policy, ..ServiceConfig::default() });
    let p = pipeline();
    let mut rng = Rng::new(3);
    // warmup (compile)
    let w = svc.submit(p.clone(), Tensor::from_u8(&rng.vec_u8(7200), &[1, 60, 120])).unwrap();
    let _ = w.recv();

    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(n);
    for _ in 0..n {
        let item = Tensor::from_u8(&rng.vec_u8(7200), &[1, 60, 120]);
        if let Ok(rx) = svc.submit(p.clone(), item) {
            pending.push(rx);
        }
    }
    let mut ok = 0usize;
    for rx in pending {
        if rx.recv().map(|r| r.is_ok()).unwrap_or(false) {
            ok += 1;
        }
    }
    let rps = ok as f64 / t0.elapsed().as_secs_f64();
    let m = svc.metrics().unwrap();
    svc.shutdown();
    (rps, m)
}

fn main() {
    println!("# coordinator_bench (chain CMSD, 60x120 u8->f32 items)");
    println!(
        "{:>10} {:>12} | {:>10} {:>10} {:>8} {:>8} {:>8}",
        "max_batch", "window_us", "req/s", "mean_bat", "p50_us", "p99_us", "launches"
    );
    let n = 1500;
    for (max_batch, window_us) in
        [(1usize, 0u64), (8, 200), (25, 500), (50, 500), (50, 2000), (150, 2000)]
    {
        let (rps, m) = drive(
            BatchPolicy { max_batch, window: Duration::from_micros(window_us), ..Default::default() },
            n,
        );
        println!(
            "{:>10} {:>12} | {:>10.0} {:>10.1} {:>8} {:>8} {:>8}",
            max_batch,
            window_us,
            rps,
            m.mean_batch(),
            m.latency.p50,
            m.latency.p99,
            m.launches
        );
    }
}
