//! Fusion micro-benchmarks (criterion unavailable offline; uses the in-repo
//! bench harness). One section per paper table dimension plus hot-path
//! microbenches used by the perf pass:
//!
//! * planner throughput (plan/sec — must never be the bottleneck)
//! * literal marshaling bandwidth (the H2D/D2H analog)
//! * fused vs unfused vs graph on the canonical CMSD chain
//! * single-launch floor (dispatch overhead)

use std::time::Duration;

use fkl::bench::{time_fn, time_fn_reps};
use fkl::cv::Context;
use fkl::exec::Engine;
use fkl::fusion::plan_pipeline;
use fkl::ops::Opcode;
use fkl::proplite::Rng;
use fkl::runtime::tensor_to_literal;
use fkl::tensor::{DType, Tensor};

fn main() -> anyhow::Result<()> {
    // drives AOT artifacts: pin the XLA backend
    let ctx = Context::with_select(fkl::exec::EngineSelect::Xla, None)?;
    let mut rng = Rng::new(1);
    println!("# fusion_bench");

    // --- planner throughput -------------------------------------------------
    let p = fkl::chain::build_erased_opcodes(
        &[(Opcode::Nop, 0.0), (Opcode::Mul, 0.5), (Opcode::Sub, 3.0), (Opcode::Div, 1.7)],
        &[60, 120],
        50,
        DType::U8,
        DType::F32,
    );
    let reg = ctx.registry()?;
    let st = time_fn_reps(2000, || plan_pipeline(&p, &reg, "pallas").unwrap());
    println!("planner/plan_cmsd_b50:        {:>10.2} us/plan ({:.0} plans/s)", st.mean_us(), 1.0 / st.mean_s);

    let sl = {
        let mut chain = Vec::new();
        for _ in 0..1000 {
            chain.push((Opcode::Mul, 0.999));
            chain.push((Opcode::Add, 0.001));
        }
        fkl::chain::build_erased_opcodes(&chain, &[512, 1024], 1, DType::U8, DType::U8)
    };
    let st = time_fn_reps(50, || plan_pipeline(&sl, &reg, "pallas").unwrap());
    println!("planner/plan_muladd_2000ops:  {:>10.2} us/plan (staticloop detection)", st.mean_us());

    // --- literal marshaling -------------------------------------------------
    for (label, n) in [("1MB", 1usize << 18), ("64MB", 1usize << 24)] {
        let t = Tensor::from_f32(&rng.vec_f32(n, 0.0, 1.0), &[n]);
        let st = time_fn(50, Duration::from_secs(1), || tensor_to_literal(&t).unwrap());
        let gbps = (n * 4) as f64 / st.mean_s / 1e9;
        println!("marshal/tensor_to_literal_{label}: {:>7.3} ms ({gbps:.1} GB/s)", st.mean_ms());
    }

    // --- engines on the canonical chain -------------------------------------
    let input = Tensor::from_u8(&rng.vec_u8(50 * 60 * 120), &[50, 60, 120]);
    for (name, engine) in [
        ("fused", ctx.fused()? as &dyn Engine),
        ("unfused", ctx.unfused()? as &dyn Engine),
        ("graph", ctx.graph()? as &dyn Engine),
    ] {
        let st = time_fn(30, Duration::from_secs(2), || engine.run(&p, &input).unwrap());
        println!(
            "engine/cmsd_b50/{name:8}       {:>7.3} ms ({} launches, rsd {:.1}%)",
            st.mean_ms(),
            engine.last_launches(),
            st.rsd_pct
        );
    }

    // --- dispatch floor ------------------------------------------------------
    let tiny = Tensor::from_f32(&rng.vec_f32(64, 0.0, 1.0), &[2, 4, 8]);
    let params = Tensor::from_f32(&[1.5, 2.0], &[2]);
    let exec = ctx.fused()?.executor();
    let st = time_fn_reps(
        500,
        || exec.run("chain_mul-add_f322f32_4x8_b2_pallas", &[&tiny, &params]).unwrap(),
    );
    println!("dispatch/single_launch_floor: {:>10.2} us", st.mean_us());
    Ok(())
}
