//! `fkl` — the coordinator CLI.
//!
//! ```text
//! fkl info                         # registry + artifact inventory
//! fkl plan  --ops mul,add --shape 60x120 --batch 50 --dtin u8 --dtout f32
//! fkl run   --ops mul:2.0,add:1.0 --shape 4x8 --batch 2   # run via engines
//! fkl serve --requests 500 --batch-window-us 500          # coordinator demo
//! fkl serve --shards 4             # sharded coordinator: hash-routed workers
//!                                  # + work stealing; prints per-shard counters
//! fkl serve --deadline-ms 5 --faults 'tier=stacked,launch=0,action=panic'
//!                                  # deadline-aware serving + fault drill
//! fkl serve --trace-out trace.json --metrics-json metrics.json
//!                                  # per-request span trees (Chrome trace-event
//!                                  # JSON, opens in Perfetto) + counters dump
//! fkl metrics --demo               # serve a tiny window, print MetricsSnapshot
//!                                  # JSON (fusion efficiency, tier times, p999)
//! fkl lint  --ops mul:1.0,neg,neg,cast:f32 --shape 60x120 [--json]
//!                                  # static analysis: diagnostics + canon report
//! fkl calibrate                    # measure this host's HwProfile
//! ```
//!
//! `fkl lint` exit codes are a contract (CI-greppable): `0` = clean or
//! warnings only, `1` = at least one error-severity diagnostic, `2` =
//! malformed chain spec (typed parse error on stderr, never a panic).

use std::time::Duration;

use fkl::chain::{self, Chain, ComputeOp, ConvertTo, Div, Mul, Sub, F32, U8};
use fkl::coordinator::{BatchPolicy, Service, ServiceConfig};
use fkl::cv::Context;
use fkl::exec::Engine;
use fkl::ops::{Opcode, Pipeline};
use fkl::proplite::Rng;
use fkl::tensor::DType;

fn arg(args: &[String], key: &str) -> Option<String> {
    args.iter().position(|a| a == key).and_then(|i| args.get(i + 1)).cloned()
}

fn parse_shape(s: &str) -> Vec<usize> {
    s.split('x').map(|p| p.parse().expect("shape like 60x120")).collect()
}

fn parse_ops(s: &str) -> Vec<(Opcode, f64)> {
    s.split(',')
        .map(|tok| {
            let (name, param) = tok.split_once(':').unwrap_or((tok, "1.0"));
            (
                Opcode::parse(name).unwrap_or_else(|| panic!("unknown op {name}")),
                param.parse().expect("param"),
            )
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("info") => info(),
        Some("plan") => plan(&args),
        Some("run") => run(&args),
        Some("serve") => serve(&args),
        Some("metrics") => metrics_cmd(&args),
        Some("lint") => lint(&args),
        Some("calibrate") => {
            let hw = fkl::bench::calibrate();
            println!(
                "host profile: mem_bw={:.1} GB/s, throughput={:.1} Gops/s, assumed launch={:.0}us",
                hw.mem_bw / 1e9,
                hw.flops / 1e9,
                hw.launch_overhead * 1e6
            );
            Ok(())
        }
        _ => {
            eprintln!("usage: fkl <info|plan|run|serve|metrics|lint|calibrate> [options]");
            Ok(())
        }
    }
}

fn info() -> anyhow::Result<()> {
    let reg = fkl::runtime::Registry::load(fkl::default_artifact_dir())?;
    println!("artifact registry: {} artifacts (scale: {})", reg.len(), reg.scale);
    let mut by_kind: std::collections::BTreeMap<String, usize> = Default::default();
    for m in reg.iter() {
        *by_kind.entry(m.kind.clone()).or_default() += 1;
    }
    for (k, n) in by_kind {
        println!("  {k:14} {n}");
    }
    Ok(())
}

fn build_pipeline(args: &[String]) -> Pipeline {
    let ops = parse_ops(&arg(args, "--ops").expect("--ops"));
    let shape = parse_shape(&arg(args, "--shape").expect("--shape"));
    let batch: usize = arg(args, "--batch").map(|b| b.parse().unwrap()).unwrap_or(1);
    let dtin = DType::parse(&arg(args, "--dtin").unwrap_or("f32".into())).expect("dtin");
    let dtout = DType::parse(&arg(args, "--dtout").unwrap_or("f32".into())).expect("dtout");
    // CLI dtypes are data -> the sanctioned dynamic entrance of the typed chain
    let stages: Vec<ComputeOp> =
        ops.iter().map(|&(op, param)| ComputeOp::scalar(op, param)).collect();
    chain::build_erased(&stages, &shape, batch, dtin, dtout)
}

fn plan(args: &[String]) -> anyhow::Result<()> {
    let ctx = Context::new()?;
    let p = build_pipeline(args);
    println!("pipeline: {}", fkl::ops::Signature::of(&p));
    println!("backend: {}", ctx.backend());
    match ctx.fused() {
        Ok(fused) => {
            let plan = fused.plan_for(&p)?;
            println!("plan: {plan:?}");
            println!("launches: {} (fused: {})", plan.launches(), plan.is_fused());
        }
        Err(_) => {
            let plan = ctx.host().plan_for(&p);
            println!(
                "plan: host single-pass (accum {:?}, group {}, chain fast path: {})",
                plan.accum(),
                plan.group(),
                plan.is_chain()
            );
            println!("launches: 1 (fused: true)");
        }
    }
    let r = fkl::fusion::memsave::report(&p);
    println!(
        "memory: fused {}B, unfused {}B, saved {}B",
        r.fused_total(),
        r.unfused_total(),
        r.saved()
    );
    Ok(())
}

fn run(args: &[String]) -> anyhow::Result<()> {
    let ctx = Context::new()?;
    let p = build_pipeline(args);
    let mut rng = Rng::new(1);
    let mut full_shape = vec![p.batch];
    full_shape.extend_from_slice(&p.shape);
    let input = fkl::tensor::Tensor::from_f64_cast(
        &(0..p.batch * p.item_elems()).map(|_| rng.f64(0.0, 1.0)).collect::<Vec<_>>(),
        &full_shape,
        p.dtin,
    );
    println!("backend: {}", ctx.backend());
    for (name, engine) in ctx.engines() {
        let t0 = std::time::Instant::now();
        match engine.run(&p, &input) {
            Ok(out) => println!(
                "{:10} -> {:?} {:?} in {:.3}ms ({} launches)",
                name,
                out.dtype(),
                out.shape(),
                t0.elapsed().as_secs_f64() * 1e3,
                engine.last_launches(),
            ),
            Err(e) => println!("{name:10} -> not covered by the artifact family: {e}"),
        }
    }
    Ok(())
}

/// `fkl lint`: run the static analyzer over an ARBITRARY textual chain spec.
/// Unlike the demo drivers above this path must never panic on user input —
/// malformed specs come back as typed [`fkl::analysis::SpecError`]s and exit
/// code 2; error-severity diagnostics exit 1; warnings/infos exit 0.
fn lint(args: &[String]) -> anyhow::Result<()> {
    let ops = arg(args, "--ops").unwrap_or_default();
    let shape = arg(args, "--shape").unwrap_or_else(|| "60x120".into());
    let batch: usize = arg(args, "--batch").and_then(|b| b.parse().ok()).unwrap_or(1);
    let dtin = arg(args, "--dtin").unwrap_or_else(|| "f32".into());
    let dtout = arg(args, "--dtout").unwrap_or_else(|| "f32".into());
    let json = args.iter().any(|a| a == "--json");

    let p = match fkl::analysis::parse_chain_spec(&ops, &shape, batch, &dtin, &dtout) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("fkl lint: {e}");
            std::process::exit(2);
        }
    };
    let diags = fkl::analysis::lint(&p);
    let (canonical, rewrites) = fkl::analysis::canonicalize(p.clone());
    let applied = rewrites.iter().filter(|r| r.applied).count();
    let suggested = rewrites.len() - applied;

    if json {
        use fkl::jsonlite::Value;
        let report = Value::obj(vec![
            ("diagnostics", Value::Arr(diags.iter().map(|d| d.to_json()).collect())),
            ("rewrites_applied", Value::num(applied as f64)),
            ("rewrites_suggested", Value::num(suggested as f64)),
            ("ops_before", Value::num(p.body().len() as f64)),
            ("ops_after", Value::num(canonical.body().len() as f64)),
        ]);
        println!("{}", report.to_json());
    } else {
        for d in &diags {
            println!("{d}");
        }
        for r in &rewrites {
            let verb = if r.applied { "applied" } else { "suggested" };
            println!("canon[{verb}] {:?} at {}: {}", r.kind, r.span, r.detail);
        }
        println!(
            "{} diagnostic(s); canonical form: {} -> {} op(s), {applied} rewrite(s) applied, \
             {suggested} report-only",
            diags.len(),
            p.body().len(),
            canonical.body().len()
        );
    }
    if diags.iter().any(|d| d.severity == fkl::analysis::Severity::Error) {
        std::process::exit(1);
    }
    Ok(())
}

fn serve(args: &[String]) -> anyhow::Result<()> {
    let n: usize = arg(args, "--requests").map(|v| v.parse().unwrap()).unwrap_or(500);
    let window_us: u64 =
        arg(args, "--batch-window-us").map(|v| v.parse().unwrap()).unwrap_or(500);
    // --shards N: run N hash-routed coordinator workers (1 = the original
    // single-thread coordinator, bit-for-bit)
    let shards: usize = arg(args, "--shards").map(|v| v.parse().unwrap()).unwrap_or(1).max(1);
    // deadline-aware serving: every request must launch within this budget
    // or be shed/expired with a typed error instead of served late
    let default_deadline =
        arg(args, "--deadline-ms").map(|v| Duration::from_millis(v.parse().unwrap()));
    // fault drill: --faults takes a spec like `tier=stacked,launch=0,
    // action=panic`; without the flag the FKL_FAULTS env var is honored
    let faults = match arg(args, "--faults") {
        Some(spec) => Some(fkl::faults::FaultPlan::parse(&spec)?),
        None => fkl::faults::FaultPlan::from_env()?,
    };
    if let Some(plan) = &faults {
        println!("fault plan armed: {} rule(s)", plan.rules.len());
    }
    // --canonicalize: admit every pipeline through the ingress canonicalizer
    let canonicalize = args.iter().any(|a| a == "--canonicalize");
    // --trace-out <path>: arm the span recorder; the capture is written as
    // Chrome trace-event JSON on shutdown (opens in ui.perfetto.dev)
    let trace_out = arg(args, "--trace-out");
    let tracer = trace_out.as_ref().map(|_| std::sync::Arc::new(fkl::trace::Tracer::new()));
    // --metrics-json <path>: dump the final MetricsSnapshot as JSON
    let metrics_out = arg(args, "--metrics-json");
    let svc = Service::start(ServiceConfig {
        artifact_dir: None,
        queue_cap: 1024,
        policy: BatchPolicy { max_batch: 50, window: Duration::from_micros(window_us), ..Default::default() },
        default_deadline,
        faults,
        canonicalize,
        tracing: tracer.clone(),
        shards,
        ..ServiceConfig::default()
    });

    // the canonical CMSD normalization chain, compile-time checked; with
    // --shards N the demo submits N width-variants of it (distinct stream
    // keys) so the hash router actually spreads the load
    let streams: Vec<(Vec<usize>, Pipeline)> = (0..shards)
        .map(|s| {
            let (h, w) = (60, 120 + s);
            let p = Chain::read::<U8>(&[h, w])
                .map(ConvertTo)
                .map(Mul(0.5))
                .map(Sub(3.0))
                .map(Div(1.7))
                .cast::<F32>()
                .write()
                .into_pipeline();
            (vec![h, w], p)
        })
        .collect();
    let mut rng = Rng::new(2);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for i in 0..n {
        let (shape, p) = &streams[i % streams.len()];
        let item =
            fkl::tensor::Tensor::from_u8(&rng.vec_u8(shape[0] * shape[1]), &[1, shape[0], shape[1]]);
        match svc.submit(p.clone(), item) {
            Ok(rx) => pending.push(rx),
            Err(e) => eprintln!("rejected: {e}"),
        }
    }
    let mut ok = 0;
    for rx in pending {
        if rx.recv().map(|r| r.is_ok()).unwrap_or(false) {
            ok += 1;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let m = svc.metrics().unwrap_or_default();
    println!("served {ok}/{n} in {dt:.3}s = {:.0} req/s", ok as f64 / dt);
    println!(
        "launches={} mean_batch={:.1} p50={}us p99={}us padded={}",
        m.launches,
        m.mean_batch(),
        m.latency.p50,
        m.latency.p99,
        m.padded_planes
    );
    println!(
        "coverage={:.0}% fused (fallbacks={} host_serves={})",
        m.fused_coverage() * 100.0,
        m.unfused_fallbacks,
        m.planner.host
    );
    println!(
        "simd: capability={} lane_width={} vectorized_passes={}",
        fkl::ops::kernel::simd_capability(),
        m.planner.vector_width,
        m.planner.vectorized
    );
    println!(
        "divergent: windows={} items={} mean_window={:.1} occupancy={:.2}",
        m.divergent_windows,
        m.divergent_items,
        m.mean_divergent_window(),
        m.divergent_occupancy()
    );
    println!(
        "bytes: read={} written={} op-at-a-time-baseline={} fusion_efficiency={:.2}x",
        m.bytes_read,
        m.bytes_written,
        m.bytes_baseline,
        m.fusion_efficiency()
    );
    println!(
        "tier time: stacked={}us divergent={}us per_item={}us plan={}us",
        m.tier_time_us.stacked,
        m.tier_time_us.divergent,
        m.tier_time_us.per_item,
        m.tier_time_us.plan
    );
    println!(
        "faults: failed={} expired={} shed={} launch_panics={} breaker_trips={} \
         breaker_rejected={}",
        m.failed, m.expired, m.shed, m.launch_panics, m.breaker_trips, m.breaker_rejected
    );
    if canonicalize {
        println!(
            "canon: lints={} rewrites_applied={} canonical_hits={} plan_cache={}",
            m.lints_emitted, m.rewrites_applied, m.canonical_cache_hits, m.planner.plan_cache
        );
    }
    if default_deadline.is_some() {
        println!(
            "deadline margin: p50={}us p99={}us (est item cost {:.1}us)",
            m.deadline_margin.p50, m.deadline_margin.p99, m.est_item_us
        );
    }
    for b in &m.breakers {
        println!(
            "breaker {}: {:?} tier={} trips={} rejected={}",
            b.key,
            b.state,
            b.tier.name(),
            b.trips,
            b.rejected
        );
    }
    for s in &m.shards {
        println!(
            "shard {}: completed={} failed={} shed={} expired={} steals={} stolen={} \
             pending={} occupancy={:.2}",
            s.shard,
            s.completed,
            s.failed,
            s.shed,
            s.expired,
            s.steals,
            s.stolen_requests,
            s.pending,
            s.occupancy
        );
    }
    if let Some(d) = &m.degraded {
        println!("degraded: {d}");
    }
    svc.shutdown();
    // exports are written AFTER shutdown: the service thread has flushed
    // every pending request, so the capture and the dump are complete
    if let Some(path) = metrics_out {
        std::fs::write(&path, m.to_json().to_json())?;
        println!("metrics dump: {path}");
    }
    if let (Some(path), Some(tr)) = (trace_out, tracer) {
        std::fs::write(&path, tr.to_chrome_trace().to_json())?;
        println!("trace capture: {path} ({} spans; open in ui.perfetto.dev)", tr.span_count());
    }
    Ok(())
}

/// `fkl metrics --demo`: serve a small mixed window in-process (stacked
/// chain-5 company plus one divergent rider) and print the resulting
/// [`fkl::coordinator::MetricsSnapshot`] as JSON — the quickest way to see
/// the export schema (fusion efficiency, per-tier time, p999) end to end.
fn metrics_cmd(args: &[String]) -> anyhow::Result<()> {
    if !args.iter().any(|a| a == "--demo") {
        eprintln!("usage: fkl metrics --demo");
        return Ok(());
    }
    let svc = Service::start(ServiceConfig {
        engine: fkl::coordinator::EngineSelect::HostFused,
        policy: BatchPolicy { max_batch: 16, window: Duration::from_micros(200), ..Default::default() },
        ..ServiceConfig::default()
    });
    // chain-5 u8->f32: op-at-a-time moves 21 bytes/elem, fused moves 5 —
    // the 4.2x ideal the efficiency counters should approach
    let p = Chain::read::<U8>(&[32, 32])
        .map(ConvertTo)
        .map(Mul(0.5))
        .map(Sub(3.0))
        .map(Div(1.7))
        .map(Mul(2.0))
        .cast::<F32>()
        .write()
        .into_pipeline();
    let lone = Chain::read::<U8>(&[32, 32]).map(ConvertTo).cast::<F32>().write().into_pipeline();
    let mut rng = Rng::new(7);
    let mut pending = Vec::new();
    for i in 0..12 {
        let item = fkl::tensor::Tensor::from_u8(&rng.vec_u8(32 * 32), &[1, 32, 32]);
        let pipe = if i % 4 == 3 { lone.clone() } else { p.clone() };
        if let Ok(rx) = svc.submit(pipe, item) {
            pending.push(rx);
        }
    }
    for rx in pending {
        let _ = rx.recv();
    }
    let m = svc.metrics().unwrap_or_default();
    println!("{}", m.to_json().to_json());
    svc.shutdown();
    Ok(())
}
