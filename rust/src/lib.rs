//! # FKL — The Fused Kernel Library, reproduced in Rust + JAX + Pallas
//!
//! A three-layer reproduction of *"The Fused Kernel Library: A C++ API to
//! Develop Highly-Efficient GPU Libraries"* (Amoros et al., 2025):
//!
//! * **Layer 3 (this crate)** — the coordination contribution: a
//!   compile-time-checked fusion-chain builder ([`chain`] — the typestate
//!   front door every consumer lowers through), pipelines of Instantiable
//!   Operations ([`ops`]), a fusion planner that performs automatic
//!   Vertical and Horizontal Fusion ([`fusion`]), four execution engines
//!   (fused / unfused / graph-replay / host-fused, [`exec`]), a streaming
//!   coordinator with dynamic HF batching ([`coordinator`]), and
//!   high-level wrappers imitating OpenCV-CUDA ([`cv`]) and NPP ([`npp`]).
//! * **Layer 2/1 (build time)** — JAX graphs calling Pallas kernels
//!   (`python/compile/`), AOT-lowered to HLO text artifacts loaded by
//!   [`runtime`] (gated behind the `pjrt` cargo feature; without it the
//!   host fused engine executes pipelines on any machine).
//!
//! See `DESIGN.md` (repo root) for the paper -> system mapping and
//! `EXPERIMENTS.md` for the reproduced evaluation.

pub mod analysis;
pub mod bench;
pub mod chain;
pub mod coordinator;
pub mod cv;
pub mod exec;
pub mod experiments;
pub mod faults;
pub mod fusion;
pub mod hostref;
pub mod jsonlite;
pub mod npp;
pub mod ops;
pub mod proplite;
pub mod runtime;
pub mod simulator;
pub mod tensor;
pub mod trace;

/// Default artifact directory: honors `FKL_ARTIFACTS`, else walks up from the
/// current directory looking for `artifacts/manifest.json`.
pub fn default_artifact_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("FKL_ARTIFACTS") {
        return dir.into();
    }
    let mut cur = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !cur.pop() {
            return "artifacts".into();
        }
    }
}
