//! Exp. 9 (Fig. 23) — speedup vs input/output data type.
//!
//! Paper: chain Cast-Mul-Sub-Div, batch 50 of 60x120; eight in->out combos.
//! Speedups similar across types except double-involving combos (CB earlier,
//! VF gains less); double->double beats float->double because it is more MB.

use anyhow::Result;

use crate::bench::Table;
use crate::exec::Engine;
use crate::proplite::Rng;
use crate::tensor::DType;

use super::common::{cmsd, fx, ms, rand_tensor, XpCtx};

pub fn run(xp: &XpCtx) -> Result<Vec<Table>> {
    let combos: Vec<(DType, DType)> = xp.registry().geometry["dtype_combos"]
        .as_arr()
        .map(|arr| {
            arr.iter()
                .filter_map(|c| {
                    Some((DType::parse(c[0].as_str()?)?, DType::parse(c[1].as_str()?)?))
                })
                .collect()
        })
        .unwrap_or_else(|| vec![(DType::U8, DType::F32), (DType::F32, DType::F32)]);

    let mut t = Table::new(
        "Fig. 23 — dtype combos, chain Cast-Mul-Sub-Div, batch 50 of 60x120",
        &["in->out", "fused_ms", "unfused_ms", "speedup"],
    );

    let mut rng = Rng::new(21);
    for (dtin, dtout) in combos {
        let input = rand_tensor(&mut rng, &[50, 60, 120], dtin);
        let p = cmsd(&[60, 120], 50, dtin, dtout);
        let fused = xp.measure(|| xp.fused().run(&p, &input).unwrap());
        let unfused = xp.measure(|| xp.unfused().run(&p, &input).unwrap());
        t.row(vec![
            format!("{dtin}->{dtout}"),
            ms(fused.mean_s),
            ms(unfused.mean_s),
            fx(unfused.mean_s / fused.mean_s),
        ]);
    }
    Ok(vec![t])
}
