//! Exp. 5 (Fig. 19) — instructions per operation.
//!
//! Paper: 500 total instructions split into N kernels of M instructions each
//! (N*M = 500); speedup of the single 500-instruction kernel vs the N-kernel
//! chain decreases as M grows, with bumps where N drops by one and a rise
//! past M=250 (the last kernel turns MB).
//!
//! Here both arms use the same StaticLoop artifact: fused = 1 launch with
//! trip 500; split = ceil(500/M) launches with trip M (remainder in the last
//! launch) — each launch is a full DRAM read+write pass, like the paper.

use anyhow::{Context, Result};

use crate::bench::Table;
use crate::proplite::Rng;
use crate::tensor::{DType, Tensor};

use super::common::{fx, ms, rand_tensor, XpCtx};

pub fn run(xp: &XpCtx) -> Result<Vec<Table>> {
    let reg = xp.registry();
    let meta = reg
        .find(|m| {
            m.kind == "staticloop"
                && m.variant == "pallas"
                && m.ops == ["mul"]
                && m.dtin == "f32"
                && m.shape.len() == 1
        })
        .into_iter()
        .max_by_key(|m| m.shape[0])
        .context("missing staticloop_mul_f32 artifact")?
        .clone();
    let n_elems = meta.shape[0];

    let mut rng = Rng::new(9);
    let x = rand_tensor(&mut rng, &[1, n_elems], DType::F32);
    let params = Tensor::from_f32(&[0.99999], &[1]);
    let exec = xp.executor();

    const TOTAL: usize = 500;
    let per_op: Vec<usize> =
        if xp.fast { vec![1, 25, 250] } else { vec![1, 2, 5, 10, 25, 50, 100, 125, 250, 400, 496] };

    let fused = {
        let trip = Tensor::from_i32(&[TOTAL as i32], &[1]);
        xp.measure(|| exec.run(&meta.name, &[&trip, &x, &params]).unwrap())
    };

    let mut t = Table::new(
        "Fig. 19 — instructions per op (500 total), f32 vector",
        &["instrs_per_op", "n_kernels", "fused_ms", "split_ms", "speedup"],
    );
    t.note(format!("vector = {n_elems} f32; fused arm = one 500-instruction kernel"));

    for &m in &per_op {
        let n_kernels = TOTAL.div_ceil(m);
        let split = xp.measure(|| {
            let mut left = TOTAL;
            let mut cur = x.clone();
            while left > 0 {
                let step = left.min(m);
                let trip = Tensor::from_i32(&[step as i32], &[1]);
                let next = exec.run(&meta.name, &[&trip, &cur, &params]).unwrap();
                cur = next;
                left -= step;
            }
            cur
        });
        t.row(vec![
            m.to_string(),
            n_kernels.to_string(),
            ms(fused.mean_s),
            ms(split.mean_s),
            fx(split.mean_s / fused.mean_s),
        ]);
    }
    Ok(vec![t])
}
