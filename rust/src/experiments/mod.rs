//! Experiment runners — one module per paper figure/table (DESIGN.md §6).
//!
//! Each produces a [`Table`](crate::bench::Table) written to `results/` as
//! CSV + markdown; EXPERIMENTS.md records paper-vs-measured for each. All
//! runners share [`XpCtx`]: the registry, engines, and a time budget knob
//! (`--fast` trims sweeps for CI; default runs fuller sweeps).

mod ablation;
mod common;
mod fig1;
mod xp01_wrapper;
mod xp02_vf;
mod xp03_hf;
mod xp04_vfhf;
mod xp05_instrs;
mod xp06_cpu;
mod xp07_datasize;
mod xp08_gpusize;
mod xp09_dtype;
mod xp10_npp;
mod xp_divhf;
mod xp_hostpre;
mod xp_hostvf;
mod xp_reduce;
mod xp_simd;
mod xpmem;

pub use common::XpCtx;

use anyhow::Result;

use crate::bench::Table;

/// All experiment ids in run order.
pub const ALL: &[&str] = &[
    "fig1", "1", "2", "3", "4", "5", "6", "7", "8", "9", "10", "mem", "ablation", "hostvf",
    "hostpre", "reduce", "divhf", "simd",
];

/// Experiments that need no artifact registry (run on any machine via
/// [`run_host`]; `xp` uses this to skip the registry requirement for them).
pub const HOST_ONLY: &[&str] = &["hostvf", "hostpre", "reduce", "divhf", "simd"];

/// Run one experiment by id.
pub fn run(id: &str, ctx: &XpCtx) -> Result<Vec<Table>> {
    match id {
        "fig1" => fig1::run(ctx),
        "1" => xp01_wrapper::run(ctx),
        "2" => xp02_vf::run(ctx),
        "3" => xp03_hf::run(ctx),
        "4" => xp04_vfhf::run(ctx),
        "5" => xp05_instrs::run(ctx),
        "6" => xp06_cpu::run(ctx),
        "7" => xp07_datasize::run(ctx),
        "8" => xp08_gpusize::run(ctx),
        "9" => xp09_dtype::run(ctx),
        "10" => xp10_npp::run(ctx),
        "mem" => xpmem::run(ctx),
        "ablation" => ablation::run(ctx),
        "hostvf" => xp_hostvf::run(ctx),
        "hostpre" => xp_hostpre::run(ctx),
        "reduce" => xp_reduce::run(ctx),
        "divhf" => xp_divhf::run(ctx),
        "simd" => xp_simd::run(ctx),
        other => anyhow::bail!("unknown experiment {other:?}; ids: {ALL:?}"),
    }
}

/// Run a [`HOST_ONLY`] experiment without constructing an [`XpCtx`] (no
/// artifacts needed).
pub fn run_host(id: &str, fast: bool) -> Result<Vec<Table>> {
    let (reps, budget) = common::measure_policy(fast);
    match id {
        "hostvf" => xp_hostvf::run_with(reps, budget, fast),
        "hostpre" => xp_hostpre::run_with(reps, budget, fast),
        "reduce" => xp_reduce::run_with(reps, budget, fast),
        "divhf" => xp_divhf::run_with(reps, budget, fast),
        "simd" => xp_simd::run_with(reps, budget, fast),
        other => anyhow::bail!("experiment {other:?} needs artifacts; ids without: {HOST_ONLY:?}"),
    }
}
