//! Exp. 2 (Fig. 16) — speedup vs number of vertically fused operations.
//!
//! Paper: 4096x2160 u8 matrix, chains of Mul-only and Mul+Add from 2 to
//! 19,902 ops; cvGS vs OpenCV-CUDA and vs OpenCV-CUDA+Graphs. Max speedups
//! ~90x (Mul) and ~185x (Mul+Add, FMA pairing). Here: fused = StaticLoop
//! artifact (1 launch); unfused = one single-op launch per op; graph = the
//! recorded replay of the same launches.

use anyhow::{Context, Result};

use crate::bench::Table;
use crate::exec::Engine;
use crate::proplite::Rng;
use crate::tensor::{DType, Tensor};

use super::common::{fx, ms, muladd_pairs, rand_tensor, XpCtx};

pub fn run(xp: &XpCtx) -> Result<Vec<Table>> {
    let reg = xp.registry();
    let vf_shape = xp.geom_usizes("vf_shape", &[512, 1024]);
    let (h, w) = (vf_shape[0], vf_shape[1]);

    let mut tables = Vec::new();
    for ops_kind in ["mul", "mul-add"] {
        let loop_meta = reg
            .find(|m| {
                m.kind == "staticloop"
                    && m.variant == "pallas"
                    && m.dtin == "u8"
                    && m.shape == vf_shape
                    && m.ops.join("-") == ops_kind
            })
            .into_iter()
            .next()
            .with_context(|| format!("missing staticloop {ops_kind} artifact"))?
            .clone();
        let body_len = loop_meta.ops.len();

        let mut rng = Rng::new(11);
        let x = rand_tensor(&mut rng, &[1, h, w], DType::U8);
        let params = if body_len == 1 {
            Tensor::from_f32(&[1.0001], &[1])
        } else {
            Tensor::from_f32(&[0.999, 0.001], &[2])
        };
        let exec = xp.executor();

        // N total fused ops ~ paper's x-axis
        let ns: Vec<usize> = if xp.fast {
            vec![2, 102, 1002]
        } else {
            vec![2, 102, 302, 1002, 3002, 10002, 19902]
        };

        let mut t = Table::new(
            &format!("Fig. 16 — VF sweep, {ops_kind} ops, {h}x{w} u8, batch 1"),
            &["n_ops", "fused_ms", "unfused_ms", "graph_ms", "speedup", "speedup_vs_graph", "baseline_mode"],
        );
        t.note(format!(
            "paper scale is 4096x2160; this run uses {h}x{w} (scale via --paper-scale artifacts)"
        ));
        t.note("baselines measured up to 3002 launches, then linearly extrapolated from per-launch cost (flagged 'extrap')");

        let cap = if xp.fast { 102 } else { 3002 };
        let mut per_launch_unfused: Option<f64> = None;
        let mut per_launch_graph: Option<f64> = None;
        for &n in &ns {
            let iters = n / body_len;
            let trip = Tensor::from_i32(&[iters as i32], &[1]);
            let fused = xp.measure(|| {
                exec.run(&loop_meta.name, &[&trip, &x, &params]).unwrap()
            });

            // unfused: n single-op launches (alternating for mul-add)
            let p = if body_len == 1 {
                crate::chain::build_erased_opcodes(
                    &vec![(crate::ops::Opcode::Mul, 1.0001); n],
                    &[h, w],
                    1,
                    DType::U8,
                    DType::U8,
                )
            } else {
                muladd_pairs(iters, &[h, w], 1, DType::U8, DType::U8)
            };
            let (unfused_s, graph_s, mode) = if n <= cap {
                let unfused = xp.measure(|| xp.unfused().run(&p, &x).unwrap());
                // graph replay of the same chain (record once outside timing)
                let graph = xp.measure(|| xp.graph().run(&p, &x).unwrap());
                per_launch_unfused = Some(unfused.mean_s / n as f64);
                per_launch_graph = Some(graph.mean_s / n as f64);
                (unfused.mean_s, graph.mean_s, "measured")
            } else {
                (
                    per_launch_unfused.expect("cap ordering") * n as f64,
                    per_launch_graph.expect("cap ordering") * n as f64,
                    "extrap",
                )
            };

            t.row(vec![
                n.to_string(),
                ms(fused.mean_s),
                ms(unfused_s),
                ms(graph_s),
                fx(unfused_s / fused.mean_s),
                fx(graph_s / fused.mean_s),
                mode.to_string(),
            ]);
        }
        tables.push(t);
    }
    Ok(tables)
}
