//! Exp. 7 (Fig. 21) — execution time vs data size.
//!
//! Paper: 1-D f32, 100..16,654,030 elements, 100 Mul+Add pairs; log-scale
//! execution times of OpenCV-CUDA vs cvGS. Both rise with size; the unfused
//! baseline is flat at small sizes (launch-bound) while the fused kernel
//! scales from the start; near bandwidth saturation the fused curve grows
//! more slowly (latency hiding).

use anyhow::Result;

use crate::bench::Table;
use crate::exec::Engine;
use crate::proplite::Rng;
use crate::tensor::{DType, Tensor};

use super::common::{fx, ms, muladd_pairs, rand_tensor, XpCtx};

const PAIRS: usize = 100;

pub fn run(xp: &XpCtx) -> Result<Vec<Table>> {
    let sizes: Vec<usize> = {
        let all = xp.geom_usizes(
            "sizes",
            &[100, 10_000, 1_000_000, 16_654_030],
        );
        if xp.fast {
            all.into_iter().filter(|n| *n <= 1_000_000).collect()
        } else {
            all
        }
    };
    let reg = xp.registry();
    let exec = xp.executor();

    let mut t = Table::new(
        "Fig. 21 — execution time vs data size (100 Mul+Add pairs, f32)",
        &["elements", "fused_ms", "unfused_ms", "speedup"],
    );
    t.note("unfused = 200 single-op launches (one per Mul/Add, like OpenCV-CUDA)");

    let mut rng = Rng::new(13);
    for &n in &sizes {
        let Some(loop_meta) = reg
            .find(|m| {
                m.kind == "staticloop" && m.variant == "pallas" && m.dtin == "f32" && m.shape == [n]
            })
            .into_iter()
            .next()
        else {
            continue;
        };
        let x = rand_tensor(&mut rng, &[1, n], DType::F32);
        let params = Tensor::from_f32(&[0.999, 0.001], &[2]);
        let trip = Tensor::from_i32(&[PAIRS as i32], &[1]);

        let fused = xp.measure(|| {
            exec.run(&loop_meta.name, &[&trip, &x, &params]).unwrap()
        });

        let p = muladd_pairs(PAIRS, &[n], 1, DType::F32, DType::F32);
        let unfused = xp.measure(|| xp.unfused().run(&p, &x).unwrap());

        t.row(vec![
            n.to_string(),
            ms(fused.mean_s),
            ms(unfused.mean_s),
            fx(unfused.mean_s / fused.mean_s),
        ]);
    }
    Ok(vec![t])
}
