//! Fig. 1 — kernel time vs instructions per element (the MB->CB knee).
//!
//! Paper: RTX 4090, 66M floats, 1..1161 float adds/thread; time flat until
//! ~260 instructions, then linear. We measure the same sweep on the CPU PJRT
//! substrate via the StaticLoop artifact (runtime trip count — one artifact,
//! no recompiles) and run the paper's own GPU on the simulator next to it.

use anyhow::{Context, Result};

use crate::bench::Table;
use crate::proplite::Rng;
use crate::simulator::{table_ii_systems, GpuModel};
use crate::tensor::Tensor;

use super::common::{ms, rand_tensor, XpCtx};

pub fn run(xp: &XpCtx) -> Result<Vec<Table>> {
    let reg = xp.registry();
    // the f32 vector staticloop artifact (mul body: 1 instruction per iter)
    let meta = reg
        .find(|m| {
            m.kind == "staticloop"
                && m.variant == "pallas"
                && m.ops == ["mul"]
                && m.dtin == "f32"
                && m.shape.len() == 1
        })
        .into_iter()
        .max_by_key(|m| m.shape[0])
        .context("missing staticloop_mul_f32 artifact")?
        .clone();
    let n = meta.shape[0];

    let mut rng = Rng::new(42);
    let x = rand_tensor(&mut rng, &[1, n], crate::tensor::DType::F32);
    let params = Tensor::from_f32(&[0.9999], &[1]);
    let exec = xp.executor();

    let points: Vec<usize> = if xp.fast {
        vec![1, 16, 64, 256, 1024]
    } else {
        vec![1, 4, 16, 64, 128, 260, 380, 512, 768, 1161]
    };

    let mut t = Table::new(
        "Fig. 1 — kernel time vs instructions per element",
        &["instrs", "measured_ms (CPU-PJRT)", "rsd_%", "sim_rtx4090_ms", "regime"],
    );
    t.note(format!("vector = {n} f32 elements; measured substrate = fused StaticLoop artifact"));
    t.note("sim column = analytical RTX 4090 model at paper scale (66.3M elems), labelled simulated");

    let gpu = GpuModel::new(table_ii_systems()[4]);
    let hw = crate::bench::calibrate();
    for &i in &points {
        let trip = Tensor::from_i32(&[i as i32], &[1]);
        let st = xp.measure(|| {
            exec.run(&meta.name, &[&trip, &x, &params]).unwrap()
        });
        let sim = gpu.fig1_curve(3840.0 * 2160.0 * 8.0, 8.0, &[i as f64])[0].1;
        let mb = crate::fusion::cost::is_memory_bound(&hw, (n * 8) as f64, n as f64, i as f64);
        t.row(vec![
            i.to_string(),
            ms(st.mean_s),
            format!("{:.2}", st.rsd_pct),
            ms(sim),
            if mb { "MB".into() } else { "CB".into() },
        ]);
    }
    Ok(vec![t])
}
