//! Reduction ablation — fused fold-while-reading vs op-at-a-time reduction
//! on the host tier, artifact-free.
//!
//! Two arms over the same map+reduce workload (per-channel mean + sum of
//! squares of a scaled u8 image batch — normalize pass 1):
//!
//! * **op-at-a-time**: materialize the mapped tensor (one whole-buffer step
//!   kernel), then one more whole-buffer sweep PER statistic over the
//!   materialized copy — the only shape the map-only op vocabulary allowed;
//! * **fused**: the engine's fold-while-reading tier — ONE pass over the
//!   raw input folding the chain in registers and both statistics into
//!   per-block accumulators (no intermediate ever touches memory).
//!
//! Like `hostvf`/`hostpre` this needs NO artifacts: it runs on any machine
//! (`xp reduce`) and anchors the fused-reduction speedup the `reduce_bench`
//! acceptance criterion enforces.

use std::time::Duration;

use anyhow::Result;

use crate::bench::{time_fn, Table};
use crate::chain::{Chain, Mul, U8};
use crate::exec::{Engine, HostFusedEngine};
use crate::hostref;
use crate::ops::{kernel, Opcode, ReduceAxis, ReduceKind, ScalarOp};
use crate::proplite::Rng;
use crate::tensor::{DType, Tensor};

use super::common::{fx, ms, XpCtx};

pub fn run(xp: &XpCtx) -> Result<Vec<Table>> {
    run_with(xp.reps, xp.budget, xp.fast)
}

/// Artifact-free entry point (`xp reduce` works without `make artifacts`).
pub fn run_with(reps: usize, budget: Duration, fast: bool) -> Result<Vec<Table>> {
    let eng = HostFusedEngine::new();
    let (h, w) = (720usize, 1280usize);

    let mut t = Table::new(
        "Reduction ablation — fused fold-while-reading vs op-at-a-time (720p RGB, mean+sumsq)",
        &["batch", "op_at_a_time_ms", "fused_ms", "speedup"],
    );
    t.note(
        "op_at_a_time: materialize the mapped tensor, then one whole-buffer sweep per statistic; \
         fused: one fold-while-reading pass over the raw input on the host fused engine — no \
         artifacts, statistics bit-equal to the hostref reduction oracle",
    );

    let batches: &[usize] = if fast { &[1, 4] } else { &[1, 4, 8, 16] };
    for &b in batches {
        let mut rng = Rng::new(7 + b as u64);
        let input = Tensor::from_u8(&rng.vec_u8(b * h * w * 3), &[b, h, w, 3]);
        let typed = Chain::read::<U8>(&[h, w, 3])
            .batch(b)
            .map(Mul(1.0 / 255.0))
            .reduce_pair_per_channel(ReduceKind::Mean, ReduceKind::SumSq);
        let p = typed.pipeline();

        // correctness anchor: the fused fold is bit-equal to the oracle
        let fused = eng.run(p, &input)?;
        let want = hostref::run_pipeline(p, &input);
        anyhow::ensure!(fused == want, "b{b}: fused reduction diverged from the oracle");

        let oat = time_fn(reps, budget, || op_at_a_time(&input));
        let fsd = time_fn(reps, budget, || eng.run(p, &input).unwrap());
        t.row(vec![b.to_string(), ms(oat.mean_s), ms(fsd.mean_s), fx(oat.mean_s / fsd.mean_s)]);
    }
    Ok(vec![t])
}

/// The pre-reduce-subsystem shape: one materialized map step, then one
/// whole-buffer sweep per statistic over the materialized copy.
fn op_at_a_time(input: &Tensor) -> Vec<f64> {
    // step 1: materialize the mapped tensor (the step-kernel boundary)
    let mut vals = input.to_f64_vec();
    ScalarOp::Scalar { op: Opcode::Mul, param: 1.0 / 255.0 }.apply_slice_f64(&mut vals, 0);
    let mapped = Tensor::from_f64_cast(&vals, input.shape(), DType::F32);
    drop(vals);
    // step 2: reduce the MATERIALIZED copy (another whole-buffer pass with
    // its own widening — the traffic fold-while-reading removes)
    let m = mapped.to_f64_vec();
    let spec =
        crate::ops::ReduceSpec::pair(ReduceKind::Mean, ReduceKind::SumSq, ReduceAxis::PerChannel);
    let mut acc = kernel::reduce_acc_identity(spec);
    for (i, &v) in m.iter().enumerate() {
        kernel::reduce_acc_fold(spec, &mut acc, i, v);
    }
    kernel::reduce_finalize(spec, &acc, m.len())
}
