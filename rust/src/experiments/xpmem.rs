//! §VI-L — GPU memory savings.
//!
//! Paper: VF avoids allocating the intermediate images (crop_32F, d_up,
//! d_temp in Fig. 25a): ~259 KB at 60x120 crops; a 4k NV12 frame is 12.44 MB
//! and RGB 24.88 MB, 8k multiplies by 4.

use anyhow::Result;

use crate::bench::Table;
use crate::chain::build_erased_opcodes;
use crate::fusion::memsave;
use crate::ops::Opcode;
use crate::tensor::DType;

fn kb(b: usize) -> String {
    format!("{:.1}", b as f64 / 1024.0)
}

fn mb(b: usize) -> String {
    format!("{:.2}", b as f64 / (1024.0 * 1024.0))
}

pub fn run(_xp: &super::XpCtx) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "§VI-L — device memory savings from VF",
        &["workload", "fused_total", "unfused_total", "saved"],
    );

    // the paper's production pipeline at batch 50
    let r = memsave::preproc_report(50, 60, 120, 128, 64);
    t.row(vec![
        "preproc b50 (60x120 -> 128x64 f32)".into(),
        format!("{} KB", kb(r.fused_total())),
        format!("{} KB", kb(r.unfused_total())),
        format!("{} KB", kb(r.saved())),
    ]);

    // chain pipelines at growing sizes
    for (label, shape) in [
        ("chain x4, 1080p u8->f32", vec![1080usize, 1920]),
        ("chain x4, 4k u8->f32", vec![2160, 4096]),
        ("chain x4, 8k u8->f32", vec![4320, 8192]),
    ] {
        let p = build_erased_opcodes(
            &[(Opcode::Nop, 0.0), (Opcode::Mul, 1.0), (Opcode::Sub, 0.0), (Opcode::Div, 1.0)],
            &shape,
            1,
            DType::U8,
            DType::F32,
        );
        let r = memsave::report(&p);
        t.row(vec![
            label.into(),
            format!("{} MB", mb(r.fused_total())),
            format!("{} MB", mb(r.unfused_total())),
            format!("{} MB", mb(r.saved())),
        ]);
    }
    t.note("paper reports 259 KB saved for the batch-50 preproc case and 12.44/24.88 MB frames at 4k");
    Ok(vec![t])
}
