//! Host-VF ablation — the paper's vertical fusion claim isolated on the CPU.
//!
//! Three arms over the same chain, 1080p f32 frame:
//!
//! * op-at-a-time (hostref: one whole-buffer sweep per op);
//! * fused single pass, 1 thread (pure VF: register-resident intermediates);
//! * fused single pass, all threads (VF + the HF analog).
//!
//! Unlike every other experiment this one needs NO artifacts: it runs on any
//! machine (`xp hostvf`) and anchors the fused-engine speedups the
//! `host_fusion_bench` acceptance criterion enforces.

use std::time::Duration;

use anyhow::Result;

use crate::bench::{time_fn, Table};
use crate::chain::build_erased_opcodes;
use crate::exec::{Engine, HostFusedEngine};
use crate::hostref;
use crate::ops::Opcode;
use crate::proplite::Rng;
use crate::tensor::{DType, Tensor};

use super::common::{fx, ms, XpCtx};

pub fn run(xp: &XpCtx) -> Result<Vec<Table>> {
    run_with(xp.reps, xp.budget, xp.fast)
}

/// Artifact-free entry point (`xp hostvf` works without `make artifacts`).
pub fn run_with(reps: usize, budget: Duration, fast: bool) -> Result<Vec<Table>> {
    let eng_1t = HostFusedEngine::with_threads(1);
    let eng_mt = HostFusedEngine::new();
    let mut rng = Rng::new(7);
    let (h, w) = (1080usize, 1920usize);
    let x = Tensor::from_f32(&rng.vec_f32(h * w, -2.0, 2.0), &[1, h, w]);

    let mut t = Table::new(
        "Host-VF ablation — single fused pass vs op-at-a-time (1080p f32)",
        &[
            "chain_len",
            "op_at_a_time_ms",
            "fused_1t_ms",
            "fused_mt_ms",
            "vf_speedup",
            "vf_hf_speedup",
        ],
    );
    t.note(format!(
        "fused_mt uses {} threads; vf_speedup = op-at-a-time / fused_1t (pure register-residency effect)",
        eng_mt.threads()
    ));

    let lens: &[usize] = if fast { &[1, 4, 16] } else { &[1, 2, 4, 8, 12, 16] };
    for &k in lens {
        let chain: Vec<(Opcode, f64)> = (0..k)
            .map(|i| match i % 3 {
                0 => (Opcode::Mul, 0.999),
                1 => (Opcode::Add, 0.001),
                _ => (Opcode::Sub, 0.0005),
            })
            .collect();
        let p = build_erased_opcodes(&chain, &[h, w], 1, DType::F32, DType::F32);
        let base = time_fn(reps, budget, || hostref::run_pipeline(&p, &x));
        let f1 = time_fn(reps, budget, || eng_1t.run(&p, &x).unwrap());
        let fm = time_fn(reps, budget, || eng_mt.run(&p, &x).unwrap());
        t.row(vec![
            k.to_string(),
            ms(base.mean_s),
            ms(f1.mean_s),
            ms(fm.mean_s),
            fx(base.mean_s / f1.mean_s),
            fx(base.mean_s / fm.mean_s),
        ]);
    }
    Ok(vec![t])
}
