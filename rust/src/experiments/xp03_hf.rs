//! Exp. 3 (Fig. 17) — speedup vs number of horizontally fused kernels.
//!
//! Paper: batches of 10..600 images of 60x120 u8; chain Cast-Mul-Sub-Div
//! (VF in both arms); batched single launch vs per-image launches; max 66x,
//! and 37x vs CUDA-Graphs-assisted looping.

use anyhow::Result;

use crate::bench::Table;
use crate::exec::Engine;
use crate::proplite::Rng;
use crate::tensor::DType;

use super::common::{cmsd, fx, ms, rand_tensor, XpCtx};

pub fn run(xp: &XpCtx) -> Result<Vec<Table>> {
    let batches: Vec<usize> = {
        let all = xp.geom_usizes("hf_batches", &[1, 2, 4, 8, 16, 25, 50]);
        if xp.fast {
            all.into_iter().filter(|b| [1usize, 8, 50, 150].contains(b)).collect()
        } else {
            all
        }
    };

    let mut t = Table::new(
        "Fig. 17 — HF sweep, chain Cast-Mul-Sub-Div, 60x120 u8->f32",
        &["batch", "hf_ms (1 launch)", "loop_ms (B launches)", "graph_loop_ms", "speedup", "speedup_vs_graph"],
    );
    t.note("both arms are vertically fused (paper: 'we use cvGS with VF in both cases')");

    let mut rng = Rng::new(5);
    for &b in &batches {
        let input = rand_tensor(&mut rng, &[b, 60, 120], DType::U8);
        // HF arm: one launch of the batched chain artifact
        let p_batched = cmsd(&[60, 120], b, DType::U8, DType::F32);
        let hf = xp.measure(|| xp.fused().run(&p_batched, &input).unwrap());

        // loop arm: B launches of the b=1 chain artifact
        let p_one = cmsd(&[60, 120], 1, DType::U8, DType::F32);
        let items: Vec<_> = (0..b)
            .map(|i| crate::exec::slice_batch(&input, i, 60 * 120, &[60, 120]))
            .collect();
        let lp = xp.measure(|| {
            for item in &items {
                std::hint::black_box(xp.fused().run(&p_one, item).unwrap());
            }
        });

        // graph arm: record the B-launch loop once, replay (paper: HF via
        // CUDA Graphs). Our ExecGraph is linear, so replay per item but with
        // zero per-step host work.
        let gr = xp.measure(|| {
            for item in &items {
                std::hint::black_box(xp.graph().run(&p_one, item).unwrap());
            }
        });

        t.row(vec![
            b.to_string(),
            ms(hf.mean_s),
            ms(lp.mean_s),
            ms(gr.mean_s),
            fx(lp.mean_s / hf.mean_s),
            fx(gr.mean_s / hf.mean_s),
        ]);
    }
    Ok(vec![t])
}
