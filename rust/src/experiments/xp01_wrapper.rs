//! Exp. 1 (§VI-A) — cvGS wrapper overhead.
//!
//! Paper: the wrapper only copies parameters from OpenCV classes into FKL
//! structs; GPU code is identical, CPU overhead negligible. We time the same
//! fused chain (a) through the `cv::execute_operations` wrapper and (b)
//! through the raw engine with a prebuilt pipeline, plus (c) the pure
//! host-side wrapper cost (pipeline building + planning, no launch).

use anyhow::Result;

use crate::bench::Table;
use crate::cv;
use crate::exec::Engine;
use crate::proplite::Rng;
use crate::tensor::DType;

use super::common::{cmsd, ms, rand_tensor, XpCtx};

pub fn run(xp: &XpCtx) -> Result<Vec<Table>> {
    let mut rng = Rng::new(7);
    let input = rand_tensor(&mut rng, &[50, 60, 120], DType::U8);
    let iops =
        [cv::convert_to(), cv::multiply(0.5), cv::subtract(3.0), cv::divide(1.7)];

    // (a) through the wrapper
    let wrapped = xp.measure(|| {
        cv::execute_operations(&xp.ctx, &input, DType::F32, &iops).unwrap()
    });

    // (b) raw engine, pipeline prebuilt
    let p = cmsd(&[60, 120], 50, DType::U8, DType::F32);
    let raw = xp.measure(|| xp.fused().run(&p, &input).unwrap());

    // (c) wrapper-only CPU work: build + validate + plan, no launch
    let cpu_only = xp.measure(|| {
        let p = cv::build_pipeline(&input, DType::F32, &iops).unwrap();
        xp.fused().plan_for(&p).unwrap()
    });

    let mut t = Table::new(
        "Exp. 1 — cvGS wrapper overhead (chain Cast-Mul-Sub-Div, batch 50, 60x120 u8->f32)",
        &["path", "mean_ms", "rsd_%", "overhead vs raw"],
    );
    let base = raw.mean_s;
    for (name, st) in [("raw engine", raw), ("cv wrapper", wrapped), ("wrapper CPU-only", cpu_only)]
    {
        t.row(vec![
            name.to_string(),
            ms(st.mean_s),
            format!("{:.2}", st.rsd_pct),
            format!("{:+.2}%", (st.mean_s - base) / base * 100.0),
        ]);
    }
    t.note("paper finds the wrapper overhead negligible; expected |overhead| within noise");
    Ok(vec![t])
}
