//! Shared experiment context and helpers.

use std::rc::Rc;
use std::time::Duration;

use anyhow::{Context as _, Result};

use crate::bench::{time_fn, Stats};
use crate::chain::{build_erased_opcodes, ComputeOp};
use crate::cv::Context;
use crate::exec::{EngineSelect, FusedEngine, GraphEngine, UnfusedEngine};
use crate::ops::{Opcode, Pipeline};
use crate::proplite::Rng;
use crate::runtime::{Executor, Registry};
use crate::tensor::{DType, Tensor};

/// Shared state for all experiment runners.
pub struct XpCtx {
    pub ctx: Context,
    /// Max measured repetitions per point (paper: 100).
    pub reps: usize,
    /// Wall-time budget per measured point.
    pub budget: Duration,
    /// Trim sweeps (CI mode).
    pub fast: bool,
}

/// Measurement policy shared by every experiment entry point (registry-backed
/// [`XpCtx`] and the artifact-free [`super::run_host`] path must measure the
/// same way or their tables are not comparable).
pub fn measure_policy(fast: bool) -> (usize, Duration) {
    if fast {
        (10, Duration::from_millis(300))
    } else {
        (30, Duration::from_secs(2))
    }
}

impl XpCtx {
    pub fn new(fast: bool) -> Result<XpCtx> {
        let (reps, budget) = measure_policy(fast);
        Ok(XpCtx {
            // experiments compare against the artifact family, so the XLA
            // backend is pinned (Auto would silently degrade to host)
            ctx: Context::with_select(EngineSelect::Xla, None)
                .context("experiments need artifacts; run `make artifacts`")?,
            reps,
            budget,
            fast,
        })
    }

    /// The XLA fused engine (present by construction: `new` pins Xla).
    pub fn fused(&self) -> &FusedEngine {
        self.ctx.fused().expect("XpCtx::new loaded the registry")
    }

    /// The per-op baseline engine.
    pub fn unfused(&self) -> &UnfusedEngine {
        self.ctx.unfused().expect("XpCtx::new loaded the registry")
    }

    /// The graph-replay baseline engine.
    pub fn graph(&self) -> &GraphEngine {
        self.ctx.graph().expect("XpCtx::new loaded the registry")
    }

    /// The raw artifact executor (for StaticLoop trip-count sweeps).
    pub fn executor(&self) -> &Executor {
        self.fused().executor()
    }

    pub fn registry(&self) -> Rc<Registry> {
        self.ctx.registry().expect("XpCtx::new loaded the registry")
    }

    /// Measure a closure with this context's rep/budget policy.
    pub fn measure<T>(&self, f: impl FnMut() -> T) -> Stats {
        time_fn(self.reps, self.budget, f)
    }

    /// Geometry list from the manifest (falls back if missing).
    pub fn geom_usizes(&self, key: &str, fallback: &[usize]) -> Vec<usize> {
        self.registry().geometry[key].as_usize_vec().unwrap_or_else(|| fallback.to_vec())
    }
}

/// Deterministic random tensor for a dtype (values kept in a range where all
/// chains stay finite and integer saturation is rare).
pub fn rand_tensor(rng: &mut Rng, shape: &[usize], dt: DType) -> Tensor {
    let n: usize = shape.iter().product();
    match dt {
        DType::U8 => Tensor::from_u8(&rng.vec_u8(n), shape),
        DType::U16 => {
            let v: Vec<u16> = (0..n).map(|_| (rng.next_u64() & 0xFFF) as u16).collect();
            Tensor::from_u16(&v, shape)
        }
        DType::I32 => {
            let v: Vec<i32> = (0..n).map(|_| (rng.next_u64() & 0xFFFF) as i32).collect();
            Tensor::from_i32(&v, shape)
        }
        DType::F32 => Tensor::from_f32(&rng.vec_f32(n, 0.0, 1.0), shape),
        DType::F64 => {
            let v: Vec<f64> = (0..n).map(|_| rng.f64(0.0, 1.0)).collect();
            Tensor::from_f64(&v, shape)
        }
    }
}

/// Pipeline of n (Mul a, Add b) pairs — the paper's favourite chain. Params
/// contractive so long chains stay finite. Lowered through the typed chain's
/// dynamic entrance (dtypes are sweep data here).
pub fn muladd_pairs(n_pairs: usize, shape: &[usize], batch: usize, dtin: DType, dtout: DType) -> Pipeline {
    let mut chain = Vec::with_capacity(n_pairs * 2);
    for _ in 0..n_pairs {
        chain.push((Opcode::Mul, 0.999));
        chain.push((Opcode::Add, 0.001));
    }
    build_erased_opcodes(&chain, shape, batch, dtin, dtout)
}

/// The Fig. 17/23 chain: Cast -> Mul -> Sub -> Div.
pub fn cmsd(shape: &[usize], batch: usize, dtin: DType, dtout: DType) -> Pipeline {
    let stages = [
        ComputeOp::scalar(Opcode::Nop, 0.0),
        ComputeOp::scalar(Opcode::Mul, 0.5),
        ComputeOp::scalar(Opcode::Sub, 3.0),
        ComputeOp::scalar(Opcode::Div, 1.7),
    ];
    crate::chain::build_erased(&stages, shape, batch, dtin, dtout)
}

/// Format a speedup cell.
pub fn fx(x: f64) -> String {
    if x >= 100.0 {
        format!("{x:.0}x")
    } else if x >= 10.0 {
        format!("{x:.1}x")
    } else {
        format!("{x:.2}x")
    }
}

/// Format milliseconds.
pub fn ms(s: f64) -> String {
    format!("{:.4}", s * 1e3)
}
