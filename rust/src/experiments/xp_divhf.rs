//! Divergent-HF ablation — per-item vs stacked vs divergent serving on the
//! host tier, artifact-free.
//!
//! Two questions, two tables:
//!
//! 1. **Mixed traffic** (the divergent tier's reason to exist): a window of
//!    signature-divergent pipelines — crops, resizes, normalize-map chains,
//!    a reduce — served per item (the only pre-divergent option: nothing
//!    stacks) vs as ONE thread-chunked divergent pass.
//! 2. **Homogeneous traffic** (the ladder's ordering): a window of
//!    IDENTICAL requests served per item, stacked into one batched launch
//!    (tier 1), and through the divergent pass (tier 2) — stacking should
//!    win on identical work, which is why the scheduler tries it first.
//!
//! Like `hostvf`/`hostpre`/`reduce` this needs NO artifacts: it runs on any
//! machine (`xp divhf`) and anchors the speedup the `divergent_bench`
//! acceptance criterion enforces.

use std::time::Duration;

use anyhow::Result;

use crate::bench::{time_fn, Table};
use crate::chain::{Add, Chain, CvtColor, DivC3, Mul, MulC3, SubC3, F32, U8};
use crate::exec::{stack_batch, Engine, HostFusedEngine};
use crate::ops::{Pipeline, ReduceKind};
use crate::proplite::Rng;
use crate::tensor::{make_frame, Rect, Tensor};

use super::common::{fx, ms, XpCtx};

pub fn run(xp: &XpCtx) -> Result<Vec<Table>> {
    run_with(xp.reps, xp.budget, xp.fast)
}

/// The mixed window of the divergent bench, at 720p scale.
fn mixed_window(n: usize, frame: &Tensor, rng: &mut Rng) -> Vec<(Pipeline, Tensor)> {
    (0..n)
        .map(|i| {
            let x = (13 * i % 700) as i32;
            let y = (11 * i % 400) as i32;
            match i % 4 {
                0 => (
                    Chain::read_crop::<U8>(Rect::new(x, y, 96, 96))
                        .map(Mul(1.0 / 255.0))
                        .map(Add(0.01 * i as f64))
                        .cast::<F32>()
                        .write()
                        .into_pipeline(),
                    frame.clone(),
                ),
                1 => (
                    Chain::read_resize::<U8>(Rect::new(x, y, 160, 120), 64, 64)
                        .map(CvtColor)
                        .map(MulC3([1.0 / 255.0; 3]))
                        .cast::<F32>()
                        .write_split()
                        .into_pipeline(),
                    frame.clone(),
                ),
                2 => (
                    Chain::read::<U8>(&[64, 64, 3])
                        .map(Mul(1.0 / 255.0))
                        .map(SubC3([0.5, 0.4, 0.3]))
                        .map(DivC3([0.2, 0.25, 0.3]))
                        .cast::<F32>()
                        .write()
                        .into_pipeline(),
                    Tensor::from_u8(&rng.vec_u8(64 * 64 * 3), &[1, 64, 64, 3]),
                ),
                _ => (
                    Chain::read_crop::<U8>(Rect::new(x, y, 96, 96))
                        .map(Mul(1.0 / 255.0))
                        .reduce_pair_per_channel(ReduceKind::Mean, ReduceKind::SumSq)
                        .into_pipeline(),
                    frame.clone(),
                ),
            }
        })
        .collect()
}

/// Artifact-free entry point (`xp divhf` works without `make artifacts`).
pub fn run_with(reps: usize, budget: Duration, fast: bool) -> Result<Vec<Table>> {
    let eng = HostFusedEngine::new();
    let mut rng = Rng::new(19);
    let frame = make_frame(720, 1280, 5);

    // --- table 1: mixed traffic, per-item vs divergent --------------------
    let mut mixed = Table::new(
        "Divergent-HF ablation — mixed window (crop/resize/normalize/reduce), per-item vs \
         one divergent pass",
        &["window", "per_item_ms", "divergent_ms", "speedup", "lanes", "occupancy"],
    );
    mixed.note(
        "signature-divergent 720p window: nothing stacks, so per-item serving was the only \
         pre-divergent option; the divergent tier chunks the window across worker lanes — \
         results bit-equal to per-item serving (asserted before timing)",
    );
    let windows: &[usize] = if fast { &[2, 8] } else { &[2, 4, 8, 16] };
    for &n in windows {
        let reqs = mixed_window(n, &frame, &mut rng);
        let refs: Vec<(&Pipeline, &Tensor)> = reqs.iter().map(|(p, t)| (p, t)).collect();
        let probe = eng.run_divergent(&refs);
        for ((p, t), res) in refs.iter().zip(&probe.results) {
            let alone = eng.run(p, t)?;
            anyhow::ensure!(res.as_ref().unwrap() == &alone, "divergent != per-item");
        }
        let occ = probe.occupancy();
        let per = time_fn(reps, budget, || {
            for (p, t) in &refs {
                eng.run(p, t).unwrap();
            }
        });
        let div = time_fn(reps, budget, || eng.run_divergent(&refs));
        mixed.row(vec![
            n.to_string(),
            ms(per.mean_s),
            ms(div.mean_s),
            fx(per.mean_s / div.mean_s),
            probe.lanes.to_string(),
            format!("{occ:.2}"),
        ]);
    }

    // --- table 2: homogeneous traffic, the ladder's three tiers -----------
    let mut homog = Table::new(
        "Divergent-HF ablation — homogeneous window of 8: per-item vs stacked vs divergent",
        &["arm", "ms", "speedup_vs_per_item"],
    );
    homog.note(
        "8 identical dense requests (u8 [96, 96, 3] -> normalize-map -> f32): stacking is one \
         monomorphized batched launch and wins, which is why the scheduler tries tier 1 first",
    );
    let p1 = Chain::read::<U8>(&[96, 96, 3])
        .map(Mul(1.0 / 255.0))
        .map(SubC3([0.5, 0.4, 0.3]))
        .map(DivC3([0.2, 0.25, 0.3]))
        .cast::<F32>()
        .write()
        .into_pipeline();
    let items: Vec<Tensor> =
        (0..8).map(|_| Tensor::from_u8(&rng.vec_u8(96 * 96 * 3), &[1, 96, 96, 3])).collect();
    let refs: Vec<(&Pipeline, &Tensor)> = items.iter().map(|t| (&p1, t)).collect();
    let item_refs: Vec<&Tensor> = items.iter().collect();
    let stacked_p = p1.with_batch(8);
    let per = time_fn(reps, budget, || {
        for (p, t) in &refs {
            eng.run(p, t).unwrap();
        }
    });
    let stk = time_fn(reps, budget, || {
        let input = stack_batch(&item_refs, 8, &p1.shape);
        eng.run(&stacked_p, &input).unwrap()
    });
    let div = time_fn(reps, budget, || eng.run_divergent(&refs));
    homog.row(vec!["per_item".into(), ms(per.mean_s), fx(1.0)]);
    homog.row(vec!["stacked".into(), ms(stk.mean_s), fx(per.mean_s / stk.mean_s)]);
    homog.row(vec!["divergent".into(), ms(div.mean_s), fx(per.mean_s / div.mean_s)]);

    Ok(vec![mixed, homog])
}
