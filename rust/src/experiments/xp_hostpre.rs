//! Host preproc ablation — the paper's flagship NPP workload (Fig. 24/25)
//! isolated on the host tier, artifact-free.
//!
//! Three arms over the same Batch(Crop+Resize -> ColorConvert -> MulC ->
//! SubC -> DivC -> Split) pipeline against a shared 720p frame:
//!
//! * NPP-style op-at-a-time ([`PreprocPipeline::run_npp_style`]): one
//!   whole-buffer pass per step per crop, every intermediate materialized;
//! * fused structured single pass ([`PreprocPipeline::run`]): bilinear
//!   gather while reading, chain folded in registers, split while writing;
//! * the same fused pass with precomputed parameters
//!   ([`PreprocPipeline::run_precomputed`]).
//!
//! Like `hostvf` this needs NO artifacts: it runs on any machine
//! (`xp hostpre`) and anchors the fused-preproc speedup the
//! `preproc_bench` acceptance criterion enforces.

use std::time::Duration;

use anyhow::Result;

use crate::bench::{time_fn, Table};
use crate::cv::Context;
use crate::exec::EngineSelect;
use crate::hostref;
use crate::npp::{PreprocPipeline, ResizeBatchSpec};
use crate::tensor::{make_frame, Rect};

use super::common::{fx, ms, XpCtx};

pub fn run(xp: &XpCtx) -> Result<Vec<Table>> {
    run_with(xp.reps, xp.budget, xp.fast)
}

/// Artifact-free entry point (`xp hostpre` works without `make artifacts`).
pub fn run_with(reps: usize, budget: Duration, fast: bool) -> Result<Vec<Table>> {
    // the host tier is the point of this ablation: pin it so the numbers
    // stay comparable on machines that DO have artifacts
    let ctx = Context::with_select(EngineSelect::HostFused, None)?;
    let frame = make_frame(720, 1280, 99);
    let (dh, dw) = (128usize, 64usize);
    let (mulv, subv, divv) = ([0.9, 1.0, 1.1], [0.5, 0.4, 0.3], [2.0, 2.1, 2.2]);

    let mut t = Table::new(
        "Host preproc ablation — fused structured pass vs NPP-style op-at-a-time (720p, 128x64)",
        &["batch", "npp_style_ms", "fused_ms", "fused_pre_ms", "speedup", "speedup_precomputed"],
    );
    t.note(
        "npp_style: one materialized pass per step per crop; fused: one structured pass per crop \
         (gather while reading, split while writing) on the host fused engine — no artifacts",
    );

    let batches: &[usize] = if fast { &[2, 8] } else { &[2, 8, 24, 50] };
    for &b in batches {
        let rects: Vec<Rect> = (0..b)
            .map(|i| Rect::new((i as i32 * 37) % 1100, (i as i32 * 17) % 640, 120, 60))
            .collect();
        let mut pipe = PreprocPipeline::new(
            ResizeBatchSpec { rects: rects.clone(), dst_h: dh, dst_w: dw },
            mulv,
            subv,
            divv,
        );

        // correctness anchor: fused matches the Fig. 25 oracle per batch
        let fused = pipe.run(&ctx, &frame)?;
        let want = hostref::preproc(&frame, &rects, mulv, subv, divv, dh, dw);
        for (i, (a, w)) in fused.to_f64_vec().iter().zip(want.to_f64_vec()).enumerate() {
            anyhow::ensure!(
                (a - w).abs() <= 1e-3 + 1e-3 * w.abs(),
                "b{b} elem {i}: fused diverged from oracle ({a} vs {w})"
            );
        }

        let npp = time_fn(reps, budget, || pipe.run_npp_style(&ctx, &frame).unwrap());
        let fsd = time_fn(reps, budget, || pipe.run(&ctx, &frame).unwrap());
        pipe.precompute();
        let pre = time_fn(reps, budget, || pipe.run_precomputed(&ctx, &frame).unwrap());

        t.row(vec![
            b.to_string(),
            ms(npp.mean_s),
            ms(fsd.mean_s),
            ms(pre.mean_s),
            fx(npp.mean_s / fsd.mean_s),
            fx(npp.mean_s / pre.mean_s),
        ]);
    }
    Ok(vec![t])
}
