//! Exp. 10 (Fig. 24) — NPP vs FastNPP on the production pipeline.
//!
//! Paper: Batch(Crop->Resize->ColorConvert->Mul->Sub->Div->Split), batch
//! 10..150; FastNPP with per-iteration CPU work saturates at 61x; with
//! precomputed IOps it reaches 136x.

use anyhow::Result;

use crate::bench::Table;
use crate::npp::{PreprocPipeline, ResizeBatchSpec};
use crate::tensor::{make_frame, Rect};

use super::common::{fx, ms, XpCtx};

pub fn run(xp: &XpCtx) -> Result<Vec<Table>> {
    let batches: Vec<usize> = {
        let all = xp.geom_usizes("preproc_batches", &[2, 8, 50, 152]);
        if xp.fast {
            all.into_iter().filter(|b| [2usize, 16, 50].contains(b)).collect()
        } else {
            all
        }
    };

    let frame = make_frame(720, 1280, 99);
    let mut t = Table::new(
        "Fig. 24 — NPP-style vs FastNPP (preproc pipeline)",
        &["batch", "npp_ms", "fastnpp_ms", "fastnpp_pre_ms", "speedup", "speedup_precomputed"],
    );
    t.note("npp arm: one launch per step per crop; fastnpp: one fused launch (with/without per-iteration CPU parameter work)");

    for &b in &batches {
        let rects: Vec<Rect> = (0..b)
            .map(|i| Rect::new((i as i32 * 37) % 1100, (i as i32 * 17) % 640, 120, 60))
            .collect();
        let mut pipe = PreprocPipeline::new(
            ResizeBatchSpec { rects, dst_h: 128, dst_w: 64 },
            [0.9, 1.0, 1.1],
            [0.5, 0.4, 0.3],
            [2.0, 2.1, 2.2],
        );

        let npp = xp.measure(|| pipe.run_npp_style(&xp.ctx, &frame).unwrap());
        let fast = xp.measure(|| pipe.run(&xp.ctx, &frame).unwrap());
        pipe.precompute();
        let fast_pre = xp.measure(|| pipe.run_precomputed(&xp.ctx, &frame).unwrap());

        t.row(vec![
            b.to_string(),
            ms(npp.mean_s),
            ms(fast.mean_s),
            ms(fast_pre.mean_s),
            fx(npp.mean_s / fast.mean_s),
            fx(npp.mean_s / fast_pre.mean_s),
        ]);
    }
    Ok(vec![t])
}
