//! Ablations beyond the paper's tables (DESIGN.md calls these out):
//!
//! 1. lowering variant: the same chain AOT'd through the Pallas TransformDPP
//!    vs plain-XLA jnp lowering (is the DPP structure costing anything on
//!    this backend?);
//! 2. planner tier: exact fused artifact vs the generic interpreter kernel
//!    (what does runtime-fusion generality cost?);
//! 3. HF bucket padding: running batch m on the next-larger bucket vs exact.

use anyhow::Result;

use crate::bench::Table;
use crate::exec::{Engine, FusedEngine};
use crate::fusion::FusionPlan;
use crate::chain::build_erased_opcodes;
use crate::ops::Opcode;
use crate::proplite::Rng;
use crate::tensor::{DType, Tensor};

use super::common::{cmsd, fx, ms, rand_tensor, XpCtx};

pub fn run(xp: &XpCtx) -> Result<Vec<Table>> {
    let mut tables = Vec::new();
    let mut rng = Rng::new(31);

    // 1. pallas vs xla lowering of the same chain
    {
        let input = rand_tensor(&mut rng, &[50, 60, 120], DType::U8);
        let p = cmsd(&[60, 120], 50, DType::U8, DType::F32);
        let pallas = FusedEngine::with_variant(xp.registry(), "pallas");
        let xla = FusedEngine::with_variant(xp.registry(), "xla");
        let tp = xp.measure(|| pallas.run(&p, &input).unwrap());
        let tx = xp.measure(|| xla.run(&p, &input).unwrap());
        let mut t = Table::new(
            "Ablation 1 — lowering variant (chain CMSD b50 60x120 u8->f32)",
            &["variant", "mean_ms", "rsd_%", "vs pallas"],
        );
        t.row(vec!["pallas".into(), ms(tp.mean_s), format!("{:.2}", tp.rsd_pct), "1.00x".into()]);
        t.row(vec![
            "xla".into(),
            ms(tx.mean_s),
            format!("{:.2}", tx.rsd_pct),
            fx(tp.mean_s / tx.mean_s),
        ]);
        t.note("same math, same fusion; differences are lowering artifacts (interpret-mode pallas emits grid loops)");
        tables.push(t);
    }

    // 2. exact tier vs interpreter tier on the interp artifact's shape
    {
        let input = rand_tensor(&mut rng, &[1, 256, 256], DType::F32);
        // a chain the interpreter covers; no exact artifact exists for it
        let p_interp = build_erased_opcodes(
            &[(Opcode::Mul, 1.1), (Opcode::Add, 0.2), (Opcode::Abs, 0.0), (Opcode::Min, 3.0)],
            &[256, 256],
            1,
            DType::F32,
            DType::F32,
        );
        let plan = xp.fused().plan_for(&p_interp)?;
        let ti = xp.measure(|| xp.fused().run(&p_interp, &input).unwrap());

        // a chain with an exact artifact at another shape for reference:
        // use mul-add on the smoke artifact shape
        let p_exact = build_erased_opcodes(
            &[(Opcode::Mul, 1.1), (Opcode::Add, 0.2)],
            &[4, 8],
            2,
            DType::F32,
            DType::F32,
        );
        let input2 = rand_tensor(&mut rng, &[2, 4, 8], DType::F32);
        let te = xp.measure(|| xp.fused().run(&p_exact, &input2).unwrap());

        let mut t = Table::new(
            "Ablation 2 — planner tier cost (per-launch overhead view)",
            &["tier", "workload", "mean_ms"],
        );
        t.row(vec![plan.tier().to_string(), "4-op chain 256x256 f32".into(), ms(ti.mean_s)]);
        t.row(vec!["exact".into(), "2-op chain 4x8x2 f32 (launch floor)".into(), ms(te.mean_s)]);
        t.note("interp tier pays a lax.switch per op slot inside the kernel; exact tier bakes the chain");
        tables.push(t);
    }

    // 3. HF bucket padding cost
    {
        let mut t = Table::new(
            "Ablation 3 — HF bucket padding (chain CMSD u8->f32)",
            &["m_items", "bucket", "exact_ms_per_item", "padded_ms_per_item", "pad_overhead"],
        );
        for (m, bucket) in [(25usize, 50usize), (100, 150)] {
            let input_m = rand_tensor(&mut rng, &[m, 60, 120], DType::U8);
            let p_m = cmsd(&[60, 120], m, DType::U8, DType::F32);
            let exact = xp.measure(|| xp.fused().run(&p_m, &input_m).unwrap());

            let mut padded_input = input_m.to_f64_vec();
            padded_input.extend(vec![0.0; (bucket - m) * 60 * 120]);
            let padded_t = Tensor::from_f64_cast(&padded_input, &[bucket, 60, 120], DType::U8);
            let p_b = cmsd(&[60, 120], bucket, DType::U8, DType::F32);
            let padded = xp.measure(|| xp.fused().run(&p_b, &padded_t).unwrap());

            let e = exact.mean_s / m as f64;
            let pd = padded.mean_s / m as f64;
            t.row(vec![
                m.to_string(),
                bucket.to_string(),
                ms(e),
                ms(pd),
                format!("{:+.1}%", (pd - e) / e * 100.0),
            ]);
        }
        t.note("padding wastes bucket-m planes; the coordinator pads only the final launch of a group");
        tables.push(t);
    }

    // also verify plan correctness claims used above
    {
        let p = cmsd(&[60, 120], 50, DType::U8, DType::F32);
        let plan = xp.fused().plan_for(&p)?;
        assert!(matches!(plan, FusionPlan::Exact { .. }), "CMSD b50 should hit tier 1");
    }
    Ok(tables)
}
