//! Exp. 6 (Fig. 20) — CPU-side execution time.
//!
//! Paper: the host work of computing kernel parameters + issuing launches for
//! the preprocessing chain, batch 2..152: the fused API does one parameter
//! pack + one launch; OpenCV/NPP redo parameter work per call per crop.
//! We time ONLY the host side: parameter tensor construction + plan lookup
//! (fused) vs per-crop per-step parameter marshaling (baseline).

use anyhow::Result;

use crate::bench::Table;
use crate::npp::{PreprocPipeline, ResizeBatchSpec};
use crate::tensor::{Rect, Tensor};

use super::common::{fx, XpCtx};

pub fn run(xp: &XpCtx) -> Result<Vec<Table>> {
    let batches: Vec<usize> = {
        let all = xp.geom_usizes("preproc_batches", &[2, 8, 50, 152]);
        if xp.fast {
            all.into_iter().filter(|b| [2usize, 50, 152].contains(b)).collect()
        } else {
            all
        }
    };

    let mut t = Table::new(
        "Fig. 20 — CPU-side time: parameter computation + launch issue (preproc chain)",
        &["batch", "fused_cpu_us", "percall_cpu_us", "speedup"],
    );
    t.note("host-side work only (no kernel execution): fused packs params once; the baseline re-derives them per crop per step");

    for &b in &batches {
        let rects: Vec<Rect> =
            (0..b).map(|i| Rect::new((i as i32 * 13) % 1100, (i as i32 * 7) % 640, 120, 60)).collect();

        // fused host work: one rect tensor + 3 constants + plan construction
        let fused = xp.measure(|| {
            let mut p = PreprocPipeline::new(
                ResizeBatchSpec { rects: rects.clone(), dst_h: 128, dst_w: 64 },
                [0.9, 1.0, 1.1],
                [0.5; 3],
                [2.0; 3],
            );
            p.precompute();
            p
        });

        // baseline host work: per crop, per step, rebuild the param tensors
        // (what nppiMulC_32f_C3R_Ctx & friends force every iteration)
        let percall = xp.measure(|| {
            for r in &rects {
                let _rect = Tensor::from_i32(&[r.x0, r.y0, r.w, r.h], &[4]);
                for _step in 0..7 {
                    let _c = Tensor::from_f32(&[0.9, 1.0, 1.1], &[3]);
                    std::hint::black_box(&_c);
                }
                std::hint::black_box(&_rect);
            }
        });

        t.row(vec![
            b.to_string(),
            format!("{:.2}", fused.mean_us()),
            format!("{:.2}", percall.mean_us()),
            fx(percall.mean_s / fused.mean_s),
        ]);
    }
    Ok(vec![t])
}
