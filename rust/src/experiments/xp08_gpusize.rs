//! Exp. 8 (Fig. 22) — GPU size: max VF x HF speedup vs FLOP/B.
//!
//! Paper: the Exp. 4 workload on the five Table II systems; max speedup
//! correlates with FLOP per byte (up to 20.9kx on System 5). We have no
//! GPUs: the five systems run on the analytical simulator (DESIGN.md §3.4),
//! and the host CPU contributes a measured datum for shape validation.

use anyhow::Result;

use crate::bench::Table;
use crate::simulator::{table_ii_systems, GpuModel, KernelShape};

use super::common::{fx, XpCtx};

pub fn run(xp: &XpCtx) -> Result<Vec<Table>> {
    // Exp. 4 workload: 60x120 u8 (read u8 + write u8 at full fusion), batch
    // 50, up to 10,000 Mul+Add pairs (FMA-paired: 1 issued instr per pair)
    let k = KernelShape {
        elems: 60.0 * 120.0,
        bytes_per_elem: 2.0,
        instrs_per_elem: 1.0,
        occupancy: 1.0,
    };
    // occupancy of ONE 60x120 image relative to each GPU: 7200 threads vs
    // cores; small kernels can't fill big GPUs (the HF motivation)
    let mut t = Table::new(
        "Fig. 22 — max VF x HF speedup vs FLOP/B (Table II systems, simulated)",
        &["system", "FLOP/B", "max_speedup (sim)", "at_pairs"],
    );
    t.note("simulated substrate: analytical latency-hiding roofline with launch overhead and spill (see simulator/)");

    let pairs_sweep: &[usize] = &[10, 100, 1000, 2000, 4000, 8000, 10000];
    for spec in table_ii_systems() {
        let m = GpuModel::new(spec);
        let small_occ = (7200.0 / spec.compute_cores as f64).min(1.0) * 0.5;
        let (mut best, mut best_at) = (0.0f64, 0usize);
        for &pairs in pairs_sweep {
            let su = m.vfhf_speedup(&k, small_occ, 50, pairs);
            if su > best {
                best = su;
                best_at = pairs;
            }
        }
        t.row(vec![
            spec.name.to_string(),
            format!("{:.2}", spec.flop_per_byte()),
            fx(best),
            best_at.to_string(),
        ]);
    }

    // measured CPU datum: fused-vs-unfused from xp04 at a modest pair count
    if !xp.fast {
        t.note("CPU-PJRT measured shape validation lives in xp04's table (same workload)");
    }
    Ok(vec![t])
}
