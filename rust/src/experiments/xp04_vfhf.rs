//! Exp. 4 (Fig. 18) — combined VF x HF sweep.
//!
//! Paper: 1..10,000 Mul+Add pairs, batch 50 of 60x120 u8: single fused
//! kernel vs one kernel per op per batch element; max speedup 20,931x, and
//! 2,527x vs OpenCV-CUDA+Graphs. Speedup curve resembles a logarithm.

use anyhow::{Context, Result};

use crate::bench::Table;
use crate::exec::Engine;
use crate::proplite::Rng;
use crate::tensor::{DType, Tensor};

use super::common::{fx, ms, muladd_pairs, rand_tensor, XpCtx};

pub fn run(xp: &XpCtx) -> Result<Vec<Table>> {
    let reg = xp.registry();
    let loop_meta = reg
        .find(|m| {
            m.kind == "staticloop"
                && m.variant == "pallas"
                && m.dtin == "u8"
                && m.shape == [60, 120]
                && m.batch == 50
        })
        .into_iter()
        .next()
        .context("missing staticloop u8 60x120 b50 artifact")?
        .clone();

    let mut rng = Rng::new(3);
    let x = rand_tensor(&mut rng, &[50, 60, 120], DType::U8);
    let params = Tensor::from_f32(&[0.999, 0.001], &[2]);
    let exec = xp.executor();

    let pairs: Vec<usize> =
        if xp.fast { vec![1, 50, 500] } else { vec![1, 10, 50, 200, 1000, 5000, 10000] };
    // the unfused arm costs 100 launches per pair; cap the honestly-measured
    // range and extrapolate the strictly-linear remainder (flagged in-table)
    let unfused_cap = if xp.fast { 50 } else { 200 };

    let mut t = Table::new(
        "Fig. 18 — VF x HF sweep, Mul+Add pairs, batch 50 of 60x120 u8",
        &["pairs", "fused_ms", "unfused_ms", "graph_ms", "speedup", "speedup_vs_graph", "unfused_mode"],
    );
    t.note("unfused arm is measured up to the cap, then linearly extrapolated from the per-launch cost (flagged 'extrap')");

    let mut per_launch: Option<f64> = None;
    for &n in &pairs {
        let trip = Tensor::from_i32(&[n as i32], &[1]);
        let fused = xp.measure(|| {
            exec.run(&loop_meta.name, &[&trip, &x, &params]).unwrap()
        });

        let (unfused_s, graph_s, mode) = if n <= unfused_cap {
            let p = muladd_pairs(n, &[60, 120], 50, DType::U8, DType::U8);
            let u = xp.measure(|| xp.unfused().run(&p, &x).unwrap());
            let g = xp.measure(|| xp.graph().run(&p, &x).unwrap());
            let launches = (2 * n * 50) as f64;
            per_launch = Some(u.mean_s / launches);
            (u.mean_s, g.mean_s, "measured")
        } else {
            let pl = per_launch.expect("cap ordering");
            let launches = (2 * n * 50) as f64;
            (pl * launches, pl * launches * 0.9, "extrap")
        };

        t.row(vec![
            n.to_string(),
            ms(fused.mean_s),
            ms(unfused_s),
            ms(graph_s),
            fx(unfused_s / fused.mean_s),
            fx(graph_s / fused.mean_s),
            mode.to_string(),
        ]);
    }
    Ok(vec![t])
}
