//! SIMD lane-blocking ablation — register-blocked fused loops vs the scalar
//! arm, artifact-free.
//!
//! Both arms are the SAME single-pass host fused engine; the ablation is the
//! register-block width alone. `HostFusedEngine::with_lane_width(1)` forces
//! the pre-SIMD scalar loops, the production engine runs each plan at its
//! compiled [`HostPlan::vectorization`](crate::fusion::HostPlan) width (16
//! f32 lanes on the fast arm, 8 f64 lanes elsewhere, 8 striped
//! sub-accumulators on the reduce tier). One row per inner-loop shape —
//! dense f32, dense u8/f64, lane-group C3, full-axis reduce — so the table
//! shows where the autovectorizer actually pays.
//!
//! Like `hostvf`/`reduce` this needs NO artifacts: `xp simd` runs on any
//! machine and anchors the speedup the `simd_bench` acceptance criterion
//! enforces.

use std::time::Duration;

use anyhow::Result;

use crate::bench::{time_fn, Table};
use crate::chain::{build_erased_opcodes, Chain, CvtColor, Mul, MulC3, F32, F64};
use crate::exec::{Engine, HostFusedEngine};
use crate::fusion::HostPlan;
use crate::ops::{kernel, Opcode, Pipeline, ReduceKind};
use crate::proplite::Rng;
use crate::tensor::{DType, Tensor};

use super::common::{fx, ms, XpCtx};

pub fn run(xp: &XpCtx) -> Result<Vec<Table>> {
    run_with(xp.reps, xp.budget, xp.fast)
}

/// Artifact-free entry point (`xp simd` works without `make artifacts`).
pub fn run_with(reps: usize, budget: Duration, fast: bool) -> Result<Vec<Table>> {
    let scalar = HostFusedEngine::with_threads(1).with_lane_width(1);
    let vector = HostFusedEngine::with_threads(1);
    let (h, w) = if fast { (360usize, 640usize) } else { (1080usize, 1920usize) };
    let mut rng = Rng::new(11);

    let mut t = Table::new(
        &format!(
            "SIMD lane-blocking ablation — register-blocked vs scalar fused loops \
             ({h}x{w}, 1 thread, simd: {})",
            kernel::simd_capability()
        ),
        &["case", "lane_width", "scalar_ms", "vector_ms", "speedup"],
    );
    t.note(
        "both arms run the SAME fused single pass; only the register-block width differs \
         (with_lane_width(1) forces the scalar loops). f64 arms are bit-equal across widths; \
         the f32 fast arm is epsilon-equal",
    );

    let mix = [
        (Opcode::Mul, 0.999),
        (Opcode::Add, 0.001),
        (Opcode::Sub, 0.0005),
        (Opcode::Max, -1000.0),
        (Opcode::Mul, 1.001),
    ];
    let f32_frame = Tensor::from_f32(&rng.vec_f32(h * w, -2.0, 2.0), &[1, h, w]);
    let u8_frame = Tensor::from_u8(&rng.vec_u8(h * w), &[1, h, w]);
    let px_frame = Tensor::from_f32(&rng.vec_f32(h * w * 3, -2.0, 2.0), &[1, h, w, 3]);

    let dense_f32 = build_erased_opcodes(&mix, &[h, w], 1, DType::F32, DType::F32);
    let dense_u8 = build_erased_opcodes(&mix, &[h, w], 1, DType::U8, DType::U8);
    let group_c3 = Chain::read::<F32>(&[h, w, 3])
        .map(CvtColor)
        .map(MulC3([0.9, 1.05, 1.1]))
        .map(Mul(0.5))
        .cast::<F64>()
        .write()
        .into_pipeline();
    let reduce = Chain::read::<F32>(&[h, w])
        .map(Mul(0.5))
        .reduce_pair(ReduceKind::Mean, ReduceKind::SumSq)
        .into_pipeline();

    let cases: [(&str, &Pipeline, &Tensor); 4] = [
        ("dense f32 chain5", &dense_f32, &f32_frame),
        ("dense u8 chain5 (f64 arm)", &dense_u8, &u8_frame),
        ("lane-group C3 body", &group_c3, &px_frame),
        ("full-axis mean+sumsq", &reduce, &f32_frame),
    ];
    for (name, p, x) in cases {
        let width = HostPlan::compile(p).vectorization();

        // correctness anchor: the width must be invisible in the results
        let s = scalar.run(p, x)?;
        let v = vector.run(p, x)?;
        let narrow = p.dtout == DType::F32;
        for (a, b) in s.to_f64_vec().iter().zip(v.to_f64_vec()) {
            if narrow {
                anyhow::ensure!(
                    (a - b).abs() <= 1e-4 + 1e-4 * b.abs(),
                    "{name}: scalar vs vector diverged ({a} vs {b})"
                );
            } else {
                anyhow::ensure!(
                    a.to_bits() == b.to_bits(),
                    "{name}: f64 arm must be bit-equal across widths ({a} vs {b})"
                );
            }
        }

        let sm = time_fn(reps, budget, || scalar.run(p, x).unwrap());
        let vm = time_fn(reps, budget, || vector.run(p, x).unwrap());
        t.row(vec![
            name.to_string(),
            width.to_string(),
            ms(sm.mean_s),
            ms(vm.mean_s),
            fx(sm.mean_s / vm.mean_s),
        ]);
    }
    Ok(vec![t])
}
