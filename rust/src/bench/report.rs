//! Result tables: CSV + markdown emission for every experiment.

use std::io::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

/// A simple results table (one per paper figure/table).
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    /// Free-form notes (scale factors, simulated-vs-measured labels).
    pub notes: Vec<String>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch in {}", self.title);
        self.rows.push(cells);
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    pub fn to_markdown(&self) -> String {
        let mut out = format!("### {}\n\n", self.title);
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!("|{}\n", "---|".repeat(self.headers.len())));
        for r in &self.rows {
            out.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        for n in &self.notes {
            out.push_str(&format!("\n> {n}\n"));
        }
        out.push('\n');
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }
}

/// Write a table as `<dir>/<stem>.csv`.
pub fn write_csv(dir: &Path, stem: &str, t: &Table) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let p = dir.join(format!("{stem}.csv"));
    let mut f = std::fs::File::create(&p).with_context(|| format!("create {}", p.display()))?;
    f.write_all(t.to_csv().as_bytes())?;
    Ok(())
}

/// Append tables to `<dir>/summary.md`.
pub fn write_markdown(dir: &Path, tables: &[&Table]) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let p = dir.join("summary.md");
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(&p)?;
    for t in tables {
        f.write_all(t.to_markdown().as_bytes())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_and_csv_shape() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.note("scaled");
        let md = t.to_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
        assert!(md.contains("> scaled"));
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
