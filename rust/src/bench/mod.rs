//! Measurement harness (criterion is unavailable offline; this is the
//! repo's own timing + stats + reporting kit, matching the paper's method:
//! repeated executions, mean and Relative Standard Deviation).

mod report;
mod timer;

pub use report::{write_csv, write_markdown, Table};
pub use timer::{calibrate, time_fn, time_fn_reps, Stats};
