//! Timing primitives with the paper's statistics (mean, RSD).

use std::time::{Duration, Instant};

use crate::fusion::cost::HwProfile;

/// Summary statistics over repeated timings.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stats {
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    /// Relative standard deviation, percent (the paper reports RSD per
    /// series; <0.01%-25% depending on magnitude, §V).
    pub rsd_pct: f64,
    pub reps: usize,
}

impl Stats {
    pub fn from_samples(samples: &[f64]) -> Stats {
        assert!(!samples.is_empty());
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
        let sd = var.sqrt();
        Stats {
            mean_s: mean,
            min_s: samples.iter().copied().fold(f64::INFINITY, f64::min),
            max_s: samples.iter().copied().fold(0.0, f64::max),
            rsd_pct: if mean > 0.0 { sd / mean * 100.0 } else { 0.0 },
            reps: samples.len(),
        }
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }

    pub fn mean_us(&self) -> f64 {
        self.mean_s * 1e6
    }
}

/// Time `f` with a fixed repetition count (1 warmup + `reps` measured).
pub fn time_fn_reps<T>(reps: usize, mut f: impl FnMut() -> T) -> Stats {
    let _ = f(); // warmup (compile caches, page faults)
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        let out = f();
        samples.push(t0.elapsed().as_secs_f64());
        std::hint::black_box(&out);
    }
    Stats::from_samples(&samples)
}

/// Adaptive timing: run up to `max_reps` but stop once `budget` of wall time
/// is spent (min 3 measured reps). The paper uses 100 reps; sweeps with
/// multi-second baselines use the budget to stay tractable — the rep count
/// is recorded in the stats.
pub fn time_fn<T>(max_reps: usize, budget: Duration, mut f: impl FnMut() -> T) -> Stats {
    let _ = f();
    let start = Instant::now();
    let mut samples = Vec::new();
    while samples.len() < max_reps
        && (samples.len() < 3 || start.elapsed() < budget)
    {
        let t0 = Instant::now();
        let out = f();
        samples.push(t0.elapsed().as_secs_f64());
        std::hint::black_box(&out);
    }
    Stats::from_samples(&samples)
}

/// Measure this host's effective bandwidth / throughput / dispatch overhead
/// to parameterize the cost model (used by predicted-vs-measured reports).
pub fn calibrate() -> HwProfile {
    // memory bandwidth: large memcpy-ish pass
    let n = 32 << 20; // 32M f32 = 128MB
    let src = vec![1.0f32; n];
    let mut dst = vec![0.0f32; n];
    let st = time_fn_reps(3, || {
        dst.copy_from_slice(&src);
        std::hint::black_box(dst[n / 2]);
    });
    let mem_bw = (2.0 * n as f64 * 4.0) / st.mean_s;

    // scalar throughput: fused mul-add loop over a cached slab
    let m = 1 << 16;
    let mut v = vec![1.0f32; m];
    let st = time_fn_reps(3, || {
        for _ in 0..64 {
            for x in v.iter_mut() {
                *x = *x * 0.999 + 0.001;
            }
        }
        std::hint::black_box(v[0]);
    });
    let flops = (64.0 * m as f64 * 2.0) / st.mean_s;

    HwProfile { mem_bw, flops, launch_overhead: 30e-6 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let s = Stats::from_samples(&[1.0, 1.0, 1.0]);
        assert_eq!(s.mean_s, 1.0);
        assert_eq!(s.rsd_pct, 0.0);
        let s = Stats::from_samples(&[1.0, 3.0]);
        assert_eq!(s.mean_s, 2.0);
        assert_eq!(s.min_s, 1.0);
        assert_eq!(s.max_s, 3.0);
        assert!(s.rsd_pct > 0.0);
    }

    #[test]
    fn time_fn_reps_counts() {
        let mut calls = 0;
        let s = time_fn_reps(5, || calls += 1);
        assert_eq!(s.reps, 5);
        assert_eq!(calls, 6, "warmup + reps");
    }

    #[test]
    fn adaptive_budget_stops_early() {
        let s = time_fn(1000, Duration::from_millis(30), || {
            std::thread::sleep(Duration::from_millis(10))
        });
        assert!(s.reps >= 3 && s.reps < 100, "reps={}", s.reps);
    }

    #[test]
    fn calibration_is_sane() {
        let hw = calibrate();
        assert!(hw.mem_bw > 1e9, "bandwidth {} should exceed 1GB/s", hw.mem_bw);
        assert!(hw.flops > 1e8, "flops {}", hw.flops);
    }
}
