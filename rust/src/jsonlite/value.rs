//! JSON value tree and serializer.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value. Objects use a BTreeMap so emission is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

static NULL: Value = Value::Null;

impl Value {
    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Array of numbers -> Vec<usize> (shape fields in the manifest).
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn as_str_vec(&self) -> Option<Vec<String>> {
        self.as_arr()?.iter().map(|v| v.as_str().map(str::to_string)).collect()
    }

    /// Compact JSON emission.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Value {
        Value::Num(n)
    }

    pub fn str(s: &str) -> Value {
        Value::Str(s.to_string())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl std::ops::Index<&str> for Value {
    type Output = Value;
    fn index(&self, key: &str) -> &Value {
        match self {
            Value::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
}

impl std::ops::Index<usize> for Value {
    type Output = Value;
    fn index(&self, i: usize) -> &Value {
        match self {
            Value::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
}
