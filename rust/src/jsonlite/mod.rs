//! Dependency-free JSON parser/serializer.
//!
//! The offline build environment has no `serde`/`serde_json`, so the artifact
//! manifest and experiment result files are handled by this small module. It
//! supports the full JSON grammar needed by `manifest.json` (objects, arrays,
//! strings with escapes, numbers, bools, null) and pretty/compact emission.

mod parse;
mod value;

pub use parse::{parse, ParseError};
pub use value::Value;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1.5", "3e8", "\"hi\""] {
            let v = parse(s).unwrap();
            let v2 = parse(&v.to_json()).unwrap();
            assert_eq!(v, v2, "roundtrip {s}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": -2.25e-3, "e": {}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v["a"][2]["b"].as_str().unwrap(), "x\ny");
        assert_eq!(v["d"].as_f64().unwrap(), -2.25e-3);
        let v2 = parse(&v.to_json()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\"b\\cA\t""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\"b\\cA\t");
        let v2 = parse(&v.to_json()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "{\"a\"}", "tru", "1.2.3", "\"unterminated"] {
            assert!(parse(s).is_err(), "should reject {s:?}");
        }
    }

    #[test]
    fn index_missing_returns_null() {
        let v = parse(r#"{"a": 1}"#).unwrap();
        assert!(v["missing"].is_null());
        assert!(v["a"]["deep"].is_null());
    }
}
