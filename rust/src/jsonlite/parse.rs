//! Recursive-descent JSON parser.

use super::Value;
use std::collections::BTreeMap;

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

struct P<'a> {
    b: &'a [u8],
    i: usize,
}

pub fn parse(src: &str) -> Result<Value, ParseError> {
    let mut p = P { b: src.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

impl<'a> P<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.eat(b'[')?;
        let mut arr = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(arr));
        }
        loop {
            self.ws();
            arr.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>().map(Value::Num).map_err(|_| self.err("bad number"))
    }
}
