//! Host reference interpreter — pure-Rust semantics of every pipeline.
//!
//! This is the numerics oracle for the Rust integration tests (mirroring
//! `kernels/ref.py` on the Python side): fused, unfused, graph AND host-fused
//! engines must all agree with it. It is also the "CPU scalar" / op-at-a-time
//! datum in experiment reports. Compute domain is f64 wide enough to cover
//! both f32 and f64 chains; integer boundaries saturate exactly like the
//! kernels.
//!
//! Op semantics are NOT defined here: every sweep below goes through the
//! shared [`ScalarOp`] table, the same code the single-pass
//! [`HostFusedEngine`](crate::exec::HostFusedEngine) runs per element group —
//! so the oracle and the fused loop cannot drift.

use crate::ops::{Pipeline, ScalarOp};
use crate::tensor::{DType, Rect, Tensor};

fn lowered_body(p: &Pipeline) -> Vec<ScalarOp> {
    ScalarOp::lower_body(p.body()).expect("validated pipeline has no interior memops")
}

/// Execute a validated element-wise pipeline on the host, one whole-buffer
/// sweep per op (the op-at-a-time traffic pattern the fused engine removes).
///
/// Note: f32 chains are evaluated in f64 here; tests compare with an epsilon
/// that covers the double-rounding difference.
pub fn run_pipeline(p: &Pipeline, input: &Tensor) -> Tensor {
    let body = lowered_body(p);
    let mut vals = input.to_f64_vec();
    for op in &body {
        op.apply_slice_f64(&mut vals, 0);
    }
    let mut shape = vec![p.batch];
    shape.extend_from_slice(&p.shape);
    Tensor::from_f64_cast(&vals, &shape, p.dtout)
}

/// StaticLoop semantics: body applied `iters` times (one read, one write).
pub fn run_staticloop(p: &Pipeline, input: &Tensor, iters: usize) -> Tensor {
    let body = lowered_body(p);
    let mut vals = input.to_f64_vec();
    for _ in 0..iters {
        for op in &body {
            if let ScalarOp::Scalar { .. } = op {
                op.apply_slice_f64(&mut vals, 0);
            }
        }
    }
    let mut shape = vec![p.batch];
    shape.extend_from_slice(&p.shape);
    Tensor::from_f64_cast(&vals, &shape, p.dtout)
}

/// UNFUSED semantics: each op is its own kernel, so integer dtypes saturate
/// at EVERY step boundary (exactly like chaining OpenCV-CUDA 8U calls).
pub fn run_unfused(p: &Pipeline, input: &Tensor) -> Tensor {
    let body = lowered_body(p);
    let mut shape = vec![p.batch];
    shape.extend_from_slice(&p.shape);
    // step boundary dtype: dtout for all intermediates (the OpenCV pattern:
    // convertTo destination type first, then arithm in that type)
    let mut cur = input.clone();
    for op in &body {
        let mut vals = cur.to_f64_vec();
        op.apply_slice_f64(&mut vals, 0);
        cur = Tensor::from_f64_cast(&vals, &shape, p.dtout);
    }
    cur
}

/// One-pass reduction oracle: (max, min, sum, mean) in f32 accumulation
/// order-compatible with the ReduceDPP kernel (tile-major).
pub fn reduce_stats(x: &Tensor) -> [f64; 4] {
    let v = x.to_f64_vec();
    let mut mx = f64::NEG_INFINITY;
    let mut mn = f64::INFINITY;
    let mut sum = 0.0;
    for &e in &v {
        mx = mx.max(e);
        mn = mn.min(e);
        sum += e;
    }
    [mx, mn, sum, sum / v.len() as f64]
}

/// Bilinear crop-resize oracle matching `ref.bilinear_gather` (half-pixel
/// centers, edge clamp), on a packed u8 frame, f32 output.
pub fn bilinear_crop_resize(frame: &Tensor, r: Rect, dh: usize, dw: usize) -> Tensor {
    assert_eq!(frame.dtype(), DType::U8);
    let (fh, fw) = (frame.shape()[0] as i32, frame.shape()[1] as i32);
    let src = frame.as_u8().unwrap();
    let sy = r.h as f64 / dh as f64;
    let sx = r.w as f64 / dw as f64;
    let mut out = vec![0f32; dh * dw * 3];
    let at = |y: i32, x: i32, c: usize| -> f64 {
        let yy = (r.y0 + y).clamp(0, fh - 1) as usize;
        let xx = (r.x0 + x).clamp(0, fw - 1) as usize;
        src[(yy * fw as usize + xx) * 3 + c] as f64
    };
    for dy in 0..dh {
        let fy = ((dy as f64 + 0.5) * sy - 0.5).clamp(0.0, r.h as f64 - 1.0);
        let y0 = fy.floor() as i32;
        let y1 = (y0 + 1).min(r.h - 1);
        let wy = fy - y0 as f64;
        for dx in 0..dw {
            let fx = ((dx as f64 + 0.5) * sx - 0.5).clamp(0.0, r.w as f64 - 1.0);
            let x0 = fx.floor() as i32;
            let x1 = (x0 + 1).min(r.w - 1);
            let wx = fx - x0 as f64;
            for c in 0..3 {
                let top = at(y0, x0, c) * (1.0 - wx) + at(y0, x1, c) * wx;
                let bot = at(y1, x0, c) * (1.0 - wx) + at(y1, x1, c) * wx;
                out[(dy * dw + dx) * 3 + c] = (top * (1.0 - wy) + bot * wy) as f32;
            }
        }
    }
    Tensor::from_f32(&out, &[dh, dw, 3])
}

/// Full preprocessing-pipeline oracle (paper Fig. 25): planar f32 output.
pub fn preproc(
    frame: &Tensor,
    rects: &[Rect],
    mulv: [f32; 3],
    subv: [f32; 3],
    divv: [f32; 3],
    dh: usize,
    dw: usize,
) -> Tensor {
    let b = rects.len();
    let mut out = vec![0f32; b * 3 * dh * dw];
    for (bi, &r) in rects.iter().enumerate() {
        let img = bilinear_crop_resize(frame, r, dh, dw);
        let v = img.as_f32().unwrap();
        for y in 0..dh {
            for x in 0..dw {
                for c in 0..3 {
                    // cvtcolor: channel swizzle c -> 2-c
                    let val = v[(y * dw + x) * 3 + (2 - c)];
                    let val = (val * mulv[c] - subv[c]) / divv[c];
                    out[bi * 3 * dh * dw + c * dh * dw + y * dw + x] = val;
                }
            }
        }
    }
    Tensor::from_f32(&out, &[b, 3, dh, dw])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::Opcode;
    use crate::tensor::make_frame;

    #[test]
    fn fused_vs_unfused_f32_agree() {
        let p = Pipeline::from_opcodes(
            &[(Opcode::Mul, 1.5), (Opcode::Add, 2.0), (Opcode::Div, 0.5)],
            &[4, 4],
            1,
            DType::F32,
            DType::F32,
        )
        .unwrap();
        let x = Tensor::from_f32(&(0..16).map(|i| i as f32).collect::<Vec<_>>(), &[1, 4, 4]);
        assert_eq!(run_pipeline(&p, &x), run_unfused(&p, &x));
    }

    #[test]
    fn fused_vs_unfused_u8_saturation_differs() {
        // fused saturates once, unfused at every step: 200*2=400 -> sat 255
        // then -100 -> 155 (unfused) vs 400-100=300 -> sat 255 (fused)
        let p = Pipeline::from_opcodes(
            &[(Opcode::Mul, 2.0), (Opcode::Sub, 100.0)],
            &[1],
            1,
            DType::U8,
            DType::U8,
        )
        .unwrap();
        let x = Tensor::from_u8(&[200], &[1, 1]);
        assert_eq!(run_pipeline(&p, &x).as_u8().unwrap(), &[255]);
        assert_eq!(run_unfused(&p, &x).as_u8().unwrap(), &[155]);
    }

    #[test]
    fn staticloop_repeats_body() {
        let p = Pipeline::from_opcodes(&[(Opcode::Mul, 2.0)], &[1], 1, DType::F32, DType::F32)
            .unwrap();
        let x = Tensor::from_f32(&[1.0], &[1, 1]);
        let y = run_staticloop(&p, &x, 10);
        assert_eq!(y.as_f32().unwrap(), &[1024.0]);
    }

    #[test]
    fn reduce_stats_basic() {
        let x = Tensor::from_f32(&[1.0, -2.0, 3.0, 6.0], &[2, 2]);
        let [mx, mn, sum, mean] = reduce_stats(&x);
        assert_eq!((mx, mn, sum, mean), (6.0, -2.0, 8.0, 2.0));
    }

    #[test]
    fn bilinear_identity_resize() {
        // resizing a crop to its own size must reproduce the crop exactly
        let f = make_frame(32, 32, 3);
        let r = Rect::new(4, 4, 8, 8);
        let out = bilinear_crop_resize(&f, r, 8, 8);
        let crop = crate::tensor::crop_frame(&f, r);
        let want: Vec<f32> = crop.as_u8().unwrap().iter().map(|&b| b as f32).collect();
        assert_eq!(out.as_f32().unwrap(), want.as_slice());
    }

    #[test]
    fn cvtcolor_swizzles_channels() {
        let p = crate::chain::Chain::read::<crate::chain::F32>(&[1, 3])
            .map(crate::chain::CvtColor)
            .write()
            .into_pipeline();
        let x = Tensor::from_f32(&[1.0, 2.0, 3.0], &[1, 1, 3]);
        assert_eq!(run_pipeline(&p, &x).as_f32().unwrap(), &[3.0, 2.0, 1.0]);
    }
}
