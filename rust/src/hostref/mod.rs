//! Host reference interpreter — pure-Rust semantics of every pipeline.
//!
//! This is the numerics oracle for the Rust integration tests (mirroring
//! `kernels/ref.py` on the Python side): fused, unfused, graph AND host-fused
//! engines must all agree with it. It is also the "CPU scalar" / op-at-a-time
//! datum in experiment reports. Compute domain is f64 wide enough to cover
//! both f32 and f64 chains; integer boundaries saturate exactly like the
//! kernels.
//!
//! Op semantics are NOT defined here: every sweep below goes through the
//! shared [`ScalarOp`] table, and the structured READ boundaries (crop /
//! bilinear crop+resize) go through the shared `ops::kernel` gather table —
//! the same code the single-pass
//! [`HostFusedEngine`](crate::exec::HostFusedEngine) runs per element group
//! and per gathered pixel — so the oracle and the fused loop cannot drift.
//! The oracle's distinguishing property is its TRAFFIC pattern, not its
//! semantics: it materializes the read (crop/resize output), sweeps the
//! whole buffer once per op, and permutes at the write — the op-at-a-time
//! pattern the fused engine removes.

use crate::ops::{kernel, Pipeline, ReadPattern, ScalarOp, WritePattern};
use crate::tensor::{DType, Rect, Tensor};

fn lowered_body(p: &Pipeline) -> Vec<ScalarOp> {
    ScalarOp::lower_body(p.body()).expect("validated pipeline has no interior memops")
}

/// Execute a validated pipeline on the host, one whole-buffer sweep per op
/// (the op-at-a-time traffic pattern the fused engine removes). Structured
/// boundaries are honored: a crop/resize read materializes its gather into
/// an f64 buffer first, a split write permutes packed → planar last — the
/// shapes the fused engine must reproduce BITWISE on every f64-accumulated
/// path (which includes all structured passes).
///
/// Note: f32 chains are evaluated in f64 here; tests compare with an epsilon
/// that covers the double-rounding difference.
pub fn run_pipeline(p: &Pipeline, input: &Tensor) -> Tensor {
    let body = lowered_body(p);

    // read: materialize the access pattern into the f64 compute buffer
    let mut vals = match p.read_pattern() {
        ReadPattern::Dense => input.to_f64_vec(),
        ReadPattern::Crop { rect } => gather_crop(input, rect, p.batch),
        ReadPattern::CropResize { rect, dst_h, dst_w } => {
            gather_resize(input, rect, dst_h, dst_w, p.batch)
        }
    };

    // body: one whole-buffer sweep per op
    for op in &body {
        op.apply_slice_f64(&mut vals, 0);
    }

    // reduce terminator: the MATERIALIZING reduction oracle — the whole
    // mapped buffer exists in memory here (the traffic the fused engine's
    // fold-while-reading tier removes), then reduces through the shared
    // blocked-tree table, so engine and oracle agree BITWISE
    if let Some(spec) = p.reduction() {
        let out = kernel::reduce_slice(spec, &vals);
        return Tensor::from_f64(&out, &p.out_shape());
    }

    // write: dense keeps the packed layout; split permutes packed -> planar
    // through the shared layout contract
    if p.write_pattern() == WritePattern::Split {
        let item = p.item_elems();
        let mut planar = vec![0f64; vals.len()];
        for (src, dst) in vals.chunks(item).zip(planar.chunks_mut(item)) {
            kernel::split_packed_to_planar(src, dst);
        }
        vals = planar;
    }
    Tensor::from_f64_cast(&vals, &p.out_shape(), p.dtout)
}

/// Materialize a crop read: one `[h, w, 3]` plane per batch item, gathered
/// through the shared edge-clamp rule.
fn gather_crop(frame: &Tensor, rect: Rect, batch: usize) -> Vec<f64> {
    let (fh, fw) = (frame.shape()[0] as i32, frame.shape()[1] as i32);
    let src = frame.to_f64_vec();
    let (h, w) = (rect.h as usize, rect.w as usize);
    let mut plane = Vec::with_capacity(h * w * 3);
    for y in 0..h {
        for x in 0..w {
            let base = kernel::clamped_frame_index(rect, y as i32, x as i32, fh, fw) * 3;
            plane.extend_from_slice(&src[base..base + 3]);
        }
    }
    repeat_plane(plane, batch)
}

/// Materialize a crop+resize read through the shared bilinear tap table.
fn gather_resize(frame: &Tensor, rect: Rect, dh: usize, dw: usize, batch: usize) -> Vec<f64> {
    let (fh, fw) = (frame.shape()[0] as i32, frame.shape()[1] as i32);
    let src = frame.to_f64_vec();
    let mut plane = Vec::with_capacity(dh * dw * 3);
    for y in 0..dh {
        for x in 0..dw {
            let tap = kernel::bilinear_tap(y, x, rect.h, rect.w, dh, dw);
            for c in 0..3 {
                plane.push(tap.blend(|yy, xx| {
                    src[kernel::clamped_frame_index(rect, yy, xx, fh, fw) * 3 + c]
                }));
            }
        }
    }
    repeat_plane(plane, batch)
}

fn repeat_plane(plane: Vec<f64>, batch: usize) -> Vec<f64> {
    if batch <= 1 {
        return plane;
    }
    let mut vals = Vec::with_capacity(plane.len() * batch);
    for _ in 0..batch {
        vals.extend_from_slice(&plane);
    }
    vals
}

/// StaticLoop semantics: body applied `iters` times (one read, one write).
pub fn run_staticloop(p: &Pipeline, input: &Tensor, iters: usize) -> Tensor {
    let body = lowered_body(p);
    let mut vals = input.to_f64_vec();
    for _ in 0..iters {
        for op in &body {
            if let ScalarOp::Scalar { .. } = op {
                op.apply_slice_f64(&mut vals, 0);
            }
        }
    }
    let mut shape = vec![p.batch];
    shape.extend_from_slice(&p.shape);
    Tensor::from_f64_cast(&vals, &shape, p.dtout)
}

/// UNFUSED semantics: each op is its own kernel, so integer dtypes saturate
/// at EVERY step boundary (exactly like chaining OpenCV-CUDA 8U calls).
pub fn run_unfused(p: &Pipeline, input: &Tensor) -> Tensor {
    let body = lowered_body(p);
    let mut shape = vec![p.batch];
    shape.extend_from_slice(&p.shape);
    // step boundary dtype: dtout for all intermediates (the OpenCV pattern:
    // convertTo destination type first, then arithm in that type)
    let mut cur = input.clone();
    for op in &body {
        let mut vals = cur.to_f64_vec();
        op.apply_slice_f64(&mut vals, 0);
        cur = Tensor::from_f64_cast(&vals, &shape, p.dtout);
    }
    cur
}

/// One-pass reduction oracle: (max, min, sum, mean) in f32 accumulation
/// order-compatible with the ReduceDPP kernel (tile-major).
pub fn reduce_stats(x: &Tensor) -> [f64; 4] {
    let v = x.to_f64_vec();
    let mut mx = f64::NEG_INFINITY;
    let mut mn = f64::INFINITY;
    let mut sum = 0.0;
    for &e in &v {
        mx = mx.max(e);
        mn = mn.min(e);
        sum += e;
    }
    [mx, mn, sum, sum / v.len() as f64]
}

/// Bilinear crop-resize oracle matching `ref.bilinear_gather` (half-pixel
/// centers, edge clamp), on a packed u8 frame, f32 output. Taps, weights
/// and clamp are the shared `ops::kernel` gather table — the very code the
/// fused engine's CropResize reader runs — so the two cannot drift.
pub fn bilinear_crop_resize(frame: &Tensor, r: Rect, dh: usize, dw: usize) -> Tensor {
    assert_eq!(frame.dtype(), DType::U8);
    let (fh, fw) = (frame.shape()[0] as i32, frame.shape()[1] as i32);
    let src = frame.as_u8().unwrap();
    let mut out = vec![0f32; dh * dw * 3];
    for dy in 0..dh {
        for dx in 0..dw {
            let tap = kernel::bilinear_tap(dy, dx, r.h, r.w, dh, dw);
            for c in 0..3 {
                out[(dy * dw + dx) * 3 + c] = tap.blend(|yy, xx| {
                    src[kernel::clamped_frame_index(r, yy, xx, fh, fw) * 3 + c] as f64
                }) as f32;
            }
        }
    }
    Tensor::from_f32(&out, &[dh, dw, 3])
}

/// Op-at-a-time bilinear resize of a packed `[h, w, 3]` f32 image to
/// `[dh, dw, 3]` — the standalone "resize step" of the NPP-style baseline
/// (the fused engine never materializes this buffer). Same shared taps.
pub fn bilinear_resize_packed(img: &Tensor, dh: usize, dw: usize) -> Tensor {
    assert_eq!(img.dtype(), DType::F32);
    let (h, w) = (img.shape()[0], img.shape()[1]);
    let src = img.as_f32().unwrap();
    let whole = Rect::new(0, 0, w as i32, h as i32);
    let mut out = vec![0f32; dh * dw * 3];
    for dy in 0..dh {
        for dx in 0..dw {
            let tap = kernel::bilinear_tap(dy, dx, h as i32, w as i32, dh, dw);
            for c in 0..3 {
                out[(dy * dw + dx) * 3 + c] = tap.blend(|yy, xx| {
                    src[kernel::clamped_frame_index(whole, yy, xx, h as i32, w as i32) * 3 + c]
                        as f64
                }) as f32;
            }
        }
    }
    Tensor::from_f32(&out, &[dh, dw, 3])
}

/// Full preprocessing-pipeline oracle (paper Fig. 25): planar f32 output.
pub fn preproc(
    frame: &Tensor,
    rects: &[Rect],
    mulv: [f32; 3],
    subv: [f32; 3],
    divv: [f32; 3],
    dh: usize,
    dw: usize,
) -> Tensor {
    let b = rects.len();
    let mut out = vec![0f32; b * 3 * dh * dw];
    for (bi, &r) in rects.iter().enumerate() {
        let img = bilinear_crop_resize(frame, r, dh, dw);
        let v = img.as_f32().unwrap();
        for y in 0..dh {
            for x in 0..dw {
                for c in 0..3 {
                    // cvtcolor: channel swizzle c -> 2-c
                    let val = v[(y * dw + x) * 3 + (2 - c)];
                    let val = (val * mulv[c] - subv[c]) / divv[c];
                    out[bi * 3 * dh * dw + c * dh * dw + y * dw + x] = val;
                }
            }
        }
    }
    Tensor::from_f32(&out, &[b, 3, dh, dw])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::Opcode;
    use crate::tensor::make_frame;

    #[test]
    fn fused_vs_unfused_f32_agree() {
        let p = Pipeline::from_opcodes(
            &[(Opcode::Mul, 1.5), (Opcode::Add, 2.0), (Opcode::Div, 0.5)],
            &[4, 4],
            1,
            DType::F32,
            DType::F32,
        )
        .unwrap();
        let x = Tensor::from_f32(&(0..16).map(|i| i as f32).collect::<Vec<_>>(), &[1, 4, 4]);
        assert_eq!(run_pipeline(&p, &x), run_unfused(&p, &x));
    }

    #[test]
    fn fused_vs_unfused_u8_saturation_differs() {
        // fused saturates once, unfused at every step: 200*2=400 -> sat 255
        // then -100 -> 155 (unfused) vs 400-100=300 -> sat 255 (fused)
        let p = Pipeline::from_opcodes(
            &[(Opcode::Mul, 2.0), (Opcode::Sub, 100.0)],
            &[1],
            1,
            DType::U8,
            DType::U8,
        )
        .unwrap();
        let x = Tensor::from_u8(&[200], &[1, 1]);
        assert_eq!(run_pipeline(&p, &x).as_u8().unwrap(), &[255]);
        assert_eq!(run_unfused(&p, &x).as_u8().unwrap(), &[155]);
    }

    #[test]
    fn staticloop_repeats_body() {
        let p = Pipeline::from_opcodes(&[(Opcode::Mul, 2.0)], &[1], 1, DType::F32, DType::F32)
            .unwrap();
        let x = Tensor::from_f32(&[1.0], &[1, 1]);
        let y = run_staticloop(&p, &x, 10);
        assert_eq!(y.as_f32().unwrap(), &[1024.0]);
    }

    #[test]
    fn reduce_stats_basic() {
        let x = Tensor::from_f32(&[1.0, -2.0, 3.0, 6.0], &[2, 2]);
        let [mx, mn, sum, mean] = reduce_stats(&x);
        assert_eq!((mx, mn, sum, mean), (6.0, -2.0, 8.0, 2.0));
    }

    #[test]
    fn bilinear_identity_resize() {
        // resizing a crop to its own size must reproduce the crop exactly
        let f = make_frame(32, 32, 3);
        let r = Rect::new(4, 4, 8, 8);
        let out = bilinear_crop_resize(&f, r, 8, 8);
        let crop = crate::tensor::crop_frame(&f, r);
        let want: Vec<f32> = crop.as_u8().unwrap().iter().map(|&b| b as f32).collect();
        assert_eq!(out.as_f32().unwrap(), want.as_slice());
    }

    #[test]
    fn structured_oracle_crop_read_equals_crop_frame() {
        let f = make_frame(20, 24, 4);
        let r = Rect::new(2, 3, 9, 6);
        let p = crate::chain::Chain::read_crop::<crate::chain::U8>(r).write().into_pipeline();
        let got = run_pipeline(&p, &f);
        assert_eq!(got.shape(), &[1, 6, 9, 3]);
        assert_eq!(got.as_u8().unwrap(), crate::tensor::crop_frame(&f, r).as_u8().unwrap());
    }

    #[test]
    fn structured_oracle_split_write_permutes_packed_to_planar() {
        let p = crate::chain::Chain::read::<crate::chain::F32>(&[2, 2, 3])
            .map(crate::chain::Mul(1.0))
            .write_split()
            .into_pipeline();
        #[rustfmt::skip]
        let x = Tensor::from_f32(
            &[
                1.0, 10.0, 100.0,  2.0, 20.0, 200.0,
                3.0, 30.0, 300.0,  4.0, 40.0, 400.0,
            ],
            &[1, 2, 2, 3],
        );
        let got = run_pipeline(&p, &x);
        assert_eq!(got.shape(), &[1, 3, 2, 2]);
        assert_eq!(
            got.as_f32().unwrap(),
            &[1.0, 2.0, 3.0, 4.0, 10.0, 20.0, 30.0, 40.0, 100.0, 200.0, 300.0, 400.0]
        );
    }

    #[test]
    fn structured_oracle_agrees_with_the_fig25_preproc_datum() {
        // the full flagship chain as a structured pipeline vs the
        // independent Fig. 25 oracle (f32 step math): epsilon agreement ties
        // the two references together
        let f = make_frame(36, 48, 6);
        let r = Rect::new(4, 5, 22, 14);
        let (dh, dw) = (16, 10);
        let (mulv, subv, divv) = ([0.9f32, 1.0, 1.1], [0.5f32, 0.4, 0.3], [2.0f32, 2.1, 2.2]);
        let p = crate::chain::Chain::read_resize::<crate::chain::U8>(r, dh, dw)
            .map(crate::chain::CvtColor)
            .map(crate::chain::MulC3(mulv))
            .map(crate::chain::SubC3(subv))
            .map(crate::chain::DivC3(divv))
            .cast::<crate::chain::F32>()
            .write_split()
            .into_pipeline();
        let got = run_pipeline(&p, &f);
        let want = preproc(&f, &[r], mulv, subv, divv, dh, dw);
        assert_eq!(got.shape(), want.shape());
        for (i, (a, b)) in got.to_f64_vec().iter().zip(want.to_f64_vec()).enumerate() {
            assert!((a - b).abs() <= 1e-4 + 1e-4 * b.abs(), "elem {i}: {a} vs {b}");
        }
    }

    #[test]
    fn reduce_oracle_materializes_then_blocks() {
        use crate::ops::ReduceKind;
        let p = crate::chain::Chain::read::<crate::chain::U8>(&[2, 3])
            .map(crate::chain::Mul(2.0))
            .reduce_per_channel(ReduceKind::Sum)
            .into_pipeline();
        let x = Tensor::from_u8(&[1, 2, 3, 4, 5, 6], &[1, 2, 3]);
        let got = run_pipeline(&p, &x);
        assert_eq!(got.shape(), &[3]);
        assert_eq!(got.as_f64().unwrap(), &[10.0, 14.0, 18.0]);
    }

    #[test]
    fn cvtcolor_swizzles_channels() {
        let p = crate::chain::Chain::read::<crate::chain::F32>(&[1, 3])
            .map(crate::chain::CvtColor)
            .write()
            .into_pipeline();
        let x = Tensor::from_f32(&[1.0, 2.0, 3.0], &[1, 1, 3]);
        assert_eq!(run_pipeline(&p, &x).as_f32().unwrap(), &[3.0, 2.0, 1.0]);
    }
}
