//! Chrome trace-event export: render the span ring as JSON that
//! `chrome://tracing` and Perfetto open directly.
//!
//! Every span becomes one complete event (`"ph": "X"`) with the required
//! `ts`/`dur`/`pid`/`tid` keys; the request id is the `tid`, so each
//! request renders as its own track and the span tree nests visually by
//! time containment. Stage-specific args (`tier`, cache hit/miss, lane
//! width, ...) land under `args` with readable names.

use crate::jsonlite::Value;

use super::{tier_name, SpanRecord, Stage, NO_PARENT};

/// Render `spans` as a Chrome trace-event JSON object
/// (`{"traceEvents": [...]}`), via the in-crate [`crate::jsonlite`].
pub fn chrome_trace(spans: &[SpanRecord]) -> Value {
    let events: Vec<Value> = spans.iter().map(event).collect();
    Value::obj(vec![
        ("traceEvents", Value::Arr(events)),
        ("displayTimeUnit", Value::str("ms")),
    ])
}

fn event(s: &SpanRecord) -> Value {
    let mut args = vec![("span", Value::num(s.id as f64))];
    if s.parent != NO_PARENT {
        args.push(("parent", Value::num(s.parent as f64)));
    }
    match s.stage {
        Stage::Admit => {
            args.push(("lints", Value::num(s.a as f64)));
            args.push(("rewrites", Value::num(s.b as f64)));
        }
        Stage::Tier => {
            args.push(("tier", Value::str(tier_name(s.a))));
            args.push(("verdict", Value::str(tier_name(s.b))));
            args.push(("group", Value::num(s.c as f64)));
        }
        Stage::Plan => {
            args.push(("cache", Value::str(if s.a == 1 { "hit" } else { "miss" })));
            args.push(("plan_us", Value::num(s.b as f64)));
        }
        Stage::Launch => {
            args.push(("elements", Value::num(s.a as f64)));
            args.push(("lane_width", Value::num(s.b as f64)));
            args.push(("threads", Value::num(s.c as f64)));
        }
        Stage::Reply => args.push(("ok", Value::Bool(s.a == 1))),
        // request roots carry which coordinator shard served them
        Stage::Request => args.push(("shard", Value::num(s.a as f64))),
        Stage::Queue => {}
    }
    if let Some(e) = s.err {
        args.push(("err", Value::str(e)));
    }
    Value::obj(vec![
        ("name", Value::str(s.stage.name())),
        ("cat", Value::str("fkl")),
        ("ph", Value::str("X")),
        ("ts", Value::num(s.start_us as f64)),
        ("dur", Value::num(s.dur_us as f64)),
        ("pid", Value::num(1.0)),
        ("tid", Value::num(s.req as f64)),
        ("args", Value::obj(args)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_event_carries_the_required_keys() {
        let spans = vec![
            SpanRecord {
                req: 7,
                id: 0,
                parent: NO_PARENT,
                stage: Stage::Request,
                start_us: 10,
                dur_us: 90,
                a: 0,
                b: 0,
                c: 0,
                err: None,
            },
            SpanRecord {
                req: 7,
                id: 5,
                parent: 3,
                stage: Stage::Launch,
                start_us: 40,
                dur_us: 30,
                a: 4096,
                b: 16,
                c: 8,
                err: Some("LaunchPanicked"),
            },
        ];
        let v = chrome_trace(&spans);
        let events = v["traceEvents"].as_arr().expect("traceEvents array");
        assert_eq!(events.len(), 2);
        for e in events {
            for key in ["ph", "ts", "dur", "pid", "tid", "name"] {
                assert!(!e[key].is_null(), "missing {key}: {}", e.to_json());
            }
            assert_eq!(e["ph"].as_str(), Some("X"), "complete events");
        }
        assert_eq!(events[0]["args"]["shard"].as_f64(), Some(0.0), "request root names its shard");
        assert_eq!(events[1]["args"]["err"].as_str(), Some("LaunchPanicked"));
        assert_eq!(events[1]["args"]["lane_width"].as_f64(), Some(16.0));
        // the export round-trips through the in-crate parser
        let parsed = crate::jsonlite::parse(&v.to_json()).expect("round-trip");
        assert_eq!(parsed["traceEvents"].as_arr().unwrap().len(), 2);
    }
}
