//! Request tracing: a fixed-capacity, overwrite-oldest span recorder.
//!
//! The paper's performance argument is about *where time and bytes go* —
//! fused kernels win by collapsing N memory passes into one — so the serving
//! stack must be able to say, for any single request, how long it spent
//! queued vs planning vs launching. This module is that instrument:
//!
//! * **One causally-linked span tree per request.** The coordinator records
//!   a `request` root span plus `admit` (shed/lint/canonicalize), `queue`,
//!   `tier` (stacked/divergent/per-item + breaker verdict) and `reply`
//!   children, with `plan` (cache hit/miss, compile time) and `launch`
//!   (threads, lane width, elements) nested under `tier`.
//! * **Zero allocation on the hot path.** [`Tracer::record`] copies one
//!   fixed-size [`SpanRecord`] into a preallocated ring; when the ring is
//!   full the oldest span is overwritten (a flight recorder, not a log).
//! * **No-op when disabled.** The tracer is armed explicitly via
//!   `ServiceConfig::tracing` / `HostFusedEngine::with_tracer`; when absent,
//!   the serving hot path carries no tracing code at all (an `Option` that
//!   is `None` — the same pattern as the fault injector).
//! * **Perfetto-openable export.** [`chrome_trace`] renders the ring as
//!   Chrome trace-event JSON (`ph`/`ts`/`dur`/`pid`/`tid`) via the in-crate
//!   [`crate::jsonlite`], so `fkl serve --trace-out trace.json` produces a
//!   capture that opens directly in `ui.perfetto.dev`.

mod chrome;

pub use chrome::chrome_trace;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Sentinel parent id of a root span.
pub const NO_PARENT: u16 = u16::MAX;

/// Default ring capacity: spans are small fixed records, so a generous
/// default keeps whole serving sessions without growing.
pub const DEFAULT_CAPACITY: usize = 1 << 16;

/// The span taxonomy — one stage of a request's life.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Root span: the whole queue-to-reply life of one request.
    Request,
    /// Ingress admission: shed check, lint, canonicalize.
    Admit,
    /// Waiting in the batcher for company or the window to close.
    Queue,
    /// The scheduling-ladder serve (stacked / divergent / per-item).
    Tier,
    /// Plan-cache consult: hit or compile.
    Plan,
    /// The fused launch itself.
    Launch,
    /// Sending the reply back to the client.
    Reply,
}

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::Request => "request",
            Stage::Admit => "admit",
            Stage::Queue => "queue",
            Stage::Tier => "tier",
            Stage::Plan => "plan",
            Stage::Launch => "launch",
            Stage::Reply => "reply",
        }
    }
}

/// Serving-tier code carried in [`SpanRecord::a`] of a `tier` span (and the
/// breaker-verdict code in [`SpanRecord::b`]).
pub const TIER_STACKED: u64 = 0;
pub const TIER_DIVERGENT: u64 = 1;
pub const TIER_PER_ITEM: u64 = 2;
/// Breaker verdict only: a half-open probe admission.
pub const TIER_PROBE: u64 = 3;
/// Breaker verdict only: an Open breaker rejected the group.
pub const TIER_REJECT: u64 = 4;

/// Human name of a tier / breaker-verdict code.
pub fn tier_name(code: u64) -> &'static str {
    match code {
        TIER_STACKED => "stacked",
        TIER_DIVERGENT => "divergent",
        TIER_PER_ITEM => "per-item",
        TIER_PROBE => "probe",
        TIER_REJECT => "reject",
        _ => "?",
    }
}

/// One closed span. Fixed-size and `Copy` — recording is a slot write, no
/// allocation. The `a`/`b`/`c` args are stage-specific:
///
/// | stage    | `a`                  | `b`                   | `c`       |
/// |----------|----------------------|-----------------------|-----------|
/// | `admit`  | lints emitted        | rewrites applied      | —         |
/// | `tier`   | served-tier code     | breaker-verdict code  | group len |
/// | `plan`   | cache hit (1/0)      | plan/compile time, us | —         |
/// | `launch` | elements             | lane width            | threads   |
/// | `reply`  | ok (1/0)             | —                     | —         |
#[derive(Debug, Clone, Copy)]
pub struct SpanRecord {
    /// Request id (tracer-scoped, monotonically assigned; `tid` in the
    /// Chrome export so each request renders as its own track).
    pub req: u64,
    /// Span id, unique within the request.
    pub id: u16,
    /// Parent span id within the request ([`NO_PARENT`] for the root).
    pub parent: u16,
    pub stage: Stage,
    /// Start, microseconds since the tracer's epoch.
    pub start_us: u64,
    pub dur_us: u64,
    pub a: u64,
    pub b: u64,
    pub c: u64,
    /// Error recorded on the failing span (the typed serve-error variant
    /// name — a `&'static str`, so failure traces stay allocation-free).
    pub err: Option<&'static str>,
}

struct Ring {
    buf: Vec<SpanRecord>,
    /// Overwrite position once `buf` has reached capacity.
    cursor: usize,
}

/// The span recorder. Thread-safe (`record` takes a short mutex over the
/// preallocated ring); dropped spans are the oldest, never the newest.
pub struct Tracer {
    epoch: Instant,
    next_req: AtomicU64,
    cap: usize,
    ring: Mutex<Ring>,
}

impl Tracer {
    pub fn new() -> Tracer {
        Self::with_capacity(DEFAULT_CAPACITY)
    }

    /// A tracer whose ring holds `cap` spans (oldest overwritten beyond it).
    pub fn with_capacity(cap: usize) -> Tracer {
        let cap = cap.max(8);
        Tracer {
            epoch: Instant::now(),
            next_req: AtomicU64::new(1),
            cap,
            ring: Mutex::new(Ring { buf: Vec::with_capacity(cap), cursor: 0 }),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Assign the next request id (1-based; 0 means "untraced").
    pub fn new_request(&self) -> u64 {
        self.next_req.fetch_add(1, Ordering::Relaxed)
    }

    /// Microseconds from the tracer's epoch to `t` (saturating).
    pub fn us(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_micros() as u64
    }

    /// Microseconds from the tracer's epoch to now.
    pub fn now_us(&self) -> u64 {
        self.us(Instant::now())
    }

    /// Record one closed span: a slot write into the preallocated ring —
    /// zero allocation, overwrite-oldest beyond capacity.
    pub fn record(&self, rec: SpanRecord) {
        let mut ring = self.ring.lock().expect("tracer ring poisoned");
        if ring.buf.len() < self.cap {
            ring.buf.push(rec);
        } else {
            let at = ring.cursor;
            ring.buf[at] = rec;
            ring.cursor = (at + 1) % self.cap;
        }
    }

    /// Spans currently held, oldest first.
    pub fn spans(&self) -> Vec<SpanRecord> {
        let ring = self.ring.lock().expect("tracer ring poisoned");
        let mut out = Vec::with_capacity(ring.buf.len());
        out.extend_from_slice(&ring.buf[ring.cursor..]);
        out.extend_from_slice(&ring.buf[..ring.cursor]);
        out
    }

    pub fn span_count(&self) -> usize {
        self.ring.lock().expect("tracer ring poisoned").buf.len()
    }

    /// The whole ring as Chrome trace-event JSON (see [`chrome_trace`]).
    pub fn to_chrome_trace(&self) -> crate::jsonlite::Value {
        chrome_trace(&self.spans())
    }
}

impl Default for Tracer {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Tracer")
            .field("capacity", &self.cap)
            .field("spans", &self.span_count())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(req: u64, id: u16, start: u64) -> SpanRecord {
        SpanRecord {
            req,
            id,
            parent: NO_PARENT,
            stage: Stage::Launch,
            start_us: start,
            dur_us: 5,
            a: 0,
            b: 0,
            c: 0,
            err: None,
        }
    }

    #[test]
    fn ring_overwrites_oldest_and_reads_back_in_order() {
        let tr = Tracer::with_capacity(8);
        for i in 0..12u64 {
            tr.record(span(i, 0, i));
        }
        let spans = tr.spans();
        assert_eq!(spans.len(), 8, "capacity bounds the ring");
        let reqs: Vec<u64> = spans.iter().map(|s| s.req).collect();
        assert_eq!(reqs, (4..12).collect::<Vec<_>>(), "oldest dropped, order kept");
    }

    #[test]
    fn request_ids_are_monotone_and_nonzero() {
        let tr = Tracer::new();
        let a = tr.new_request();
        let b = tr.new_request();
        assert!(a >= 1, "0 is the untraced sentinel");
        assert_eq!(b, a + 1);
    }

    #[test]
    fn clock_is_monotone_from_epoch() {
        let tr = Tracer::new();
        let t0 = tr.now_us();
        let t1 = tr.now_us();
        assert!(t1 >= t0);
    }
}
