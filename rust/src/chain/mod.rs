//! The typed fusion-chain builder — ONE compile-time-checked front door for
//! every backend.
//!
//! The paper's core contribution is an API: users compose fusionable
//! components through a high-level interface, and C++17 metaprogramming
//! statically asserts Read-first/Write-last plus per-stage type flow
//! (Fig. 10 `S_ASSERT_INPUT_OUTPUT`) *before* a fused kernel is generated.
//! This module is the Rust analog: a typestate builder in which an illegal
//! chain is a **compile error**, not a `PipelineError` at run time.
//!
//! ```
//! use fkl::chain::{Chain, Mul, Sub, F32, U8};
//!
//! // read u8 -> *0.5 -> -10 -> write f32: checked entirely by the compiler
//! let p = Chain::read::<U8>(&[60, 120])
//!     .batch(4)
//!     .map(Mul(0.5))
//!     .map(Sub(10.0))
//!     .cast::<F32>()
//!     .write();
//! assert_eq!(p.pipeline().dtin, fkl::tensor::DType::U8);
//! assert_eq!(p.pipeline().dtout, fkl::tensor::DType::F32);
//! assert_eq!(p.pipeline().batch, 4);
//! ```
//!
//! # The typestate
//!
//! A chain moves through three marker states, mirroring the paper's template
//! instantiation order:
//!
//! * [`Reading`] — a read end has been configured ([`Chain::read`],
//!   [`Chain::read_crop`], [`Chain::read_resize`]); structured reads are
//!   typed stages here, not special cases.
//! * [`Computing`] — at least one compute stage (or an explicit
//!   [`ChainLink::cast`]) has been appended.
//! * [`Sealed`] — a write end ([`ChainLink::write`] /
//!   [`ChainLink::write_split`]) turned the chain into a
//!   [`TypedPipeline<In, Out>`]. Only sealed chains execute.
//!
//! Alongside the state, two dtype markers flow through the builder:
//! `In` (fixed by the read) and `Cur` (the current element type, changed
//! only by the explicit [`ChainLink::cast`] boundary). The write end seals
//! at `Cur`, so the output dtype of a chain is part of its compile-time
//! type — exactly the paper's per-stage `InputType`/`OutputType` agreement.
//!
//! # Illegal chains do not compile
//!
//! Each of the following mirrors the runtime [`PipelineError`] variant the
//! lowered IR still enforces (see `rust/tests/chain_api.rs` for the runtime
//! pins); the typed front door rejects them at compile time.
//!
//! Missing write ([`PipelineError::MissingWrite`]) — an unsealed chain is
//! not a pipeline:
//!
//! ```compile_fail
//! use fkl::chain::{Chain, Mul, TypedPipeline, F32};
//! let p: TypedPipeline<F32, F32> = Chain::read::<F32>(&[4, 4]).map(Mul(2.0));
//! ```
//!
//! Missing read ([`PipelineError::MissingRead`]) — the read constructors are
//! the only way to begin a chain; `ChainLink` cannot be assembled by hand:
//!
//! ```compile_fail
//! use fkl::chain::{ChainLink, Computing, F32};
//! let c = ChainLink::<Computing, F32, F32> {
//!     ops: vec![],
//!     shape: vec![4],
//!     batch: 1,
//!     _t: std::marker::PhantomData,
//! };
//! ```
//!
//! Interior memory op ([`PipelineError::InteriorMemOp`]) — a read is not a
//! compute stage, so it cannot appear mid-chain:
//!
//! ```compile_fail
//! use fkl::chain::{Chain, Mul, F32};
//! let _ = Chain::read::<F32>(&[4]).map(Mul(2.0)).map(Chain::read::<F32>(&[4]));
//! ```
//!
//! Mismatched dtype boundary — the write seals at the chain's *current*
//! type; a `U8` chain with no cast can never be an `F32` pipeline:
//!
//! ```compile_fail
//! use fkl::chain::{Chain, Mul, TypedPipeline, F32, U8};
//! let p: TypedPipeline<U8, F32> = Chain::read::<U8>(&[4]).map(Mul(2.0)).write();
//! ```
//!
//! Reduce-shaped illegal chains do not compile either. A reduction SEALS
//! the chain (it is the pipeline's terminator), so mapping after an
//! unsealed reduce is a compile error:
//!
//! ```compile_fail
//! use fkl::chain::{Chain, Mul, U8};
//! use fkl::ops::ReduceKind;
//! let p = Chain::read::<U8>(&[4, 4]).map(Mul(2.0)).reduce(ReduceKind::Mean).map(Mul(2.0));
//! ```
//!
//! ... a reduce cannot precede the read (the read constructors are the only
//! way to begin a chain — there is nothing to reduce before one):
//!
//! ```compile_fail
//! use fkl::chain::Chain;
//! use fkl::ops::ReduceKind;
//! let p = Chain::reduce(ReduceKind::Sum);
//! ```
//!
//! ... and a written (sealed) pipeline cannot grow a second terminator:
//!
//! ```compile_fail
//! use fkl::chain::{Chain, F32};
//! use fkl::ops::ReduceKind;
//! let p = Chain::read::<F32>(&[4]).write().reduce(ReduceKind::Sum);
//! ```
//!
//! # Lowering and execution
//!
//! A [`TypedPipeline`] *is* a validated runtime [`Pipeline`] plus its
//! compile-time dtype evidence. The runtime `Pipeline` stays the stable IR
//! for the XLA/unfused/graph engines and the [`Signature`] plan cache
//! (signatures remain parameter-agnostic, so cache reuse is unchanged).
//! On the host backend the evidence pays off directly:
//! [`TypedPipeline::run_host`] dispatches into
//! [`HostFusedEngine::run_mono`], whose `(input lane, output lane)` pair is
//! fixed by the caller's *types* — the monomorphized single-pass loop is
//! selected at compile time with zero runtime dtype dispatch, the Rust
//! analog of the paper's compile-time kernel generation.
//!
//! Callers whose dtypes are data (CLI flags, manifest-driven sweeps) go
//! through [`build_erased`], the 5x5 monomorphization table over the same
//! typed builder — the one sanctioned dynamic entrance, so every pipeline
//! in the system flows through this module.

use std::marker::PhantomData;

use anyhow::{ensure, Context as _, Result};

use crate::exec::{HostFusedEngine, HostLane};
use crate::ops::{
    kernel, CastStep, IOp, MemOp, Opcode, Pipeline, ReduceAxis, ReduceKind, ReduceSpec,
    Signature,
};
#[allow(unused_imports)] // doc links
use crate::ops::PipelineError;
use crate::tensor::{DType, Rect, Tensor, TensorData};

// ---------------------------------------------------------------------------
// dtype markers

mod sealed {
    /// Seals [`super::Elem`]: the dtype vocabulary is exactly the five
    /// manifest dtypes, mirroring the paper's template instantiation set.
    pub trait SealedElem {}
    /// Seals [`super::State`]: Reading/Computing/Sealed only.
    pub trait SealedState {}
}

/// A compile-time element-type marker (the `T` of the paper's `Ptr2D<T>`
/// template parameters). Ties the marker to its runtime [`DType`], its
/// host lane type, and the tensor accessors the monomorphized loops need.
pub trait Elem: sealed::SealedElem + 'static {
    /// The runtime dtype this marker lowers to.
    const DTYPE: DType;
    /// The host lane the fused loop reads/writes for this dtype.
    type Lane: HostLane;
    /// View a tensor's storage as this lane type (None on dtype mismatch).
    fn slice(t: &Tensor) -> Option<&[Self::Lane]>;
    /// Wrap an owned lane buffer as a tensor (no copy).
    fn from_vec(v: Vec<Self::Lane>, shape: &[usize]) -> Tensor;
}

macro_rules! elem {
    ($(#[$m:meta])* $marker:ident, $dt:ident, $lane:ty, $as:ident, $variant:ident) => {
        $(#[$m])*
        #[derive(Debug, Clone, Copy, PartialEq, Eq)]
        pub struct $marker;

        impl sealed::SealedElem for $marker {}

        impl Elem for $marker {
            const DTYPE: DType = DType::$dt;
            type Lane = $lane;

            fn slice(t: &Tensor) -> Option<&[$lane]> {
                t.$as()
            }

            fn from_vec(v: Vec<$lane>, shape: &[usize]) -> Tensor {
                Tensor::from_data(TensorData::$variant(v), shape)
            }
        }
    };
}

elem!(
    /// `u8` element marker (image bytes).
    U8, U8, u8, as_u8, U8
);
elem!(
    /// `u16` element marker.
    U16, U16, u16, as_u16, U16
);
elem!(
    /// `i32` element marker.
    I32, I32, i32, as_i32, I32
);
elem!(
    /// `f32` element marker.
    F32, F32, f32, as_f32, F32
);
elem!(
    /// `f64` element marker.
    F64, F64, f64, as_f64, F64
);

// ---------------------------------------------------------------------------
// typestate markers

/// Typestate of an open (unsealed) chain. Sealed trait: the only states are
/// [`Reading`], [`Computing`] and (via [`TypedPipeline`]) [`Sealed`].
pub trait State: sealed::SealedState {}

/// Typestate: a read end is configured, no compute stage yet.
#[derive(Debug, Clone, Copy)]
pub struct Reading;

/// Typestate: at least one compute stage (or cast) has been appended.
#[derive(Debug, Clone, Copy)]
pub struct Computing;

/// Typestate: the chain has its write end. [`TypedPipeline`] is the sealed
/// form — the marker exists so the state vocabulary is nameable in bounds
/// and docs.
#[derive(Debug, Clone, Copy)]
pub struct Sealed;

impl sealed::SealedState for Reading {}
impl State for Reading {}
impl sealed::SealedState for Computing {}
impl State for Computing {}
impl sealed::SealedState for Sealed {}
impl State for Sealed {}

// ---------------------------------------------------------------------------
// compute stages

/// A reified compute stage — the value `cv::*` wrappers return and
/// [`ChainLink::map`] accepts. Compute-only **by construction**: there is no
/// constructor that wraps a memory op, so an interior read/write is
/// unrepresentable in the typed API (the compile-time form of
/// [`PipelineError::InteriorMemOp`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ComputeOp {
    iop: IOp,
}

impl ComputeOp {
    /// Element-wise op with a scalar parameter (ignored by unary ops).
    pub fn scalar(op: Opcode, param: f64) -> ComputeOp {
        ComputeOp { iop: IOp::compute(op, param) }
    }

    /// Element-wise op with a per-channel float3 parameter.
    pub fn c3(op: Opcode, param: [f32; 3]) -> ComputeOp {
        ComputeOp { iop: IOp::ComputeC3 { op, param } }
    }

    /// BGR<->RGB channel swizzle (the ColorConvert UOp).
    pub fn cvt_color() -> ComputeOp {
        ComputeOp { iop: IOp::CvtColor }
    }

    /// The underlying IOp (always a compute op, never a memop).
    pub fn iop(&self) -> &IOp {
        &self.iop
    }

    /// Lower into the runtime IOp.
    pub fn into_iop(self) -> IOp {
        self.iop
    }
}

/// Anything that can be appended to a chain as one compute stage: the sugar
/// stage structs ([`Mul`], [`Abs`], [`MulC3`], [`CvtColor`], ...) and
/// [`ComputeOp`] itself. Memory operations deliberately do NOT implement
/// this — reads begin chains, writes seal them.
pub trait ComputeStage {
    fn into_op(self) -> ComputeOp;
}

impl ComputeStage for ComputeOp {
    fn into_op(self) -> ComputeOp {
        self
    }
}

macro_rules! scalar_stage {
    ($(#[$m:meta])* $name:ident, $op:ident) => {
        $(#[$m])*
        #[derive(Debug, Clone, Copy, PartialEq)]
        pub struct $name(pub f64);

        impl ComputeStage for $name {
            fn into_op(self) -> ComputeOp {
                ComputeOp::scalar(Opcode::$op, self.0)
            }
        }
    };
}

macro_rules! unit_stage {
    ($(#[$m:meta])* $name:ident, $op:ident) => {
        $(#[$m])*
        #[derive(Debug, Clone, Copy, PartialEq, Eq)]
        pub struct $name;

        impl ComputeStage for $name {
            fn into_op(self) -> ComputeOp {
                ComputeOp::scalar(Opcode::$op, 0.0)
            }
        }
    };
}

macro_rules! c3_stage {
    ($(#[$m:meta])* $name:ident, $op:ident) => {
        $(#[$m])*
        #[derive(Debug, Clone, Copy, PartialEq)]
        pub struct $name(pub [f32; 3]);

        impl ComputeStage for $name {
            fn into_op(self) -> ComputeOp {
                ComputeOp::c3(Opcode::$op, self.0)
            }
        }
    };
}

scalar_stage!(
    /// Multiply by a scalar (`cv::cuda::multiply`).
    Mul, Mul
);
scalar_stage!(
    /// Add a scalar (`cv::cuda::add`).
    Add, Add
);
scalar_stage!(
    /// Subtract a scalar (`cv::cuda::subtract`).
    Sub, Sub
);
scalar_stage!(
    /// Divide by a scalar (`cv::cuda::divide`).
    Div, Div
);
scalar_stage!(
    /// Element-wise min with a scalar.
    Min, Min
);
scalar_stage!(
    /// Element-wise max with a scalar.
    Max, Max
);
unit_stage!(
    /// Identity stage — the `convertTo` placeholder of the OpenCV-flavored
    /// wrapper (the dtype change itself happens at [`ChainLink::cast`] /
    /// the write boundary).
    ConvertTo, Nop
);
unit_stage!(
    /// Absolute value.
    Abs, Abs
);
unit_stage!(
    /// Negate.
    Neg, Neg
);
unit_stage!(
    /// `sqrt(|x|)`.
    Sqrt, Sqrt
);
unit_stage!(
    /// `exp(x)`.
    Exp, Exp
);
unit_stage!(
    /// `ln(|x| + 1)`.
    Log, Log
);
unit_stage!(
    /// Clamp into `[0, 1]`.
    Clamp01, Clamp01
);
c3_stage!(
    /// Per-channel multiply (`nppiMulC_32f_C3R`).
    MulC3, Mul
);
c3_stage!(
    /// Per-channel add.
    AddC3, Add
);
c3_stage!(
    /// Per-channel subtract (`nppiSubC_32f_C3R`).
    SubC3, Sub
);
c3_stage!(
    /// Per-channel divide (`nppiDivC_32f_C3R`).
    DivC3, Div
);

/// BGR<->RGB channel swizzle stage (ColorConvert).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CvtColor;

impl ComputeStage for CvtColor {
    fn into_op(self) -> ComputeOp {
        ComputeOp::cvt_color()
    }
}

// ---------------------------------------------------------------------------
// the builder

/// The front door: associated constructors for the read end of a chain.
/// Structured reads (crop, crop+resize) are first-class typed stages here,
/// exactly like the paper's Fig. 11 read patterns.
pub struct Chain;

impl Chain {
    /// Dense per-thread read of a `[batch, *shape]` tensor.
    pub fn read<T: Elem>(shape: &[usize]) -> ChainLink<Reading, T, T> {
        ChainLink::start(IOp::Mem(MemOp::Read { dtype: T::DTYPE }), shape.to_vec())
    }

    /// Crop-ROI read from a shared frame (BatchRead pattern). The element
    /// shape is the packed-RGB crop `[h, w, 3]`.
    pub fn read_crop<T: Elem>(rect: Rect) -> ChainLink<Reading, T, T> {
        ChainLink::start(
            IOp::Mem(MemOp::CropRead { rect }),
            vec![rect.h as usize, rect.w as usize, 3],
        )
    }

    /// Crop + bilinear-resize read fused at the read end (Fig. 11). The
    /// element shape is the packed-RGB destination `[dst_h, dst_w, 3]`.
    pub fn read_resize<T: Elem>(
        rect: Rect,
        dst_h: usize,
        dst_w: usize,
    ) -> ChainLink<Reading, T, T> {
        ChainLink::start(
            IOp::Mem(MemOp::ResizeRead { rect, dst_h, dst_w }),
            vec![dst_h, dst_w, 3],
        )
    }
}

/// An open chain: `S` is the typestate ([`Reading`] or [`Computing`]), `In`
/// the dtype fixed by the read end, `Cur` the current element type the next
/// stage sees. Sealing ([`ChainLink::write`]) yields a
/// [`TypedPipeline<In, Cur>`].
pub struct ChainLink<S, In, Cur> {
    ops: Vec<IOp>,
    shape: Vec<usize>,
    batch: usize,
    /// Marker-type casts in chain order, handed to the sealed pipeline as
    /// its [`Pipeline::cast_trace`] (static analysis sees what the erased
    /// IR cannot).
    casts: Vec<CastStep>,
    _t: PhantomData<fn() -> (S, In, Cur)>,
}

impl<In: Elem> ChainLink<Reading, In, In> {
    fn start(read: IOp, shape: Vec<usize>) -> ChainLink<Reading, In, In> {
        ChainLink { ops: vec![read], shape, batch: 1, casts: Vec::new(), _t: PhantomData }
    }
}

impl<S: State, In: Elem, Cur: Elem> ChainLink<S, In, Cur> {
    /// Set the HF batch width (default 1).
    pub fn batch(mut self, n: usize) -> ChainLink<S, In, Cur> {
        self.batch = n.max(1);
        self
    }

    /// Append one compute stage. The element type flows through unchanged —
    /// compute runs in the engine's accumulator domain; only
    /// [`ChainLink::cast`] moves the dtype boundary.
    pub fn map(mut self, stage: impl ComputeStage) -> ChainLink<Computing, In, Cur> {
        self.ops.push(stage.into_op().into_iop());
        self.transition()
    }

    /// Append a slice of reified stages (the `execute_operations` shape).
    pub fn extend(mut self, stages: &[ComputeOp]) -> ChainLink<Computing, In, Cur> {
        self.ops.extend(stages.iter().cloned().map(ComputeOp::into_iop));
        self.transition()
    }

    /// Move the dtype boundary: every later stage (and the write end) sees
    /// `W`. Lowering is a no-op — the runtime IR carries dtypes only at the
    /// read/write boundary, so the cast costs nothing and the
    /// [`Signature`] is unchanged (plan-cache parity with the untyped IR).
    /// The cast IS recorded in the sealed pipeline's
    /// [`Pipeline::cast_trace`], where the `analysis` linter flags
    /// redundant chains and narrowing round-trips.
    pub fn cast<W: Elem>(mut self) -> ChainLink<Computing, In, W> {
        self.casts.push(CastStep { at: self.ops.len() - 1, to: W::DTYPE });
        ChainLink {
            ops: self.ops,
            shape: self.shape,
            batch: self.batch,
            casts: self.casts,
            _t: PhantomData,
        }
    }

    /// Seal with a dense per-thread write of the current element type.
    pub fn write(self) -> TypedPipeline<In, Cur> {
        self.seal(MemOp::Write { dtype: Cur::DTYPE })
    }

    /// Seal with a packed->planar split write (the Split WOp of Fig. 11).
    pub fn write_split(self) -> TypedPipeline<In, Cur> {
        self.seal(MemOp::SplitWrite { dtype: Cur::DTYPE })
    }

    /// Seal with a full-tensor reduction terminator (the ReduceDPP of paper
    /// §IV-C): the fused pass folds every element's chain output into the
    /// statistic WHILE reading — no per-element write, no materialized
    /// intermediate. Reductions seal at `F64` (the statistics domain)
    /// regardless of the chain's current element type; like every seal this
    /// is terminal, so `map`-after-`reduce` is a compile error.
    pub fn reduce(self, kind: ReduceKind) -> TypedPipeline<In, F64> {
        self.reduce_spec(ReduceSpec::single(kind, ReduceAxis::Full))
    }

    /// Seal with a per-channel reduction: one statistic per packed-RGB lane
    /// (global element index % 3 — the same lane rule as `MulC3`/`CvtColor`
    /// stages), output shape `[3]`.
    pub fn reduce_per_channel(self, kind: ReduceKind) -> TypedPipeline<In, F64> {
        self.reduce_spec(ReduceSpec::single(kind, ReduceAxis::PerChannel))
    }

    /// Seal with TWO statistics folded in the very same pass (output `[2]`)
    /// — how normalize's pass 1 gets mean AND sum-of-squares from one read.
    pub fn reduce_pair(self, kind: ReduceKind, extra: ReduceKind) -> TypedPipeline<In, F64> {
        self.reduce_spec(ReduceSpec::pair(kind, extra, ReduceAxis::Full))
    }

    /// [`ChainLink::reduce_pair`] per packed-RGB channel (output `[2, 3]`).
    pub fn reduce_pair_per_channel(
        self,
        kind: ReduceKind,
        extra: ReduceKind,
    ) -> TypedPipeline<In, F64> {
        self.reduce_spec(ReduceSpec::pair(kind, extra, ReduceAxis::PerChannel))
    }

    /// The general reduce seal (the sugar above lowers here; also the
    /// erased entrance's hook, [`build_erased_reduce`]).
    pub fn reduce_spec(mut self, spec: ReduceSpec) -> TypedPipeline<In, F64> {
        self.ops.push(IOp::Mem(MemOp::Reduce { spec }));
        let pipeline = Pipeline::new(self.ops, self.shape, self.batch, In::DTYPE, DType::F64)
            .expect("chain builder invariant: read first, reduce last, f64 statistics")
            .with_cast_trace(self.casts);
        TypedPipeline { pipeline, _t: PhantomData }
    }

    fn transition<S2: State>(self) -> ChainLink<S2, In, Cur> {
        ChainLink {
            ops: self.ops,
            shape: self.shape,
            batch: self.batch,
            casts: self.casts,
            _t: PhantomData,
        }
    }

    fn seal(mut self, write: MemOp) -> TypedPipeline<In, Cur> {
        self.ops.push(IOp::Mem(write));
        let pipeline = Pipeline::new(self.ops, self.shape, self.batch, In::DTYPE, Cur::DTYPE)
            .expect("chain builder invariant: read first, write last, compute-only interior")
            .with_cast_trace(self.casts);
        TypedPipeline { pipeline, _t: PhantomData }
    }
}

// ---------------------------------------------------------------------------
// the sealed pipeline

/// A sealed, compile-time-checked pipeline: the [`Sealed`] state of the
/// chain. Carries the validated runtime [`Pipeline`] (the stable IR every
/// engine and the [`Signature`] plan cache consume) plus the `In`/`Out`
/// dtype evidence the host backend uses to monomorphize.
pub struct TypedPipeline<In, Out> {
    pipeline: Pipeline,
    _t: PhantomData<fn() -> (In, Out)>,
}

impl<In: Elem, Out: Elem> TypedPipeline<In, Out> {
    /// The lowered runtime IR (what [`crate::exec::Engine::run`] consumes).
    pub fn pipeline(&self) -> &Pipeline {
        &self.pipeline
    }

    /// Lower by value (e.g. for [`crate::coordinator::Service::submit`]).
    pub fn into_pipeline(self) -> Pipeline {
        self.pipeline
    }

    /// Parameter-agnostic cache identity — unchanged vs the untyped IR, so
    /// plan/artifact reuse is byte-for-byte the same.
    pub fn signature(&self) -> Signature {
        Signature::of(&self.pipeline)
    }

    /// Run the static analyzer over the sealed IR: typed, coded diagnostics
    /// (identity ops, cast chains, saturation/NaN hazards, tier
    /// prediction). Pure — the pipeline is not touched.
    pub fn lint(&self) -> Vec<crate::analysis::Diagnostic> {
        crate::analysis::lint(&self.pipeline)
    }

    /// The canonicalized twin of this pipeline plus the rewrite report.
    /// Only bit-safety-proven rewrites are applied, so the result computes
    /// the same bits; the dtype evidence therefore still holds and the
    /// result is a [`TypedPipeline`] of the same `(In, Out)`.
    pub fn canonicalized(&self) -> (TypedPipeline<In, Out>, Vec<crate::analysis::Rewrite>) {
        let (pipeline, rewrites) = crate::analysis::canonicalize(self.pipeline.clone());
        (TypedPipeline { pipeline, _t: PhantomData }, rewrites)
    }

    /// Execute on the host fused engine through the **statically
    /// monomorphized** single-pass loop: the `(In, Out)` markers pick the
    /// lane pair at compile time ([`HostFusedEngine::run_mono`]), the Rust
    /// analog of the paper's compile-time kernel instantiation. Structured
    /// boundary stages execute natively in the same pass — a crop/resize
    /// read gathers from `input` as the shared `[fh, fw, 3]` frame, a split
    /// write lands planar (see [`Pipeline::out_shape`]). Numerics are
    /// identical to the dynamic [`crate::exec::Engine::run`] path — same
    /// plan, same loops.
    pub fn run_host(&self, engine: &HostFusedEngine, input: &Tensor) -> Result<Tensor> {
        let p = &self.pipeline;
        ensure!(
            input.dtype() == In::DTYPE,
            "chain input dtype {} != typed In = {}",
            input.dtype(),
            In::DTYPE
        );
        let src = In::slice(input).context("dtype checked above")?;
        let out: Vec<Out::Lane> = engine.run_mono(p, src, input.shape())?;
        Ok(Out::from_vec(out, &p.out_shape()))
    }
}

impl<In: Elem, Out: Elem> Clone for TypedPipeline<In, Out> {
    fn clone(&self) -> Self {
        TypedPipeline { pipeline: self.pipeline.clone(), _t: PhantomData }
    }
}

impl<In: Elem, Out: Elem> std::fmt::Debug for TypedPipeline<In, Out> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TypedPipeline")
            .field("in", &In::DTYPE)
            .field("out", &Out::DTYPE)
            .field("pipeline", &self.pipeline)
            .finish()
    }
}

impl<In: Elem, Out: Elem> From<TypedPipeline<In, Out>> for Pipeline {
    fn from(tp: TypedPipeline<In, Out>) -> Pipeline {
        tp.pipeline
    }
}

impl<In: Elem, Out: Elem> From<&TypedPipeline<In, Out>> for Pipeline {
    fn from(tp: &TypedPipeline<In, Out>) -> Pipeline {
        tp.pipeline.clone()
    }
}

impl<In: Elem, Out: Elem> AsRef<Pipeline> for TypedPipeline<In, Out> {
    fn as_ref(&self) -> &Pipeline {
        &self.pipeline
    }
}

// ---------------------------------------------------------------------------
// the dynamic entrance (dtypes as data)

/// Build through the typed chain with *runtime* dtypes: the 5x5
/// monomorphization table over [`Chain::read`]/[`ChainLink::cast`]. This is
/// the single sanctioned dynamic entrance — CLI flags and manifest-driven
/// sweeps lower here, so every pipeline in the system flows through the
/// typed builder. Infallible by construction (the builder's invariants hold
/// for every dispatch arm).
pub fn build_erased(
    stages: &[ComputeOp],
    shape: &[usize],
    batch: usize,
    dtin: DType,
    dtout: DType,
) -> Pipeline {
    fn seal_out<In: Elem>(
        link: ChainLink<Computing, In, In>,
        dtout: DType,
    ) -> Pipeline {
        match dtout {
            DType::U8 => link.cast::<U8>().write().into_pipeline(),
            DType::U16 => link.cast::<U16>().write().into_pipeline(),
            DType::I32 => link.cast::<I32>().write().into_pipeline(),
            DType::F32 => link.cast::<F32>().write().into_pipeline(),
            DType::F64 => link.cast::<F64>().write().into_pipeline(),
        }
    }
    fn build_in<In: Elem>(
        stages: &[ComputeOp],
        shape: &[usize],
        batch: usize,
        dtout: DType,
    ) -> Pipeline {
        seal_out::<In>(Chain::read::<In>(shape).batch(batch).extend(stages), dtout)
    }
    match dtin {
        DType::U8 => build_in::<U8>(stages, shape, batch, dtout),
        DType::U16 => build_in::<U16>(stages, shape, batch, dtout),
        DType::I32 => build_in::<I32>(stages, shape, batch, dtout),
        DType::F32 => build_in::<F32>(stages, shape, batch, dtout),
        DType::F64 => build_in::<F64>(stages, shape, batch, dtout),
    }
}

/// [`build_erased`] over `(Opcode, param)` pairs — the migration path for
/// the experiment/bench sweeps that used `Pipeline::from_opcodes`.
pub fn build_erased_opcodes(
    chain: &[(Opcode, f64)],
    shape: &[usize],
    batch: usize,
    dtin: DType,
    dtout: DType,
) -> Pipeline {
    let stages: Vec<ComputeOp> =
        chain.iter().map(|&(op, param)| ComputeOp::scalar(op, param)).collect();
    build_erased(&stages, shape, batch, dtin, dtout)
}

/// [`build_erased`] for reduce-terminated chains: runtime dtype, typed
/// builder underneath — the erased entrance `cv::mean_std` and
/// `cv::normalize` lower through. Reductions always seal at f64.
pub fn build_erased_reduce(
    stages: &[ComputeOp],
    shape: &[usize],
    batch: usize,
    dtin: DType,
    spec: ReduceSpec,
) -> Pipeline {
    fn build_in<In: Elem>(
        stages: &[ComputeOp],
        shape: &[usize],
        batch: usize,
        spec: ReduceSpec,
    ) -> Pipeline {
        Chain::read::<In>(shape).batch(batch).extend(stages).reduce_spec(spec).into_pipeline()
    }
    match dtin {
        DType::U8 => build_in::<U8>(stages, shape, batch, spec),
        DType::U16 => build_in::<U16>(stages, shape, batch, spec),
        DType::I32 => build_in::<I32>(stages, shape, batch, spec),
        DType::F32 => build_in::<F32>(stages, shape, batch, spec),
        DType::F64 => build_in::<F64>(stages, shape, batch, spec),
    }
}

// ---------------------------------------------------------------------------
// the divergent window front door

/// Run a WINDOW of erased pipelines — mixed params, signatures and chain
/// lengths; dense, structured and reduce terminators alike — as ONE
/// divergent-HF pass on the host fused engine
/// ([`HostFusedEngine::run_divergent`](crate::exec::HostFusedEngine::run_divergent)):
/// items are weighted by element count, chunked across worker lanes, and
/// each lane dispatches its items' monomorphized loops back-to-back.
/// Results come back in window order and are BIT-EQUAL to running each
/// `(pipeline, input)` alone; the first failing item fails the call,
/// naming its window index.
pub fn run_many(
    engine: &HostFusedEngine,
    window: &[(&Pipeline, &Tensor)],
) -> Result<Vec<Tensor>> {
    let out = engine.run_divergent(window);
    out.results
        .into_iter()
        .enumerate()
        .map(|(i, r)| r.with_context(|| format!("window item {i}")))
        .collect()
}

// ---------------------------------------------------------------------------
// the normalize preset (multi-pass fused pipelines)

/// The `(x − μ) / σ` stage pair for per-lane statistics — the ONE definition
/// of normalize pass 2's body, shared by the typed [`Normalize`] preset and
/// the erased `cv::normalize` front door (per-channel constants are `f32`,
/// like every C3 stage; `mu`/`sigma` must carry one value per lane of
/// `axis`).
pub fn normalize_stages(axis: ReduceAxis, mu: &[f64], sigma: &[f64]) -> Vec<ComputeOp> {
    match axis {
        ReduceAxis::Full => {
            vec![ComputeOp::scalar(Opcode::Sub, mu[0]), ComputeOp::scalar(Opcode::Div, sigma[0])]
        }
        ReduceAxis::PerChannel => {
            vec![
                ComputeOp::c3(Opcode::Sub, [mu[0] as f32, mu[1] as f32, mu[2] as f32]),
                ComputeOp::c3(Opcode::Div, [sigma[0] as f32, sigma[1] as f32, sigma[2] as f32]),
            ]
        }
    }
}

/// The register-resident normalize workload as a TWO-PASS fused plan:
///
/// * **pass 1** — `read -> [map stages] -> reduce(Mean + SumSq)`: both
///   statistics fold in ONE read (the fold-while-reading tier);
/// * **pass 2** — `read -> [map stages] -> Sub(μ) -> Div(σ) -> write f32`:
///   the statistics hand over as BOUND SCALARS (per-channel `f32` constants
///   or a full-tensor `f64` param) — no intermediate tensor is ever
///   materialized between the passes.
///
/// ```
/// use fkl::chain::{Chain, Mul, U8};
/// use fkl::exec::HostFusedEngine;
/// use fkl::ops::ReduceAxis;
/// use fkl::tensor::Tensor;
///
/// let norm = Chain::normalize::<U8>(&[4, 4], ReduceAxis::Full).map(Mul(0.5));
/// let x = Tensor::from_u8(&(0..16).collect::<Vec<u8>>(), &[1, 4, 4]);
/// let out = norm.run_host(&HostFusedEngine::new(), &x).unwrap();
/// // normalized output: mean 0, std 1 (f64 statistics, f32 output)
/// let mean: f64 = out.to_f64_vec().iter().sum::<f64>() / 16.0;
/// assert!(mean.abs() < 1e-5);
/// ```
pub struct Normalize<In: Elem> {
    shape: Vec<usize>,
    batch: usize,
    axis: ReduceAxis,
    eps: f64,
    stages: Vec<ComputeOp>,
    _t: PhantomData<fn() -> In>,
}

impl Chain {
    /// Begin a two-pass fused normalize over `[batch, *shape]` tensors (see
    /// [`Normalize`]).
    pub fn normalize<In: Elem>(shape: &[usize], axis: ReduceAxis) -> Normalize<In> {
        Normalize {
            shape: shape.to_vec(),
            batch: 1,
            axis,
            eps: 1e-12,
            stages: Vec::new(),
            _t: PhantomData,
        }
    }
}

impl<In: Elem> Normalize<In> {
    /// Set the HF batch width (default 1). Statistics fold over the whole
    /// batch.
    pub fn batch(mut self, n: usize) -> Normalize<In> {
        self.batch = n.max(1);
        self
    }

    /// Floor for σ (default `1e-12`), keeping pass 2's divide well-defined
    /// on constant inputs.
    pub fn eps(mut self, eps: f64) -> Normalize<In> {
        self.eps = eps.max(0.0);
        self
    }

    /// Append a compute stage shared by BOTH passes (the "map" of
    /// map+reduce fusion): pass 1 folds its output into the statistics,
    /// pass 2 re-applies it before subtracting μ — so the normalize is of
    /// the *mapped* values, and the mapped tensor still never materializes.
    pub fn map(mut self, stage: impl ComputeStage) -> Normalize<In> {
        self.stages.push(stage.into_op());
        self
    }

    /// The reduce spec pass 1 folds: `(Mean, SumSq)` over this preset's
    /// axis.
    pub fn spec(&self) -> ReduceSpec {
        ReduceSpec::pair(ReduceKind::Mean, ReduceKind::SumSq, self.axis)
    }

    /// Pass 1: the fused map+reduce pipeline (mean and sum-of-squares in
    /// one read).
    pub fn stats_pass(&self) -> TypedPipeline<In, F64> {
        let link = Chain::read::<In>(&self.shape).batch(self.batch).extend(&self.stages);
        link.reduce_spec(self.spec())
    }

    /// Split pass 1's statistics tensor into per-lane `(μ, σ)` through the
    /// shared [`kernel::mean_sigma_from_stats`] table.
    pub fn mean_sigma(&self, stats: &Tensor) -> Result<(Vec<f64>, Vec<f64>)> {
        let spec = self.spec();
        let vals = stats.as_f64().context("stats pass seals at f64")?;
        ensure!(
            vals.len() == spec.out_len(),
            "stats tensor has {} values, the (mean, sumsq) spec needs {}",
            vals.len(),
            spec.out_len()
        );
        let n = self.batch * self.shape.iter().product::<usize>();
        Ok(kernel::mean_sigma_from_stats(spec, vals, n, self.eps))
    }

    /// Pass 2: the fused `(x - μ) / σ` map with the statistics bound as
    /// stage params (through the shared [`normalize_stages`] definition).
    pub fn map_pass(&self, mu: &[f64], sigma: &[f64]) -> TypedPipeline<In, F32> {
        let lanes = self.spec().lanes();
        assert_eq!(mu.len(), lanes, "μ must carry one value per lane");
        assert_eq!(sigma.len(), lanes, "σ must carry one value per lane");
        Chain::read::<In>(&self.shape)
            .batch(self.batch)
            .extend(&self.stages)
            .extend(&normalize_stages(self.axis, mu, sigma))
            .cast::<F32>()
            .write()
    }

    /// Run both passes on the host fused engine: one fold-while-reading
    /// pass for the statistics, one map pass for the output — two memory
    /// passes total, nothing materialized in between.
    pub fn run_host(&self, engine: &HostFusedEngine, input: &Tensor) -> Result<Tensor> {
        let stats = self.stats_pass().run_host(engine, input)?;
        let (mu, sigma) = self.mean_sigma(&stats)?;
        self.map_pass(&mu, &sigma).run_host(engine, input)
    }
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Engine;

    #[test]
    fn typed_chain_lowers_to_the_same_ir_as_the_untyped_builder() {
        // plan-cache parity: identical IOps, dtypes, shape, batch, signature
        let typed = Chain::read::<U8>(&[60, 120])
            .batch(4)
            .map(ConvertTo)
            .map(Mul(0.5))
            .map(Sub(3.0))
            .map(Div(1.7))
            .cast::<F32>()
            .write();
        let untyped = Pipeline::from_opcodes(
            &[(Opcode::Nop, 0.0), (Opcode::Mul, 0.5), (Opcode::Sub, 3.0), (Opcode::Div, 1.7)],
            &[60, 120],
            4,
            DType::U8,
            DType::F32,
        )
        .unwrap();
        assert_eq!(typed.pipeline(), &untyped);
        assert_eq!(typed.signature(), Signature::of(&untyped));
    }

    #[test]
    fn cast_is_free_and_signature_is_param_agnostic() {
        let a = Chain::read::<F32>(&[8]).map(Mul(2.0)).write();
        let b = Chain::read::<F32>(&[8]).map(Mul(9.0)).cast::<F32>().write();
        assert_eq!(a.signature(), b.signature(), "cast adds no ops, params ignored");
    }

    #[test]
    fn interior_casts_are_traced_and_surface_through_lint_and_canonicalize() {
        use crate::ops::CastStep;
        // the final cast to the write dtype is implied (trace stays empty =
        // plan-cache parity with the untyped IR); interior casts survive
        let plain = Chain::read::<U8>(&[8]).map(Mul(2.0)).cast::<F32>().write();
        assert_eq!(plain.pipeline().cast_trace(), &[]);
        let traced = Chain::read::<F64>(&[8])
            .map(Mul(2.0))
            .cast::<F32>()
            .cast::<F64>()
            .map(Add(1.0))
            .write();
        assert_eq!(
            traced.pipeline().cast_trace(),
            &[CastStep { at: 1, to: DType::F32 }, CastStep { at: 1, to: DType::F64 }]
        );
        // the narrowing round trip is a lint (FKL004), not a rewrite: the
        // canonical twin keeps it and the builder's dtype evidence
        let diags = traced.lint();
        assert!(diags.iter().any(|d| d.code.code() == "FKL004"), "{diags:?}");
        let (canon, rewrites) = traced.canonicalized();
        assert!(rewrites.iter().all(|r| !r.applied));
        assert_eq!(canon.pipeline(), traced.pipeline());

        // a dead identity stage IS rewritten away, preserving the signature
        // modulo the removed op
        let noisy = Chain::read::<U8>(&[8]).map(Mul(1.0)).map(Add(3.0)).cast::<F32>().write();
        let (canon, rewrites) = noisy.canonicalized();
        assert!(rewrites.iter().any(|r| r.applied));
        assert_eq!(canon.pipeline().body(), &[IOp::compute(Opcode::Add, 3.0)]);
    }

    #[test]
    fn structured_reads_and_split_writes_are_typed_stages() {
        let r = Rect::new(10, 20, 120, 60);
        let p = Chain::read_resize::<U8>(r, 128, 64)
            .map(CvtColor)
            .map(MulC3([0.5, 0.4, 0.3]))
            .cast::<F32>()
            .write_split();
        let sig = p.signature();
        assert_eq!(sig.ops, "resize[128x64]-cvtcolor-mulc3-split[f32]");
        assert_eq!(sig.dtin, "u8");
        assert_eq!(sig.dtout, "f32");
        assert_eq!(p.pipeline().shape, vec![128, 64, 3]);
        // the typed front door SERVES structured chains on the host engine:
        // gather while reading, split while writing, one pass — bit-equal
        // to the structured oracle
        let eng = HostFusedEngine::with_threads(1);
        let frame = crate::tensor::make_frame(200, 320, 31);
        let out = p.run_host(&eng, &frame).expect("structured chains run on the host tier");
        assert_eq!(out.shape(), &[1, 3, 128, 64]);
        assert_eq!(out, crate::hostref::run_pipeline(p.pipeline(), &frame));
        assert_eq!(eng.structured_runs(), 1);
        // a batched dense tensor is NOT a frame: still refused loudly
        let batched = Tensor::zeros(DType::U8, &[1, 128, 64, 3]);
        assert!(p.run_host(&eng, &batched).is_err());
    }

    #[test]
    fn run_host_matches_the_dynamic_engine_bitwise() {
        let typed = Chain::read::<U8>(&[9, 7])
            .batch(2)
            .map(Mul(1.7))
            .map(Add(11.0))
            .write();
        let mut vals = Vec::new();
        let mut x = 3u64;
        for _ in 0..126 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            vals.push((x >> 56) as u8);
        }
        let input = Tensor::from_u8(&vals, &[2, 9, 7]);
        let eng = HostFusedEngine::with_threads(2);
        let mono = typed.run_host(&eng, &input).unwrap();
        let dynamic = eng.run(typed.pipeline(), &input).unwrap();
        assert_eq!(mono, dynamic, "static and dynamic dispatch share the loops");
        assert_eq!(mono, crate::hostref::run_pipeline(typed.pipeline(), &input));
    }

    #[test]
    fn run_host_rejects_wrong_inputs_loudly() {
        let typed = Chain::read::<F32>(&[4]).map(Mul(2.0)).write();
        let eng = HostFusedEngine::with_threads(1);
        let wrong_dtype = Tensor::from_u8(&[1; 4], &[1, 4]);
        assert!(typed.run_host(&eng, &wrong_dtype).is_err());
        let wrong_shape = Tensor::from_f32(&[0.0; 8], &[2, 4]);
        assert!(typed.run_host(&eng, &wrong_shape).is_err());
    }

    #[test]
    fn erased_entrance_dispatches_every_dtype_pair() {
        const ALL: [DType; 5] =
            [DType::U8, DType::U16, DType::I32, DType::F32, DType::F64];
        let stages = [ComputeOp::scalar(Opcode::Mul, 2.0)];
        for dtin in ALL {
            for dtout in ALL {
                let p = build_erased(&stages, &[4, 4], 3, dtin, dtout);
                assert_eq!(p.dtin, dtin);
                assert_eq!(p.dtout, dtout);
                assert_eq!(p.batch, 3);
                assert_eq!(p.body().len(), 1);
            }
        }
    }

    #[test]
    fn reading_state_can_seal_directly() {
        // a read-write passthrough is legal (the runtime IR allows an empty
        // body); the typestate permits sealing from Reading
        let p = Chain::read::<F32>(&[4]).write();
        assert_eq!(p.pipeline().body().len(), 0);

        // ... and a reduce can seal straight from Reading too (raw stats)
        let r = Chain::read::<F32>(&[4]).reduce(ReduceKind::Max);
        assert_eq!(r.pipeline().body().len(), 0);
        assert_eq!(r.pipeline().dtout, DType::F64);
    }

    #[test]
    fn typed_reduce_seals_lower_and_serve_on_the_host_tier() {
        // the acceptance shape: read -> map -> reduce(Mean), served by the
        // fold-while-reading tier, bit-equal to the hostref oracle
        let typed = Chain::read::<U8>(&[6, 5]).batch(3).map(Mul(0.5)).reduce(ReduceKind::Mean);
        let sig = typed.signature();
        assert_eq!(sig.ops, "mul-reduce[mean]");
        assert_eq!((sig.dtin.as_str(), sig.dtout.as_str()), ("u8", "f64"));
        let spec = typed.pipeline().reduction().expect("terminator recorded");
        assert_eq!((spec.kind, spec.axis), (ReduceKind::Mean, ReduceAxis::Full));

        let mut vals = Vec::new();
        let mut x = 7u64;
        for _ in 0..90 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            vals.push((x >> 56) as u8);
        }
        let input = Tensor::from_u8(&vals, &[3, 6, 5]);
        let eng = HostFusedEngine::with_threads(2);
        let got = typed.run_host(&eng, &input).unwrap();
        assert_eq!(got.shape(), &[1]);
        assert_eq!(got, crate::hostref::run_pipeline(typed.pipeline(), &input));
        assert_eq!(eng.reduce_runs(), 1);
        // the dynamic entry shares the loops bitwise
        assert_eq!(eng.run(typed.pipeline(), &input).unwrap(), got);
    }

    #[test]
    fn run_many_serves_a_mixed_window_bit_equal_to_per_item() {
        use crate::ops::ReduceKind;
        use crate::tensor::{make_frame, Rect};
        let dense = Chain::read::<U8>(&[5, 6]).map(Mul(2.0)).cast::<F32>().write().into_pipeline();
        let structured =
            Chain::read_crop::<U8>(Rect::new(1, 2, 6, 4)).map(Mul(0.5)).write().into_pipeline();
        let reduce =
            Chain::read::<U8>(&[5, 6]).map(Mul(0.25)).reduce(ReduceKind::Mean).into_pipeline();
        let item = Tensor::from_u8(&(0..30).collect::<Vec<u8>>(), &[1, 5, 6]);
        let frame = make_frame(12, 16, 3);
        let eng = HostFusedEngine::with_threads(2);
        let window: Vec<(&Pipeline, &Tensor)> =
            vec![(&dense, &item), (&structured, &frame), (&reduce, &item)];
        let got = run_many(&eng, &window).expect("mixed window serves");
        assert_eq!(got.len(), 3);
        for (i, ((p, t), out)) in window.iter().zip(&got).enumerate() {
            assert_eq!(out, &crate::hostref::run_pipeline(p, t), "item {i}");
        }
        assert_eq!(eng.divergent_runs(), 1, "one pass for the whole window");
        // a failing item names its window index
        let bad = Tensor::from_f32(&[0.0; 30], &[1, 5, 6]);
        let err = run_many(&eng, &[(&dense, &item), (&dense, &bad)]).unwrap_err();
        assert!(format!("{err:#}").contains("window item 1"), "{err:#}");
    }

    #[test]
    fn normalize_preset_is_two_fused_passes_with_bound_scalars() {
        let norm = Chain::normalize::<U8>(&[4, 2, 3], ReduceAxis::PerChannel)
            .batch(2)
            .map(Mul(2.0));
        let eng = HostFusedEngine::with_threads(1);
        let mut vals = Vec::new();
        let mut x = 11u64;
        for _ in 0..48 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            vals.push((x >> 56) as u8);
        }
        let input = Tensor::from_u8(&vals, &[2, 4, 2, 3]);
        let out = norm.run_host(&eng, &input).unwrap();
        assert_eq!(out.shape(), &[2, 4, 2, 3]);

        // the preset == composing its passes through the ORACLE with the
        // same bound scalars (bit-equal: both passes are oracle-pinned)
        let stats = crate::hostref::run_pipeline(norm.stats_pass().pipeline(), &input);
        let (mu, sigma) = norm.mean_sigma(&stats).unwrap();
        let want = crate::hostref::run_pipeline(norm.map_pass(&mu, &sigma).pipeline(), &input);
        assert_eq!(out, want, "engine normalize == oracle-composed passes");

        // per-channel mean of the OUTPUT is 0 and std is 1 (the workload's
        // defining property), up to f32 write rounding
        let v = out.as_f32().unwrap();
        for c in 0..3 {
            let lane: Vec<f64> = v.iter().skip(c).step_by(3).map(|&x| x as f64).collect();
            let mean: f64 = lane.iter().sum::<f64>() / lane.len() as f64;
            let var: f64 = lane.iter().map(|x| x * x).sum::<f64>() / lane.len() as f64;
            assert!(mean.abs() < 1e-5, "lane {c} mean {mean}");
            assert!((var - 1.0).abs() < 1e-4, "lane {c} var {var}");
        }
    }
}
