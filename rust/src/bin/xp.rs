//! `xp` — the experiment runner: regenerates every table and figure of the
//! paper's evaluation section (DESIGN.md §6).
//!
//! ```text
//! xp all            # run everything -> results/*.csv + results/summary.md
//! xp 2 3 4          # run selected experiments
//! xp fig1 --fast    # trimmed sweeps (CI)
//! xp list           # list experiment ids
//! ```

use std::path::PathBuf;

use fkl::bench::{write_csv, write_markdown};
use fkl::experiments::{self, XpCtx};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let fast = args.iter().any(|a| a == "--fast");
    let out_pos = args.iter().position(|a| a == "--out");
    let out: PathBuf = out_pos
        .and_then(|i| args.get(i + 1))
        .map(PathBuf::from)
        .unwrap_or_else(|| "results".into());
    let out_val_idx = out_pos.map(|i| i + 1);
    let ids: Vec<&str> = args
        .iter()
        .enumerate()
        .filter(|(i, a)| !a.starts_with("--") && Some(*i) != out_val_idx)
        .map(|(_, a)| a.as_str())
        .collect();

    if ids.first() == Some(&"list") {
        println!("experiments: {:?}", experiments::ALL);
        return;
    }
    let ids: Vec<&str> =
        if ids.is_empty() || ids == ["all"] { experiments::ALL.to_vec() } else { ids };

    // the registry-backed context is built lazily: host-only experiments
    // (experiments::HOST_ONLY) run without artifacts on any machine
    let mut ctx: Option<XpCtx> = None;
    // fresh summary per invocation
    let _ = std::fs::remove_file(out.join("summary.md"));

    let mut failed = 0;
    for id in &ids {
        let t0 = std::time::Instant::now();
        eprintln!("== running experiment {id} ==");
        let result = if experiments::HOST_ONLY.contains(id) {
            experiments::run_host(id, fast)
        } else {
            if ctx.is_none() {
                match XpCtx::new(fast) {
                    Ok(c) => ctx = Some(c),
                    Err(e) => {
                        eprintln!("error: {e:#}");
                        std::process::exit(1);
                    }
                }
            }
            experiments::run(id, ctx.as_ref().expect("context just built"))
        };
        match result {
            Ok(tables) => {
                for (i, t) in tables.iter().enumerate() {
                    let stem = if tables.len() == 1 {
                        format!("xp{id}")
                    } else {
                        format!("xp{id}_{i}")
                    };
                    if let Err(e) = write_csv(&out, &stem, t) {
                        eprintln!("  write {stem}: {e:#}");
                    }
                    print!("{}", t.to_markdown());
                }
                if let Err(e) = write_markdown(&out, &tables.iter().collect::<Vec<_>>()) {
                    eprintln!("  summary: {e:#}");
                }
                eprintln!("== {id} done in {:.1}s ==", t0.elapsed().as_secs_f64());
            }
            Err(e) => {
                eprintln!("experiment {id} FAILED: {e:#}");
                failed += 1;
            }
        }
    }
    if failed > 0 {
        std::process::exit(1);
    }
}
