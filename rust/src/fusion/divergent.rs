//! Divergent Horizontal Fusion planning — one launch for a window of
//! HETEROGENEOUS pipelines.
//!
//! The paper's HF story is not "batch identical work": arbitrary
//! combinations of library functions fuse into one kernel, and "Automatic
//! Horizontal Fusion for GPU Kernels" (PAPERS.md) interleaves fully
//! *divergent* instruction sequences in one launch. The identical-signature
//! tier ([`hfusion`](super::hfusion)) cannot express that: it packs m equal
//! planes into batch buckets of ONE code shape. This module plans the
//! divergent tier instead: a coordinator window of mixed pipelines —
//! different params, different signatures, different chain lengths; dense,
//! structured and reduce terminators alike — compiles into one
//! [`DivergentPlan`]: per-item sub-plans (reusing [`HostPlan`] and the
//! engine's per-signature cache) bound into a single thread-chunked launch.
//!
//! Bucketing generalizes [`hfusion::pack`](super::hfusion::pack) to
//! mixed-SHAPE items: where identical HF's unit is a batch plane and its
//! bucket a batch width, the divergent unit is one item weighted by its
//! element count and the bucket is a worker LANE
//! ([`hfusion::chunk_weighted`](super::hfusion::chunk_weighted)). Padding
//! accounting generalizes the same way: every lane runs as long as the
//! heaviest, so the idle weight of the lighter lanes
//! ([`hfusion::chunk_padding`](super::hfusion::chunk_padding)) is the
//! divergent analog of pad planes, surfaced as occupancy in coordinator
//! metrics.
//!
//! Execution lives in [`crate::exec::HostFusedEngine::run_divergent`]; the
//! artifact tiers refuse divergent windows with the typed
//! [`PlanError::Divergent`](super::PlanError::Divergent) (one artifact
//! launch binds one code shape) and
//! [`crate::exec::FusedEngine::run_many`] re-routes them here.

use std::collections::HashSet;
use std::ops::Range;
use std::rc::Rc;

use crate::ops::Pipeline;

use super::{hfusion, HostPlan};

/// One window item of a divergent launch: its compiled (cached) host plan
/// plus the work weight the lane chunking balances.
#[derive(Debug, Clone)]
pub struct DivergentItem {
    plan: Rc<HostPlan>,
    work_elems: usize,
}

impl DivergentItem {
    /// The item's compiled sub-plan (shared with the per-signature cache).
    pub fn plan(&self) -> &HostPlan {
        &self.plan
    }

    /// Elements this item's fused pass touches (`batch * item_elems` — for
    /// structured reads this is the gathered OUTPUT space, the loop's trip
    /// count).
    pub fn work_elems(&self) -> usize {
        self.work_elems
    }
}

/// A compiled divergent-HF window: per-item sub-plans bound into one
/// thread-chunked launch, plus the pad/occupancy accounting of the
/// chunking. Item order is window order; results never depend on the lane
/// assignment (every sub-pass is thread-count invariant).
#[derive(Debug, Clone)]
pub struct DivergentPlan {
    items: Vec<DivergentItem>,
    chunks: Vec<Range<usize>>,
    distinct_signatures: usize,
    total_work_elems: usize,
    padded_work_elems: usize,
}

impl DivergentPlan {
    /// Compile a window against at most `lanes` worker lanes. `plan_for`
    /// supplies each item's [`HostPlan`] — pass the engine's cached lookup
    /// so repeated signatures in the window (and across windows) share one
    /// compiled plan.
    pub fn compile(
        window: &[&Pipeline],
        lanes: usize,
        mut plan_for: impl FnMut(&Pipeline) -> Rc<HostPlan>,
    ) -> DivergentPlan {
        let items: Vec<DivergentItem> = window
            .iter()
            .map(|p| DivergentItem {
                plan: plan_for(p),
                work_elems: p.batch * p.item_elems(),
            })
            .collect();
        let weights: Vec<usize> = items.iter().map(DivergentItem::work_elems).collect();
        let chunks = hfusion::chunk_weighted(&weights, lanes);
        let padded_work_elems = hfusion::chunk_padding(&weights, &chunks);
        let distinct_signatures = {
            let sigs: HashSet<_> = items.iter().map(|it| it.plan.signature()).collect();
            sigs.len()
        };
        DivergentPlan {
            total_work_elems: weights.iter().sum(),
            padded_work_elems,
            distinct_signatures,
            items,
            chunks,
        }
    }

    /// The window's items, in window order.
    pub fn items(&self) -> &[DivergentItem] {
        &self.items
    }

    /// Contiguous item ranges, one per worker lane (cover the window
    /// exactly, every lane non-empty).
    pub fn chunks(&self) -> &[Range<usize>] {
        &self.chunks
    }

    /// Worker lanes the launch actually uses (≤ the requested `lanes`).
    pub fn lanes(&self) -> usize {
        self.chunks.len()
    }

    /// Distinct pipeline signatures in the window. `> 1` is what makes the
    /// window divergent — the identical-signature tier cannot serve it.
    pub fn distinct_signatures(&self) -> usize {
        self.distinct_signatures
    }

    /// True when the window mixes signatures (the traffic this tier exists
    /// for; a homogeneous window still executes correctly).
    pub fn is_divergent(&self) -> bool {
        self.distinct_signatures > 1
    }

    /// Total useful elements the launch touches.
    pub fn total_work_elems(&self) -> usize {
        self.total_work_elems
    }

    /// Idle weight of the chunking: every lane runs as long as the
    /// heaviest, lighter lanes idle for the difference — the mixed-shape
    /// analog of HF pad planes.
    pub fn padded_work_elems(&self) -> usize {
        self.padded_work_elems
    }

    /// Useful work over total lane time, 0..=1 (1.0 for an empty window).
    pub fn occupancy(&self) -> f64 {
        occupancy_ratio(self.total_work_elems as u64, self.padded_work_elems as u64)
    }
}

/// The ONE occupancy rule of the divergent tier: useful work over total
/// lane time, 0..=1, with an idle tier reporting 1.0 (nothing ran, nothing
/// was wasted). Shared by [`DivergentPlan::occupancy`],
/// [`crate::exec::DivergentOutcome::occupancy`] and the coordinator's
/// `divergent_occupancy` metric, so the three can never drift.
pub fn occupancy_ratio(work_elems: u64, padded_elems: u64) -> f64 {
    let busy = work_elems + padded_elems;
    if busy == 0 {
        1.0
    } else {
        work_elems as f64 / busy as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chain::{Chain, CvtColor, Mul, F32, U8};
    use crate::ops::{ReduceKind, Signature};
    use crate::tensor::Rect;

    fn mixed_window() -> Vec<Pipeline> {
        vec![
            Chain::read::<U8>(&[8, 8]).map(Mul(2.0)).cast::<F32>().write().into_pipeline(),
            // same signature as the head, different param: divergent-PARAM
            Chain::read::<U8>(&[8, 8]).map(Mul(5.0)).cast::<F32>().write().into_pipeline(),
            Chain::read_resize::<U8>(Rect::new(0, 0, 12, 6), 4, 4)
                .map(CvtColor)
                .cast::<F32>()
                .write_split()
                .into_pipeline(),
            Chain::read_crop::<U8>(Rect::new(1, 1, 5, 5))
                .map(Mul(0.5))
                .reduce(ReduceKind::Mean)
                .into_pipeline(),
        ]
    }

    #[test]
    fn compile_reuses_cached_plans_and_counts_signatures() {
        let window = mixed_window();
        let refs: Vec<&Pipeline> = window.iter().collect();
        let mut cache: std::collections::HashMap<Signature, Rc<HostPlan>> =
            std::collections::HashMap::new();
        let mut compiles = 0usize;
        let plan = DivergentPlan::compile(&refs, 2, |p| {
            cache
                .entry(Signature::of(p))
                .or_insert_with(|| {
                    compiles += 1;
                    Rc::new(HostPlan::compile(p))
                })
                .clone()
        });
        // items 0 and 1 share a signature: 3 compiles serve 4 items
        assert_eq!(compiles, 3);
        assert_eq!(plan.items().len(), 4);
        assert_eq!(plan.distinct_signatures(), 3);
        assert!(plan.is_divergent());
        assert!(Rc::ptr_eq(&plan.items()[0].plan, &plan.items()[1].plan));
    }

    #[test]
    fn chunks_cover_the_window_and_account_padding() {
        let window = mixed_window();
        let refs: Vec<&Pipeline> = window.iter().collect();
        for lanes in 1..=6 {
            let plan = DivergentPlan::compile(&refs, lanes, |p| Rc::new(HostPlan::compile(p)));
            assert!(plan.lanes() <= lanes.min(4));
            let mut covered = 0usize;
            for r in plan.chunks() {
                assert!(!r.is_empty(), "lanes are never empty");
                assert_eq!(r.start, covered, "chunks are contiguous and ordered");
                covered = r.end;
            }
            assert_eq!(covered, 4, "every item lands in exactly one lane");
            let total: usize = refs.iter().map(|p| p.batch * p.item_elems()).sum();
            assert_eq!(plan.total_work_elems(), total);
            assert!(plan.occupancy() > 0.0 && plan.occupancy() <= 1.0);
            if plan.lanes() == 1 {
                assert_eq!(plan.padded_work_elems(), 0, "one lane never idles");
                assert_eq!(plan.occupancy(), 1.0);
            }
        }
    }

    #[test]
    fn homogeneous_windows_are_not_divergent() {
        let p = Chain::read::<F32>(&[4]).map(Mul(2.0)).write().into_pipeline();
        let q = Chain::read::<F32>(&[4]).map(Mul(9.0)).write().into_pipeline();
        let refs = [&p, &q];
        let plan = DivergentPlan::compile(&refs, 2, |p| Rc::new(HostPlan::compile(p)));
        assert_eq!(plan.distinct_signatures(), 1, "params are outside the signature");
        assert!(!plan.is_divergent());
    }

    #[test]
    fn empty_windows_compile_to_nothing() {
        let plan = DivergentPlan::compile(&[], 4, |p| Rc::new(HostPlan::compile(p)));
        assert_eq!(plan.lanes(), 0);
        assert_eq!(plan.total_work_elems(), 0);
        assert_eq!(plan.occupancy(), 1.0);
    }
}
