//! HostPlan — the host-engine analog of [`FusionPlan`](super::FusionPlan).
//!
//! Where `plan_pipeline` maps a pipeline onto pre-lowered AOT artifacts, the
//! host planner "lowers" it directly: once per [`Signature`] it decides the
//! fused loop's shape — the READER kind (dense, crop, crop+resize bilinear
//! gather), element-group width, compute domain (f32 registers for f32-out
//! chains, f64 wherever bit-exactness vs the oracle is promised), whether
//! the body is a dense scalar chain the monomorphized loops can fold without
//! per-element shape dispatch, and the WRITER kind (dense, packed→planar
//! split). Exactly like artifact plans, a `HostPlan` is parameter-AGNOSTIC
//! (the `Signature` cache key ignores params — including crop RECTS, which
//! are runtime parameters exactly like chain params); the concrete op
//! parameters are bound at run time by [`HostPlan::bind_body`] /
//! [`HostPlan::bind_chain`] and the rect by the engine interrogating
//! [`Pipeline::read_pattern`] — the host analog of
//! [`PlanInputs::chain_params`](super::PlanInputs::chain_params) building the
//! params tensor per launch.

use crate::ops::{
    kernel, IOp, Opcode, Pipeline, ReadPattern, ReduceSpec, ScalarOp, Signature, WritePattern,
};
use crate::tensor::DType;

/// Compute domain of the fused single-pass loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HostAccum {
    /// Narrow fast path: intermediates live in f32 registers. Only chosen
    /// when the oracle's f64 result is reproduced within float epsilon
    /// (f32 output, exactly-representable input domain).
    F32,
    /// Oracle-exact path: intermediates in f64, bit-compatible with
    /// [`crate::hostref::run_pipeline`] on every dtype.
    F64,
}

/// Param-agnostic shape of a plan's read end. The crop rect is a RUNTIME
/// parameter (outside the signature); only the pattern KIND shapes the
/// monomorphized loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReaderKind {
    /// Per-thread dense read of `[batch, *shape]`.
    Dense,
    /// ROI gather from a shared packed frame.
    Crop,
    /// Crop + bilinear-resample gather fused at the read (paper Fig. 11).
    CropResize,
}

/// Param-agnostic shape of a plan's write end.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WriterKind {
    /// Per-thread dense write of `[batch, *shape]`.
    Dense,
    /// Packed `[h, w, 3]` pixels scattered planar `[3, h, w]` while writing.
    Split,
    /// No per-element write: statistics accumulate while reading and only
    /// the finalized f64 result lands (the fold-while-reading tier; the
    /// spec itself is recorded in [`HostPlan::reduce`]).
    Reduce,
}

/// A compiled host execution plan: one fused memory pass over the data.
#[derive(Debug, Clone)]
pub struct HostPlan {
    sig: Signature,
    group: usize,
    accum: HostAccum,
    is_chain: bool,
    reader: ReaderKind,
    writer: WriterKind,
    reduce: Option<ReduceSpec>,
    vectorization: u8,
    dtin: DType,
    dtout: DType,
    batch: usize,
    item_elems: usize,
    /// Bytes one run of this plan actually writes: the out-shape surface
    /// for dense/split writers, only the statistics for the reduce tier.
    bytes_written: usize,
    /// Bytes the op-at-a-time baseline would materialize for one run
    /// ([`Pipeline::baseline_bytes`], static from the IR) — the numerator
    /// of the fusion-efficiency ratio.
    bytes_baseline: usize,
}

impl HostPlan {
    /// Lower a validated pipeline's shape. Never fails: the host backend
    /// covers the whole element-wise vocabulary INCLUDING the structured
    /// boundary patterns (that is its point — it is the engine that runs
    /// everywhere). Unservable geometry (e.g. a split write on a shape that
    /// is not `[h, w, 3]`) is refused loudly by the engine at run time.
    pub fn compile(p: &Pipeline) -> HostPlan {
        let body = ScalarOp::lower_body(p.body())
            .expect("validated pipeline has no interior memops");
        let group = kernel::group_width(&body);
        let reader = match p.read_pattern() {
            ReadPattern::Dense => ReaderKind::Dense,
            ReadPattern::Crop { .. } => ReaderKind::Crop,
            ReadPattern::CropResize { .. } => ReaderKind::CropResize,
        };
        let writer = match p.write_pattern() {
            WritePattern::Dense => WriterKind::Dense,
            WritePattern::Split => WriterKind::Split,
            WritePattern::Reduce { .. } => WriterKind::Reduce,
        };
        let dense = reader == ReaderKind::Dense && writer == WriterKind::Dense;
        let is_chain =
            dense && p.body().iter().all(|op| matches!(op, IOp::Compute { .. }));
        // structured passes always fold in f64: the gather itself is f64,
        // and bit-compatibility with the structured oracle is the contract.
        // (Reductions always land here too: their dtout is f64 by
        // construction, so the narrow accumulator is never selected.)
        let accum = if p.dtout == DType::F32
            && matches!(p.dtin, DType::U8 | DType::U16 | DType::F32)
            && is_chain
        {
            HostAccum::F32
        } else {
            HostAccum::F64
        };
        // register-block width of the fused inner loop (burn-jit style
        // `vectorization: u8`): the reduce tier stripes REDUCE_LANES
        // sub-accumulators per block; the f32 fast arm blocks 16 f32 lanes;
        // every f64 arm (dense, lane-group, structured gather) blocks 8.
        // A property of the SIGNATURE — recorded on the plan so stats,
        // lints and the tier predictor report the same width the loops run.
        let vectorization = if writer == WriterKind::Reduce {
            kernel::REDUCE_LANES as u8
        } else if accum == HostAccum::F32 {
            kernel::LANE_WIDTH_F32 as u8
        } else {
            kernel::LANE_WIDTH_F64 as u8
        };
        let bytes_written = match p.reduction() {
            Some(spec) => spec.out_len() * p.dtout.size_bytes(),
            None => p.batch * p.item_elems() * p.dtout.size_bytes(),
        };
        HostPlan {
            sig: Signature::of(p),
            group,
            accum,
            is_chain,
            reader,
            writer,
            reduce: p.reduction(),
            vectorization,
            dtin: p.dtin,
            dtout: p.dtout,
            batch: p.batch,
            item_elems: p.item_elems(),
            bytes_written,
            bytes_baseline: p.baseline_bytes(),
        }
    }

    /// Bind this run's parameters: the full lowered body, general path.
    pub fn bind_body(&self, p: &Pipeline) -> Vec<ScalarOp> {
        debug_assert_eq!(Signature::of(p), self.sig, "plan bound to a foreign pipeline");
        ScalarOp::lower_body(p.body()).expect("validated pipeline has no interior memops")
    }

    /// Bind this run's parameters as a dense scalar chain (fast path);
    /// `None` when the body is not all-scalar or a boundary is structured.
    pub fn bind_chain(&self, p: &Pipeline) -> Option<Vec<(Opcode, f64)>> {
        if !self.is_chain {
            return None;
        }
        debug_assert_eq!(Signature::of(p), self.sig, "plan bound to a foreign pipeline");
        p.body()
            .iter()
            .map(|op| match op {
                IOp::Compute { op, param } => Some((*op, *param)),
                _ => None,
            })
            .collect()
    }

    pub fn signature(&self) -> &Signature {
        &self.sig
    }

    /// Element-group width (3 when lane-structured ops are present).
    pub fn group(&self) -> usize {
        self.group
    }

    pub fn accum(&self) -> HostAccum {
        self.accum
    }

    /// True if the body is a dense all-scalar chain.
    pub fn is_chain(&self) -> bool {
        self.is_chain
    }

    /// The plan's read-end kind.
    pub fn reader(&self) -> ReaderKind {
        self.reader
    }

    /// The plan's write-end kind.
    pub fn writer(&self) -> WriterKind {
        self.writer
    }

    /// The reduce terminator this plan folds, if any (the fold-while-reading
    /// tier; kinds and axis are code shape, recorded per signature — there
    /// are no runtime reduce params to bind).
    pub fn reduce(&self) -> Option<ReduceSpec> {
        self.reduce
    }

    /// Register-block width of the fused inner loop: how many elements one
    /// iteration stages through the op chain (reduce plans: how many striped
    /// sub-accumulators fold per block). `1` never occurs in a compiled
    /// plan — the scalar arm exists only as the engine-level width override
    /// used by the ablation benches and the differential fuzz harness.
    pub fn vectorization(&self) -> u8 {
        self.vectorization
    }

    /// True when both boundaries are dense (the pre-structured loop shapes).
    pub fn is_dense(&self) -> bool {
        self.reader == ReaderKind::Dense && self.writer == WriterKind::Dense
    }

    pub fn dtin(&self) -> DType {
        self.dtin
    }

    pub fn dtout(&self) -> DType {
        self.dtout
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn item_elems(&self) -> usize {
        self.item_elems
    }

    /// Total elements one run touches.
    pub fn total_elems(&self) -> usize {
        self.batch * self.item_elems
    }

    /// Bytes one fused pass moves (read + write) — the host analog of
    /// [`Pipeline::fused_bytes`].
    pub fn fused_bytes(&self) -> usize {
        self.total_elems() * (self.dtin.size_bytes() + self.dtout.size_bytes())
    }

    /// Bytes one run reads. Structured gathers (crop / crop+resize) are
    /// counted at the logical post-gather element stream — the same
    /// convention `fused_bytes` uses — so the ratio against the op-at-a-time
    /// baseline compares like with like.
    pub fn bytes_read(&self) -> usize {
        self.total_elems() * self.dtin.size_bytes()
    }

    /// Bytes one run writes: the out surface for dense/split writers, only
    /// the finalized statistics for the reduce tier.
    pub fn bytes_written(&self) -> usize {
        self.bytes_written
    }

    /// Bytes an op-at-a-time execution of the same pipeline would move
    /// ([`Pipeline::baseline_bytes`], captured at compile). The
    /// fusion-efficiency ratio is `bytes_baseline / (bytes_read +
    /// bytes_written)` — ≈(k+1)/2 for a same-width dense chain of k ops.
    pub fn bytes_baseline(&self) -> usize {
        self.bytes_baseline
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{Opcode, Pipeline};
    use crate::tensor::{DType, Rect};

    fn chain_pipe(dtin: DType, dtout: DType) -> Pipeline {
        Pipeline::from_opcodes(
            &[(Opcode::Mul, 2.0), (Opcode::Add, 1.0)],
            &[4, 4],
            3,
            dtin,
            dtout,
        )
        .unwrap()
    }

    #[test]
    fn f32_out_chains_use_narrow_accum() {
        for dtin in [DType::U8, DType::U16, DType::F32] {
            let plan = HostPlan::compile(&chain_pipe(dtin, DType::F32));
            assert_eq!(plan.accum(), HostAccum::F32, "{dtin}");
            assert!(plan.is_chain());
            assert!(plan.is_dense());
            assert_eq!(plan.group(), 1);
        }
    }

    #[test]
    fn exactness_paths_use_f64_accum() {
        // integer outputs must be bit-compatible with the oracle; f64 and
        // i32 inputs exceed f32's exact range
        for (dtin, dtout) in [
            (DType::U8, DType::U8),
            (DType::F32, DType::U16),
            (DType::F64, DType::F32),
            (DType::I32, DType::F32),
            (DType::F64, DType::F64),
        ] {
            let plan = HostPlan::compile(&chain_pipe(dtin, dtout));
            assert_eq!(plan.accum(), HostAccum::F64, "{dtin}->{dtout}");
        }
    }

    #[test]
    fn binding_rebinds_fresh_params_per_run() {
        // same signature, different params: one cached plan must serve both
        let a = chain_pipe(DType::F32, DType::F32);
        let b = Pipeline::from_opcodes(
            &[(Opcode::Mul, 9.0), (Opcode::Add, -4.0)],
            &[4, 4],
            3,
            DType::F32,
            DType::F32,
        )
        .unwrap();
        let plan = HostPlan::compile(&a);
        assert_eq!(Signature::of(&b), *plan.signature());
        assert_eq!(plan.bind_chain(&a).unwrap(), vec![(Opcode::Mul, 2.0), (Opcode::Add, 1.0)]);
        assert_eq!(plan.bind_chain(&b).unwrap(), vec![(Opcode::Mul, 9.0), (Opcode::Add, -4.0)]);
    }

    #[test]
    fn lane_structured_bodies_disable_chain_fast_path() {
        let p = crate::chain::Chain::read::<crate::chain::F32>(&[2, 3])
            .map(crate::chain::Mul(2.0))
            .map(crate::chain::AddC3([1.0, 2.0, 3.0]))
            .write()
            .into_pipeline();
        let plan = HostPlan::compile(&p);
        assert!(!plan.is_chain());
        assert!(plan.bind_chain(&p).is_none());
        assert_eq!(plan.bind_body(&p).len(), 2);
        assert_eq!(plan.group(), 3);
        assert_eq!(plan.accum(), HostAccum::F64, "group path stays oracle-exact");
    }

    #[test]
    fn structured_boundaries_plan_as_reader_writer_kinds() {
        // the preproc shape: resize-read front, split-write back — planned,
        // not refused; rects stay OUT of the plan (runtime params)
        let p = crate::chain::Chain::read_resize::<crate::chain::U8>(Rect::new(2, 3, 20, 10), 8, 4)
            .map(crate::chain::CvtColor)
            .map(crate::chain::MulC3([0.5, 0.4, 0.3]))
            .cast::<crate::chain::F32>()
            .write_split();
        let plan = HostPlan::compile(p.pipeline());
        assert_eq!(plan.reader(), ReaderKind::CropResize);
        assert_eq!(plan.writer(), WriterKind::Split);
        assert!(!plan.is_dense());
        assert!(!plan.is_chain(), "structured passes take the pixel loop");
        assert!(plan.bind_chain(p.pipeline()).is_none());
        assert_eq!(plan.accum(), HostAccum::F64, "gathers fold in f64");

        // a crop read with a DIFFERENT rect shares the same cached plan:
        // rects are bound per run, exactly like chain params
        let a = crate::chain::Chain::read_crop::<crate::chain::U8>(Rect::new(0, 0, 4, 4))
            .map(crate::chain::Mul(2.0))
            .write();
        let b = crate::chain::Chain::read_crop::<crate::chain::U8>(Rect::new(9, 7, 4, 4))
            .map(crate::chain::Mul(3.0))
            .write();
        assert_eq!(a.signature(), b.signature());
        let plan = HostPlan::compile(a.pipeline());
        assert_eq!(plan.reader(), ReaderKind::Crop);
        assert_eq!(plan.writer(), WriterKind::Dense);
        assert_eq!(*plan.signature(), b.signature());
    }

    #[test]
    fn reduce_terminators_plan_as_the_fold_tier() {
        use crate::ops::{ReduceAxis, ReduceKind};
        let p = crate::chain::Chain::read::<crate::chain::U8>(&[4, 4, 3])
            .batch(2)
            .map(crate::chain::Mul(0.5))
            .reduce_per_channel(ReduceKind::Mean)
            .into_pipeline();
        let plan = HostPlan::compile(&p);
        assert_eq!(plan.writer(), WriterKind::Reduce);
        let spec = plan.reduce().expect("reduce plans record their spec");
        assert_eq!((spec.kind, spec.axis), (ReduceKind::Mean, ReduceAxis::PerChannel));
        assert!(!plan.is_dense(), "reduce runs never take the flat write loops");
        assert_eq!(plan.accum(), HostAccum::F64, "statistics accumulate wide");
        // same signature, one plan — reduce pipelines cache like any other
        let q = crate::chain::Chain::read::<crate::chain::U8>(&[4, 4, 3])
            .batch(2)
            .map(crate::chain::Mul(9.0))
            .reduce_per_channel(ReduceKind::Mean)
            .into_pipeline();
        assert_eq!(Signature::of(&q), *plan.signature());
    }

    #[test]
    fn vectorization_width_follows_the_accum_and_tier_rule() {
        use crate::chain::{AddC3, Chain, Mul, F32, U8};
        use crate::ops::ReduceKind;
        // f32 fast arm: 16 f32 lanes per block
        let narrow = HostPlan::compile(&chain_pipe(DType::U8, DType::F32));
        assert_eq!(narrow.vectorization(), kernel::LANE_WIDTH_F32 as u8);
        // every f64 arm blocks 8 — dense chains and lane-group bodies alike
        let wide = HostPlan::compile(&chain_pipe(DType::F64, DType::F64));
        assert_eq!(wide.vectorization(), kernel::LANE_WIDTH_F64 as u8);
        let grouped =
            Chain::read::<F32>(&[2, 3]).map(AddC3([1.0, 2.0, 3.0])).write().into_pipeline();
        assert_eq!(HostPlan::compile(&grouped).vectorization(), kernel::LANE_WIDTH_F64 as u8);
        // structured gathers fold in f64 blocks too
        let structured = Chain::read_crop::<U8>(Rect::new(0, 0, 4, 4)).map(Mul(2.0)).write();
        assert_eq!(
            HostPlan::compile(structured.pipeline()).vectorization(),
            kernel::LANE_WIDTH_F64 as u8
        );
        // the reduce tier's width is its stripe count
        let reduce = Chain::read::<U8>(&[4, 4, 3])
            .map(Mul(0.5))
            .reduce_per_channel(ReduceKind::Mean)
            .into_pipeline();
        assert_eq!(HostPlan::compile(&reduce).vectorization(), kernel::REDUCE_LANES as u8);
    }

    #[test]
    fn geometry_is_recorded() {
        let plan = HostPlan::compile(&chain_pipe(DType::U8, DType::F32));
        assert_eq!(plan.batch(), 3);
        assert_eq!(plan.item_elems(), 16);
        assert_eq!(plan.total_elems(), 48);
        assert_eq!(plan.fused_bytes(), 48 * (1 + 4));
    }

    #[test]
    fn byte_accounting_matches_the_ir_model() {
        // dense chain-2 u8→f32: read n·1, write n·4, baseline n·(1+4+4)
        let plan = HostPlan::compile(&chain_pipe(DType::U8, DType::F32));
        assert_eq!(plan.bytes_read(), 48);
        assert_eq!(plan.bytes_written(), 48 * 4);
        assert_eq!(plan.bytes_baseline(), 48 * (1 + 4 + 4));
        // fused moves 5n vs baseline 9n: chain-2 mixed-width efficiency
        assert_eq!(plan.bytes_baseline(), plan.bytes_read() + plan.bytes_written() + 48 * 4);

        // reduce tier: only the statistics land
        use crate::ops::ReduceKind;
        let p = crate::chain::Chain::read::<crate::chain::U8>(&[4, 4, 3])
            .batch(2)
            .map(crate::chain::Mul(0.5))
            .reduce_per_channel(ReduceKind::Mean)
            .into_pipeline();
        let plan = HostPlan::compile(&p);
        let spec = plan.reduce().unwrap();
        assert_eq!(plan.bytes_written(), spec.out_len() * 8);
        assert!(plan.bytes_written() < plan.bytes_read(), "stats, not a surface");
        assert_eq!(plan.bytes_baseline(), p.baseline_bytes());
    }
}
