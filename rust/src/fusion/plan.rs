//! Fusion plans: the executable form of a pipeline.

use crate::ops::{IOp, Pipeline};
use crate::tensor::Tensor;

/// How a pipeline will execute. Produced by [`super::plan_pipeline`].
#[derive(Debug, Clone, PartialEq)]
pub enum FusionPlan {
    /// One launch of an exact fused-chain artifact (tier 1).
    Exact { artifact: String },
    /// One launch of a StaticLoop artifact with a runtime trip count (tier 2).
    StaticLoop { artifact: String, iters: usize },
    /// One launch of the interpreter artifact; opcode/param tensors are
    /// derived from the pipeline at RUN time (plans are cached under a
    /// params-agnostic signature, so they must not embed parameter values).
    Interp { artifact: String, kmax: usize },
    /// No fused artifact covers this pipeline: one launch per op (the
    /// baseline path; also what the unfused engine uses on purpose).
    Unfused { artifacts: Vec<String> },
}

impl FusionPlan {
    /// Number of kernel launches this plan issues.
    pub fn launches(&self) -> usize {
        match self {
            FusionPlan::Unfused { artifacts } => artifacts.len(),
            _ => 1,
        }
    }

    /// True if the plan keeps all intermediates in registers (fused tiers).
    pub fn is_fused(&self) -> bool {
        !matches!(self, FusionPlan::Unfused { .. })
    }

    pub fn tier(&self) -> &'static str {
        match self {
            FusionPlan::Exact { .. } => "exact",
            FusionPlan::StaticLoop { .. } => "staticloop",
            FusionPlan::Interp { .. } => "interp",
            FusionPlan::Unfused { .. } => "unfused",
        }
    }
}

/// Runtime input tensors for a plan, in artifact argument order.
pub struct PlanInputs;

impl PlanInputs {
    /// Parameter vector f32[K] for a chain artifact (param per body op;
    /// unary ops contribute their slot as 0).
    pub fn chain_params(p: &Pipeline) -> Tensor {
        let v: Vec<f32> = p
            .body()
            .iter()
            .map(|op| match op {
                IOp::Compute { param, .. } => *param as f32,
                _ => 0.0,
            })
            .collect();
        let k = v.len();
        Tensor::from_f32(&v, &[k])
    }

    /// StaticLoop inputs: (trip, params-of-one-iteration).
    pub fn staticloop_inputs(p: &Pipeline, body_len: usize, iters: usize) -> (Tensor, Tensor) {
        let pattern = &p.body()[..body_len];
        let v: Vec<f32> = pattern
            .iter()
            .map(|op| match op {
                IOp::Compute { param, .. } => *param as f32,
                _ => 0.0,
            })
            .collect();
        (Tensor::from_i32(&[iters as i32], &[1]), Tensor::from_f32(&v, &[body_len]))
    }

    /// Interp inputs: (opcodes i32[kmax], params f32[kmax]), nop-padded.
    pub fn interp_inputs(p: &Pipeline, kmax: usize) -> (Tensor, Tensor) {
        let mut opc = vec![0i32; kmax];
        let mut par = vec![0f32; kmax];
        for (i, op) in p.body().iter().enumerate() {
            if let IOp::Compute { op, param } = op {
                opc[i] = op.code();
                par[i] = *param as f32;
            }
        }
        (Tensor::from_i32(&opc, &[kmax]), Tensor::from_f32(&par, &[kmax]))
    }
}
