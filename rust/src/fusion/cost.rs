//! Roofline cost model: memory-bound vs compute-bound classification.
//!
//! The paper's Fig. 1 measures the defining behaviour: a kernel's time is
//! flat in instruction count while memory-bound (latency hiding absorbs the
//! ALU work), then grows linearly once compute-bound. This model captures
//! that with a smooth-max roofline and is shared by the planner (is fusion
//! worth it?), the experiments (predicted-vs-measured) and the GPU simulator
//! (which adds launch overhead and spill effects on top).

/// Hardware profile for the cost model. `effective_*` values are measured on
/// this host by `bench::calibrate` (defaults are rough CPU numbers).
#[derive(Debug, Clone, Copy)]
pub struct HwProfile {
    /// Sustained memory bandwidth, bytes/sec.
    pub mem_bw: f64,
    /// Sustained element-op throughput, simple ops/sec (all cores).
    pub flops: f64,
    /// Fixed cost of one kernel launch/dispatch, seconds.
    pub launch_overhead: f64,
}

impl Default for HwProfile {
    fn default() -> Self {
        // conservative single-socket CPU defaults; calibrate() refines
        HwProfile { mem_bw: 20e9, flops: 30e9, launch_overhead: 30e-6 }
    }
}

/// Estimated execution time of ONE kernel moving `bytes` and executing
/// `elems * instrs_per_elem` simple ops.
pub fn kernel_time(hw: &HwProfile, bytes: f64, elems: f64, instrs_per_elem: f64) -> f64 {
    let mem_t = bytes / hw.mem_bw;
    let cmp_t = elems * instrs_per_elem / hw.flops;
    // latency hiding: mem and compute overlap; total is max, softened so the
    // MB->CB knee is smooth like the measured Fig. 1 curve
    let m = mem_t.max(cmp_t);
    let s = mem_t.min(cmp_t);
    hw.launch_overhead + m + 0.08 * s
}

/// A kernel is memory-bound if the memory term dominates.
pub fn is_memory_bound(hw: &HwProfile, bytes: f64, elems: f64, instrs_per_elem: f64) -> bool {
    bytes / hw.mem_bw >= elems * instrs_per_elem / hw.flops
}

/// Instructions/element at which the kernel transitions MB -> CB given its
/// bytes-per-element traffic (the paper's ~260 float adds on an RTX 4090).
pub fn cb_knee(hw: &HwProfile, bytes_per_elem: f64) -> f64 {
    bytes_per_elem / hw.mem_bw * hw.flops
}

/// Predicted time of a FUSED chain: one kernel, all body instructions.
pub fn fused_time(hw: &HwProfile, elems: f64, io_bytes: f64, total_instrs: f64) -> f64 {
    kernel_time(hw, io_bytes, elems, total_instrs)
}

/// Predicted time of the UNFUSED chain: one kernel per op, each doing a full
/// read+write pass (paper Fig. 3A).
pub fn unfused_time(
    hw: &HwProfile,
    elems: f64,
    per_kernel_bytes: f64,
    instrs_each: &[f64],
) -> f64 {
    instrs_each.iter().map(|&i| kernel_time(hw, per_kernel_bytes, elems, i)).sum()
}

/// Predicted VF speedup for a chain of `n_ops` 1-instruction ops.
pub fn vf_speedup(hw: &HwProfile, elems: f64, bytes_per_elem: f64, n_ops: usize) -> f64 {
    let io = elems * bytes_per_elem;
    let fused = fused_time(hw, elems, io, n_ops as f64);
    let unfused = unfused_time(hw, elems, io, &vec![1.0; n_ops]);
    unfused / fused
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hw() -> HwProfile {
        HwProfile { mem_bw: 100e9, flops: 1000e9, launch_overhead: 10e-6 }
    }

    #[test]
    fn mb_kernels_are_flat_in_instructions() {
        let h = hw();
        let elems = 1e7;
        let bytes = elems * 8.0;
        let t1 = kernel_time(&h, bytes, elems, 1.0);
        let t2 = kernel_time(&h, bytes, elems, 4.0);
        // still MB: time changes only through the overlap softening term
        assert!((t2 - t1) / t1 < 0.05, "t1={t1} t2={t2}");
    }

    #[test]
    fn cb_kernels_grow_linearly() {
        let h = hw();
        let elems = 1e7;
        let bytes = elems * 8.0;
        let knee = cb_knee(&h, 8.0);
        let t1 = kernel_time(&h, bytes, elems, knee * 4.0);
        let t2 = kernel_time(&h, bytes, elems, knee * 8.0);
        assert!(t2 / t1 > 1.8, "expected ~2x: {}", t2 / t1);
    }

    #[test]
    fn knee_matches_flopb_ratio() {
        // paper: FLOP/B 68.97 on the 4090, 8 bytes/elem r+w for f32
        // knee ~= 8 * FLOP_per_byte in 1-instr units
        let h = HwProfile { mem_bw: 1008e9, flops: 82.58e12 / 2.0, launch_overhead: 5e-6 };
        let k = cb_knee(&h, 8.0);
        assert!(k > 200.0 && k < 500.0, "knee {k} should be a few hundred like Fig. 1");
    }

    #[test]
    fn vf_speedup_monotone_then_saturating() {
        let h = hw();
        let s2 = vf_speedup(&h, 1e7, 8.0, 2);
        let s64 = vf_speedup(&h, 1e7, 8.0, 64);
        let s4096 = vf_speedup(&h, 1e7, 8.0, 4096);
        let s8192 = vf_speedup(&h, 1e7, 8.0, 8192);
        assert!(s2 > 1.5 && s64 > s2, "s2={s2} s64={s64}");
        // saturation: doubling ops no longer doubles speedup
        assert!(s8192 / s4096 < 1.3, "saturating: {s4096} -> {s8192}");
    }

    #[test]
    fn single_op_speedup_is_one() {
        let s = vf_speedup(&hw(), 1e7, 8.0, 1);
        assert!((s - 1.0).abs() < 1e-9);
    }
}
