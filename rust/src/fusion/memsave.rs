//! GPU-memory savings accounting (paper §VI-L).
//!
//! The fused pipeline only allocates its input and output; the unfused
//! baseline needs intermediate device buffers between kernels (OpenCV's
//! `crop_32F`, `d_up`, `d_temp` ping-pong pair in Fig. 25a). This module
//! computes both footprints so experiments report the saving, including the
//! paper's 4k/8k projections.

use crate::ops::Pipeline;

/// Memory footprint report for one pipeline execution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemReport {
    pub input_bytes: usize,
    pub output_bytes: usize,
    /// Intermediates the unfused execution allocates (fused: zero).
    pub intermediate_bytes: usize,
}

impl MemReport {
    pub fn fused_total(&self) -> usize {
        self.input_bytes + self.output_bytes
    }

    pub fn unfused_total(&self) -> usize {
        self.fused_total() + self.intermediate_bytes
    }

    pub fn saved(&self) -> usize {
        self.intermediate_bytes
    }
}

/// Accounting for an element-wise chain pipeline.
pub fn report(p: &Pipeline) -> MemReport {
    let n = p.batch * p.item_elems();
    MemReport {
        input_bytes: n * p.dtin.size_bytes(),
        output_bytes: n * p.dtout.size_bytes(),
        intermediate_bytes: p.intermediate_bytes(),
    }
}

/// Accounting for the preprocessing pipeline (paper Fig. 25): per crop, the
/// unfused baseline allocates crop_32F (src f32), d_up and d_temp (dst f32)
/// — exactly the orange variables in the figure.
pub fn preproc_report(batch: usize, src_h: usize, src_w: usize, dh: usize, dw: usize) -> MemReport {
    let in_b = batch * src_h * src_w * 3; // u8 crops
    let out_b = batch * 3 * dh * dw * 4; // planar f32
    let crop32f = src_h * src_w * 3 * 4;
    let d_up = dh * dw * 3 * 4;
    let d_temp = dh * dw * 3 * 4;
    MemReport {
        input_bytes: in_b,
        output_bytes: out_b,
        // OpenCV reuses the scratch trio across the loop, so the saving is
        // per-pipeline, not per-crop (conservative, matches the paper's 259KB)
        intermediate_bytes: crop32f + d_up + d_temp,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{Opcode, Pipeline};
    use crate::tensor::DType;

    #[test]
    fn paper_259kb_figure() {
        // paper §VI-L: 60x120 crops, float3 pixels -> ~259 KB saved
        let r = preproc_report(50, 60, 120, 128, 64);
        let kb = r.saved() as f64 / 1024.0;
        assert!((kb - 276.5).abs() < 60.0, "saved {kb} KB; paper reports 259 KB-class savings");
    }

    #[test]
    fn fused_chain_saves_intermediates() {
        let p = Pipeline::from_opcodes(
            &[(Opcode::Mul, 1.0), (Opcode::Add, 2.0), (Opcode::Div, 3.0)],
            &[1000],
            4,
            DType::U8,
            DType::F32,
        )
        .unwrap();
        let r = report(&p);
        assert_eq!(r.input_bytes, 4000);
        assert_eq!(r.output_bytes, 16000);
        assert!(r.saved() > 0);
        assert_eq!(r.unfused_total() - r.fused_total(), r.saved());
    }
}
