//! Horizontal Fusion planning: packing independent requests into batch
//! buckets (paper §IV-B BatchRead/BatchWrite, Fig. 12).
//!
//! Batched artifacts exist at discrete batch widths (the manifest's
//! `hf_batches` geometry). m pending requests are served by a minimal
//! sequence of bucket launches; a final partial bucket is padded — the paper
//! does the same ("we still need to set the values in the non-used thread.z
//! positions to a default value") and the pad cost is accounted explicitly.

/// One HF launch: a bucket width and how many of its planes carry real work.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BucketLaunch {
    pub bucket: usize,
    pub used: usize,
}

impl BucketLaunch {
    pub fn padding(&self) -> usize {
        self.bucket - self.used
    }
}

/// Pack `m` requests into bucket launches.
///
/// Greedy: repeatedly take the largest bucket <= remaining; the tail uses the
/// smallest bucket >= remaining (padding). Guarantees every request is
/// assigned exactly once and padding only occurs on the final launch.
pub fn pack(m: usize, buckets: &[usize]) -> Vec<BucketLaunch> {
    assert!(!buckets.is_empty(), "no HF buckets available");
    let mut sorted: Vec<usize> = buckets.to_vec();
    sorted.sort_unstable();
    sorted.dedup();
    let mut launches = Vec::new();
    let mut left = m;
    while left > 0 {
        if let Some(&b) = sorted.iter().rev().find(|&&b| b <= left) {
            // largest bucket that fits entirely
            launches.push(BucketLaunch { bucket: b, used: b });
            left -= b;
        } else {
            // smallest bucket that covers the tail (padded)
            let b = *sorted.iter().find(|&&b| b >= left).unwrap();
            launches.push(BucketLaunch { bucket: b, used: left });
            left = 0;
        }
    }
    launches
}

/// Total padded planes of a packing (the HF overhead metric).
pub fn total_padding(launches: &[BucketLaunch]) -> usize {
    launches.iter().map(BucketLaunch::padding).sum()
}

/// Pick a single bucket for a whole batch (coordinator fast path: one launch,
/// possibly padded). Returns None if m exceeds the largest bucket.
pub fn single_bucket(m: usize, buckets: &[usize]) -> Option<usize> {
    buckets.iter().copied().filter(|&b| b >= m).min()
}

/// [`pack`] generalized to MIXED-SHAPE items (the divergent-HF tier): chunk
/// a window of weighted items into at most `lanes` contiguous ranges of
/// near-equal total weight. Where identical-signature HF's unit is one
/// batch plane and its bucket a batch width, the divergent unit is one item
/// weighted by its element count and the bucket is a worker LANE. Every
/// item lands in exactly one range; ranges are non-empty and cover `0..n`
/// in order, so the chunking never reorders or drops work.
pub fn chunk_weighted(weights: &[usize], lanes: usize) -> Vec<std::ops::Range<usize>> {
    let n = weights.len();
    if n == 0 {
        return Vec::new();
    }
    let lanes = lanes.clamp(1, n);
    let total: usize = weights.iter().sum();
    let mut out: Vec<std::ops::Range<usize>> = Vec::with_capacity(lanes);
    let (mut start, mut acc, mut done) = (0usize, 0usize, 0usize);
    for (i, &w) in weights.iter().enumerate() {
        acc += w;
        let open = lanes - out.len(); // lanes still to emit, this one included
        if open == 1 {
            break; // everything left belongs to the final lane
        }
        // close at the fair share of the REMAINING weight, or when the tail
        // must keep one item per remaining lane
        let target = (total - done).div_ceil(open);
        if acc >= target || n - i - 1 == open - 1 {
            out.push(start..i + 1);
            done += acc;
            start = i + 1;
            acc = 0;
        }
    }
    out.push(start..n);
    out
}

/// Idle weight of a weighted chunking — the mixed-shape analog of
/// [`total_padding`]: every lane runs as long as the heaviest, so lighter
/// lanes idle for the difference. This is the divergent tier's pad
/// accounting, surfaced as occupancy in coordinator metrics.
pub fn chunk_padding(weights: &[usize], chunks: &[std::ops::Range<usize>]) -> usize {
    let lane: Vec<usize> =
        chunks.iter().map(|r| weights[r.start..r.end].iter().sum()).collect();
    let max = lane.iter().copied().max().unwrap_or(0);
    lane.iter().map(|&w| max - w).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    const BUCKETS: &[usize] = &[1, 2, 4, 8, 16, 25, 50];

    #[test]
    fn exact_fit_has_no_padding() {
        let l = pack(50, BUCKETS);
        assert_eq!(l, vec![BucketLaunch { bucket: 50, used: 50 }]);
        assert_eq!(total_padding(&l), 0);
    }

    #[test]
    fn greedy_packs_large_then_tail() {
        let l = pack(77, BUCKETS);
        let assigned: usize = l.iter().map(|b| b.used).sum();
        assert_eq!(assigned, 77);
        assert_eq!(l[0], BucketLaunch { bucket: 50, used: 50 });
        // tail 27 -> bucket 50 padded? no: largest <= 27 is 25, then 2
        assert_eq!(l[1], BucketLaunch { bucket: 25, used: 25 });
        assert_eq!(l[2], BucketLaunch { bucket: 2, used: 2 });
        assert_eq!(total_padding(&l), 0);
    }

    #[test]
    fn tail_padding_is_minimal_bucket() {
        let l = pack(3, BUCKETS);
        let assigned: usize = l.iter().map(|b| b.used).sum();
        assert_eq!(assigned, 3);
        // 2 fits, then 1 fits: no padding at all with bucket 1 present
        assert_eq!(total_padding(&l), 0);
        // without bucket 1: 3 -> [2, 4(pad 3... no: largest<=1 none -> smallest>=1 is 2, used 1)]
        let l2 = pack(3, &[2, 4, 8]);
        let assigned2: usize = l2.iter().map(|b| b.used).sum();
        assert_eq!(assigned2, 3);
        assert_eq!(total_padding(&l2), 1);
    }

    #[test]
    fn every_m_is_covered_exactly() {
        for m in 1..=200 {
            let l = pack(m, BUCKETS);
            assert_eq!(l.iter().map(|b| b.used).sum::<usize>(), m, "m={m}");
            // padding only on final launch
            for b in &l[..l.len() - 1] {
                assert_eq!(b.padding(), 0, "m={m}");
            }
        }
    }

    #[test]
    fn single_bucket_selection() {
        assert_eq!(single_bucket(3, BUCKETS), Some(4));
        assert_eq!(single_bucket(50, BUCKETS), Some(50));
        assert_eq!(single_bucket(51, BUCKETS), None);
    }

    #[test]
    fn weighted_chunks_cover_exactly_and_balance() {
        let weights = [5usize, 1, 1, 7, 2, 2, 2, 4];
        for lanes in 1..=10 {
            let chunks = chunk_weighted(&weights, lanes);
            assert!(!chunks.is_empty() && chunks.len() <= lanes.min(weights.len()));
            let mut covered = 0usize;
            for r in &chunks {
                assert!(!r.is_empty(), "lanes={lanes}: empty lane");
                assert_eq!(r.start, covered, "lanes={lanes}: gap or overlap");
                covered = r.end;
            }
            assert_eq!(covered, weights.len(), "lanes={lanes}: items lost");
        }
        // an even split exists and the chunking finds it: padding 0
        let chunks = chunk_weighted(&[3, 3, 3, 3], 2);
        assert_eq!(chunks, vec![0..2, 2..4]);
        assert_eq!(chunk_padding(&[3, 3, 3, 3], &chunks), 0);
    }

    #[test]
    fn weighted_padding_is_idle_lane_weight() {
        // lanes [5] and [1, 1]: the light lane idles for 3
        let weights = [5usize, 1, 1];
        let chunks = chunk_weighted(&weights, 2);
        assert_eq!(chunks, vec![0..1, 1..3]);
        assert_eq!(chunk_padding(&weights, &chunks), 3);
        // degenerate shapes
        assert_eq!(chunk_weighted(&[], 4), Vec::<std::ops::Range<usize>>::new());
        assert_eq!(chunk_padding(&[], &[]), 0);
        assert_eq!(chunk_weighted(&[9], 4), vec![0..1]);
        // one heavy head: the tail still gets one item per lane
        let chunks = chunk_weighted(&[100, 1, 1, 1], 4);
        assert_eq!(chunks.len(), 4);
    }
}
