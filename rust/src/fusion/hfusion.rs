//! Horizontal Fusion planning: packing independent requests into batch
//! buckets (paper §IV-B BatchRead/BatchWrite, Fig. 12).
//!
//! Batched artifacts exist at discrete batch widths (the manifest's
//! `hf_batches` geometry). m pending requests are served by a minimal
//! sequence of bucket launches; a final partial bucket is padded — the paper
//! does the same ("we still need to set the values in the non-used thread.z
//! positions to a default value") and the pad cost is accounted explicitly.

/// One HF launch: a bucket width and how many of its planes carry real work.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BucketLaunch {
    pub bucket: usize,
    pub used: usize,
}

impl BucketLaunch {
    pub fn padding(&self) -> usize {
        self.bucket - self.used
    }
}

/// Pack `m` requests into bucket launches.
///
/// Greedy: repeatedly take the largest bucket <= remaining; the tail uses the
/// smallest bucket >= remaining (padding). Guarantees every request is
/// assigned exactly once and padding only occurs on the final launch.
pub fn pack(m: usize, buckets: &[usize]) -> Vec<BucketLaunch> {
    assert!(!buckets.is_empty(), "no HF buckets available");
    let mut sorted: Vec<usize> = buckets.to_vec();
    sorted.sort_unstable();
    sorted.dedup();
    let mut launches = Vec::new();
    let mut left = m;
    while left > 0 {
        if let Some(&b) = sorted.iter().rev().find(|&&b| b <= left) {
            // largest bucket that fits entirely
            launches.push(BucketLaunch { bucket: b, used: b });
            left -= b;
        } else {
            // smallest bucket that covers the tail (padded)
            let b = *sorted.iter().find(|&&b| b >= left).unwrap();
            launches.push(BucketLaunch { bucket: b, used: left });
            left = 0;
        }
    }
    launches
}

/// Total padded planes of a packing (the HF overhead metric).
pub fn total_padding(launches: &[BucketLaunch]) -> usize {
    launches.iter().map(BucketLaunch::padding).sum()
}

/// Pick a single bucket for a whole batch (coordinator fast path: one launch,
/// possibly padded). Returns None if m exceeds the largest bucket.
pub fn single_bucket(m: usize, buckets: &[usize]) -> Option<usize> {
    buckets.iter().copied().filter(|&b| b >= m).min()
}

#[cfg(test)]
mod tests {
    use super::*;

    const BUCKETS: &[usize] = &[1, 2, 4, 8, 16, 25, 50];

    #[test]
    fn exact_fit_has_no_padding() {
        let l = pack(50, BUCKETS);
        assert_eq!(l, vec![BucketLaunch { bucket: 50, used: 50 }]);
        assert_eq!(total_padding(&l), 0);
    }

    #[test]
    fn greedy_packs_large_then_tail() {
        let l = pack(77, BUCKETS);
        let assigned: usize = l.iter().map(|b| b.used).sum();
        assert_eq!(assigned, 77);
        assert_eq!(l[0], BucketLaunch { bucket: 50, used: 50 });
        // tail 27 -> bucket 50 padded? no: largest <= 27 is 25, then 2
        assert_eq!(l[1], BucketLaunch { bucket: 25, used: 25 });
        assert_eq!(l[2], BucketLaunch { bucket: 2, used: 2 });
        assert_eq!(total_padding(&l), 0);
    }

    #[test]
    fn tail_padding_is_minimal_bucket() {
        let l = pack(3, BUCKETS);
        let assigned: usize = l.iter().map(|b| b.used).sum();
        assert_eq!(assigned, 3);
        // 2 fits, then 1 fits: no padding at all with bucket 1 present
        assert_eq!(total_padding(&l), 0);
        // without bucket 1: 3 -> [2, 4(pad 3... no: largest<=1 none -> smallest>=1 is 2, used 1)]
        let l2 = pack(3, &[2, 4, 8]);
        let assigned2: usize = l2.iter().map(|b| b.used).sum();
        assert_eq!(assigned2, 3);
        assert_eq!(total_padding(&l2), 1);
    }

    #[test]
    fn every_m_is_covered_exactly() {
        for m in 1..=200 {
            let l = pack(m, BUCKETS);
            assert_eq!(l.iter().map(|b| b.used).sum::<usize>(), m, "m={m}");
            // padding only on final launch
            for b in &l[..l.len() - 1] {
                assert_eq!(b.padding(), 0, "m={m}");
            }
        }
    }

    #[test]
    fn single_bucket_selection() {
        assert_eq!(single_bucket(3, BUCKETS), Some(4));
        assert_eq!(single_bucket(50, BUCKETS), Some(50));
        assert_eq!(single_bucket(51, BUCKETS), None);
    }
}
