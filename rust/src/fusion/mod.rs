//! Automatic fusion — the paper's core contribution, as a runtime planner.
//!
//! The paper fuses at C++ compile time: the user's IOp sequence instantiates
//! a single `__global__` kernel. Our runtime is AOT (Python never runs on
//! the request path), so "compile time" happened at `make artifacts`; this
//! module maps an arbitrary user [`Pipeline`](crate::ops::Pipeline) onto the
//! pre-lowered artifact family through three tiers (DESIGN.md §3.6):
//!
//! 1. **Exact** — a chain artifact whose op sequence/dtypes/shape/batch match.
//! 2. **StaticLoop** — the body is a repetition of an artifact's loop body
//!    (the paper's StaticLoop Op); the trip count becomes a runtime input.
//! 3. **Interp** — the generic interpreter kernel executes any vocabulary
//!    chain up to `kmax` ops with opcodes/params as runtime tensors.
//!
//! Horizontal Fusion is planned by [`hfusion`]: requests sharing a stream
//! key are packed into batch buckets. Windows that MIX signatures take the
//! divergent-HF tier instead ([`DivergentPlan`]): per-item sub-plans bound
//! into one thread-chunked launch, with the pack/padding accounting
//! generalized to mixed-shape items. [`cost`] is the roofline model that
//! classifies kernels MB/CB and predicts fusion gain; [`memsave`] accounts
//! the DRAM the fused plan avoids (paper §VI-L).

pub mod cost;
mod divergent;
pub mod hfusion;
mod host_plan;
pub mod memsave;
mod plan;
mod planner;

pub use divergent::{occupancy_ratio, DivergentItem, DivergentPlan};
pub use host_plan::{HostAccum, HostPlan, ReaderKind, WriterKind};
pub use plan::{FusionPlan, PlanInputs};
pub use planner::{plan_pipeline, plan_window, unfused_plan, PlanError, Planner, PlannerStats};
