//! The VF planner: pipeline -> FusionPlan against the artifact registry.

use crate::ops::{IOp, Pipeline, Signature};
use crate::runtime::{ArtifactMeta, Registry};

use super::FusionPlan;

#[derive(Debug, thiserror::Error)]
pub enum PlanError {
    #[error("no artifact covers pipeline {sig} (tiers tried: exact, staticloop, interp, unfused)")]
    NoCoverage { sig: String },
    #[error("pipeline contains non-elementwise ops; only chain pipelines are plannable: {0}")]
    NotAChain(String),
    #[error(
        "pipeline has a structured boundary op ({0}); dense chain ARTIFACTS cannot serve it \
         (it needs a dedicated artifact family like the preproc kernels — or the host fused \
         engine, which executes structured boundaries natively)"
    )]
    StructuredBoundary(String),
    #[error(
        "pipeline ends in a reduction ({0}); dense chain ARTIFACTS cannot serve it (serving \
         reductions on the artifact tier takes a dedicated ReduceDPP family — the host fused \
         engine folds them while reading, natively)"
    )]
    Reduction(String),
    #[error(
        "window mixes {0}; one artifact launch binds ONE code shape, so artifact tiers only \
         serve signature-homogeneous windows — mixed windows take the host divergent-HF tier"
    )]
    Divergent(String),
}

/// Cumulative planner decisions (exposed as coordinator metrics and used by
/// the tier-ablation bench).
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct PlannerStats {
    pub exact: usize,
    pub staticloop: usize,
    pub interp: usize,
    pub unfused: usize,
    /// Runs served by the host fused engine (single-pass CPU backend).
    pub host: usize,
    /// Typed [`UnsupportedOp`](crate::exec::UnsupportedOp) detections:
    /// bodies outside the XLA chain vocabulary (`ComputeC3`/`CvtColor`)
    /// that [`FusedEngine`](crate::exec::FusedEngine) re-routed to the host
    /// single-pass engine. A detection counter, not a serve tier — the
    /// serves themselves land under `host` — so it is excluded from
    /// [`PlannerStats::total`].
    pub unsupported: usize,
    /// Structured-boundary pipelines (crop/resize reads, split writes)
    /// served by the host single-pass engine — either re-routed there by
    /// [`FusedEngine`](crate::exec::FusedEngine) (dense artifacts cannot
    /// express them) or run natively on the host backend. Like
    /// `unsupported`, a sub-count of `host`, excluded from
    /// [`PlannerStats::total`] — it makes structured traffic (the flagship
    /// preproc workload) observable in serving dashboards.
    pub structured: usize,
    /// Reduce-terminated pipelines served by the host fold-while-reading
    /// tier — detected at the artifact planner as
    /// [`PlanError::Reduction`] and re-routed by
    /// [`FusedEngine`](crate::exec::FusedEngine), or run natively on the
    /// host backend. Like `structured`, a sub-count of `host` excluded from
    /// [`PlannerStats::total`]: the new reduce workload gets its own tier in
    /// serving dashboards.
    pub reduction: usize,
    /// Divergent-HF WINDOWS (mixed pipeline signatures served in one
    /// thread-chunked pass) — detected at the window planner as
    /// [`PlanError::Divergent`] and partitioned by
    /// [`FusedEngine::run_many`](crate::exec::FusedEngine::run_many)
    /// (artifact-covered items keep their artifact launches, the refused
    /// remainder takes the host pass), or served natively by
    /// [`HostFusedEngine::run_divergent`](crate::exec::HostFusedEngine::run_divergent).
    /// A window counter (the per-item serves land under `host`), excluded
    /// from [`PlannerStats::total`] like `structured`/`reduction`.
    pub divergent: usize,
    /// Distinct compiled plans alive in the serving engine's plan cache
    /// (host tier only today) — a gauge, not a counter, so canonicalization
    /// ablations can assert how many plans a window of equivalent chains
    /// compiled down to. Excluded from [`PlannerStats::total`].
    pub plan_cache: usize,
    /// Host runs whose fused inner loop took a register-blocked
    /// (SIMD-shaped) arm — effective width > 1, i.e. every production run;
    /// the scalar arm exists only under the engine's
    /// [`with_lane_width`](crate::exec::HostFusedEngine::with_lane_width)
    /// ablation override. A sub-count of `host` excluded from
    /// [`PlannerStats::total`], mirrored from
    /// [`HostFusedEngine::vector_runs`](crate::exec::HostFusedEngine::vector_runs).
    pub vectorized: usize,
    /// Widest register block any host run used (elements per iteration:
    /// 16 on the f32 fast arm, 8 on f64 arms and reduce stripes; 0 before
    /// the first run) — a gauge mirrored from
    /// [`HostFusedEngine::vector_width`](crate::exec::HostFusedEngine::vector_width),
    /// so dashboards show which SIMD shape actually served.
    pub vector_width: u8,
    /// Bytes host fused passes actually READ (gauge accumulated per launch,
    /// mirrored from [`HostFusedEngine::bytes_read`](crate::exec::HostFusedEngine::bytes_read)).
    /// With `bytes_written` and `bytes_baseline` this is the fusion-efficiency
    /// accounting: actual single-pass traffic vs what an op-at-a-time
    /// execution of the same pipelines would have moved.
    pub bytes_read: u64,
    /// Bytes host fused passes actually WROTE (reduce passes land only the
    /// statistics — that is the point of the fold-while-reading tier).
    pub bytes_written: u64,
    /// Bytes the UNFUSED op-at-a-time baseline would have moved for the same
    /// launches: per-stage materialization of `out_shape` × dtype width,
    /// derived statically from the IR ([`crate::ops::Pipeline::baseline_bytes`]).
    pub bytes_baseline: u64,
}

impl PlannerStats {
    /// Runs that kept intermediates fused (any tier but the per-op fallback).
    pub fn fused_total(&self) -> usize {
        self.exact + self.staticloop + self.interp + self.host
    }

    pub fn total(&self) -> usize {
        self.fused_total() + self.unfused
    }
}

/// Stateless planning with stat tracking.
pub struct Planner {
    pub stats: PlannerStats,
    /// artifact variant preference ("pallas" with "xla" fallback)
    pub variant: String,
}

impl Default for Planner {
    fn default() -> Self {
        Planner { stats: PlannerStats::default(), variant: "pallas".to_string() }
    }
}

impl Planner {
    pub fn plan(&mut self, p: &Pipeline, reg: &Registry) -> Result<FusionPlan, PlanError> {
        let plan = plan_pipeline(p, reg, &self.variant)?;
        match &plan {
            FusionPlan::Exact { .. } => self.stats.exact += 1,
            FusionPlan::StaticLoop { .. } => self.stats.staticloop += 1,
            FusionPlan::Interp { .. } => self.stats.interp += 1,
            FusionPlan::Unfused { .. } => self.stats.unfused += 1,
        }
        Ok(plan)
    }
}

fn body_opnames(p: &Pipeline) -> Result<Vec<&'static str>, PlanError> {
    p.body()
        .iter()
        .map(|op| match op {
            IOp::Compute { op, .. } => Ok(op.name()),
            other => Err(PlanError::NotAChain(other.sig_token())),
        })
        .collect()
}

fn ensure_dense_boundaries(p: &Pipeline) -> Result<(), PlanError> {
    // interrogate the boundary metadata (never sig-token strings): a
    // structured boundary changes the access pattern of the generated code,
    // which no dense artifact family can reproduce
    if p.has_structured_boundary() {
        for op in [p.ops().first(), p.ops().last()].into_iter().flatten() {
            if matches!(op, IOp::Mem(m) if m.is_structured()) {
                return Err(PlanError::StructuredBoundary(op.sig_token()));
            }
        }
    }
    Ok(())
}

/// Plan a WINDOW of pipelines as one artifact launch. Artifact tiers bind
/// exactly one code shape per launch, so the window must be
/// signature-homogeneous; a mixed window is refused with the typed
/// [`PlanError::Divergent`] — callers
/// ([`FusedEngine::run_many`](crate::exec::FusedEngine::run_many)) re-route
/// it to the host divergent tier
/// ([`HostFusedEngine::run_divergent`](crate::exec::HostFusedEngine::run_divergent)),
/// which interleaves the divergent sequences in one thread-chunked pass.
pub fn plan_window(
    window: &[&Pipeline],
    reg: &Registry,
    variant: &str,
) -> Result<FusionPlan, PlanError> {
    let Some(head) = window.first() else {
        return Err(PlanError::NoCoverage { sig: "(empty window)".to_string() });
    };
    let sigs: std::collections::HashSet<Signature> =
        window.iter().map(|p| Signature::of(p)).collect();
    if sigs.len() > 1 {
        return Err(PlanError::Divergent(format!(
            "{} distinct pipeline signatures",
            sigs.len()
        )));
    }
    plan_pipeline(head, reg, variant)
}

/// Plan one pipeline. Tier order: exact > staticloop > interp > unfused.
pub fn plan_pipeline(
    p: &Pipeline,
    reg: &Registry,
    variant: &str,
) -> Result<FusionPlan, PlanError> {
    // a reduce terminator is a different KERNEL SHAPE, not just a different
    // access pattern: no dense chain artifact accumulates anything. Typed,
    // artifact-tier-only refusal — FusedEngine re-routes to the host fused
    // engine's fold-while-reading tier (interrogate the metadata, never
    // sig-token strings).
    if p.reduction().is_some() {
        let token = p.ops().last().map(IOp::sig_token).unwrap_or_default();
        return Err(PlanError::Reduction(token));
    }
    // a structured boundary (crop/resize read, split write) changes the
    // memory pattern of the generated code: matching the BODY against a
    // dense chain artifact would silently execute the wrong kernel. The
    // refusal is ARTIFACT-tier only — FusedEngine re-routes these pipelines
    // to the host fused engine, which plans and serves them natively.
    ensure_dense_boundaries(p)?;
    let names = body_opnames(p)?;
    let dtin = p.dtin.name();
    let dtout = p.dtout.name();

    // tier 1: exact fused chain
    let exact = reg.find(|m| {
        (m.kind == "chain" || m.kind == "single_op")
            && matches_variant(m, variant)
            && m.ops == names
            && m.dtin == dtin
            && m.dtout == dtout
            && m.shape == p.shape
            && m.batch == p.batch
    });
    if let Some(m) = prefer_variant(exact, variant) {
        return Ok(FusionPlan::Exact { artifact: m.name.clone() });
    }

    // tier 2: StaticLoop — body is n repetitions of an artifact's loop body
    // with position-uniform params (the paper reuses one Op instance)
    let loops = reg.find(|m| {
        m.kind == "staticloop"
            && matches_variant(m, variant)
            && m.dtin == dtin
            && m.dtout == dtout
            && m.shape == p.shape
            && m.batch == p.batch
    });
    for m in prefer_variant_all(loops, variant) {
        if let Some(iters) = repetition_count(p, &m.ops) {
            return Ok(FusionPlan::StaticLoop { artifact: m.name.clone(), iters });
        }
    }

    // tier 3: interpreter kernel
    let interps = reg.find(|m| {
        m.kind == "interp"
            && matches_variant(m, variant)
            && m.dtin == dtin
            && m.dtout == dtout
            && m.shape == p.shape
            && m.batch == p.batch
            && m.kmax >= names.len()
    });
    if let Some(m) = prefer_variant(interps, variant) {
        return Ok(FusionPlan::Interp { artifact: m.name.clone(), kmax: m.kmax });
    }

    // tier 4: unfused fallback — per-op singles at batch width (or b=1)
    if let Some(plan) = unfused_plan(p, reg, &names) {
        return Ok(plan);
    }

    Err(PlanError::NoCoverage { sig: Signature::of(p).to_string() })
}

/// Build the per-op launch list of the unfused baseline: first op carries the
/// dtin->dtout cast, the rest run dtout->dtout (the OpenCV convertTo-then-
/// arithm structure).
pub fn unfused_plan(p: &Pipeline, reg: &Registry, names: &[&str]) -> Option<FusionPlan> {
    let dtout = p.dtout.name();
    let mut artifacts = Vec::with_capacity(names.len());
    for (i, &name) in names.iter().enumerate() {
        let dtin = if i == 0 { p.dtin.name() } else { dtout };
        let m = reg
            .find(|m| {
                m.kind == "single_op"
                    && m.ops.len() == 1
                    && m.ops[0] == name
                    && m.dtin == dtin
                    && m.dtout == dtout
                    && m.shape == p.shape
                    && (m.batch == p.batch || m.batch == 1)
            })
            .into_iter()
            // prefer exact batch match over b=1 looping
            .max_by_key(|m| (m.batch == p.batch) as u8)?;
        artifacts.push(m.name.clone());
    }
    Some(FusionPlan::Unfused { artifacts })
}

/// If the pipeline body is exactly `pattern` repeated n >= 1 times with
/// position-uniform params, return n.
fn repetition_count(p: &Pipeline, pattern: &[String]) -> Option<usize> {
    let body = p.body();
    if pattern.is_empty() || body.len() % pattern.len() != 0 {
        return None;
    }
    let n = body.len() / pattern.len();
    let mut first_params: Vec<f64> = Vec::with_capacity(pattern.len());
    for (i, op) in body.iter().enumerate() {
        let IOp::Compute { op, param } = op else { return None };
        if op.name() != pattern[i % pattern.len()] {
            return None;
        }
        if i < pattern.len() {
            first_params.push(*param);
        } else if *param != first_params[i % pattern.len()] {
            return None; // params must repeat with the pattern
        }
    }
    Some(n)
}

fn matches_variant(m: &ArtifactMeta, variant: &str) -> bool {
    m.variant == variant || m.variant == "pallas" || m.variant == "xla"
}

fn prefer_variant<'a>(mut v: Vec<&'a ArtifactMeta>, variant: &str) -> Option<&'a ArtifactMeta> {
    v.sort_by_key(|m| (m.variant != variant) as u8);
    v.into_iter().next()
}

fn prefer_variant_all<'a>(mut v: Vec<&'a ArtifactMeta>, variant: &str) -> Vec<&'a ArtifactMeta> {
    v.sort_by_key(|m| (m.variant != variant) as u8);
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{Opcode, Pipeline};
    use crate::tensor::DType;

    fn pipe(chain: &[(Opcode, f64)], shape: &[usize], batch: usize) -> Pipeline {
        Pipeline::from_opcodes(chain, shape, batch, DType::F32, DType::F32).unwrap()
    }

    #[test]
    fn repetition_detection() {
        let p = pipe(
            &[(Opcode::Mul, 2.0), (Opcode::Add, 1.0), (Opcode::Mul, 2.0), (Opcode::Add, 1.0)],
            &[4],
            1,
        );
        assert_eq!(repetition_count(&p, &["mul".into(), "add".into()]), Some(2));
        // non-uniform params break the loop contract
        let p2 = pipe(
            &[(Opcode::Mul, 2.0), (Opcode::Add, 1.0), (Opcode::Mul, 3.0), (Opcode::Add, 1.0)],
            &[4],
            1,
        );
        assert_eq!(repetition_count(&p2, &["mul".into(), "add".into()]), None);
        // wrong op order
        let p3 = pipe(&[(Opcode::Add, 1.0), (Opcode::Mul, 2.0)], &[4], 1);
        assert_eq!(repetition_count(&p3, &["mul".into(), "add".into()]), None);
        // length not divisible
        let p4 = pipe(&[(Opcode::Mul, 2.0), (Opcode::Add, 1.0), (Opcode::Mul, 2.0)], &[4], 1);
        assert_eq!(repetition_count(&p4, &["mul".into(), "add".into()]), None);
    }

    #[test]
    fn single_rep_counts_as_one() {
        let p = pipe(&[(Opcode::Mul, 2.0), (Opcode::Add, 1.0)], &[4], 1);
        assert_eq!(repetition_count(&p, &["mul".into(), "add".into()]), Some(1));
    }
}
