//! FastNPP — the NPP-style wrapper (paper §VI-J, Fig. 25b).
//!
//! NPP encodes dtype/channel layout in the function name
//! (`nppiMulC_32f_C3R_Ctx`); FastNPP keeps those names but returns lazy
//! stages executed by one fused kernel. This module reproduces the
//! preprocessing pipeline of the paper's NPP comparison, including the two
//! CPU-side modes measured in Fig. 24:
//!
//! * [`PreprocPipeline::run`] — re-derives kernel parameters every call (what
//!   NPP forces you to do);
//! * [`PreprocPipeline::precompute`] + [`PreprocPipeline::run_precomputed`] —
//!   the FastNPP advantage: parameters built once, kernel re-launched with
//!   the same inputs.
//!
//! Since the typed-chain redesign this is a PRESET CHAIN, not a parallel
//! implementation: [`PreprocPipeline::preset_chain`] declares the per-crop
//! semantics through [`crate::chain`] (ResizeRead -> ColorConvert -> MulC ->
//! SubC -> DivC -> Split, all typed stages), and the `run*` entry points
//! launch the AOT artifact that chain lowers to. Launches BORROW the frame —
//! no per-call tensor clones on the hot path.

use anyhow::{bail, Result};

use crate::chain::{Chain, CvtColor, DivC3, MulC3, SubC3, TypedPipeline, F32, U8};
use crate::cv::Context;
use crate::runtime::DeviceValue;
use crate::tensor::{Rect, Tensor};

/// `nppiResizeBatch_32f_C3R_Advanced_Ctx` analog: batch crop+resize spec.
#[derive(Debug, Clone)]
pub struct ResizeBatchSpec {
    pub rects: Vec<Rect>,
    pub dst_h: usize,
    pub dst_w: usize,
}

/// Per-channel constant (the `Npp32f aConstants[3]` of MulC/SubC/DivC).
pub type C3 = [f32; 3];

/// The fused Batch(Crop->Resize->ColorConvert->MulC->SubC->DivC->Split)
/// pipeline against a shared source frame.
pub struct PreprocPipeline {
    pub spec: ResizeBatchSpec,
    pub mul: C3,
    pub sub: C3,
    pub div: C3,
    /// precomputed kernel inputs (rect tensor + constants), if any
    precomputed: Option<[Tensor; 4]>,
}

impl PreprocPipeline {
    pub fn new(spec: ResizeBatchSpec, mul: C3, sub: C3, div: C3) -> PreprocPipeline {
        PreprocPipeline { spec, mul, sub, div, precomputed: None }
    }

    /// The per-crop semantics as a typed chain — the single declaration of
    /// what this pipeline computes. Structured read (crop+resize fused at
    /// the read end) and the packed->planar split write are typed stages;
    /// the chain seals as `TypedPipeline<U8, F32>` and its parameter-
    /// agnostic [`crate::ops::Signature`] is what tests pin the AOT artifact
    /// family against.
    pub fn preset_chain(&self, rect: Rect) -> TypedPipeline<U8, F32> {
        Chain::read_resize::<U8>(rect, self.spec.dst_h, self.spec.dst_w)
            .map(CvtColor)
            .map(MulC3(self.mul))
            .map(SubC3(self.sub))
            .map(DivC3(self.div))
            .cast::<F32>()
            .write_split()
    }

    /// Artifact name for this batch size (must be one of the AOT'd buckets).
    fn artifact(&self, ctx: &Context, batch: usize) -> Result<String> {
        let reg = ctx.registry()?;
        let m = reg
            .find(|m| m.kind == "preproc" && m.variant == "pallas" && m.batch == batch)
            .into_iter()
            .next()
            .ok_or_else(|| anyhow::anyhow!("no preproc artifact for batch {batch}"))?;
        Ok(m.name.clone())
    }

    fn kernel_inputs(&self) -> [Tensor; 4] {
        [
            Rect::batch_tensor(&self.spec.rects),
            Tensor::from_f32(&self.mul, &[3]),
            Tensor::from_f32(&self.sub, &[3]),
            Tensor::from_f32(&self.div, &[3]),
        ]
    }

    /// FastNPP without precomputation: CPU parameter derivation every call
    /// (rect marshaling, constant tensors) + one fused launch. The frame is
    /// borrowed straight into the launch — never cloned.
    pub fn run(&self, ctx: &Context, frame: &Tensor) -> Result<Tensor> {
        let b = self.spec.rects.len();
        let name = self.artifact(ctx, b)?;
        let [rects, mul, sub, div] = self.kernel_inputs();
        ctx.fused()?.executor().run(&name, &[frame, &rects, &mul, &sub, &div])
    }

    /// Build the parameters once (paper: "compute the CPU part of each Op
    /// once and iteratively call the kernel with the same parameters").
    pub fn precompute(&mut self) {
        self.precomputed = Some(self.kernel_inputs());
    }

    /// Launch with precomputed parameters; fails if not precomputed. Zero
    /// host-tensor copies per launch: the frame AND the precomputed inputs
    /// are borrowed.
    pub fn run_precomputed(&self, ctx: &Context, frame: &Tensor) -> Result<Tensor> {
        let Some(inputs) = &self.precomputed else {
            bail!("call precompute() first");
        };
        let b = self.spec.rects.len();
        let name = self.artifact(ctx, b)?;
        ctx.fused()?.executor().run(
            &name,
            &[frame, &inputs[0], &inputs[1], &inputs[2], &inputs[3]],
        )
    }

    /// The NPP baseline: one library call per step per crop (Fig. 25b, top).
    /// Per call: fresh parameter derivation + launch; intermediates live in
    /// device memory.
    pub fn run_npp_style(&self, ctx: &Context, frame: &Tensor) -> Result<Tensor> {
        let (dh, dw) = (self.spec.dst_h, self.spec.dst_w);
        let reg = ctx.registry()?;
        let exec = ctx.fused()?.executor();
        let find = |step: &str| -> Result<String> {
            reg.find(|m| m.kind == "preproc_step" && m.ops == [step.to_string()])
                .into_iter()
                .next()
                .map(|m| m.name.clone())
                .ok_or_else(|| anyhow::anyhow!("missing preproc step artifact {step}"))
        };
        let crop_a = find("crop")?;
        let conv_a = find("convert")?;
        let rsz_a = find("resize")?;
        let cvt_a = find("cvtcolor")?;
        let mul_a = find("mulc")?;
        let sub_a = find("subc")?;
        let div_a = find("divc")?;
        let split_a = find("split")?;

        let b = self.spec.rects.len();
        let mut out = Vec::with_capacity(b * 3 * dh * dw);
        for r in &self.spec.rects {
            // nppiConvert / nppiResize / nppiSwapChannels / nppiMulC / ...
            let rect = Tensor::from_i32(&[r.x0, r.y0, r.w, r.h], &[4]);
            let mulc = Tensor::from_f32(&self.mul, &[3]);
            let subc = Tensor::from_f32(&self.sub, &[3]);
            let divc = Tensor::from_f32(&self.div, &[3]);
            let crop = exec.run(&crop_a, &[frame, &rect])?;
            let f = exec.run(&conv_a, &[&crop])?;
            let up = exec.run(&rsz_a, &[&f])?;
            let sw = exec.run(&cvt_a, &[&up])?;
            let m = exec.run(&mul_a, &[&sw, &mulc])?;
            let s = exec.run(&sub_a, &[&m, &subc])?;
            let d = exec.run(&div_a, &[&s, &divc])?;
            let planar = exec.run(&split_a, &[&d])?;
            let vals = planar.as_f32().ok_or_else(|| anyhow::anyhow!("planar f32"))?;
            out.extend_from_slice(vals);
        }
        Ok(Tensor::from_f32(&out, &[b, 3, dh, dw]))
    }
}

/// Keep a frame resident on device between iterations (both NPP and FastNPP
/// hold source data in GPU memory across a video loop).
pub struct DeviceFrame {
    pub value: DeviceValue,
    pub shape: Vec<usize>,
}

impl DeviceFrame {
    pub fn upload(frame: &Tensor) -> Result<DeviceFrame> {
        Ok(DeviceFrame { value: DeviceValue::upload(frame)?, shape: frame.shape().to_vec() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn preproc() -> PreprocPipeline {
        PreprocPipeline::new(
            ResizeBatchSpec { rects: vec![Rect::new(0, 0, 120, 60)], dst_h: 128, dst_w: 64 },
            [2.0, 2.0, 2.0],
            [0.0; 3],
            [1.0; 3],
        )
    }

    #[test]
    fn spec_construction() {
        assert!(preproc().precomputed.is_none());
    }

    #[test]
    fn precompute_builds_inputs_once() {
        let mut p = preproc();
        p.precompute();
        let inp = p.precomputed.as_ref().unwrap();
        assert_eq!(inp[0].shape(), &[1, 4]);
        assert_eq!(inp[1].as_f32().unwrap(), &[2.0, 2.0, 2.0]);
    }

    #[test]
    fn preset_chain_declares_the_preproc_semantics() {
        // the typed chain IS the semantic declaration: its signature pins
        // the op sequence the AOT preproc artifact family implements
        let p = preproc();
        let chain = p.preset_chain(p.spec.rects[0]);
        let sig = chain.signature();
        // boundary tokens participate: a structured chain never shares a
        // cache key / HF stream with a dense chain of the same body
        assert_eq!(sig.ops, "resize[128x64]-cvtcolor-mulc3-subc3-divc3-split[f32]");
        assert_eq!(sig.dtin, "u8");
        assert_eq!(sig.dtout, "f32");
        assert_eq!(chain.pipeline().shape, vec![128, 64, 3]);
        // structured read + split write survive lowering as typed memops
        let ops = chain.pipeline().ops();
        assert!(matches!(
            ops.first(),
            Some(crate::ops::IOp::Mem(crate::ops::MemOp::ResizeRead { .. }))
        ));
        assert!(matches!(
            ops.last(),
            Some(crate::ops::IOp::Mem(crate::ops::MemOp::SplitWrite { .. }))
        ));
    }
}
