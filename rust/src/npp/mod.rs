//! FastNPP — the NPP-style wrapper (paper §VI-J, Fig. 25b).
//!
//! NPP encodes dtype/channel layout in the function name
//! (`nppiMulC_32f_C3R_Ctx`); FastNPP keeps those names but returns lazy
//! stages executed by one fused kernel. This module reproduces the
//! preprocessing pipeline of the paper's NPP comparison, including the two
//! CPU-side modes measured in Fig. 24:
//!
//! * [`PreprocPipeline::run`] — re-derives kernel parameters every call (what
//!   NPP forces you to do);
//! * [`PreprocPipeline::precompute`] + [`PreprocPipeline::run_precomputed`] —
//!   the FastNPP advantage: parameters built once, kernel re-launched with
//!   the same inputs.
//!
//! Since the typed-chain redesign this is a PRESET CHAIN, not a parallel
//! implementation: [`PreprocPipeline::preset_chain`] declares the per-crop
//! semantics through [`crate::chain`] (ResizeRead -> ColorConvert -> MulC ->
//! SubC -> DivC -> Split, all typed stages), and the `run*` entry points
//! execute it on whichever backend the [`Context`] resolved: the AOT
//! artifact family when the registry loaded, or the host fused engine —
//! which runs the structured boundaries natively in one pass (gather while
//! reading, split while writing) — on any machine with ZERO artifacts.
//! Launches BORROW the frame — no per-call tensor clones on the hot path.

use anyhow::{bail, ensure, Result};

use crate::chain::{Chain, CvtColor, DivC3, MulC3, SubC3, TypedPipeline, F32, F64, U8};
use crate::cv::Context;
use crate::hostref;
use crate::ops::{kernel, Opcode, ReduceKind, ScalarOp};
use crate::runtime::DeviceValue;
use crate::tensor::{crop_frame, DType, Rect, Tensor};

/// `nppiResizeBatch_32f_C3R_Advanced_Ctx` analog: batch crop+resize spec.
#[derive(Debug, Clone)]
pub struct ResizeBatchSpec {
    pub rects: Vec<Rect>,
    pub dst_h: usize,
    pub dst_w: usize,
}

/// Per-channel constant (the `Npp32f aConstants[3]` of MulC/SubC/DivC).
pub type C3 = [f32; 3];

/// The fused Batch(Crop->Resize->ColorConvert->MulC->SubC->DivC->Split)
/// pipeline against a shared source frame.
pub struct PreprocPipeline {
    pub spec: ResizeBatchSpec,
    pub mul: C3,
    pub sub: C3,
    pub div: C3,
    /// precomputed kernel inputs (rect tensor + constants), if any
    precomputed: Option<[Tensor; 4]>,
}

impl PreprocPipeline {
    pub fn new(spec: ResizeBatchSpec, mul: C3, sub: C3, div: C3) -> PreprocPipeline {
        PreprocPipeline { spec, mul, sub, div, precomputed: None }
    }

    /// The per-crop semantics as a typed chain — the single declaration of
    /// what this pipeline computes. Structured read (crop+resize fused at
    /// the read end) and the packed->planar split write are typed stages;
    /// the chain seals as `TypedPipeline<U8, F32>` and its parameter-
    /// agnostic [`crate::ops::Signature`] is what tests pin the AOT artifact
    /// family against.
    pub fn preset_chain(&self, rect: Rect) -> TypedPipeline<U8, F32> {
        Chain::read_resize::<U8>(rect, self.spec.dst_h, self.spec.dst_w)
            .map(CvtColor)
            .map(MulC3(self.mul))
            .map(SubC3(self.sub))
            .map(DivC3(self.div))
            .cast::<F32>()
            .write_split()
    }

    /// Artifact name for this batch size (must be one of the AOT'd buckets).
    fn artifact(&self, ctx: &Context, batch: usize) -> Result<String> {
        let reg = ctx.registry()?;
        let m = reg
            .find(|m| m.kind == "preproc" && m.variant == "pallas" && m.batch == batch)
            .into_iter()
            .next()
            .ok_or_else(|| anyhow::anyhow!("no preproc artifact for batch {batch}"))?;
        Ok(m.name.clone())
    }

    fn kernel_inputs(&self) -> [Tensor; 4] {
        [
            Rect::batch_tensor(&self.spec.rects),
            Tensor::from_f32(&self.mul, &[3]),
            Tensor::from_f32(&self.sub, &[3]),
            Tensor::from_f32(&self.div, &[3]),
        ]
    }

    /// The host fused path: each rect is one structured single-pass run —
    /// bilinear gather while reading, chain folded in registers, split
    /// while writing — through the SAME preset chain the artifacts
    /// implement. The plan is cached per signature (all rects share one);
    /// the rect is bound per run, exactly like chain params. Runs with zero
    /// artifacts on any machine.
    fn run_host_fused(&self, ctx: &Context, frame: &Tensor) -> Result<Tensor> {
        let (dh, dw) = (self.spec.dst_h, self.spec.dst_w);
        let b = self.spec.rects.len();
        let engine = ctx.host();
        let mut out = Vec::with_capacity(b * 3 * dh * dw);
        for &r in &self.spec.rects {
            let plane = self.preset_chain(r).run_host(engine, frame)?;
            out.extend_from_slice(plane.as_f32().expect("preset chain seals at f32"));
        }
        Ok(Tensor::from_f32(&out, &[b, 3, dh, dw]))
    }

    /// FastNPP without precomputation: CPU parameter derivation every call
    /// (rect marshaling, constant tensors) + one fused launch per batch (one
    /// per crop on the host tier). The frame is borrowed straight into the
    /// launch — never cloned. Serves on EVERY backend: the AOT preproc
    /// artifact when the registry loaded, the host fused engine otherwise.
    pub fn run(&self, ctx: &Context, frame: &Tensor) -> Result<Tensor> {
        if !ctx.has_artifacts() {
            return self.run_host_fused(ctx, frame);
        }
        let b = self.spec.rects.len();
        let name = self.artifact(ctx, b)?;
        let [rects, mul, sub, div] = self.kernel_inputs();
        ctx.fused()?.executor().run(&name, &[frame, &rects, &mul, &sub, &div])
    }

    /// Build the parameters once (paper: "compute the CPU part of each Op
    /// once and iteratively call the kernel with the same parameters").
    pub fn precompute(&mut self) {
        self.precomputed = Some(self.kernel_inputs());
    }

    /// Launch with precomputed parameters; fails if not precomputed. Zero
    /// host-tensor copies per launch: the frame AND the precomputed inputs
    /// are borrowed. On the host tier the precomputed tensors have no
    /// kernel to feed — the cached plan plays their role — so the fused
    /// host path serves directly.
    pub fn run_precomputed(&self, ctx: &Context, frame: &Tensor) -> Result<Tensor> {
        let Some(inputs) = &self.precomputed else {
            bail!("call precompute() first");
        };
        if !ctx.has_artifacts() {
            return self.run_host_fused(ctx, frame);
        }
        let b = self.spec.rects.len();
        let name = self.artifact(ctx, b)?;
        ctx.fused()?.executor().run(
            &name,
            &[frame, &inputs[0], &inputs[1], &inputs[2], &inputs[3]],
        )
    }

    /// The per-crop STATISTICS chain of the normalize stage: crop+resize
    /// gather -> color convert -> MulC scaling, terminated by a per-channel
    /// (mean, sum-of-squares) pair reduction — one fold-while-reading pass
    /// per crop, the resized crop never materializes.
    pub fn stats_chain(&self, rect: Rect) -> TypedPipeline<U8, F64> {
        Chain::read_resize::<U8>(rect, self.spec.dst_h, self.spec.dst_w)
            .map(CvtColor)
            .map(MulC3(self.mul))
            .reduce_pair_per_channel(ReduceKind::Mean, ReduceKind::SumSq)
    }

    /// Per-channel (μ, σ) of THIS batch's scaled crops, measured with one
    /// fused reduce pass per crop and combined across crops in the fixed
    /// rect order (every crop contributes `dst_h * dst_w` pixels per lane,
    /// so the batch mean is the mean of crop means and the sums of squares
    /// add). Serves on every backend — the reduce chains re-route to the
    /// host tier under XLA.
    pub fn channel_mean_std(&self, ctx: &Context, frame: &Tensor) -> Result<([f64; 3], [f64; 3])> {
        let b = self.spec.rects.len();
        ensure!(b > 0, "normalize stage needs at least one crop rect");
        let mut mean_sum = [0f64; 3];
        let mut sumsq_sum = [0f64; 3];
        for &r in &self.spec.rects {
            let stats = ctx.run(self.stats_chain(r).pipeline(), frame)?;
            let vals = stats.as_f64().expect("stats chain seals at f64");
            for c in 0..3 {
                mean_sum[c] += vals[c];
                sumsq_sum[c] += vals[3 + c];
            }
        }
        let n_lane = b * self.spec.dst_h * self.spec.dst_w;
        let mut mu = [0f64; 3];
        let mut sigma = [0f64; 3];
        for c in 0..3 {
            mu[c] = mean_sum[c] / b as f64;
            sigma[c] = kernel::normalize_sigma(mu[c], sumsq_sum[c], n_lane, 1e-12);
        }
        Ok((mu, sigma))
    }

    /// The NORMALIZE stage: the preset chain with DATA-DERIVED per-channel
    /// statistics — `SubC(μ)` / `DivC(σ)` measured from this batch's scaled
    /// crops ([`PreprocPipeline::channel_mean_std`]) instead of caller
    /// constants. Two fused phases, nothing materialized in between: the
    /// stats phase folds while reading, then the standard preproc pass runs
    /// with the statistics bound as its per-channel constants. Output
    /// channels land mean 0 / σ 1.
    pub fn run_normalized(&self, ctx: &Context, frame: &Tensor) -> Result<Tensor> {
        let (mu, sigma) = self.channel_mean_std(ctx, frame)?;
        self.run_normalized_with(ctx, frame, mu, sigma)
    }

    /// [`PreprocPipeline::run_normalized`] with ALREADY-derived statistics —
    /// the video-loop shape: measure μ/σ once (or per keyframe) with
    /// [`PreprocPipeline::channel_mean_std`], then launch every frame
    /// without re-running the stats sweep.
    pub fn run_normalized_with(
        &self,
        ctx: &Context,
        frame: &Tensor,
        mu: [f64; 3],
        sigma: [f64; 3],
    ) -> Result<Tensor> {
        let derived = PreprocPipeline::new(
            self.spec.clone(),
            self.mul,
            [mu[0] as f32, mu[1] as f32, mu[2] as f32],
            [sigma[0] as f32, sigma[1] as f32, sigma[2] as f32],
        );
        derived.run(ctx, frame)
    }

    /// The NPP baseline on the host tier: one whole-buffer pass per step per
    /// crop, every intermediate MATERIALIZED (crop, convert, resize,
    /// cvtcolor, mulc, subc, divc, split — the exact step list of the
    /// artifact baseline), intermediates held in f32 like the step kernels.
    /// This is the op-at-a-time traffic pattern the fused path removes.
    fn run_npp_style_host(&self, frame: &Tensor) -> Result<Tensor> {
        let (dh, dw) = (self.spec.dst_h, self.spec.dst_w);
        let b = self.spec.rects.len();
        let mut out = Vec::with_capacity(b * 3 * dh * dw);
        // one step: sweep the packed f32 buffer with a ScalarOp, then
        // materialize back to f32 (the step-kernel boundary)
        let sweep = |img: &Tensor, op: ScalarOp| -> Tensor {
            let mut vals = img.to_f64_vec();
            op.apply_slice_f64(&mut vals, 0);
            Tensor::from_f64_cast(&vals, img.shape(), DType::F32)
        };
        for &r in &self.spec.rects {
            let crop = crop_frame(frame, r); // nppiCopy (crop)
            let f = crop.cast(DType::F32); // nppiConvert
            let up = hostref::bilinear_resize_packed(&f, dh, dw); // nppiResize
            let sw = sweep(&up, ScalarOp::Swizzle); // nppiSwapChannels
            let m = sweep(&sw, ScalarOp::PerLane { op: Opcode::Mul, param: self.mul });
            let s = sweep(&m, ScalarOp::PerLane { op: Opcode::Sub, param: self.sub });
            let d = sweep(&s, ScalarOp::PerLane { op: Opcode::Div, param: self.div });
            // split: packed [dh, dw, 3] -> planar [3, dh, dw] through the
            // shared layout contract
            let packed = d.as_f32().expect("f32 step buffer");
            let mut planar = vec![0f32; packed.len()];
            crate::ops::kernel::split_packed_to_planar(packed, &mut planar);
            out.extend_from_slice(&planar);
        }
        Ok(Tensor::from_f32(&out, &[b, 3, dh, dw]))
    }

    /// The NPP baseline: one library call per step per crop (Fig. 25b, top).
    /// Per call: fresh parameter derivation + launch; intermediates live in
    /// device memory (host memory on the artifact-free host tier).
    pub fn run_npp_style(&self, ctx: &Context, frame: &Tensor) -> Result<Tensor> {
        if !ctx.has_artifacts() {
            return self.run_npp_style_host(frame);
        }
        let (dh, dw) = (self.spec.dst_h, self.spec.dst_w);
        let reg = ctx.registry()?;
        let exec = ctx.fused()?.executor();
        let find = |step: &str| -> Result<String> {
            reg.find(|m| m.kind == "preproc_step" && m.ops == [step.to_string()])
                .into_iter()
                .next()
                .map(|m| m.name.clone())
                .ok_or_else(|| anyhow::anyhow!("missing preproc step artifact {step}"))
        };
        let crop_a = find("crop")?;
        let conv_a = find("convert")?;
        let rsz_a = find("resize")?;
        let cvt_a = find("cvtcolor")?;
        let mul_a = find("mulc")?;
        let sub_a = find("subc")?;
        let div_a = find("divc")?;
        let split_a = find("split")?;

        let b = self.spec.rects.len();
        let mut out = Vec::with_capacity(b * 3 * dh * dw);
        for r in &self.spec.rects {
            // nppiConvert / nppiResize / nppiSwapChannels / nppiMulC / ...
            let rect = Tensor::from_i32(&[r.x0, r.y0, r.w, r.h], &[4]);
            let mulc = Tensor::from_f32(&self.mul, &[3]);
            let subc = Tensor::from_f32(&self.sub, &[3]);
            let divc = Tensor::from_f32(&self.div, &[3]);
            let crop = exec.run(&crop_a, &[frame, &rect])?;
            let f = exec.run(&conv_a, &[&crop])?;
            let up = exec.run(&rsz_a, &[&f])?;
            let sw = exec.run(&cvt_a, &[&up])?;
            let m = exec.run(&mul_a, &[&sw, &mulc])?;
            let s = exec.run(&sub_a, &[&m, &subc])?;
            let d = exec.run(&div_a, &[&s, &divc])?;
            let planar = exec.run(&split_a, &[&d])?;
            let vals = planar.as_f32().ok_or_else(|| anyhow::anyhow!("planar f32"))?;
            out.extend_from_slice(vals);
        }
        Ok(Tensor::from_f32(&out, &[b, 3, dh, dw]))
    }
}

/// Keep a frame resident on device between iterations (both NPP and FastNPP
/// hold source data in GPU memory across a video loop).
pub struct DeviceFrame {
    pub value: DeviceValue,
    pub shape: Vec<usize>,
}

impl DeviceFrame {
    pub fn upload(frame: &Tensor) -> Result<DeviceFrame> {
        Ok(DeviceFrame { value: DeviceValue::upload(frame)?, shape: frame.shape().to_vec() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::make_frame;

    fn preproc() -> PreprocPipeline {
        PreprocPipeline::new(
            ResizeBatchSpec { rects: vec![Rect::new(0, 0, 120, 60)], dst_h: 128, dst_w: 64 },
            [2.0, 2.0, 2.0],
            [0.0; 3],
            [1.0; 3],
        )
    }

    #[test]
    fn spec_construction() {
        assert!(preproc().precomputed.is_none());
    }

    #[test]
    fn precompute_builds_inputs_once() {
        let mut p = preproc();
        p.precompute();
        let inp = p.precomputed.as_ref().unwrap();
        assert_eq!(inp[0].shape(), &[1, 4]);
        assert_eq!(inp[1].as_f32().unwrap(), &[2.0, 2.0, 2.0]);
    }

    #[test]
    fn run_serves_on_the_host_tier_with_zero_artifacts() {
        // the flagship acceptance shape: PreprocPipeline::run under the
        // host fused backend, no artifacts anywhere
        let ctx = Context::with_select(crate::exec::EngineSelect::HostFused, None).unwrap();
        let frame = make_frame(90, 160, 12);
        let rects = vec![Rect::new(3, 5, 40, 20), Rect::new(50, 11, 24, 36)];
        let (mulv, subv, divv) = ([0.9, 1.0, 1.1], [0.5, 0.4, 0.3], [2.0, 2.1, 2.2]);
        let mut p = PreprocPipeline::new(
            ResizeBatchSpec { rects: rects.clone(), dst_h: 32, dst_w: 16 },
            mulv,
            subv,
            divv,
        );
        let got = p.run(&ctx, &frame).unwrap();
        assert_eq!(got.shape(), &[2, 3, 32, 16]);

        // bitwise vs the structured oracle per rect (f64-accumulated path)
        let plane = 3 * 32 * 16;
        for (bi, &r) in rects.iter().enumerate() {
            let want = crate::hostref::run_pipeline(p.preset_chain(r).pipeline(), &frame);
            assert_eq!(
                &got.as_f32().unwrap()[bi * plane..(bi + 1) * plane],
                want.as_f32().unwrap(),
                "rect {bi}"
            );
        }

        // epsilon vs the independent Fig. 25 oracle (f32 step math)
        let want = crate::hostref::preproc(&frame, &rects, mulv, subv, divv, 32, 16);
        assert_eq!(got.shape(), want.shape());
        for (i, (a, b)) in got.to_f64_vec().iter().zip(want.to_f64_vec()).enumerate() {
            assert!((a - b).abs() <= 1e-4 + 1e-4 * b.abs(), "elem {i}: {a} vs {b}");
        }

        // the precomputed entry serves identically on the host tier
        p.precompute();
        assert_eq!(p.run_precomputed(&ctx, &frame).unwrap(), got);

        // the op-at-a-time baseline serves too and agrees within epsilon
        let npp = p.run_npp_style(&ctx, &frame).unwrap();
        assert_eq!(npp.shape(), got.shape());
        for (i, (a, b)) in npp.to_f64_vec().iter().zip(got.to_f64_vec()).enumerate() {
            assert!((a - b).abs() <= 1e-3 + 1e-3 * b.abs(), "elem {i}: {a} vs {b}");
        }
    }

    #[test]
    fn normalize_stage_lands_zero_mean_unit_sigma_channels() {
        let ctx = Context::with_select(crate::exec::EngineSelect::HostFused, None).unwrap();
        let frame = make_frame(80, 120, 21);
        let rects = vec![Rect::new(2, 4, 36, 24), Rect::new(40, 30, 28, 40)];
        let p = PreprocPipeline::new(
            ResizeBatchSpec { rects, dst_h: 16, dst_w: 12 },
            [1.0 / 255.0; 3],
            [0.0; 3], // sub/div placeholders: the normalize stage derives its own
            [1.0; 3],
        );
        let (mu, sigma) = p.channel_mean_std(&ctx, &frame).unwrap();
        for c in 0..3 {
            assert!(mu[c].is_finite() && sigma[c] > 0.0, "lane {c}: μ={} σ={}", mu[c], sigma[c]);
        }
        let out = p.run_normalized(&ctx, &frame).unwrap();
        assert_eq!(out.shape(), &[2, 3, 16, 12]);

        // per-channel mean ≈ 0 and variance ≈ 1 across the whole batch
        // (channel c is plane c of each item — the split write's layout)
        let v = out.as_f32().unwrap();
        let plane = 16 * 12;
        for c in 0..3 {
            let mut lane = Vec::with_capacity(2 * plane);
            for bi in 0..2 {
                let base = bi * 3 * plane + c * plane;
                lane.extend(v[base..base + plane].iter().map(|&x| x as f64));
            }
            let n = lane.len() as f64;
            let mean: f64 = lane.iter().sum::<f64>() / n;
            let var: f64 = lane.iter().map(|x| x * x).sum::<f64>() / n;
            assert!(mean.abs() < 1e-3, "lane {c} mean {mean}");
            assert!((var - 1.0).abs() < 1e-2, "lane {c} var {var}");
        }
    }

    #[test]
    fn preset_chain_declares_the_preproc_semantics() {
        // the typed chain IS the semantic declaration: its signature pins
        // the op sequence the AOT preproc artifact family implements
        let p = preproc();
        let chain = p.preset_chain(p.spec.rects[0]);
        let sig = chain.signature();
        // boundary tokens participate: a structured chain never shares a
        // cache key / HF stream with a dense chain of the same body
        assert_eq!(sig.ops, "resize[128x64]-cvtcolor-mulc3-subc3-divc3-split[f32]");
        assert_eq!(sig.dtin, "u8");
        assert_eq!(sig.dtout, "f32");
        assert_eq!(chain.pipeline().shape, vec![128, 64, 3]);
        // structured read + split write survive lowering as typed memops
        let ops = chain.pipeline().ops();
        assert!(matches!(
            ops.first(),
            Some(crate::ops::IOp::Mem(crate::ops::MemOp::ResizeRead { .. }))
        ));
        assert!(matches!(
            ops.last(),
            Some(crate::ops::IOp::Mem(crate::ops::MemOp::SplitWrite { .. }))
        ));
    }
}
