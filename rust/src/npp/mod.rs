//! FastNPP — the NPP-style wrapper (paper §VI-J, Fig. 25b).
//!
//! NPP encodes dtype/channel layout in the function name
//! (`nppiMulC_32f_C3R_Ctx`); FastNPP keeps those names but returns lazy IOps
//! executed by one fused kernel. This module reproduces the preprocessing
//! pipeline of the paper's NPP comparison, including the two CPU-side modes
//! measured in Fig. 24:
//!
//! * [`PreprocPipeline::run`] — re-derives kernel parameters every call (what
//!   NPP forces you to do);
//! * [`PreprocPipeline::precompute`] + [`PreprocPipeline::run_precomputed`] —
//!   the FastNPP advantage: IOps built once, kernel re-launched with the same
//!   parameters.

use anyhow::{bail, Context as _, Result};

use crate::cv::Context;
use crate::runtime::DeviceValue;
use crate::tensor::{Rect, Tensor};

/// `nppiResizeBatch_32f_C3R_Advanced_Ctx` analog: batch crop+resize spec.
#[derive(Debug, Clone)]
pub struct ResizeBatchSpec {
    pub rects: Vec<Rect>,
    pub dst_h: usize,
    pub dst_w: usize,
}

/// Per-channel constant (the `Npp32f aConstants[3]` of MulC/SubC/DivC).
pub type C3 = [f32; 3];

/// The fused Batch(Crop->Resize->ColorConvert->MulC->SubC->DivC->Split)
/// pipeline against a shared source frame.
pub struct PreprocPipeline {
    pub spec: ResizeBatchSpec,
    pub mul: C3,
    pub sub: C3,
    pub div: C3,
    /// precomputed kernel inputs (rect tensor + constants), if any
    precomputed: Option<[Tensor; 4]>,
}

impl PreprocPipeline {
    pub fn new(spec: ResizeBatchSpec, mul: C3, sub: C3, div: C3) -> PreprocPipeline {
        PreprocPipeline { spec, mul, sub, div, precomputed: None }
    }

    /// Artifact name for this batch size (must be one of the AOT'd buckets).
    fn artifact(&self, ctx: &Context, batch: usize) -> Result<String> {
        let m = ctx
            .registry
            .find(|m| m.kind == "preproc" && m.variant == "pallas" && m.batch == batch)
            .into_iter()
            .next()
            .with_context(|| format!("no preproc artifact for batch {batch}"))?;
        Ok(m.name.clone())
    }

    fn kernel_inputs(&self) -> [Tensor; 4] {
        [
            Rect::batch_tensor(&self.spec.rects),
            Tensor::from_f32(&self.mul, &[3]),
            Tensor::from_f32(&self.sub, &[3]),
            Tensor::from_f32(&self.div, &[3]),
        ]
    }

    /// FastNPP without precomputation: CPU parameter derivation every call
    /// (rect marshaling, constant tensors) + one fused launch.
    pub fn run(&self, ctx: &Context, frame: &Tensor) -> Result<Tensor> {
        let b = self.spec.rects.len();
        let name = self.artifact(ctx, b)?;
        let [rects, mul, sub, div] = self.kernel_inputs();
        ctx.fused.executor().run(&name, &[frame.clone(), rects, mul, sub, div])
    }

    /// Build the IOps once (paper: "compute the CPU part of each Op once and
    /// iteratively call the kernel with the same parameters").
    pub fn precompute(&mut self) {
        self.precomputed = Some(self.kernel_inputs());
    }

    /// Launch with precomputed parameters; fails if not precomputed.
    pub fn run_precomputed(&self, ctx: &Context, frame: &Tensor) -> Result<Tensor> {
        let Some(inputs) = &self.precomputed else {
            bail!("call precompute() first");
        };
        let b = self.spec.rects.len();
        let name = self.artifact(ctx, b)?;
        ctx.fused.executor().run(
            &name,
            &[
                frame.clone(),
                inputs[0].clone(),
                inputs[1].clone(),
                inputs[2].clone(),
                inputs[3].clone(),
            ],
        )
    }

    /// The NPP baseline: one library call per step per crop (Fig. 25b, top).
    /// Per call: fresh parameter derivation + launch; intermediates live in
    /// device memory.
    pub fn run_npp_style(&self, ctx: &Context, frame: &Tensor) -> Result<Tensor> {
        let (dh, dw) = (self.spec.dst_h, self.spec.dst_w);
        let reg = &ctx.registry;
        let exec = ctx.fused.executor();
        let find = |step: &str| -> Result<String> {
            reg.find(|m| m.kind == "preproc_step" && m.ops == [step.to_string()])
                .into_iter()
                .next()
                .map(|m| m.name.clone())
                .with_context(|| format!("missing preproc step artifact {step}"))
        };
        let crop_a = find("crop")?;
        let conv_a = find("convert")?;
        let rsz_a = find("resize")?;
        let cvt_a = find("cvtcolor")?;
        let mul_a = find("mulc")?;
        let sub_a = find("subc")?;
        let div_a = find("divc")?;
        let split_a = find("split")?;

        let b = self.spec.rects.len();
        let mut out = Vec::with_capacity(b * 3 * dh * dw);
        for r in &self.spec.rects {
            // nppiConvert / nppiResize / nppiSwapChannels / nppiMulC / ...
            let rect = Tensor::from_i32(&[r.x0, r.y0, r.w, r.h], &[4]);
            let crop = exec.run(&crop_a, &[frame.clone(), rect])?;
            let f = exec.run(&conv_a, &[crop])?;
            let up = exec.run(&rsz_a, &[f])?;
            let sw = exec.run(&cvt_a, &[up])?;
            let m = exec.run(&mul_a, &[sw, Tensor::from_f32(&self.mul, &[3])])?;
            let s = exec.run(&sub_a, &[m, Tensor::from_f32(&self.sub, &[3])])?;
            let d = exec.run(&div_a, &[s, Tensor::from_f32(&self.div, &[3])])?;
            let planar = exec.run(&split_a, &[d])?;
            out.extend_from_slice(planar.as_f32().context("planar f32")?);
        }
        Ok(Tensor::from_f32(&out, &[b, 3, dh, dw]))
    }
}

/// Keep a frame resident on device between iterations (both NPP and FastNPP
/// hold source data in GPU memory across a video loop).
pub struct DeviceFrame {
    pub value: DeviceValue,
    pub shape: Vec<usize>,
}

impl DeviceFrame {
    pub fn upload(frame: &Tensor) -> Result<DeviceFrame> {
        Ok(DeviceFrame { value: DeviceValue::upload(frame)?, shape: frame.shape().to_vec() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_construction() {
        let p = PreprocPipeline::new(
            ResizeBatchSpec { rects: vec![Rect::new(0, 0, 120, 60)], dst_h: 128, dst_w: 64 },
            [1.0; 3],
            [0.0; 3],
            [1.0; 3],
        );
        assert!(p.precomputed.is_none());
    }

    #[test]
    fn precompute_builds_inputs_once() {
        let mut p = PreprocPipeline::new(
            ResizeBatchSpec { rects: vec![Rect::new(0, 0, 120, 60)], dst_h: 128, dst_w: 64 },
            [2.0, 2.0, 2.0],
            [0.0; 3],
            [1.0; 3],
        );
        p.precompute();
        let inp = p.precomputed.as_ref().unwrap();
        assert_eq!(inp[0].shape(), &[1, 4]);
        assert_eq!(inp[1].as_f32().unwrap(), &[2.0, 2.0, 2.0]);
    }
}
