//! ExecGraph — the CUDA Graphs analog (baseline #2, DESIGN.md §3.3).
//!
//! CUDA Graphs record a DAG of kernel launches once, then replay it with a
//! single runtime call: per-launch CPU overhead disappears but the kernels
//! themselves are unchanged — no fusion, intermediates still round-trip
//! through device memory. `ExecGraph` reproduces exactly that: executables
//! and parameter buffers are resolved/uploaded at record time; `replay()`
//! only issues `execute_b` calls, chaining device-resident buffers.

use anyhow::{Context, Result};
use std::rc::Rc;

use crate::tensor::Tensor;

use super::exec::DeviceValue;
use super::{Executor, Registry};

/// Max in-flight intermediates during a replay before forcing a sync.
const SYNC_WINDOW: usize = 64;

/// One recorded launch: an executable plus, for each argument slot, either
/// the running value (None) or a pre-uploaded constant buffer (Some).
pub struct GraphNode {
    exe: Rc<xla::PjRtLoadedExecutable>,
    /// arg slots; None = wire the previous node's output here
    args: Vec<Option<DeviceValue>>,
    pub name: String,
}

/// A linear recorded chain of launches (the paper's per-op kernel sequence).
pub struct ExecGraph {
    nodes: Vec<GraphNode>,
}

impl ExecGraph {
    pub fn record() -> GraphBuilder {
        GraphBuilder { nodes: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Replay the chain on a fresh input. No host work besides the final
    /// download: this is the "single runtime call" the paper grants CUDA
    /// Graphs.
    ///
    /// PJRT executions are asynchronous: an intermediate buffer must stay
    /// alive until the final download (a sync point that transitively waits
    /// for every producer in the chain), so intermediates are parked in
    /// `spent` instead of dropped mid-flight.
    pub fn replay(&self, input: &Tensor) -> Result<Tensor> {
        let mut cur = DeviceValue::upload(input)?;
        let mut spent: Vec<DeviceValue> = Vec::with_capacity(SYNC_WINDOW + 1);
        for node in &self.nodes {
            let arg_refs: Vec<&xla::PjRtBuffer> = node
                .args
                .iter()
                .map(|slot| match slot {
                    Some(c) => &c.buf,
                    None => &cur.buf,
                })
                .collect();
            let result = node
                .exe
                .execute_b(&arg_refs)
                .map_err(|e| anyhow::anyhow!("graph node {}: {e}", node.name))?;
            let mut replica = result.into_iter().next().context("no replica")?;
            spent.push(cur);
            cur = DeviceValue::from_buffer(replica.remove(0));
            // bound live intermediates: long chains (the paper runs 19,902
            // kernels) would otherwise hold every intermediate until the
            // final sync -- O(chain) device memory. A cheap sync point every
            // SYNC_WINDOW nodes lets the window be dropped.
            if spent.len() >= SYNC_WINDOW {
                let _ = cur.buf.to_literal_sync().map_err(|e| anyhow::anyhow!("sync: {e}"))?;
                spent.clear();
            }
        }
        let out = cur.download(); // sync point: all producers complete here
        drop(spent);
        out
    }

    /// Replay keeping the result on device (for chained graphs). Returns the
    /// output plus the intermediate buffers, which the caller must keep alive
    /// until it syncs on the output (see `replay`).
    pub fn replay_device(&self, input: DeviceValue) -> Result<(DeviceValue, Vec<DeviceValue>)> {
        let mut cur = input;
        let mut spent: Vec<DeviceValue> = Vec::with_capacity(self.nodes.len());
        for node in &self.nodes {
            let arg_refs: Vec<&xla::PjRtBuffer> = node
                .args
                .iter()
                .map(|slot| match slot {
                    Some(c) => &c.buf,
                    None => &cur.buf,
                })
                .collect();
            let result = node
                .exe
                .execute_b(&arg_refs)
                .map_err(|e| anyhow::anyhow!("graph node {}: {e}", node.name))?;
            let mut replica = result.into_iter().next().context("no replica")?;
            spent.push(cur);
            cur = DeviceValue::from_buffer(replica.remove(0));
        }
        Ok((cur, spent))
    }
}

pub struct GraphBuilder {
    nodes: Vec<GraphNode>,
}

impl GraphBuilder {
    /// Record one launch. `const_args[i]` provides constant tensors by arg
    /// slot; the slot NOT present receives the running value.
    pub fn launch(
        mut self,
        executor: &Executor,
        registry: &Registry,
        name: &str,
        const_args: &[(usize, &Tensor)],
    ) -> Result<GraphBuilder> {
        let meta = registry.get(name).with_context(|| format!("unknown artifact {name}"))?;
        let n_args = meta.input_roles.len();
        let exe = registry.executable(name)?;
        let mut args: Vec<Option<DeviceValue>> = Vec::with_capacity(n_args);
        for slot in 0..n_args {
            match const_args.iter().find(|(s, _)| *s == slot) {
                Some((_, t)) => args.push(Some(DeviceValue::upload(t)?)),
                None => args.push(None),
            }
        }
        let n_wired = args.iter().filter(|a| a.is_none()).count();
        anyhow::ensure!(
            n_wired == 1,
            "graph node {name} must wire exactly one running-value slot (got {n_wired})"
        );
        let _ = executor;
        self.nodes.push(GraphNode { exe, args, name: name.to_string() });
        Ok(self)
    }

    pub fn finish(self) -> ExecGraph {
        ExecGraph { nodes: self.nodes }
    }
}
