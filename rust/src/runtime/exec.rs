//! Literal marshaling and the low-level executor.
//!
//! The marshaling boundary is the host<->device edge of the cost model
//! (DESIGN.md §2): `tensor_to_literal` + `buffer_from_host_literal` is the
//! H2D copy; `to_literal_sync` + `literal_to_tensor` the D2H. Engines that
//! chain executables keep `PjRtBuffer`s device-resident between steps.

use anyhow::{anyhow, bail, Context, Result};
use std::rc::Rc;

use crate::tensor::{DType, Tensor};

use super::registry::ArtifactMeta;
use super::Registry;

/// Host tensor -> XLA literal (copies once).
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    xla::Literal::create_from_shape_and_untyped_data(t.dtype().xla(), t.shape(), t.raw_bytes())
        .map_err(|e| anyhow!("literal from tensor: {e}"))
}

/// XLA literal -> host tensor.
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape().map_err(|e| anyhow!("literal shape: {e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let ty = lit.ty().map_err(|e| anyhow!("literal ty: {e}"))?;
    Ok(match ty {
        xla::ElementType::U8 => Tensor::from_u8(&lit.to_vec::<u8>().map_err(err)?, &dims),
        xla::ElementType::U16 => Tensor::from_u16(&lit.to_vec::<u16>().map_err(err)?, &dims),
        xla::ElementType::S32 => Tensor::from_i32(&lit.to_vec::<i32>().map_err(err)?, &dims),
        xla::ElementType::F32 => Tensor::from_f32(&lit.to_vec::<f32>().map_err(err)?, &dims),
        xla::ElementType::F64 => Tensor::from_f64(&lit.to_vec::<f64>().map_err(err)?, &dims),
        other => bail!("unsupported output element type {other:?}"),
    })
}

fn err(e: xla::Error) -> anyhow::Error {
    anyhow!("literal to_vec: {e}")
}

/// A device-resident value flowing between executable launches.
///
/// SAFETY NOTE: `buffer_from_host_literal` on the TFRT CPU client copies the
/// host literal *asynchronously*; the source `Literal` must outlive the copy
/// or the transfer reads freed memory (observed as nondeterministic segfaults
/// and size-check aborts). Uploaded values therefore keep their source
/// literal alive for the buffer's whole lifetime; buffers produced by
/// `execute_b` have no host source and carry `None`.
pub struct DeviceValue {
    pub buf: xla::PjRtBuffer,
    _keepalive: Option<xla::Literal>,
}

impl DeviceValue {
    /// Upload (H2D edge).
    pub fn upload(t: &Tensor) -> Result<DeviceValue> {
        let lit = tensor_to_literal(t)?;
        let buf = super::client()?
            .buffer_from_host_literal(None, &lit)
            .map_err(|e| anyhow!("upload: {e}"))?;
        Ok(DeviceValue { buf, _keepalive: Some(lit) })
    }

    /// Wrap an execute output (no host source).
    pub fn from_buffer(buf: xla::PjRtBuffer) -> DeviceValue {
        DeviceValue { buf, _keepalive: None }
    }

    /// Download (D2H edge).
    pub fn download(&self) -> Result<Tensor> {
        let lit = self.buf.to_literal_sync().map_err(|e| anyhow!("download: {e}"))?;
        literal_to_tensor(&lit)
    }
}

/// Executes artifacts by name, marshaling tensors at the boundary. All AOT
/// artifacts are lowered with `return_tuple=False` (single plain-array
/// output), so results chain directly between executables as device buffers.
pub struct Executor {
    registry: Rc<Registry>,
}

impl Executor {
    pub fn new(registry: Rc<Registry>) -> Executor {
        Executor { registry }
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Host->host execution: tensors in, tensor out. One full launch
    /// (H2D + dispatch + D2H) — the cost unit of the unfused baseline.
    /// Inputs are BORROWED: the H2D upload is the only copy, so hot paths
    /// (the NPP frame loop, the fused engines) never clone host tensors to
    /// launch.
    ///
    /// Implementation note: this goes through `execute_b` with explicitly
    /// managed input buffers rather than the crate's literal-based
    /// `execute`, because the latter *leaks* every input device buffer (its
    /// C++ side `release()`s the buffers to keep them alive across the async
    /// execution and never frees them) — a ~16 MB/launch leak on the
    /// data-size experiments. Here the final `to_literal_sync` is the sync
    /// point after which dropping the inputs is safe.
    pub fn run(&self, name: &str, inputs: &[&Tensor]) -> Result<Tensor> {
        let meta = self.registry.get(name).with_context(|| format!("unknown artifact {name}"))?;
        if inputs.len() != meta.input_roles.len() {
            bail!(
                "{name}: expected {} inputs ({:?}), got {}",
                meta.input_roles.len(),
                meta.input_roles,
                inputs.len()
            );
        }
        let exe = self.registry.executable(name)?;
        let devs: Vec<DeviceValue> =
            inputs.iter().map(|t| DeviceValue::upload(t)).collect::<Result<_>>()?;
        let refs: Vec<&xla::PjRtBuffer> = devs.iter().map(|d| &d.buf).collect();
        let result = exe.execute_b(&refs).map_err(|e| anyhow!("execute {name}: {e}"))?;
        let mut replica = result.into_iter().next().context("no replica output")?;
        if replica.is_empty() {
            bail!("{name}: empty output");
        }
        let out_buf = replica.remove(0);
        let out = out_buf.to_literal_sync().map_err(|e| anyhow!("sync {name}: {e}"))?;
        drop(devs); // inputs provably consumed after the output sync
        // artifacts are lowered with return_tuple=False: plain array root
        literal_to_tensor(&out)
    }

    /// Device->device execution: buffers in, buffers out, no host copies.
    /// The cost unit of a fused/graph-chained step.
    pub fn run_b(&self, name: &str, inputs: &[&xla::PjRtBuffer]) -> Result<xla::PjRtBuffer> {
        let exe = self.registry.executable(name)?;
        let result = exe.execute_b(inputs).map_err(|e| anyhow!("execute_b {name}: {e}"))?;
        let mut replica = result.into_iter().next().context("no replica output")?;
        // return_tuple=True artifacts yield the tuple's elements as separate
        // buffers in PJRT; a single logical output is element 0.
        if replica.is_empty() {
            bail!("{name}: empty output");
        }
        Ok(replica.remove(0))
    }

    /// Validate a data tensor against the artifact's declared data input.
    pub fn check_data_shape(&self, meta: &ArtifactMeta, t: &Tensor) -> Result<()> {
        let want_dt = DType::parse(&meta.dtin)
            .with_context(|| format!("bad dtin {} in manifest", meta.dtin))?;
        if t.dtype() != want_dt {
            bail!("{}: dtype {} != artifact dtin {}", meta.name, t.dtype(), want_dt);
        }
        let mut want_shape = vec![meta.batch];
        want_shape.extend_from_slice(&meta.shape);
        if t.shape() != want_shape.as_slice() {
            bail!("{}: shape {:?} != artifact {:?}", meta.name, t.shape(), want_shape);
        }
        Ok(())
    }
}
