//! Per-thread PJRT CPU client.
//!
//! The `xla` crate's `PjRtClient` is reference-counted with `Rc` (not
//! `Send`), so it cannot be shared across threads. Each thread that executes
//! XLA computations gets its own client, created on first use. In practice
//! the coordinator funnels all execution through one service thread (the
//! leader/worker split of DESIGN.md §4), so one client exists per process.

use std::cell::RefCell;

pub type Client = xla::PjRtClient;

thread_local! {
    static CLIENT: RefCell<Option<Client>> = const { RefCell::new(None) };
}

/// This thread's CPU PJRT client (created on first use).
pub fn client() -> anyhow::Result<Client> {
    CLIENT.with(|c| {
        let mut c = c.borrow_mut();
        if c.is_none() {
            *c = Some(
                xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu client: {e}"))?,
            );
        }
        Ok(c.as_ref().unwrap().clone())
    })
}

#[cfg(test)]
mod tests {
    #[test]
    fn client_is_usable() {
        let a = super::client().unwrap();
        assert!(a.device_count() >= 1);
        assert_eq!(a.platform_name(), "cpu");
    }
}
