//! Runtime — the PJRT bridge: load AOT HLO-text artifacts, compile once,
//! execute from the Rust hot path. Python is never involved here.
//!
//! The registry/metadata layer is pure Rust and always available. The
//! execution layer needs the `xla` crate (PJRT bindings) and is gated behind
//! the `pjrt` feature: without it, [`stub`] provides the same types with
//! run-time "built without pjrt" errors, and the host fused engine
//! ([`crate::exec::HostFusedEngine`]) is the backend that executes pipelines.

#[cfg(feature = "pjrt")]
mod client;
#[cfg(feature = "pjrt")]
mod exec;
#[cfg(feature = "pjrt")]
mod graph;
mod registry;
#[cfg(not(feature = "pjrt"))]
mod stub;

#[cfg(feature = "pjrt")]
pub use client::{client, Client};
#[cfg(feature = "pjrt")]
pub use exec::{literal_to_tensor, tensor_to_literal, DeviceValue, Executor};
#[cfg(feature = "pjrt")]
pub use graph::{ExecGraph, GraphNode};
pub use registry::{ArtifactMeta, Registry};
#[cfg(not(feature = "pjrt"))]
pub use stub::{DeviceValue, ExecGraph, Executor};
