//! Runtime — the PJRT bridge: load AOT HLO-text artifacts, compile once,
//! execute from the Rust hot path. Python is never involved here.

mod client;
mod exec;
mod graph;
mod registry;

pub use client::{client, Client};
pub use exec::{literal_to_tensor, tensor_to_literal, DeviceValue, Executor};
pub use graph::{ExecGraph, GraphNode};
pub use registry::{ArtifactMeta, Registry};
