//! No-PJRT stand-ins for the runtime execution types.
//!
//! Built when the `pjrt` feature is off: the manifest/registry layer stays
//! fully functional (it is pure Rust), while anything that would launch an
//! XLA executable fails with a clear error at RUN time instead of at compile
//! time. This keeps every engine, the coordinator and the experiments
//! compiling everywhere; the host fused engine
//! ([`crate::exec::HostFusedEngine`]) is the execution backend that actually
//! runs in these builds.

use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::tensor::{DType, Tensor};

use super::registry::ArtifactMeta;
use super::Registry;

const NO_PJRT: &str =
    "built without the `pjrt` feature: XLA artifact execution is unavailable \
     (the host fused engine serves pipelines in this configuration)";

/// Stand-in for the PJRT executor: artifact lookups still validate, launches
/// fail loudly.
pub struct Executor {
    registry: Rc<Registry>,
}

impl Executor {
    pub fn new(registry: Rc<Registry>) -> Executor {
        Executor { registry }
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Borrows its inputs (like the PJRT build): callers never clone tensors
    /// to launch.
    pub fn run(&self, name: &str, inputs: &[&Tensor]) -> Result<Tensor> {
        // arity check still works (metadata is loaded) so callers get the
        // most precise error available before the capability one
        if let Some(meta) = self.registry.get(name) {
            if inputs.len() != meta.input_roles.len() {
                bail!(
                    "{name}: expected {} inputs ({:?}), got {}",
                    meta.input_roles.len(),
                    meta.input_roles,
                    inputs.len()
                );
            }
        }
        bail!("cannot execute artifact {name}: {NO_PJRT}")
    }

    /// Validate a data tensor against the artifact's declared data input
    /// (identical to the PJRT build — pure metadata).
    pub fn check_data_shape(&self, meta: &ArtifactMeta, t: &Tensor) -> Result<()> {
        let want_dt = DType::parse(&meta.dtin)
            .with_context(|| format!("bad dtin {} in manifest", meta.dtin))?;
        if t.dtype() != want_dt {
            bail!("{}: dtype {} != artifact dtin {}", meta.name, t.dtype(), want_dt);
        }
        let mut want_shape = vec![meta.batch];
        want_shape.extend_from_slice(&meta.shape);
        if t.shape() != want_shape.as_slice() {
            bail!("{}: shape {:?} != artifact {:?}", meta.name, t.shape(), want_shape);
        }
        Ok(())
    }
}

/// Stand-in for a device-resident value.
pub struct DeviceValue;

impl DeviceValue {
    pub fn upload(_t: &Tensor) -> Result<DeviceValue> {
        bail!(NO_PJRT)
    }

    pub fn download(&self) -> Result<Tensor> {
        bail!(NO_PJRT)
    }
}

/// Stand-in for the recorded launch chain.
pub struct ExecGraph {
    nodes: usize,
}

impl ExecGraph {
    pub fn record() -> GraphBuilder {
        GraphBuilder {}
    }

    pub fn len(&self) -> usize {
        self.nodes
    }

    pub fn is_empty(&self) -> bool {
        self.nodes == 0
    }

    pub fn replay(&self, _input: &Tensor) -> Result<Tensor> {
        bail!(NO_PJRT)
    }
}

pub struct GraphBuilder {}

impl GraphBuilder {
    pub fn launch(
        self,
        _executor: &Executor,
        _registry: &Registry,
        name: &str,
        _const_args: &[(usize, &Tensor)],
    ) -> Result<GraphBuilder> {
        bail!("cannot record launch of {name}: {NO_PJRT}")
    }

    pub fn finish(self) -> ExecGraph {
        ExecGraph { nodes: 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly_not_silently() {
        assert!(DeviceValue::upload(&Tensor::zeros(DType::F32, &[1])).is_err());
        let g = ExecGraph::record().finish();
        assert!(g.is_empty());
        let err = g.replay(&Tensor::zeros(DType::F32, &[1])).unwrap_err();
        assert!(format!("{err:#}").contains("pjrt"), "{err:#}");
    }
}
