//! Artifact registry: manifest.json -> lazily compiled PJRT executables.
//!
//! This is the runtime face of the AOT family (DESIGN.md §5). Artifacts are
//! compiled on first use and cached for the process lifetime, so the steady
//! state cost of "launching a kernel" is one `execute()` call — the analog of
//! a pre-instantiated template kernel in the paper's C++ library.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
#[cfg(feature = "pjrt")]
use std::cell::RefCell;
#[cfg(feature = "pjrt")]
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use crate::jsonlite::{parse, Value};
use crate::ops::{Opcode, ALL_OPCODES};

/// Metadata of one AOT artifact (one manifest entry).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub variant: String,
    pub ops: Vec<String>,
    pub dtin: String,
    pub dtout: String,
    pub shape: Vec<usize>,
    pub batch: usize,
    pub kmax: usize,
    /// Input roles in argument order (data/params/trip/opcodes/frame/rects/vec3/rect).
    pub input_roles: Vec<String>,
    pub out_shape: Vec<usize>,
    pub out_dtype: String,
}

impl ArtifactMeta {
    fn from_json(v: &Value) -> Option<ArtifactMeta> {
        Some(ArtifactMeta {
            name: v["name"].as_str()?.to_string(),
            file: v["file"].as_str()?.to_string(),
            kind: v["kind"].as_str()?.to_string(),
            variant: v["variant"].as_str()?.to_string(),
            ops: v["ops"].as_str_vec().unwrap_or_default(),
            dtin: v["dtin"].as_str().unwrap_or("f32").to_string(),
            dtout: v["dtout"].as_str().unwrap_or("f32").to_string(),
            shape: v["shape"].as_usize_vec().unwrap_or_default(),
            batch: v["batch"].as_usize().unwrap_or(1),
            kmax: v["kmax"].as_usize().unwrap_or(0),
            input_roles: v["inputs"]
                .as_arr()?
                .iter()
                .filter_map(|i| i["role"].as_str().map(str::to_string))
                .collect(),
            out_shape: v["output"]["shape"].as_usize_vec().unwrap_or_default(),
            out_dtype: v["output"]["dtype"].as_str().unwrap_or("f32").to_string(),
        })
    }

    /// Canonical chain key: `ops|dtin->dtout|shape|batch`.
    pub fn chain_key(&self) -> String {
        format!(
            "{}|{}->{}|{}|b{}",
            self.ops.join("-"),
            self.dtin,
            self.dtout,
            self.shape.iter().map(|s| s.to_string()).collect::<Vec<_>>().join("x"),
            self.batch
        )
    }
}

/// Loaded manifest + compile cache.
pub struct Registry {
    dir: PathBuf,
    by_name: HashMap<String, ArtifactMeta>,
    /// experiment geometry the python side baked in (bucket lists etc.)
    pub geometry: Value,
    pub scale: String,
    #[cfg(feature = "pjrt")]
    compiled: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Registry {
    /// Load `<dir>/manifest.json`. Verifies the embedded opcode table matches
    /// this binary's [`Opcode`] enum (layer-drift guard).
    pub fn load(dir: impl AsRef<Path>) -> Result<Registry> {
        let dir = dir.as_ref().to_path_buf();
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {}; run `make artifacts` first", mpath.display()))?;
        let v = parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;

        // opcode drift check
        let opcodes = v["opcodes"].as_obj().context("manifest missing opcodes table")?;
        for op in ALL_OPCODES {
            let got = opcodes.get(op.name()).and_then(Value::as_i64);
            if got != Some(op.code() as i64) {
                bail!(
                    "opcode drift: python says {}={:?}, rust says {}",
                    op.name(),
                    got,
                    op.code()
                );
            }
        }
        if opcodes.len() != ALL_OPCODES.len() {
            bail!("opcode drift: python has {} ops, rust has {}", opcodes.len(), ALL_OPCODES.len());
        }

        let mut by_name = HashMap::new();
        for a in v["artifacts"].as_arr().context("manifest missing artifacts")? {
            let meta = ArtifactMeta::from_json(a).context("bad artifact entry")?;
            // single-op entries are emitted once per dtype combo; identical
            // names are identical artifacts, keep the first
            by_name.entry(meta.name.clone()).or_insert(meta);
        }
        Ok(Registry {
            dir,
            by_name,
            geometry: v["geometry"].clone(),
            scale: v["scale"].as_str().unwrap_or("scaled").to_string(),
            #[cfg(feature = "pjrt")]
            compiled: RefCell::new(HashMap::new()),
        })
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.by_name.get(name)
    }

    pub fn iter(&self) -> impl Iterator<Item = &ArtifactMeta> {
        self.by_name.values()
    }

    /// Find artifacts by predicate (planner tier lookups).
    pub fn find(&self, pred: impl Fn(&ArtifactMeta) -> bool) -> Vec<&ArtifactMeta> {
        let mut v: Vec<_> = self.by_name.values().filter(|m| pred(m)).collect();
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    /// Exact fused-chain lookup (planner tier 1).
    pub fn find_chain(
        &self,
        ops: &[Opcode],
        dtin: &str,
        dtout: &str,
        shape: &[usize],
        batch: usize,
        variant: &str,
    ) -> Option<&ArtifactMeta> {
        let names: Vec<&str> = ops.iter().map(|o| o.name()).collect();
        self.by_name.values().find(|m| {
            (m.kind == "chain" || m.kind == "single_op")
                && m.variant == variant
                && m.ops == names
                && m.dtin == dtin
                && m.dtout == dtout
                && m.shape == shape
                && m.batch == batch
        })
    }

    /// Compile (or fetch the cached) executable for artifact `name`.
    #[cfg(feature = "pjrt")]
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.compiled.borrow().get(name) {
            return Ok(exe.clone());
        }
        let meta = self.by_name.get(name).with_context(|| format!("unknown artifact {name}"))?;
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = super::client()?
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e}"))?;
        let exe = Rc::new(exe);
        self.compiled.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of executables compiled so far (metrics / tests).
    #[cfg(feature = "pjrt")]
    pub fn compiled_count(&self) -> usize {
        self.compiled.borrow().len()
    }

    /// Without PJRT nothing ever compiles.
    #[cfg(not(feature = "pjrt"))]
    pub fn compiled_count(&self) -> usize {
        0
    }
}
