//! Engine implementations.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::fusion::{plan_pipeline, unfused_plan, FusionPlan, PlanError, PlanInputs, PlannerStats};
use crate::ops::{IOp, Pipeline, Signature};
use crate::runtime::{ExecGraph, Executor, Registry};
use crate::tensor::Tensor;

/// A pipeline execution engine. Input is the batched data tensor
/// (`[batch, *shape]` in the pipeline's dtin); output the batched result.
pub trait Engine {
    fn name(&self) -> &'static str;
    fn run(&self, p: &Pipeline, input: &Tensor) -> Result<Tensor>;
    /// Kernel launches the last `run` issued (experiment reporting).
    fn last_launches(&self) -> usize;
}

/// Which execution backend a front door builds. Shared by
/// [`crate::cv::Context`] and [`crate::coordinator::Service`], so every
/// entry point degrades the same way.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineSelect {
    /// Prefer the XLA fused engine when the artifact registry loads (and the
    /// `pjrt` feature is compiled in); fall back to the host fused engine
    /// otherwise — the front door always comes up.
    #[default]
    Auto,
    /// XLA fused engine only: a missing/corrupt registry is a hard error.
    Xla,
    /// Host fused engine only: single-pass CPU execution, no artifacts, no
    /// PJRT — runs everywhere.
    HostFused,
}

/// Typed "this engine cannot lower that op" error. Raised by the artifact
/// engines for bodies outside the chain vocabulary (`ComputeC3`,
/// `CvtColor`) and — on the per-op engines, which are dense-only — for
/// structured boundary ops; [`FusedEngine::run`] counts the detection in
/// [`PlannerStats::unsupported`] / [`PlannerStats::structured`] and
/// re-routes the pipeline to the host single-pass engine (which runs both
/// lane-structured bodies and structured boundaries natively — see the
/// group pass and the pixel pass in `host_fused`) instead of failing with a
/// stringly message.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
#[error("{engine} engine does not support op `{token}` (chain vocabulary only)")]
pub struct UnsupportedOp {
    /// Engine that made the detection.
    pub engine: &'static str,
    /// Signature token of the offending op.
    pub token: String,
}

/// Typed error carried when [`catch_launch`] contains a panic: the launch is
/// poisoned, but only the requests riding on it fail — the caller (the
/// coordinator's serving ladder, the divergent lanes) keeps going.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
#[error("launch panicked (isolated): {msg}")]
pub struct LaunchPanic {
    /// The panic payload, rendered (`&str`/`String` payloads verbatim).
    pub msg: String,
}

/// Render a caught panic payload for [`LaunchPanic`].
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run one launch under `catch_unwind`, converting a panic into a typed
/// [`LaunchPanic`] error instead of unwinding through the service thread.
/// `AssertUnwindSafe` is sound here because every caller treats the launch
/// as failed wholesale on `Err` — no engine state is trusted mid-launch,
/// and the engines' interior mutability (plan caches, run counters) is
/// insert-only bookkeeping that stays coherent across an unwind.
pub fn catch_launch<T>(f: impl FnOnce() -> Result<T>) -> Result<T> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
        Ok(r) => r,
        Err(payload) => Err(LaunchPanic { msg: panic_message(payload.as_ref()) }.into()),
    }
}

fn body_names<'a>(p: &'a Pipeline, engine: &'static str) -> Result<Vec<&'a str>> {
    // dense per-op chains cannot reproduce a structured boundary's access
    // pattern — refuse with the typed error instead of silently executing
    // with the wrong layout (interrogate the boundary METADATA, never
    // sig-token strings)
    for op in [p.ops().first(), p.ops().last()].into_iter().flatten() {
        if matches!(op, IOp::Mem(m) if m.is_structured()) {
            return Err(UnsupportedOp { engine, token: op.sig_token() }.into());
        }
    }
    p.body()
        .iter()
        .map(|op| match op {
            IOp::Compute { op, .. } => Ok(op.name()),
            other => Err(UnsupportedOp { engine, token: other.sig_token() }.into()),
        })
        .collect()
}

fn body_param(p: &Pipeline, i: usize) -> f32 {
    match &p.body()[i] {
        IOp::Compute { param, .. } => *param as f32,
        _ => 0.0,
    }
}

// ---------------------------------------------------------------------------

/// The FKL engine: plan once per signature, then one launch per run.
pub struct FusedEngine {
    exec: Executor,
    reg: Rc<Registry>,
    plan_cache: RefCell<HashMap<Signature, FusionPlan>>,
    variant: String,
    last: RefCell<usize>,
    /// Lazily-built per-op fallback engine, shared across fallback runs
    /// (building one per call re-created an Executor + allocations on the
    /// hot path).
    unfused_fallback: RefCell<Option<Rc<UnfusedEngine>>>,
    /// Lazily-built host single-pass engine for bodies the XLA chain
    /// lowering cannot express (ComputeC3/CvtColor): the per-op engine
    /// rejects those too, so the host backend — which runs them natively,
    /// still fused — is the only fallback that can actually serve.
    host_fallback: RefCell<Option<Rc<super::HostFusedEngine>>>,
    /// Per-RUN tier counts: how the engine's traffic was actually served
    /// (exposed through coordinator metrics as VF coverage).
    stats: RefCell<PlannerStats>,
    last_fallback: Cell<bool>,
}

impl FusedEngine {
    pub fn new(reg: Rc<Registry>) -> FusedEngine {
        Self::with_variant(reg, "pallas")
    }

    /// `variant` selects the artifact lowering family ("pallas" or "xla") —
    /// the lowering ablation of DESIGN.md §3.6.
    pub fn with_variant(reg: Rc<Registry>, variant: &str) -> FusedEngine {
        FusedEngine {
            exec: Executor::new(reg.clone()),
            reg,
            plan_cache: RefCell::new(HashMap::new()),
            variant: variant.to_string(),
            last: RefCell::new(0),
            unfused_fallback: RefCell::new(None),
            host_fallback: RefCell::new(None),
            stats: RefCell::new(PlannerStats::default()),
            last_fallback: Cell::new(false),
        }
    }

    pub fn plan_for(&self, p: &Pipeline) -> Result<FusionPlan> {
        let sig = Signature::of(p);
        if let Some(plan) = self.plan_cache.borrow().get(&sig) {
            return Ok(plan.clone());
        }
        let plan = plan_pipeline(p, &self.reg, &self.variant)?;
        self.plan_cache.borrow_mut().insert(sig, plan.clone());
        Ok(plan)
    }

    pub fn executor(&self) -> &Executor {
        &self.exec
    }

    pub fn registry(&self) -> Rc<Registry> {
        self.reg.clone()
    }

    /// The shared per-op fallback engine (built on first fallback run).
    fn fallback_engine(&self) -> Rc<UnfusedEngine> {
        let mut slot = self.unfused_fallback.borrow_mut();
        slot.get_or_insert_with(|| Rc::new(UnfusedEngine::new(self.reg.clone()))).clone()
    }

    /// The shared host single-pass engine (built on first unsupported body).
    fn host_engine(&self) -> Rc<super::HostFusedEngine> {
        let mut slot = self.host_fallback.borrow_mut();
        slot.get_or_insert_with(|| Rc::new(super::HostFusedEngine::new())).clone()
    }

    /// Cumulative per-run tier counts (VF coverage of the served traffic).
    pub fn planner_stats(&self) -> PlannerStats {
        let mut stats = self.stats.borrow().clone();
        if let Some(host) = self.host_fallback.borrow().as_ref() {
            // host-tier re-routes run register-blocked — mirror the lane
            // telemetry so vectorization coverage survives the re-route
            stats.vectorized = host.vector_runs();
            stats.vector_width = host.vector_width();
            // the byte model lives on host plans: surface whatever the host
            // tier moved (artifact launches are accounted upstream)
            stats.bytes_read = host.bytes_read();
            stats.bytes_written = host.bytes_written();
            stats.bytes_baseline = host.bytes_baseline();
        }
        stats
    }

    /// Serve a WINDOW of pipelines. One artifact launch binds ONE code
    /// shape, so the window planner ([`crate::fusion::plan_window`])
    /// refuses a signature-divergent window with the typed
    /// [`PlanError::Divergent`]; this front door counts the detection in
    /// [`PlannerStats::divergent`] and partitions the window: an item the
    /// artifact tiers DO cover keeps its own artifact launch — its result
    /// bits never depend on window company — and the refused remainder
    /// (lane-structured bodies, structured boundaries, reductions,
    /// uncovered shapes) serves in ONE host divergent-HF pass
    /// ([`HostFusedEngine::run_divergent`](super::HostFusedEngine::run_divergent)),
    /// tallied under the host tier. Signature-homogeneous windows run
    /// through the normal per-run artifact path — the coordinator stacks
    /// those upstream.
    pub fn run_many(&self, window: &[(&Pipeline, &Tensor)]) -> super::DivergentOutcome {
        if window.is_empty() {
            return super::DivergentOutcome::empty();
        }
        let pipes: Vec<&Pipeline> = window.iter().map(|&(p, _)| p).collect();
        match crate::fusion::plan_window(&pipes, &self.reg, &self.variant) {
            Err(PlanError::Divergent(_)) => {
                self.stats.borrow_mut().divergent += 1;
                self.last_fallback.set(false);
                let covered: Vec<bool> =
                    pipes.iter().map(|p| self.plan_for(p).is_ok()).collect();
                let host_items: Vec<(&Pipeline, &Tensor)> = window
                    .iter()
                    .zip(&covered)
                    .filter(|&(_, &c)| !c)
                    .map(|(&item, _)| item)
                    .collect();
                let host_out = (!host_items.is_empty())
                    .then(|| self.host_engine().run_divergent(&host_items));
                let (host_results, lanes, work, padded, divergent_pass) = match host_out {
                    Some(o) => {
                        self.stats.borrow_mut().host +=
                            o.results.iter().filter(|r| r.is_ok()).count();
                        (o.results, o.lanes, o.total_work_elems, o.padded_work_elems, true)
                    }
                    None => (Vec::new(), 0, 0, 0, false),
                };
                let mut host_iter = host_results.into_iter();
                let mut launches = divergent_pass as usize;
                let mut results = Vec::with_capacity(window.len());
                for (&(p, t), &c) in window.iter().zip(&covered) {
                    if c {
                        results.push(self.run(p, t));
                        launches += self.last_launches();
                    } else {
                        let res = host_iter.next().expect("one host result per refused item");
                        results.push(res);
                    }
                }
                *self.last.borrow_mut() = launches;
                let distinct_signatures = {
                    let sigs: std::collections::HashSet<Signature> =
                        pipes.iter().map(|p| Signature::of(p)).collect();
                    sigs.len()
                };
                super::DivergentOutcome {
                    results,
                    divergent_pass,
                    lanes,
                    launches,
                    distinct_signatures,
                    total_work_elems: work,
                    padded_work_elems: padded,
                }
            }
            _ => {
                // homogeneous window (or a refusal the per-run path already
                // detects, counts and re-routes itself): serve item by item
                // through the artifact path
                let results: Vec<Result<Tensor>> =
                    window.iter().map(|&(p, t)| self.run(p, t)).collect();
                super::DivergentOutcome {
                    divergent_pass: false,
                    lanes: 1,
                    launches: window.len(),
                    distinct_signatures: 1,
                    total_work_elems: pipes.iter().map(|p| p.batch * p.item_elems()).sum(),
                    padded_work_elems: 0,
                    results,
                }
            }
        }
    }

    /// True if the most recent `run` took the per-op fallback path.
    pub fn last_was_fallback(&self) -> bool {
        self.last_fallback.get()
    }
}

impl Engine for FusedEngine {
    fn name(&self) -> &'static str {
        "fused"
    }

    fn run(&self, p: &Pipeline, input: &Tensor) -> Result<Tensor> {
        let plan = match self.plan_for(p) {
            Ok(plan) => plan,
            Err(e) => {
                // three pipeline families the ARTIFACT tiers cannot express:
                // lane-structured bodies (ComputeC3/CvtColor — outside the
                // XLA chain vocabulary), structured boundaries (crop /
                // resize reads, split writes — a dense chain artifact would
                // execute the wrong memory pattern) and reduce terminators
                // (a different kernel shape entirely: nothing dense
                // accumulates). The per-op fallback rejects all three too;
                // the host single-pass engine runs them NATIVELY, still one
                // fused memory pass (the fold-while-reading tier for
                // reductions). Typed detection, counted per family, routed
                // — tallied under the host tier.
                let token = match e.downcast_ref::<PlanError>() {
                    Some(PlanError::NotAChain(t)) => {
                        self.stats.borrow_mut().unsupported += 1;
                        t.clone()
                    }
                    Some(PlanError::StructuredBoundary(t)) => {
                        self.stats.borrow_mut().structured += 1;
                        t.clone()
                    }
                    Some(PlanError::Reduction(t)) => {
                        self.stats.borrow_mut().reduction += 1;
                        t.clone()
                    }
                    _ => return Err(e),
                };
                self.last_fallback.set(false);
                *self.last.borrow_mut() = 1;
                let host = self.host_engine();
                return match host.run(p, input) {
                    Ok(t) => {
                        self.stats.borrow_mut().host += 1;
                        Ok(t)
                    }
                    Err(fe) => Err(fe.context(UnsupportedOp { engine: "fused", token })),
                };
            }
        };
        *self.last.borrow_mut() = plan.launches();
        self.last_fallback.set(matches!(plan, FusionPlan::Unfused { .. }));
        let result = match &plan {
            FusionPlan::Exact { artifact } => {
                let params = PlanInputs::chain_params(p);
                self.exec.run(artifact, &[input, &params])
            }
            FusionPlan::StaticLoop { artifact, iters } => {
                let meta = self.reg.get(artifact).context("plan artifact vanished")?;
                let (trip, params) = PlanInputs::staticloop_inputs(p, meta.ops.len(), *iters);
                self.exec.run(artifact, &[&trip, input, &params])
            }
            FusionPlan::Interp { artifact, kmax } => {
                let (opc, par) = PlanInputs::interp_inputs(p, *kmax);
                self.exec.run(artifact, &[input, &opc, &par])
            }
            FusionPlan::Unfused { .. } => {
                // planner had no fused coverage; run the per-op fallback
                // (cached: building an engine per call cost an Executor +
                // allocations every time)
                self.fallback_engine().run(p, input)
            }
        };
        // tally tiers only for runs that actually served traffic, so
        // fused-coverage metrics never count errored launches
        if result.is_ok() {
            let mut st = self.stats.borrow_mut();
            match &plan {
                FusionPlan::Exact { .. } => st.exact += 1,
                FusionPlan::StaticLoop { .. } => st.staticloop += 1,
                FusionPlan::Interp { .. } => st.interp += 1,
                FusionPlan::Unfused { .. } => st.unfused += 1,
            }
        }
        result
    }

    fn last_launches(&self) -> usize {
        *self.last.borrow()
    }
}

// ---------------------------------------------------------------------------

/// The OpenCV-CUDA/NPP analog: one launch per op (per batch item when only
/// b=1 artifacts exist, like OpenCV's per-crop loop), intermediates written
/// back to device memory between launches, params re-marshaled per call.
pub struct UnfusedEngine {
    exec: Executor,
    reg: Rc<Registry>,
    last: RefCell<usize>,
}

impl UnfusedEngine {
    pub fn new(reg: Rc<Registry>) -> UnfusedEngine {
        UnfusedEngine { exec: Executor::new(reg.clone()), reg, last: RefCell::new(0) }
    }

    fn steps(&self, p: &Pipeline) -> Result<Vec<String>> {
        let names = body_names(p, "unfused")?;
        match unfused_plan(p, &self.reg, &names) {
            Some(FusionPlan::Unfused { artifacts }) => Ok(artifacts),
            _ => bail!("no single-op artifact coverage for {}", Signature::of(p)),
        }
    }
}

impl Engine for UnfusedEngine {
    fn name(&self) -> &'static str {
        "unfused"
    }

    fn run(&self, p: &Pipeline, input: &Tensor) -> Result<Tensor> {
        let steps = self.steps(p)?;
        let mut launches = 0usize;

        let first = self.reg.get(&steps[0]).context("step artifact missing")?;
        let per_item = first.batch == 1 && p.batch > 1;

        let run_chain = |item: &Tensor, launches: &mut usize| -> Result<Tensor> {
            let mut cur = item.clone();
            for (i, name) in steps.iter().enumerate() {
                // param literal rebuilt every call = the per-call CPU work of
                // the original libraries (measured by Exp. 6)
                let params = Tensor::from_f32(&[body_param(p, i)], &[1]);
                let next = self.exec.run(name, &[&cur, &params])?;
                cur = next;
                *launches += 1;
            }
            Ok(cur)
        };

        let out = if per_item {
            let item_elems = p.item_elems();
            let mut parts: Vec<Tensor> = Vec::with_capacity(p.batch);
            for b in 0..p.batch {
                let item = slice_batch(input, b, item_elems, &p.shape);
                parts.push(run_chain(&item, &mut launches)?);
            }
            concat_batch(&parts, &p.shape)
        } else {
            run_chain(input, &mut launches)?
        };
        *self.last.borrow_mut() = launches;
        Ok(out)
    }

    fn last_launches(&self) -> usize {
        *self.last.borrow()
    }
}

/// Extract item `b` of a batched tensor as a `[1, *shape]` tensor.
pub fn slice_batch(t: &Tensor, b: usize, item_elems: usize, shape: &[usize]) -> Tensor {
    let mut item_shape = vec![1usize];
    item_shape.extend_from_slice(shape);
    let lo = b * item_elems;
    let hi = lo + item_elems;
    use crate::tensor::TensorData::*;
    match t.data() {
        U8(v) => Tensor::from_u8(&v[lo..hi], &item_shape),
        U16(v) => Tensor::from_u16(&v[lo..hi], &item_shape),
        I32(v) => Tensor::from_i32(&v[lo..hi], &item_shape),
        F32(v) => Tensor::from_f32(&v[lo..hi], &item_shape),
        F64(v) => Tensor::from_f64(&v[lo..hi], &item_shape),
    }
}

/// Concatenate `[1, *shape]` items back into `[B, *shape]`.
pub fn concat_batch(parts: &[Tensor], shape: &[usize]) -> Tensor {
    assert!(!parts.is_empty());
    let mut full_shape = vec![parts.len()];
    full_shape.extend_from_slice(shape);
    use crate::tensor::TensorData::*;
    macro_rules! cat {
        ($variant:ident, $ctor:ident, $t:ty) => {{
            let mut v: Vec<$t> = Vec::with_capacity(parts.len() * parts[0].len());
            for p in parts {
                match p.data() {
                    $variant(d) => v.extend_from_slice(d),
                    _ => panic!("mixed dtypes in concat_batch"),
                }
            }
            Tensor::$ctor(&v, &full_shape)
        }};
    }
    match parts[0].data() {
        U8(_) => cat!(U8, from_u8, u8),
        U16(_) => cat!(U16, from_u16, u16),
        I32(_) => cat!(I32, from_i32, i32),
        F32(_) => cat!(F32, from_f32, f32),
        F64(_) => cat!(F64, from_f64, f64),
    }
}

/// Stack `items` (each `[1, *shape]`) into one `[bucket, *shape]` batch with
/// a SINGLE allocation and one copy per item, replicating the last item into
/// the `bucket - items.len()` pad planes. This is the coordinator's
/// group-stacking hot path: the clone-each-item-then-`concat_batch` pattern
/// it replaces copied every plane twice and allocated per item.
pub fn stack_batch(items: &[&Tensor], bucket: usize, shape: &[usize]) -> Tensor {
    assert!(!items.is_empty(), "stack_batch needs at least one item");
    assert!(bucket >= items.len(), "bucket {bucket} < items {}", items.len());
    let mut full_shape = vec![bucket];
    full_shape.extend_from_slice(shape);
    use crate::tensor::TensorData::*;
    macro_rules! stack {
        ($variant:ident, $t:ty) => {{
            let item_len = items[0].len();
            let mut v: Vec<$t> = Vec::with_capacity(bucket * item_len);
            for it in items {
                match it.data() {
                    $variant(d) => v.extend_from_slice(d),
                    _ => panic!("mixed dtypes in stack_batch"),
                }
            }
            let last = match items[items.len() - 1].data() {
                $variant(d) => d,
                _ => unreachable!("dtype checked above"),
            };
            for _ in items.len()..bucket {
                v.extend_from_slice(last);
            }
            Tensor::from_data($variant(v), &full_shape)
        }};
    }
    match items[0].data() {
        U8(_) => stack!(U8, u8),
        U16(_) => stack!(U16, u16),
        I32(_) => stack!(I32, i32),
        F32(_) => stack!(F32, f32),
        F64(_) => stack!(F64, f64),
    }
}

// ---------------------------------------------------------------------------

/// The CUDA Graphs analog: per-op chain recorded once per signature, then
/// replayed. Same kernels and memory traffic as [`UnfusedEngine`]; no
/// per-step host work on replay.
pub struct GraphEngine {
    exec: Executor,
    reg: Rc<Registry>,
    graphs: RefCell<HashMap<Signature, Rc<(ExecGraph, usize)>>>,
    last: RefCell<usize>,
}

impl GraphEngine {
    pub fn new(reg: Rc<Registry>) -> GraphEngine {
        GraphEngine {
            exec: Executor::new(reg.clone()),
            reg,
            graphs: RefCell::new(HashMap::new()),
            last: RefCell::new(0),
        }
    }

    /// Returns (graph, first_step_batch).
    fn graph_for(&self, p: &Pipeline) -> Result<Rc<(ExecGraph, usize)>> {
        let sig = Signature::of(p);
        if let Some(g) = self.graphs.borrow().get(&sig) {
            return Ok(g.clone());
        }
        let names = body_names(p, "graph")?;
        let Some(FusionPlan::Unfused { artifacts }) = unfused_plan(p, &self.reg, &names) else {
            bail!("no single-op artifact coverage for {}", Signature::of(p))
        };
        let first_batch =
            self.reg.get(&artifacts[0]).context("step artifact missing")?.batch;
        let mut builder = ExecGraph::record();
        for (i, name) in artifacts.iter().enumerate() {
            let params = Tensor::from_f32(&[body_param(p, i)], &[1]);
            builder = builder.launch(&self.exec, &self.reg, name, &[(1, &params)])?;
        }
        let g = Rc::new((builder.finish(), first_batch));
        self.graphs.borrow_mut().insert(sig, g.clone());
        Ok(g)
    }
}

impl Engine for GraphEngine {
    fn name(&self) -> &'static str {
        "graph"
    }

    fn run(&self, p: &Pipeline, input: &Tensor) -> Result<Tensor> {
        let g = self.graph_for(p)?;
        let (graph, first_batch) = (&g.0, g.1);
        let per_item = first_batch == 1 && p.batch > 1;
        let out = if per_item {
            let item_elems = p.item_elems();
            let mut parts = Vec::with_capacity(p.batch);
            for b in 0..p.batch {
                let item = slice_batch(input, b, item_elems, &p.shape);
                parts.push(graph.replay(&item)?);
            }
            *self.last.borrow_mut() = graph.len() * p.batch;
            concat_batch(&parts, &p.shape)
        } else {
            *self.last.borrow_mut() = graph.len();
            graph.replay(input)?
        };
        Ok(out)
    }

    fn last_launches(&self) -> usize {
        *self.last.borrow()
    }
}
