//! Execution engines: the three ways a pipeline runs in the experiments.
//!
//! * [`FusedEngine`] — the FKL path: the planner maps the pipeline onto ONE
//!   fused artifact launch (VF; batched artifacts add HF).
//! * [`UnfusedEngine`] — the OpenCV-CUDA/NPP analog: one launch per op, with
//!   intermediates round-tripping through device buffers, and per-call
//!   host-side parameter work (paper Fig. 3A / Fig. 25 top).
//! * [`GraphEngine`] — the CUDA Graphs analog: same per-op launches, but the
//!   chain is recorded once and replayed without per-step host work.
//!
//! All three implement [`Engine`] and must agree numerically with
//! [`crate::hostref`] (enforced by `rust/tests/engines_equivalence.rs`).

mod engines;

pub use engines::{concat_batch, slice_batch, Engine, FusedEngine, GraphEngine, UnfusedEngine};
