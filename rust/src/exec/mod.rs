//! Execution engines: the four ways a pipeline runs in the experiments.
//!
//! * [`FusedEngine`] — the FKL path: the planner maps the pipeline onto ONE
//!   fused artifact launch (VF; batched artifacts add HF).
//! * [`UnfusedEngine`] — the OpenCV-CUDA/NPP analog: one launch per op, with
//!   intermediates round-tripping through device buffers, and per-call
//!   host-side parameter work (paper Fig. 3A / Fig. 25 top).
//! * [`GraphEngine`] — the CUDA Graphs analog: same per-op launches, but the
//!   chain is recorded once and replayed without per-step host work.
//! * [`HostFusedEngine`] — vertical fusion compiled for the HOST (DESIGN.md
//!   §3.5): one memory pass with register-resident intermediates, batch
//!   chunked across threads; runs everywhere, no PJRT or artifacts required.
//!   Executes the paper's structured boundaries natively — crop / bilinear
//!   crop+resize reads gather while reading, split writes scatter planar
//!   while writing — so the flagship preproc workload serves on any machine.
//!   Its divergent-HF tier ([`HostFusedEngine::run_divergent`]) serves a
//!   WINDOW of mixed pipelines (different params, signatures, chain
//!   lengths) in one thread-chunked pass, bit-equal to per-item serving.
//!
//! All implement [`Engine`] and must agree numerically with
//! [`crate::hostref`] (enforced by `rust/tests/engines_equivalence.rs` and
//! `rust/tests/host_fused_props.rs`).

mod engines;
mod host_fused;

pub use engines::{
    catch_launch, concat_batch, panic_message, slice_batch, stack_batch, Engine, EngineSelect,
    FusedEngine, GraphEngine, LaunchPanic, UnfusedEngine, UnsupportedOp,
};
pub use host_fused::{DivergentOutcome, HostFusedEngine, HostLane};
