//! HostFusedEngine — vertical fusion on the CPU: ONE memory pass per run.
//!
//! This is the backend that runs everywhere (no PJRT, no artifacts). It
//! reproduces the paper's fusion story on the host, including its THREE-PART
//! kernel shape (ReadOp -> compute chain -> WriteOp, Fig. 10/11): the
//! boundary operations own the memory access pattern. A dense chain reads
//! each element once, folds the entire op chain through a register-resident
//! accumulator, and writes each element once — where the op-at-a-time
//! reference ([`crate::hostref::run_pipeline`]) widens the whole buffer to
//! f64 and sweeps it once per op. A STRUCTURED boundary fuses its access
//! pattern into the same single pass: a crop+resize read performs the
//! bilinear gather *while reading* (the resized intermediate never exists in
//! memory), and a split write scatters packed pixels to planar planes
//! *while writing* (the packed result never exists either). The batch /
//! row dimension is chunked across OS threads, the host analog of
//! Horizontal Fusion filling the GPU with independent planes.
//!
//! Reduce-terminated pipelines take the FOLD-WHILE-READING tier: the same
//! single pass, but instead of writing each element the chain's output folds
//! into per-block statistics accumulators (`kernel::REDUCE_BLOCK` elements
//! per block) combined in a fixed pairwise tree — deterministic across
//! thread counts and bit-equal to the hostref reduction oracle, which runs
//! the same shared blocked-reduction table over its materialized buffer.
//!
//! Loops are monomorphized per (reader, input dtype, output dtype, writer):
//! an f32 chain never touches f64, a u8→f32 normalization chain reads bytes
//! and writes floats with no whole-buffer widening step, and the structured
//! fast paths cost no runtime dispatch inside the loop. Numerics contract
//! (enforced by `rust/tests/host_fused_props.rs` and
//! `rust/tests/structured_props.rs`): bit-compatible with the oracle on
//! every f64-accumulated path — which includes ALL integer outputs AND all
//! structured passes — and within float epsilon on the f32 fast path. The
//! structured gather itself is shared code ([`crate::ops::kernel`]'s
//! bilinear tap table), so the oracle and this engine cannot drift.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{ensure, Result};

use crate::fusion::{DivergentPlan, HostAccum, HostPlan, ReaderKind, WriterKind};
use crate::ops::{
    kernel, Opcode, Pipeline, ReadPattern, ReduceSpec, ScalarOp, Signature, WritePattern,
};
use crate::tensor::{Rect, Tensor, TensorData};

use super::Engine;

/// Below this many total elements a run stays single-threaded: thread spawn
/// costs tens of microseconds, which dwarfs small pipelines.
const MIN_ELEMS_PER_THREAD: usize = 32 * 1024;

/// The host vertical-fusion engine. Plans are cached per [`Signature`]
/// (params — chain scalars AND crop rects — are bound per run, mirroring
/// [`super::FusedEngine::plan_for`]).
pub struct HostFusedEngine {
    plans: RefCell<HashMap<Signature, Rc<HostPlan>>>,
    threads: usize,
    /// Register-block width override. `None` (production) lets every plan
    /// run at its own [`HostPlan::vectorization`] width; `Some(1)` forces
    /// the scalar arm — the ablation baseline the SIMD bench and the
    /// differential fuzz harness compare against. Widths never change
    /// results on any f64 path (bit-equal by construction) and stay within
    /// float epsilon on the f32 fast arm.
    lane_width: Option<u8>,
    runs: Cell<usize>,
    structured: Cell<usize>,
    reduces: Cell<usize>,
    divergent: Cell<usize>,
    vector_runs: Cell<usize>,
    vector_width: Cell<u8>,
    /// Fusion-efficiency accounting, accumulated per completed run from the
    /// plan's static byte model: bytes actually read / written by the fused
    /// passes, and what an op-at-a-time baseline would have moved.
    bytes_read: Cell<u64>,
    bytes_written: Cell<u64>,
    bytes_baseline: Cell<u64>,
    /// Armed fault injector (absent in production — zero cost when off).
    /// Consulted once per divergent-window item, serially in window order
    /// BEFORE the lanes spawn, so injected faults land at deterministic
    /// launch indices regardless of lane scheduling.
    faults: Option<std::sync::Arc<crate::faults::FaultInjector>>,
    /// Armed span recorder (absent in production — when `None`, tracing
    /// compiles down to a skipped branch per run). [`Engine::run`] records
    /// one `launch` span per fused pass. The serving coordinator does NOT
    /// arm this — it records launch spans itself inside each request's span
    /// tree; this knob is for standalone library use.
    tracer: Option<std::sync::Arc<crate::trace::Tracer>>,
}

impl HostFusedEngine {
    /// Engine with one worker per available core.
    pub fn new() -> HostFusedEngine {
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::with_threads(threads)
    }

    /// Engine with a fixed worker count (1 = the pure VF ablation: single
    /// pass, no batch-dimension parallelism).
    pub fn with_threads(threads: usize) -> HostFusedEngine {
        HostFusedEngine {
            plans: RefCell::new(HashMap::new()),
            threads: threads.max(1),
            lane_width: None,
            runs: Cell::new(0),
            structured: Cell::new(0),
            reduces: Cell::new(0),
            divergent: Cell::new(0),
            vector_runs: Cell::new(0),
            vector_width: Cell::new(0),
            bytes_read: Cell::new(0),
            bytes_written: Cell::new(0),
            bytes_baseline: Cell::new(0),
            faults: None,
            tracer: None,
        }
    }

    /// Force every run to a fixed register-block width instead of the
    /// plan-selected one. `1` is the scalar arm (the pre-SIMD loops) — the
    /// baseline of the `simd_bench` ablation and the scalar-vs-vector leg
    /// of the differential fuzz harness. Results are unchanged on every
    /// f64 path and within float epsilon on the f32 fast arm.
    pub fn with_lane_width(mut self, width: u8) -> HostFusedEngine {
        self.lane_width = Some(width.max(1));
        self
    }

    /// Arm a fault injector: divergent-window items consult it (tier
    /// `Divergent`) and fail alone when selected — the harness for proving
    /// the window's failure-isolation contract.
    pub fn with_fault_injector(
        mut self,
        faults: std::sync::Arc<crate::faults::FaultInjector>,
    ) -> HostFusedEngine {
        self.faults = Some(faults);
        self
    }

    /// Arm a span recorder: every [`Engine::run`] records one `launch` span
    /// (elements, register-block width, worker threads, duration) into the
    /// tracer's fixed ring. Zero-allocation on the hot path; when never
    /// called the engine carries no tracing cost beyond one `Option` check.
    pub fn with_tracer(
        mut self,
        tracer: std::sync::Arc<crate::trace::Tracer>,
    ) -> HostFusedEngine {
        self.tracer = Some(tracer);
        self
    }

    /// Plan lookup/compile, cached per signature.
    pub fn plan_for(&self, p: &Pipeline) -> Rc<HostPlan> {
        let sig = Signature::of(p);
        if let Some(plan) = self.plans.borrow().get(&sig) {
            return plan.clone();
        }
        let plan = Rc::new(HostPlan::compile(p));
        self.plans.borrow_mut().insert(sig, plan.clone());
        plan
    }

    pub fn plan_cache_len(&self) -> usize {
        self.plans.borrow().len()
    }

    /// True when `p`'s signature already has a compiled plan — the probe the
    /// serving coordinator uses to label its `plan` span hit/miss WITHOUT
    /// perturbing the cache.
    pub fn plan_cached(&self, p: &Pipeline) -> bool {
        self.plans.borrow().contains_key(&Signature::of(p))
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Completed runs (each is exactly one fused memory pass).
    pub fn runs(&self) -> usize {
        self.runs.get()
    }

    /// Completed runs whose pipeline carried a structured boundary (a
    /// subset of [`HostFusedEngine::runs`]) — surfaced through
    /// [`crate::fusion::PlannerStats::structured`] so structured traffic is
    /// observable in serving dashboards.
    pub fn structured_runs(&self) -> usize {
        self.structured.get()
    }

    /// Completed runs that ended in a reduce terminator (the
    /// fold-while-reading tier) — surfaced through
    /// [`crate::fusion::PlannerStats::reduction`] so the reduce workload is
    /// observable in serving dashboards, like structured traffic.
    pub fn reduce_runs(&self) -> usize {
        self.reduces.get()
    }

    /// Divergent-HF windows served ([`HostFusedEngine::run_divergent`]) —
    /// surfaced through [`crate::fusion::PlannerStats::divergent`]. A
    /// WINDOW counter: the per-item serves inside each window land in
    /// [`HostFusedEngine::runs`] (and its structured/reduce sub-counts)
    /// exactly as if they had been served alone.
    pub fn divergent_runs(&self) -> usize {
        self.divergent.get()
    }

    /// Completed runs that took a register-blocked loop (effective width
    /// > 1; every run in production — the scalar arm exists only under a
    /// [`HostFusedEngine::with_lane_width`] override) — surfaced through
    /// [`crate::fusion::PlannerStats::vectorized`].
    pub fn vector_runs(&self) -> usize {
        self.vector_runs.get()
    }

    /// Widest register block any completed run used (0 before the first
    /// run) — surfaced through [`crate::fusion::PlannerStats::vector_width`]
    /// so perf dashboards show which SIMD shape actually served.
    pub fn vector_width(&self) -> u8 {
        self.vector_width.get()
    }

    /// Bytes the fused passes actually read across all completed runs —
    /// surfaced through [`crate::fusion::PlannerStats::bytes_read`].
    pub fn bytes_read(&self) -> u64 {
        self.bytes_read.get()
    }

    /// Bytes the fused passes actually wrote across all completed runs —
    /// surfaced through [`crate::fusion::PlannerStats::bytes_written`].
    pub fn bytes_written(&self) -> u64 {
        self.bytes_written.get()
    }

    /// Bytes an op-at-a-time execution of the same runs would have moved
    /// ([`HostPlan::bytes_baseline`], static from the IR) — surfaced through
    /// [`crate::fusion::PlannerStats::bytes_baseline`]. The ratio
    /// `bytes_baseline / (bytes_read + bytes_written)` is the engine's
    /// measured fusion efficiency (≈(k+1)/2 for same-width dense chain-k).
    pub fn bytes_baseline(&self) -> u64 {
        self.bytes_baseline.get()
    }

    /// The register-block width a run of `plan` executes at: the engine
    /// override if set, else the plan's own [`HostPlan::vectorization`] —
    /// divergent-window items each pick their width from their OWN sub-plan.
    fn effective_width(&self, plan: &HostPlan) -> u8 {
        self.lane_width.unwrap_or_else(|| plan.vectorization())
    }

    fn observe_run(&self, structured: bool, reduce: bool, width: u8) {
        self.runs.set(self.runs.get() + 1);
        if structured {
            self.structured.set(self.structured.get() + 1);
        }
        if reduce {
            self.reduces.set(self.reduces.get() + 1);
        }
        if width > 1 {
            self.vector_runs.set(self.vector_runs.get() + 1);
        }
        if width > self.vector_width.get() {
            self.vector_width.set(width);
        }
    }

    /// [`HostFusedEngine::observe_run`] driven by the plan's boundary
    /// metadata (shared by the single-run path and the divergent lanes),
    /// plus the plan's per-run byte accounting.
    fn observe_plan_run(&self, plan: &HostPlan) {
        let reduce = plan.reduce().is_some();
        let structured = plan.reader() != ReaderKind::Dense
            || (!reduce && plan.writer() != WriterKind::Dense);
        self.observe_run(structured, reduce, self.effective_width(plan));
        self.bytes_read.set(self.bytes_read.get() + plan.bytes_read() as u64);
        self.bytes_written.set(self.bytes_written.get() + plan.bytes_written() as u64);
        self.bytes_baseline.set(self.bytes_baseline.get() + plan.bytes_baseline() as u64);
    }

    /// The DIVERGENT-HF tier: serve a window of HETEROGENEOUS pipelines —
    /// different params, signatures and chain lengths; dense, structured
    /// and reduce terminators alike — in ONE thread-chunked pass. The
    /// window compiles to a [`DivergentPlan`] (per-item sub-plans from the
    /// shared per-signature cache; items weighted by element count and
    /// chunked across worker lanes), then every lane dispatches its items'
    /// monomorphized loops back-to-back: register-resident intermediates
    /// preserved, structured items gathering/scattering while they
    /// read/write, reduce items folding into their own accumulators in the
    /// same sweep. Per-item results are BIT-EQUAL to serving each request
    /// alone ([`Engine::run`]) — every pass is thread-count invariant, so
    /// lane placement never shows in the output — and one failing item
    /// fails ALONE (its slot carries the error; the window still serves).
    pub fn run_divergent(&self, window: &[(&Pipeline, &Tensor)]) -> DivergentOutcome {
        if window.is_empty() {
            // consistent with the artifact front door: an empty window is a
            // no-op, never a counted pass
            return DivergentOutcome::empty();
        }
        let pipes: Vec<&Pipeline> = window.iter().map(|&(p, _)| p).collect();
        let total: usize = pipes.iter().map(|p| p.batch * p.item_elems()).sum();
        // same spawn-threshold policy as the in-run chunking: tiny windows
        // stay serial (lane choice never changes results, only wall-clock)
        let lanes = self.threads.min(total / MIN_ELEMS_PER_THREAD).max(1);
        let plan = DivergentPlan::compile(&pipes, lanes, |p| self.plan_for(p));
        // raw &HostPlan refs: the Rc handles stay on this thread, only the
        // Sync plan data crosses into the lanes
        let plan_refs: Vec<&HostPlan> = plan.items().iter().map(|it| it.plan()).collect();
        // per-item register-block widths: each sub-plan picks its own (an
        // engine override still wins — the fuzz harness runs whole windows
        // on the scalar arm this way)
        let widths: Vec<u8> = plan_refs.iter().map(|hp| self.effective_width(hp)).collect();

        // every lane gets its share of the worker pool: a window NARROWER
        // than the pool (few large items) keeps intra-run threading inside
        // each lane instead of regressing to one worker per item — results
        // are unchanged either way (every pass is thread-count invariant),
        // and sub-threshold items clamp their own worker count back to 1
        let lane_workers = (self.threads / plan.lanes().max(1)).max(1);
        // consult the fault injector serially, in window order, BEFORE any
        // lane spawns: injected faults land at deterministic launch indices
        // under every lane layout (and at zero cost when no injector is armed)
        let injected: Vec<Option<InjectedHere>> = match &self.faults {
            None => window.iter().map(|_| None).collect(),
            Some(inj) => pipes
                .iter()
                .map(|p| {
                    inj.check(crate::faults::FaultTier::Divergent, &Signature::of(p).stream_key())
                })
                .collect(),
        };
        let mut slots: Vec<Option<Result<Tensor>>> = Vec::with_capacity(window.len());
        slots.resize_with(window.len(), || None);
        if plan.lanes() <= 1 {
            let items = window.iter().zip(plan_refs.iter().copied()).zip(widths.iter().copied());
            for ((slot, ((&(p, t), hp), width)), fault) in
                slots.iter_mut().zip(items).zip(injected.iter().cloned())
            {
                *slot = Some(divergent_item(hp, p, t, self.threads, width, fault));
            }
        } else {
            std::thread::scope(|scope| {
                let mut rest: &mut [Option<Result<Tensor>>] = &mut slots;
                for r in plan.chunks() {
                    let (head, tail) = rest.split_at_mut(r.len());
                    rest = tail;
                    let lane_win = &window[r.start..r.end];
                    let lane_plans = &plan_refs[r.start..r.end];
                    let lane_widths = &widths[r.start..r.end];
                    let lane_faults = &injected[r.start..r.end];
                    scope.spawn(move || {
                        let items = lane_win
                            .iter()
                            .zip(lane_plans.iter().copied())
                            .zip(lane_widths.iter().copied());
                        for ((slot, ((&(p, t), hp), width)), fault) in
                            head.iter_mut().zip(items).zip(lane_faults.iter().cloned())
                        {
                            *slot = Some(divergent_item(hp, p, t, lane_workers, width, fault));
                        }
                    });
                }
            });
        }
        let results: Vec<Result<Tensor>> =
            slots.into_iter().map(|s| s.expect("every lane fills its slots")).collect();
        for (hp, res) in plan_refs.iter().copied().zip(&results) {
            if res.is_ok() {
                self.observe_plan_run(hp);
            }
        }
        self.divergent.set(self.divergent.get() + 1);
        DivergentOutcome {
            divergent_pass: true,
            lanes: plan.lanes(),
            launches: 1,
            distinct_signatures: plan.distinct_signatures(),
            total_work_elems: plan.total_work_elems(),
            padded_work_elems: plan.padded_work_elems(),
            results,
        }
    }

    /// The statically-typed entry: the `(S, W)` lane pair is fixed by the
    /// CALLER's types, so the monomorphized loop is selected at compile time
    /// with zero runtime dtype dispatch — the entry the typed chain front
    /// door ([`crate::chain::TypedPipeline::run_host`]) lowers into.
    /// `src_shape` is the caller's input shape: `[batch, *shape]` for dense
    /// reads, the shared `[fh, fw, 3]` frame for crop-family reads. The
    /// returned buffer is laid out per [`Pipeline::out_shape`]. Numerics are
    /// identical to [`Engine::run`]: same cached plan, same accumulator
    /// policy, same loops.
    pub fn run_mono<S: HostLane, W: HostLane>(
        &self,
        p: &Pipeline,
        src: &[S],
        src_shape: &[usize],
    ) -> Result<Vec<W>> {
        ensure!(
            S::DTYPE == p.dtin,
            "run_mono: input lane {} != pipeline dtin {}",
            S::DTYPE,
            p.dtin
        );
        ensure!(
            W::DTYPE == p.dtout,
            "run_mono: output lane {} != pipeline dtout {}",
            W::DTYPE,
            p.dtout
        );
        let plan = self.plan_for(p);
        let width = self.effective_width(&plan);
        let vectorized = width > 1;
        if let Some(spec) = plan.reduce() {
            let body = plan.bind_body(p);
            let vals = reduce_pass(
                p,
                spec,
                &body,
                plan.group(),
                self.threads,
                vectorized,
                src,
                src_shape,
            )?;
            self.observe_plan_run(&plan);
            return Ok(vals.into_iter().map(W::from_f64).collect());
        }
        let dst = if plan.is_dense() {
            let mut want = vec![p.batch];
            want.extend_from_slice(&p.shape);
            ensure!(
                src_shape == want.as_slice(),
                "run_mono: input shape {:?} != pipeline {:?}",
                src_shape,
                want
            );
            ensure!(
                src.len() == p.batch * p.item_elems(),
                "run_mono: {} elements != pipeline {}x{}",
                src.len(),
                p.batch,
                p.item_elems()
            );
            let mut dst = vec![W::default(); src.len()];
            if plan.accum() == HostAccum::F32 {
                let chain: Vec<(Opcode, f32)> = plan
                    .bind_chain(p)
                    .expect("F32 accum implies an all-scalar chain")
                    .into_iter()
                    .map(|(op, param)| (op, param as f32))
                    .collect();
                chain_pass_f32(&chain, self.threads, vectorized, src, &mut dst);
            } else if let Some(chain) = plan.bind_chain(p) {
                chain_pass_f64(&chain, self.threads, vectorized, src, &mut dst);
            } else {
                let body = plan.bind_body(p);
                group_pass(&body, plan.group(), self.threads, vectorized, src, &mut dst);
            }
            dst
        } else {
            let body = plan.bind_body(p);
            structured_pass::<S, W>(p, &body, self.threads, vectorized, src, src_shape)?
        };
        self.observe_plan_run(&plan);
        Ok(dst)
    }

    fn check_dense_input(p: &Pipeline, input: &Tensor) -> Result<()> {
        ensure!(
            input.dtype() == p.dtin,
            "host_fused: input dtype {} != pipeline dtin {}",
            input.dtype(),
            p.dtin
        );
        let mut want = vec![p.batch];
        want.extend_from_slice(&p.shape);
        ensure!(
            input.shape() == want.as_slice(),
            "host_fused: input shape {:?} != pipeline {:?}",
            input.shape(),
            want
        );
        Ok(())
    }
}

impl Default for HostFusedEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl Engine for HostFusedEngine {
    fn name(&self) -> &'static str {
        "host_fused"
    }

    fn run(&self, p: &Pipeline, input: &Tensor) -> Result<Tensor> {
        let plan = self.plan_for(p);
        let width = self.effective_width(&plan);
        // standalone-library tracing: one launch span per fused pass (the
        // serving coordinator records launch spans itself and leaves this
        // tracer unarmed, so launches are never double-counted)
        let t0 = self.tracer.as_ref().map(|tr| (tr, tr.now_us(), tr.new_request()));
        let result = execute_any(&plan, p, input, self.threads, width);
        if let Some((tr, start_us, req)) = t0 {
            use crate::trace::{SpanRecord, Stage, NO_PARENT};
            tr.record(SpanRecord {
                req,
                id: 0,
                parent: NO_PARENT,
                stage: Stage::Launch,
                start_us,
                dur_us: tr.now_us().saturating_sub(start_us),
                a: plan.total_elems() as u64,
                b: width as u64,
                c: self.threads as u64,
                err: result.as_ref().err().map(|_| "Exec"),
            });
        }
        let out = result?;
        self.observe_plan_run(&plan);
        Ok(out)
    }

    /// Always 1: the defining property of the fused plan.
    fn last_launches(&self) -> usize {
        1
    }
}

// ---------------------------------------------------------------------------
// the divergent-HF window pass

/// The result of one divergent-HF window pass
/// ([`HostFusedEngine::run_divergent`] /
/// [`FusedEngine::run_many`](super::FusedEngine::run_many)): per-item
/// results in window order plus the pass's shape and pad/occupancy
/// accounting (surfaced as coordinator metrics).
#[derive(Debug)]
pub struct DivergentOutcome {
    /// One result per window item, in window order. A failing item fails
    /// ALONE — the rest of the window still serves.
    pub results: Vec<Result<Tensor>>,
    /// True when the window was actually served by the divergent tier
    /// (one thread-chunked pass). False on the artifact front door's
    /// signature-homogeneous path, which serves item by item — divergent
    /// metrics must not count that traffic.
    pub divergent_pass: bool,
    /// Worker lanes the window was chunked across.
    pub lanes: usize,
    /// Launches the pass issued (1 for the host divergent tier; the
    /// artifact path counts its per-item launches).
    pub launches: usize,
    /// Distinct pipeline signatures in the window.
    pub distinct_signatures: usize,
    /// Useful elements the pass touched.
    pub total_work_elems: usize,
    /// Idle weight of the lane chunking: every lane runs as long as the
    /// heaviest, lighter lanes idle for the difference (the mixed-shape
    /// pad accounting of [`crate::fusion::DivergentPlan`]).
    pub padded_work_elems: usize,
}

impl DivergentOutcome {
    /// An empty window: nothing ran, nothing is counted anywhere.
    pub(crate) fn empty() -> DivergentOutcome {
        DivergentOutcome {
            results: Vec::new(),
            divergent_pass: false,
            lanes: 0,
            launches: 0,
            distinct_signatures: 0,
            total_work_elems: 0,
            padded_work_elems: 0,
        }
    }

    /// Useful work over total lane time, 0..=1 (1.0 when the pass touched
    /// nothing) — [`crate::fusion::occupancy_ratio`], the tier's one rule.
    pub fn occupancy(&self) -> f64 {
        crate::fusion::occupancy_ratio(self.total_work_elems as u64, self.padded_work_elems as u64)
    }
}

/// A pre-checked fault for one divergent-window item (checked serially on
/// the dispatching thread; triggered inside the item's own lane).
type InjectedHere = (crate::faults::FaultAction, crate::faults::InjectedFault);

/// One divergent-window item, panic-isolated: an injected fault or a panic
/// anywhere in the monomorphized loop fails THIS item's slot with a typed
/// error ([`super::LaunchPanic`] for panics) — the lane, and the window,
/// keep serving.
fn divergent_item(
    plan: &HostPlan,
    p: &Pipeline,
    input: &Tensor,
    threads: usize,
    width: u8,
    fault: Option<InjectedHere>,
) -> Result<Tensor> {
    super::catch_launch(|| {
        if let Some((action, info)) = fault {
            crate::faults::trigger(action, info)?;
        }
        execute_any(plan, p, input, threads, width)
    })
}

/// Execute one already-planned run at an explicit worker count and
/// register-block width: the shared body of [`Engine::run`] (whole engine
/// thread pool, plan-selected width) and of each divergent-HF lane (the pool
/// split across lanes, each item at its own sub-plan's width). Neither
/// thread count nor width changes results on any f64 path — every pass is a
/// pure element/pixel/block map and the reduce stripes are data-addressed —
/// so any lane split is bit-equal to the engine's full-pool run.
fn execute_any(
    plan: &HostPlan,
    p: &Pipeline,
    input: &Tensor,
    threads: usize,
    width: u8,
) -> Result<Tensor> {
    let vectorized = width > 1;
    if let Some(spec) = plan.reduce() {
        ensure!(
            input.dtype() == p.dtin,
            "host_fused: input dtype {} != pipeline dtin {}",
            input.dtype(),
            p.dtin
        );
        return execute_reduce(plan, p, spec, input, threads, vectorized);
    }
    if plan.is_dense() {
        HostFusedEngine::check_dense_input(p, input)?;
        Ok(execute_plan(plan, p, input, threads, vectorized, &p.out_shape()))
    } else {
        ensure!(
            input.dtype() == p.dtin,
            "host_fused: input dtype {} != pipeline dtin {}",
            input.dtype(),
            p.dtin
        );
        execute_structured(plan, p, input, threads, vectorized)
    }
}

// ---------------------------------------------------------------------------
// monomorphized execution

/// One tensor lane type as the monomorphized fused loops see it: per-element
/// reads into the f32/f64 compute domains and writes back with the EXACT
/// boundary semantics of [`Tensor::from_f64_cast`] (round + saturate for
/// integer image types) — same expressions, so bit-compatibility with the
/// oracle is by construction.
///
/// Public because the typed chain front door ([`crate::chain`]) selects the
/// `(input lane, output lane)` pair at COMPILE time through its `Elem`
/// markers and hands it to [`HostFusedEngine::run_mono`] — the Rust analog
/// of the paper's template instantiation.
pub trait HostLane: Copy + Send + Sync + Default + 'static {
    /// The runtime dtype this lane carries (cross-checked by `run_mono`).
    const DTYPE: crate::tensor::DType;
    /// Read into the f64 compute domain (lossless for every lane).
    fn to_f64(self) -> f64;
    /// Read into the f32 fast-path domain. Lossy for i32/f64 — the planner
    /// never selects the f32 accumulator for those inputs, so the lossy
    /// arms are statically present but dynamically unreachable.
    fn to_f32(self) -> f32;
    /// Write from the f64 compute domain (round + saturate boundary).
    fn from_f64(v: f64) -> Self;
    /// Write from the f32 fast path. Identity for f32 (the only output lane
    /// the planner pairs with the f32 accumulator).
    fn from_f32(v: f32) -> Self;
}

macro_rules! host_lane {
    ($t:ty, $dt:ident, $from:expr) => {
        impl HostLane for $t {
            const DTYPE: crate::tensor::DType = crate::tensor::DType::$dt;

            #[inline(always)]
            fn to_f64(self) -> f64 {
                self as f64
            }

            #[inline(always)]
            fn to_f32(self) -> f32 {
                self as f32
            }

            #[inline(always)]
            fn from_f64(v: f64) -> $t {
                $from(v)
            }

            #[inline(always)]
            fn from_f32(v: f32) -> $t {
                <$t as HostLane>::from_f64(v as f64)
            }
        }
    };
}

host_lane!(u8, U8, |v: f64| v.round().clamp(0.0, 255.0) as u8);
host_lane!(u16, U16, |v: f64| v.round().clamp(0.0, 65535.0) as u16);
host_lane!(i32, I32, |v: f64| v.round() as i32);
host_lane!(f32, F32, |v: f64| v as f32);
host_lane!(f64, F64, |v: f64| v);

/// Split `src`/`dst` into per-thread chunks (boundaries aligned to `group`
/// elements so lane-structured pixels never straddle threads) and run `f`
/// on each. `f` receives the chunk's global element offset — results are
/// bitwise identical regardless of the thread count because the work is a
/// pure element-group map.
fn par_chunks<S, W>(
    threads: usize,
    group: usize,
    src: &[S],
    dst: &mut [W],
    f: impl Fn(usize, &[S], &mut [W]) + Sync,
) where
    S: Sync,
    W: Send,
{
    let n = src.len();
    debug_assert_eq!(n, dst.len());
    let threads = threads.min(n / MIN_ELEMS_PER_THREAD).max(1);
    if threads <= 1 {
        f(0, src, dst);
        return;
    }
    let per = n.div_ceil(threads).div_ceil(group) * group;
    std::thread::scope(|scope| {
        let f = &f;
        let mut rest: &mut [W] = dst;
        let mut base = 0usize;
        for chunk in src.chunks(per) {
            let (head, tail) = rest.split_at_mut(chunk.len());
            rest = tail;
            let start = base;
            scope.spawn(move || f(start, chunk, head));
            base += chunk.len();
        }
    });
}

/// The f32 fast path: fold an all-scalar chain through f32 registers.
/// (`W` is always `f32` in practice — the planner only selects the f32
/// accumulator for f32 outputs — and `W::from_f32` is the identity there.)
///
/// Vectorized arm: stage [`kernel::LANE_WIDTH_F32`] elements in a register
/// block, run each chain op over the whole block with its dispatch hoisted
/// ([`Opcode::apply_f32_lanes`]), write the block, scalar tail via
/// `chunks_exact`'s remainder. Per element the op sequence is IDENTICAL to
/// the scalar arm (no cross-lane arithmetic, no re-association) — the two
/// arms differ only in instruction schedule.
fn chain_pass_f32<S: HostLane, W: HostLane>(
    chain: &[(Opcode, f32)],
    threads: usize,
    vectorized: bool,
    src: &[S],
    dst: &mut [W],
) {
    let scalar = |s: &[S], d: &mut [W]| {
        for (out, &x) in d.iter_mut().zip(s) {
            let mut acc = x.to_f32();
            for &(op, param) in chain {
                acc = op.apply_f32(acc, param);
            }
            *out = W::from_f32(acc);
        }
    };
    par_chunks(threads, 1, src, dst, |_base, s, d| {
        if !vectorized {
            scalar(s, d);
            return;
        }
        const B: usize = kernel::LANE_WIDTH_F32;
        let mut sc = s.chunks_exact(B);
        let mut dc = d.chunks_exact_mut(B);
        for (sg, dg) in (&mut sc).zip(&mut dc) {
            let mut lanes = [0f32; B];
            for (l, x) in lanes.iter_mut().zip(sg) {
                *l = x.to_f32();
            }
            for &(op, param) in chain {
                op.apply_f32_lanes(&mut lanes, param);
            }
            for (out, &l) in dg.iter_mut().zip(&lanes) {
                *out = W::from_f32(l);
            }
        }
        scalar(sc.remainder(), dc.into_remainder());
    });
}

/// The oracle-exact chain path: fold through f64 registers, write with
/// boundary semantics. Vectorized arm blocks [`kernel::LANE_WIDTH_F64`]
/// elements with the same per-element op sequence as the scalar arm —
/// bit-identical output, proven across the fuzz seeds.
fn chain_pass_f64<S: HostLane, W: HostLane>(
    chain: &[(Opcode, f64)],
    threads: usize,
    vectorized: bool,
    src: &[S],
    dst: &mut [W],
) {
    let scalar = |s: &[S], d: &mut [W]| {
        for (out, &x) in d.iter_mut().zip(s) {
            let mut acc = x.to_f64();
            for &(op, param) in chain {
                acc = op.apply(acc, param);
            }
            *out = W::from_f64(acc);
        }
    };
    par_chunks(threads, 1, src, dst, |_base, s, d| {
        if !vectorized {
            scalar(s, d);
            return;
        }
        const B: usize = kernel::LANE_WIDTH_F64;
        let mut sc = s.chunks_exact(B);
        let mut dc = d.chunks_exact_mut(B);
        for (sg, dg) in (&mut sc).zip(&mut dc) {
            let mut lanes = [0f64; B];
            for (l, x) in lanes.iter_mut().zip(sg) {
                *l = x.to_f64();
            }
            for &(op, param) in chain {
                op.apply_f64_lanes(&mut lanes, param);
            }
            for (out, &l) in dg.iter_mut().zip(&lanes) {
                *out = W::from_f64(l);
            }
        }
        scalar(sc.remainder(), dc.into_remainder());
    });
}

/// The general path for lane-structured bodies (ComputeC3 / CvtColor): each
/// pixel group lives in a 3-wide register block while the whole body runs.
/// Vectorized arm: [`kernel::LANE_WIDTH_F64`] pixel groups (24 f64 lanes)
/// stage together and each body op sweeps the whole block once — bit-equal
/// to the per-group arm because [`ScalarOp::apply_slice_f64`] is defined
/// element-wise over any slice length (the
/// `whole_buffer_equals_per_group_application` invariant) and blocks start
/// on pixel boundaries.
fn group_pass<S: HostLane, W: HostLane>(
    body: &[ScalarOp],
    group: usize,
    threads: usize,
    vectorized: bool,
    src: &[S],
    dst: &mut [W],
) {
    par_chunks(threads, group, src, dst, |base, s, d| {
        let per_group = |gstart: usize, s: &[S], d: &mut [W]| {
            let mut buf = [0f64; 3];
            for (gi, (sg, dg)) in s.chunks(group).zip(d.chunks_mut(group)).enumerate() {
                let len = sg.len();
                for (b, &x) in buf.iter_mut().zip(sg) {
                    *b = x.to_f64();
                }
                let gbase = gstart + gi * group;
                for op in body {
                    op.apply_slice_f64(&mut buf[..len], gbase);
                }
                for (out, &b) in dg.iter_mut().zip(&buf[..len]) {
                    *out = W::from_f64(b);
                }
            }
        };
        if !(vectorized && group == 3) {
            per_group(base, s, d);
            return;
        }
        const BE: usize = kernel::LANE_WIDTH_F64 * 3;
        let mut sc = s.chunks_exact(BE);
        let mut dc = d.chunks_exact_mut(BE);
        let mut off = 0usize;
        for (sg, dg) in (&mut sc).zip(&mut dc) {
            let mut buf = [0f64; BE];
            for (b, x) in buf.iter_mut().zip(sg) {
                *b = x.to_f64();
            }
            for op in body {
                op.apply_slice_f64(&mut buf, base + off);
            }
            for (out, &b) in dg.iter_mut().zip(&buf) {
                *out = W::from_f64(b);
            }
            off += BE;
        }
        per_group(base + off, sc.remainder(), dc.into_remainder());
    });
}

/// Execute one fused DENSE pass. Dispatches to the monomorphization selected
/// by the plan's (input dtype, output dtype, accumulator) triple.
fn execute_plan(
    plan: &HostPlan,
    p: &Pipeline,
    input: &Tensor,
    threads: usize,
    vectorized: bool,
    out_shape: &[usize],
) -> Tensor {
    use TensorData::*;

    if plan.accum() == HostAccum::F32 {
        let chain: Vec<(Opcode, f32)> = plan
            .bind_chain(p)
            .expect("F32 accum implies an all-scalar chain")
            .into_iter()
            .map(|(op, param)| (op, param as f32))
            .collect();
        let mut dst = vec![0f32; input.len()];
        match input.data() {
            U8(v) => chain_pass_f32(&chain, threads, vectorized, v, &mut dst),
            U16(v) => chain_pass_f32(&chain, threads, vectorized, v, &mut dst),
            F32(v) => chain_pass_f32(&chain, threads, vectorized, v, &mut dst),
            _ => unreachable!("F32 accum is only planned for u8/u16/f32 inputs"),
        }
        return Tensor::from_data(F32(dst), out_shape);
    }

    // f64 accumulator: oracle-exact on every dtype pair
    macro_rules! to_out {
        ($src:expr) => {
            match plan.dtout() {
                crate::tensor::DType::U8 => from_to!($src, u8, U8),
                crate::tensor::DType::U16 => from_to!($src, u16, U16),
                crate::tensor::DType::I32 => from_to!($src, i32, I32),
                crate::tensor::DType::F32 => from_to!($src, f32, F32),
                crate::tensor::DType::F64 => from_to!($src, f64, F64),
            }
        };
    }
    macro_rules! from_to {
        ($src:expr, $w:ty, $variant:ident) => {{
            let mut dst: Vec<$w> = vec![<$w>::default(); $src.len()];
            if let Some(chain) = plan.bind_chain(p) {
                chain_pass_f64(&chain, threads, vectorized, $src, &mut dst);
            } else {
                let body = plan.bind_body(p);
                group_pass(&body, plan.group(), threads, vectorized, $src, &mut dst);
            }
            Tensor::from_data($variant(dst), out_shape)
        }};
    }
    match input.data() {
        U8(v) => to_out!(v),
        U16(v) => to_out!(v),
        I32(v) => to_out!(v),
        F32(v) => to_out!(v),
        F64(v) => to_out!(v),
    }
}

// ---------------------------------------------------------------------------
// structured boundaries: the Reader -> fold -> Writer pixel pass

/// The read half of the structured pass: produce packed-RGB pixel `(y, x)`
/// of the logical `[h, w, 3]` element space in the f64 compute domain.
/// Implementations own their source view, so monomorphization covers the
/// (reader pattern, source lane) pair.
trait PixelRead: Sync {
    fn read(&self, y: usize, x: usize, px: &mut [f64; 3]);
}

/// Dense reader over one packed `[h, w, 3]` batch plane.
struct DenseRead<'a, S> {
    src: &'a [S],
    w: usize,
}

impl<S: HostLane> PixelRead for DenseRead<'_, S> {
    #[inline]
    fn read(&self, y: usize, x: usize, px: &mut [f64; 3]) {
        let base = (y * self.w + x) * 3;
        for (c, out) in px.iter_mut().enumerate() {
            *out = self.src[base + c].to_f64();
        }
    }
}

/// Crop-ROI reader over a shared packed frame. Edge clamp comes from the
/// shared gather table ([`kernel::clamped_frame_index`]) — the same code
/// the oracle runs.
struct CropRead<'a, S> {
    frame: &'a [S],
    fh: i32,
    fw: i32,
    rect: Rect,
}

impl<S: HostLane> PixelRead for CropRead<'_, S> {
    #[inline]
    fn read(&self, y: usize, x: usize, px: &mut [f64; 3]) {
        let base =
            kernel::clamped_frame_index(self.rect, y as i32, x as i32, self.fh, self.fw) * 3;
        for (c, out) in px.iter_mut().enumerate() {
            *out = self.frame[base + c].to_f64();
        }
    }
}

/// Crop + bilinear-resize reader: the gather happens WHILE reading (paper
/// Fig. 11) — the four taps blend straight into the accumulator and the
/// resized intermediate never exists in memory. Taps, weights and clamp are
/// the shared [`kernel`] gather table, so this loop and the hostref oracle
/// cannot drift: the per-row/per-column [`kernel::AxisTap`]s are pure
/// functions of the geometry, precomputed ONCE per pass instead of once per
/// output pixel (bitwise-identical — [`kernel::bilinear_tap`] is defined as
/// the two axis taps combined).
struct ResizeRead<'a, S> {
    frame: &'a [S],
    fh: i32,
    fw: i32,
    rect: Rect,
    ytaps: Vec<kernel::AxisTap>,
    xtaps: Vec<kernel::AxisTap>,
}

impl<'a, S: HostLane> ResizeRead<'a, S> {
    fn new(frame: &'a [S], fh: i32, fw: i32, rect: Rect, dh: usize, dw: usize) -> Self {
        let ytaps = (0..dh).map(|dy| kernel::axis_tap(dy, rect.h, dh)).collect();
        let xtaps = (0..dw).map(|dx| kernel::axis_tap(dx, rect.w, dw)).collect();
        ResizeRead { frame, fh, fw, rect, ytaps, xtaps }
    }
}

impl<S: HostLane> PixelRead for ResizeRead<'_, S> {
    #[inline]
    fn read(&self, y: usize, x: usize, px: &mut [f64; 3]) {
        let (ty, tx) = (self.ytaps[y], self.xtaps[x]);
        let tap = kernel::BilinearTap {
            y0: ty.i0,
            y1: ty.i1,
            wy: ty.w,
            x0: tx.i0,
            x1: tx.i1,
            wx: tx.w,
        };
        for (c, out) in px.iter_mut().enumerate() {
            *out = tap.blend(|yy, xx| {
                let i = kernel::clamped_frame_index(self.rect, yy, xx, self.fh, self.fw);
                self.frame[i * 3 + c].to_f64()
            });
        }
    }
}

/// The write half of the structured pass: place one computed pixel into
/// this thread's chunk of the output.
trait PixelWrite<W>: Send {
    fn write(&mut self, local_y: usize, x: usize, px: &[f64; 3]);
}

/// Dense packed writer: rows stay `[h, w, 3]`.
struct PackedRows<'a, W> {
    buf: &'a mut [W],
    w: usize,
}

impl<W: HostLane> PixelWrite<W> for PackedRows<'_, W> {
    #[inline]
    fn write(&mut self, local_y: usize, x: usize, px: &[f64; 3]) {
        let base = (local_y * self.w + x) * 3;
        for (c, &v) in px.iter().enumerate() {
            self.buf[base + c] = W::from_f64(v);
        }
    }
}

/// Split writer: packed pixels scatter to three planar row chunks WHILE
/// writing — the packed result never exists in memory.
struct PlanarRows<'a, W> {
    planes: [&'a mut [W]; 3],
    w: usize,
}

impl<W: HostLane> PixelWrite<W> for PlanarRows<'_, W> {
    #[inline]
    fn write(&mut self, local_y: usize, x: usize, px: &[f64; 3]) {
        let idx = local_y * self.w + x;
        for (plane, &v) in self.planes.iter_mut().zip(px) {
            plane[idx] = W::from_f64(v);
        }
    }
}

/// Rows `y0..y1` of one output plane: gather (reader) -> fold the body
/// through f64 registers -> place (writer). This is the paper's three-part
/// kernel, monomorphized per (reader, lane pair, writer) so the structured
/// fast paths carry no dispatch inside the loop.
///
/// Vectorized arm: [`kernel::LANE_WIDTH_F64`] adjacent row pixels gather
/// into one 24-lane block WHILE reading, then each body op sweeps the whole
/// block once (dispatch hoisted) before the pixels are placed; the row's
/// ragged tail runs per pixel. Bit-equal to the per-pixel arm — the gather
/// is per-pixel either way and [`ScalarOp::apply_slice_f64`] applies the
/// same f64 op at the same global lane index regardless of slice length.
fn pixel_rows<R: PixelRead, W: HostLane, O: PixelWrite<W>>(
    reader: &R,
    body: &[ScalarOp],
    w: usize,
    y0: usize,
    y1: usize,
    vectorized: bool,
    mut out: O,
) {
    const BP: usize = kernel::LANE_WIDTH_F64;
    let mut px = [0f64; 3];
    for y in y0..y1 {
        let mut x = 0usize;
        if vectorized {
            let mut buf = [0f64; BP * 3];
            while x + BP <= w {
                for i in 0..BP {
                    reader.read(y, x + i, &mut px);
                    buf[i * 3..i * 3 + 3].copy_from_slice(&px);
                }
                // packed pixels start at a global element index that is a
                // multiple of 3, so lane-structured body ops see the same
                // lane assignment as the oracle's whole-buffer sweep
                let gbase = (y * w + x) * 3;
                for op in body {
                    op.apply_slice_f64(&mut buf, gbase);
                }
                for i in 0..BP {
                    px.copy_from_slice(&buf[i * 3..i * 3 + 3]);
                    out.write(y - y0, x + i, &px);
                }
                x += BP;
            }
        }
        for x in x..w {
            reader.read(y, x, &mut px);
            let gbase = (y * w + x) * 3;
            for op in body {
                op.apply_slice_f64(&mut px, gbase);
            }
            out.write(y - y0, x, &px);
        }
    }
}

/// One output plane (`h*w*3` elements, packed or planar), rows chunked
/// across threads. Thread count never changes results: the pass is a pure
/// per-pixel map.
fn structured_plane<R: PixelRead, W: HostLane>(
    reader: &R,
    body: &[ScalarOp],
    write: WritePattern,
    threads: usize,
    vectorized: bool,
    h: usize,
    w: usize,
    dst: &mut [W],
) {
    debug_assert_eq!(dst.len(), h * w * 3);
    if h == 0 || w == 0 {
        return;
    }
    let threads = threads.min((h * w * 3) / MIN_ELEMS_PER_THREAD).clamp(1, h);
    let per = h.div_ceil(threads);
    match write {
        WritePattern::Dense => {
            if threads <= 1 {
                pixel_rows(reader, body, w, 0, h, vectorized, PackedRows { buf: dst, w });
                return;
            }
            std::thread::scope(|scope| {
                for (i, chunk) in dst.chunks_mut(per * w * 3).enumerate() {
                    let y0 = i * per;
                    let y1 = y0 + chunk.len() / (w * 3);
                    scope.spawn(move || {
                        pixel_rows(
                            reader,
                            body,
                            w,
                            y0,
                            y1,
                            vectorized,
                            PackedRows { buf: chunk, w },
                        )
                    });
                }
            });
        }
        // reduce terminators never reach the pixel WRITE pass: the engine
        // routes them to the fold tier before any structured dispatch
        WritePattern::Reduce { .. } => {
            unreachable!("reduce pipelines take the fold-while-reading tier")
        }
        WritePattern::Split => {
            let plane = h * w;
            let (p0, rest) = dst.split_at_mut(plane);
            let (p1, p2) = rest.split_at_mut(plane);
            if threads <= 1 {
                let rows = PlanarRows { planes: [p0, p1, p2], w };
                pixel_rows(reader, body, w, 0, h, vectorized, rows);
                return;
            }
            std::thread::scope(|scope| {
                let rows = per * w;
                let chunks =
                    p0.chunks_mut(rows).zip(p1.chunks_mut(rows)).zip(p2.chunks_mut(rows));
                for (i, ((c0, c1), c2)) in chunks.enumerate() {
                    let y0 = i * per;
                    let y1 = y0 + c0.len() / w;
                    scope.spawn(move || {
                        let rows = PlanarRows { planes: [c0, c1, c2], w };
                        pixel_rows(reader, body, w, y0, y1, vectorized, rows)
                    });
                }
            });
        }
    }
}

/// Pixel dims of a structured pass: the element shape must be packed RGB
/// `[h, w, 3]` (the layout every structured boundary is defined over).
fn pixel_dims(p: &Pipeline) -> Result<(usize, usize)> {
    ensure!(
        p.shape.len() == 3 && p.shape[2] == 3 && p.shape[0] > 0 && p.shape[1] > 0,
        "host_fused: structured boundaries need a packed [h, w, 3] element shape, got {:?}",
        p.shape
    );
    Ok((p.shape[0], p.shape[1]))
}

/// Validate a shared-frame input for a crop-family read: packed RGB rank-3,
/// length-consistent storage, positive rect. Rect corners may extend past
/// the frame — samples clamp to the edge, exactly like the oracle.
fn frame_dims(src_len: usize, src_shape: &[usize], rect: Rect) -> Result<(i32, i32)> {
    ensure!(
        src_shape.len() == 3 && src_shape[2] == 3,
        "host_fused: crop-family reads gather from a packed [fh, fw, 3] frame, got {src_shape:?}"
    );
    ensure!(
        src_len == src_shape.iter().product::<usize>(),
        "host_fused: frame storage has {src_len} elements, shape {src_shape:?} disagrees"
    );
    ensure!(rect.w > 0 && rect.h > 0, "host_fused: degenerate crop rect {rect:?}");
    Ok((src_shape[0] as i32, src_shape[1] as i32))
}

/// One structured run, monomorphized per (source lane, output lane). Each
/// output pixel is gathered by the reader, folded through the body in f64
/// registers, and placed by the writer — one memory pass, no materialized
/// intermediates. The returned buffer is laid out per
/// [`Pipeline::out_shape`].
fn structured_pass<S: HostLane, W: HostLane>(
    p: &Pipeline,
    body: &[ScalarOp],
    threads: usize,
    vectorized: bool,
    src: &[S],
    src_shape: &[usize],
) -> Result<Vec<W>> {
    let (h, w) = pixel_dims(p)?;
    let write = p.write_pattern();
    let plane = h * w * 3;
    let mut dst = vec![W::default(); p.batch * plane];
    match p.read_pattern() {
        ReadPattern::Dense => {
            let mut want = vec![p.batch];
            want.extend_from_slice(&p.shape);
            ensure!(
                src_shape == want.as_slice() && src.len() == p.batch * plane,
                "host_fused: input shape {:?} ({} elements) != pipeline {:?}",
                src_shape,
                src.len(),
                want
            );
            for (sp, dp) in src.chunks(plane).zip(dst.chunks_mut(plane)) {
                let reader = DenseRead { src: sp, w };
                structured_plane(&reader, body, write, threads, vectorized, h, w, dp);
            }
        }
        ReadPattern::Crop { rect } => {
            let (fh, fw) = frame_dims(src.len(), src_shape, rect)?;
            ensure!(
                (h, w) == (rect.h as usize, rect.w as usize),
                "host_fused: crop rect {rect:?} does not produce element shape {:?}",
                p.shape
            );
            let reader = CropRead { frame: src, fh, fw, rect };
            for dp in dst.chunks_mut(plane) {
                structured_plane(&reader, body, write, threads, vectorized, h, w, dp);
            }
        }
        ReadPattern::CropResize { rect, dst_h, dst_w } => {
            let (fh, fw) = frame_dims(src.len(), src_shape, rect)?;
            ensure!(
                (h, w) == (dst_h, dst_w),
                "host_fused: resize read {dst_h}x{dst_w} does not produce element shape {:?}",
                p.shape
            );
            let reader = ResizeRead::new(src, fh, fw, rect, dst_h, dst_w);
            for dp in dst.chunks_mut(plane) {
                structured_plane(&reader, body, write, threads, vectorized, h, w, dp);
            }
        }
    }
    Ok(dst)
}

/// Dynamic-dispatch entry for structured runs: select the (input lane,
/// output lane) monomorphization from the tensor dtypes, then run the same
/// generic pass `run_mono` uses.
fn execute_structured(
    plan: &HostPlan,
    p: &Pipeline,
    input: &Tensor,
    threads: usize,
    vectorized: bool,
) -> Result<Tensor> {
    use TensorData::*;
    let body = plan.bind_body(p);
    let out_shape = p.out_shape();
    macro_rules! from_to {
        ($src:expr, $w:ty, $variant:ident) => {{
            let dst: Vec<$w> =
                structured_pass(p, &body, threads, vectorized, $src, input.shape())?;
            Tensor::from_data($variant(dst), &out_shape)
        }};
    }
    macro_rules! to_out {
        ($src:expr) => {
            match plan.dtout() {
                crate::tensor::DType::U8 => from_to!($src, u8, U8),
                crate::tensor::DType::U16 => from_to!($src, u16, U16),
                crate::tensor::DType::I32 => from_to!($src, i32, I32),
                crate::tensor::DType::F32 => from_to!($src, f32, F32),
                crate::tensor::DType::F64 => from_to!($src, f64, F64),
            }
        };
    }
    Ok(match input.data() {
        U8(v) => to_out!(v),
        U16(v) => to_out!(v),
        I32(v) => to_out!(v),
        F32(v) => to_out!(v),
        F64(v) => to_out!(v),
    })
}

// ---------------------------------------------------------------------------
// the fold-while-reading tier: reduce terminators
//
// A reduce pipeline performs ONE memory pass: each element is read (or
// gathered, for crop/resize reads), folded through the fused op chain in f64
// registers, and accumulated into the requested statistics — no per-element
// write, no materialized intermediate. Determinism contract: partials are
// computed per fixed-size [`kernel::REDUCE_BLOCK`] (a property of the DATA,
// not the thread count) and combined in the fixed pairwise tree of
// [`kernel::reduce_combine_tree`], so results are bit-identical across
// 1/2/8 workers AND bit-equal to the hostref oracle's
// [`kernel::reduce_slice`] over the materialized value stream — same f64
// values, same block boundaries, same combine order, same finalize.

/// Compute per-block partials, block ranges chunked across threads. Which
/// thread computes a block never matters: every partial lands in its
/// block-indexed slot before the fixed-order tree combine.
fn compute_partials(
    spec: ReduceSpec,
    nblocks: usize,
    total_elems: usize,
    threads: usize,
    compute: &(impl Fn(usize) -> kernel::ReduceAcc + Sync),
) -> Vec<kernel::ReduceAcc> {
    let mut partials = vec![kernel::reduce_acc_identity(spec); nblocks];
    let threads = threads.min(total_elems / MIN_ELEMS_PER_THREAD).max(1).min(nblocks.max(1));
    if threads <= 1 {
        for (bi, slot) in partials.iter_mut().enumerate() {
            *slot = compute(bi);
        }
        return partials;
    }
    let per = nblocks.div_ceil(threads);
    std::thread::scope(|scope| {
        for (ti, chunk) in partials.chunks_mut(per).enumerate() {
            scope.spawn(move || {
                for (k, slot) in chunk.iter_mut().enumerate() {
                    *slot = compute(ti * per + k);
                }
            });
        }
    });
    partials
}

/// Dense fold-while-reading: fold the chain through a register per element
/// (pixel-group registers for lane-structured bodies) and accumulate into
/// the striped block state ([`kernel::reduce_block_fold`]).
///
/// Vectorized full-axis chain arm: the chain folds [`kernel::REDUCE_LANES`]
/// elements at once through register blocks and the statistics accumulate
/// in register-resident stripe rows ([`kernel::ReduceStripes`]) — which
/// stripe an element feeds is its block offset mod `REDUCE_LANES` in BOTH
/// arms, so scalar and vectorized folds are bit-identical, as both are to
/// the oracle's [`kernel::reduce_slice`]. Per-channel reductions keep the
/// scalar striped fold (the 3-lane rule crosses stripe rows).
fn reduce_dense<S: HostLane>(
    spec: ReduceSpec,
    body: &[ScalarOp],
    group: usize,
    threads: usize,
    vectorized: bool,
    src: &[S],
) -> Vec<f64> {
    use crate::ops::ReduceAxis;
    let n = src.len();
    let nblocks = n.div_ceil(kernel::REDUCE_BLOCK);
    // group == 1 means an all-scalar body: fold it as a flat (op, param)
    // chain with no group buffer (the reduce analog of `chain_pass_f64`)
    let chain: Option<Vec<(Opcode, f64)>> = (group == 1).then(|| {
        body.iter()
            .map(|op| match op {
                ScalarOp::Scalar { op, param } => (*op, *param),
                _ => unreachable!("group 1 implies an all-scalar body"),
            })
            .collect()
    });
    let compute = |bi: usize| -> kernel::ReduceAcc {
        let start = bi * kernel::REDUCE_BLOCK;
        let end = (start + kernel::REDUCE_BLOCK).min(n);
        let mut blk = kernel::reduce_block_identity(spec);
        if let Some(chain) = &chain {
            let mut j = 0usize;
            if vectorized && matches!(spec.axis, ReduceAxis::Full) {
                const B: usize = kernel::REDUCE_LANES;
                let mut st = kernel::reduce_stripes_identity(spec);
                let mut chunks = src[start..end].chunks_exact(B);
                for chunk in &mut chunks {
                    let mut xs = [0f64; B];
                    for (slot, x) in xs.iter_mut().zip(chunk) {
                        *slot = x.to_f64();
                    }
                    for &(op, param) in chain {
                        op.apply_f64_lanes(&mut xs, param);
                    }
                    kernel::reduce_stripes_fold(spec, &mut st, &xs);
                    j += B;
                }
                blk = kernel::reduce_stripes_into_block(spec, &st);
            }
            // scalar arm, and the vectorized arm's ragged tail (full blocks
            // have none: REDUCE_BLOCK % REDUCE_LANES == 0)
            for x in &src[start + j..end] {
                let mut v = x.to_f64();
                for &(op, param) in chain {
                    v = op.apply(v, param);
                }
                kernel::reduce_block_fold(spec, &mut blk, start, j, v);
                j += 1;
            }
        } else {
            let mut i = start;
            if vectorized && group == 3 {
                // lane-group reduce: stage LANE_WIDTH_F64 pixel groups per
                // iteration so the body sweeps a whole block per op; the
                // fold itself stays element-wise (bit-equal either way)
                const BE: usize = kernel::LANE_WIDTH_F64 * 3;
                while i + BE <= end {
                    let mut buf = [0f64; BE];
                    for (slot, x) in buf.iter_mut().zip(&src[i..i + BE]) {
                        *slot = x.to_f64();
                    }
                    for op in body {
                        op.apply_slice_f64(&mut buf, i);
                    }
                    for (j, &v) in buf.iter().enumerate() {
                        kernel::reduce_block_fold(spec, &mut blk, start, i - start + j, v);
                    }
                    i += BE;
                }
            }
            let mut buf = [0f64; 3];
            while i < end {
                let len = group.min(end - i);
                for (slot, x) in buf.iter_mut().zip(&src[i..i + len]) {
                    *slot = x.to_f64();
                }
                for op in body {
                    op.apply_slice_f64(&mut buf[..len], i);
                }
                for (j, &v) in buf[..len].iter().enumerate() {
                    kernel::reduce_block_fold(spec, &mut blk, start, i - start + j, v);
                }
                i += len;
            }
        }
        kernel::reduce_block_finish(spec, &blk)
    };
    let partials = compute_partials(spec, nblocks, n, threads, &compute);
    kernel::reduce_finalize(spec, &kernel::reduce_combine_tree(spec, &partials), n)
}

/// Structured fold-while-reading: gather each pixel through the shared
/// reader (bilinear taps / edge clamp from [`kernel`]), fold the body in f64
/// registers, accumulate — the cropped/resized intermediate never exists in
/// memory. Blocks are `REDUCE_BLOCK / 3` pixels, so block boundaries land on
/// the very same element indices as the oracle's blocks over the
/// materialized stream.
fn reduce_pixels<R: PixelRead>(
    spec: ReduceSpec,
    body: &[ScalarOp],
    threads: usize,
    reader: &R,
    batch: usize,
    h: usize,
    w: usize,
) -> Vec<f64> {
    let plane_px = h * w;
    let total_px = batch * plane_px;
    let n = total_px * 3;
    let px_per_block = kernel::REDUCE_BLOCK / 3;
    let nblocks = total_px.div_ceil(px_per_block);
    let compute = |bi: usize| -> kernel::ReduceAcc {
        let start = bi * px_per_block;
        let end = (start + px_per_block).min(total_px);
        let mut blk = kernel::reduce_block_identity(spec);
        let mut px = [0f64; 3];
        for pi in start..end {
            // batch items repeat the same gathered plane (exactly like the
            // oracle's materialized batch): plane-local pixel, global lanes
            let pp = pi % plane_px;
            reader.read(pp / w, pp % w, &mut px);
            let gbase = pi * 3;
            for op in body {
                op.apply_slice_f64(&mut px, gbase);
            }
            for (c, &v) in px.iter().enumerate() {
                // block base in elements is start * 3 == bi * REDUCE_BLOCK
                kernel::reduce_block_fold(spec, &mut blk, start * 3, (pi - start) * 3 + c, v);
            }
        }
        kernel::reduce_block_finish(spec, &blk)
    };
    let partials = compute_partials(spec, nblocks, n, threads, &compute);
    kernel::reduce_finalize(spec, &kernel::reduce_combine_tree(spec, &partials), n)
}

/// One reduce run, monomorphized per source lane: route by read pattern,
/// validate geometry loudly, fold. Returns the finalized statistics in the
/// stat-major layout of [`ReduceSpec::out_shape`].
fn reduce_pass<S: HostLane>(
    p: &Pipeline,
    spec: ReduceSpec,
    body: &[ScalarOp],
    group: usize,
    threads: usize,
    vectorized: bool,
    src: &[S],
    src_shape: &[usize],
) -> Result<Vec<f64>> {
    match p.read_pattern() {
        ReadPattern::Dense => {
            let mut want = vec![p.batch];
            want.extend_from_slice(&p.shape);
            ensure!(
                src_shape == want.as_slice() && src.len() == p.batch * p.item_elems(),
                "host_fused: input shape {:?} ({} elements) != pipeline {:?}",
                src_shape,
                src.len(),
                want
            );
            Ok(reduce_dense(spec, body, group, threads, vectorized, src))
        }
        ReadPattern::Crop { rect } => {
            let (fh, fw) = frame_dims(src.len(), src_shape, rect)?;
            let (h, w) = pixel_dims(p)?;
            ensure!(
                (h, w) == (rect.h as usize, rect.w as usize),
                "host_fused: crop rect {rect:?} does not produce element shape {:?}",
                p.shape
            );
            let reader = CropRead { frame: src, fh, fw, rect };
            Ok(reduce_pixels(spec, body, threads, &reader, p.batch, h, w))
        }
        ReadPattern::CropResize { rect, dst_h, dst_w } => {
            let (fh, fw) = frame_dims(src.len(), src_shape, rect)?;
            let (h, w) = pixel_dims(p)?;
            ensure!(
                (h, w) == (dst_h, dst_w),
                "host_fused: resize read {dst_h}x{dst_w} does not produce element shape {:?}",
                p.shape
            );
            let reader = ResizeRead::new(src, fh, fw, rect, dst_h, dst_w);
            Ok(reduce_pixels(spec, body, threads, &reader, p.batch, h, w))
        }
    }
}

/// Dynamic-dispatch entry for reduce runs: select the source-lane
/// monomorphization from the tensor dtype, fold, and land the statistics as
/// an f64 tensor shaped per [`Pipeline::out_shape`].
fn execute_reduce(
    plan: &HostPlan,
    p: &Pipeline,
    spec: ReduceSpec,
    input: &Tensor,
    threads: usize,
    vectorized: bool,
) -> Result<Tensor> {
    use TensorData::*;
    let body = plan.bind_body(p);
    let group = plan.group();
    let vals = match input.data() {
        U8(v) => reduce_pass(p, spec, &body, group, threads, vectorized, v, input.shape()),
        U16(v) => reduce_pass(p, spec, &body, group, threads, vectorized, v, input.shape()),
        I32(v) => reduce_pass(p, spec, &body, group, threads, vectorized, v, input.shape()),
        F32(v) => reduce_pass(p, spec, &body, group, threads, vectorized, v, input.shape()),
        F64(v) => reduce_pass(p, spec, &body, group, threads, vectorized, v, input.shape()),
    }?;
    Ok(Tensor::from_f64(&vals, &p.out_shape()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostref;
    use crate::proplite::Rng;
    use crate::tensor::{make_frame, DType};

    fn assert_close_f64(got: &Tensor, want: &Tensor, tol: f64) {
        assert_eq!(got.shape(), want.shape());
        assert_eq!(got.dtype(), want.dtype());
        for (i, (a, b)) in got.to_f64_vec().iter().zip(want.to_f64_vec()).enumerate() {
            assert!((a - b).abs() <= tol + tol * b.abs(), "elem {i}: {a} vs {b}");
        }
    }

    #[test]
    fn f32_chain_matches_oracle_within_epsilon() {
        let p = Pipeline::from_opcodes(
            &[(Opcode::Nop, 0.0), (Opcode::Mul, 0.5), (Opcode::Sub, 3.0), (Opcode::Div, 1.7)],
            &[60, 120],
            4,
            DType::F32,
            DType::F32,
        )
        .unwrap();
        let mut rng = Rng::new(11);
        let x = Tensor::from_f32(&rng.vec_f32(4 * 7200, -4.0, 4.0), &[4, 60, 120]);
        let eng = HostFusedEngine::new();
        let got = eng.run(&p, &x).unwrap();
        assert_close_f64(&got, &hostref::run_pipeline(&p, &x), 1e-5);
        assert_eq!(eng.last_launches(), 1);
    }

    #[test]
    fn integer_paths_are_bit_compatible_with_oracle() {
        let mut rng = Rng::new(5);
        for (dtin, dtout) in [
            (DType::U8, DType::U8),
            (DType::U8, DType::U16),
            (DType::U16, DType::U8),
            (DType::I32, DType::I32),
            (DType::F64, DType::U8),
        ] {
            let p = Pipeline::from_opcodes(
                &[(Opcode::Mul, 1.7), (Opcode::Add, 11.0), (Opcode::Sub, 4.5)],
                &[9, 7],
                2,
                dtin,
                dtout,
            )
            .unwrap();
            let vals: Vec<f64> = (0..126).map(|_| rng.f64(0.0, 300.0)).collect();
            let x = Tensor::from_f64_cast(&vals, &[2, 9, 7], dtin);
            let got = HostFusedEngine::new().run(&p, &x).unwrap();
            assert_eq!(got, hostref::run_pipeline(&p, &x), "{dtin}->{dtout}");
        }
    }

    #[test]
    fn lane_structured_pipeline_matches_oracle_exactly() {
        // cvtcolor + per-channel math, including a ragged (non-multiple-of-3)
        // tail — the oracle's global-index lane semantics must be reproduced
        let p = crate::chain::Chain::read::<crate::chain::F64>(&[5, 2])
            .batch(2)
            .map(crate::chain::CvtColor)
            .map(crate::chain::MulC3([2.0, 3.0, 4.0]))
            .map(crate::chain::Add(1.0))
            .write()
            .into_pipeline();
        let mut rng = Rng::new(3);
        let vals: Vec<f64> = (0..20).map(|_| rng.f64(-5.0, 5.0)).collect();
        let x = Tensor::from_f64(&vals, &[2, 5, 2]);
        let got = HostFusedEngine::new().run(&p, &x).unwrap();
        assert_eq!(got, hostref::run_pipeline(&p, &x));
    }

    #[test]
    fn thread_count_never_changes_results() {
        let p = Pipeline::from_opcodes(
            &[(Opcode::Mul, 0.999), (Opcode::Add, 0.001), (Opcode::Sqrt, 0.0)],
            &[257, 129], // odd sizes: ragged chunk boundaries
            3,
            DType::F32,
            DType::F32,
        )
        .unwrap();
        let mut rng = Rng::new(29);
        let x = Tensor::from_f32(&rng.vec_f32(3 * 257 * 129, -2.0, 2.0), &[3, 257, 129]);
        let want = HostFusedEngine::with_threads(1).run(&p, &x).unwrap();
        for threads in [2, 3, 8] {
            let got = HostFusedEngine::with_threads(threads).run(&p, &x).unwrap();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn plans_are_cached_per_signature_and_rebound_per_params() {
        let eng = HostFusedEngine::new();
        let a = Pipeline::from_opcodes(&[(Opcode::Mul, 2.0)], &[8], 1, DType::F32, DType::F32)
            .unwrap();
        let b = Pipeline::from_opcodes(&[(Opcode::Mul, 5.0)], &[8], 1, DType::F32, DType::F32)
            .unwrap();
        let x = Tensor::from_f32(&[1.0; 8], &[1, 8]);
        assert_eq!(eng.run(&a, &x).unwrap().as_f32().unwrap(), &[2.0; 8]);
        assert_eq!(eng.run(&b, &x).unwrap().as_f32().unwrap(), &[5.0; 8]);
        assert_eq!(eng.plan_cache_len(), 1, "same signature, one plan");
        assert_eq!(eng.runs(), 2);
        assert_eq!(eng.structured_runs(), 0);
    }

    #[test]
    fn input_mismatches_are_rejected() {
        let p = Pipeline::from_opcodes(&[(Opcode::Mul, 2.0)], &[8], 1, DType::F32, DType::F32)
            .unwrap();
        let eng = HostFusedEngine::new();
        let wrong_dtype = Tensor::from_u8(&[0; 8], &[1, 8]);
        assert!(eng.run(&p, &wrong_dtype).is_err());
        let wrong_shape = Tensor::from_f32(&[0.0; 16], &[2, 8]);
        assert!(eng.run(&p, &wrong_shape).is_err());
    }

    // --- structured boundaries --------------------------------------------

    #[test]
    fn crop_read_reproduces_the_crop_oracle_bitwise() {
        let frame = make_frame(24, 32, 5);
        let rect = Rect::new(3, 4, 10, 7);
        let p = crate::chain::Chain::read_crop::<crate::chain::U8>(rect)
            .write()
            .into_pipeline();
        let eng = HostFusedEngine::with_threads(2);
        let got = eng.run(&p, &frame).unwrap();
        assert_eq!(got.shape(), &[1, 7, 10, 3]);
        let want = crate::tensor::crop_frame(&frame, rect);
        assert_eq!(got.as_u8().unwrap(), want.as_u8().unwrap());
        assert_eq!(eng.structured_runs(), 1);
    }

    #[test]
    fn resize_read_matches_the_bilinear_oracle_bitwise() {
        let frame = make_frame(40, 48, 9);
        let rect = Rect::new(5, 6, 21, 13);
        let (dh, dw) = (17, 11); // odd sizes: fractional taps everywhere
        let p = crate::chain::Chain::read_resize::<crate::chain::U8>(rect, dh, dw)
            .cast::<crate::chain::F32>()
            .write()
            .into_pipeline();
        let got = HostFusedEngine::with_threads(3).run(&p, &frame).unwrap();
        assert_eq!(got.shape(), &[1, dh, dw, 3]);
        let want = hostref::bilinear_crop_resize(&frame, rect, dh, dw);
        assert_eq!(got.as_f32().unwrap(), want.as_f32().unwrap());
    }

    #[test]
    fn preproc_style_chain_matches_the_structured_oracle_bitwise() {
        // resize read -> cvtcolor -> c3 math -> split write: the flagship
        // shape, bit-equal to the structured hostref oracle (f64 path)
        let frame = make_frame(30, 40, 2);
        let p = crate::chain::Chain::read_resize::<crate::chain::U8>(Rect::new(2, 3, 18, 9), 12, 8)
            .map(crate::chain::CvtColor)
            .map(crate::chain::MulC3([0.9, 1.0, 1.1]))
            .map(crate::chain::SubC3([0.5, 0.4, 0.3]))
            .map(crate::chain::DivC3([2.0, 2.1, 2.2]))
            .cast::<crate::chain::F32>()
            .write_split()
            .into_pipeline();
        let eng = HostFusedEngine::with_threads(2);
        let got = eng.run(&p, &frame).unwrap();
        assert_eq!(got.shape(), &[1, 3, 12, 8]);
        assert_eq!(got, hostref::run_pipeline(&p, &frame));
    }

    // --- the fold-while-reading reduce tier --------------------------------

    #[test]
    fn dense_reduce_is_bit_equal_to_the_oracle_and_thread_invariant() {
        use crate::ops::{ReduceAxis, ALL_REDUCE_KINDS};
        let mut rng = Rng::new(17);
        // sizes straddling REDUCE_BLOCK boundaries: the blocked tree must
        // make 1/2/8 workers (and the oracle) agree bitwise
        let n = kernel::REDUCE_BLOCK * 2 + 7;
        let vals: Vec<f64> = (0..n).map(|_| rng.f64(-3.0, 3.0)).collect();
        let x = Tensor::from_f64(&vals, &[1, n]);
        for kind in ALL_REDUCE_KINDS {
            for axis in [ReduceAxis::Full, ReduceAxis::PerChannel] {
                let p = crate::chain::Chain::read::<crate::chain::F64>(&[n])
                    .map(crate::chain::Mul(1.000001))
                    .reduce_spec(crate::ops::ReduceSpec::single(kind, axis))
                    .into_pipeline();
                let want = hostref::run_pipeline(&p, &x);
                for threads in [1usize, 2, 8] {
                    let eng = HostFusedEngine::with_threads(threads);
                    let got = eng.run(&p, &x).unwrap();
                    assert_eq!(got.shape(), want.shape());
                    let (g, w) = (got.to_f64_vec(), want.to_f64_vec());
                    for (i, (a, b)) in g.iter().zip(&w).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{kind:?}/{axis:?} t{threads} lane {i}: {a} vs {b}"
                        );
                    }
                    assert_eq!(eng.reduce_runs(), 1);
                    assert_eq!(eng.structured_runs(), 0, "dense-read reduce");
                }
            }
        }
    }

    #[test]
    fn crop_read_reduce_folds_while_gathering() {
        use crate::ops::ReduceKind;
        // mean of a cropped region: the crop intermediate never materializes
        // in the engine, yet the result is bit-equal to the materializing
        // oracle (shared gather + shared blocked reduction)
        let frame = make_frame(40, 50, 8);
        let rect = Rect::new(5, 7, 21, 13);
        let p = crate::chain::Chain::read_crop::<crate::chain::U8>(rect)
            .map(crate::chain::Mul(0.25))
            .reduce_per_channel(ReduceKind::Mean)
            .into_pipeline();
        let want = hostref::run_pipeline(&p, &frame);
        let eng = HostFusedEngine::with_threads(3);
        let got = eng.run(&p, &frame).unwrap();
        assert_eq!(got.shape(), &[3]);
        assert_eq!(got, want, "f64 stats tensors compare bitwise");
        assert_eq!(eng.reduce_runs(), 1);
        assert_eq!(eng.structured_runs(), 1, "crop-read reduce is structured traffic");
    }

    #[test]
    fn reduce_pair_folds_both_stats_in_one_pass() {
        use crate::ops::ReduceKind;
        let mut rng = Rng::new(9);
        let vals = rng.vec_f32(4 * 999, -2.0, 2.0);
        let x = Tensor::from_f32(&vals, &[4, 999]);
        let p = crate::chain::Chain::read::<crate::chain::F32>(&[999])
            .batch(4)
            .reduce_pair(ReduceKind::Mean, ReduceKind::SumSq)
            .into_pipeline();
        let eng = HostFusedEngine::with_threads(2);
        let got = eng.run(&p, &x).unwrap();
        assert_eq!(got.shape(), &[2]);
        assert_eq!(got, hostref::run_pipeline(&p, &x));
        // the pair agrees with the two single reductions (same fold table)
        for (i, kind) in [ReduceKind::Mean, ReduceKind::SumSq].into_iter().enumerate() {
            let single = crate::chain::Chain::read::<crate::chain::F32>(&[999])
                .batch(4)
                .reduce(kind)
                .into_pipeline();
            let alone = eng.run(&single, &x).unwrap();
            assert_eq!(alone.as_f64().unwrap()[0], got.as_f64().unwrap()[i], "{kind:?}");
        }
    }

    #[test]
    fn empty_and_mismatched_reduce_inputs() {
        use crate::ops::ReduceKind;
        let p = crate::chain::Chain::read::<crate::chain::F32>(&[0])
            .reduce(ReduceKind::Sum)
            .into_pipeline();
        let empty = Tensor::zeros(DType::F32, &[1, 0]);
        let eng = HostFusedEngine::with_threads(2);
        let got = eng.run(&p, &empty).unwrap();
        assert_eq!(got.as_f64().unwrap(), &[0.0], "empty sum is the identity");
        assert_eq!(got, hostref::run_pipeline(&p, &empty));
        // wrong dtype / shape fail loudly, never silently cast
        assert!(eng.run(&p, &Tensor::zeros(DType::U8, &[1, 0])).is_err());
        assert!(eng.run(&p, &Tensor::zeros(DType::F32, &[1, 4])).is_err());
    }

    // --- the divergent-HF window pass --------------------------------------

    #[test]
    fn divergent_window_matches_per_item_serving_bitwise() {
        use crate::chain::{Chain, CvtColor, Mul, MulC3, F32, U8};
        use crate::ops::ReduceKind;
        // a window mixing three signatures — dense (param-divergent pair),
        // structured resize->split, crop-read reduce — in one pass
        let frame = make_frame(30, 40, 4);
        let dense_a = Chain::read::<U8>(&[6, 7]).map(Mul(1.7)).write().into_pipeline();
        let dense_b = Chain::read::<U8>(&[6, 7]).map(Mul(4.0)).write().into_pipeline();
        let structured = Chain::read_resize::<U8>(Rect::new(2, 3, 20, 12), 9, 5)
            .map(CvtColor)
            .map(MulC3([0.9, 1.0, 1.1]))
            .cast::<F32>()
            .write_split()
            .into_pipeline();
        let reduce = Chain::read_crop::<U8>(Rect::new(1, 1, 8, 6))
            .map(Mul(0.5))
            .reduce_per_channel(ReduceKind::Mean)
            .into_pipeline();
        let mut rng = Rng::new(21);
        let item = Tensor::from_u8(&rng.vec_u8(42), &[1, 6, 7]);
        let window: Vec<(&Pipeline, &Tensor)> = vec![
            (&dense_a, &item),
            (&structured, &frame),
            (&dense_b, &item),
            (&reduce, &frame),
        ];
        let eng = HostFusedEngine::with_threads(8);
        let out = eng.run_divergent(&window);
        assert_eq!(out.results.len(), 4);
        assert_eq!(out.launches, 1, "the divergent tier is ONE pass");
        assert_eq!(out.distinct_signatures, 3);
        assert!(out.lanes >= 1);
        for (i, ((p, t), res)) in window.iter().zip(&out.results).enumerate() {
            let got = res.as_ref().expect("window item serves");
            assert_eq!(got, &hostref::run_pipeline(p, t), "item {i} vs oracle");
            assert_eq!(got, &eng.run(p, t).unwrap(), "item {i} == per-item serving");
        }
        assert_eq!(eng.divergent_runs(), 1, "one window counted");
        assert!(eng.reduce_runs() >= 1, "reduce items land in the reduce tier");
        assert!(eng.structured_runs() >= 2, "structured items stay observable");
    }

    #[test]
    fn divergent_window_isolates_failing_items() {
        let p = Pipeline::from_opcodes(&[(Opcode::Mul, 2.0)], &[4], 1, DType::F32, DType::F32)
            .unwrap();
        let good = Tensor::from_f32(&[1.0, 2.0, 3.0, 4.0], &[1, 4]);
        let bad = Tensor::from_u8(&[0; 4], &[1, 4]); // wrong dtype
        let eng = HostFusedEngine::with_threads(2);
        let window: Vec<(&Pipeline, &Tensor)> = vec![(&p, &good), (&p, &bad), (&p, &good)];
        let out = eng.run_divergent(&window);
        assert!(out.results[0].is_ok() && out.results[2].is_ok());
        assert!(out.results[1].is_err(), "the malformed item fails ALONE");
        assert_eq!(
            out.results[0].as_ref().unwrap().as_f32().unwrap(),
            &[2.0, 4.0, 6.0, 8.0]
        );
        // only the served items count as runs; the window counts once
        assert_eq!(eng.runs(), 2);
        assert_eq!(eng.divergent_runs(), 1);
        assert_eq!(eng.plan_cache_len(), 1, "one signature, one cached plan");
    }

    #[test]
    fn structured_geometry_mismatches_are_rejected() {
        let p = crate::chain::Chain::read_crop::<crate::chain::U8>(Rect::new(0, 0, 4, 4))
            .write()
            .into_pipeline();
        let eng = HostFusedEngine::with_threads(1);
        // not a packed frame (rank 4)
        let batched = Tensor::zeros(DType::U8, &[1, 8, 8, 3]);
        assert!(eng.run(&p, &batched).is_err());
        // wrong dtype
        let f32_frame = Tensor::zeros(DType::F32, &[8, 8, 3]);
        assert!(eng.run(&p, &f32_frame).is_err());
    }
}
