//! HostFusedEngine — vertical fusion on the CPU: ONE memory pass per run.
//!
//! This is the backend that runs everywhere (no PJRT, no artifacts). It
//! reproduces the paper's fusion story on the host: where the op-at-a-time
//! reference ([`crate::hostref::run_pipeline`]) widens the whole buffer to
//! f64 and sweeps it once per op (N reads + N writes of DRAM-resident
//! intermediates), this engine reads each element once, folds the entire op
//! chain through a register-resident accumulator, and writes each output
//! element once — the CPU analog of keeping intermediates in GPU registers.
//! The batch dimension is chunked across OS threads, the host analog of
//! Horizontal Fusion filling the GPU with independent planes.
//!
//! Loops are monomorphized per (input dtype, output dtype, compute domain):
//! an f32 chain never touches f64, a u8→f32 normalization chain reads bytes
//! and writes floats with no whole-buffer widening step. Numerics contract
//! (enforced by `rust/tests/host_fused_props.rs`): bit-compatible with the
//! oracle on every f64-accumulated path — which includes ALL integer outputs
//! — and within float epsilon on the f32 fast path.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{ensure, Result};

use crate::fusion::{HostAccum, HostPlan};
use crate::ops::{IOp, MemOp, Opcode, Pipeline, ScalarOp, Signature};
use crate::tensor::{Tensor, TensorData};

use super::Engine;

/// The host loops execute DENSE pipelines only: structured boundary ops
/// (crop/resize reads, split writes) lower to the AOT artifact backend.
/// Refusing here is what keeps a split-write chain from silently coming
/// back in packed layout.
fn ensure_dense_boundaries(p: &Pipeline) -> Result<()> {
    ensure!(
        matches!(p.ops().first(), Some(IOp::Mem(MemOp::Read { .. }))),
        "host_fused: structured read ({}) lowers to the artifact backend",
        p.ops().first().map(|o| o.sig_token()).unwrap_or_default()
    );
    ensure!(
        matches!(p.ops().last(), Some(IOp::Mem(MemOp::Write { .. }))),
        "host_fused: structured write ({}) lowers to the artifact backend",
        p.ops().last().map(|o| o.sig_token()).unwrap_or_default()
    );
    Ok(())
}

/// Below this many total elements a run stays single-threaded: thread spawn
/// costs tens of microseconds, which dwarfs small pipelines.
const MIN_ELEMS_PER_THREAD: usize = 32 * 1024;

/// The host vertical-fusion engine. Plans are cached per [`Signature`]
/// (params are bound per run, mirroring [`super::FusedEngine::plan_for`]).
pub struct HostFusedEngine {
    plans: RefCell<HashMap<Signature, Rc<HostPlan>>>,
    threads: usize,
    runs: Cell<usize>,
}

impl HostFusedEngine {
    /// Engine with one worker per available core.
    pub fn new() -> HostFusedEngine {
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::with_threads(threads)
    }

    /// Engine with a fixed worker count (1 = the pure VF ablation: single
    /// pass, no batch-dimension parallelism).
    pub fn with_threads(threads: usize) -> HostFusedEngine {
        HostFusedEngine {
            plans: RefCell::new(HashMap::new()),
            threads: threads.max(1),
            runs: Cell::new(0),
        }
    }

    /// Plan lookup/compile, cached per signature.
    pub fn plan_for(&self, p: &Pipeline) -> Rc<HostPlan> {
        let sig = Signature::of(p);
        if let Some(plan) = self.plans.borrow().get(&sig) {
            return plan.clone();
        }
        let plan = Rc::new(HostPlan::compile(p));
        self.plans.borrow_mut().insert(sig, plan.clone());
        plan
    }

    pub fn plan_cache_len(&self) -> usize {
        self.plans.borrow().len()
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Completed runs (each is exactly one fused memory pass).
    pub fn runs(&self) -> usize {
        self.runs.get()
    }

    /// The statically-typed entry: the `(S, W)` lane pair is fixed by the
    /// CALLER's types, so the monomorphized loop is selected at compile time
    /// with zero runtime dtype dispatch — the entry the typed chain front
    /// door ([`crate::chain::TypedPipeline::run_host`]) lowers into.
    /// Numerics are identical to [`Engine::run`]: same cached plan, same
    /// accumulator policy, same loops.
    pub fn run_mono<S: HostLane, W: HostLane>(
        &self,
        p: &Pipeline,
        src: &[S],
    ) -> Result<Vec<W>> {
        ensure_dense_boundaries(p)?;
        ensure!(
            S::DTYPE == p.dtin,
            "run_mono: input lane {} != pipeline dtin {}",
            S::DTYPE,
            p.dtin
        );
        ensure!(
            W::DTYPE == p.dtout,
            "run_mono: output lane {} != pipeline dtout {}",
            W::DTYPE,
            p.dtout
        );
        ensure!(
            src.len() == p.batch * p.item_elems(),
            "run_mono: {} elements != pipeline {}x{}",
            src.len(),
            p.batch,
            p.item_elems()
        );
        let plan = self.plan_for(p);
        let mut dst = vec![W::default(); src.len()];
        if plan.accum() == HostAccum::F32 {
            let chain: Vec<(Opcode, f32)> = plan
                .bind_chain(p)
                .expect("F32 accum implies an all-scalar chain")
                .into_iter()
                .map(|(op, param)| (op, param as f32))
                .collect();
            chain_pass_f32(&chain, self.threads, src, &mut dst);
        } else if let Some(chain) = plan.bind_chain(p) {
            chain_pass_f64(&chain, self.threads, src, &mut dst);
        } else {
            let body = plan.bind_body(p);
            group_pass(&body, plan.group(), self.threads, src, &mut dst);
        }
        self.runs.set(self.runs.get() + 1);
        Ok(dst)
    }

    fn check_input(p: &Pipeline, input: &Tensor) -> Result<()> {
        ensure_dense_boundaries(p)?;
        ensure!(
            input.dtype() == p.dtin,
            "host_fused: input dtype {} != pipeline dtin {}",
            input.dtype(),
            p.dtin
        );
        let mut want = vec![p.batch];
        want.extend_from_slice(&p.shape);
        ensure!(
            input.shape() == want.as_slice(),
            "host_fused: input shape {:?} != pipeline {:?}",
            input.shape(),
            want
        );
        Ok(())
    }
}

impl Default for HostFusedEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl Engine for HostFusedEngine {
    fn name(&self) -> &'static str {
        "host_fused"
    }

    fn run(&self, p: &Pipeline, input: &Tensor) -> Result<Tensor> {
        Self::check_input(p, input)?;
        let plan = self.plan_for(p);
        let mut out_shape = vec![p.batch];
        out_shape.extend_from_slice(&p.shape);
        let out = execute_plan(&plan, p, input, self.threads, &out_shape);
        self.runs.set(self.runs.get() + 1);
        Ok(out)
    }

    /// Always 1: the defining property of the fused plan.
    fn last_launches(&self) -> usize {
        1
    }
}

// ---------------------------------------------------------------------------
// monomorphized execution

/// One tensor lane type as the monomorphized fused loops see it: per-element
/// reads into the f32/f64 compute domains and writes back with the EXACT
/// boundary semantics of [`Tensor::from_f64_cast`] (round + saturate for
/// integer image types) — same expressions, so bit-compatibility with the
/// oracle is by construction.
///
/// Public because the typed chain front door ([`crate::chain`]) selects the
/// `(input lane, output lane)` pair at COMPILE time through its `Elem`
/// markers and hands it to [`HostFusedEngine::run_mono`] — the Rust analog
/// of the paper's template instantiation.
pub trait HostLane: Copy + Send + Sync + Default + 'static {
    /// The runtime dtype this lane carries (cross-checked by `run_mono`).
    const DTYPE: crate::tensor::DType;
    /// Read into the f64 compute domain (lossless for every lane).
    fn to_f64(self) -> f64;
    /// Read into the f32 fast-path domain. Lossy for i32/f64 — the planner
    /// never selects the f32 accumulator for those inputs, so the lossy
    /// arms are statically present but dynamically unreachable.
    fn to_f32(self) -> f32;
    /// Write from the f64 compute domain (round + saturate boundary).
    fn from_f64(v: f64) -> Self;
    /// Write from the f32 fast path. Identity for f32 (the only output lane
    /// the planner pairs with the f32 accumulator).
    fn from_f32(v: f32) -> Self;
}

macro_rules! host_lane {
    ($t:ty, $dt:ident, $from:expr) => {
        impl HostLane for $t {
            const DTYPE: crate::tensor::DType = crate::tensor::DType::$dt;

            #[inline(always)]
            fn to_f64(self) -> f64 {
                self as f64
            }

            #[inline(always)]
            fn to_f32(self) -> f32 {
                self as f32
            }

            #[inline(always)]
            fn from_f64(v: f64) -> $t {
                $from(v)
            }

            #[inline(always)]
            fn from_f32(v: f32) -> $t {
                <$t as HostLane>::from_f64(v as f64)
            }
        }
    };
}

host_lane!(u8, U8, |v: f64| v.round().clamp(0.0, 255.0) as u8);
host_lane!(u16, U16, |v: f64| v.round().clamp(0.0, 65535.0) as u16);
host_lane!(i32, I32, |v: f64| v.round() as i32);
host_lane!(f32, F32, |v: f64| v as f32);
host_lane!(f64, F64, |v: f64| v);

/// Split `src`/`dst` into per-thread chunks (boundaries aligned to `group`
/// elements so lane-structured pixels never straddle threads) and run `f`
/// on each. `f` receives the chunk's global element offset — results are
/// bitwise identical regardless of the thread count because the work is a
/// pure element-group map.
fn par_chunks<S, W>(
    threads: usize,
    group: usize,
    src: &[S],
    dst: &mut [W],
    f: impl Fn(usize, &[S], &mut [W]) + Sync,
) where
    S: Sync,
    W: Send,
{
    let n = src.len();
    debug_assert_eq!(n, dst.len());
    let threads = threads.min(n / MIN_ELEMS_PER_THREAD).max(1);
    if threads <= 1 {
        f(0, src, dst);
        return;
    }
    let per = n.div_ceil(threads).div_ceil(group) * group;
    std::thread::scope(|scope| {
        let f = &f;
        let mut rest: &mut [W] = dst;
        let mut base = 0usize;
        for chunk in src.chunks(per) {
            let (head, tail) = rest.split_at_mut(chunk.len());
            rest = tail;
            let start = base;
            scope.spawn(move || f(start, chunk, head));
            base += chunk.len();
        }
    });
}

/// The f32 fast path: fold an all-scalar chain through an f32 register.
/// (`W` is always `f32` in practice — the planner only selects the f32
/// accumulator for f32 outputs — and `W::from_f32` is the identity there.)
fn chain_pass_f32<S: HostLane, W: HostLane>(
    chain: &[(Opcode, f32)],
    threads: usize,
    src: &[S],
    dst: &mut [W],
) {
    par_chunks(threads, 1, src, dst, |_base, s, d| {
        for (out, &x) in d.iter_mut().zip(s) {
            let mut acc = x.to_f32();
            for &(op, param) in chain {
                acc = op.apply_f32(acc, param);
            }
            *out = W::from_f32(acc);
        }
    });
}

/// The oracle-exact chain path: fold through an f64 register, write with
/// boundary semantics.
fn chain_pass_f64<S: HostLane, W: HostLane>(
    chain: &[(Opcode, f64)],
    threads: usize,
    src: &[S],
    dst: &mut [W],
) {
    par_chunks(threads, 1, src, dst, |_base, s, d| {
        for (out, &x) in d.iter_mut().zip(s) {
            let mut acc = x.to_f64();
            for &(op, param) in chain {
                acc = op.apply(acc, param);
            }
            *out = W::from_f64(acc);
        }
    });
}

/// The general path for lane-structured bodies (ComputeC3 / CvtColor): each
/// pixel group lives in a 3-wide register block while the whole body runs.
fn group_pass<S: HostLane, W: HostLane>(
    body: &[ScalarOp],
    group: usize,
    threads: usize,
    src: &[S],
    dst: &mut [W],
) {
    par_chunks(threads, group, src, dst, |base, s, d| {
        let mut buf = [0f64; 3];
        for (gi, (sg, dg)) in s.chunks(group).zip(d.chunks_mut(group)).enumerate() {
            let len = sg.len();
            for (b, &x) in buf.iter_mut().zip(sg) {
                *b = x.to_f64();
            }
            let gbase = base + gi * group;
            for op in body {
                op.apply_slice_f64(&mut buf[..len], gbase);
            }
            for (out, &b) in dg.iter_mut().zip(&buf[..len]) {
                *out = W::from_f64(b);
            }
        }
    });
}

/// Execute one fused pass. Dispatches to the monomorphization selected by
/// the plan's (input dtype, output dtype, accumulator) triple.
fn execute_plan(
    plan: &HostPlan,
    p: &Pipeline,
    input: &Tensor,
    threads: usize,
    out_shape: &[usize],
) -> Tensor {
    use TensorData::*;

    if plan.accum() == HostAccum::F32 {
        let chain: Vec<(Opcode, f32)> = plan
            .bind_chain(p)
            .expect("F32 accum implies an all-scalar chain")
            .into_iter()
            .map(|(op, param)| (op, param as f32))
            .collect();
        let mut dst = vec![0f32; input.len()];
        match input.data() {
            U8(v) => chain_pass_f32(&chain, threads, v, &mut dst),
            U16(v) => chain_pass_f32(&chain, threads, v, &mut dst),
            F32(v) => chain_pass_f32(&chain, threads, v, &mut dst),
            _ => unreachable!("F32 accum is only planned for u8/u16/f32 inputs"),
        }
        return Tensor::from_data(F32(dst), out_shape);
    }

    // f64 accumulator: oracle-exact on every dtype pair
    macro_rules! to_out {
        ($src:expr) => {
            match plan.dtout() {
                crate::tensor::DType::U8 => from_to!($src, u8, U8),
                crate::tensor::DType::U16 => from_to!($src, u16, U16),
                crate::tensor::DType::I32 => from_to!($src, i32, I32),
                crate::tensor::DType::F32 => from_to!($src, f32, F32),
                crate::tensor::DType::F64 => from_to!($src, f64, F64),
            }
        };
    }
    macro_rules! from_to {
        ($src:expr, $w:ty, $variant:ident) => {{
            let mut dst: Vec<$w> = vec![<$w>::default(); $src.len()];
            if let Some(chain) = plan.bind_chain(p) {
                chain_pass_f64(&chain, threads, $src, &mut dst);
            } else {
                let body = plan.bind_body(p);
                group_pass(&body, plan.group(), threads, $src, &mut dst);
            }
            Tensor::from_data($variant(dst), out_shape)
        }};
    }
    match input.data() {
        U8(v) => to_out!(v),
        U16(v) => to_out!(v),
        I32(v) => to_out!(v),
        F32(v) => to_out!(v),
        F64(v) => to_out!(v),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostref;
    use crate::proplite::Rng;
    use crate::tensor::DType;

    fn assert_close_f64(got: &Tensor, want: &Tensor, tol: f64) {
        assert_eq!(got.shape(), want.shape());
        assert_eq!(got.dtype(), want.dtype());
        for (i, (a, b)) in got.to_f64_vec().iter().zip(want.to_f64_vec()).enumerate() {
            assert!((a - b).abs() <= tol + tol * b.abs(), "elem {i}: {a} vs {b}");
        }
    }

    #[test]
    fn f32_chain_matches_oracle_within_epsilon() {
        let p = Pipeline::from_opcodes(
            &[(Opcode::Nop, 0.0), (Opcode::Mul, 0.5), (Opcode::Sub, 3.0), (Opcode::Div, 1.7)],
            &[60, 120],
            4,
            DType::F32,
            DType::F32,
        )
        .unwrap();
        let mut rng = Rng::new(11);
        let x = Tensor::from_f32(&rng.vec_f32(4 * 7200, -4.0, 4.0), &[4, 60, 120]);
        let eng = HostFusedEngine::new();
        let got = eng.run(&p, &x).unwrap();
        assert_close_f64(&got, &hostref::run_pipeline(&p, &x), 1e-5);
        assert_eq!(eng.last_launches(), 1);
    }

    #[test]
    fn integer_paths_are_bit_compatible_with_oracle() {
        let mut rng = Rng::new(5);
        for (dtin, dtout) in [
            (DType::U8, DType::U8),
            (DType::U8, DType::U16),
            (DType::U16, DType::U8),
            (DType::I32, DType::I32),
            (DType::F64, DType::U8),
        ] {
            let p = Pipeline::from_opcodes(
                &[(Opcode::Mul, 1.7), (Opcode::Add, 11.0), (Opcode::Sub, 4.5)],
                &[9, 7],
                2,
                dtin,
                dtout,
            )
            .unwrap();
            let vals: Vec<f64> = (0..126).map(|_| rng.f64(0.0, 300.0)).collect();
            let x = Tensor::from_f64_cast(&vals, &[2, 9, 7], dtin);
            let got = HostFusedEngine::new().run(&p, &x).unwrap();
            assert_eq!(got, hostref::run_pipeline(&p, &x), "{dtin}->{dtout}");
        }
    }

    #[test]
    fn lane_structured_pipeline_matches_oracle_exactly() {
        // cvtcolor + per-channel math, including a ragged (non-multiple-of-3)
        // tail — the oracle's global-index lane semantics must be reproduced
        let p = crate::chain::Chain::read::<crate::chain::F64>(&[5, 2])
            .batch(2)
            .map(crate::chain::CvtColor)
            .map(crate::chain::MulC3([2.0, 3.0, 4.0]))
            .map(crate::chain::Add(1.0))
            .write()
            .into_pipeline();
        let mut rng = Rng::new(3);
        let vals: Vec<f64> = (0..20).map(|_| rng.f64(-5.0, 5.0)).collect();
        let x = Tensor::from_f64(&vals, &[2, 5, 2]);
        let got = HostFusedEngine::new().run(&p, &x).unwrap();
        assert_eq!(got, hostref::run_pipeline(&p, &x));
    }

    #[test]
    fn thread_count_never_changes_results() {
        let p = Pipeline::from_opcodes(
            &[(Opcode::Mul, 0.999), (Opcode::Add, 0.001), (Opcode::Sqrt, 0.0)],
            &[257, 129], // odd sizes: ragged chunk boundaries
            3,
            DType::F32,
            DType::F32,
        )
        .unwrap();
        let mut rng = Rng::new(29);
        let x = Tensor::from_f32(&rng.vec_f32(3 * 257 * 129, -2.0, 2.0), &[3, 257, 129]);
        let want = HostFusedEngine::with_threads(1).run(&p, &x).unwrap();
        for threads in [2, 3, 8] {
            let got = HostFusedEngine::with_threads(threads).run(&p, &x).unwrap();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn plans_are_cached_per_signature_and_rebound_per_params() {
        let eng = HostFusedEngine::new();
        let a = Pipeline::from_opcodes(&[(Opcode::Mul, 2.0)], &[8], 1, DType::F32, DType::F32)
            .unwrap();
        let b = Pipeline::from_opcodes(&[(Opcode::Mul, 5.0)], &[8], 1, DType::F32, DType::F32)
            .unwrap();
        let x = Tensor::from_f32(&[1.0; 8], &[1, 8]);
        assert_eq!(eng.run(&a, &x).unwrap().as_f32().unwrap(), &[2.0; 8]);
        assert_eq!(eng.run(&b, &x).unwrap().as_f32().unwrap(), &[5.0; 8]);
        assert_eq!(eng.plan_cache_len(), 1, "same signature, one plan");
        assert_eq!(eng.runs(), 2);
    }

    #[test]
    fn input_mismatches_are_rejected() {
        let p = Pipeline::from_opcodes(&[(Opcode::Mul, 2.0)], &[8], 1, DType::F32, DType::F32)
            .unwrap();
        let eng = HostFusedEngine::new();
        let wrong_dtype = Tensor::from_u8(&[0; 8], &[1, 8]);
        assert!(eng.run(&p, &wrong_dtype).is_err());
        let wrong_shape = Tensor::from_f32(&[0.0; 16], &[2, 8]);
        assert!(eng.run(&p, &wrong_shape).is_err());
    }
}
