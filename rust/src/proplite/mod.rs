//! proplite — a tiny property-testing harness (proptest is unavailable in
//! the offline vendor set).
//!
//! Provides a deterministic xorshift PRNG and a `forall` runner that reports
//! the failing seed so cases are reproducible:
//!
//! ```no_run
//! use fkl::proplite::{forall, Rng};
//! forall(100, |rng: &mut Rng| {
//!     let x = rng.range_u64(0, 100) as i64;
//!     assert!(x >= 0 && x < 100);
//! });
//! ```

/// Deterministic xorshift64* PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng { state: seed.wrapping_mul(0x9E3779B97F4A7C15) | 1 }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [lo, hi).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + self.next_u64() % (hi - lo)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform float in [lo, hi).
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64 * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(0, xs.len())]
    }

    /// Vec of f32 in [lo, hi).
    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f64(lo as f64, hi as f64) as f32).collect()
    }

    /// Vec of u8.
    pub fn vec_u8(&mut self, n: usize) -> Vec<u8> {
        (0..n).map(|_| (self.next_u64() & 0xFF) as u8).collect()
    }
}

/// Run `body` for `cases` seeds; on panic, re-raise with the failing seed in
/// the message.
pub fn forall(cases: u64, body: impl Fn(&mut Rng) + std::panic::RefUnwindSafe) {
    for seed in 1..=cases {
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            body(&mut rng);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!("property failed at seed {seed}: {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_respects_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let v = r.range_u64(10, 20);
            assert!((10..20).contains(&v));
            let f = r.f64(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
    }

    #[test]
    fn forall_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            forall(50, |rng| {
                let v = rng.range_u64(0, 10);
                assert!(v != 3, "hit the bad value");
            })
        });
        let err = r.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| format!("{err:?}"));
        assert!(msg.contains("property failed at seed"), "{msg}");
    }
}
