//! cvGS — the cvGPUSpeedup-style wrapper (paper §IV-D, Fig. 15/25a).
//!
//! Functions mirror OpenCV-CUDA's names and argument feel but, exactly like
//! the paper's cvGS, DO NOT launch kernels: each returns a typed
//! [`ComputeOp`] stage. The user hands the stages to [`execute_operations`],
//! which lowers them through the typed chain builder ([`crate::chain`]) and
//! runs the validated pipeline on the context's backend — one fused pass for
//! the whole chain, no intermediate `d_temp`/`d_up` allocations.
//!
//! [`Context::new`] performs [`EngineSelect::Auto`] backend selection (the
//! same policy as the coordinator): the XLA fused engine when the artifact
//! registry loads, the everywhere-capable host fused engine otherwise — so
//! this example executes on any machine, artifacts or not:
//!
//! ```
//! use fkl::cv::*;
//! use fkl::tensor::{DType, Tensor};
//!
//! let ctx = Context::new().unwrap();           // Auto backend selection
//! let crops = Tensor::from_u8(&vec![100u8; 2 * 6 * 12], &[2, 6, 12]);
//! let out = execute_operations(
//!     &ctx,
//!     &crops,
//!     DType::F32,
//!     &[
//!         convert_to(),            // cv::cuda::GpuMat::convertTo
//!         multiply(0.5),           // cv::cuda::multiply
//!         subtract(10.0),          // cv::cuda::subtract
//!         divide(2.0),             // cv::cuda::divide
//!     ],
//! )
//! .unwrap();
//! assert_eq!(out.dtype(), DType::F32);
//! assert_eq!(out.shape(), &[2, 6, 12]);
//! // (100 * 0.5 - 10) / 2 = 20, on every backend Auto may pick
//! assert!((out.as_f32().unwrap()[0] - 20.0).abs() < 1e-5);
//! println!("served by {}", ctx.backend());
//! ```

use std::path::PathBuf;
use std::rc::Rc;

use anyhow::{ensure, Context as _, Result};

use crate::chain::{self, ComputeOp};
use crate::exec::{
    Engine, EngineSelect, FusedEngine, GraphEngine, HostFusedEngine, UnfusedEngine,
};
use crate::ops::{kernel, Opcode, Pipeline, ReduceAxis, ReduceKind, ReduceSpec};
use crate::runtime::Registry;
use crate::tensor::{DType, Tensor};

/// Which backend [`EngineSelect`] resolution actually picked.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActiveBackend {
    /// The artifact registry loaded: XLA fused/unfused/graph engines.
    Xla,
    /// Host fused engine: single-pass CPU execution, runs everywhere.
    HostFused,
}

impl std::fmt::Display for ActiveBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ActiveBackend::Xla => "xla",
            ActiveBackend::HostFused => "host_fused",
        })
    }
}

/// The artifact-backed engine set (present when the registry loaded).
pub struct XlaEngines {
    pub fused: FusedEngine,
    pub unfused: UnfusedEngine,
    pub graph: GraphEngine,
    pub registry: Rc<Registry>,
}

impl XlaEngines {
    fn new(registry: Rc<Registry>) -> XlaEngines {
        XlaEngines {
            fused: FusedEngine::new(registry.clone()),
            unfused: UnfusedEngine::new(registry.clone()),
            graph: GraphEngine::new(registry.clone()),
            registry,
        }
    }
}

/// Execution context: backend selection + the engines it resolved. The host
/// fused engine is ALWAYS present (it is the backend that runs everywhere);
/// the XLA engine set exists when the artifact registry loaded.
pub struct Context {
    xla: Option<XlaEngines>,
    host: HostFusedEngine,
}

impl Context {
    /// [`EngineSelect::Auto`] on the default artifact directory: never fails
    /// just because artifacts are absent — the host fused backend serves.
    pub fn new() -> Result<Context> {
        Self::with_select(EngineSelect::Auto, None)
    }

    /// XLA pinned on an explicit artifact directory (a missing registry is a
    /// hard error — the pre-Auto behavior, used where artifacts are the
    /// point, e.g. the experiment runners).
    pub fn with_dir(dir: impl AsRef<std::path::Path>) -> Result<Context> {
        Self::with_select(EngineSelect::Xla, Some(dir.as_ref().to_path_buf()))
    }

    /// Full backend selection — the same policy as
    /// [`crate::coordinator::ServiceConfig::engine`].
    pub fn with_select(select: EngineSelect, dir: Option<PathBuf>) -> Result<Context> {
        let host = HostFusedEngine::new();
        let dir = dir.unwrap_or_else(crate::default_artifact_dir);
        let xla = match select {
            EngineSelect::HostFused => None,
            // without the pjrt feature there is no XLA to prefer
            EngineSelect::Auto if !cfg!(feature = "pjrt") => None,
            EngineSelect::Xla | EngineSelect::Auto => match Registry::load(&dir) {
                Ok(r) => Some(XlaEngines::new(Rc::new(r))),
                Err(e) if select == EngineSelect::Auto => {
                    // degrade to the backend that runs everywhere, visibly
                    eprintln!(
                        "fkl-cv: artifact registry unavailable ({e:#}); \
                         using the host fused backend"
                    );
                    None
                }
                Err(e) => return Err(e.context("loading artifact registry")),
            },
        };
        Ok(Context { xla, host })
    }

    /// Which backend selection picked (exposed so callers can report it).
    pub fn backend(&self) -> ActiveBackend {
        if self.xla.is_some() {
            ActiveBackend::Xla
        } else {
            ActiveBackend::HostFused
        }
    }

    /// True when the XLA engine set is loaded.
    pub fn has_artifacts(&self) -> bool {
        self.xla.is_some()
    }

    /// The host fused engine (always available).
    pub fn host(&self) -> &HostFusedEngine {
        &self.host
    }

    /// The XLA fused engine; errors when the registry did not load.
    pub fn fused(&self) -> Result<&FusedEngine> {
        self.xla
            .as_ref()
            .map(|x| &x.fused)
            .context("artifact registry not loaded (backend = host_fused); run `make artifacts`")
    }

    /// The per-op baseline engine; errors when the registry did not load.
    pub fn unfused(&self) -> Result<&UnfusedEngine> {
        self.xla
            .as_ref()
            .map(|x| &x.unfused)
            .context("artifact registry not loaded (backend = host_fused); run `make artifacts`")
    }

    /// The graph-replay baseline engine; errors when the registry did not load.
    pub fn graph(&self) -> Result<&GraphEngine> {
        self.xla
            .as_ref()
            .map(|x| &x.graph)
            .context("artifact registry not loaded (backend = host_fused); run `make artifacts`")
    }

    /// The artifact registry; errors when it did not load.
    pub fn registry(&self) -> Result<Rc<Registry>> {
        self.xla
            .as_ref()
            .map(|x| x.registry.clone())
            .context("artifact registry not loaded (backend = host_fused); run `make artifacts`")
    }

    /// Every engine this context can drive, preferred first — the surface
    /// `fkl run` and the examples iterate.
    pub fn engines(&self) -> Vec<(&'static str, &dyn Engine)> {
        let mut v: Vec<(&'static str, &dyn Engine)> = Vec::new();
        if let Some(x) = &self.xla {
            v.push(("fused", &x.fused));
            v.push(("unfused", &x.unfused));
            v.push(("graph", &x.graph));
        }
        v.push(("host_fused", &self.host));
        v
    }

    /// Run a pipeline on the selected primary backend (XLA fused when
    /// loaded, host fused otherwise). Structured pipelines (crop/resize
    /// reads, split writes) are served on EITHER backend: the host engine
    /// runs them natively, and the XLA fused engine re-routes them to its
    /// host fallback when no dedicated artifact family covers them.
    pub fn run(&self, p: &Pipeline, input: &Tensor) -> Result<Tensor> {
        match &self.xla {
            Some(x) => x.fused.run(p, input),
            None => self.host.run(p, input),
        }
    }

    /// Run a WINDOW of pipelines in one pass — the divergent-HF front door
    /// of the generic context. Mixed windows (different params, signatures,
    /// chain lengths; dense, structured and reduce terminators alike) serve
    /// on either backend: the host engine chunks the window across its
    /// worker lanes natively
    /// ([`HostFusedEngine::run_divergent`](crate::exec::HostFusedEngine::run_divergent)),
    /// and the XLA fused engine detects the divergence (typed, counted in
    /// `PlannerStats::divergent`) and re-routes the window to its host
    /// divergent tier. Results come back in window order, bit-equal to
    /// running each request alone; the first failing item fails the call,
    /// naming its window index.
    pub fn run_many(&self, window: &[(&Pipeline, &Tensor)]) -> Result<Vec<Tensor>> {
        let out = match &self.xla {
            Some(x) => x.fused.run_many(window),
            None => self.host.run_divergent(window),
        };
        out.results
            .into_iter()
            .enumerate()
            .map(|(i, r)| r.with_context(|| format!("window item {i}")))
            .collect()
    }
}

// --- the OpenCV-flavored stage constructors (lazy, no kernel launched) -----

/// `convertTo` — dtype cast happens at the pipeline's read/write boundary,
/// so the stage itself is the identity (paper: Cast is a UOp).
pub fn convert_to() -> ComputeOp {
    ComputeOp::scalar(Opcode::Nop, 0.0)
}

/// `cv::cuda::add` with a scalar.
pub fn add(v: f64) -> ComputeOp {
    ComputeOp::scalar(Opcode::Add, v)
}

/// `cv::cuda::multiply` with a scalar.
pub fn multiply(v: f64) -> ComputeOp {
    ComputeOp::scalar(Opcode::Mul, v)
}

/// `cv::cuda::subtract` with a scalar.
pub fn subtract(v: f64) -> ComputeOp {
    ComputeOp::scalar(Opcode::Sub, v)
}

/// `cv::cuda::divide` with a scalar.
pub fn divide(v: f64) -> ComputeOp {
    ComputeOp::scalar(Opcode::Div, v)
}

/// `cv::cuda::abs`.
pub fn abs() -> ComputeOp {
    ComputeOp::scalar(Opcode::Abs, 0.0)
}

/// `cv::cuda::min` with a scalar.
pub fn min(v: f64) -> ComputeOp {
    ComputeOp::scalar(Opcode::Min, v)
}

/// `cv::cuda::max` with a scalar.
pub fn max(v: f64) -> ComputeOp {
    ComputeOp::scalar(Opcode::Max, v)
}

/// `cv::cuda::sqrt` (magnitude).
pub fn sqrt() -> ComputeOp {
    ComputeOp::scalar(Opcode::Sqrt, 0.0)
}

/// `cv::cuda::exp`.
pub fn exp() -> ComputeOp {
    ComputeOp::scalar(Opcode::Exp, 0.0)
}

/// Lower the stage list for a batched input tensor `[B, ...shape]` through
/// the typed chain builder (the single dynamic entrance,
/// [`chain::build_erased`]).
pub fn build_pipeline(input: &Tensor, dtout: DType, stages: &[ComputeOp]) -> Result<Pipeline> {
    ensure!(
        input.shape().len() >= 2,
        "input must be batched: [B, ...shape], got {:?}",
        input.shape()
    );
    let shape = input.shape()[1..].to_vec();
    let batch = input.shape()[0];
    Ok(chain::build_erased(stages, &shape, batch, input.dtype(), dtout))
}

/// The executor function (paper Fig. 15 line 7): fuse + launch ONCE, on
/// whichever backend [`EngineSelect::Auto`] resolved.
pub fn execute_operations(
    ctx: &Context,
    input: &Tensor,
    dtout: DType,
    stages: &[ComputeOp],
) -> Result<Tensor> {
    let p = build_pipeline(input, dtout, stages)?;
    ctx.run(&p, input)
}

/// `cv::cuda::meanStdDev` analog: per-channel (or full-tensor) mean and
/// standard deviation of a batched `[B, ...shape]` tensor in ONE fused
/// reduce-while-reading pass (mean and sum-of-squares fold together; no
/// intermediate ever materializes). Serves on every backend: natively on
/// the host tier, re-routed there by the XLA fused engine
/// (`PlanError::Reduction` is artifact-tier-only).
pub fn mean_std(ctx: &Context, input: &Tensor, axis: ReduceAxis) -> Result<(Vec<f64>, Vec<f64>)> {
    ensure!(
        input.shape().len() >= 2,
        "input must be batched: [B, ...shape], got {:?}",
        input.shape()
    );
    let shape = input.shape()[1..].to_vec();
    let batch = input.shape()[0];
    let spec = ReduceSpec::pair(ReduceKind::Mean, ReduceKind::SumSq, axis);
    let p = chain::build_erased_reduce(&[], &shape, batch, input.dtype(), spec);
    let stats = ctx.run(&p, input)?;
    let vals = stats.as_f64().context("reduce pipelines seal at f64")?;
    // eps 0: report σ exactly as measured (a constant channel HAS σ = 0)
    Ok(kernel::mean_sigma_from_stats(spec, vals, input.len(), 0.0))
}

/// Fused two-pass normalize: `(x − μ) / σ` with data-derived statistics —
/// pass 1 folds mean+sumsq while reading, pass 2 maps with μ/σ bound as
/// stage params; the only tensor ever written is the f32 output.
pub fn normalize(ctx: &Context, input: &Tensor, axis: ReduceAxis) -> Result<Tensor> {
    // pass 1 IS mean_std's fused reduce; floor σ afterwards so pass 2's
    // divide stays well-defined on constant inputs (same result as deriving
    // with the floor in place)
    let (mu, sigma_raw) = mean_std(ctx, input, axis)?;
    let sigma: Vec<f64> = sigma_raw.iter().map(|s| s.max(1e-12)).collect();
    let shape = input.shape()[1..].to_vec();
    let batch = input.shape()[0];
    // pass 2's body comes from the ONE shared definition (the typed
    // Normalize preset builds the very same stages)
    let stages = chain::normalize_stages(axis, &mu, &sigma);
    let p2 = chain::build_erased(&stages, &shape, batch, input.dtype(), DType::F32);
    ctx.run(&p2, input)
}

/// The same chain executed the way stock OpenCV-CUDA would run it: one
/// kernel per call, intermediates in device memory (experiment baseline;
/// requires artifacts).
pub fn execute_operations_opencv_style(
    ctx: &Context,
    input: &Tensor,
    dtout: DType,
    stages: &[ComputeOp],
) -> Result<Tensor> {
    let p = build_pipeline(input, dtout, stages)?;
    ctx.unfused()?.run(&p, input)
}

/// OpenCV-CUDA + CUDA Graphs baseline: recorded once, replayed (requires
/// artifacts).
pub fn execute_operations_graph_style(
    ctx: &Context,
    input: &Tensor,
    dtout: DType,
    stages: &[ComputeOp],
) -> Result<Tensor> {
    let p = build_pipeline(input, dtout, stages)?;
    ctx.graph()?.run(&p, input)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::IOp;

    #[test]
    fn stages_are_lazy_values() {
        // calling wrapper functions performs no GPU work and no allocation
        // beyond the stage value itself (paper §IV-D)
        let ops = [convert_to(), multiply(2.0), subtract(1.0), divide(4.0)];
        assert_eq!(ops.len(), 4);
        assert_eq!(ops[1].iop(), &IOp::compute(Opcode::Mul, 2.0));
    }

    #[test]
    fn build_pipeline_validates_through_the_typed_chain() {
        let t = Tensor::zeros(DType::U8, &[2, 4, 4]);
        let p = build_pipeline(&t, DType::F32, &[convert_to(), multiply(2.0)]).unwrap();
        assert_eq!(p.batch, 2);
        assert_eq!(p.shape, vec![4, 4]);
        assert_eq!(p.dtin, DType::U8);
        assert_eq!(p.dtout, DType::F32);
        // unbatched input is rejected before lowering
        assert!(build_pipeline(&Tensor::zeros(DType::U8, &[4]), DType::F32, &[]).is_err());
    }

    #[test]
    fn auto_context_always_comes_up() {
        // satellite: cv::Context::new() must not hard-fail without artifacts
        let ctx = Context::new().expect("Auto never fails on a bare machine");
        if cfg!(not(feature = "pjrt")) {
            assert_eq!(ctx.backend(), ActiveBackend::HostFused);
            assert!(!ctx.has_artifacts());
            assert!(ctx.fused().is_err(), "XLA accessors fail loudly");
            assert_eq!(ctx.engines().len(), 1);
        }
        // the host engine serves real traffic either way
        let input = Tensor::from_u8(&[10, 20, 30, 40], &[1, 4]);
        let out =
            execute_operations(&ctx, &input, DType::F32, &[multiply(2.0), add(1.0)]).unwrap();
        assert_eq!(out.as_f32().unwrap(), &[21.0, 41.0, 61.0, 81.0]);
    }

    #[test]
    fn pinned_host_backend_ignores_artifacts() {
        let ctx = Context::with_select(EngineSelect::HostFused, None).unwrap();
        assert_eq!(ctx.backend(), ActiveBackend::HostFused);
        assert_eq!(ctx.backend().to_string(), "host_fused");
    }

    #[test]
    fn mean_std_and_normalize_serve_on_any_backend() {
        let ctx = Context::with_select(EngineSelect::HostFused, None).unwrap();
        // 2 items of [2, 3] packed pixels: per-channel stats over the batch
        let vals: Vec<f32> =
            vec![1.0, 10.0, 100.0, 3.0, 30.0, 300.0, 5.0, 50.0, 500.0, 7.0, 70.0, 700.0];
        let x = Tensor::from_f32(&vals, &[2, 2, 3]);
        let (mu, sigma) = mean_std(&ctx, &x, ReduceAxis::PerChannel).unwrap();
        assert_eq!(mu, vec![4.0, 40.0, 400.0]);
        // σ of {1,3,5,7} about mean 4 = sqrt(5)
        assert!((sigma[0] - 5.0f64.sqrt()).abs() < 1e-12, "{sigma:?}");

        let out = normalize(&ctx, &x, ReduceAxis::PerChannel).unwrap();
        assert_eq!(out.shape(), x.shape());
        assert_eq!(out.dtype(), DType::F32);
        // each channel lands mean 0 / σ 1
        let v = out.as_f32().unwrap();
        let lane0: Vec<f64> = v.iter().step_by(3).map(|&a| a as f64).collect();
        let m: f64 = lane0.iter().sum::<f64>() / lane0.len() as f64;
        assert!(m.abs() < 1e-6, "{m}");

        // full-tensor stats agree with a hand fold
        let (mu, _) = mean_std(&ctx, &x, ReduceAxis::Full).unwrap();
        let want: f64 = vals.iter().map(|&a| a as f64).sum::<f64>() / vals.len() as f64;
        assert_eq!(mu, vec![want]);

        // unbatched inputs are rejected before any pass runs
        assert!(mean_std(&ctx, &Tensor::zeros(DType::F32, &[4]), ReduceAxis::Full).is_err());
    }

    #[test]
    fn context_run_many_serves_mixed_windows() {
        // three distinct signatures — dense map, crop read, reduce seal —
        // through the generic front door in one divergent pass
        use crate::ops::ReduceKind;
        use crate::tensor::{make_frame, Rect};
        let ctx = Context::with_select(EngineSelect::HostFused, None).unwrap();
        let dense = chain::Chain::read::<chain::U8>(&[4, 6])
            .map(chain::Mul(3.0))
            .cast::<chain::F32>()
            .write()
            .into_pipeline();
        let crop = chain::Chain::read_crop::<chain::U8>(Rect::new(0, 1, 5, 4))
            .map(chain::Mul(0.5))
            .write()
            .into_pipeline();
        let stats = chain::Chain::read::<chain::U8>(&[4, 6])
            .reduce(ReduceKind::Mean)
            .into_pipeline();
        let item = Tensor::from_u8(&(0..24).collect::<Vec<u8>>(), &[1, 4, 6]);
        let frame = make_frame(10, 12, 9);
        let window: Vec<(&Pipeline, &Tensor)> =
            vec![(&dense, &item), (&crop, &frame), (&stats, &item)];
        let got = ctx.run_many(&window).expect("mixed window serves on any backend");
        assert_eq!(got.len(), 3);
        for (i, ((p, t), out)) in window.iter().zip(&got).enumerate() {
            assert_eq!(out, &crate::hostref::run_pipeline(p, t), "item {i}");
            assert_eq!(out, &ctx.run(p, t).unwrap(), "item {i} == per-item run");
        }
        assert_eq!(ctx.host().divergent_runs(), 1);
    }

    #[test]
    fn context_run_serves_structured_pipelines() {
        // the flagship workload shape through the generic front door: a
        // crop+resize read with a split write runs on whatever backend the
        // context resolved — artifact-free machines included
        use crate::tensor::{make_frame, Rect};
        let ctx = Context::with_select(EngineSelect::HostFused, None).unwrap();
        let p = chain::Chain::read_resize::<chain::U8>(Rect::new(2, 2, 20, 10), 8, 6)
            .map(chain::CvtColor)
            .cast::<chain::F32>()
            .write_split()
            .into_pipeline();
        let frame = make_frame(40, 50, 77);
        let out = ctx.run(&p, &frame).unwrap();
        assert_eq!(out.shape(), &[1, 3, 8, 6]);
        assert_eq!(out, crate::hostref::run_pipeline(&p, &frame));
    }
}
