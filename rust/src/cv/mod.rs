//! cvGS — the cvGPUSpeedup-style wrapper (paper §IV-D, Fig. 15/25a).
//!
//! Functions mirror OpenCV-CUDA's names and argument feel but, exactly like
//! the paper's cvGS, DO NOT launch kernels: each returns an IOp. The user
//! hands the IOps to [`execute_operations`], which builds the validated
//! pipeline and runs it through the fused engine — one kernel for the whole
//! chain, no intermediate `d_temp`/`d_up` allocations.
//!
//! ```no_run
//! use fkl::cv::*;
//! use fkl::tensor::{DType, Tensor};
//! let ctx = Context::new().unwrap();
//! let crops = Tensor::zeros(DType::U8, &[50, 60, 120]);
//! let out = execute_operations(
//!     &ctx,
//!     &crops,
//!     DType::F32,
//!     &[
//!         convert_to(),            // cv::cuda::GpuMat::convertTo
//!         multiply(0.5),           // cv::cuda::multiply
//!         subtract(10.0),          // cv::cuda::subtract
//!         divide(2.0),             // cv::cuda::divide
//!     ],
//! ).unwrap();
//! ```

use std::rc::Rc;

use anyhow::{Context as _, Result};

use crate::exec::{Engine, FusedEngine, GraphEngine, UnfusedEngine};
use crate::ops::{IOp, Opcode, Pipeline};
use crate::runtime::Registry;
use crate::tensor::{DType, Tensor};

/// Execution context: registry + the three engines (fused is the default
/// path; unfused/graph exist for the baseline comparisons).
pub struct Context {
    pub fused: FusedEngine,
    pub unfused: UnfusedEngine,
    pub graph: GraphEngine,
    pub registry: Rc<Registry>,
}

impl Context {
    pub fn new() -> Result<Context> {
        Self::with_dir(crate::default_artifact_dir())
    }

    pub fn with_dir(dir: impl AsRef<std::path::Path>) -> Result<Context> {
        let registry = Rc::new(Registry::load(dir).context("loading artifact registry")?);
        Ok(Context {
            fused: FusedEngine::new(registry.clone()),
            unfused: UnfusedEngine::new(registry.clone()),
            graph: GraphEngine::new(registry.clone()),
            registry,
        })
    }
}

// --- the OpenCV-flavored IOp constructors (lazy, no kernel launched) -------

/// `convertTo` — dtype cast happens at the pipeline's read/write boundary, so
/// the IOp itself is the identity (paper: Cast is a UOp).
pub fn convert_to() -> IOp {
    IOp::compute(Opcode::Nop, 0.0)
}

/// `cv::cuda::add` with a scalar.
pub fn add(v: f64) -> IOp {
    IOp::compute(Opcode::Add, v)
}

/// `cv::cuda::multiply` with a scalar.
pub fn multiply(v: f64) -> IOp {
    IOp::compute(Opcode::Mul, v)
}

/// `cv::cuda::subtract` with a scalar.
pub fn subtract(v: f64) -> IOp {
    IOp::compute(Opcode::Sub, v)
}

/// `cv::cuda::divide` with a scalar.
pub fn divide(v: f64) -> IOp {
    IOp::compute(Opcode::Div, v)
}

/// `cv::cuda::abs`.
pub fn abs() -> IOp {
    IOp::compute(Opcode::Abs, 0.0)
}

/// `cv::cuda::min` with a scalar.
pub fn min(v: f64) -> IOp {
    IOp::compute(Opcode::Min, v)
}

/// `cv::cuda::max` with a scalar.
pub fn max(v: f64) -> IOp {
    IOp::compute(Opcode::Max, v)
}

/// `cv::cuda::sqrt` (magnitude).
pub fn sqrt() -> IOp {
    IOp::compute(Opcode::Sqrt, 0.0)
}

/// `cv::cuda::exp`.
pub fn exp() -> IOp {
    IOp::compute(Opcode::Exp, 0.0)
}

/// Build the pipeline for a batched input tensor `[B, ...shape]`.
pub fn build_pipeline(input: &Tensor, dtout: DType, iops: &[IOp]) -> Result<Pipeline> {
    let shape = input.shape()[1..].to_vec();
    let batch = input.shape()[0];
    Pipeline::elementwise(iops.to_vec(), shape, batch, input.dtype(), dtout)
        .map_err(|e| anyhow::anyhow!("invalid operation chain: {e}"))
}

/// The executor function (paper Fig. 15 line 7): fuse + launch ONCE.
pub fn execute_operations(
    ctx: &Context,
    input: &Tensor,
    dtout: DType,
    iops: &[IOp],
) -> Result<Tensor> {
    let p = build_pipeline(input, dtout, iops)?;
    ctx.fused.run(&p, input)
}

/// The same chain executed the way stock OpenCV-CUDA would run it: one
/// kernel per call, intermediates in device memory (experiment baseline).
pub fn execute_operations_opencv_style(
    ctx: &Context,
    input: &Tensor,
    dtout: DType,
    iops: &[IOp],
) -> Result<Tensor> {
    let p = build_pipeline(input, dtout, iops)?;
    ctx.unfused.run(&p, input)
}

/// OpenCV-CUDA + CUDA Graphs baseline: recorded once, replayed.
pub fn execute_operations_graph_style(
    ctx: &Context,
    input: &Tensor,
    dtout: DType,
    iops: &[IOp],
) -> Result<Tensor> {
    let p = build_pipeline(input, dtout, iops)?;
    ctx.graph.run(&p, input)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iops_are_lazy_values() {
        // calling wrapper functions performs no GPU work and no allocation
        // beyond the IOp value itself (paper §IV-D)
        let ops = [convert_to(), multiply(2.0), subtract(1.0), divide(4.0)];
        assert_eq!(ops.len(), 4);
        assert_eq!(ops[1], IOp::compute(Opcode::Mul, 2.0));
    }

    #[test]
    fn build_pipeline_validates() {
        let t = Tensor::zeros(DType::U8, &[2, 4, 4]);
        let p = build_pipeline(&t, DType::F32, &[convert_to(), multiply(2.0)]).unwrap();
        assert_eq!(p.batch, 2);
        assert_eq!(p.shape, vec![4, 4]);
        assert_eq!(p.dtin, DType::U8);
        assert_eq!(p.dtout, DType::F32);
    }
}
