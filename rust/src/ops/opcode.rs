//! Element-wise compute op vocabulary (COps — paper §IV-A).
//!
//! Must stay in lockstep with `python/compile/opcodes.py`; the manifest
//! embeds the Python table and [`crate::runtime::Registry`] cross-checks it
//! at load time, so drift is a startup error, not a silent wrong answer.

/// One element-wise Compute Operation. `Binary*` ops take a scalar parameter
/// (the paper's `params`), `Unary*` ops ignore it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Opcode {
    Nop,
    Add,
    Sub,
    Mul,
    Div,
    Abs,
    Neg,
    Min,
    Max,
    Sqrt,
    Exp,
    Log,
    Clamp01,
}

pub const ALL_OPCODES: [Opcode; 13] = [
    Opcode::Nop,
    Opcode::Add,
    Opcode::Sub,
    Opcode::Mul,
    Opcode::Div,
    Opcode::Abs,
    Opcode::Neg,
    Opcode::Min,
    Opcode::Max,
    Opcode::Sqrt,
    Opcode::Exp,
    Opcode::Log,
    Opcode::Clamp01,
];

/// Expand `$body` once per opcode variant with `$c` bound to that variant as
/// a compile-time constant. This is the register-block trick: the dispatch
/// `match` runs ONCE, outside whatever loop `$body` contains, so after
/// inlining LLVM constant-folds the inner `apply` down to the one operation
/// and the surrounding lane loop autovectorizes. The arms deliberately carry
/// no semantics of their own — they only re-enter the scalar tables above
/// with a known `self`.
macro_rules! with_const_opcode {
    ($op:expr, |$c:ident| $body:expr) => {
        match $op {
            Opcode::Nop => {
                let $c = Opcode::Nop;
                $body
            }
            Opcode::Add => {
                let $c = Opcode::Add;
                $body
            }
            Opcode::Sub => {
                let $c = Opcode::Sub;
                $body
            }
            Opcode::Mul => {
                let $c = Opcode::Mul;
                $body
            }
            Opcode::Div => {
                let $c = Opcode::Div;
                $body
            }
            Opcode::Abs => {
                let $c = Opcode::Abs;
                $body
            }
            Opcode::Neg => {
                let $c = Opcode::Neg;
                $body
            }
            Opcode::Min => {
                let $c = Opcode::Min;
                $body
            }
            Opcode::Max => {
                let $c = Opcode::Max;
                $body
            }
            Opcode::Sqrt => {
                let $c = Opcode::Sqrt;
                $body
            }
            Opcode::Exp => {
                let $c = Opcode::Exp;
                $body
            }
            Opcode::Log => {
                let $c = Opcode::Log;
                $body
            }
            Opcode::Clamp01 => {
                let $c = Opcode::Clamp01;
                $body
            }
        }
    };
}

impl Opcode {
    /// Interpreter opcode (the lax.switch index in the InterpDPP kernel).
    pub fn code(self) -> i32 {
        match self {
            Opcode::Nop => 0,
            Opcode::Add => 1,
            Opcode::Sub => 2,
            Opcode::Mul => 3,
            Opcode::Div => 4,
            Opcode::Abs => 5,
            Opcode::Neg => 6,
            Opcode::Min => 7,
            Opcode::Max => 8,
            Opcode::Sqrt => 9,
            Opcode::Exp => 10,
            Opcode::Log => 11,
            Opcode::Clamp01 => 12,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Opcode::Nop => "nop",
            Opcode::Add => "add",
            Opcode::Sub => "sub",
            Opcode::Mul => "mul",
            Opcode::Div => "div",
            Opcode::Abs => "abs",
            Opcode::Neg => "neg",
            Opcode::Min => "min",
            Opcode::Max => "max",
            Opcode::Sqrt => "sqrt",
            Opcode::Exp => "exp",
            Opcode::Log => "log",
            Opcode::Clamp01 => "clamp01",
        }
    }

    pub fn parse(s: &str) -> Option<Opcode> {
        ALL_OPCODES.iter().copied().find(|o| o.name() == s)
    }

    /// BinaryType (takes a scalar param) vs UnaryType — paper Table I.
    pub fn takes_param(self) -> bool {
        matches!(self, Opcode::Add | Opcode::Sub | Opcode::Mul | Opcode::Div | Opcode::Min | Opcode::Max)
    }

    /// Apply in the compute domain — the hostref semantics of this op.
    /// Mirrors `opcodes.apply_op` exactly.
    pub fn apply(self, x: f64, p: f64) -> f64 {
        match self {
            Opcode::Nop => x,
            Opcode::Add => x + p,
            Opcode::Sub => x - p,
            Opcode::Mul => x * p,
            Opcode::Div => x / p,
            Opcode::Abs => x.abs(),
            Opcode::Neg => -x,
            Opcode::Min => x.min(p),
            Opcode::Max => x.max(p),
            Opcode::Sqrt => x.abs().sqrt(),
            Opcode::Exp => x.exp(),
            Opcode::Log => (x.abs() + 1.0).ln(),
            Opcode::Clamp01 => x.clamp(0.0, 1.0),
        }
    }

    /// f32 monomorphization of [`Opcode::apply`] — the fused host engine's
    /// register-resident fast path for f32 chains. Must mirror `apply`
    /// op-for-op (checked by `apply_f32_tracks_f64`); integer and f64
    /// pipelines never use it, so the oracle's f64 domain stays the single
    /// source of truth for exact semantics.
    #[inline(always)]
    pub fn apply_f32(self, x: f32, p: f32) -> f32 {
        match self {
            Opcode::Nop => x,
            Opcode::Add => x + p,
            Opcode::Sub => x - p,
            Opcode::Mul => x * p,
            Opcode::Div => x / p,
            Opcode::Abs => x.abs(),
            Opcode::Neg => -x,
            Opcode::Min => x.min(p),
            Opcode::Max => x.max(p),
            Opcode::Sqrt => x.abs().sqrt(),
            Opcode::Exp => x.exp(),
            Opcode::Log => (x.abs() + 1.0).ln(),
            Opcode::Clamp01 => x.clamp(0.0, 1.0),
        }
    }

    /// Apply this op to a fixed-width register block of f32 lanes — the
    /// SIMD-shaped form of [`Opcode::apply_f32`]. The opcode dispatch is
    /// hoisted OUTSIDE the lane loop (one `match`, then `N` applications of
    /// a compile-time-known op), so the loop body is branch-free straight
    /// arithmetic the autovectorizer turns into AVX2/NEON lanes. Each arm
    /// re-invokes the scalar table with a constant `self`, so the semantics
    /// stay defined exactly once and the two forms cannot drift
    /// (bit-identity is pinned by `lane_blocks_match_scalar_bit_for_bit`).
    #[inline(always)]
    pub fn apply_f32_lanes<const N: usize>(self, lanes: &mut [f32; N], p: f32) {
        with_const_opcode!(self, |op| {
            for v in lanes.iter_mut() {
                *v = op.apply_f32(*v, p);
            }
        });
    }

    /// f64 twin of [`Opcode::apply_f32_lanes`] — same hoisted dispatch, same
    /// single-source scalar semantics ([`Opcode::apply`]) per lane.
    #[inline(always)]
    pub fn apply_f64_lanes<const N: usize>(self, lanes: &mut [f64; N], p: f64) {
        with_const_opcode!(self, |op| {
            for v in lanes.iter_mut() {
                *v = op.apply(*v, p);
            }
        });
    }

    /// Slice form of [`Opcode::apply_f64_lanes`] for callers whose block
    /// width is not a const generic (the lane-group and structured paths,
    /// which stage whole pixel groups into one buffer). Same hoisted
    /// dispatch, same per-element semantics as [`Opcode::apply`].
    #[inline(always)]
    pub fn apply_f64_slice(self, vals: &mut [f64], p: f64) {
        with_const_opcode!(self, |op| {
            for v in vals.iter_mut() {
                *v = op.apply(*v, p);
            }
        });
    }

    /// Per-channel (packed RGB) slice form: element `base + j` takes its
    /// parameter from `param[(base + j) % 3]` — the same global-index lane
    /// rule as `ScalarOp::PerLane`, with the opcode dispatch hoisted out of
    /// the element loop like the other blocked forms.
    #[inline(always)]
    pub fn apply_f64_slice_c3(self, vals: &mut [f64], base: usize, param: [f32; 3]) {
        with_const_opcode!(self, |op| {
            for (j, v) in vals.iter_mut().enumerate() {
                *v = op.apply(*v, param[(base + j) % 3] as f64);
            }
        });
    }

    /// Approximate per-element instruction cost (used by the roofline cost
    /// model and the GPU simulator; mul/add == 1 like the paper's Fig. 1).
    pub fn instr_cost(self) -> f64 {
        match self {
            Opcode::Nop => 0.0,
            Opcode::Add | Opcode::Sub | Opcode::Mul | Opcode::Neg | Opcode::Abs => 1.0,
            Opcode::Min | Opcode::Max | Opcode::Clamp01 => 1.0,
            Opcode::Div => 4.0,
            Opcode::Sqrt => 8.0,
            Opcode::Exp | Opcode::Log => 16.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_dense_and_ordered() {
        for (i, op) in ALL_OPCODES.iter().enumerate() {
            assert_eq!(op.code(), i as i32, "opcode table must be dense (switch index)");
        }
    }

    #[test]
    fn name_roundtrip() {
        for op in ALL_OPCODES {
            assert_eq!(Opcode::parse(op.name()), Some(op));
        }
        assert_eq!(Opcode::parse("bogus"), None);
    }

    #[test]
    fn apply_semantics() {
        assert_eq!(Opcode::Mul.apply(3.0, 2.0), 6.0);
        assert_eq!(Opcode::Div.apply(3.0, 2.0), 1.5);
        assert_eq!(Opcode::Neg.apply(3.0, 99.0), -3.0);
        assert_eq!(Opcode::Clamp01.apply(3.0, 99.0), 1.0);
        assert_eq!(Opcode::Log.apply(0.0, 0.0), 0.0);
    }

    #[test]
    fn apply_f32_tracks_f64() {
        // the f32 kernel must behave like the f64 kernel rounded to f32, for
        // every opcode over a representative input/param grid — including
        // x=200, where Exp overflows f32 (expect = (e^200 as f32) = inf) but
        // stays finite in f64
        let xs = [-3.5f64, -1.0, -0.25, 0.0, 0.5, 1.0, 2.75, 200.0];
        let ps = [-2.0f64, -0.5, 0.0, 0.5, 1.5, 3.0];
        for op in ALL_OPCODES {
            for &x in &xs {
                for &p in &ps {
                    let expect = op.apply(x, p) as f32;
                    let narrow = op.apply_f32(x as f32, p as f32);
                    if expect.is_nan() {
                        assert!(narrow.is_nan(), "{op:?}({x},{p})");
                    } else if expect.is_infinite() {
                        assert_eq!(expect, narrow, "{op:?}({x},{p})");
                    } else {
                        let tol = 1e-5 * (1.0 + expect.abs());
                        assert!(
                            (expect - narrow).abs() <= tol,
                            "{op:?}({x},{p}): {expect} vs {narrow}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn lane_blocks_match_scalar_bit_for_bit() {
        // the blocked forms are the SAME scalar table applied lane-by-lane:
        // for every opcode, over a grid including negatives, zero, NaN-free
        // extremes and values that overflow f32, each lane must equal the
        // scalar apply bit-for-bit (NaN compared as NaN)
        let xs = [-3.5f64, -1.0, -0.25, 0.0, 0.5, 1.0, 2.75, 200.0];
        let ps = [-2.0f64, -0.5, 0.0, 0.5, 1.5, 3.0];
        for op in ALL_OPCODES {
            for &p in &ps {
                let mut l64 = [0f64; 8];
                l64.copy_from_slice(&xs);
                op.apply_f64_lanes(&mut l64, p);
                let mut s64 = xs;
                op.apply_f64_slice(&mut s64, p);
                let mut l32 = [0f32; 8];
                for (d, &x) in l32.iter_mut().zip(&xs) {
                    *d = x as f32;
                }
                op.apply_f32_lanes(&mut l32, p as f32);
                for (j, &x) in xs.iter().enumerate() {
                    let want = op.apply(x, p);
                    assert_eq!(l64[j].to_bits(), want.to_bits(), "{op:?} f64 lane ({x},{p})");
                    assert_eq!(s64[j].to_bits(), want.to_bits(), "{op:?} f64 slice ({x},{p})");
                    let want32 = op.apply_f32(x as f32, p as f32);
                    assert_eq!(l32[j].to_bits(), want32.to_bits(), "{op:?} f32 lane ({x},{p})");
                }
            }
        }
    }

    #[test]
    fn param_classification_matches_python() {
        // binary ops per python OPS table
        for op in [Opcode::Add, Opcode::Sub, Opcode::Mul, Opcode::Div, Opcode::Min, Opcode::Max] {
            assert!(op.takes_param());
        }
        for op in [Opcode::Nop, Opcode::Abs, Opcode::Neg, Opcode::Sqrt, Opcode::Exp, Opcode::Log, Opcode::Clamp01] {
            assert!(!op.takes_param());
        }
    }
}
