//! Element-wise compute op vocabulary (COps — paper §IV-A).
//!
//! Must stay in lockstep with `python/compile/opcodes.py`; the manifest
//! embeds the Python table and [`crate::runtime::Registry`] cross-checks it
//! at load time, so drift is a startup error, not a silent wrong answer.

/// One element-wise Compute Operation. `Binary*` ops take a scalar parameter
/// (the paper's `params`), `Unary*` ops ignore it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Opcode {
    Nop,
    Add,
    Sub,
    Mul,
    Div,
    Abs,
    Neg,
    Min,
    Max,
    Sqrt,
    Exp,
    Log,
    Clamp01,
}

pub const ALL_OPCODES: [Opcode; 13] = [
    Opcode::Nop,
    Opcode::Add,
    Opcode::Sub,
    Opcode::Mul,
    Opcode::Div,
    Opcode::Abs,
    Opcode::Neg,
    Opcode::Min,
    Opcode::Max,
    Opcode::Sqrt,
    Opcode::Exp,
    Opcode::Log,
    Opcode::Clamp01,
];

impl Opcode {
    /// Interpreter opcode (the lax.switch index in the InterpDPP kernel).
    pub fn code(self) -> i32 {
        match self {
            Opcode::Nop => 0,
            Opcode::Add => 1,
            Opcode::Sub => 2,
            Opcode::Mul => 3,
            Opcode::Div => 4,
            Opcode::Abs => 5,
            Opcode::Neg => 6,
            Opcode::Min => 7,
            Opcode::Max => 8,
            Opcode::Sqrt => 9,
            Opcode::Exp => 10,
            Opcode::Log => 11,
            Opcode::Clamp01 => 12,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Opcode::Nop => "nop",
            Opcode::Add => "add",
            Opcode::Sub => "sub",
            Opcode::Mul => "mul",
            Opcode::Div => "div",
            Opcode::Abs => "abs",
            Opcode::Neg => "neg",
            Opcode::Min => "min",
            Opcode::Max => "max",
            Opcode::Sqrt => "sqrt",
            Opcode::Exp => "exp",
            Opcode::Log => "log",
            Opcode::Clamp01 => "clamp01",
        }
    }

    pub fn parse(s: &str) -> Option<Opcode> {
        ALL_OPCODES.iter().copied().find(|o| o.name() == s)
    }

    /// BinaryType (takes a scalar param) vs UnaryType — paper Table I.
    pub fn takes_param(self) -> bool {
        matches!(self, Opcode::Add | Opcode::Sub | Opcode::Mul | Opcode::Div | Opcode::Min | Opcode::Max)
    }

    /// Apply in the compute domain — the hostref semantics of this op.
    /// Mirrors `opcodes.apply_op` exactly.
    pub fn apply(self, x: f64, p: f64) -> f64 {
        match self {
            Opcode::Nop => x,
            Opcode::Add => x + p,
            Opcode::Sub => x - p,
            Opcode::Mul => x * p,
            Opcode::Div => x / p,
            Opcode::Abs => x.abs(),
            Opcode::Neg => -x,
            Opcode::Min => x.min(p),
            Opcode::Max => x.max(p),
            Opcode::Sqrt => x.abs().sqrt(),
            Opcode::Exp => x.exp(),
            Opcode::Log => (x.abs() + 1.0).ln(),
            Opcode::Clamp01 => x.clamp(0.0, 1.0),
        }
    }

    /// f32 monomorphization of [`Opcode::apply`] — the fused host engine's
    /// register-resident fast path for f32 chains. Must mirror `apply`
    /// op-for-op (checked by `apply_f32_tracks_f64`); integer and f64
    /// pipelines never use it, so the oracle's f64 domain stays the single
    /// source of truth for exact semantics.
    #[inline(always)]
    pub fn apply_f32(self, x: f32, p: f32) -> f32 {
        match self {
            Opcode::Nop => x,
            Opcode::Add => x + p,
            Opcode::Sub => x - p,
            Opcode::Mul => x * p,
            Opcode::Div => x / p,
            Opcode::Abs => x.abs(),
            Opcode::Neg => -x,
            Opcode::Min => x.min(p),
            Opcode::Max => x.max(p),
            Opcode::Sqrt => x.abs().sqrt(),
            Opcode::Exp => x.exp(),
            Opcode::Log => (x.abs() + 1.0).ln(),
            Opcode::Clamp01 => x.clamp(0.0, 1.0),
        }
    }

    /// Approximate per-element instruction cost (used by the roofline cost
    /// model and the GPU simulator; mul/add == 1 like the paper's Fig. 1).
    pub fn instr_cost(self) -> f64 {
        match self {
            Opcode::Nop => 0.0,
            Opcode::Add | Opcode::Sub | Opcode::Mul | Opcode::Neg | Opcode::Abs => 1.0,
            Opcode::Min | Opcode::Max | Opcode::Clamp01 => 1.0,
            Opcode::Div => 4.0,
            Opcode::Sqrt => 8.0,
            Opcode::Exp | Opcode::Log => 16.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_dense_and_ordered() {
        for (i, op) in ALL_OPCODES.iter().enumerate() {
            assert_eq!(op.code(), i as i32, "opcode table must be dense (switch index)");
        }
    }

    #[test]
    fn name_roundtrip() {
        for op in ALL_OPCODES {
            assert_eq!(Opcode::parse(op.name()), Some(op));
        }
        assert_eq!(Opcode::parse("bogus"), None);
    }

    #[test]
    fn apply_semantics() {
        assert_eq!(Opcode::Mul.apply(3.0, 2.0), 6.0);
        assert_eq!(Opcode::Div.apply(3.0, 2.0), 1.5);
        assert_eq!(Opcode::Neg.apply(3.0, 99.0), -3.0);
        assert_eq!(Opcode::Clamp01.apply(3.0, 99.0), 1.0);
        assert_eq!(Opcode::Log.apply(0.0, 0.0), 0.0);
    }

    #[test]
    fn apply_f32_tracks_f64() {
        // the f32 kernel must behave like the f64 kernel rounded to f32, for
        // every opcode over a representative input/param grid — including
        // x=200, where Exp overflows f32 (expect = (e^200 as f32) = inf) but
        // stays finite in f64
        let xs = [-3.5f64, -1.0, -0.25, 0.0, 0.5, 1.0, 2.75, 200.0];
        let ps = [-2.0f64, -0.5, 0.0, 0.5, 1.5, 3.0];
        for op in ALL_OPCODES {
            for &x in &xs {
                for &p in &ps {
                    let expect = op.apply(x, p) as f32;
                    let narrow = op.apply_f32(x as f32, p as f32);
                    if expect.is_nan() {
                        assert!(narrow.is_nan(), "{op:?}({x},{p})");
                    } else if expect.is_infinite() {
                        assert_eq!(expect, narrow, "{op:?}({x},{p})");
                    } else {
                        let tol = 1e-5 * (1.0 + expect.abs());
                        assert!(
                            (expect - narrow).abs() <= tol,
                            "{op:?}({x},{p}): {expect} vs {narrow}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn param_classification_matches_python() {
        // binary ops per python OPS table
        for op in [Opcode::Add, Opcode::Sub, Opcode::Mul, Opcode::Div, Opcode::Min, Opcode::Max] {
            assert!(op.takes_param());
        }
        for op in [Opcode::Nop, Opcode::Abs, Opcode::Neg, Opcode::Sqrt, Opcode::Exp, Opcode::Log, Opcode::Clamp01] {
            assert!(!op.takes_param());
        }
    }
}
