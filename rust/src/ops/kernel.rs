//! The shared scalar semantics table: ONE lowering from [`IOp`] to an
//! execution-ready [`ScalarOp`], used by BOTH the hostref oracle (op-at-a-time
//! whole-buffer sweeps) and the fused host engine (single pass, intermediates
//! in registers). Because the two paths run the very same `apply_*` code for
//! every op, they cannot drift semantically — the only difference the fused
//! engine is allowed to introduce is the compute width (f32 fast path) and
//! the traffic pattern (one memory pass instead of one per op).

use super::{IOp, Opcode};

/// Lowered form of one compute-body IOp. Memory operations do not lower —
/// they are the pipeline's read/write boundary, not body semantics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScalarOp {
    /// Element-wise compute with a scalar parameter.
    Scalar { op: Opcode, param: f64 },
    /// Element-wise compute with a per-channel parameter; the lane is the
    /// global element index modulo 3 (packed RGB layout).
    PerLane { op: Opcode, param: [f32; 3] },
    /// BGR<->RGB swizzle within each packed 3-lane pixel.
    Swizzle,
}

impl ScalarOp {
    /// Lower one body IOp. Returns `None` for memory operations.
    pub fn lower(op: &IOp) -> Option<ScalarOp> {
        match op {
            IOp::Compute { op, param } => Some(ScalarOp::Scalar { op: *op, param: *param }),
            IOp::ComputeC3 { op, param } => Some(ScalarOp::PerLane { op: *op, param: *param }),
            IOp::CvtColor => Some(ScalarOp::Swizzle),
            IOp::Mem(_) => None,
        }
    }

    /// Lower a whole validated compute body. `None` if any op is a memop
    /// (impossible for a validated [`super::Pipeline`] body).
    pub fn lower_body(body: &[IOp]) -> Option<Vec<ScalarOp>> {
        body.iter().map(ScalarOp::lower).collect()
    }

    /// Apply this op to a slice of values in the f64 compute domain.
    ///
    /// `base` is the global element index of `vals[0]`; it only matters for
    /// lane-structured ops. The oracle calls this once per op with the whole
    /// buffer (`base = 0`); the fused engine calls it per pixel group with
    /// the group's global offset — both produce identical results.
    #[inline]
    pub fn apply_slice_f64(&self, vals: &mut [f64], base: usize) {
        match self {
            ScalarOp::Scalar { op, param } => {
                for v in vals.iter_mut() {
                    *v = op.apply(*v, *param);
                }
            }
            ScalarOp::PerLane { op, param } => {
                for (j, v) in vals.iter_mut().enumerate() {
                    *v = op.apply(*v, param[(base + j) % 3] as f64);
                }
            }
            ScalarOp::Swizzle => {
                for px in vals.chunks_mut(3) {
                    if px.len() == 3 {
                        px.swap(0, 2);
                    }
                }
            }
        }
    }

    /// True if this op needs 3-lane pixel structure (forces group width 3).
    pub fn is_lane_structured(&self) -> bool {
        matches!(self, ScalarOp::PerLane { .. } | ScalarOp::Swizzle)
    }
}

/// Element-group width of a lowered body: 3 when any op is lane-structured
/// (packed RGB pixels must stay together in registers), else 1.
pub fn group_width(body: &[ScalarOp]) -> usize {
    if body.iter().any(ScalarOp::is_lane_structured) {
        3
    } else {
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{MemOp, Pipeline};
    use crate::tensor::DType;

    #[test]
    fn lowering_covers_every_body_op() {
        let p = Pipeline::elementwise(
            vec![
                IOp::compute(Opcode::Mul, 2.0),
                IOp::ComputeC3 { op: Opcode::Add, param: [1.0, 2.0, 3.0] },
                IOp::CvtColor,
            ],
            vec![2, 3],
            1,
            DType::F32,
            DType::F32,
        )
        .unwrap();
        let body = ScalarOp::lower_body(p.body()).unwrap();
        assert_eq!(body.len(), 3);
        assert_eq!(group_width(&body), 3);
        assert!(ScalarOp::lower(&IOp::Mem(MemOp::Write { dtype: DType::F32 })).is_none());
    }

    #[test]
    fn scalar_chains_have_group_width_one() {
        let body = vec![
            ScalarOp::Scalar { op: Opcode::Mul, param: 2.0 },
            ScalarOp::Scalar { op: Opcode::Add, param: 1.0 },
        ];
        assert_eq!(group_width(&body), 1);
    }

    #[test]
    fn whole_buffer_equals_per_group_application() {
        // the invariant the fused engine relies on: applying an op to the
        // whole buffer at once == applying it group by group with offsets
        let ops = [
            ScalarOp::Scalar { op: Opcode::Mul, param: 1.5 },
            ScalarOp::PerLane { op: Opcode::Sub, param: [1.0, 2.0, 3.0] },
            ScalarOp::Swizzle,
        ];
        // 8 elements: not a multiple of 3, exercises the ragged tail
        let src: Vec<f64> = (0..8).map(|i| i as f64).collect();
        for op in &ops {
            let mut whole = src.clone();
            op.apply_slice_f64(&mut whole, 0);
            let mut grouped = src.clone();
            for (gi, chunk) in grouped.chunks_mut(3).enumerate() {
                op.apply_slice_f64(chunk, gi * 3);
            }
            assert_eq!(whole, grouped, "{op:?}");
        }
    }

    #[test]
    fn swizzle_skips_ragged_tail() {
        let mut v = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        ScalarOp::Swizzle.apply_slice_f64(&mut v, 0);
        assert_eq!(v, vec![3.0, 2.0, 1.0, 4.0, 5.0]);
    }
}
