//! The shared scalar semantics table: ONE lowering from [`IOp`] to an
//! execution-ready [`ScalarOp`], used by BOTH the hostref oracle (op-at-a-time
//! whole-buffer sweeps) and the fused host engine (single pass, intermediates
//! in registers). Because the two paths run the very same `apply_*` code for
//! every op, they cannot drift semantically — the only difference the fused
//! engine is allowed to introduce is the compute width (f32 fast path) and
//! the traffic pattern (one memory pass instead of one per op).
//!
//! The same rule covers the structured READ boundaries: the bilinear
//! crop-resize gather (half-pixel centers, edge clamp) is defined ONCE here
//! ([`bilinear_tap`], [`BilinearTap::blend`], [`clamped_frame_index`]) and
//! shared by the `hostref` oracle and the fused engine's CropResize reader,
//! so the gather semantics cannot drift either.

use crate::tensor::Rect;

use super::{IOp, Opcode, ReduceAxis, ReduceSpec};

/// Lowered form of one compute-body IOp. Memory operations do not lower —
/// they are the pipeline's read/write boundary, not body semantics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScalarOp {
    /// Element-wise compute with a scalar parameter.
    Scalar { op: Opcode, param: f64 },
    /// Element-wise compute with a per-channel parameter; the lane is the
    /// global element index modulo 3 (packed RGB layout).
    PerLane { op: Opcode, param: [f32; 3] },
    /// BGR<->RGB swizzle within each packed 3-lane pixel.
    Swizzle,
}

impl ScalarOp {
    /// Lower one body IOp. Returns `None` for memory operations.
    pub fn lower(op: &IOp) -> Option<ScalarOp> {
        match op {
            IOp::Compute { op, param } => Some(ScalarOp::Scalar { op: *op, param: *param }),
            IOp::ComputeC3 { op, param } => Some(ScalarOp::PerLane { op: *op, param: *param }),
            IOp::CvtColor => Some(ScalarOp::Swizzle),
            IOp::Mem(_) => None,
        }
    }

    /// Lower a whole validated compute body. `None` if any op is a memop
    /// (impossible for a validated [`super::Pipeline`] body).
    pub fn lower_body(body: &[IOp]) -> Option<Vec<ScalarOp>> {
        body.iter().map(ScalarOp::lower).collect()
    }

    /// Apply this op to a slice of values in the f64 compute domain.
    ///
    /// `base` is the global element index of `vals[0]`; it only matters for
    /// lane-structured ops. The oracle calls this once per op with the whole
    /// buffer (`base = 0`); the fused engine calls it per pixel group with
    /// the group's global offset — both produce identical results.
    /// The compute-op arms delegate to the blocked [`Opcode`] slice forms,
    /// which hoist the opcode dispatch out of the element loop — per-element
    /// semantics are unchanged (`apply` per element), but a whole register
    /// block flows through one op before the next dispatch.
    #[inline]
    pub fn apply_slice_f64(&self, vals: &mut [f64], base: usize) {
        match self {
            ScalarOp::Scalar { op, param } => op.apply_f64_slice(vals, *param),
            ScalarOp::PerLane { op, param } => op.apply_f64_slice_c3(vals, base, *param),
            ScalarOp::Swizzle => {
                for px in vals.chunks_mut(3) {
                    if px.len() == 3 {
                        px.swap(0, 2);
                    }
                }
            }
        }
    }

    /// True if this op needs 3-lane pixel structure (forces group width 3).
    pub fn is_lane_structured(&self) -> bool {
        matches!(self, ScalarOp::PerLane { .. } | ScalarOp::Swizzle)
    }
}

/// Element-group width of a lowered body: 3 when any op is lane-structured
/// (packed RGB pixels must stay together in registers), else 1.
pub fn group_width(body: &[ScalarOp]) -> usize {
    if body.iter().any(ScalarOp::is_lane_structured) {
        3
    } else {
        1
    }
}

// ---------------------------------------------------------------------------
// register-block widths (the SIMD shape of the fused inner loops)
//
// The fused host engine processes fixed-width element blocks per iteration —
// the dense fast arms stage `LANE_WIDTH_*` elements in a stack array, run the
// whole op chain over the block (dispatch hoisted per op, see
// `Opcode::apply_*_lanes`), then write the block out, with an explicit scalar
// tail for the ragged end. The widths are chosen so one block fills two
// AVX-512 / four AVX2 registers: wide enough that the autovectorizer emits
// full-width lanes at any of those targets, small enough to stay in registers
// on 128-bit NEON/SSE2.

/// Dense f32 fast-arm block width (16 × f32 = 64 bytes).
pub const LANE_WIDTH_F32: usize = 16;

/// Dense f64 arm block width (8 × f64 = 64 bytes). Also the width of the
/// lane-group arm in PIXELS (8 packed-RGB pixels = 24 f64 lanes per block).
pub const LANE_WIDTH_F64: usize = 8;

/// The SIMD instruction set the binary was compiled for, from compile-time
/// target features — printed by `fkl serve` and the benches so perf numbers
/// are interpretable across machines. The default x86-64 target reports
/// "sse2"; a `-C target-cpu=native` build on a modern core reports
/// "avx2"/"avx512".
pub fn simd_capability() -> &'static str {
    if cfg!(target_feature = "avx512f") {
        "avx512"
    } else if cfg!(target_feature = "avx2") {
        "avx2"
    } else if cfg!(target_feature = "neon") {
        "neon"
    } else if cfg!(target_feature = "sse2") {
        "sse2"
    } else {
        "scalar"
    }
}

// ---------------------------------------------------------------------------
// boundary gather semantics (the structured-read half of the one-table rule)

/// The four source taps + weights of one bilinear sample, in RECT-LOCAL
/// coordinates (half-pixel centers, interior clamp — matching
/// `python/compile/kernels/ref.bilinear_gather`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BilinearTap {
    pub y0: i32,
    pub y1: i32,
    pub wy: f64,
    pub x0: i32,
    pub x1: i32,
    pub wx: f64,
}

impl BilinearTap {
    /// Blend the four taps through `at(y, x)` (rect-local coordinates).
    /// The expression order is the contract: oracle and fused reader call
    /// this same code, so they agree BITWISE.
    #[inline]
    pub fn blend(&self, mut at: impl FnMut(i32, i32) -> f64) -> f64 {
        let top = at(self.y0, self.x0) * (1.0 - self.wx) + at(self.y0, self.x1) * self.wx;
        let bot = at(self.y1, self.x0) * (1.0 - self.wx) + at(self.y1, self.x1) * self.wx;
        top * (1.0 - self.wy) + bot * self.wy
    }
}

/// One axis of a bilinear tap: the two source indices and the fractional
/// weight for destination coordinate `d` of a `src` → `dst` axis resize.
/// The tap is separable — [`bilinear_tap`] is defined as two of these — so
/// hot loops may precompute one tap per output row/column (pure functions
/// of the geometry; identical bitwise results).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AxisTap {
    pub i0: i32,
    pub i1: i32,
    pub w: f64,
}

/// Source tap for destination coordinate `d` of a `src` → `dst` axis resize
/// (half-pixel centers, interior clamp).
#[inline]
pub fn axis_tap(d: usize, src: i32, dst: usize) -> AxisTap {
    let s = src as f64 / dst as f64;
    let f = ((d as f64 + 0.5) * s - 0.5).clamp(0.0, src as f64 - 1.0);
    let i0 = f.floor() as i32;
    let i1 = (i0 + 1).min(src - 1);
    AxisTap { i0, i1, w: f - i0 as f64 }
}

/// Source taps for destination pixel `(dy, dx)` of a `src_h`×`src_w` →
/// `dh`×`dw` bilinear resize: the two [`axis_tap`]s combined.
#[inline]
pub fn bilinear_tap(
    dy: usize,
    dx: usize,
    src_h: i32,
    src_w: i32,
    dh: usize,
    dw: usize,
) -> BilinearTap {
    let y = axis_tap(dy, src_h, dh);
    let x = axis_tap(dx, src_w, dw);
    BilinearTap { y0: y.i0, y1: y.i1, wy: y.w, x0: x.i0, x1: x.i1, wx: x.w }
}

/// Edge-clamped PIXEL index into an `fh`×`fw` packed frame for rect-local
/// `(y, x)` — the shared clamp rule of every crop-family read. Multiply by
/// the lane count (3) to address packed channels.
#[inline]
pub fn clamped_frame_index(rect: Rect, y: i32, x: i32, fh: i32, fw: i32) -> usize {
    let yy = (rect.y0 + y).clamp(0, fh - 1) as usize;
    let xx = (rect.x0 + x).clamp(0, fw - 1) as usize;
    yy * fw as usize + xx
}

/// Scatter one packed `[h*w, 3]` pixel plane into planar `[3, h*w]` order —
/// the Split WOp's layout contract, defined ONCE for every materializing
/// consumer (the structured oracle, the NPP-style step baseline). The fused
/// engine's split WRITER reproduces the same contract element-by-element
/// without ever materializing the packed side.
pub fn split_packed_to_planar<T: Copy>(packed: &[T], planar: &mut [T]) {
    debug_assert_eq!(packed.len(), planar.len());
    debug_assert_eq!(packed.len() % 3, 0);
    let pixels = packed.len() / 3;
    for i in 0..pixels {
        for (c, px) in packed[i * 3..i * 3 + 3].iter().enumerate() {
            planar[c * pixels + i] = *px;
        }
    }
}

// ---------------------------------------------------------------------------
// reduction semantics (the divergent-pattern half of the one-table rule)
//
// The fold itself lives on [`super::ReduceKind`]; what is defined HERE is the
// deterministic *shape* of a reduction — fixed-size blocks, a fixed stripe
// rule inside each block, a fixed pairwise combine tree, per-lane counts and
// the finalize layout — shared by the hostref oracle ([`reduce_slice`] over a
// materialized buffer) and the fused engine (the fold-while-reading tier
// computes the very same block partials without materializing). Inside a
// block, element `j` folds into sub-accumulator `j % REDUCE_LANES`
// ([`reduce_block_fold`]) and the `REDUCE_LANES` sub-accumulators combine
// through the same fixed pairwise tree — so a SIMD arm that folds 8 stripes
// at once and a scalar arm that folds one element at a time land on the SAME
// bits: which stripe an element feeds is a property of its block offset, not
// of the arm (or thread) that folds it. Because block boundaries, stripe
// assignment and combine order are all properties of the DATA, results are
// bit-identical across 1/2/8 workers, across oracle vs engine, and across
// scalar vs vectorized arms.

/// Elements per reduction block. Divisible by 3 so packed-RGB pixel groups
/// (and per-channel lanes) never straddle a block boundary, and by
/// [`REDUCE_LANES`] so full blocks have no stripe tail.
pub const REDUCE_BLOCK: usize = 3072;

/// Striped sub-accumulators per reduction block — the register-block width
/// of the reduce arm ([`LANE_WIDTH_F64`]): element `j` of a block folds into
/// stripe `j % REDUCE_LANES`.
pub const REDUCE_LANES: usize = LANE_WIDTH_F64;

/// One block's partial accumulators: up to 2 statistics × up to 3 lanes
/// (unused slots idle at their fold identity). Lane 0 is the only live lane
/// for full-tensor reductions.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReduceAcc {
    /// `s[lane][stat]` in the f64 accumulate domain.
    pub s: [[f64; 2]; 3],
}

/// The accumulator every block fold starts from.
pub fn reduce_acc_identity(spec: ReduceSpec) -> ReduceAcc {
    let mut s = [[0.0f64; 2]; 3];
    for lane in s.iter_mut() {
        for k in 0..spec.stat_count() {
            lane[k] = spec.stat(k).identity();
        }
    }
    ReduceAcc { s }
}

/// Fold element `x` at global element index `index` into `acc`. The lane of
/// a per-channel fold is `index % 3` — the same global-index lane rule as
/// [`ScalarOp::PerLane`], so statistics compose with lane-structured bodies.
#[inline(always)]
pub fn reduce_acc_fold(spec: ReduceSpec, acc: &mut ReduceAcc, index: usize, x: f64) {
    let lane = match spec.axis {
        ReduceAxis::Full => 0,
        ReduceAxis::PerChannel => index % 3,
    };
    for k in 0..spec.stat_count() {
        acc.s[lane][k] = spec.stat(k).fold(acc.s[lane][k], x);
    }
}

/// Combine two block partials (per stat, per lane).
pub fn reduce_acc_combine(spec: ReduceSpec, a: &ReduceAcc, b: &ReduceAcc) -> ReduceAcc {
    let mut out = *a;
    for lane in 0..3 {
        for k in 0..spec.stat_count() {
            out.s[lane][k] = spec.stat(k).combine(a.s[lane][k], b.s[lane][k]);
        }
    }
    out
}

/// Combine block partials in a FIXED pairwise tree: adjacent pairs per
/// round, regardless of who computed them. This is the determinism
/// contract — the combine order is a function of the block count alone, so
/// thread scheduling can never reorder a floating-point sum.
pub fn reduce_combine_tree(spec: ReduceSpec, partials: &[ReduceAcc]) -> ReduceAcc {
    if partials.is_empty() {
        return reduce_acc_identity(spec);
    }
    let mut cur = partials.to_vec();
    while cur.len() > 1 {
        let mut next = Vec::with_capacity(cur.len().div_ceil(2));
        for pair in cur.chunks(2) {
            next.push(if pair.len() == 2 {
                reduce_acc_combine(spec, &pair[0], &pair[1])
            } else {
                pair[0]
            });
        }
        cur = next;
    }
    cur[0]
}

/// One block's striped partial state: [`REDUCE_LANES`] independent
/// [`ReduceAcc`]s, stripe `j` folding the block's elements at offsets
/// `j, j + REDUCE_LANES, j + 2·REDUCE_LANES, …` in offset order. Finishing a
/// block ([`reduce_block_finish`]) combines the stripes through the fixed
/// pairwise tree — the block partial every arm must reproduce bit-for-bit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReduceBlockAcc {
    pub sub: [ReduceAcc; REDUCE_LANES],
}

/// The striped state every block fold starts from.
pub fn reduce_block_identity(spec: ReduceSpec) -> ReduceBlockAcc {
    ReduceBlockAcc { sub: [reduce_acc_identity(spec); REDUCE_LANES] }
}

/// Fold element `x` at offset `offset` within the block starting at global
/// element index `base`: stripe `offset % REDUCE_LANES`, channel lane
/// `(base + offset) % 3` (via [`reduce_acc_fold`]). This is the scalar form
/// of the striped fold — the SIMD arm folds whole stripe rows at once
/// ([`ReduceStripes`]) and lands on the same bits by construction.
#[inline(always)]
pub fn reduce_block_fold(
    spec: ReduceSpec,
    blk: &mut ReduceBlockAcc,
    base: usize,
    offset: usize,
    x: f64,
) {
    reduce_acc_fold(spec, &mut blk.sub[offset % REDUCE_LANES], base + offset, x);
}

/// Combine a block's stripes into its partial — the same fixed pairwise tree
/// used across blocks, so the whole reduction is ONE tree shape.
pub fn reduce_block_finish(spec: ReduceSpec, blk: &ReduceBlockAcc) -> ReduceAcc {
    reduce_combine_tree(spec, &blk.sub)
}

/// Register-resident stripe rows for the FULL-axis vectorized fold:
/// `rows[stat][j]` is stripe `j` of statistic `stat` (channel lane 0 — the
/// only live lane on [`ReduceAxis::Full`]). The engine's dense reduce arm
/// keeps this in registers across a whole block, folding aligned
/// [`REDUCE_LANES`]-wide chunks via [`ReduceKind::fold_lanes`]; per-channel
/// reductions stay on the scalar striped fold (the 3-lane rule crosses
/// stripe boundaries).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReduceStripes {
    pub rows: [[f64; REDUCE_LANES]; 2],
}

/// The stripe rows every full-axis block fold starts from.
pub fn reduce_stripes_identity(spec: ReduceSpec) -> ReduceStripes {
    debug_assert!(matches!(spec.axis, ReduceAxis::Full), "stripes are the Full-axis fast path");
    let mut rows = [[0.0f64; REDUCE_LANES]; 2];
    for (k, row) in rows.iter_mut().enumerate().take(spec.stat_count()) {
        *row = [spec.stat(k).identity(); REDUCE_LANES];
    }
    ReduceStripes { rows }
}

/// Fold one aligned [`REDUCE_LANES`]-wide chunk (block offsets
/// `c·REDUCE_LANES .. (c+1)·REDUCE_LANES`) into the stripe rows: stripe `j`
/// folds `xs[j]`, exactly what [`reduce_block_fold`] does element-by-element
/// for the same offsets.
#[inline(always)]
pub fn reduce_stripes_fold(spec: ReduceSpec, st: &mut ReduceStripes, xs: &[f64; REDUCE_LANES]) {
    for k in 0..spec.stat_count() {
        spec.stat(k).fold_lanes(&mut st.rows[k], xs);
    }
}

/// Scatter the stripe rows back into the canonical striped block state
/// (stripe `j`, channel lane 0) so the block can finish — or keep absorbing
/// a ragged tail — through the shared scalar machinery.
pub fn reduce_stripes_into_block(spec: ReduceSpec, st: &ReduceStripes) -> ReduceBlockAcc {
    let mut blk = reduce_block_identity(spec);
    for (j, sub) in blk.sub.iter_mut().enumerate() {
        for k in 0..spec.stat_count() {
            sub.s[0][k] = st.rows[k][j];
        }
    }
    blk
}

/// Exact per-lane element counts of an `n`-element reduction (lane = global
/// index % 3 for per-channel; everything in lane 0 for full).
pub fn reduce_lane_counts(spec: ReduceSpec, n: usize) -> [usize; 3] {
    match spec.axis {
        ReduceAxis::Full => [n, 0, 0],
        ReduceAxis::PerChannel => {
            let mut c = [n / 3; 3];
            for slot in c.iter_mut().take(n % 3) {
                *slot += 1;
            }
            c
        }
    }
}

/// Finalize a combined accumulator into the output layout: stat-major,
/// lane-minor (`[stat0 lane0.., stat1 lane0..]` — the layout of
/// [`ReduceSpec::out_shape`]).
pub fn reduce_finalize(spec: ReduceSpec, acc: &ReduceAcc, n: usize) -> Vec<f64> {
    let counts = reduce_lane_counts(spec, n);
    let mut out = Vec::with_capacity(spec.out_len());
    for k in 0..spec.stat_count() {
        for lane in 0..spec.lanes() {
            out.push(spec.stat(k).finalize(acc.s[lane][k], counts[lane]));
        }
    }
    out
}

/// The whole striped blocked-tree reduction over a materialized f64 buffer —
/// the ORACLE's reduce path, and the bit-for-bit definition the fused
/// engine's fold-while-reading tier reproduces without ever materializing
/// `vals` (whether it folds element-at-a-time or [`REDUCE_LANES`] stripes at
/// once).
pub fn reduce_slice(spec: ReduceSpec, vals: &[f64]) -> Vec<f64> {
    let partials: Vec<ReduceAcc> = vals
        .chunks(REDUCE_BLOCK)
        .enumerate()
        .map(|(bi, chunk)| {
            let mut blk = reduce_block_identity(spec);
            let base = bi * REDUCE_BLOCK;
            for (j, &x) in chunk.iter().enumerate() {
                reduce_block_fold(spec, &mut blk, base, j, x);
            }
            reduce_block_finish(spec, &blk)
        })
        .collect();
    reduce_finalize(spec, &reduce_combine_tree(spec, &partials), vals.len())
}

/// σ from normalize pass 1's `(mean, sum-of-squares)` statistics:
/// `sqrt(max(E[x²] − μ², 0))`, floored at `eps` so pass 2's divide is
/// always well-defined. `n == 0` yields 1.0 (normalizing nothing is the
/// identity). Defined ONCE here so every normalize front door (`chain`
/// preset, `cv::normalize`, `npp::run_normalized`) derives σ identically.
pub fn normalize_sigma(mean: f64, sumsq: f64, n: usize, eps: f64) -> f64 {
    if n == 0 {
        return 1.0;
    }
    let var = (sumsq / n as f64 - mean * mean).max(0.0);
    var.sqrt().max(eps)
}

/// Split a `(Mean, SumSq)` pair-reduction output into per-lane `(μ, σ)` —
/// the handover from pass 1 to pass 2's bound scalars. `vals` is the
/// stat-major finalize layout; `n` the reduced element count.
pub fn mean_sigma_from_stats(
    spec: ReduceSpec,
    vals: &[f64],
    n: usize,
    eps: f64,
) -> (Vec<f64>, Vec<f64>) {
    debug_assert_eq!(spec.stat_count(), 2, "mean/σ needs the (Mean, SumSq) pair");
    debug_assert_eq!(vals.len(), spec.out_len());
    let lanes = spec.lanes();
    let counts = reduce_lane_counts(spec, n);
    let mut mu = Vec::with_capacity(lanes);
    let mut sigma = Vec::with_capacity(lanes);
    for lane in 0..lanes {
        let mean = vals[lane];
        let sumsq = vals[lanes + lane];
        mu.push(mean);
        sigma.push(normalize_sigma(mean, sumsq, counts[lane], eps));
    }
    (mu, sigma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{MemOp, Pipeline, ReduceKind};
    use crate::tensor::DType;

    #[test]
    fn lowering_covers_every_body_op() {
        let p = Pipeline::elementwise(
            vec![
                IOp::compute(Opcode::Mul, 2.0),
                IOp::ComputeC3 { op: Opcode::Add, param: [1.0, 2.0, 3.0] },
                IOp::CvtColor,
            ],
            vec![2, 3],
            1,
            DType::F32,
            DType::F32,
        )
        .unwrap();
        let body = ScalarOp::lower_body(p.body()).unwrap();
        assert_eq!(body.len(), 3);
        assert_eq!(group_width(&body), 3);
        assert!(ScalarOp::lower(&IOp::Mem(MemOp::Write { dtype: DType::F32 })).is_none());
    }

    #[test]
    fn scalar_chains_have_group_width_one() {
        let body = vec![
            ScalarOp::Scalar { op: Opcode::Mul, param: 2.0 },
            ScalarOp::Scalar { op: Opcode::Add, param: 1.0 },
        ];
        assert_eq!(group_width(&body), 1);
    }

    #[test]
    fn whole_buffer_equals_per_group_application() {
        // the invariant the fused engine relies on: applying an op to the
        // whole buffer at once == applying it group by group with offsets
        let ops = [
            ScalarOp::Scalar { op: Opcode::Mul, param: 1.5 },
            ScalarOp::PerLane { op: Opcode::Sub, param: [1.0, 2.0, 3.0] },
            ScalarOp::Swizzle,
        ];
        // 8 elements: not a multiple of 3, exercises the ragged tail
        let src: Vec<f64> = (0..8).map(|i| i as f64).collect();
        for op in &ops {
            let mut whole = src.clone();
            op.apply_slice_f64(&mut whole, 0);
            let mut grouped = src.clone();
            for (gi, chunk) in grouped.chunks_mut(3).enumerate() {
                op.apply_slice_f64(chunk, gi * 3);
            }
            assert_eq!(whole, grouped, "{op:?}");
        }
    }

    #[test]
    fn identity_resize_taps_are_exact() {
        // dst size == src size: every tap must hit its own pixel with zero
        // fractional weight, so an identity resize reproduces the crop
        for (h, w) in [(1usize, 1usize), (3, 5), (8, 8)] {
            for dy in 0..h {
                for dx in 0..w {
                    let t = bilinear_tap(dy, dx, h as i32, w as i32, h, w);
                    assert_eq!((t.y0, t.x0), (dy as i32, dx as i32));
                    assert_eq!((t.wy, t.wx), (0.0, 0.0), "({dy},{dx}) in {h}x{w}");
                }
            }
        }
    }

    #[test]
    fn bilinear_tap_is_separable() {
        // the per-axis precompute hot loops rely on: combining two axis
        // taps IS the pixel tap, bit-for-bit
        for (dy, dx) in [(0usize, 0usize), (3, 1), (7, 6)] {
            let whole = bilinear_tap(dy, dx, 9, 11, 8, 7);
            let y = axis_tap(dy, 9, 8);
            let x = axis_tap(dx, 11, 7);
            assert_eq!((whole.y0, whole.y1, whole.wy), (y.i0, y.i1, y.w));
            assert_eq!((whole.x0, whole.x1, whole.wx), (x.i0, x.i1, x.w));
        }
    }

    #[test]
    fn split_scatters_packed_pixels_to_planes() {
        let packed = [1, 10, 100, 2, 20, 200, 3, 30, 300];
        let mut planar = [0; 9];
        split_packed_to_planar(&packed, &mut planar);
        assert_eq!(planar, [1, 2, 3, 10, 20, 30, 100, 200, 300]);
    }

    #[test]
    fn frame_index_clamps_at_edges() {
        let r = Rect::new(-2, 6, 4, 4);
        // negative origin clamps to column 0; beyond-bottom clamps to fh-1
        assert_eq!(clamped_frame_index(r, 0, 0, 8, 8), 6 * 8);
        assert_eq!(clamped_frame_index(r, 10, 1, 8, 8), 7 * 8);
        // interior is untouched
        assert_eq!(clamped_frame_index(r, 1, 3, 8, 8), 7 * 8 + 1);
    }

    #[test]
    fn swizzle_skips_ragged_tail() {
        let mut v = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        ScalarOp::Swizzle.apply_slice_f64(&mut v, 0);
        assert_eq!(v, vec![3.0, 2.0, 1.0, 4.0, 5.0]);
    }

    // --- reductions --------------------------------------------------------

    #[test]
    fn reduce_block_is_pixel_aligned() {
        // per-channel lanes and 3-wide pixel groups must never straddle a
        // block boundary; full blocks must have no stripe tail
        assert_eq!(REDUCE_BLOCK % 3, 0);
        assert_eq!(REDUCE_BLOCK % REDUCE_LANES, 0);
    }

    #[test]
    fn reduce_slice_is_the_striped_block_definition() {
        use crate::ops::{ReduceAxis, ReduceSpec};
        // order-sensitive data (1e16 absorbs 1.0 in any fold it joins): pin
        // that reduce_slice stripes each block — element j into stripe
        // j % REDUCE_LANES, stripes combined pairwise — by emulating that
        // shape independently and demanding bit equality, while the naive
        // sequential fold genuinely lands on different bits
        let mut vals = vec![1.0f64; 19];
        vals[0] = 1e16;
        let spec = ReduceSpec::single(ReduceKind::Sum, ReduceAxis::Full);

        let mut stripes = [0.0f64; REDUCE_LANES];
        for (j, &x) in vals.iter().enumerate() {
            stripes[j % REDUCE_LANES] += x;
        }
        let pair = |a: f64, b: f64| a + b;
        let want = pair(
            pair(pair(stripes[0], stripes[1]), pair(stripes[2], stripes[3])),
            pair(pair(stripes[4], stripes[5]), pair(stripes[6], stripes[7])),
        );
        let got = reduce_slice(spec, &vals)[0];
        assert_eq!(got.to_bits(), want.to_bits());
        let naive: f64 = vals.iter().sum();
        assert_ne!(got.to_bits(), naive.to_bits(), "striping must be observable here");
    }

    #[test]
    fn stripe_rows_match_the_scalar_striped_fold_bit_for_bit() {
        use crate::ops::{ReduceAxis, ReduceSpec};
        // the SIMD staging path (fold aligned 8-wide chunks into register
        // rows, scatter back, absorb the ragged tail scalar) must land on
        // the same bits as folding every element through reduce_block_fold
        let spec = ReduceSpec::pair(ReduceKind::Mean, ReduceKind::SumSq, ReduceAxis::Full);
        let n = REDUCE_LANES * 5 + 3;
        let vals: Vec<f64> = (0..n).map(|i| ((i * 37 + 11) % 101) as f64 * 0.37 - 12.5).collect();

        let mut scalar = reduce_block_identity(spec);
        for (j, &x) in vals.iter().enumerate() {
            reduce_block_fold(spec, &mut scalar, 0, j, x);
        }

        let mut st = reduce_stripes_identity(spec);
        let mut chunks = vals.chunks_exact(REDUCE_LANES);
        for chunk in &mut chunks {
            let mut xs = [0.0f64; REDUCE_LANES];
            xs.copy_from_slice(chunk);
            reduce_stripes_fold(spec, &mut st, &xs);
        }
        let mut blk = reduce_stripes_into_block(spec, &st);
        let done = n - chunks.remainder().len();
        for (j, &x) in chunks.remainder().iter().enumerate() {
            reduce_block_fold(spec, &mut blk, 0, done + j, x);
        }

        assert_eq!(blk, scalar, "stripe rows and scalar striped fold must agree bitwise");
        assert_eq!(reduce_block_finish(spec, &blk), reduce_block_finish(spec, &scalar));
    }

    #[test]
    fn reduce_slice_matches_naive_sweeps_on_small_inputs() {
        use crate::ops::{ReduceAxis, ReduceSpec};
        // inputs shorter than one block: the blocked-tree shape degenerates
        // to the naive fold, so plain sweeps are the expected values
        let vals: Vec<f64> = (0..10).map(|i| i as f64 - 4.0).collect();
        let full = |k| ReduceSpec::single(k, ReduceAxis::Full);
        assert_eq!(reduce_slice(full(ReduceKind::Sum), &vals), vec![vals.iter().sum::<f64>()]);
        assert_eq!(reduce_slice(full(ReduceKind::Min), &vals), vec![-4.0]);
        assert_eq!(reduce_slice(full(ReduceKind::Max), &vals), vec![5.0]);
        assert_eq!(
            reduce_slice(full(ReduceKind::Mean), &vals),
            vec![vals.iter().sum::<f64>() / 10.0]
        );
        assert_eq!(
            reduce_slice(full(ReduceKind::SumSq), &vals),
            vec![vals.iter().map(|v| v * v).sum::<f64>()]
        );

        // per-channel: lane = index % 3, ragged tail included (10 = 3*3+1)
        let spec = ReduceSpec::single(ReduceKind::Sum, ReduceAxis::PerChannel);
        let mut want = [0.0f64; 3];
        for (i, &v) in vals.iter().enumerate() {
            want[i % 3] += v;
        }
        assert_eq!(reduce_slice(spec, &vals), want.to_vec());
        assert_eq!(reduce_lane_counts(spec, 10), [4, 3, 3]);
    }

    #[test]
    fn combine_tree_is_the_fixed_pairwise_shape() {
        use crate::ops::{ReduceAxis, ReduceSpec};
        // order-sensitive partials (1e16 absorbs 1.0): pin the EXACT
        // combine order the tree promises — adjacent pairs per round,
        // ((p0+p1)+(p2+p3))+p4, nothing else — so any rewrite that folds
        // left-to-right or reorders by worker changes these bits
        let spec = ReduceSpec::single(ReduceKind::Sum, ReduceAxis::Full);
        let partials: Vec<ReduceAcc> = (0..4)
            .map(|i| {
                let mut acc = reduce_acc_identity(spec);
                reduce_acc_fold(spec, &mut acc, 0, if i == 0 { 1e16 } else { 1.0 });
                acc
            })
            .collect();
        let got = reduce_combine_tree(spec, &partials).s[0][0];
        let want = (1e16 + 1.0) + (1.0 + 1.0);
        assert_eq!(got.to_bits(), want.to_bits());
        // ... and the naive left fold genuinely disagrees here: 1.0 is below
        // 1e16's ulp, so folding one-at-a-time absorbs every small partial
        // ((1e16+1)+1)+1 = 1e16, while the pair (1+1) = 2 survives the tree
        let left = ((1e16 + 1.0) + 1.0) + 1.0;
        assert_ne!(got.to_bits(), left.to_bits());
    }

    #[test]
    fn empty_reductions_finalize_to_identities() {
        use crate::ops::{ReduceAxis, ReduceSpec};
        let full = |k| ReduceSpec::single(k, ReduceAxis::Full);
        assert_eq!(reduce_slice(full(ReduceKind::Sum), &[]), vec![0.0]);
        assert_eq!(reduce_slice(full(ReduceKind::Min), &[]), vec![f64::INFINITY]);
        assert_eq!(reduce_slice(full(ReduceKind::Max), &[]), vec![f64::NEG_INFINITY]);
        assert!(reduce_slice(full(ReduceKind::Mean), &[])[0].is_nan());
    }

    #[test]
    fn pair_reductions_share_the_pass_and_the_layout() {
        use crate::ops::{ReduceAxis, ReduceSpec};
        let vals = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let spec = ReduceSpec::pair(ReduceKind::Mean, ReduceKind::SumSq, ReduceAxis::PerChannel);
        let out = reduce_slice(spec, &vals);
        // stat-major: [mean_r, mean_g, mean_b, sumsq_r, sumsq_g, sumsq_b]
        assert_eq!(out, vec![2.5, 3.5, 4.5, 17.0, 29.0, 45.0]);
        let (mu, sigma) = mean_sigma_from_stats(spec, &out, vals.len(), 0.0);
        assert_eq!(mu, vec![2.5, 3.5, 4.5]);
        for (lane, s) in sigma.iter().enumerate() {
            assert!((s - 1.5).abs() < 1e-12, "lane {lane}: {s}");
        }
    }

    #[test]
    fn normalize_sigma_floors_and_handles_empty() {
        assert_eq!(normalize_sigma(2.0, 16.0, 4, 1e-12), 0.0f64.max(1e-12));
        assert_eq!(normalize_sigma(0.0, 0.0, 0, 1e-12), 1.0);
        // var would be slightly negative from rounding: clamped to eps
        assert_eq!(normalize_sigma(1.0, 0.999999, 1, 1e-6), 1e-6);
    }
}
