//! Canonical pipeline signatures — the artifact/plan cache key.
//!
//! Two pipelines with the same op sequence, dtypes, shape and batch execute
//! on the same compiled artifact regardless of parameter values (the paper's
//! distinction between the IOp *type*, which drives codegen, and the IOp
//! *contents*, which are runtime kernel arguments).

use super::Pipeline;

/// Canonical, hashable identity of a pipeline's generated code.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Signature {
    pub ops: String,
    pub dtin: String,
    pub dtout: String,
    pub shape: Vec<usize>,
    pub batch: usize,
}

impl Signature {
    pub fn of(p: &Pipeline) -> Signature {
        Signature {
            ops: p.body().iter().map(|o| o.sig_token()).collect::<Vec<_>>().join("-"),
            dtin: p.dtin.name().to_string(),
            dtout: p.dtout.name().to_string(),
            shape: p.shape.clone(),
            batch: p.batch,
        }
    }

    /// Same code, different batch width (HF bucket lookup).
    pub fn with_batch(&self, batch: usize) -> Signature {
        Signature { batch, ..self.clone() }
    }

    /// Batch-agnostic key (used to group requests in the dynamic batcher).
    pub fn stream_key(&self) -> String {
        format!(
            "{}|{}->{}|{}",
            self.ops,
            self.dtin,
            self.dtout,
            self.shape.iter().map(|s| s.to_string()).collect::<Vec<_>>().join("x"),
        )
    }
}

impl std::fmt::Display for Signature {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}@b{}", self.stream_key(), self.batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{IOp, Opcode, Pipeline};
    use crate::tensor::DType;

    fn pipe(params: &[f64], batch: usize) -> Pipeline {
        let body = params.iter().map(|&p| IOp::compute(Opcode::Mul, p)).collect();
        Pipeline::elementwise(body, vec![8, 8], batch, DType::U8, DType::F32).unwrap()
    }

    #[test]
    fn params_do_not_change_signature() {
        assert_eq!(Signature::of(&pipe(&[1.0, 2.0], 1)), Signature::of(&pipe(&[9.0, 8.0], 1)));
    }

    #[test]
    fn batch_changes_signature_but_not_stream_key() {
        let a = Signature::of(&pipe(&[1.0], 1));
        let b = Signature::of(&pipe(&[1.0], 4));
        assert_ne!(a, b);
        assert_eq!(a.stream_key(), b.stream_key());
        assert_eq!(a.with_batch(4), b);
    }

    #[test]
    fn op_order_matters() {
        let p1 = Pipeline::from_opcodes(
            &[(Opcode::Mul, 1.0), (Opcode::Add, 1.0)],
            &[4],
            1,
            DType::F32,
            DType::F32,
        )
        .unwrap();
        let p2 = Pipeline::from_opcodes(
            &[(Opcode::Add, 1.0), (Opcode::Mul, 1.0)],
            &[4],
            1,
            DType::F32,
            DType::F32,
        )
        .unwrap();
        assert_ne!(Signature::of(&p1), Signature::of(&p2));
    }
}
