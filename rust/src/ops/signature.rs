//! Canonical pipeline signatures — the artifact/plan cache key.
//!
//! Two pipelines with the same op sequence, dtypes, shape and batch execute
//! on the same compiled artifact regardless of parameter values (the paper's
//! distinction between the IOp *type*, which drives codegen, and the IOp
//! *contents*, which are runtime kernel arguments).

use super::{IOp, MemOp, Pipeline};

/// Canonical, hashable identity of a pipeline's generated code.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Signature {
    pub ops: String,
    pub dtin: String,
    pub dtout: String,
    pub shape: Vec<usize>,
    pub batch: usize,
}

impl Signature {
    pub fn of(p: &Pipeline) -> Signature {
        // Dense Read/Write boundaries are fully described by (dtin, dtout),
        // which are already part of the signature, so they contribute no
        // token (keeps cache keys byte-identical for every pre-structured
        // pipeline). STRUCTURED boundaries (crop/resize reads, split
        // writes) change the generated code and must distinguish the key —
        // otherwise a resize-read chain would share a plan-cache entry and
        // an HF batch group with a dense chain of the same body.
        let mut toks: Vec<String> = Vec::with_capacity(p.ops().len());
        if let Some(op) = p.ops().first() {
            if !matches!(op, IOp::Mem(MemOp::Read { .. })) {
                toks.push(op.sig_token());
            }
        }
        toks.extend(p.body().iter().map(|o| o.sig_token()));
        if let Some(op) = p.ops().last() {
            if !matches!(op, IOp::Mem(MemOp::Write { .. })) {
                toks.push(op.sig_token());
            }
        }
        Signature {
            ops: toks.join("-"),
            dtin: p.dtin.name().to_string(),
            dtout: p.dtout.name().to_string(),
            shape: p.shape.clone(),
            batch: p.batch,
        }
    }

    /// Same code, different batch width (HF bucket lookup).
    pub fn with_batch(&self, batch: usize) -> Signature {
        Signature { batch, ..self.clone() }
    }

    /// Stable 64-bit hash of [`Signature::stream_key`] — the sharded
    /// coordinator's routing function (FNV-1a, fixed constants: the shard
    /// of a stream must not depend on compiler, platform, or process, so
    /// `DefaultHasher` is out). Batch- and parameter-agnostic, like the
    /// stream key itself: every request of a stream hashes identically,
    /// which keeps a stream's requests on one shard and its HF batch
    /// groups intact.
    pub fn stream_hash(&self) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = OFFSET;
        for b in self.stream_key().bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
        h
    }

    /// Batch-agnostic key (used to group requests in the dynamic batcher).
    pub fn stream_key(&self) -> String {
        format!(
            "{}|{}->{}|{}",
            self.ops,
            self.dtin,
            self.dtout,
            self.shape.iter().map(|s| s.to_string()).collect::<Vec<_>>().join("x"),
        )
    }
}

impl std::fmt::Display for Signature {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}@b{}", self.stream_key(), self.batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{IOp, Opcode, Pipeline};
    use crate::tensor::DType;

    fn pipe(params: &[f64], batch: usize) -> Pipeline {
        let body = params.iter().map(|&p| IOp::compute(Opcode::Mul, p)).collect();
        Pipeline::elementwise(body, vec![8, 8], batch, DType::U8, DType::F32).unwrap()
    }

    #[test]
    fn params_do_not_change_signature() {
        assert_eq!(Signature::of(&pipe(&[1.0, 2.0], 1)), Signature::of(&pipe(&[9.0, 8.0], 1)));
    }

    #[test]
    fn batch_changes_signature_but_not_stream_key() {
        let a = Signature::of(&pipe(&[1.0], 1));
        let b = Signature::of(&pipe(&[1.0], 4));
        assert_ne!(a, b);
        assert_eq!(a.stream_key(), b.stream_key());
        assert_eq!(a.with_batch(4), b);
    }

    #[test]
    fn structured_boundaries_change_the_signature() {
        use crate::ops::MemOp;
        use crate::tensor::Rect;
        // same body, same shape/dtypes: a resize-read/split-write chain must
        // NOT share a cache key (or HF stream) with the dense chain
        let dense = Pipeline::from_opcodes(
            &[(Opcode::Mul, 1.0)],
            &[8, 4, 3],
            1,
            DType::U8,
            DType::F32,
        )
        .unwrap();
        let structured = Pipeline::new(
            vec![
                IOp::Mem(MemOp::ResizeRead { rect: Rect::new(0, 0, 16, 8), dst_h: 8, dst_w: 4 }),
                IOp::compute(Opcode::Mul, 1.0),
                IOp::Mem(MemOp::SplitWrite { dtype: DType::F32 }),
            ],
            vec![8, 4, 3],
            1,
            DType::U8,
            DType::F32,
        )
        .unwrap();
        let sd = Signature::of(&dense);
        let ss = Signature::of(&structured);
        assert_eq!(sd.ops, "mul");
        assert_eq!(ss.ops, "resize[8x4]-mul-split[f32]");
        assert_ne!(sd, ss);
        assert_ne!(sd.stream_key(), ss.stream_key());
    }

    #[test]
    fn reduce_terminators_change_the_signature() {
        use crate::ops::{MemOp, ReduceAxis, ReduceKind, ReduceSpec};
        // a reduce-terminated chain never shares a plan-cache entry or HF
        // stream with the dense map chain of the same body — and the two
        // axes/kinds are distinct code shapes too
        let mk = |spec| {
            Pipeline::new(
                vec![
                    IOp::Mem(MemOp::Read { dtype: DType::U8 }),
                    IOp::compute(Opcode::Mul, 1.0),
                    IOp::Mem(MemOp::Reduce { spec }),
                ],
                vec![8, 8],
                1,
                DType::U8,
                DType::F64,
            )
            .unwrap()
        };
        let mean = Signature::of(&mk(ReduceSpec::single(ReduceKind::Mean, ReduceAxis::Full)));
        assert_eq!(mean.ops, "mul-reduce[mean]");
        let per_ch =
            Signature::of(&mk(ReduceSpec::single(ReduceKind::Mean, ReduceAxis::PerChannel)));
        assert_eq!(per_ch.ops, "mul-reduce[mean@ch]");
        assert_ne!(mean, per_ch);
        let pair = Signature::of(&mk(ReduceSpec::pair(
            ReduceKind::Mean,
            ReduceKind::SumSq,
            ReduceAxis::PerChannel,
        )));
        assert_eq!(pair.ops, "mul-reduce[mean+sumsq@ch]");
    }

    #[test]
    fn stream_hash_is_batch_and_param_agnostic() {
        let a = Signature::of(&pipe(&[1.0, 2.0], 1));
        let b = Signature::of(&pipe(&[9.0, 8.0], 4));
        assert_eq!(a.stream_hash(), b.stream_hash(), "one stream, one shard");
        // different code shapes should (with overwhelming probability)
        // route differently — and must at minimum hash the key, not the
        // struct, so this pins the key-derived value
        let c = Signature::of(&pipe(&[1.0], 1));
        assert_ne!(a.stream_hash(), c.stream_hash());
        // FNV-1a with fixed constants: stable across processes/platforms
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for byte in a.stream_key().bytes() {
            h = (h ^ byte as u64).wrapping_mul(0x0000_0100_0000_01b3);
        }
        assert_eq!(a.stream_hash(), h);
    }

    #[test]
    fn op_order_matters() {
        let p1 = Pipeline::from_opcodes(
            &[(Opcode::Mul, 1.0), (Opcode::Add, 1.0)],
            &[4],
            1,
            DType::F32,
            DType::F32,
        )
        .unwrap();
        let p2 = Pipeline::from_opcodes(
            &[(Opcode::Add, 1.0), (Opcode::Mul, 1.0)],
            &[4],
            1,
            DType::F32,
            DType::F32,
        )
        .unwrap();
        assert_ne!(Signature::of(&p1), Signature::of(&p2));
    }
}
