//! The paper's component model in Rust: Operations, Instantiable Operations
//! and Pipelines.
//!
//! Paper §IV defines four Operation classes (Table I): ReadType, UnaryType,
//! BinaryType, WriteType. Library functions return *Instantiable Operations*
//! (IOps) — values carrying the op identity plus its runtime parameters —
//! and the user hands an ordered sequence of IOps to an executor. Our
//! [`Pipeline`] is that sequence, with the paper's compile-time static
//! asserts reproduced as construction-time validation (read first, write
//! last, dtype chain agreement).

mod iop;
pub mod kernel;
mod opcode;
mod pipeline;
mod reduce;
mod signature;

pub use iop::{IOp, MemOp, OpClass, ReadPattern, WritePattern};
pub use kernel::ScalarOp;
pub use opcode::{Opcode, ALL_OPCODES};
pub use pipeline::{CastStep, Pipeline, PipelineError};
pub use reduce::{ReduceAxis, ReduceKind, ReduceSpec, ALL_REDUCE_KINDS};
pub use signature::Signature;
