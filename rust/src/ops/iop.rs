//! Instantiable Operations — the runtime values library functions return.

use crate::tensor::{DType, Rect};

use super::{Opcode, ReduceSpec};

/// The paper's four Operation classes (Table I).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpClass {
    Read,
    Unary,
    Binary,
    Write,
}

/// Memory Operations (MOps, §IV-B): the read/write ends of a pipeline,
/// including the structured read patterns of Fig. 11.
#[derive(Debug, Clone, PartialEq)]
pub enum MemOp {
    /// Per-thread read of a dense tensor (PerThreadRead).
    Read { dtype: DType },
    /// Crop ROI read from a shared frame (the BatchRead pattern: each batch
    /// plane has its own rect).
    CropRead { rect: Rect },
    /// Bilinear-resample read (Crop+Resize fused at the read, Fig. 11).
    ResizeRead { rect: Rect, dst_h: usize, dst_w: usize },
    /// Per-thread write of a dense tensor.
    Write { dtype: DType },
    /// Packed -> planar write (the Split WOp of Fig. 11).
    SplitWrite { dtype: DType },
    /// Reduction terminator (the divergent-pattern ReduceDPP of §IV-C):
    /// statistics fold WHILE reading and only the tiny f64 result is
    /// written — the pipeline's write end, with no per-element write.
    Reduce { spec: ReduceSpec },
}

/// The access pattern a pipeline's READ end performs. This is the boundary
/// metadata planners and engines interrogate — never string-match
/// [`IOp::sig_token`] to discover a boundary shape. Structured patterns own
/// their memory access: a `CropResize` read performs the bilinear gather
/// *while reading* (paper Fig. 11), so intermediates never touch DRAM.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ReadPattern {
    /// Per-thread dense read of a `[batch, *shape]` tensor.
    Dense,
    /// ROI read from a shared frame; the rect is a RUNTIME parameter
    /// (outside the signature), bound per run like chain params.
    Crop { rect: Rect },
    /// Crop + bilinear resample fused at the read end. `dst_h`/`dst_w`
    /// shape the generated code (they are signature tokens); the rect is a
    /// runtime parameter.
    CropResize { rect: Rect, dst_h: usize, dst_w: usize },
}

/// The access pattern a pipeline's WRITE end performs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WritePattern {
    /// Per-thread dense write of a `[batch, *shape]` tensor.
    Dense,
    /// Packed `[h, w, 3]` pixels scattered to planar `[3, h, w]` *while
    /// writing* (the Split WOp of Fig. 11).
    Split,
    /// No per-element write at all: statistics accumulate while reading and
    /// only the finalized f64 result lands ([`ReduceSpec::out_shape`]).
    Reduce { spec: ReduceSpec },
}

impl MemOp {
    pub fn class(&self) -> OpClass {
        match self {
            MemOp::Read { .. } | MemOp::CropRead { .. } | MemOp::ResizeRead { .. } => OpClass::Read,
            MemOp::Write { .. } | MemOp::SplitWrite { .. } | MemOp::Reduce { .. } => OpClass::Write,
        }
    }

    /// True for boundary ops that own a non-dense access pattern (crop /
    /// resize reads, split writes). Structured boundaries change the
    /// generated code: their tokens participate in [`super::Signature`] and
    /// dense artifact tiers refuse them.
    pub fn is_structured(&self) -> bool {
        !matches!(self, MemOp::Read { .. } | MemOp::Write { .. })
    }

    /// The read pattern of this op (`None` for writes).
    pub fn read_pattern(&self) -> Option<ReadPattern> {
        match *self {
            MemOp::Read { .. } => Some(ReadPattern::Dense),
            MemOp::CropRead { rect } => Some(ReadPattern::Crop { rect }),
            MemOp::ResizeRead { rect, dst_h, dst_w } => {
                Some(ReadPattern::CropResize { rect, dst_h, dst_w })
            }
            MemOp::Write { .. } | MemOp::SplitWrite { .. } | MemOp::Reduce { .. } => None,
        }
    }

    /// The write pattern of this op (`None` for reads).
    pub fn write_pattern(&self) -> Option<WritePattern> {
        match self {
            MemOp::Write { .. } => Some(WritePattern::Dense),
            MemOp::SplitWrite { .. } => Some(WritePattern::Split),
            MemOp::Reduce { spec } => Some(WritePattern::Reduce { spec: *spec }),
            _ => None,
        }
    }

    /// The reduction terminator of this op (`None` for everything else) —
    /// the metadata planners interrogate to route reduce-terminated
    /// pipelines (never sig-token strings).
    pub fn reduction(&self) -> Option<ReduceSpec> {
        match self {
            MemOp::Reduce { spec } => Some(*spec),
            _ => None,
        }
    }
}

/// An Instantiable Operation: op identity + runtime parameters. This is what
/// `cv::*` / `npp::*` wrapper functions return instead of launching kernels
/// (paper §IV-D: lazy execution).
#[derive(Debug, Clone, PartialEq)]
pub enum IOp {
    /// Element-wise compute op with a scalar parameter (ignored by unary ops).
    Compute { op: Opcode, param: f64 },
    /// Element-wise compute op with a per-channel float3 parameter.
    ComputeC3 { op: Opcode, param: [f32; 3] },
    /// Channel swizzle (ColorConvert UOp).
    CvtColor,
    /// Memory operation end-point.
    Mem(MemOp),
}

impl IOp {
    pub fn compute(op: Opcode, param: f64) -> IOp {
        IOp::Compute { op, param }
    }

    pub fn class(&self) -> OpClass {
        match self {
            IOp::Compute { op, .. } => {
                if op.takes_param() {
                    OpClass::Binary
                } else {
                    OpClass::Unary
                }
            }
            IOp::ComputeC3 { .. } => OpClass::Binary,
            IOp::CvtColor => OpClass::Unary,
            IOp::Mem(m) => m.class(),
        }
    }

    /// Canonical token used in pipeline signatures and artifact matching.
    pub fn sig_token(&self) -> String {
        match self {
            IOp::Compute { op, .. } => op.name().to_string(),
            IOp::ComputeC3 { op, .. } => format!("{}c3", op.name()),
            IOp::CvtColor => "cvtcolor".to_string(),
            IOp::Mem(MemOp::Read { dtype }) => format!("read[{dtype}]"),
            IOp::Mem(MemOp::CropRead { .. }) => "crop".to_string(),
            IOp::Mem(MemOp::ResizeRead { dst_h, dst_w, .. }) => {
                format!("resize[{dst_h}x{dst_w}]")
            }
            IOp::Mem(MemOp::Write { dtype }) => format!("write[{dtype}]"),
            IOp::Mem(MemOp::SplitWrite { dtype }) => format!("split[{dtype}]"),
            IOp::Mem(MemOp::Reduce { spec }) => spec.sig_token(),
        }
    }

    /// Per-element instruction estimate (cost model input).
    pub fn instr_cost(&self) -> f64 {
        match self {
            IOp::Compute { op, .. } | IOp::ComputeC3 { op, .. } => op.instr_cost(),
            IOp::CvtColor => 1.0,
            IOp::Mem(_) => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classes_match_table_i() {
        assert_eq!(IOp::compute(Opcode::Mul, 2.0).class(), OpClass::Binary);
        assert_eq!(IOp::compute(Opcode::Abs, 0.0).class(), OpClass::Unary);
        assert_eq!(IOp::Mem(MemOp::Read { dtype: DType::U8 }).class(), OpClass::Read);
        assert_eq!(IOp::Mem(MemOp::SplitWrite { dtype: DType::F32 }).class(), OpClass::Write);
    }

    #[test]
    fn boundary_metadata_is_interrogable() {
        // planners branch on this metadata, never on sig-token strings
        let dense_r = MemOp::Read { dtype: DType::U8 };
        assert!(!dense_r.is_structured());
        assert_eq!(dense_r.read_pattern(), Some(ReadPattern::Dense));
        assert_eq!(dense_r.write_pattern(), None);

        let rect = Rect::new(1, 2, 8, 4);
        let crop = MemOp::CropRead { rect };
        assert!(crop.is_structured());
        assert_eq!(crop.read_pattern(), Some(ReadPattern::Crop { rect }));

        let rsz = MemOp::ResizeRead { rect, dst_h: 16, dst_w: 8 };
        assert_eq!(
            rsz.read_pattern(),
            Some(ReadPattern::CropResize { rect, dst_h: 16, dst_w: 8 })
        );

        let split = MemOp::SplitWrite { dtype: DType::F32 };
        assert!(split.is_structured());
        assert_eq!(split.write_pattern(), Some(WritePattern::Split));
        assert_eq!(split.read_pattern(), None);

        // the reduce terminator is write-class boundary metadata too: dense
        // artifact tiers must see it as structured (they cannot serve it)
        use crate::ops::{ReduceAxis, ReduceKind, ReduceSpec};
        let spec = ReduceSpec::single(ReduceKind::Mean, ReduceAxis::PerChannel);
        let red = MemOp::Reduce { spec };
        assert_eq!(red.class(), OpClass::Write);
        assert!(red.is_structured());
        assert_eq!(red.write_pattern(), Some(WritePattern::Reduce { spec }));
        assert_eq!(red.read_pattern(), None);
        assert_eq!(red.reduction(), Some(spec));
        assert_eq!(IOp::Mem(red).sig_token(), "reduce[mean@ch]");
        assert_eq!(MemOp::Write { dtype: DType::F64 }.reduction(), None);
    }

    #[test]
    fn sig_tokens_are_param_independent() {
        // VF artifact reuse depends on params living OUTSIDE the signature
        let a = IOp::compute(Opcode::Mul, 2.0);
        let b = IOp::compute(Opcode::Mul, 7.5);
        assert_eq!(a.sig_token(), b.sig_token());
    }
}
