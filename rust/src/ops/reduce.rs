//! Reduction vocabulary — the divergent-pattern Operations of the paper's
//! §IV-C (ReduceDPP), as first-class pipeline TERMINATORS.
//!
//! A map-only op vocabulary cannot express `mean`, `max` or sum-of-squares,
//! so the canonical preprocessing step (per-channel mean/std normalize)
//! could not be served at all before this module. A [`ReduceSpec`] seals a
//! pipeline the way a write does: the fused engine folds every element
//! through the op chain in registers and accumulates the requested
//! statistics in the SAME single memory pass ("reduce while reading") —
//! intermediates never touch DRAM, which is exactly where kernel fusion
//! pays most (Filipovič et al., "Optimizing CUDA Code By Kernel Fusion").
//!
//! This file is the *vocabulary*: kinds, axes and the per-element fold
//! semantics. The blocked, deterministic tree-combine machinery shared by
//! the hostref oracle and the fused engine lives in [`super::kernel`]
//! (`REDUCE_BLOCK`, `reduce_slice`, `reduce_combine_tree`) — one table, so
//! engine and oracle cannot drift.

/// One reduction statistic. `Mean` divides at finalize; everything else is
/// the raw fold.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ReduceKind {
    Sum,
    Min,
    Max,
    Mean,
    SumSq,
}

/// Every reduction kind, in a stable order (sweeps and tests iterate this).
pub const ALL_REDUCE_KINDS: [ReduceKind; 5] = [
    ReduceKind::Sum,
    ReduceKind::Min,
    ReduceKind::Max,
    ReduceKind::Mean,
    ReduceKind::SumSq,
];

impl ReduceKind {
    pub fn name(self) -> &'static str {
        match self {
            ReduceKind::Sum => "sum",
            ReduceKind::Min => "min",
            ReduceKind::Max => "max",
            ReduceKind::Mean => "mean",
            ReduceKind::SumSq => "sumsq",
        }
    }

    /// The fold's starting value. An empty reduction finalizes to exactly
    /// this (so `Min` of nothing is `+inf`, `Mean` of nothing is `NaN`).
    pub fn identity(self) -> f64 {
        match self {
            ReduceKind::Sum | ReduceKind::Mean | ReduceKind::SumSq => 0.0,
            ReduceKind::Min => f64::INFINITY,
            ReduceKind::Max => f64::NEG_INFINITY,
        }
    }

    /// Fold one element into an accumulator (f64 domain). `Min`/`Max` use
    /// Rust's IEEE `minNum`/`maxNum` semantics: a NaN element is SKIPPED
    /// (the non-NaN side wins), so NaN-bearing inputs still reduce to the
    /// extremum of their finite values — deterministically, independent of
    /// chunking (pinned by `rust/tests/reduce_props.rs`).
    #[inline(always)]
    pub fn fold(self, acc: f64, x: f64) -> f64 {
        match self {
            ReduceKind::Sum | ReduceKind::Mean => acc + x,
            ReduceKind::SumSq => acc + x * x,
            ReduceKind::Min => acc.min(x),
            ReduceKind::Max => acc.max(x),
        }
    }

    /// Fold one `N`-wide block into `N` striped sub-accumulators: lane `j`
    /// of `acc` folds lane `j` of `xs`, each with per-element semantics
    /// IDENTICAL to [`ReduceKind::fold`] (bit-for-bit — pinned by
    /// `fold_lanes_is_per_lane_fold`). The kind dispatch sits outside the
    /// lane loop so the fold autovectorizes; determinism is unaffected
    /// because which stripe an element lands in is a property of its block
    /// offset, not of the arm that folds it.
    #[inline(always)]
    pub fn fold_lanes<const N: usize>(self, acc: &mut [f64; N], xs: &[f64; N]) {
        match self {
            ReduceKind::Sum | ReduceKind::Mean => {
                for j in 0..N {
                    acc[j] += xs[j];
                }
            }
            ReduceKind::SumSq => {
                for j in 0..N {
                    acc[j] += xs[j] * xs[j];
                }
            }
            ReduceKind::Min => {
                for j in 0..N {
                    acc[j] = acc[j].min(xs[j]);
                }
            }
            ReduceKind::Max => {
                for j in 0..N {
                    acc[j] = acc[j].max(xs[j]);
                }
            }
        }
    }

    /// Combine two partial accumulators (the tree-combine step).
    #[inline(always)]
    pub fn combine(self, a: f64, b: f64) -> f64 {
        match self {
            ReduceKind::Sum | ReduceKind::Mean | ReduceKind::SumSq => a + b,
            ReduceKind::Min => a.min(b),
            ReduceKind::Max => a.max(b),
        }
    }

    /// Turn the combined accumulator into the statistic (`Mean` divides by
    /// the element count; `n == 0` yields `NaN`, loudly not-a-number).
    #[inline]
    pub fn finalize(self, acc: f64, n: usize) -> f64 {
        match self {
            ReduceKind::Mean => acc / n as f64,
            _ => acc,
        }
    }
}

impl std::fmt::Display for ReduceKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Which axis the statistics fold over.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ReduceAxis {
    /// One statistic over the entire `[batch, *shape]` tensor.
    Full,
    /// One statistic per packed-RGB channel: the lane is the global element
    /// index modulo 3, the SAME lane rule every `ComputeC3`/`CvtColor` body
    /// op uses — so per-channel statistics compose with lane-structured
    /// bodies without a layout change.
    PerChannel,
}

/// The reduce terminator of a pipeline: one statistic — optionally two
/// folded in the very same pass (normalize pass 1 needs mean AND
/// sum-of-squares from one read; the paper's `ReduceDPP` kernels likewise
/// produce several statistics per pass) — over a [`ReduceAxis`].
///
/// Like every boundary op this is *metadata planners interrogate*
/// ([`crate::ops::Pipeline::reduction`]), never a sig-token string; and like
/// crop rects, nothing here is a runtime parameter — kinds and axis shape
/// the generated fold, so they all participate in the
/// [`Signature`](crate::ops::Signature).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ReduceSpec {
    /// The first (or only) statistic.
    pub kind: ReduceKind,
    /// Optional second statistic folded in the same single pass.
    pub extra: Option<ReduceKind>,
    pub axis: ReduceAxis,
}

impl ReduceSpec {
    /// One statistic over `axis`.
    pub fn single(kind: ReduceKind, axis: ReduceAxis) -> ReduceSpec {
        ReduceSpec { kind, extra: None, axis }
    }

    /// Two statistics folded in one pass over `axis`.
    pub fn pair(kind: ReduceKind, extra: ReduceKind, axis: ReduceAxis) -> ReduceSpec {
        ReduceSpec { kind, extra: Some(extra), axis }
    }

    /// Number of statistics this pass folds (1 or 2).
    #[inline(always)]
    pub fn stat_count(&self) -> usize {
        1 + self.extra.is_some() as usize
    }

    /// Statistic `i` (`i < stat_count()`).
    #[inline(always)]
    pub fn stat(&self, i: usize) -> ReduceKind {
        if i == 0 {
            self.kind
        } else {
            self.extra.expect("stat index < stat_count")
        }
    }

    /// Number of output lanes (1 for `Full`, 3 for `PerChannel`).
    #[inline(always)]
    pub fn lanes(&self) -> usize {
        match self.axis {
            ReduceAxis::Full => 1,
            ReduceAxis::PerChannel => 3,
        }
    }

    /// Logical output shape of the reduction (the batch dimension folds in:
    /// statistics summarize the whole run). Layout is stat-major,
    /// lane-minor: `[lanes]`, or `[2, lanes-collapsed]` for pairs —
    /// concretely `[1]`, `[3]`, `[2]` or `[2, 3]`.
    pub fn out_shape(&self) -> Vec<usize> {
        match (self.extra.is_some(), self.axis) {
            (false, ReduceAxis::Full) => vec![1],
            (false, ReduceAxis::PerChannel) => vec![3],
            (true, ReduceAxis::Full) => vec![2],
            (true, ReduceAxis::PerChannel) => vec![2, 3],
        }
    }

    /// Total output element count.
    pub fn out_len(&self) -> usize {
        self.stat_count() * self.lanes()
    }

    /// Canonical signature token: kinds and axis shape the generated fold,
    /// so they distinguish plan-cache keys and HF streams.
    pub fn sig_token(&self) -> String {
        let stats = match self.extra {
            Some(extra) => format!("{}+{}", self.kind, extra),
            None => self.kind.to_string(),
        };
        match self.axis {
            ReduceAxis::Full => format!("reduce[{stats}]"),
            ReduceAxis::PerChannel => format!("reduce[{stats}@ch]"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fold_and_finalize_semantics() {
        assert_eq!(ReduceKind::Sum.fold(1.0, 2.0), 3.0);
        assert_eq!(ReduceKind::SumSq.fold(1.0, 3.0), 10.0);
        assert_eq!(ReduceKind::Min.fold(2.0, -1.0), -1.0);
        assert_eq!(ReduceKind::Max.fold(2.0, -1.0), 2.0);
        assert_eq!(ReduceKind::Mean.finalize(10.0, 4), 2.5);
        assert_eq!(ReduceKind::Sum.finalize(10.0, 4), 10.0);
    }

    #[test]
    fn fold_lanes_is_per_lane_fold() {
        // stripe j of the blocked fold must equal a scalar fold of the same
        // elements, bit-for-bit, for every kind — including NaN skipping
        let xs = [[1.5f64, -2.0, 0.0, f64::NAN], [3.25, 0.5, -7.0, 2.0]];
        for kind in ALL_REDUCE_KINDS {
            let mut blocked = [kind.identity(); 4];
            let mut scalar = [kind.identity(); 4];
            for row in &xs {
                kind.fold_lanes(&mut blocked, row);
                for (acc, &x) in scalar.iter_mut().zip(row) {
                    *acc = kind.fold(*acc, x);
                }
            }
            for j in 0..4 {
                assert_eq!(
                    blocked[j].to_bits(),
                    scalar[j].to_bits(),
                    "{kind:?} stripe {j}: {} vs {}",
                    blocked[j],
                    scalar[j]
                );
            }
        }
    }

    #[test]
    fn identities_cover_empty_reductions() {
        assert_eq!(ReduceKind::Sum.identity(), 0.0);
        assert_eq!(ReduceKind::Min.identity(), f64::INFINITY);
        assert_eq!(ReduceKind::Max.identity(), f64::NEG_INFINITY);
        assert!(ReduceKind::Mean.finalize(ReduceKind::Mean.identity(), 0).is_nan());
    }

    #[test]
    fn nan_elements_are_skipped_by_min_max() {
        // Rust f64::min/max return the non-NaN operand: folding a NaN is a
        // no-op, in ANY order — the determinism contract relies on this
        assert_eq!(ReduceKind::Max.fold(2.0, f64::NAN), 2.0);
        assert_eq!(ReduceKind::Min.fold(2.0, f64::NAN), 2.0);
        assert_eq!(ReduceKind::Max.fold(f64::NEG_INFINITY, f64::NAN), f64::NEG_INFINITY);
    }

    #[test]
    fn spec_geometry() {
        let s = ReduceSpec::single(ReduceKind::Mean, ReduceAxis::Full);
        assert_eq!((s.stat_count(), s.lanes(), s.out_len()), (1, 1, 1));
        assert_eq!(s.out_shape(), vec![1]);
        assert_eq!(s.sig_token(), "reduce[mean]");

        let p = ReduceSpec::pair(ReduceKind::Mean, ReduceKind::SumSq, ReduceAxis::PerChannel);
        assert_eq!((p.stat_count(), p.lanes(), p.out_len()), (2, 3, 6));
        assert_eq!(p.out_shape(), vec![2, 3]);
        assert_eq!(p.stat(0), ReduceKind::Mean);
        assert_eq!(p.stat(1), ReduceKind::SumSq);
        assert_eq!(p.sig_token(), "reduce[mean+sumsq@ch]");

        assert_eq!(
            ReduceSpec::single(ReduceKind::Max, ReduceAxis::PerChannel).sig_token(),
            "reduce[max@ch]"
        );
    }
}
