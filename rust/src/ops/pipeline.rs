//! Pipelines: validated, ordered IOp sequences.
//!
//! The paper's `__global__` executor statically asserts that the first IOp is
//! a ReadType and the last a WriteType, and that each op's OutputType matches
//! the next op's InputType (Fig. 10 `S_ASSERT_INPUT_OUTPUT`). Those checks
//! happen here at pipeline construction, before anything touches the runtime.

use crate::tensor::DType;

use super::{IOp, MemOp, Opcode};

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum PipelineError {
    #[error("pipeline must start with a Read operation")]
    MissingRead,
    #[error("pipeline must end with a Write operation")]
    MissingWrite,
    #[error("interior operation {index} is a memory operation ({token})")]
    InteriorMemOp { index: usize, token: String },
    #[error("pipeline has no compute body")]
    Empty,
}

/// A validated chain: Read, [Compute...], Write over an element shape with an
/// optional batch (HF) dimension.
#[derive(Debug, Clone, PartialEq)]
pub struct Pipeline {
    ops: Vec<IOp>,
    /// Logical element shape of one batch item (excludes batch dim).
    pub shape: Vec<usize>,
    /// Batch size (HF width); 1 = no horizontal fusion.
    pub batch: usize,
    pub dtin: DType,
    pub dtout: DType,
}

impl Pipeline {
    /// Validate and build. `ops` must be Read, compute*, Write.
    pub fn new(
        ops: Vec<IOp>,
        shape: Vec<usize>,
        batch: usize,
        dtin: DType,
        dtout: DType,
    ) -> Result<Pipeline, PipelineError> {
        if ops.is_empty() {
            return Err(PipelineError::Empty);
        }
        if !matches!(ops.first(), Some(IOp::Mem(m)) if m.class() == super::OpClass::Read) {
            return Err(PipelineError::MissingRead);
        }
        if !matches!(ops.last(), Some(IOp::Mem(m)) if m.class() == super::OpClass::Write) {
            return Err(PipelineError::MissingWrite);
        }
        for (index, op) in ops.iter().enumerate().skip(1).take(ops.len().saturating_sub(2)) {
            if matches!(op, IOp::Mem(_)) {
                return Err(PipelineError::InteriorMemOp { index, token: op.sig_token() });
            }
        }
        Ok(Pipeline { ops, shape, batch, dtin, dtout })
    }

    /// Convenience: dense read -> compute chain -> dense write.
    pub fn elementwise(
        body: Vec<IOp>,
        shape: Vec<usize>,
        batch: usize,
        dtin: DType,
        dtout: DType,
    ) -> Result<Pipeline, PipelineError> {
        let mut ops = Vec::with_capacity(body.len() + 2);
        ops.push(IOp::Mem(MemOp::Read { dtype: dtin }));
        ops.extend(body);
        ops.push(IOp::Mem(MemOp::Write { dtype: dtout }));
        Pipeline::new(ops, shape, batch, dtin, dtout)
    }

    /// Convenience: a chain of (opcode, param) pairs.
    pub fn from_opcodes(
        chain: &[(Opcode, f64)],
        shape: &[usize],
        batch: usize,
        dtin: DType,
        dtout: DType,
    ) -> Result<Pipeline, PipelineError> {
        let body = chain.iter().map(|&(op, p)| IOp::compute(op, p)).collect();
        Pipeline::elementwise(body, shape.to_vec(), batch, dtin, dtout)
    }

    pub fn ops(&self) -> &[IOp] {
        &self.ops
    }

    /// The same code at a different HF width (bucket re-batching on the
    /// coordinator's hot path — no revalidation needed, the op sequence is
    /// already proven).
    pub fn with_batch(&self, batch: usize) -> Pipeline {
        Pipeline {
            ops: self.ops.clone(),
            shape: self.shape.clone(),
            batch,
            dtin: self.dtin,
            dtout: self.dtout,
        }
    }

    /// The compute body (everything between read and write).
    pub fn body(&self) -> &[IOp] {
        &self.ops[1..self.ops.len() - 1]
    }

    /// Number of elements of one batch item.
    pub fn item_elems(&self) -> usize {
        self.shape.iter().product()
    }

    /// Per-element instruction estimate of the whole body.
    pub fn instr_cost(&self) -> f64 {
        self.ops.iter().map(IOp::instr_cost).sum()
    }

    /// Bytes moved by the FUSED execution: one read + one write.
    pub fn fused_bytes(&self) -> usize {
        self.batch
            * self.item_elems()
            * (self.dtin.size_bytes() + self.dtout.size_bytes())
    }

    /// Bytes moved by the UNFUSED execution: each op is its own kernel with a
    /// full read + write pass (paper Fig. 3A). Intermediates travel at the
    /// output dtype width.
    pub fn unfused_bytes(&self) -> usize {
        let n = self.batch * self.item_elems();
        let k = self.body().len().max(1);
        // first kernel: dtin -> inter; middle: inter -> inter; last: -> dtout
        let inter = self.dtout.size_bytes().max(4);
        let first = n * (self.dtin.size_bytes() + inter);
        let middle = (k.saturating_sub(2)) * n * 2 * inter;
        let last = if k > 1 { n * (inter + self.dtout.size_bytes()) } else { 0 };
        first + middle + last
    }

    /// GPU memory the unfused execution must allocate for intermediates and
    /// the fused one avoids (paper §VI-L).
    pub fn intermediate_bytes(&self) -> usize {
        let k = self.body().len();
        if k <= 1 {
            return 0;
        }
        let inter = self.dtout.size_bytes().max(4);
        // double-buffered ping-pong like the paper's d_up/d_temp pair
        2_usize.min(k - 1) * self.batch * self.item_elems() * inter
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(body: Vec<IOp>) -> Result<Pipeline, PipelineError> {
        Pipeline::elementwise(body, vec![4, 4], 1, DType::F32, DType::F32)
    }

    #[test]
    fn valid_pipeline() {
        let p = mk(vec![IOp::compute(Opcode::Mul, 2.0), IOp::compute(Opcode::Add, 1.0)]).unwrap();
        assert_eq!(p.body().len(), 2);
        assert_eq!(p.instr_cost(), 2.0);
    }

    #[test]
    fn rejects_interior_memop() {
        let e = Pipeline::new(
            vec![
                IOp::Mem(MemOp::Read { dtype: DType::F32 }),
                IOp::Mem(MemOp::Read { dtype: DType::F32 }),
                IOp::Mem(MemOp::Write { dtype: DType::F32 }),
            ],
            vec![4],
            1,
            DType::F32,
            DType::F32,
        )
        .unwrap_err();
        assert!(matches!(e, PipelineError::InteriorMemOp { index: 1, .. }));
    }

    #[test]
    fn rejects_missing_ends() {
        let e = Pipeline::new(
            vec![IOp::compute(Opcode::Mul, 2.0)],
            vec![4],
            1,
            DType::F32,
            DType::F32,
        )
        .unwrap_err();
        assert_eq!(e, PipelineError::MissingRead);
    }

    #[test]
    fn byte_accounting_fused_vs_unfused() {
        let p = Pipeline::from_opcodes(
            &[(Opcode::Mul, 2.0), (Opcode::Add, 1.0), (Opcode::Sub, 0.5)],
            &[100],
            1,
            DType::F32,
            DType::F32,
        )
        .unwrap();
        assert_eq!(p.fused_bytes(), 100 * 8);
        // 3 kernels, each 100 elems * (4 read + 4 write)
        assert_eq!(p.unfused_bytes(), 3 * 100 * 8);
        assert!(p.intermediate_bytes() > 0);
    }

    #[test]
    fn single_op_has_no_intermediates() {
        let p = Pipeline::from_opcodes(&[(Opcode::Mul, 2.0)], &[10], 1, DType::F32, DType::F32)
            .unwrap();
        assert_eq!(p.intermediate_bytes(), 0);
        assert_eq!(p.fused_bytes(), p.unfused_bytes());
    }
}
