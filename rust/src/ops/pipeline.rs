//! Pipelines: validated, ordered IOp sequences.
//!
//! The paper's `__global__` executor statically asserts that the first IOp is
//! a ReadType and the last a WriteType, and that each op's OutputType matches
//! the next op's InputType (Fig. 10 `S_ASSERT_INPUT_OUTPUT`). Those checks
//! happen here at pipeline construction, before anything touches the runtime.

use crate::tensor::DType;

use super::{IOp, MemOp, Opcode, ReduceSpec};

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum PipelineError {
    #[error("pipeline must start with a Read operation")]
    MissingRead,
    #[error("pipeline must end with a Write operation")]
    MissingWrite,
    #[error("interior operation {index} is a memory operation ({token})")]
    InteriorMemOp { index: usize, token: String },
    #[error("pipeline has no compute body")]
    Empty,
    #[error("reduce terminator seals at f64 (the statistics domain), got dtout {0}")]
    ReduceOutput(String),
}

/// One recorded marker-type change from the typed chain builder: after body
/// stage `at` (0 = before any compute op), values were reinterpreted as `to`.
/// Casts are free at run time — the lane type is erased at lowering — so the
/// trace exists purely for static analysis (`crate::analysis`), which uses it
/// to flag redundant chains and narrowing round-trips the executed IR cannot
/// see.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CastStep {
    /// Body index the cast sits after: `0..=body.len()`.
    pub at: usize,
    /// The marker dtype the chain switched to.
    pub to: DType,
}

/// A validated chain: Read, [Compute...], Write over an element shape with an
/// optional batch (HF) dimension.
#[derive(Debug, Clone, PartialEq)]
pub struct Pipeline {
    ops: Vec<IOp>,
    /// Interior marker-type casts recorded by the typed builder (empty for
    /// pipelines built straight from opcodes). Normalized: the final cast to
    /// `dtout` at the write boundary is implied and never stored, so a typed
    /// chain and its untyped `from_opcodes` twin compare equal.
    casts: Vec<CastStep>,
    /// Logical element shape of one batch item (excludes batch dim).
    pub shape: Vec<usize>,
    /// Batch size (HF width); 1 = no horizontal fusion.
    pub batch: usize,
    pub dtin: DType,
    pub dtout: DType,
}

impl Pipeline {
    /// Validate and build. `ops` must be Read, compute*, Write.
    pub fn new(
        ops: Vec<IOp>,
        shape: Vec<usize>,
        batch: usize,
        dtin: DType,
        dtout: DType,
    ) -> Result<Pipeline, PipelineError> {
        if ops.is_empty() {
            return Err(PipelineError::Empty);
        }
        if !matches!(ops.first(), Some(IOp::Mem(m)) if m.class() == super::OpClass::Read) {
            return Err(PipelineError::MissingRead);
        }
        if !matches!(ops.last(), Some(IOp::Mem(m)) if m.class() == super::OpClass::Write) {
            return Err(PipelineError::MissingWrite);
        }
        for (index, op) in ops.iter().enumerate().skip(1).take(ops.len().saturating_sub(2)) {
            if matches!(op, IOp::Mem(_)) {
                return Err(PipelineError::InteriorMemOp { index, token: op.sig_token() });
            }
        }
        // a reduce terminator produces f64 statistics: sealing at any other
        // dtype would silently round the accumulators at the boundary
        if matches!(ops.last(), Some(IOp::Mem(m)) if m.reduction().is_some())
            && dtout != DType::F64
        {
            return Err(PipelineError::ReduceOutput(dtout.to_string()));
        }
        Ok(Pipeline { ops, casts: Vec::new(), shape, batch, dtin, dtout })
    }

    /// Convenience: dense read -> compute chain -> dense write.
    pub fn elementwise(
        body: Vec<IOp>,
        shape: Vec<usize>,
        batch: usize,
        dtin: DType,
        dtout: DType,
    ) -> Result<Pipeline, PipelineError> {
        let mut ops = Vec::with_capacity(body.len() + 2);
        ops.push(IOp::Mem(MemOp::Read { dtype: dtin }));
        ops.extend(body);
        ops.push(IOp::Mem(MemOp::Write { dtype: dtout }));
        Pipeline::new(ops, shape, batch, dtin, dtout)
    }

    /// Convenience: a chain of (opcode, param) pairs.
    pub fn from_opcodes(
        chain: &[(Opcode, f64)],
        shape: &[usize],
        batch: usize,
        dtin: DType,
        dtout: DType,
    ) -> Result<Pipeline, PipelineError> {
        let body = chain.iter().map(|&(op, p)| IOp::compute(op, p)).collect();
        Pipeline::elementwise(body, shape.to_vec(), batch, dtin, dtout)
    }

    pub fn ops(&self) -> &[IOp] {
        &self.ops
    }

    /// Attach the typed builder's cast trace. Entries are clamped to the body
    /// range and normalized: trailing casts at the write boundary that match
    /// `dtout` restate what the write already records, so they are dropped —
    /// this keeps a typed chain `==` its untyped `from_opcodes` twin.
    pub fn with_cast_trace(mut self, casts: Vec<CastStep>) -> Pipeline {
        let body_len = self.ops.len() - 2;
        self.casts = casts
            .into_iter()
            .map(|c| CastStep { at: c.at.min(body_len), to: c.to })
            .collect();
        while matches!(self.casts.last(), Some(c) if c.at == body_len && c.to == self.dtout) {
            self.casts.pop();
        }
        self
    }

    /// Interior marker-type casts recorded by the typed builder, in chain
    /// order (empty unless the chain used `cast::<T>()` mid-body).
    pub fn cast_trace(&self) -> &[CastStep] {
        &self.casts
    }

    /// The same code at a different HF width (bucket re-batching on the
    /// coordinator's hot path — no revalidation needed, the op sequence is
    /// already proven).
    pub fn with_batch(&self, batch: usize) -> Pipeline {
        Pipeline {
            ops: self.ops.clone(),
            casts: self.casts.clone(),
            shape: self.shape.clone(),
            batch,
            dtin: self.dtin,
            dtout: self.dtout,
        }
    }

    /// The compute body (everything between read and write).
    pub fn body(&self) -> &[IOp] {
        &self.ops[1..self.ops.len() - 1]
    }

    /// The access pattern of the read end (validated to exist by `new`).
    pub fn read_pattern(&self) -> super::ReadPattern {
        match self.ops.first() {
            Some(IOp::Mem(m)) => m.read_pattern().expect("validated: first op is a read"),
            _ => unreachable!("validated pipeline starts with a read"),
        }
    }

    /// The access pattern of the write end (validated to exist by `new`).
    pub fn write_pattern(&self) -> super::WritePattern {
        match self.ops.last() {
            Some(IOp::Mem(m)) => m.write_pattern().expect("validated: last op is a write"),
            _ => unreachable!("validated pipeline ends with a write"),
        }
    }

    /// True when either boundary owns a non-dense access pattern — the
    /// question every planner used to answer by pattern-matching boundary
    /// variants (or worse, sig tokens).
    pub fn has_structured_boundary(&self) -> bool {
        self.read_pattern() != super::ReadPattern::Dense
            || self.write_pattern() != super::WritePattern::Dense
    }

    /// The reduction terminator, if this pipeline ends in one — the metadata
    /// planners interrogate to route reduce pipelines (artifact tiers refuse
    /// with [`crate::fusion::PlanError::Reduction`]; the host fused engine
    /// serves them in its fold-while-reading tier).
    pub fn reduction(&self) -> Option<ReduceSpec> {
        match self.ops.last() {
            Some(IOp::Mem(m)) => m.reduction(),
            _ => None,
        }
    }

    /// Logical output shape of one run. Dense writes produce
    /// `[batch, *shape]`; a Split write scatters the trailing 3-lane pixel
    /// dim to the front of the item (`[h, w, 3]` -> `[batch, 3, h, w]`); a
    /// Reduce terminator folds the batch dimension too and lands the tiny
    /// statistics tensor ([`ReduceSpec::out_shape`]).
    pub fn out_shape(&self) -> Vec<usize> {
        let mut out = vec![self.batch];
        match self.write_pattern() {
            super::WritePattern::Dense => out.extend_from_slice(&self.shape),
            super::WritePattern::Split => {
                out.push(3);
                if let Some((_, rest)) = self.shape.split_last() {
                    out.extend_from_slice(rest);
                }
            }
            super::WritePattern::Reduce { spec } => return spec.out_shape(),
        }
        out
    }

    /// Number of elements of one batch item.
    pub fn item_elems(&self) -> usize {
        self.shape.iter().product()
    }

    /// Per-element instruction estimate of the whole body.
    pub fn instr_cost(&self) -> f64 {
        self.ops.iter().map(IOp::instr_cost).sum()
    }

    /// Bytes moved by the FUSED execution: one read + one write. A reduce
    /// terminator has no per-element write — only the statistics land.
    pub fn fused_bytes(&self) -> usize {
        if let Some(spec) = self.reduction() {
            return self.batch * self.item_elems() * self.dtin.size_bytes()
                + spec.out_len() * self.dtout.size_bytes();
        }
        self.batch
            * self.item_elems()
            * (self.dtin.size_bytes() + self.dtout.size_bytes())
    }

    /// Bytes moved by the UNFUSED execution: each op is its own kernel with a
    /// full read + write pass (paper Fig. 3A). Intermediates travel at the
    /// output dtype width.
    pub fn unfused_bytes(&self) -> usize {
        let n = self.batch * self.item_elems();
        let k = self.body().len().max(1);
        // first kernel: dtin -> inter; middle: inter -> inter; last: -> dtout
        let inter = self.dtout.size_bytes().max(4);
        let first = n * (self.dtin.size_bytes() + inter);
        let middle = (k.saturating_sub(2)) * n * 2 * inter;
        let last = if k > 1 { n * (inter + self.dtout.size_bytes()) } else { 0 };
        first + middle + last
    }

    /// Bytes an op-at-a-time baseline MATERIALIZES, counting each buffer
    /// once: the input, one intermediate per interior stage (at the same
    /// `dtout.max(4)` width as [`Pipeline::unfused_bytes`]), and the final
    /// output. This is the memory-traffic denominator of the
    /// fusion-efficiency ratio: against the fused pass's `in + out`, a
    /// dense chain-k map ideals out at `(k+1)/2`× (k+1 buffers collapse to
    /// 2). A reduce terminator reads its last intermediate and lands only
    /// the statistics, so a bare read→reduce baselines equal to its fused
    /// pass (ratio 1.0) and every map stage in front of the seal adds a
    /// whole materialization the fused fold never pays.
    pub fn baseline_bytes(&self) -> usize {
        let n = self.batch * self.item_elems();
        let inter = self.dtout.size_bytes().max(4);
        if let Some(spec) = self.reduction() {
            return n * self.dtin.size_bytes()
                + self.body().len() * n * inter
                + spec.out_len() * self.dtout.size_bytes();
        }
        let k = self.body().len().max(1);
        n * self.dtin.size_bytes() + (k - 1) * n * inter + n * self.dtout.size_bytes()
    }

    /// GPU memory the unfused execution must allocate for intermediates and
    /// the fused one avoids (paper §VI-L).
    pub fn intermediate_bytes(&self) -> usize {
        let k = self.body().len();
        if k <= 1 {
            return 0;
        }
        let inter = self.dtout.size_bytes().max(4);
        // double-buffered ping-pong like the paper's d_up/d_temp pair
        2_usize.min(k - 1) * self.batch * self.item_elems() * inter
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(body: Vec<IOp>) -> Result<Pipeline, PipelineError> {
        Pipeline::elementwise(body, vec![4, 4], 1, DType::F32, DType::F32)
    }

    #[test]
    fn valid_pipeline() {
        let p = mk(vec![IOp::compute(Opcode::Mul, 2.0), IOp::compute(Opcode::Add, 1.0)]).unwrap();
        assert_eq!(p.body().len(), 2);
        assert_eq!(p.instr_cost(), 2.0);
    }

    #[test]
    fn rejects_interior_memop() {
        let e = Pipeline::new(
            vec![
                IOp::Mem(MemOp::Read { dtype: DType::F32 }),
                IOp::Mem(MemOp::Read { dtype: DType::F32 }),
                IOp::Mem(MemOp::Write { dtype: DType::F32 }),
            ],
            vec![4],
            1,
            DType::F32,
            DType::F32,
        )
        .unwrap_err();
        assert!(matches!(e, PipelineError::InteriorMemOp { index: 1, .. }));
    }

    #[test]
    fn rejects_missing_ends() {
        let e = Pipeline::new(
            vec![IOp::compute(Opcode::Mul, 2.0)],
            vec![4],
            1,
            DType::F32,
            DType::F32,
        )
        .unwrap_err();
        assert_eq!(e, PipelineError::MissingRead);
    }

    #[test]
    fn boundary_patterns_and_out_shape() {
        use super::super::{ReadPattern, WritePattern};
        use crate::tensor::Rect;
        let dense = mk(vec![IOp::compute(Opcode::Mul, 2.0)]).unwrap();
        assert_eq!(dense.read_pattern(), ReadPattern::Dense);
        assert_eq!(dense.write_pattern(), WritePattern::Dense);
        assert!(!dense.has_structured_boundary());
        assert_eq!(dense.out_shape(), vec![1, 4, 4]);

        let rect = Rect::new(0, 0, 16, 8);
        let structured = Pipeline::new(
            vec![
                IOp::Mem(MemOp::ResizeRead { rect, dst_h: 8, dst_w: 4 }),
                IOp::compute(Opcode::Mul, 1.0),
                IOp::Mem(MemOp::SplitWrite { dtype: DType::F32 }),
            ],
            vec![8, 4, 3],
            2,
            DType::U8,
            DType::F32,
        )
        .unwrap();
        assert_eq!(
            structured.read_pattern(),
            ReadPattern::CropResize { rect, dst_h: 8, dst_w: 4 }
        );
        assert_eq!(structured.write_pattern(), WritePattern::Split);
        assert!(structured.has_structured_boundary());
        // split: packed [8, 4, 3] pixels land planar as [2, 3, 8, 4]
        assert_eq!(structured.out_shape(), vec![2, 3, 8, 4]);
    }

    #[test]
    fn byte_accounting_fused_vs_unfused() {
        let p = Pipeline::from_opcodes(
            &[(Opcode::Mul, 2.0), (Opcode::Add, 1.0), (Opcode::Sub, 0.5)],
            &[100],
            1,
            DType::F32,
            DType::F32,
        )
        .unwrap();
        assert_eq!(p.fused_bytes(), 100 * 8);
        // 3 kernels, each 100 elems * (4 read + 4 write)
        assert_eq!(p.unfused_bytes(), 3 * 100 * 8);
        assert!(p.intermediate_bytes() > 0);
        // baseline materializes k+1 buffers once each: in + 2 inter + out;
        // against the fused 2 buffers the chain-3 ideal is (3+1)/2 = 2x
        assert_eq!(p.baseline_bytes(), 100 * 16);
        assert_eq!(p.baseline_bytes() as f64 / p.fused_bytes() as f64, 2.0);
    }

    #[test]
    fn baseline_bytes_chain_k_ideal_and_reduce_seal() {
        // chain-1 moves exactly what the fused pass moves (ratio 1.0)
        let one =
            Pipeline::from_opcodes(&[(Opcode::Mul, 2.0)], &[8], 1, DType::F32, DType::F32)
                .unwrap();
        assert_eq!(one.baseline_bytes(), one.fused_bytes());
        // chain-5 u8->f32: 1 + 4*4 + 4 = 21 bytes/elem vs 5 fused = 4.2x
        let five = Pipeline::from_opcodes(
            &[(Opcode::Mul, 2.0); 5],
            &[10],
            1,
            DType::U8,
            DType::F32,
        )
        .unwrap();
        assert_eq!(five.baseline_bytes(), 10 * (1 + 4 * 4 + 4));
        assert!(five.baseline_bytes() > five.fused_bytes());
        // a bare read->reduce baseline equals its fused pass: there is no
        // per-element intermediate for fusion to save
        use super::super::{ReduceAxis, ReduceKind, ReduceSpec};
        let spec = ReduceSpec::single(ReduceKind::Mean, ReduceAxis::Full);
        let seal = Pipeline::new(
            vec![
                IOp::Mem(MemOp::Read { dtype: DType::F32 }),
                IOp::compute(Opcode::Mul, 2.0),
                IOp::Mem(MemOp::Reduce { spec }),
            ],
            vec![4, 4],
            1,
            DType::F32,
            DType::F64,
        )
        .unwrap();
        // one map stage in front of the seal = one full materialization
        assert_eq!(seal.baseline_bytes(), seal.fused_bytes() + 16 * 8);
    }

    #[test]
    fn reduce_terminators_validate_and_shape() {
        use super::super::{ReduceAxis, ReduceKind, ReduceSpec};
        let spec = ReduceSpec::pair(ReduceKind::Mean, ReduceKind::SumSq, ReduceAxis::PerChannel);
        let mk = |dtout| {
            Pipeline::new(
                vec![
                    IOp::Mem(MemOp::Read { dtype: DType::U8 }),
                    IOp::compute(Opcode::Mul, 0.5),
                    IOp::Mem(MemOp::Reduce { spec }),
                ],
                vec![4, 4, 3],
                2,
                DType::U8,
                dtout,
            )
        };
        // sealing anywhere but f64 is refused loudly
        let err = mk(DType::F32).unwrap_err();
        assert_eq!(err, PipelineError::ReduceOutput("f32".to_string()));

        let p = mk(DType::F64).unwrap();
        assert_eq!(p.reduction(), Some(spec));
        assert!(p.has_structured_boundary(), "dense tiers must not match it");
        // the batch folds into the statistics: out shape is the spec's
        assert_eq!(p.out_shape(), vec![2, 3]);
        // one read of the data + the statistics write, nothing per-element
        assert_eq!(p.fused_bytes(), 2 * 48 + 6 * 8);
        // dense pipelines report no reduction
        assert_eq!(mkp().reduction(), None);

        fn mkp() -> Pipeline {
            Pipeline::from_opcodes(&[(Opcode::Mul, 2.0)], &[4], 1, DType::F32, DType::F32)
                .unwrap()
        }
    }

    #[test]
    fn cast_trace_normalizes_the_write_boundary_away() {
        let p = mk(vec![IOp::compute(Opcode::Mul, 2.0), IOp::compute(Opcode::Abs, 0.0)]).unwrap();
        // the trailing cast restates the write dtype: normalized away, so the
        // traced pipeline still compares equal to its untraced twin
        let traced = p.clone().with_cast_trace(vec![CastStep { at: 2, to: DType::F32 }]);
        assert_eq!(traced.cast_trace(), &[]);
        assert_eq!(traced, p);
        // an interior cast survives (and is clamped into the body range)
        let traced = p.clone().with_cast_trace(vec![
            CastStep { at: 1, to: DType::F64 },
            CastStep { at: 9, to: DType::F32 },
        ]);
        assert_eq!(traced.cast_trace(), &[CastStep { at: 1, to: DType::F64 }]);
        assert_ne!(traced, p);
        assert_eq!(traced.with_batch(4).cast_trace().len(), 1, "rebatching keeps the trace");
    }

    #[test]
    fn single_op_has_no_intermediates() {
        let p = Pipeline::from_opcodes(&[(Opcode::Mul, 2.0)], &[10], 1, DType::F32, DType::F32)
            .unwrap();
        assert_eq!(p.intermediate_bytes(), 0);
        assert_eq!(p.fused_bytes(), p.unfused_bytes());
    }
}
