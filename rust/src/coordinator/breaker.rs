//! Per-signature circuit breakers driving the degradation ladder.
//!
//! A stream that keeps failing must stop re-entering the stacked-HF tier:
//! a stacked launch couples the fates of every request in the bucket, so a
//! poisoned signature turns the fast path into a blast radius. Each stream
//! key gets a breaker that walks the serving ladder DOWN on consecutive
//! failures — stacked HF → divergent HF → per-item → reject (Open) — and
//! back UP on sustained success. Probation is **attempt-counted**, never
//! wall-clock: an Open breaker admits a half-open probe after a fixed
//! number of rejected attempts, so every transition is deterministic under
//! test (no sleeps, no clocks).

use std::collections::HashMap;

/// Classic breaker states, per stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Serving normally at [`BreakerSnapshot::tier`].
    Closed,
    /// Rejecting everything; counting rejected attempts toward probation.
    Open,
    /// One probe request is in flight per-item; company is rejected.
    HalfOpen,
}

/// The ladder tier a stream is currently allowed to serve at.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum ServeTier {
    /// Tier 1: identical requests stack into one HF launch.
    Stacked,
    /// Tier 2: requests join the window's shared divergent-HF pass.
    Divergent,
    /// Tier 3: each request launches alone.
    PerItem,
}

impl ServeTier {
    pub fn name(self) -> &'static str {
        match self {
            ServeTier::Stacked => "stacked",
            ServeTier::Divergent => "divergent",
            ServeTier::PerItem => "peritem",
        }
    }

    fn demoted(self) -> Option<ServeTier> {
        match self {
            ServeTier::Stacked => Some(ServeTier::Divergent),
            ServeTier::Divergent => Some(ServeTier::PerItem),
            ServeTier::PerItem => None,
        }
    }

    fn promoted(self) -> Option<ServeTier> {
        match self {
            ServeTier::Stacked => None,
            ServeTier::Divergent => Some(ServeTier::Stacked),
            ServeTier::PerItem => Some(ServeTier::Divergent),
        }
    }
}

/// Breaker thresholds. All counts, no durations — deterministic by design.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BreakerPolicy {
    /// Consecutive failures at the current tier that demote one level
    /// (from per-item, demotion means opening the breaker).
    pub failure_threshold: u32,
    /// Rejected attempts an Open breaker counts before admitting a
    /// half-open probe.
    pub probation_attempts: u32,
    /// Consecutive successes at a demoted tier before promoting one level.
    pub promote_successes: u32,
}

impl Default for BreakerPolicy {
    fn default() -> Self {
        BreakerPolicy { failure_threshold: 3, probation_attempts: 4, promote_successes: 4 }
    }
}

/// What the scheduler may do with a group of one stream right now.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Serve the whole group at this ladder tier.
    Serve(ServeTier),
    /// Half-open: serve EXACTLY ONE request per item as the probe; reject
    /// the rest of the group.
    Probe,
    /// Open: reject the whole group with a typed error.
    Reject,
}

#[derive(Debug)]
struct Breaker {
    state: BreakerState,
    tier: ServeTier,
    consecutive_failures: u32,
    consecutive_successes: u32,
    /// Rejected attempts since the breaker opened (probation progress).
    open_attempts: u32,
    /// A half-open probe is in flight (admit no second probe).
    probing: bool,
    trips: u64,
    rejected: u64,
}

impl Breaker {
    fn new() -> Breaker {
        Breaker {
            state: BreakerState::Closed,
            tier: ServeTier::Stacked,
            consecutive_failures: 0,
            consecutive_successes: 0,
            open_attempts: 0,
            probing: false,
            trips: 0,
            rejected: 0,
        }
    }

    fn pristine(&self) -> bool {
        self.state == BreakerState::Closed
            && self.tier == ServeTier::Stacked
            && self.consecutive_failures == 0
            && self.trips == 0
            && self.rejected == 0
    }
}

/// Point-in-time state of one stream's breaker (exported via
/// [`crate::coordinator::MetricsSnapshot::breakers`]; pristine
/// never-tripped streams are omitted).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BreakerSnapshot {
    pub key: String,
    pub state: BreakerState,
    pub tier: ServeTier,
    pub consecutive_failures: u32,
    /// Demotions (including opening) this stream has taken.
    pub trips: u64,
    /// Requests rejected while Open/HalfOpen.
    pub rejected: u64,
}

/// All breakers, keyed by stream key. Plain data — unit-testable without a
/// service thread.
#[derive(Debug, Default)]
pub struct BreakerBoard {
    policy: BreakerPolicy,
    map: HashMap<String, Breaker>,
}

impl BreakerBoard {
    pub fn new(policy: BreakerPolicy) -> BreakerBoard {
        BreakerBoard { policy, map: HashMap::new() }
    }

    /// Decide what the scheduler may do with a group of this stream. An
    /// Open breaker's probation advances by *attempts* (see
    /// [`BreakerBoard::note_rejected`]), so the call itself is read-only
    /// except for the Open→HalfOpen/probe transitions.
    pub fn admit(&mut self, key: &str) -> Admission {
        let b = self.map.entry(key.to_string()).or_insert_with(Breaker::new);
        match b.state {
            BreakerState::Closed => Admission::Serve(b.tier),
            BreakerState::HalfOpen => {
                if b.probing {
                    Admission::Reject
                } else {
                    b.probing = true;
                    Admission::Probe
                }
            }
            BreakerState::Open => {
                if b.open_attempts >= self.policy.probation_attempts {
                    b.state = BreakerState::HalfOpen;
                    b.probing = true;
                    Admission::Probe
                } else {
                    Admission::Reject
                }
            }
        }
    }

    /// Count `n` requests rejected for this stream. While Open, rejected
    /// attempts are the probation clock.
    pub fn note_rejected(&mut self, key: &str, n: usize) {
        let b = self.map.entry(key.to_string()).or_insert_with(Breaker::new);
        b.rejected += n as u64;
        if b.state == BreakerState::Open {
            b.open_attempts += n as u32;
        }
    }

    /// One served request (or one stacked launch) failed with a
    /// service-side error. Client-side errors (malformed items) must NOT be
    /// reported here — they say nothing about the stream's pipeline.
    pub fn record_failure(&mut self, key: &str) {
        let p = self.policy;
        let b = self.map.entry(key.to_string()).or_insert_with(Breaker::new);
        match b.state {
            BreakerState::HalfOpen => {
                // failed probe: back to Open, probation restarts
                b.state = BreakerState::Open;
                b.probing = false;
                b.open_attempts = 0;
                b.consecutive_failures = 0;
                b.consecutive_successes = 0;
                b.trips += 1;
            }
            BreakerState::Closed => {
                b.consecutive_successes = 0;
                b.consecutive_failures += 1;
                if b.consecutive_failures >= p.failure_threshold {
                    b.consecutive_failures = 0;
                    b.trips += 1;
                    match b.tier.demoted() {
                        Some(t) => b.tier = t,
                        None => {
                            b.state = BreakerState::Open;
                            b.open_attempts = 0;
                        }
                    }
                }
            }
            BreakerState::Open => {}
        }
    }

    /// One served request (or one stacked launch) succeeded.
    pub fn record_success(&mut self, key: &str) {
        let p = self.policy;
        let b = self.map.entry(key.to_string()).or_insert_with(Breaker::new);
        match b.state {
            BreakerState::HalfOpen => {
                // successful probe: resume serving, bottom of the ladder
                b.state = BreakerState::Closed;
                b.tier = ServeTier::PerItem;
                b.probing = false;
                b.open_attempts = 0;
                b.consecutive_failures = 0;
                b.consecutive_successes = 1;
            }
            BreakerState::Closed => {
                b.consecutive_failures = 0;
                if b.tier != ServeTier::Stacked {
                    b.consecutive_successes += 1;
                    if b.consecutive_successes >= p.promote_successes {
                        b.consecutive_successes = 0;
                        if let Some(t) = b.tier.promoted() {
                            b.tier = t;
                        }
                    }
                }
            }
            BreakerState::Open => {}
        }
    }

    /// Total demotions across all streams.
    pub fn trips(&self) -> u64 {
        self.map.values().map(|b| b.trips).sum()
    }

    /// Total rejected requests across all streams.
    pub fn rejected(&self) -> u64 {
        self.map.values().map(|b| b.rejected).sum()
    }

    /// Snapshot every non-pristine breaker, sorted by key (deterministic).
    pub fn snapshot(&self) -> Vec<BreakerSnapshot> {
        let mut v: Vec<BreakerSnapshot> = self
            .map
            .iter()
            .filter(|(_, b)| !b.pristine())
            .map(|(k, b)| BreakerSnapshot {
                key: k.clone(),
                state: b.state,
                tier: b.tier,
                consecutive_failures: b.consecutive_failures,
                trips: b.trips,
                rejected: b.rejected,
            })
            .collect();
        v.sort_by(|a, b| a.key.cmp(&b.key));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> BreakerPolicy {
        BreakerPolicy { failure_threshold: 2, probation_attempts: 3, promote_successes: 2 }
    }

    #[test]
    fn healthy_stream_stays_stacked_and_unsnapshotted() {
        let mut b = BreakerBoard::new(policy());
        for _ in 0..10 {
            assert_eq!(b.admit("k"), Admission::Serve(ServeTier::Stacked));
            b.record_success("k");
        }
        assert!(b.snapshot().is_empty(), "pristine breakers stay out of snapshots");
    }

    #[test]
    fn consecutive_failures_walk_the_ladder_down_to_open() {
        let mut b = BreakerBoard::new(policy());
        for (expect, _) in
            [(ServeTier::Stacked, 0), (ServeTier::Divergent, 1), (ServeTier::PerItem, 2)]
        {
            assert_eq!(b.admit("k"), Admission::Serve(expect));
            b.record_failure("k");
            b.record_failure("k");
        }
        assert_eq!(b.admit("k"), Admission::Reject, "per-item trip opens the breaker");
        assert_eq!(b.trips(), 3);
        assert_eq!(b.snapshot()[0].state, BreakerState::Open);
    }

    #[test]
    fn interleaved_success_resets_the_failure_streak() {
        let mut b = BreakerBoard::new(policy());
        b.record_failure("k");
        b.record_success("k");
        b.record_failure("k");
        assert_eq!(b.admit("k"), Admission::Serve(ServeTier::Stacked), "streak broken, no trip");
        assert_eq!(b.trips(), 0);
    }

    #[test]
    fn probation_is_attempt_counted_then_probe_recovers_up_the_ladder() {
        let mut b = BreakerBoard::new(policy());
        for _ in 0..6 {
            b.record_failure("k"); // 2 per tier: stacked -> divergent -> peritem -> open
        }
        // probation: 3 rejected attempts before a probe
        assert_eq!(b.admit("k"), Admission::Reject);
        b.note_rejected("k", 3);
        assert_eq!(b.admit("k"), Admission::Probe);
        // only one probe at a time
        assert_eq!(b.admit("k"), Admission::Reject);
        b.record_success("k");
        assert_eq!(b.admit("k"), Admission::Serve(ServeTier::PerItem), "probe success closes");
        // promote_successes=2 per level: the probe success already counted 1
        b.record_success("k");
        assert_eq!(b.admit("k"), Admission::Serve(ServeTier::Divergent));
        b.record_success("k");
        b.record_success("k");
        assert_eq!(b.admit("k"), Admission::Serve(ServeTier::Stacked), "full recovery");
    }

    #[test]
    fn failed_probe_reopens_and_restarts_probation() {
        let mut b = BreakerBoard::new(policy());
        for _ in 0..6 {
            b.record_failure("k");
        }
        b.note_rejected("k", 3);
        assert_eq!(b.admit("k"), Admission::Probe);
        b.record_failure("k");
        assert_eq!(b.admit("k"), Admission::Reject, "probe failure reopens");
        b.note_rejected("k", 2);
        assert_eq!(b.admit("k"), Admission::Reject, "probation restarted from zero");
        b.note_rejected("k", 1);
        assert_eq!(b.admit("k"), Admission::Probe);
    }

    #[test]
    fn streams_are_independent() {
        let mut b = BreakerBoard::new(policy());
        b.record_failure("bad");
        b.record_failure("bad");
        assert_eq!(b.admit("bad"), Admission::Serve(ServeTier::Divergent));
        assert_eq!(b.admit("good"), Admission::Serve(ServeTier::Stacked));
        let snap = b.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].key, "bad");
    }
}
