//! Stream-key-hash ingress router and shard mailboxes.
//!
//! The sharded coordinator ([`crate::coordinator::ServiceConfig::shards`]
//! `> 1`) replaces the single `sync_channel` with one [`Mailbox`] per
//! shard behind a [`Router`]:
//!
//! * **Routing** — a request lands on the shard picked by the FNV-1a hash
//!   of its param-agnostic stream key
//!   ([`crate::ops::Signature::stream_hash`]). Same key → same shard, so
//!   HF grouping survives sharding: identical streams still meet in one
//!   batcher and stack into one launch.
//! * **Global admission, per-shard backpressure** — one shared atomic
//!   counts queued requests across ALL shards against
//!   [`crate::coordinator::ServiceConfig::queue_cap`] (total admission is
//!   the same as the single-worker coordinator), and each mailbox
//!   additionally caps its own slice (`ceil(queue_cap / shards)`) so one
//!   hot stream cannot monopolize the whole admission budget.
//! * **Work stealing** — an idle shard takes the OLDER half of its
//!   busiest sibling's mailbox ([`Router::steal_for`]); control messages
//!   (snapshot probes, shutdown) are never stolen and never counted
//!   against admission.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::SyncSender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::coordinator::service::Req;
use crate::coordinator::{MetricsSnapshot, SubmitError};
use crate::ops::Signature;

/// What a shard's worker loop receives — the sharded twin of the single
/// path's private `Msg` enum.
pub(crate) enum ShardMsg {
    Request(Box<Req>),
    Snapshot(SyncSender<MetricsSnapshot>),
    Shutdown,
}

struct Inner {
    queue: VecDeque<ShardMsg>,
    /// How many `ShardMsg::Request` entries `queue` holds (control
    /// messages ride for free).
    requests: usize,
}

/// One shard's bounded inbox: a mutex-guarded deque with a condvar so the
/// shard thread can sleep on it with a deadline-aware timeout.
pub(crate) struct Mailbox {
    inner: Mutex<Inner>,
    ready: Condvar,
    /// Per-shard request cap (backpressure even when the global budget
    /// still has room).
    cap: usize,
    /// Queued requests across ALL shards (shared; admission control).
    queued_global: Arc<AtomicUsize>,
    global_cap: usize,
}

impl Mailbox {
    fn new(cap: usize, queued_global: Arc<AtomicUsize>, global_cap: usize) -> Mailbox {
        Mailbox {
            inner: Mutex::new(Inner { queue: VecDeque::new(), requests: 0 }),
            ready: Condvar::new(),
            cap,
            queued_global,
            global_cap,
        }
    }

    /// Admit one request: the global budget first, then this shard's
    /// slice. On `QueueFull` the request is dropped here — its reply
    /// sender drops with it, which the submitter never observes because
    /// the error return precedes handing out the receiver.
    fn try_push_request(&self, req: Box<Req>) -> Result<(), SubmitError> {
        let prev = self.queued_global.fetch_add(1, Ordering::AcqRel);
        if prev >= self.global_cap {
            self.queued_global.fetch_sub(1, Ordering::AcqRel);
            return Err(SubmitError::QueueFull);
        }
        let mut inner = self.inner.lock().unwrap();
        if inner.requests >= self.cap {
            drop(inner);
            self.queued_global.fetch_sub(1, Ordering::AcqRel);
            return Err(SubmitError::QueueFull);
        }
        inner.requests += 1;
        inner.queue.push_back(ShardMsg::Request(req));
        drop(inner);
        self.ready.notify_one();
        Ok(())
    }

    /// Push a control message (snapshot probe / shutdown): never capped —
    /// observability and shutdown must work under full backpressure. FIFO
    /// like everything else, so a `Shutdown` pushed after N submissions is
    /// processed after them (graceful shutdown drains admitted work,
    /// exactly like the single-worker channel).
    pub(crate) fn push_control(&self, msg: ShardMsg) {
        let mut inner = self.inner.lock().unwrap();
        inner.queue.push_back(msg);
        drop(inner);
        self.ready.notify_one();
    }

    /// Pop the next message, waiting up to `timeout`. `None` = timed out
    /// (a spurious condvar wake with an empty queue also reports `None`;
    /// the shard loop treats both as "go look for other work").
    pub(crate) fn recv_timeout(&self, timeout: Duration) -> Option<ShardMsg> {
        let mut inner = self.inner.lock().unwrap();
        if inner.queue.is_empty() {
            let (guard, _) = self.ready.wait_timeout(inner, timeout).unwrap();
            inner = guard;
        }
        Self::pop_locked(&mut inner, &self.queued_global)
    }

    /// Non-blocking pop (the shard loop's opportunistic drain).
    pub(crate) fn try_recv(&self) -> Option<ShardMsg> {
        let mut inner = self.inner.lock().unwrap();
        Self::pop_locked(&mut inner, &self.queued_global)
    }

    fn pop_locked(inner: &mut Inner, queued_global: &AtomicUsize) -> Option<ShardMsg> {
        let msg = inner.queue.pop_front()?;
        if matches!(msg, ShardMsg::Request(_)) {
            inner.requests -= 1;
            queued_global.fetch_sub(1, Ordering::AcqRel);
        }
        Some(msg)
    }

    /// Queued requests (excluding control messages) — the steal heuristic
    /// and the per-shard `pending` gauge read this.
    pub(crate) fn queued_requests(&self) -> usize {
        self.inner.lock().unwrap().requests
    }

    /// Remove up to `max` requests from the FRONT of the queue (oldest
    /// first — the stolen work is the work that has waited longest).
    /// Control messages are skipped in place; their order relative to the
    /// remaining requests is preserved.
    fn steal(&self, max: usize) -> Vec<Box<Req>> {
        let mut inner = self.inner.lock().unwrap();
        let mut out = Vec::new();
        let mut i = 0;
        while out.len() < max && i < inner.queue.len() {
            if matches!(inner.queue[i], ShardMsg::Request(_)) {
                match inner.queue.remove(i) {
                    Some(ShardMsg::Request(r)) => out.push(r),
                    _ => unreachable!("checked variant under the same lock"),
                }
            } else {
                i += 1;
            }
        }
        inner.requests -= out.len();
        drop(inner);
        self.queued_global.fetch_sub(out.len(), Ordering::AcqRel);
        out
    }
}

/// The sharded coordinator's front door: routes submissions to mailboxes
/// by stream-key hash and closes them all on shutdown.
pub(crate) struct Router {
    mailboxes: Vec<Mailbox>,
    closed: AtomicBool,
}

impl Router {
    pub(crate) fn new(shards: usize, queue_cap: usize) -> Router {
        let shards = shards.max(1);
        let queued = Arc::new(AtomicUsize::new(0));
        // ceil(queue_cap / shards), at least 1: the slices jointly cover
        // the global budget with a little slack, and the global counter is
        // what actually enforces `queue_cap`
        let per_shard = queue_cap.div_ceil(shards).max(1);
        let mailboxes = (0..shards)
            .map(|_| Mailbox::new(per_shard, queued.clone(), queue_cap))
            .collect();
        Router { mailboxes, closed: AtomicBool::new(false) }
    }

    pub(crate) fn shards(&self) -> usize {
        self.mailboxes.len()
    }

    pub(crate) fn mailbox(&self, shard: usize) -> &Mailbox {
        &self.mailboxes[shard]
    }

    /// Which shard serves this signature's stream.
    pub(crate) fn shard_of(&self, sig: &Signature) -> usize {
        (sig.stream_hash() % self.mailboxes.len() as u64) as usize
    }

    /// Route one request to its stream's shard.
    pub(crate) fn submit(&self, req: Req) -> Result<(), SubmitError> {
        if self.closed.load(Ordering::Acquire) {
            return Err(SubmitError::Stopped);
        }
        let shard = self.shard_of(&Signature::of(&req.pipeline));
        self.mailboxes[shard].try_push_request(Box::new(req))
    }

    /// Stop admitting and tell every shard to flush and exit. Idempotent:
    /// only the first call pushes the `Shutdown` controls.
    pub(crate) fn close(&self) {
        if self.closed.swap(true, Ordering::AcqRel) {
            return;
        }
        for mb in &self.mailboxes {
            mb.push_control(ShardMsg::Shutdown);
        }
    }

    /// Work stealing for an idle shard `me`: find the sibling with the
    /// most queued requests and take the older half of them. Returns an
    /// empty vec when no sibling has at least 2 queued (stealing a lone
    /// request buys nothing — its shard is about to serve it).
    pub(crate) fn steal_for(&self, me: usize) -> Vec<Box<Req>> {
        let busiest = self
            .mailboxes
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != me)
            .map(|(i, mb)| (mb.queued_requests(), i))
            .max();
        match busiest {
            Some((n, victim)) if n >= 2 => self.mailboxes[victim].steal(n / 2),
            _ => Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::sync_channel;
    use std::time::Instant;

    use crate::coordinator::PendingRequest;
    use crate::ops::{Opcode, Pipeline};
    use crate::tensor::{DType, Tensor};

    fn req(mul: f64) -> Req {
        let pipeline = Pipeline::from_opcodes(
            &[(Opcode::Mul, mul)],
            &[2, 2],
            1,
            DType::F32,
            DType::F32,
        )
        .unwrap();
        // the reply receiver is dropped: router tests never send replies
        let (rtx, _) = sync_channel(1);
        let enqueued = Instant::now();
        PendingRequest {
            pipeline,
            item: Tensor::from_f32(&[0.0; 4], &[1, 2, 2]),
            enqueued,
            deadline: None,
            reply: rtx,
            trace_id: 0,
            trace_verdict: 0,
            admitted: enqueued,
        }
    }

    #[test]
    fn routing_is_stable_and_key_sticky() {
        let r = Router::new(4, 64);
        let sig = Signature::of(&req(1.0).pipeline);
        let shard = r.shard_of(&sig);
        for _ in 0..10 {
            assert_eq!(r.shard_of(&sig), shard, "same signature, same shard, every time");
        }
        // param-divergent twin: same stream key, same shard
        let sig2 = Signature::of(&req(99.0).pipeline);
        assert_eq!(r.shard_of(&sig2), shard);
    }

    #[test]
    fn global_cap_bounds_total_admission() {
        let r = Router::new(2, 3);
        let mut admitted = 0;
        for _ in 0..10 {
            if r.submit(req(1.0)).is_ok() {
                admitted += 1;
            }
        }
        assert!(admitted <= 3, "global queue_cap=3 bounds admission, got {admitted}");
        assert!(admitted >= 1, "an empty router admits");
    }

    #[test]
    fn closed_router_answers_stopped() {
        let r = Router::new(2, 8);
        r.close();
        assert!(matches!(r.submit(req(1.0)), Err(SubmitError::Stopped)));
        // each mailbox got exactly one Shutdown control
        for i in 0..2 {
            assert!(matches!(
                r.mailbox(i).recv_timeout(Duration::from_millis(10)),
                Some(ShardMsg::Shutdown)
            ));
        }
    }

    #[test]
    fn steal_takes_oldest_half_and_skips_controls() {
        let r = Router::new(2, 64);
        let sig = Signature::of(&req(1.0).pipeline);
        let victim = r.shard_of(&sig);
        let me = 1 - victim;
        for _ in 0..5 {
            r.submit(req(1.0)).unwrap();
        }
        let (stx, _srx) = sync_channel(1);
        r.mailbox(victim).push_control(ShardMsg::Snapshot(stx));
        let stolen = r.steal_for(me);
        assert_eq!(stolen.len(), 2, "half of 5, rounded down");
        assert_eq!(r.mailbox(victim).queued_requests(), 3);
        // the surviving requests still precede the control message
        for _ in 0..3 {
            assert!(matches!(
                r.mailbox(victim).recv_timeout(Duration::from_millis(10)),
                Some(ShardMsg::Request(_))
            ));
        }
        assert!(matches!(
            r.mailbox(victim).recv_timeout(Duration::from_millis(10)),
            Some(ShardMsg::Snapshot(_))
        ));
    }

    #[test]
    fn steal_leaves_lone_requests_alone() {
        let r = Router::new(2, 64);
        let sig = Signature::of(&req(1.0).pipeline);
        let victim = r.shard_of(&sig);
        r.submit(req(1.0)).unwrap();
        assert!(r.steal_for(1 - victim).is_empty());
        assert_eq!(r.mailbox(victim).queued_requests(), 1);
    }

    #[test]
    fn recv_timeout_times_out_empty() {
        let r = Router::new(1, 4);
        let t0 = Instant::now();
        assert!(r.mailbox(0).recv_timeout(Duration::from_millis(5)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(4));
    }

    #[test]
    fn pop_releases_admission_budget() {
        let r = Router::new(1, 2);
        r.submit(req(1.0)).unwrap();
        r.submit(req(1.0)).unwrap();
        assert!(matches!(r.submit(req(1.0)), Err(SubmitError::QueueFull)));
        assert!(r.mailbox(0).try_recv().is_some());
        r.submit(req(1.0)).expect("popping a request frees one admission slot");
    }
}
