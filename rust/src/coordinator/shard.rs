//! The sharded coordinator's worker loop: one per shard, each owning its
//! own backend, batcher, breaker board, metrics, and plan cache.
//!
//! Per-shard (not shared) caches are deliberate: the host engine's plan
//! compile is ~µs-cheap and re-compiles at most once per stream per shard,
//! while a shared cache would put a lock on every plan consult in every
//! launch — see DESIGN.md §10 for the measurement. Everything inside the
//! loop is the SAME code as the single-worker `service_loop`: `ingest`,
//! `pop_ready`/`expire`, `serve_window`, `flush` — so `shards = N` is N
//! bit-identical coordinators behind a hash router, plus work stealing.

use std::collections::HashSet;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::router::{Router, ShardMsg};
use crate::coordinator::service::{
    arm_faults, expire, flush, ingest, serve_window, snapshot, supervised_build, ServeError,
    ServiceConfig, SupervisedBuild,
};
use crate::coordinator::{Batcher, BreakerBoard, Metrics, MetricsSnapshot, ShardStat};

/// Idle-poll cadence: an idle shard re-checks its siblings for stealable
/// work this often (also the cap on how long it sleeps past a batcher
/// wake hint). 1ms keeps steal latency well under the default batch
/// window while costing an idle shard ~1k wakeups/s.
const STEAL_POLL: Duration = Duration::from_millis(1);

pub(crate) fn shard_loop(cfg: ServiceConfig, shard: usize, router: Arc<Router>) {
    let faults = arm_faults(&cfg);
    let (backend, degraded, restarts) = match supervised_build(&cfg, &faults) {
        SupervisedBuild::Ready { backend, degraded, restarts } => (backend, degraded, restarts),
        SupervisedBuild::Poisoned { msg, restarts } => {
            poison_shard(&router, shard, msg, restarts);
            return;
        }
    };

    let mut batcher = Batcher::new(cfg.policy);
    let mut metrics = Metrics::default();
    let mut breakers = BreakerBoard::new(cfg.breaker);
    let tracer_arc = cfg.tracing.clone();
    let tracer = tracer_arc.as_deref();
    let mut canon_seen: Option<HashSet<String>> = cfg.canonicalize.then(HashSet::new);
    metrics.supervisor_restarts = restarts;
    metrics.degraded = degraded;
    if let Some(d) = &metrics.degraded {
        // one line for the fleet, not one per shard; every shard still
        // carries the structured copy in its snapshot
        if shard == 0 {
            eprintln!("fkl-coordinator: {d}");
        }
    }

    let sid = shard as u64;
    let mailbox = router.mailbox(shard);
    loop {
        // 1. ingest: wait for mail, but never sleep past the batcher's
        // wake hint (window fire or member deadline) or the steal poll
        let hint = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()));
        let timeout = hint.map_or(STEAL_POLL, |h| h.min(STEAL_POLL));
        match mailbox.recv_timeout(timeout) {
            Some(ShardMsg::Request(r)) => {
                ingest(*r, &mut batcher, &mut metrics, &mut canon_seen, tracer, sid);
                // opportunistically drain whatever else is queued
                while let Some(m) = mailbox.try_recv() {
                    match m {
                        ShardMsg::Request(r) => {
                            ingest(*r, &mut batcher, &mut metrics, &mut canon_seen, tracer, sid)
                        }
                        ShardMsg::Snapshot(tx) => {
                            let _ = tx.send(shard_snapshot(
                                &mut metrics,
                                &backend,
                                &breakers,
                                &batcher,
                                mailbox.queued_requests(),
                                sid,
                            ));
                        }
                        ShardMsg::Shutdown => {
                            flush(
                                &mut batcher,
                                &backend,
                                &mut metrics,
                                &mut breakers,
                                &faults,
                                tracer,
                                sid,
                            );
                            return;
                        }
                    }
                }
            }
            Some(ShardMsg::Snapshot(tx)) => {
                let _ = tx.send(shard_snapshot(
                    &mut metrics,
                    &backend,
                    &breakers,
                    &batcher,
                    mailbox.queued_requests(),
                    sid,
                ));
            }
            Some(ShardMsg::Shutdown) => {
                flush(&mut batcher, &backend, &mut metrics, &mut breakers, &faults, tracer, sid);
                return;
            }
            None => {
                // idle (nothing batched, nothing queued): steal the older
                // half of the busiest sibling's mailbox and serve it here
                if batcher.pending() == 0 && mailbox.queued_requests() == 0 {
                    let stolen = router.steal_for(shard);
                    if !stolen.is_empty() {
                        metrics.steals += 1;
                        metrics.stolen_requests += stolen.len() as u64;
                        for r in stolen {
                            ingest(*r, &mut batcher, &mut metrics, &mut canon_seen, tracer, sid);
                        }
                    }
                }
            }
        }

        // 2. launch: identical to the single-worker scheduling window
        let now = Instant::now();
        let mut groups = Vec::new();
        while let Some(popped) = batcher.pop_ready(now) {
            expire(popped.expired, &mut metrics, tracer, sid);
            if !popped.live.is_empty() {
                groups.push(popped.live);
            }
        }
        if !groups.is_empty() {
            serve_window(groups, &backend, &mut metrics, &mut breakers, &faults, tracer, sid);
        }
    }
}

/// This shard's slice of the merged snapshot: the ordinary counters plus
/// one [`ShardStat`] row (occupancy is filled in by
/// [`MetricsSnapshot::merge`], which knows the fleet total).
fn shard_snapshot(
    metrics: &mut Metrics,
    backend: &crate::coordinator::service::Backend,
    breakers: &BreakerBoard,
    batcher: &Batcher<crate::coordinator::service::ReplyTx>,
    mailbox_queued: usize,
    sid: u64,
) -> MetricsSnapshot {
    let mut snap = snapshot(metrics, backend, breakers);
    snap.shards = vec![ShardStat {
        shard: sid,
        completed: snap.completed,
        failed: snap.failed,
        shed: snap.shed,
        expired: snap.expired,
        steals: snap.steals,
        stolen_requests: snap.stolen_requests,
        pending: (mailbox_queued + batcher.pending()) as u64,
        occupancy: 0.0,
    }];
    snap
}

/// Terminal state for a shard that never got a working backend: answer
/// every routed request with a typed error until shutdown. The other
/// shards keep serving — one poisoned shard degrades its key range, not
/// the fleet.
fn poison_shard(router: &Arc<Router>, shard: usize, msg: String, restarts: u64) {
    eprintln!("fkl-coordinator-{shard}: {msg}");
    let mailbox = router.mailbox(shard);
    loop {
        match mailbox.recv_timeout(Duration::from_millis(50)) {
            Some(ShardMsg::Request(r)) => {
                let _ = r.reply.send(Err(ServeError::Unavailable(msg.clone())));
            }
            Some(ShardMsg::Snapshot(tx)) => {
                let _ = tx.send(MetricsSnapshot {
                    supervisor_restarts: restarts,
                    degraded: Some(msg.clone()),
                    ..MetricsSnapshot::default()
                });
            }
            Some(ShardMsg::Shutdown) => return,
            None => {}
        }
    }
}
