//! Streaming coordinator: the serving face of the library.
//!
//! The paper's production deployment (AutomaticTV) feeds batches of crops
//! from live video through fused kernels. This module is that shape of
//! system: clients submit single-item pipeline requests; a dynamic batcher
//! groups compatible requests (same stream key = same generated code) within
//! a small window and the scheduler serves each window through a three-tier
//! ladder — identical requests stack into ONE horizontally-fused launch,
//! the mixed remainder (different params, signatures, chain lengths) shares
//! ONE divergent-HF pass, and a lone leftover serves per item — on the
//! service thread that owns the PJRT client.
//!
//! Design constraints it encodes:
//! * one XLA thread per process (xla_extension is not thread-safe) — the
//!   service thread owns Registry + engines; everything else passes messages;
//! * bounded request queue = backpressure;
//! * batch window/size caps = the latency/throughput trade of every dynamic
//!   batcher (vLLM-style), measured by `benches/coordinator_bench.rs`;
//! * backend selection ([`EngineSelect`]): the XLA fused engine when the
//!   artifact registry is available, the single-pass host fused engine
//!   otherwise — the service comes up and serves correctly everywhere.

mod batcher;
mod breaker;
mod hist;
mod metrics;
mod router;
mod service;
mod shard;

pub use batcher::{BatchPolicy, Batcher, PendingRequest, Popped};
pub use breaker::{
    Admission, BreakerBoard, BreakerPolicy, BreakerSnapshot, BreakerState, ServeTier,
};
pub use hist::{LogHistogram, BUCKETS};
pub use metrics::{LatencyStats, Metrics, MetricsSnapshot, ShardStat, TierTimes};
pub use service::{EngineSelect, ServeError, Service, ServiceConfig, SubmitError};
