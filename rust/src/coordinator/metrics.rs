//! Coordinator metrics: throughput, latency percentiles, fusion counters,
//! and the fault-tolerance surface (deadlines, breakers, isolated panics).

use std::time::Duration;

use crate::coordinator::BreakerSnapshot;
use crate::fusion::PlannerStats;

/// Online latency reservoir (fixed capacity, overwrite-oldest) + counters.
#[derive(Debug)]
pub struct Metrics {
    latencies_us: Vec<u64>,
    cursor: usize,
    filled: bool,
    /// Deadline-margin reservoir: remaining time at completion for requests
    /// that carried a deadline (small margins = the service is flying close
    /// to its shed threshold).
    margins_us: Vec<u64>,
    margin_cursor: usize,
    margin_filled: bool,
    pub completed: u64,
    pub rejected: u64,
    pub failed: u64,
    /// Requests dropped at pop time: their deadline passed while queued.
    pub expired: u64,
    /// Requests shed at ingress: dead on arrival, or the estimated queue
    /// delay already exceeded their deadline.
    pub shed: u64,
    /// Launch panics contained by `catch_unwind` (each poisoned exactly one
    /// launch; the service thread survived every one of them).
    pub launch_panics: u64,
    /// Backend-construction panics the supervisor absorbed by rebuilding.
    pub supervisor_restarts: u64,
    /// Structured degradation notice (e.g. Auto fell back to the host
    /// engine because the artifact registry was unavailable). Printed once
    /// to stderr when set; asserted on directly by tests and `fkl serve`.
    pub degraded: Option<String>,
    /// EWMA of per-item service cost in microseconds — the admission
    /// controller's queue-delay estimate (`pending * ewma` vs deadline).
    pub ewma_item_us: f64,
    pub launches: u64,
    pub batched_items: u64,
    pub padded_planes: u64,
    /// Launches that went down the per-op fallback path (no fused coverage)
    /// — counted separately so VF regressions show up in serving dashboards
    /// instead of hiding inside `launches`.
    pub unfused_fallbacks: u64,
    /// Windows served by the divergent-HF tier (mixed pipelines, one pass).
    pub divergent_windows: u64,
    /// Requests those windows carried.
    pub divergent_items: u64,
    /// Useful elements divergent passes touched.
    pub divergent_work_elems: u64,
    /// Idle weight of divergent passes: every lane runs as long as the
    /// heaviest, lighter lanes idle for the difference — the mixed-shape
    /// analog of `padded_planes`.
    pub divergent_padded_elems: u64,
    /// Lint diagnostics emitted at ingress (canonicalizing mode only).
    pub lints_emitted: u64,
    /// Bit-safe rewrites the ingress canonicalizer applied to admitted
    /// pipelines.
    pub rewrites_applied: u64,
    /// Admissions whose canonical form matched a previously seen canonical
    /// stream — the plan-cache wins canonicalization buys.
    pub canonical_cache_hits: u64,
    /// Per-tier serve counts copied from the engine (HF/VF coverage).
    pub planner: PlannerStats,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::with_capacity(4096)
    }
}

impl Metrics {
    pub fn with_capacity(cap: usize) -> Metrics {
        Metrics {
            latencies_us: vec![0; cap.max(1)],
            cursor: 0,
            filled: false,
            margins_us: vec![0; cap.max(1)],
            margin_cursor: 0,
            margin_filled: false,
            completed: 0,
            rejected: 0,
            failed: 0,
            expired: 0,
            shed: 0,
            launch_panics: 0,
            supervisor_restarts: 0,
            degraded: None,
            ewma_item_us: 0.0,
            launches: 0,
            batched_items: 0,
            padded_planes: 0,
            unfused_fallbacks: 0,
            divergent_windows: 0,
            divergent_items: 0,
            divergent_work_elems: 0,
            divergent_padded_elems: 0,
            lints_emitted: 0,
            rewrites_applied: 0,
            canonical_cache_hits: 0,
            planner: PlannerStats::default(),
        }
    }

    /// Record one request's queue-to-reply latency. Failed requests record
    /// too — the slow-failure tail must not vanish from the distribution —
    /// so this deliberately does NOT bump `completed` (callers count
    /// completion/failure explicitly).
    pub fn observe_latency(&mut self, d: Duration) {
        self.latencies_us[self.cursor] = d.as_micros() as u64;
        self.cursor += 1;
        if self.cursor == self.latencies_us.len() {
            self.cursor = 0;
            self.filled = true;
        }
    }

    /// Record the margin a deadline-carrying request completed with.
    pub fn observe_margin(&mut self, remaining: Duration) {
        self.margins_us[self.margin_cursor] = remaining.as_micros() as u64;
        self.margin_cursor += 1;
        if self.margin_cursor == self.margins_us.len() {
            self.margin_cursor = 0;
            self.margin_filled = true;
        }
    }

    /// Fold one launch's cost into the per-item EWMA (admission control's
    /// queue-delay estimate).
    pub fn note_service_cost(&mut self, items: usize, elapsed: Duration) {
        if items == 0 {
            return;
        }
        let per_item_us = elapsed.as_micros() as f64 / items as f64;
        self.ewma_item_us = if self.ewma_item_us == 0.0 {
            per_item_us
        } else {
            0.8 * self.ewma_item_us + 0.2 * per_item_us
        };
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let n = if self.filled { self.latencies_us.len() } else { self.cursor };
        let mut lat: Vec<u64> = self.latencies_us[..n].to_vec();
        lat.sort_unstable();
        let m = if self.margin_filled { self.margins_us.len() } else { self.margin_cursor };
        let mut margins: Vec<u64> = self.margins_us[..m].to_vec();
        margins.sort_unstable();
        MetricsSnapshot {
            completed: self.completed,
            rejected: self.rejected,
            failed: self.failed,
            expired: self.expired,
            shed: self.shed,
            launch_panics: self.launch_panics,
            supervisor_restarts: self.supervisor_restarts,
            degraded: self.degraded.clone(),
            est_item_us: self.ewma_item_us,
            launches: self.launches,
            batched_items: self.batched_items,
            padded_planes: self.padded_planes,
            unfused_fallbacks: self.unfused_fallbacks,
            divergent_windows: self.divergent_windows,
            divergent_items: self.divergent_items,
            divergent_work_elems: self.divergent_work_elems,
            divergent_padded_elems: self.divergent_padded_elems,
            lints_emitted: self.lints_emitted,
            rewrites_applied: self.rewrites_applied,
            canonical_cache_hits: self.canonical_cache_hits,
            planner: self.planner.clone(),
            latency: LatencyStats::from_sorted(&lat),
            deadline_margin: LatencyStats::from_sorted(&margins),
            breaker_trips: 0,
            breaker_rejected: 0,
            breakers: Vec::new(),
        }
    }
}

/// Latency percentiles in microseconds.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LatencyStats {
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
    pub max: u64,
    pub mean: f64,
    pub count: usize,
}

impl LatencyStats {
    pub fn from_sorted(sorted_us: &[u64]) -> LatencyStats {
        if sorted_us.is_empty() {
            return LatencyStats::default();
        }
        let n = sorted_us.len();
        let q = |p: f64| sorted_us[((n as f64 - 1.0) * p).floor() as usize];
        LatencyStats {
            p50: q(0.50),
            p95: q(0.95),
            p99: q(0.99),
            max: sorted_us[n - 1],
            mean: sorted_us.iter().sum::<u64>() as f64 / n as f64,
            count: n,
        }
    }
}

/// Point-in-time copy of all counters.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    pub completed: u64,
    pub rejected: u64,
    pub failed: u64,
    pub expired: u64,
    pub shed: u64,
    pub launch_panics: u64,
    pub supervisor_restarts: u64,
    pub degraded: Option<String>,
    /// Admission control's live per-item cost estimate (EWMA, microseconds).
    pub est_item_us: f64,
    pub launches: u64,
    pub batched_items: u64,
    pub padded_planes: u64,
    pub unfused_fallbacks: u64,
    pub divergent_windows: u64,
    pub divergent_items: u64,
    pub divergent_work_elems: u64,
    pub divergent_padded_elems: u64,
    /// Lint diagnostics emitted at ingress (canonicalizing mode only).
    pub lints_emitted: u64,
    /// Bit-safe rewrites the ingress canonicalizer applied.
    pub rewrites_applied: u64,
    /// Admissions whose canonical form matched an earlier canonical stream.
    pub canonical_cache_hits: u64,
    pub planner: PlannerStats,
    pub latency: LatencyStats,
    /// Remaining-time-at-completion distribution for deadline requests.
    pub deadline_margin: LatencyStats,
    /// Total breaker demotions across all streams.
    pub breaker_trips: u64,
    /// Total requests rejected by Open/HalfOpen breakers.
    pub breaker_rejected: u64,
    /// Every non-pristine breaker, sorted by stream key.
    pub breakers: Vec<BreakerSnapshot>,
}

impl MetricsSnapshot {
    /// Mean items per launch — the achieved HF width.
    pub fn mean_batch(&self) -> f64 {
        if self.launches == 0 {
            0.0
        } else {
            self.batched_items as f64 / self.launches as f64
        }
    }

    /// Fraction of serves with fused (single-pass) coverage, 0..=1.
    pub fn fused_coverage(&self) -> f64 {
        let total = self.planner.total();
        if total == 0 {
            1.0
        } else {
            self.planner.fused_total() as f64 / total as f64
        }
    }

    /// Mean requests per divergent window — the achieved divergent-HF width.
    pub fn mean_divergent_window(&self) -> f64 {
        if self.divergent_windows == 0 {
            0.0
        } else {
            self.divergent_items as f64 / self.divergent_windows as f64
        }
    }

    /// Occupancy of the divergent-HF tier, 0..=1: useful work over total
    /// lane time (1.0 when no divergent window has run) — the shared
    /// [`crate::fusion::occupancy_ratio`] rule.
    pub fn divergent_occupancy(&self) -> f64 {
        crate::fusion::occupancy_ratio(self.divergent_work_elems, self.divergent_padded_elems)
    }

    /// The breaker snapshot for one stream key, if that stream has ever
    /// tripped (convenience for tests and dashboards).
    pub fn breaker(&self, key: &str) -> Option<&BreakerSnapshot> {
        self.breakers.iter().find(|b| b.key == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_from_sorted() {
        let v: Vec<u64> = (1..=100).collect();
        let s = LatencyStats::from_sorted(&v);
        assert_eq!(s.p50, 50);
        assert_eq!(s.p95, 95);
        assert_eq!(s.p99, 99);
        assert_eq!(s.max, 100);
        assert!((s.mean - 50.5).abs() < 1e-9);
    }

    #[test]
    fn reservoir_wraps() {
        let mut m = Metrics::with_capacity(4);
        for i in 0..10 {
            m.observe_latency(Duration::from_micros(i));
        }
        let s = m.snapshot();
        assert_eq!(s.completed, 0, "latency observation no longer implies completion");
        assert_eq!(s.latency.count, 4, "reservoir holds last `cap` samples");
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let m = Metrics::default();
        assert_eq!(m.snapshot().latency, LatencyStats::default());
        assert_eq!(m.snapshot().deadline_margin, LatencyStats::default());
    }

    #[test]
    fn margin_reservoir_is_independent_of_latency() {
        let mut m = Metrics::with_capacity(8);
        m.observe_latency(Duration::from_micros(100));
        m.observe_margin(Duration::from_micros(40));
        m.observe_margin(Duration::from_micros(60));
        let s = m.snapshot();
        assert_eq!(s.latency.count, 1);
        assert_eq!(s.deadline_margin.count, 2);
        assert_eq!(s.deadline_margin.max, 60);
    }

    #[test]
    fn service_cost_ewma_converges_toward_observations() {
        let mut m = Metrics::default();
        m.note_service_cost(2, Duration::from_micros(200)); // 100us/item
        assert!((m.ewma_item_us - 100.0).abs() < 1e-9, "first sample seeds the EWMA");
        for _ in 0..64 {
            m.note_service_cost(1, Duration::from_micros(50));
        }
        assert!(m.ewma_item_us > 49.0 && m.ewma_item_us < 60.0, "ewma={}", m.ewma_item_us);
        m.note_service_cost(0, Duration::from_micros(999_999));
        assert!(m.ewma_item_us < 60.0, "zero-item launches never move the estimate");
    }

    #[test]
    fn fault_counters_and_degraded_surface_in_snapshot() {
        let mut m = Metrics::default();
        m.expired = 3;
        m.shed = 2;
        m.launch_panics = 1;
        m.supervisor_restarts = 4;
        m.degraded = Some("registry unavailable".into());
        let s = m.snapshot();
        assert_eq!((s.expired, s.shed, s.launch_panics, s.supervisor_restarts), (3, 2, 1, 4));
        assert_eq!(s.degraded.as_deref(), Some("registry unavailable"));
    }

    #[test]
    fn mean_batch_reports_hf_width() {
        let mut m = Metrics::default();
        m.launches = 4;
        m.batched_items = 100;
        assert_eq!(m.snapshot().mean_batch(), 25.0);
    }

    #[test]
    fn divergent_tier_metrics_surface_in_snapshot() {
        let mut m = Metrics::default();
        m.divergent_windows = 2;
        m.divergent_items = 9;
        m.divergent_work_elems = 900;
        m.divergent_padded_elems = 100;
        let s = m.snapshot();
        assert_eq!((s.divergent_windows, s.divergent_items), (2, 9));
        assert_eq!(s.mean_divergent_window(), 4.5);
        assert!((s.divergent_occupancy() - 0.9).abs() < 1e-12);
        // nothing divergent yet: occupancy defaults to 1, width to 0
        let empty = Metrics::default().snapshot();
        assert_eq!(empty.divergent_occupancy(), 1.0);
        assert_eq!(empty.mean_divergent_window(), 0.0);
    }

    #[test]
    fn fallbacks_and_planner_tiers_surface_in_snapshot() {
        let mut m = Metrics::default();
        m.unfused_fallbacks = 3;
        m.planner.exact = 6;
        m.planner.host = 1;
        m.planner.unfused = 3;
        let s = m.snapshot();
        assert_eq!(s.unfused_fallbacks, 3);
        assert_eq!(s.planner.fused_total(), 7);
        assert_eq!(s.planner.total(), 10);
        assert!((s.fused_coverage() - 0.7).abs() < 1e-12);
        // empty snapshot: coverage defaults to 1 (nothing has fallen back)
        assert_eq!(Metrics::default().snapshot().fused_coverage(), 1.0);
    }
}
