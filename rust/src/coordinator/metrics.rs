//! Coordinator metrics: throughput, latency percentiles, fusion counters,
//! fusion-efficiency byte accounting, per-tier time, and the
//! fault-tolerance surface (deadlines, breakers, isolated panics).

use std::time::Duration;

use crate::coordinator::hist::LogHistogram;
use crate::coordinator::{BreakerBoard, BreakerSnapshot};
use crate::fusion::PlannerStats;
use crate::jsonlite::Value;

/// Latency/margin histograms (log-bucketed, nothing ever dropped) + counters.
#[derive(Debug, Default)]
pub struct Metrics {
    latency: LogHistogram,
    /// Deadline-margin distribution: remaining time at completion for
    /// requests that carried a deadline (small margins = the service is
    /// flying close to its shed threshold).
    margin: LogHistogram,
    /// Wall-clock spent inside each serve tier (accumulated by the service
    /// loop around every launch; plan time is the cache probe/compile cost).
    pub tier_times: TierTimes,
    pub completed: u64,
    pub rejected: u64,
    pub failed: u64,
    /// Requests dropped at pop time: their deadline passed while queued.
    pub expired: u64,
    /// Requests shed at ingress: dead on arrival, or the estimated queue
    /// delay already exceeded their deadline.
    pub shed: u64,
    /// Launch panics contained by `catch_unwind` (each poisoned exactly one
    /// launch; the service thread survived every one of them).
    pub launch_panics: u64,
    /// Backend-construction panics the supervisor absorbed by rebuilding.
    pub supervisor_restarts: u64,
    /// Structured degradation notice (e.g. Auto fell back to the host
    /// engine because the artifact registry was unavailable). Printed once
    /// to stderr when set; asserted on directly by tests and `fkl serve`.
    pub degraded: Option<String>,
    /// EWMA of per-item service cost in microseconds — the admission
    /// controller's queue-delay estimate (`pending * ewma` vs deadline).
    pub ewma_item_us: f64,
    pub launches: u64,
    pub batched_items: u64,
    pub padded_planes: u64,
    /// Launches that went down the per-op fallback path (no fused coverage)
    /// — counted separately so VF regressions show up in serving dashboards
    /// instead of hiding inside `launches`.
    pub unfused_fallbacks: u64,
    /// Windows served by the divergent-HF tier (mixed pipelines, one pass).
    pub divergent_windows: u64,
    /// Requests those windows carried.
    pub divergent_items: u64,
    /// Useful elements divergent passes touched.
    pub divergent_work_elems: u64,
    /// Idle weight of divergent passes: every lane runs as long as the
    /// heaviest, lighter lanes idle for the difference — the mixed-shape
    /// analog of `padded_planes`.
    pub divergent_padded_elems: u64,
    /// Lint diagnostics emitted at ingress (canonicalizing mode only).
    pub lints_emitted: u64,
    /// Bit-safe rewrites the ingress canonicalizer applied to admitted
    /// pipelines.
    pub rewrites_applied: u64,
    /// Admissions whose canonical form matched a previously seen canonical
    /// stream — the plan-cache wins canonicalization buys.
    pub canonical_cache_hits: u64,
    /// Steal events: how many times THIS worker, finding itself idle, took
    /// work from a busier sibling shard (always 0 on the single-worker
    /// coordinator).
    pub steals: u64,
    /// Requests those steal events moved onto this worker.
    pub stolen_requests: u64,
    /// Per-tier serve counts copied from the engine (HF/VF coverage).
    pub planner: PlannerStats,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Record one request's queue-to-reply latency. Failed requests record
    /// too — the slow-failure tail must not vanish from the distribution —
    /// so this deliberately does NOT bump `completed` (callers count
    /// completion/failure explicitly). Backed by a log-bucketed histogram
    /// that keeps EVERY observation, so p999 reflects real 1-in-10k tails
    /// instead of whatever survived a bounded reservoir.
    pub fn observe_latency(&mut self, d: Duration) {
        self.latency.record(d.as_micros() as u64);
    }

    /// Record the margin a deadline-carrying request completed with.
    pub fn observe_margin(&mut self, remaining: Duration) {
        self.margin.record(remaining.as_micros() as u64);
    }

    /// Fold one launch's cost into the per-item EWMA (admission control's
    /// queue-delay estimate).
    pub fn note_service_cost(&mut self, items: usize, elapsed: Duration) {
        if items == 0 {
            return;
        }
        let per_item_us = elapsed.as_micros() as f64 / items as f64;
        self.ewma_item_us = if self.ewma_item_us == 0.0 {
            per_item_us
        } else {
            0.8 * self.ewma_item_us + 0.2 * per_item_us
        };
    }

    /// Point-in-time snapshot. The breaker board is part of the signature —
    /// this is the ONE seam where breaker state joins the counters, so a
    /// snapshot can never carry zero-filled breaker fields waiting for a
    /// caller to remember to patch them in.
    pub fn snapshot(&self, breakers: &BreakerBoard) -> MetricsSnapshot {
        MetricsSnapshot {
            completed: self.completed,
            rejected: self.rejected,
            failed: self.failed,
            expired: self.expired,
            shed: self.shed,
            launch_panics: self.launch_panics,
            supervisor_restarts: self.supervisor_restarts,
            degraded: self.degraded.clone(),
            est_item_us: self.ewma_item_us,
            launches: self.launches,
            batched_items: self.batched_items,
            padded_planes: self.padded_planes,
            unfused_fallbacks: self.unfused_fallbacks,
            divergent_windows: self.divergent_windows,
            divergent_items: self.divergent_items,
            divergent_work_elems: self.divergent_work_elems,
            divergent_padded_elems: self.divergent_padded_elems,
            lints_emitted: self.lints_emitted,
            rewrites_applied: self.rewrites_applied,
            canonical_cache_hits: self.canonical_cache_hits,
            steals: self.steals,
            stolen_requests: self.stolen_requests,
            bytes_read: self.planner.bytes_read,
            bytes_written: self.planner.bytes_written,
            bytes_baseline: self.planner.bytes_baseline,
            tier_time_us: self.tier_times,
            planner: self.planner.clone(),
            latency: LatencyStats::from_histogram(&self.latency),
            deadline_margin: LatencyStats::from_histogram(&self.margin),
            latency_hist: self.latency.clone(),
            margin_hist: self.margin.clone(),
            shards: Vec::new(),
            breaker_trips: breakers.trips(),
            breaker_rejected: breakers.rejected(),
            breakers: breakers.snapshot(),
        }
    }
}

/// Wall-clock microseconds the service loop spent inside each serve tier,
/// plus plan-cache probe/compile time — the per-tier breakdown of where a
/// serving window's latency went.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierTimes {
    /// Stacked-HF launches (identical requests, one launch).
    pub stacked: u64,
    /// Divergent-HF window passes (mixed signatures, one pass).
    pub divergent: u64,
    /// Per-item serves (lone leftovers, probes).
    pub per_item: u64,
    /// Plan-cache probes and compiles (hit or miss).
    pub plan: u64,
}

impl TierTimes {
    /// Total time across all tiers (µs).
    pub fn total(&self) -> u64 {
        self.stacked + self.divergent + self.per_item + self.plan
    }
}

/// Latency percentiles in microseconds.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LatencyStats {
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
    /// Meaningful because the backing histogram never drops observations —
    /// a 1-in-10k outlier survives any number of subsequent samples.
    pub p999: u64,
    pub max: u64,
    pub mean: f64,
    pub count: usize,
}

impl LatencyStats {
    /// Exact percentiles from a fully-materialized sorted sample (tests,
    /// benches — places that keep every sample anyway).
    pub fn from_sorted(sorted_us: &[u64]) -> LatencyStats {
        if sorted_us.is_empty() {
            return LatencyStats::default();
        }
        let n = sorted_us.len();
        let q = |p: f64| sorted_us[((n as f64 - 1.0) * p).floor() as usize];
        LatencyStats {
            p50: q(0.50),
            p95: q(0.95),
            p99: q(0.99),
            p999: q(0.999),
            max: sorted_us[n - 1],
            mean: sorted_us.iter().sum::<u64>() as f64 / n as f64,
            count: n,
        }
    }

    /// Percentiles at histogram (√2-bucket) resolution; max/mean/count are
    /// exact. Same rank rule as [`LatencyStats::from_sorted`].
    pub fn from_histogram(h: &LogHistogram) -> LatencyStats {
        if h.count() == 0 {
            return LatencyStats::default();
        }
        LatencyStats {
            p50: h.quantile(0.50),
            p95: h.quantile(0.95),
            p99: h.quantile(0.99),
            p999: h.quantile(0.999),
            max: h.max(),
            mean: h.mean(),
            count: h.count() as usize,
        }
    }

    fn to_json(self) -> Value {
        Value::obj(vec![
            ("p50", Value::num(self.p50 as f64)),
            ("p95", Value::num(self.p95 as f64)),
            ("p99", Value::num(self.p99 as f64)),
            ("p999", Value::num(self.p999 as f64)),
            ("max", Value::num(self.max as f64)),
            ("mean", Value::num(self.mean)),
            ("count", Value::num(self.count as f64)),
        ])
    }
}

/// Point-in-time copy of all counters.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    pub completed: u64,
    pub rejected: u64,
    pub failed: u64,
    pub expired: u64,
    pub shed: u64,
    pub launch_panics: u64,
    pub supervisor_restarts: u64,
    pub degraded: Option<String>,
    /// Admission control's live per-item cost estimate (EWMA, microseconds).
    pub est_item_us: f64,
    pub launches: u64,
    pub batched_items: u64,
    pub padded_planes: u64,
    pub unfused_fallbacks: u64,
    pub divergent_windows: u64,
    pub divergent_items: u64,
    pub divergent_work_elems: u64,
    pub divergent_padded_elems: u64,
    /// Lint diagnostics emitted at ingress (canonicalizing mode only).
    pub lints_emitted: u64,
    /// Bit-safe rewrites the ingress canonicalizer applied.
    pub rewrites_applied: u64,
    /// Admissions whose canonical form matched an earlier canonical stream.
    pub canonical_cache_hits: u64,
    /// Work-steal events across all workers (0 on the single coordinator).
    pub steals: u64,
    /// Requests moved between shards by those steals.
    pub stolen_requests: u64,
    /// Bytes the fused passes actually read (host-plan byte model).
    pub bytes_read: u64,
    /// Bytes the fused passes actually wrote.
    pub bytes_written: u64,
    /// Bytes an op-at-a-time execution of the same traffic would have moved.
    pub bytes_baseline: u64,
    /// Wall-clock spent per serve tier (µs).
    pub tier_time_us: TierTimes,
    pub planner: PlannerStats,
    pub latency: LatencyStats,
    /// Remaining-time-at-completion distribution for deadline requests.
    pub deadline_margin: LatencyStats,
    /// The full latency histogram behind `latency` — carried so shard
    /// snapshots merge EXACTLY (bucket-wise) instead of averaging
    /// percentiles, which is statistically meaningless.
    pub latency_hist: LogHistogram,
    /// The full histogram behind `deadline_margin` (same reason).
    pub margin_hist: LogHistogram,
    /// Per-shard rows, one per worker (empty on the single-worker
    /// coordinator; filled by the shard snapshot path and finalized —
    /// occupancy, sort order — by [`MetricsSnapshot::merge`]).
    pub shards: Vec<ShardStat>,
    /// Total breaker demotions across all streams.
    pub breaker_trips: u64,
    /// Total requests rejected by Open/HalfOpen breakers.
    pub breaker_rejected: u64,
    /// Every non-pristine breaker, sorted by stream key.
    pub breakers: Vec<BreakerSnapshot>,
}

/// One shard's row in a merged [`MetricsSnapshot`]: outcome counters,
/// steal activity, and load gauges for THAT worker — imbalance and steal
/// traffic stay visible after the counters sum.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ShardStat {
    /// Worker index (also the `shard` arg on its trace request-roots).
    pub shard: u64,
    pub completed: u64,
    pub failed: u64,
    pub shed: u64,
    pub expired: u64,
    /// Steal events THIS shard performed (it was the idle thief).
    pub steals: u64,
    /// Requests it took from siblings across those steals.
    pub stolen_requests: u64,
    /// Queued work at snapshot time: mailbox backlog + batcher pending.
    pub pending: u64,
    /// This shard's share of all completed requests, 0..=1 (0 before any
    /// traffic). Filled by [`MetricsSnapshot::merge`].
    pub occupancy: f64,
}

impl MetricsSnapshot {
    /// Merge per-shard snapshots into one fleet view. Counters sum;
    /// histograms merge bucket-wise and the percentile stats are recomputed
    /// from the merged histograms (exact — never an average of averages);
    /// `est_item_us` is the completion-weighted mean of the shard
    /// estimates; per-stream breaker rows concatenate (each shard runs its
    /// own board, so one stream key may appear once per shard); shard rows
    /// concatenate, get their occupancy share, and sort by shard id.
    pub fn merge(parts: Vec<MetricsSnapshot>) -> MetricsSnapshot {
        let mut it = parts.into_iter();
        let Some(mut out) = it.next() else {
            return MetricsSnapshot::default();
        };
        for p in it {
            out.completed += p.completed;
            out.rejected += p.rejected;
            out.failed += p.failed;
            out.expired += p.expired;
            out.shed += p.shed;
            out.launch_panics += p.launch_panics;
            out.supervisor_restarts += p.supervisor_restarts;
            if out.degraded.is_none() {
                out.degraded = p.degraded;
            }
            // completion-weighted blend; a shard that served nothing must
            // not drag the fleet estimate toward zero
            let (wa, wb) = (out.completed - p.completed, p.completed);
            if wa + wb > 0 {
                out.est_item_us = (out.est_item_us * wa as f64 + p.est_item_us * wb as f64)
                    / (wa + wb) as f64;
            }
            out.launches += p.launches;
            out.batched_items += p.batched_items;
            out.padded_planes += p.padded_planes;
            out.unfused_fallbacks += p.unfused_fallbacks;
            out.divergent_windows += p.divergent_windows;
            out.divergent_items += p.divergent_items;
            out.divergent_work_elems += p.divergent_work_elems;
            out.divergent_padded_elems += p.divergent_padded_elems;
            out.lints_emitted += p.lints_emitted;
            out.rewrites_applied += p.rewrites_applied;
            out.canonical_cache_hits += p.canonical_cache_hits;
            out.steals += p.steals;
            out.stolen_requests += p.stolen_requests;
            out.bytes_read += p.bytes_read;
            out.bytes_written += p.bytes_written;
            out.bytes_baseline += p.bytes_baseline;
            out.tier_time_us.stacked += p.tier_time_us.stacked;
            out.tier_time_us.divergent += p.tier_time_us.divergent;
            out.tier_time_us.per_item += p.tier_time_us.per_item;
            out.tier_time_us.plan += p.tier_time_us.plan;
            out.planner.exact += p.planner.exact;
            out.planner.staticloop += p.planner.staticloop;
            out.planner.interp += p.planner.interp;
            out.planner.unfused += p.planner.unfused;
            out.planner.host += p.planner.host;
            out.planner.unsupported += p.planner.unsupported;
            out.planner.structured += p.planner.structured;
            out.planner.reduction += p.planner.reduction;
            out.planner.divergent += p.planner.divergent;
            out.planner.plan_cache += p.planner.plan_cache;
            out.planner.vectorized += p.planner.vectorized;
            out.planner.vector_width = out.planner.vector_width.max(p.planner.vector_width);
            out.planner.bytes_read += p.planner.bytes_read;
            out.planner.bytes_written += p.planner.bytes_written;
            out.planner.bytes_baseline += p.planner.bytes_baseline;
            out.latency_hist.merge(&p.latency_hist);
            out.margin_hist.merge(&p.margin_hist);
            out.breaker_trips += p.breaker_trips;
            out.breaker_rejected += p.breaker_rejected;
            out.breakers.extend(p.breakers);
            out.shards.extend(p.shards);
        }
        out.latency = LatencyStats::from_histogram(&out.latency_hist);
        out.deadline_margin = LatencyStats::from_histogram(&out.margin_hist);
        out.breakers.sort_by(|a, b| a.key.cmp(&b.key));
        for s in &mut out.shards {
            s.occupancy = if out.completed == 0 {
                0.0
            } else {
                s.completed as f64 / out.completed as f64
            };
        }
        out.shards.sort_by_key(|s| s.shard);
        out
    }

    /// Mean items per launch — the achieved HF width.
    pub fn mean_batch(&self) -> f64 {
        if self.launches == 0 {
            0.0
        } else {
            self.batched_items as f64 / self.launches as f64
        }
    }

    /// Fraction of serves with fused (single-pass) coverage, 0..=1.
    pub fn fused_coverage(&self) -> f64 {
        let total = self.planner.total();
        if total == 0 {
            1.0
        } else {
            self.planner.fused_total() as f64 / total as f64
        }
    }

    /// Mean requests per divergent window — the achieved divergent-HF width.
    pub fn mean_divergent_window(&self) -> f64 {
        if self.divergent_windows == 0 {
            0.0
        } else {
            self.divergent_items as f64 / self.divergent_windows as f64
        }
    }

    /// Occupancy of the divergent-HF tier, 0..=1: useful work over total
    /// lane time (1.0 when no divergent window has run) — the shared
    /// [`crate::fusion::occupancy_ratio`] rule.
    pub fn divergent_occupancy(&self) -> f64 {
        crate::fusion::occupancy_ratio(self.divergent_work_elems, self.divergent_padded_elems)
    }

    /// Measured fusion efficiency: bytes an op-at-a-time baseline would
    /// have moved over bytes the fused passes actually moved. ≈(k+1)/2 for
    /// a same-width dense chain of k ops (each fused pass moves 2n bytes
    /// where the baseline moves (k+1)n); 1.0 before any traffic.
    pub fn fusion_efficiency(&self) -> f64 {
        let actual = self.bytes_read + self.bytes_written;
        if actual == 0 {
            1.0
        } else {
            self.bytes_baseline as f64 / actual as f64
        }
    }

    /// The breaker snapshot for one stream key, if that stream has ever
    /// tripped (convenience for tests and dashboards).
    pub fn breaker(&self, key: &str) -> Option<&BreakerSnapshot> {
        self.breakers.iter().find(|b| b.key == key)
    }

    /// Machine-readable export: every counter, the latency/margin stats,
    /// per-tier time, byte accounting, planner tiers and breakers as one
    /// jsonlite object (`fkl serve --metrics-json`, `fkl metrics --demo`).
    pub fn to_json(&self) -> Value {
        let n = |v: u64| Value::num(v as f64);
        let breakers: Vec<Value> = self
            .breakers
            .iter()
            .map(|b| {
                Value::obj(vec![
                    ("key", Value::str(&b.key)),
                    ("state", Value::str(&format!("{:?}", b.state))),
                    ("tier", Value::str(&format!("{:?}", b.tier))),
                    ("consecutive_failures", Value::num(b.consecutive_failures as f64)),
                    ("trips", n(b.trips)),
                    ("rejected", n(b.rejected)),
                ])
            })
            .collect();
        Value::obj(vec![
            ("completed", n(self.completed)),
            ("rejected", n(self.rejected)),
            ("failed", n(self.failed)),
            ("expired", n(self.expired)),
            ("shed", n(self.shed)),
            ("launch_panics", n(self.launch_panics)),
            ("supervisor_restarts", n(self.supervisor_restarts)),
            (
                "degraded",
                match &self.degraded {
                    Some(msg) => Value::str(msg),
                    None => Value::Null,
                },
            ),
            ("est_item_us", Value::num(self.est_item_us)),
            ("launches", n(self.launches)),
            ("batched_items", n(self.batched_items)),
            ("padded_planes", n(self.padded_planes)),
            ("unfused_fallbacks", n(self.unfused_fallbacks)),
            ("divergent_windows", n(self.divergent_windows)),
            ("divergent_items", n(self.divergent_items)),
            ("divergent_work_elems", n(self.divergent_work_elems)),
            ("divergent_padded_elems", n(self.divergent_padded_elems)),
            ("lints_emitted", n(self.lints_emitted)),
            ("rewrites_applied", n(self.rewrites_applied)),
            ("canonical_cache_hits", n(self.canonical_cache_hits)),
            ("steals", n(self.steals)),
            ("stolen_requests", n(self.stolen_requests)),
            ("bytes_read", n(self.bytes_read)),
            ("bytes_written", n(self.bytes_written)),
            ("bytes_baseline", n(self.bytes_baseline)),
            ("fusion_efficiency", Value::num(self.fusion_efficiency())),
            ("mean_batch", Value::num(self.mean_batch())),
            ("fused_coverage", Value::num(self.fused_coverage())),
            ("divergent_occupancy", Value::num(self.divergent_occupancy())),
            (
                "tier_time_us",
                Value::obj(vec![
                    ("stacked", n(self.tier_time_us.stacked)),
                    ("divergent", n(self.tier_time_us.divergent)),
                    ("per_item", n(self.tier_time_us.per_item)),
                    ("plan", n(self.tier_time_us.plan)),
                ]),
            ),
            ("latency_us", self.latency.to_json()),
            ("deadline_margin_us", self.deadline_margin.to_json()),
            (
                "planner",
                Value::obj(vec![
                    ("exact", Value::num(self.planner.exact as f64)),
                    ("staticloop", Value::num(self.planner.staticloop as f64)),
                    ("interp", Value::num(self.planner.interp as f64)),
                    ("unfused", Value::num(self.planner.unfused as f64)),
                    ("host", Value::num(self.planner.host as f64)),
                    ("unsupported", Value::num(self.planner.unsupported as f64)),
                    ("structured", Value::num(self.planner.structured as f64)),
                    ("reduction", Value::num(self.planner.reduction as f64)),
                    ("divergent", Value::num(self.planner.divergent as f64)),
                    ("plan_cache", Value::num(self.planner.plan_cache as f64)),
                    ("vectorized", Value::num(self.planner.vectorized as f64)),
                    ("vector_width", Value::num(self.planner.vector_width as f64)),
                ]),
            ),
            ("breaker_trips", n(self.breaker_trips)),
            ("breaker_rejected", n(self.breaker_rejected)),
            ("breakers", Value::Arr(breakers)),
            (
                "shards",
                Value::Arr(
                    self.shards
                        .iter()
                        .map(|s| {
                            Value::obj(vec![
                                ("shard", n(s.shard)),
                                ("completed", n(s.completed)),
                                ("failed", n(s.failed)),
                                ("shed", n(s.shed)),
                                ("expired", n(s.expired)),
                                ("steals", n(s.steals)),
                                ("stolen_requests", n(s.stolen_requests)),
                                ("pending", n(s.pending)),
                                ("occupancy", Value::num(s.occupancy)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::BreakerBoard;

    fn board() -> BreakerBoard {
        BreakerBoard::new(crate::coordinator::BreakerPolicy::default())
    }

    #[test]
    fn percentiles_from_sorted() {
        let v: Vec<u64> = (1..=100).collect();
        let s = LatencyStats::from_sorted(&v);
        assert_eq!(s.p50, 50);
        assert_eq!(s.p95, 95);
        assert_eq!(s.p99, 99);
        assert_eq!(s.p999, 99, "floor((n-1)·q) rank rule");
        assert_eq!(s.max, 100);
        assert!((s.mean - 50.5).abs() < 1e-9);
    }

    #[test]
    fn histogram_never_drops_observations() {
        // the reservoir this replaced capped at `cap` samples; the
        // histogram counts everything
        let mut m = Metrics::default();
        for i in 0..10 {
            m.observe_latency(Duration::from_micros(i));
        }
        let s = m.snapshot(&board());
        assert_eq!(s.completed, 0, "latency observation no longer implies completion");
        assert_eq!(s.latency.count, 10, "every observation is retained");
    }

    #[test]
    fn outlier_survives_sustained_load_through_public_path() {
        // satellite regression: a 1-in-10k tail must survive 100k
        // observations THROUGH Metrics (not just the raw histogram)
        let mut m = Metrics::default();
        for i in 0..100_000u64 {
            m.observe_latency(Duration::from_micros(if i % 10_000 == 0 { 1_000_000 } else { 50 }));
        }
        let s = m.snapshot(&board());
        assert_eq!(s.latency.count, 100_000);
        assert_eq!(s.latency.max, 1_000_000, "outlier visible after 100k samples");
        assert!(s.latency.p999 <= 64, "10 outliers sit above p999");
        assert!(s.latency.p50 >= 32 && s.latency.p50 <= 50);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let m = Metrics::default();
        assert_eq!(m.snapshot(&board()).latency, LatencyStats::default());
        assert_eq!(m.snapshot(&board()).deadline_margin, LatencyStats::default());
    }

    #[test]
    fn margin_histogram_is_independent_of_latency() {
        let mut m = Metrics::default();
        m.observe_latency(Duration::from_micros(100));
        m.observe_margin(Duration::from_micros(40));
        m.observe_margin(Duration::from_micros(60));
        let s = m.snapshot(&board());
        assert_eq!(s.latency.count, 1);
        assert_eq!(s.deadline_margin.count, 2);
        assert_eq!(s.deadline_margin.max, 60);
    }

    #[test]
    fn service_cost_ewma_converges_toward_observations() {
        let mut m = Metrics::default();
        m.note_service_cost(2, Duration::from_micros(200)); // 100us/item
        assert!((m.ewma_item_us - 100.0).abs() < 1e-9, "first sample seeds the EWMA");
        for _ in 0..64 {
            m.note_service_cost(1, Duration::from_micros(50));
        }
        assert!(m.ewma_item_us > 49.0 && m.ewma_item_us < 60.0, "ewma={}", m.ewma_item_us);
        m.note_service_cost(0, Duration::from_micros(999_999));
        assert!(m.ewma_item_us < 60.0, "zero-item launches never move the estimate");
    }

    #[test]
    fn fault_counters_and_degraded_surface_in_snapshot() {
        let mut m = Metrics::default();
        m.expired = 3;
        m.shed = 2;
        m.launch_panics = 1;
        m.supervisor_restarts = 4;
        m.degraded = Some("registry unavailable".into());
        let s = m.snapshot(&board());
        assert_eq!((s.expired, s.shed, s.launch_panics, s.supervisor_restarts), (3, 2, 1, 4));
        assert_eq!(s.degraded.as_deref(), Some("registry unavailable"));
    }

    #[test]
    fn breaker_state_joins_through_the_snapshot_seam() {
        use crate::coordinator::BreakerPolicy;
        // drive a board to a trip through its public API, then check the
        // snapshot carries the breaker fields WITHOUT any caller patching
        let mut b = BreakerBoard::new(BreakerPolicy {
            failure_threshold: 2,
            ..BreakerPolicy::default()
        });
        b.admit("s");
        b.record_failure("s");
        b.admit("s");
        b.record_failure("s");
        b.note_rejected("s", 3);
        assert!(b.trips() >= 1, "two failures at threshold 2 demote");
        let m = Metrics::default();
        let s = m.snapshot(&b);
        assert_eq!(s.breaker_trips, b.trips());
        assert!(s.breaker_trips >= 1, "trip visible through Metrics::snapshot");
        assert_eq!(s.breaker_rejected, 3);
        assert!(s.breaker("s").is_some(), "per-stream snapshot rides along");
    }

    #[test]
    fn mean_batch_reports_hf_width() {
        let mut m = Metrics::default();
        m.launches = 4;
        m.batched_items = 100;
        assert_eq!(m.snapshot(&board()).mean_batch(), 25.0);
    }

    #[test]
    fn divergent_tier_metrics_surface_in_snapshot() {
        let mut m = Metrics::default();
        m.divergent_windows = 2;
        m.divergent_items = 9;
        m.divergent_work_elems = 900;
        m.divergent_padded_elems = 100;
        let s = m.snapshot(&board());
        assert_eq!((s.divergent_windows, s.divergent_items), (2, 9));
        assert_eq!(s.mean_divergent_window(), 4.5);
        assert!((s.divergent_occupancy() - 0.9).abs() < 1e-12);
        // nothing divergent yet: occupancy defaults to 1, width to 0
        let empty = Metrics::default().snapshot(&board());
        assert_eq!(empty.divergent_occupancy(), 1.0);
        assert_eq!(empty.mean_divergent_window(), 0.0);
    }

    #[test]
    fn fallbacks_and_planner_tiers_surface_in_snapshot() {
        let mut m = Metrics::default();
        m.unfused_fallbacks = 3;
        m.planner.exact = 6;
        m.planner.host = 1;
        m.planner.unfused = 3;
        let s = m.snapshot(&board());
        assert_eq!(s.unfused_fallbacks, 3);
        assert_eq!(s.planner.fused_total(), 7);
        assert_eq!(s.planner.total(), 10);
        assert!((s.fused_coverage() - 0.7).abs() < 1e-12);
        // empty snapshot: coverage defaults to 1 (nothing has fallen back)
        assert_eq!(Metrics::default().snapshot(&board()).fused_coverage(), 1.0);
    }

    #[test]
    fn fusion_efficiency_is_baseline_over_actual() {
        let mut m = Metrics::default();
        // chain-5 dense f32: baseline 6n, fused 2n -> 3.0
        m.planner.bytes_read = 1000;
        m.planner.bytes_written = 1000;
        m.planner.bytes_baseline = 6000;
        let s = m.snapshot(&board());
        assert_eq!((s.bytes_read, s.bytes_written, s.bytes_baseline), (1000, 1000, 6000));
        assert!((s.fusion_efficiency() - 3.0).abs() < 1e-12);
        // no traffic: ratio reads 1.0, not NaN
        assert_eq!(Metrics::default().snapshot(&board()).fusion_efficiency(), 1.0);
    }

    #[test]
    fn merge_sums_counters_and_merges_histograms_exactly() {
        let mk = |completed: u64, lat_us: &[u64]| {
            let mut m = Metrics::default();
            m.completed = completed;
            m.failed = 1;
            m.launches = 2;
            m.batched_items = completed;
            m.steals = 1;
            m.stolen_requests = 3;
            m.planner.host = completed as usize;
            m.planner.vector_width = if completed > 4 { 16 } else { 8 };
            m.tier_times.stacked = 10;
            for &us in lat_us {
                m.observe_latency(Duration::from_micros(us));
            }
            m.snapshot(&board())
        };
        let a = mk(4, &[100, 100, 100, 100]);
        let b = mk(8, &[1_000_000; 8]);
        let merged = MetricsSnapshot::merge(vec![a.clone(), b]);
        assert_eq!(merged.completed, 12);
        assert_eq!(merged.failed, 2);
        assert_eq!(merged.launches, 4);
        assert_eq!((merged.steals, merged.stolen_requests), (2, 6));
        assert_eq!(merged.planner.host, 12);
        assert_eq!(merged.planner.vector_width, 16, "gauge takes the max");
        assert_eq!(merged.tier_time_us.stacked, 20);
        // histogram merge is exact: all 12 observations, true max, and the
        // p50 sits in the slow shard's range (8 of 12 samples are slow)
        assert_eq!(merged.latency.count, 12);
        assert_eq!(merged.latency.max, 1_000_000);
        assert!(merged.latency.p50 >= 500_000, "p50={}", merged.latency.p50);
        // single-part and empty merges are identity-shaped
        assert_eq!(MetricsSnapshot::merge(vec![a.clone()]).completed, a.completed);
        assert_eq!(MetricsSnapshot::merge(Vec::new()).completed, 0);
    }

    #[test]
    fn merge_fills_shard_occupancy_and_sorts_rows() {
        let row = |shard: u64, completed: u64| {
            let mut m = Metrics::default();
            m.completed = completed;
            let mut s = m.snapshot(&board());
            s.shards = vec![ShardStat { shard, completed, ..ShardStat::default() }];
            s
        };
        let merged = MetricsSnapshot::merge(vec![row(2, 6), row(0, 2), row(1, 0)]);
        assert_eq!(merged.shards.len(), 3);
        assert_eq!(
            merged.shards.iter().map(|s| s.shard).collect::<Vec<_>>(),
            vec![0, 1, 2],
            "rows sort by shard id"
        );
        let occ: Vec<f64> = merged.shards.iter().map(|s| s.occupancy).collect();
        assert!((occ[0] - 0.25).abs() < 1e-12);
        assert!((occ[1] - 0.0).abs() < 1e-12);
        assert!((occ[2] - 0.75).abs() < 1e-12);
        assert!((occ.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert_eq!(
            merged.shards.iter().map(|s| s.completed).sum::<u64>(),
            merged.completed,
            "shard rows account for every completion"
        );
    }

    #[test]
    fn merge_weights_est_item_us_by_completions() {
        let part = |completed: u64, est: f64| {
            let mut m = Metrics::default();
            m.completed = completed;
            m.ewma_item_us = est;
            m.snapshot(&board())
        };
        let merged = MetricsSnapshot::merge(vec![part(3, 100.0), part(1, 500.0)]);
        assert!((merged.est_item_us - 200.0).abs() < 1e-9, "est={}", merged.est_item_us);
        // an idle shard (no completions) leaves the estimate alone
        let merged = MetricsSnapshot::merge(vec![part(2, 80.0), part(0, 0.0)]);
        assert!((merged.est_item_us - 80.0).abs() < 1e-9);
    }

    #[test]
    fn merged_shards_surface_in_json() {
        let mut s = Metrics::default().snapshot(&board());
        s.shards = vec![ShardStat { shard: 1, completed: 5, pending: 2, ..ShardStat::default() }];
        let text = s.to_json().to_json();
        let v = crate::jsonlite::parse(&text).expect("metrics JSON parses");
        assert_eq!(v["shards"][0]["shard"].as_f64(), Some(1.0));
        assert_eq!(v["shards"][0]["completed"].as_f64(), Some(5.0));
        assert_eq!(v["shards"][0]["pending"].as_f64(), Some(2.0));
        assert_eq!(v["steals"].as_f64(), Some(0.0));
    }

    #[test]
    fn snapshot_round_trips_through_json() {
        let mut m = Metrics::default();
        m.completed = 7;
        m.shed = 1;
        m.planner.bytes_read = 10;
        m.planner.bytes_written = 10;
        m.planner.bytes_baseline = 50;
        m.tier_times.stacked = 120;
        m.tier_times.plan = 30;
        m.observe_latency(Duration::from_micros(500));
        let s = m.snapshot(&board());
        let text = s.to_json().to_json();
        let v = crate::jsonlite::parse(&text).expect("metrics JSON parses");
        assert_eq!(v["completed"].as_f64(), Some(7.0));
        assert_eq!(v["shed"].as_f64(), Some(1.0));
        assert_eq!(v["bytes_baseline"].as_f64(), Some(50.0));
        assert_eq!(v["fusion_efficiency"].as_f64(), Some(2.5));
        assert_eq!(v["tier_time_us"]["stacked"].as_f64(), Some(120.0));
        assert_eq!(v["tier_time_us"]["plan"].as_f64(), Some(30.0));
        assert_eq!(v["latency_us"]["count"].as_f64(), Some(1.0));
        assert_eq!(v["latency_us"]["max"].as_f64(), Some(500.0));
        assert!(v["latency_us"]["p999"].as_f64().is_some());
        assert_eq!(v["degraded"], crate::jsonlite::Value::Null);
    }
}
