//! Dynamic HF batcher: groups same-signature requests into bucket launches.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::ops::Pipeline;
use crate::tensor::Tensor;

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Max items fused into one launch (clamped to available buckets).
    pub max_batch: usize,
    /// How long the first request of a group may wait for company.
    pub window: Duration,
    /// Lead time subtracted from member deadlines when deciding wake and
    /// pop instants. A group pops once ANY member is within `deadline_slack`
    /// of its deadline, so the launch starts *before* the deadline passes
    /// (covering scheduler wake + launch setup) instead of exactly at it —
    /// which would split the member into the expired half.
    pub deadline_slack: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 64,
            window: Duration::from_micros(500),
            deadline_slack: Duration::from_micros(150),
        }
    }
}

/// A queued request: one item of a single-item pipeline plus its reply slot.
pub struct PendingRequest<R> {
    pub pipeline: Pipeline,
    pub item: Tensor,
    pub enqueued: Instant,
    /// Serve-by instant. A request whose deadline has passed when its group
    /// pops is dropped with a typed `Expired` reply instead of being served
    /// after its usefulness expired (the paper's framing: drop frames
    /// rather than lag). `None` = serve whenever.
    pub deadline: Option<Instant>,
    pub reply: R,
    /// Request id in the armed [`crate::trace::Tracer`]'s span space
    /// (0 = tracing off / untraced request). Assigned at ingest.
    pub trace_id: u64,
    /// Breaker verdict code for this request's group
    /// ([`crate::trace::TIER_STACKED`]-family), recorded by the scheduler
    /// so the `tier` span can report WHY a tier was chosen. Only meaningful
    /// when `trace_id != 0`.
    pub trace_verdict: u64,
    /// When ingest finished admitting this request — the boundary between
    /// its `admit` and `queue` spans. Equal to `enqueued` for untraced
    /// requests.
    pub admitted: Instant,
}

impl<R> PendingRequest<R> {
    /// Has this request's deadline passed at `now`? (A deadline exactly at
    /// `now` counts as expired — makes zero-duration deadlines
    /// deterministic under test.)
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// One popped group, split at pop time: `live` still has time to serve,
/// `expired` must be answered with the typed `Expired` error.
pub struct Popped<R> {
    pub live: Vec<PendingRequest<R>>,
    pub expired: Vec<PendingRequest<R>>,
}

/// Accumulates pending requests per stream key and decides when a group is
/// ready to launch. Pure data structure — no XLA, fully unit-testable.
pub struct Batcher<R> {
    queues: HashMap<String, Vec<PendingRequest<R>>>,
    policy: BatchPolicy,
}

impl<R> Batcher<R> {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher { queues: HashMap::new(), policy }
    }

    pub fn push(&mut self, req: PendingRequest<R>) {
        let key = crate::ops::Signature::of(&req.pipeline).stream_key();
        self.queues.entry(key).or_default().push(req);
    }

    pub fn pending(&self) -> usize {
        self.queues.values().map(Vec::len).sum()
    }

    /// Pop the next group that is ready: full (>= max_batch), aged past the
    /// window, or holding a member whose deadline is within
    /// `deadline_slack` of `now` (serve it NOW or answer `Expired` later).
    /// Requests come out in arrival order (FIFO within a stream), split
    /// into live and deadline-expired halves at pop time.
    pub fn pop_ready(&mut self, now: Instant) -> Option<Popped<R>> {
        let policy = self.policy;
        let key = self
            .queues
            .iter()
            .filter(|(_, q)| {
                !q.is_empty()
                    && (q.len() >= policy.max_batch
                        || now.duration_since(q[0].enqueued) >= policy.window
                        || q.iter().any(|r| deadline_due(r, now, policy.deadline_slack)))
            })
            // oldest head first: fairness across streams
            .min_by_key(|(_, q)| q[0].enqueued)
            .map(|(k, _)| k.clone())?;
        let q = self.queues.get_mut(&key).unwrap();
        let take = q.len().min(policy.max_batch);
        let (live, expired): (Vec<_>, Vec<_>) =
            q.drain(..take).partition(|r| !r.expired(now));
        if q.is_empty() {
            self.queues.remove(&key);
        }
        Some(Popped { live, expired })
    }

    /// Pop everything regardless of readiness (drain on shutdown). Expired
    /// requests are split out group by group, exactly like
    /// [`Batcher::pop_ready`] — shutdown resolves EVERY reply, it never
    /// serves stale work.
    pub fn drain_all(&mut self, now: Instant) -> Vec<Popped<R>> {
        let mut out = Vec::new();
        for (_, mut q) in self.queues.drain() {
            while !q.is_empty() {
                let take = q.len().min(self.policy.max_batch);
                let (live, expired): (Vec<_>, Vec<_>) =
                    q.drain(..take).partition(|r| !r.expired(now));
                out.push(Popped { live, expired });
            }
        }
        out
    }

    /// Earliest instant at which any group becomes ready (service loop
    /// sleep hint): the minimum over every stream's window fire
    /// (`head.enqueued + window`) AND every member's deadline minus
    /// `deadline_slack`. A loop that sleeps past this instant would let a
    /// member's deadline lapse inside the batcher — the deadline-blind bug
    /// this replaces woke only at window fires, so any deadline shorter
    /// than the window expired even on an idle service.
    pub fn next_deadline(&self) -> Option<Instant> {
        let slack = self.policy.deadline_slack;
        self.queues
            .values()
            .flat_map(|q| {
                let window_fire = q.first().map(|r| r.enqueued + self.policy.window);
                let deadline_fire = q
                    .iter()
                    .filter_map(|r| {
                        r.deadline.map(|d| d.checked_sub(slack).unwrap_or(r.enqueued))
                    })
                    .min();
                window_fire.into_iter().chain(deadline_fire)
            })
            .min()
    }
}

/// Is `req`'s deadline within `slack` of `now` (or already past)? Such a
/// request must be popped immediately: waiting any longer either serves it
/// dangerously late or expires it outright.
fn deadline_due<R>(req: &PendingRequest<R>, now: Instant, slack: Duration) -> bool {
    req.deadline.is_some_and(|d| now + slack >= d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{Opcode, Pipeline};
    use crate::tensor::{DType, Tensor};

    fn req(mul: f64, tag: u32) -> PendingRequest<u32> {
        let pipeline = Pipeline::from_opcodes(
            &[(Opcode::Mul, mul)],
            &[2, 2],
            1,
            DType::F32,
            DType::F32,
        )
        .unwrap();
        let enqueued = Instant::now();
        PendingRequest {
            pipeline,
            item: Tensor::from_f32(&[0.0; 4], &[1, 2, 2]),
            enqueued,
            deadline: None,
            reply: tag,
            trace_id: 0,
            trace_verdict: 0,
            admitted: enqueued,
        }
    }

    fn req_deadline(mul: f64, tag: u32, deadline: Duration) -> PendingRequest<u32> {
        let mut r = req(mul, tag);
        r.deadline = Some(r.enqueued + deadline);
        r
    }

    #[test]
    fn groups_by_stream_key_not_params() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 10, window: Duration::ZERO, ..Default::default() });
        b.push(req(1.0, 0));
        b.push(req(99.0, 1)); // different param, same code
        let g = b.pop_ready(Instant::now()).unwrap();
        assert_eq!(g.live.len(), 2);
        assert!(g.expired.is_empty());
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn full_batch_fires_before_window() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, window: Duration::from_secs(60), ..Default::default() });
        b.push(req(1.0, 0));
        assert!(b.pop_ready(Instant::now()).is_none(), "waits for window/company");
        b.push(req(1.0, 1));
        assert_eq!(b.pop_ready(Instant::now()).unwrap().live.len(), 2);
    }

    #[test]
    fn window_expiry_fires_partial_batch() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, window: Duration::from_millis(1), ..Default::default() });
        b.push(req(1.0, 0));
        let later = Instant::now() + Duration::from_millis(5);
        assert_eq!(b.pop_ready(later).unwrap().live.len(), 1);
    }

    #[test]
    fn fifo_within_stream_and_no_loss() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, window: Duration::ZERO, ..Default::default() });
        for i in 0..7 {
            b.push(req(1.0, i));
        }
        let mut seen = Vec::new();
        while let Some(g) = b.pop_ready(Instant::now()) {
            assert!(g.live.len() <= 3);
            seen.extend(g.live.iter().map(|r| r.reply));
        }
        assert_eq!(seen, (0..7).collect::<Vec<_>>(), "FIFO, nothing lost or duplicated");
    }

    #[test]
    fn drain_all_empties() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 4, window: Duration::from_secs(9), ..Default::default() });
        for i in 0..9 {
            b.push(req(1.0, i));
        }
        let groups = b.drain_all(Instant::now());
        let total: usize = groups.iter().map(|g| g.live.len() + g.expired.len()).sum();
        assert_eq!(total, 9);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn deadline_expired_requests_split_out_at_pop_time() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 10, window: Duration::ZERO, ..Default::default() });
        b.push(req(1.0, 0)); // no deadline: never expires
        b.push(req_deadline(1.0, 1, Duration::from_secs(60))); // generous
        b.push(req_deadline(1.0, 2, Duration::ZERO)); // dead on arrival
        let g = b.pop_ready(Instant::now()).unwrap();
        assert_eq!(g.live.iter().map(|r| r.reply).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(g.expired.iter().map(|r| r.reply).collect::<Vec<_>>(), vec![2]);
    }

    #[test]
    fn sub_window_deadline_pops_immediately_and_live() {
        // The headline regression: deadline (100µs) shorter than the window
        // (500µs). The deadline-blind batcher held this request for the full
        // window and then split it into the expired half; the deadline-aware
        // batcher pops it at once, still live.
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 64,
            window: Duration::from_micros(500),
            deadline_slack: Duration::from_micros(150),
        });
        b.push(req_deadline(1.0, 7, Duration::from_micros(100)));
        let g = b.pop_ready(Instant::now()).expect("deadline-due group is ready NOW");
        assert_eq!(g.live.iter().map(|r| r.reply).collect::<Vec<_>>(), vec![7]);
        assert!(g.expired.is_empty(), "popped before the deadline passed");
    }

    #[test]
    fn next_deadline_wakes_for_member_deadline_before_window_fire() {
        let policy = BatchPolicy {
            max_batch: 64,
            window: Duration::from_secs(60),
            deadline_slack: Duration::from_micros(150),
        };
        let mut b = Batcher::new(policy);
        let r = req_deadline(1.0, 0, Duration::from_millis(5));
        let enqueued = r.enqueued;
        b.push(r);
        let wake = b.next_deadline().expect("pending work has a wake instant");
        assert!(
            wake <= enqueued + Duration::from_millis(5),
            "wake no later than the deadline itself"
        );
        assert!(
            wake < enqueued + Duration::from_secs(1),
            "wake is driven by the deadline, not the 60s window"
        );
    }

    #[test]
    fn far_deadline_keeps_the_window_fire() {
        // A lax deadline must not delay the window pop, and the wake hint
        // stays the window fire (the earlier of the two).
        let policy = BatchPolicy {
            max_batch: 64,
            window: Duration::from_millis(1),
            deadline_slack: Duration::from_micros(150),
        };
        let mut b = Batcher::new(policy);
        let r = req_deadline(1.0, 0, Duration::from_secs(10));
        let enqueued = r.enqueued;
        b.push(r);
        assert_eq!(b.next_deadline(), Some(enqueued + Duration::from_millis(1)));
        assert!(b.pop_ready(enqueued).is_none(), "not ready before the window");
        assert!(b.pop_ready(enqueued + Duration::from_millis(2)).is_some());
    }

    #[test]
    fn deadline_due_member_fires_its_whole_group() {
        // One urgent member makes the group ready; its lax companions ride
        // along in the same launch (FIFO order preserved).
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 64,
            window: Duration::from_secs(60),
            deadline_slack: Duration::from_micros(150),
        });
        b.push(req(1.0, 0));
        b.push(req_deadline(1.0, 1, Duration::from_micros(50)));
        let g = b.pop_ready(Instant::now()).expect("urgent member fires the group");
        assert_eq!(g.live.iter().map(|r| r.reply).collect::<Vec<_>>(), vec![0, 1]);
    }

    #[test]
    fn drain_all_splits_expired_like_pop_ready() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 4, window: Duration::from_secs(9), ..Default::default() });
        b.push(req_deadline(1.0, 0, Duration::ZERO));
        b.push(req(1.0, 1));
        let groups = b.drain_all(Instant::now());
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].live.iter().map(|r| r.reply).collect::<Vec<_>>(), vec![1]);
        assert_eq!(groups[0].expired.iter().map(|r| r.reply).collect::<Vec<_>>(), vec![0]);
    }
}
