//! Dynamic HF batcher: groups same-signature requests into bucket launches.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::ops::Pipeline;
use crate::tensor::Tensor;

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Max items fused into one launch (clamped to available buckets).
    pub max_batch: usize,
    /// How long the first request of a group may wait for company.
    pub window: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 64, window: Duration::from_micros(500) }
    }
}

/// A queued request: one item of a single-item pipeline plus its reply slot.
pub struct PendingRequest<R> {
    pub pipeline: Pipeline,
    pub item: Tensor,
    pub enqueued: Instant,
    /// Serve-by instant. A request whose deadline has passed when its group
    /// pops is dropped with a typed `Expired` reply instead of being served
    /// after its usefulness expired (the paper's framing: drop frames
    /// rather than lag). `None` = serve whenever.
    pub deadline: Option<Instant>,
    pub reply: R,
    /// Request id in the armed [`crate::trace::Tracer`]'s span space
    /// (0 = tracing off / untraced request). Assigned at ingest.
    pub trace_id: u64,
    /// Breaker verdict code for this request's group
    /// ([`crate::trace::TIER_STACKED`]-family), recorded by the scheduler
    /// so the `tier` span can report WHY a tier was chosen. Only meaningful
    /// when `trace_id != 0`.
    pub trace_verdict: u64,
    /// When ingest finished admitting this request — the boundary between
    /// its `admit` and `queue` spans. Equal to `enqueued` for untraced
    /// requests.
    pub admitted: Instant,
}

impl<R> PendingRequest<R> {
    /// Has this request's deadline passed at `now`? (A deadline exactly at
    /// `now` counts as expired — makes zero-duration deadlines
    /// deterministic under test.)
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// One popped group, split at pop time: `live` still has time to serve,
/// `expired` must be answered with the typed `Expired` error.
pub struct Popped<R> {
    pub live: Vec<PendingRequest<R>>,
    pub expired: Vec<PendingRequest<R>>,
}

/// Accumulates pending requests per stream key and decides when a group is
/// ready to launch. Pure data structure — no XLA, fully unit-testable.
pub struct Batcher<R> {
    queues: HashMap<String, Vec<PendingRequest<R>>>,
    policy: BatchPolicy,
}

impl<R> Batcher<R> {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher { queues: HashMap::new(), policy }
    }

    pub fn push(&mut self, req: PendingRequest<R>) {
        let key = crate::ops::Signature::of(&req.pipeline).stream_key();
        self.queues.entry(key).or_default().push(req);
    }

    pub fn pending(&self) -> usize {
        self.queues.values().map(Vec::len).sum()
    }

    /// Pop the next group that is ready: full (>= max_batch) or aged past the
    /// window. Requests come out in arrival order (FIFO within a stream),
    /// split into live and deadline-expired halves at pop time.
    pub fn pop_ready(&mut self, now: Instant) -> Option<Popped<R>> {
        let policy = self.policy;
        let key = self
            .queues
            .iter()
            .filter(|(_, q)| {
                !q.is_empty()
                    && (q.len() >= policy.max_batch
                        || now.duration_since(q[0].enqueued) >= policy.window)
            })
            // oldest head first: fairness across streams
            .min_by_key(|(_, q)| q[0].enqueued)
            .map(|(k, _)| k.clone())?;
        let q = self.queues.get_mut(&key).unwrap();
        let take = q.len().min(policy.max_batch);
        let (live, expired): (Vec<_>, Vec<_>) =
            q.drain(..take).partition(|r| !r.expired(now));
        if q.is_empty() {
            self.queues.remove(&key);
        }
        Some(Popped { live, expired })
    }

    /// Pop everything regardless of readiness (drain on shutdown). Expired
    /// requests are split out group by group, exactly like
    /// [`Batcher::pop_ready`] — shutdown resolves EVERY reply, it never
    /// serves stale work.
    pub fn drain_all(&mut self, now: Instant) -> Vec<Popped<R>> {
        let mut out = Vec::new();
        for (_, mut q) in self.queues.drain() {
            while !q.is_empty() {
                let take = q.len().min(self.policy.max_batch);
                let (live, expired): (Vec<_>, Vec<_>) =
                    q.drain(..take).partition(|r| !r.expired(now));
                out.push(Popped { live, expired });
            }
        }
        out
    }

    /// Deadline of the oldest pending request (service loop sleep hint).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queues
            .values()
            .filter_map(|q| q.first())
            .map(|r| r.enqueued + self.policy.window)
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{Opcode, Pipeline};
    use crate::tensor::{DType, Tensor};

    fn req(mul: f64, tag: u32) -> PendingRequest<u32> {
        let pipeline = Pipeline::from_opcodes(
            &[(Opcode::Mul, mul)],
            &[2, 2],
            1,
            DType::F32,
            DType::F32,
        )
        .unwrap();
        let enqueued = Instant::now();
        PendingRequest {
            pipeline,
            item: Tensor::from_f32(&[0.0; 4], &[1, 2, 2]),
            enqueued,
            deadline: None,
            reply: tag,
            trace_id: 0,
            trace_verdict: 0,
            admitted: enqueued,
        }
    }

    fn req_deadline(mul: f64, tag: u32, deadline: Duration) -> PendingRequest<u32> {
        let mut r = req(mul, tag);
        r.deadline = Some(r.enqueued + deadline);
        r
    }

    #[test]
    fn groups_by_stream_key_not_params() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 10, window: Duration::ZERO });
        b.push(req(1.0, 0));
        b.push(req(99.0, 1)); // different param, same code
        let g = b.pop_ready(Instant::now()).unwrap();
        assert_eq!(g.live.len(), 2);
        assert!(g.expired.is_empty());
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn full_batch_fires_before_window() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, window: Duration::from_secs(60) });
        b.push(req(1.0, 0));
        assert!(b.pop_ready(Instant::now()).is_none(), "waits for window/company");
        b.push(req(1.0, 1));
        assert_eq!(b.pop_ready(Instant::now()).unwrap().live.len(), 2);
    }

    #[test]
    fn window_expiry_fires_partial_batch() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, window: Duration::from_millis(1) });
        b.push(req(1.0, 0));
        let later = Instant::now() + Duration::from_millis(5);
        assert_eq!(b.pop_ready(later).unwrap().live.len(), 1);
    }

    #[test]
    fn fifo_within_stream_and_no_loss() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, window: Duration::ZERO });
        for i in 0..7 {
            b.push(req(1.0, i));
        }
        let mut seen = Vec::new();
        while let Some(g) = b.pop_ready(Instant::now()) {
            assert!(g.live.len() <= 3);
            seen.extend(g.live.iter().map(|r| r.reply));
        }
        assert_eq!(seen, (0..7).collect::<Vec<_>>(), "FIFO, nothing lost or duplicated");
    }

    #[test]
    fn drain_all_empties() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 4, window: Duration::from_secs(9) });
        for i in 0..9 {
            b.push(req(1.0, i));
        }
        let groups = b.drain_all(Instant::now());
        let total: usize = groups.iter().map(|g| g.live.len() + g.expired.len()).sum();
        assert_eq!(total, 9);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn deadline_expired_requests_split_out_at_pop_time() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 10, window: Duration::ZERO });
        b.push(req(1.0, 0)); // no deadline: never expires
        b.push(req_deadline(1.0, 1, Duration::from_secs(60))); // generous
        b.push(req_deadline(1.0, 2, Duration::ZERO)); // dead on arrival
        let g = b.pop_ready(Instant::now()).unwrap();
        assert_eq!(g.live.iter().map(|r| r.reply).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(g.expired.iter().map(|r| r.reply).collect::<Vec<_>>(), vec![2]);
    }

    #[test]
    fn drain_all_splits_expired_like_pop_ready() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 4, window: Duration::from_secs(9) });
        b.push(req_deadline(1.0, 0, Duration::ZERO));
        b.push(req(1.0, 1));
        let groups = b.drain_all(Instant::now());
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].live.iter().map(|r| r.reply).collect::<Vec<_>>(), vec![1]);
        assert_eq!(groups[0].expired.iter().map(|r| r.reply).collect::<Vec<_>>(), vec![0]);
    }
}
