//! The coordinator service: bounded ingress, batching loop, fused execution.

use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::{BatchPolicy, Batcher, Metrics, MetricsSnapshot, PendingRequest};
use crate::exec::{concat_batch, slice_batch, Engine, FusedEngine};
use crate::fusion::hfusion;
use crate::ops::Pipeline;
use crate::tensor::Tensor;

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Artifact directory (defaults to the repo's).
    pub artifact_dir: Option<std::path::PathBuf>,
    /// Ingress queue capacity — submissions beyond this are rejected
    /// (backpressure; the paper's pipelines drop frames rather than lag).
    pub queue_cap: usize,
    pub policy: BatchPolicy,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { artifact_dir: None, queue_cap: 1024, policy: BatchPolicy::default() }
    }
}

enum Msg {
    Request(PendingRequest<SyncSender<Result<Tensor, String>>>),
    Snapshot(SyncSender<MetricsSnapshot>),
    Shutdown,
}

#[derive(Debug, thiserror::Error)]
pub enum SubmitError {
    #[error("coordinator queue full (backpressure)")]
    QueueFull,
    #[error("coordinator stopped")]
    Stopped,
}

/// Handle to a running coordinator. Cloneable across threads; all XLA work
/// happens on the single service thread.
pub struct Service {
    tx: SyncSender<Msg>,
    handle: Option<JoinHandle<()>>,
}

impl Service {
    /// Start the service thread (loads the registry there — the PJRT client
    /// must live on that thread).
    pub fn start(cfg: ServiceConfig) -> Service {
        let (tx, rx) = sync_channel::<Msg>(cfg.queue_cap);
        let handle = std::thread::Builder::new()
            .name("fkl-coordinator".into())
            .spawn(move || service_loop(cfg, rx))
            .expect("spawn coordinator thread");
        Service { tx, handle: Some(handle) }
    }

    /// Submit one item; returns a receiver for the result. Non-blocking:
    /// fails fast under backpressure.
    pub fn submit(
        &self,
        pipeline: Pipeline,
        item: Tensor,
    ) -> Result<Receiver<Result<Tensor, String>>, SubmitError> {
        let (rtx, rrx) = sync_channel(1);
        let req =
            PendingRequest { pipeline, item, enqueued: Instant::now(), reply: rtx };
        match self.tx.try_send(Msg::Request(req)) {
            Ok(()) => Ok(rrx),
            Err(TrySendError::Full(_)) => Err(SubmitError::QueueFull),
            Err(TrySendError::Disconnected(_)) => Err(SubmitError::Stopped),
        }
    }

    pub fn metrics(&self) -> Option<MetricsSnapshot> {
        let (tx, rx) = sync_channel(1);
        self.tx.send(Msg::Snapshot(tx)).ok()?;
        rx.recv().ok()
    }

    /// Graceful shutdown: drain pending work, then join.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn service_loop(cfg: ServiceConfig, rx: Receiver<Msg>) {
    let dir = cfg.artifact_dir.clone().unwrap_or_else(crate::default_artifact_dir);
    let reg = match crate::runtime::Registry::load(&dir) {
        Ok(r) => std::rc::Rc::new(r),
        Err(e) => {
            // poison: reply to every request with the load error
            for msg in rx.iter() {
                match msg {
                    Msg::Request(r) => {
                        let _ = r.reply.send(Err(format!("registry: {e}")));
                    }
                    Msg::Snapshot(tx) => {
                        let _ = tx.send(MetricsSnapshot::default());
                    }
                    Msg::Shutdown => break,
                }
            }
            return;
        }
    };
    let engine = FusedEngine::new(reg.clone());
    let buckets: Vec<usize> = reg.geometry["hf_batches"]
        .as_usize_vec()
        .unwrap_or_else(|| vec![1, 2, 4, 8, 16, 32, 64]);
    let mut batcher = Batcher::new(cfg.policy);
    let mut metrics = Metrics::default();

    loop {
        // 1. ingest: wait until something arrives or the oldest group expires
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(Msg::Request(r)) => {
                batcher.push(r);
                // opportunistically drain whatever else is queued
                while let Ok(m) = rx.try_recv() {
                    match m {
                        Msg::Request(r) => batcher.push(r),
                        Msg::Snapshot(tx) => {
                            let _ = tx.send(metrics.snapshot());
                        }
                        Msg::Shutdown => {
                            flush(&mut batcher, &engine, &buckets, &mut metrics);
                            return;
                        }
                    }
                }
            }
            Ok(Msg::Snapshot(tx)) => {
                let _ = tx.send(metrics.snapshot());
            }
            Ok(Msg::Shutdown) => {
                flush(&mut batcher, &engine, &buckets, &mut metrics);
                return;
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                flush(&mut batcher, &engine, &buckets, &mut metrics);
                return;
            }
        }

        // 2. launch every ready group
        let now = Instant::now();
        while let Some(group) = batcher.pop_ready(now) {
            execute_group(group, &engine, &buckets, &mut metrics);
        }
    }
}

fn flush(
    batcher: &mut Batcher<SyncSender<Result<Tensor, String>>>,
    engine: &FusedEngine,
    buckets: &[usize],
    metrics: &mut Metrics,
) {
    for group in batcher.drain_all() {
        execute_group(group, engine, buckets, metrics);
    }
}

/// Execute one same-signature group as an HF-batched launch: pad the stack to
/// a bucket, run, slice replies back out.
fn execute_group(
    group: Vec<PendingRequest<SyncSender<Result<Tensor, String>>>>,
    engine: &FusedEngine,
    buckets: &[usize],
    metrics: &mut Metrics,
) {
    let m = group.len();
    let proto = &group[0].pipeline;
    // pick a bucket the planner can actually serve: prefer the smallest AOT
    // bucket >= m, then the exact group size; fall back to per-item launches
    // when only b=1 artifacts exist for this stream
    let mut batched = None;
    let mut candidates = vec![m];
    if let Some(b) = hfusion::single_bucket(m, buckets) {
        candidates.insert(0, b);
    }
    for bucket in candidates {
        let cand = Pipeline::new(
            proto.ops().to_vec(),
            proto.shape.clone(),
            bucket,
            proto.dtin,
            proto.dtout,
        )
        .expect("group pipeline revalidation");
        if engine.plan_for(&cand).is_ok() {
            batched = Some((bucket, cand));
            break;
        }
    }
    let Some((bucket, batched)) = batched else {
        // per-item fallback: still correct, just no HF for this stream
        for req in &group {
            match engine.run(&req.pipeline, &req.item) {
                Ok(t) => {
                    metrics.launches += engine.last_launches() as u64;
                    metrics.batched_items += 1;
                    metrics.observe_latency(req.enqueued.elapsed());
                    let _ = req.reply.send(Ok(t));
                }
                Err(e) => {
                    metrics.failed += 1;
                    let _ = req.reply.send(Err(format!("{e:#}")));
                }
            }
        }
        return;
    };

    // stack items (+ replicate the last item into pad planes)
    let mut parts: Vec<Tensor> = group.iter().map(|r| r.item.clone()).collect();
    for _ in m..bucket {
        parts.push(parts[m - 1].clone());
    }
    let input = concat_batch(&parts, &proto.shape);

    match engine.run(&batched, &input) {
        Ok(out) => {
            metrics.launches += engine.last_launches() as u64;
            metrics.batched_items += m as u64;
            metrics.padded_planes += (bucket - m) as u64;
            let item_elems: usize = out.len() / bucket;
            let item_shape: Vec<usize> = out.shape()[1..].to_vec();
            for (b, req) in group.iter().enumerate() {
                let t = slice_batch(&out, b, item_elems, &item_shape);
                metrics.observe_latency(req.enqueued.elapsed());
                let _ = req.reply.send(Ok(t));
            }
        }
        Err(e) => {
            metrics.failed += group.len() as u64;
            for req in &group {
                let _ = req.reply.send(Err(format!("{e:#}")));
            }
        }
    }
}
