//! The coordinator service: bounded ingress, batching loop, fused execution,
//! failure containment.
//!
//! Fault-tolerance model (DESIGN.md §"Failure containment & degradation"):
//!
//! * **Deadlines** — requests may carry a serve-by deadline; admission
//!   control sheds at ingress when the estimated queue delay already
//!   exceeds it, and the batcher drops expired requests at pop time. Both
//!   paths answer with a typed error immediately — stale work is never
//!   served (the paper's pipelines drop frames rather than lag).
//! * **Panic isolation** — every backend launch (stacked, divergent,
//!   per-item, and backend construction itself) runs under
//!   [`crate::exec::catch_launch`]: a poisoned launch fails exactly the
//!   requests riding on it with [`ServeError::LaunchPanicked`]; the
//!   service thread keeps serving, and a supervisor rebuilds a backend
//!   whose construction panicked.
//! * **Circuit breakers** — consecutive service-side failures of one
//!   stream key demote that stream down the serving ladder (stacked HF →
//!   divergent HF → per-item → reject) and sustained success promotes it
//!   back up ([`crate::coordinator::BreakerBoard`]; attempt-counted, no
//!   wall clocks).
//! * **Fault injection** — [`ServiceConfig::faults`] arms a deterministic
//!   [`crate::faults::FaultInjector`] consulted at every launch site,
//!   which is how all of the above is tested.

use std::collections::HashSet;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::{
    Admission, BatchPolicy, Batcher, BreakerBoard, BreakerPolicy, Metrics, MetricsSnapshot,
    PendingRequest, ServeTier,
};
use crate::exec::{
    self, slice_batch, stack_batch, DivergentOutcome, Engine, FusedEngine, HostFusedEngine,
};
use crate::faults::{FaultInjector, FaultPlan, FaultTier};
use crate::fusion::{hfusion, PlannerStats};
use crate::ops::{Pipeline, Signature};
use crate::tensor::Tensor;
use crate::trace::{self, SpanRecord, Stage, Tracer, NO_PARENT};

use super::router::{Router, ShardMsg};

/// Reply slot of one request.
pub(crate) type ReplyTx = SyncSender<Result<Tensor, ServeError>>;

/// One queued request as the service thread sees it.
pub(crate) type Req = PendingRequest<ReplyTx>;

/// Which execution backend the service thread builds — the selection policy
/// now lives in [`crate::exec`] and is shared with [`crate::cv::Context`],
/// so every front door degrades identically.
pub use crate::exec::EngineSelect;

/// Typed reply error: every way the coordinator can decline or fail a
/// request, distinguishable without string matching.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum ServeError {
    /// The deadline passed while the request was queued (dropped at pop).
    #[error("deadline expired while queued (dropped at pop time)")]
    Expired,
    /// Admission control refused the request: dead on arrival, or the
    /// estimated queue delay already exceeded the deadline.
    #[error("shed at admission: estimated queue delay exceeds the deadline")]
    Shed,
    /// The launch serving this request panicked; the panic was contained
    /// and only this launch's requests failed.
    #[error("launch panicked (isolated): {0}")]
    LaunchPanicked(String),
    /// This stream's circuit breaker is open (probation counts attempts).
    #[error("circuit open for stream `{stream}`")]
    CircuitOpen { stream: String },
    /// The request itself is malformed (client error — never counted
    /// against the stream's breaker).
    #[error("malformed request: {0}")]
    BadItem(String),
    /// The backend failed the launch with an ordinary error.
    #[error("execution failed: {0}")]
    Exec(String),
    /// The service could not build a working backend.
    #[error("service unavailable: {0}")]
    Unavailable(String),
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Artifact directory (defaults to the repo's).
    pub artifact_dir: Option<std::path::PathBuf>,
    /// Ingress queue capacity — submissions beyond this are rejected
    /// (backpressure; the paper's pipelines drop frames rather than lag).
    pub queue_cap: usize,
    pub policy: BatchPolicy,
    pub engine: EngineSelect,
    /// Deadline applied to every [`Service::submit`] (`None` = requests
    /// without an explicit deadline wait forever).
    pub default_deadline: Option<Duration>,
    /// Circuit-breaker thresholds (attempt-counted, deterministic).
    pub breaker: BreakerPolicy,
    /// Armed fault plan for the deterministic fault-injection harness
    /// (`None`/empty = off; the hot path then carries no injector at all).
    pub faults: Option<FaultPlan>,
    /// Supervisor budget: how many backend-construction panics are
    /// absorbed by rebuilding before the service gives up and answers
    /// [`ServeError::Unavailable`].
    pub max_build_retries: u32,
    /// Canonicalize admitted pipelines at ingress
    /// ([`crate::analysis::canonicalize`]): syntactically distinct but
    /// bit-equivalent chains collapse onto one canonical pipeline, so they
    /// stack into the same HF launches and compile ONE cached plan. Off by
    /// default — rewrites are bit-safety-proven (the fuzz harness's
    /// raw-vs-canonicalized contract) but ingress should opt in. Lint
    /// diagnostics are counted in [`MetricsSnapshot::lints_emitted`].
    pub canonicalize: bool,
    /// Armed span recorder: the service thread records one causally-linked
    /// span tree per request (admit/queue/tier/plan/launch/reply under a
    /// request root) into this tracer's fixed ring. `None` (default) = the
    /// hot path carries no tracing code at all — same pattern as `faults`.
    /// The caller keeps its own `Arc` and exports with
    /// [`Tracer::to_chrome_trace`] whenever it likes (e.g. on shutdown).
    pub tracing: Option<Arc<Tracer>>,
    /// Service worker count. `1` (the default) runs the original
    /// single-thread coordinator, bit-for-bit. `N > 1` starts N workers
    /// behind a stream-key-hash ingress router: each shard owns its own
    /// backend, batcher, breaker board, and plan cache, so same-key
    /// requests keep landing together (HF grouping is preserved) while
    /// distinct streams serve in parallel. An idle shard steals queued
    /// requests from its busiest sibling, and admission control stays
    /// global: `queue_cap` bounds TOTAL queued requests across shards.
    pub shards: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            artifact_dir: None,
            queue_cap: 1024,
            policy: BatchPolicy::default(),
            engine: EngineSelect::default(),
            default_deadline: None,
            breaker: BreakerPolicy::default(),
            faults: None,
            max_build_retries: 2,
            canonicalize: false,
            tracing: None,
            shards: 1,
        }
    }
}

enum Msg {
    Request(Req),
    Snapshot(SyncSender<MetricsSnapshot>),
    Shutdown,
}

#[derive(Debug, thiserror::Error)]
pub enum SubmitError {
    #[error("coordinator queue full (backpressure)")]
    QueueFull,
    #[error("coordinator stopped")]
    Stopped,
}

/// How many `try_send` attempts a metrics probe makes against a full
/// ingress queue before giving up (each attempt yields the CPU — the
/// service thread is actively draining).
const SNAPSHOT_RETRIES: usize = 1024;

/// Handle to a running coordinator. Cloneable across threads; all XLA work
/// happens on the single service thread.
pub struct Service {
    ingress: Ingress,
    default_deadline: Option<Duration>,
}

/// How submissions reach the service worker(s). `Single` is the original
/// one-thread `sync_channel` path, preserved bit-for-bit when
/// [`ServiceConfig::shards`] is 1. `Sharded` routes by stream-key hash
/// through a [`Router`] to N worker threads.
enum Ingress {
    Single { tx: Option<SyncSender<Msg>>, handle: Option<JoinHandle<()>> },
    Sharded { router: Option<Arc<Router>>, handles: Vec<JoinHandle<()>> },
}

impl Service {
    /// Start the service thread(s) (the registry loads there — the PJRT
    /// client must live on its service thread).
    pub fn start(cfg: ServiceConfig) -> Service {
        let default_deadline = cfg.default_deadline;
        let shards = cfg.shards.max(1);
        if shards == 1 {
            let (tx, rx) = sync_channel::<Msg>(cfg.queue_cap);
            let handle = std::thread::Builder::new()
                .name("fkl-coordinator".into())
                .spawn(move || service_loop(cfg, rx))
                .expect("spawn coordinator thread");
            return Service {
                ingress: Ingress::Single { tx: Some(tx), handle: Some(handle) },
                default_deadline,
            };
        }
        let router = Arc::new(Router::new(shards, cfg.queue_cap));
        let handles = (0..shards)
            .map(|shard| {
                let cfg = cfg.clone();
                let router = router.clone();
                std::thread::Builder::new()
                    .name(format!("fkl-coordinator-{shard}"))
                    .spawn(move || super::shard::shard_loop(cfg, shard, router))
                    .expect("spawn coordinator shard thread")
            })
            .collect();
        Service {
            ingress: Ingress::Sharded { router: Some(router), handles },
            default_deadline,
        }
    }

    /// Submit one item; returns a receiver for the result. Non-blocking:
    /// fails fast under backpressure. Accepts the runtime [`Pipeline`] IR or
    /// a typed chain ([`crate::chain::TypedPipeline`]) — the coordinator is
    /// a chain front door like `cv`/`npp`. Dense pipelines take
    /// `[1, *shape]` items; structured chains (crop/resize reads) take the
    /// shared `[fh, fw, 3]` FRAME as the item. The scheduler auto-tiers
    /// every window: identical requests stack into one HF launch, the
    /// mixed remainder (different params, signatures, chain lengths —
    /// structured and reduce streams included) shares ONE divergent-HF
    /// pass, and a lone leftover serves per item. The configured
    /// [`ServiceConfig::default_deadline`] (if any) applies.
    pub fn submit(
        &self,
        pipeline: impl Into<Pipeline>,
        item: Tensor,
    ) -> Result<Receiver<Result<Tensor, ServeError>>, SubmitError> {
        self.submit_opt(pipeline.into(), item, self.default_deadline)
    }

    /// [`Service::submit`] with an explicit serve-by deadline, measured
    /// from now. A request that cannot launch before its deadline is
    /// answered with [`ServeError::Shed`] (at ingress) or
    /// [`ServeError::Expired`] (at pop time) instead of being served late.
    pub fn submit_with_deadline(
        &self,
        pipeline: impl Into<Pipeline>,
        item: Tensor,
        deadline: Duration,
    ) -> Result<Receiver<Result<Tensor, ServeError>>, SubmitError> {
        self.submit_opt(pipeline.into(), item, Some(deadline))
    }

    fn submit_opt(
        &self,
        pipeline: Pipeline,
        item: Tensor,
        deadline: Option<Duration>,
    ) -> Result<Receiver<Result<Tensor, ServeError>>, SubmitError> {
        let (rtx, rrx) = sync_channel(1);
        let enqueued = Instant::now();
        let deadline = deadline.and_then(|d| enqueued.checked_add(d));
        let req = PendingRequest {
            pipeline,
            item,
            enqueued,
            deadline,
            reply: rtx,
            trace_id: 0,
            trace_verdict: 0,
            admitted: enqueued,
        };
        match &self.ingress {
            Ingress::Single { tx, .. } => {
                let Some(tx) = tx.as_ref() else {
                    return Err(SubmitError::Stopped);
                };
                match tx.try_send(Msg::Request(req)) {
                    Ok(()) => Ok(rrx),
                    Err(TrySendError::Full(_)) => Err(SubmitError::QueueFull),
                    Err(TrySendError::Disconnected(_)) => Err(SubmitError::Stopped),
                }
            }
            Ingress::Sharded { router, .. } => {
                let Some(r) = router.as_ref() else {
                    return Err(SubmitError::Stopped);
                };
                r.submit(req).map(|()| rrx)
            }
        }
    }

    /// Snapshot the service metrics. Bounded on the single-worker path: a
    /// full ingress queue makes the probe retry-with-yield a fixed number
    /// of times and then return `None` — it never blocks behind
    /// backpressure. On the sharded path a snapshot probe is a control
    /// message (never capped by admission control); every shard answers
    /// its own counters and the parts merge at the
    /// [`MetricsSnapshot::merge`] seam.
    pub fn metrics(&self) -> Option<MetricsSnapshot> {
        match &self.ingress {
            Ingress::Single { tx, .. } => {
                let tx = tx.as_ref()?;
                let (stx, srx) = sync_channel(1);
                let mut msg = Msg::Snapshot(stx);
                for _ in 0..SNAPSHOT_RETRIES {
                    match tx.try_send(msg) {
                        Ok(()) => return srx.recv().ok(),
                        Err(TrySendError::Full(m)) => {
                            msg = m;
                            std::thread::yield_now();
                        }
                        Err(TrySendError::Disconnected(_)) => return None,
                    }
                }
                None
            }
            Ingress::Sharded { router, .. } => {
                let r = router.as_ref()?;
                let rxs: Vec<_> = (0..r.shards())
                    .map(|i| {
                        let (stx, srx) = sync_channel(1);
                        r.mailbox(i).push_control(ShardMsg::Snapshot(stx));
                        srx
                    })
                    .collect();
                let parts: Option<Vec<MetricsSnapshot>> =
                    rxs.into_iter().map(|rx| rx.recv().ok()).collect();
                parts.map(MetricsSnapshot::merge)
            }
        }
    }

    /// Graceful shutdown: drain pending work, then join.
    pub fn shutdown(mut self) {
        self.stop();
    }

    /// Shared by [`Service::shutdown`] and `Drop`: never blocks on a full
    /// ingress queue. Single path: a polite `Shutdown` is *tried*; either
    /// way the sender is dropped, and channel disconnect makes the service
    /// loop flush pending work and exit — so the join below always
    /// completes. Sharded path: the router closes (new submissions answer
    /// `Stopped`) and pushes an uncapped `Shutdown` control message to
    /// every mailbox, so each shard flushes and exits.
    fn stop(&mut self) {
        match &mut self.ingress {
            Ingress::Single { tx, handle } => {
                if let Some(tx) = tx.take() {
                    let _ = tx.try_send(Msg::Shutdown);
                    drop(tx);
                }
                if let Some(h) = handle.take() {
                    let _ = h.join();
                }
            }
            Ingress::Sharded { router, handles } => {
                if let Some(r) = router.take() {
                    r.close();
                }
                for h in handles.drain(..) {
                    let _ = h.join();
                }
            }
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.stop();
    }
}

/// The service thread's execution backend: the XLA fused engine against the
/// artifact registry, or the everywhere-capable host fused engine.
pub(crate) enum Backend {
    Xla { engine: FusedEngine, buckets: Vec<usize> },
    Host { engine: HostFusedEngine, buckets: Vec<usize> },
}

const DEFAULT_BUCKETS: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];

impl Backend {
    fn buckets(&self) -> &[usize] {
        match self {
            Backend::Xla { buckets, .. } | Backend::Host { buckets, .. } => buckets,
        }
    }

    /// Can this backend serve the pipeline (used to pick an HF bucket)?
    fn covers(&self, p: &Pipeline) -> bool {
        match self {
            Backend::Xla { engine, .. } => engine.plan_for(p).is_ok(),
            // the host engine executes the whole element-wise vocabulary; the
            // one thing it refuses is HF-stacking a lane-structured (3-lane
            // pixel) stream whose items are not a whole number of pixels —
            // stacking would shift lane indices across items, silently
            // changing per-item results (those streams run per item instead)
            Backend::Host { engine, .. } => {
                let plan = engine.plan_for(p);
                p.batch == 1 || plan.group() == 1 || p.item_elems() % plan.group() == 0
            }
        }
    }

    fn run(&self, p: &Pipeline, input: &Tensor) -> Result<Tensor> {
        match self {
            Backend::Xla { engine, .. } => engine.run(p, input),
            Backend::Host { engine, .. } => engine.run(p, input),
        }
    }

    fn last_launches(&self) -> usize {
        match self {
            Backend::Xla { engine, .. } => engine.last_launches(),
            Backend::Host { engine, .. } => engine.last_launches(),
        }
    }

    fn last_was_fallback(&self) -> bool {
        match self {
            Backend::Xla { engine, .. } => engine.last_was_fallback(),
            Backend::Host { .. } => false,
        }
    }

    /// Serve a mixed window in one divergent-HF pass: natively on the host
    /// backend, detected-and-re-routed on the XLA front door.
    fn run_many(&self, window: &[(&Pipeline, &Tensor)]) -> DivergentOutcome {
        match self {
            Backend::Xla { engine, .. } => engine.run_many(window),
            Backend::Host { engine, .. } => engine.run_divergent(window),
        }
    }

    /// Probe the plan cache for `p`: `(was already cached, probe/compile
    /// time)`. Host backend only — the XLA front door's cache is interior
    /// to the engine, so its `plan` span is folded into the launch.
    fn plan_probe(&self, p: &Pipeline) -> Option<(bool, Duration)> {
        match self {
            Backend::Xla { .. } => None,
            Backend::Host { engine, .. } => {
                let hit = engine.plan_cached(p);
                let t0 = Instant::now();
                let _ = engine.plan_for(p);
                Some((hit, t0.elapsed()))
            }
        }
    }

    /// Launch geometry for `p` as the trace reports it: `(register-block
    /// lane width, worker threads)`.
    fn launch_shape(&self, p: &Pipeline) -> (u64, u64) {
        match self {
            Backend::Xla { .. } => (0, 1),
            Backend::Host { engine, .. } => {
                (engine.plan_for(p).vectorization() as u64, engine.threads() as u64)
            }
        }
    }

    fn planner_stats(&self) -> PlannerStats {
        match self {
            Backend::Xla { engine, .. } => engine.planner_stats(),
            Backend::Host { engine, .. } => PlannerStats {
                host: engine.runs(),
                structured: engine.structured_runs(),
                reduction: engine.reduce_runs(),
                divergent: engine.divergent_runs(),
                plan_cache: engine.plan_cache_len(),
                vectorized: engine.vector_runs(),
                vector_width: engine.vector_width(),
                bytes_read: engine.bytes_read(),
                bytes_written: engine.bytes_written(),
                bytes_baseline: engine.bytes_baseline(),
                ..PlannerStats::default()
            },
        }
    }
}

/// What one backend-construction attempt produced.
enum BuildOutcome {
    Ready { backend: Backend, degraded: Option<String> },
    /// Unrecoverable (pinned XLA without a registry): serve typed errors.
    Poisoned(String),
}

fn build_backend(cfg: &ServiceConfig, faults: &Option<Arc<FaultInjector>>) -> BuildOutcome {
    let dir = cfg.artifact_dir.clone().unwrap_or_else(crate::default_artifact_dir);
    let host_backend = || {
        let engine = match faults {
            Some(inj) => HostFusedEngine::new().with_fault_injector(inj.clone()),
            None => HostFusedEngine::new(),
        };
        Backend::Host { engine, buckets: DEFAULT_BUCKETS.to_vec() }
    };
    match cfg.engine {
        EngineSelect::HostFused => BuildOutcome::Ready { backend: host_backend(), degraded: None },
        // without the pjrt feature there is no XLA to prefer — degrade
        // visibly (structured, not just stderr)
        EngineSelect::Auto if !cfg!(feature = "pjrt") => BuildOutcome::Ready {
            backend: host_backend(),
            degraded: Some(
                "no XLA backend compiled (pjrt feature off); \
                 serving with the host fused engine"
                    .into(),
            ),
        },
        EngineSelect::Xla | EngineSelect::Auto => match crate::runtime::Registry::load(&dir) {
            Ok(r) => {
                let reg = std::rc::Rc::new(r);
                let buckets = reg.geometry["hf_batches"]
                    .as_usize_vec()
                    .unwrap_or_else(|| DEFAULT_BUCKETS.to_vec());
                BuildOutcome::Ready {
                    backend: Backend::Xla { engine: FusedEngine::new(reg), buckets },
                    degraded: None,
                }
            }
            Err(e) if cfg.engine == EngineSelect::Auto => BuildOutcome::Ready {
                backend: host_backend(),
                degraded: Some(format!(
                    "artifact registry unavailable ({e:#}); \
                     serving with the host fused engine"
                )),
            },
            Err(e) => BuildOutcome::Poisoned(format!("registry: {e}")),
        },
    }
}

/// Terminal state for a service that never got a working backend: answer
/// every request with a typed error until shutdown. The supervisor lands
/// here after exhausting [`ServiceConfig::max_build_retries`].
fn poison_loop(rx: Receiver<Msg>, msg: String, restarts: u64) {
    eprintln!("fkl-coordinator: {msg}");
    for m in rx.iter() {
        match m {
            Msg::Request(r) => {
                let _ = r.reply.send(Err(ServeError::Unavailable(msg.clone())));
            }
            Msg::Snapshot(tx) => {
                let _ = tx.send(MetricsSnapshot {
                    supervisor_restarts: restarts,
                    degraded: Some(msg.clone()),
                    ..MetricsSnapshot::default()
                });
            }
            Msg::Shutdown => break,
        }
    }
}

/// Arm the deterministic fault injector from the config (`None` when the
/// plan is absent or empty — the hot path then carries no injector at all).
/// Called once per service worker: each shard owns its own injector, so
/// attempt-counted fault rules fire deterministically per shard.
pub(crate) fn arm_faults(cfg: &ServiceConfig) -> Option<Arc<FaultInjector>> {
    cfg.faults
        .as_ref()
        .filter(|p| !p.is_empty())
        .map(|p| Arc::new(FaultInjector::new(p.clone())))
}

/// What supervised backend construction produced: a working backend (plus
/// how many construction panics the supervisor absorbed getting there), or
/// a poisoned worker that must answer typed `Unavailable` until shutdown.
pub(crate) enum SupervisedBuild {
    Ready { backend: Backend, degraded: Option<String>, restarts: u64 },
    Poisoned { msg: String, restarts: u64 },
}

/// Supervised construction: a panicking backend constructor (exercised via
/// tier=build faults) is rebuilt up to [`ServiceConfig::max_build_retries`]
/// before the worker gives up and poisons itself.
pub(crate) fn supervised_build(
    cfg: &ServiceConfig,
    faults: &Option<Arc<FaultInjector>>,
) -> SupervisedBuild {
    let mut restarts: u64 = 0;
    loop {
        let attempt = exec::catch_launch(|| {
            if let Some(inj) = faults {
                inj.apply(FaultTier::Build, "backend")?;
            }
            Ok(build_backend(cfg, faults))
        });
        match attempt {
            Ok(BuildOutcome::Ready { backend, degraded }) => {
                return SupervisedBuild::Ready { backend, degraded, restarts }
            }
            Ok(BuildOutcome::Poisoned(msg)) => {
                return SupervisedBuild::Poisoned { msg, restarts }
            }
            Err(e) => {
                restarts += 1;
                if restarts > cfg.max_build_retries as u64 {
                    return SupervisedBuild::Poisoned {
                        msg: format!("backend construction kept failing ({e:#})"),
                        restarts,
                    };
                }
            }
        }
    }
}

fn service_loop(cfg: ServiceConfig, rx: Receiver<Msg>) {
    let faults = arm_faults(&cfg);
    let (backend, degraded, restarts) = match supervised_build(&cfg, &faults) {
        SupervisedBuild::Ready { backend, degraded, restarts } => (backend, degraded, restarts),
        SupervisedBuild::Poisoned { msg, restarts } => {
            poison_loop(rx, msg, restarts);
            return;
        }
    };

    let mut batcher = Batcher::new(cfg.policy);
    let mut metrics = Metrics::default();
    let mut breakers = BreakerBoard::new(cfg.breaker);
    let tracer = cfg.tracing.clone();
    let tracer = tracer.as_deref();
    // ingress canonicalizer state: the canonical stream keys seen so far
    // (`None` = canonicalization off; ingest admits pipelines untouched)
    let mut canon_seen: Option<HashSet<String>> = cfg.canonicalize.then(HashSet::new);
    metrics.supervisor_restarts = restarts;
    metrics.degraded = degraded;
    if let Some(d) = &metrics.degraded {
        // printed exactly once; the structured copy lives in the snapshot
        eprintln!("fkl-coordinator: {d}");
    }

    loop {
        // 1. ingest: wait until something arrives or the oldest group expires
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(Msg::Request(r)) => {
                ingest(r, &mut batcher, &mut metrics, &mut canon_seen, tracer, 0);
                // opportunistically drain whatever else is queued
                while let Ok(m) = rx.try_recv() {
                    match m {
                        Msg::Request(r) => {
                            ingest(r, &mut batcher, &mut metrics, &mut canon_seen, tracer, 0)
                        }
                        Msg::Snapshot(tx) => {
                            let _ = tx.send(snapshot(&mut metrics, &backend, &breakers));
                        }
                        Msg::Shutdown => {
                            flush(
                                &mut batcher,
                                &backend,
                                &mut metrics,
                                &mut breakers,
                                &faults,
                                tracer,
                                0,
                            );
                            return;
                        }
                    }
                }
            }
            Ok(Msg::Snapshot(tx)) => {
                let _ = tx.send(snapshot(&mut metrics, &backend, &breakers));
            }
            Ok(Msg::Shutdown) => {
                flush(&mut batcher, &backend, &mut metrics, &mut breakers, &faults, tracer, 0);
                return;
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                flush(&mut batcher, &backend, &mut metrics, &mut breakers, &faults, tracer, 0);
                return;
            }
        }

        // 2. launch: collect EVERY ready group into one scheduling window —
        // identical pipelines stack per group (tier 1), and the signature/
        // param-divergent remainder of the WHOLE window shares one
        // divergent-HF pass (tier 2) instead of degrading per item.
        // Deadline-expired requests split out at pop time and are answered
        // immediately, never served.
        let now = Instant::now();
        let mut groups = Vec::new();
        while let Some(popped) = batcher.pop_ready(now) {
            expire(popped.expired, &mut metrics, tracer, 0);
            if !popped.live.is_empty() {
                groups.push(popped.live);
            }
        }
        if !groups.is_empty() {
            serve_window(groups, &backend, &mut metrics, &mut breakers, &faults, tracer, 0);
        }
    }
}

/// Admission control. A deadline-carrying request is shed right here when
/// it is dead on arrival, or when the queue-delay estimate (pending items x
/// the EWMA per-item cost) says it cannot launch in time — the client
/// learns *now*, not after the queue wasted time on it.
///
/// With [`ServiceConfig::canonicalize`] on (`canon_seen` is `Some`), every
/// admitted pipeline is replaced by its canonical twin BEFORE the batcher
/// groups it: syntactically distinct but bit-equivalent chains then share a
/// stream key, stack into the same HF launches, and compile one cached
/// plan. Only bit-safety-proven rewrites apply (the analysis contract), so
/// replies are bit-identical to serving the raw pipeline.
pub(crate) fn ingest(
    mut req: Req,
    batcher: &mut Batcher<ReplyTx>,
    metrics: &mut Metrics,
    canon_seen: &mut Option<HashSet<String>>,
    tracer: Option<&Tracer>,
    shard: u64,
) {
    let armed = tracer.map(|tr| {
        req.trace_id = tr.new_request();
        (tr, tr.now_us())
    });
    let (lints0, rewrites0) = (metrics.lints_emitted, metrics.rewrites_applied);
    if let Some(dl) = req.deadline {
        // dead-on-arrival is judged against NOW, not the enqueue instant: a
        // request that aged past its deadline sitting in the ingress channel
        // is shed right here instead of being queued and answered `Expired`
        // at pop time after the batcher wasted a wake on it
        let now = Instant::now();
        let est = Duration::from_micros((metrics.ewma_item_us * batcher.pending() as f64) as u64);
        let remaining = dl.saturating_duration_since(now);
        if dl <= now || (est > Duration::ZERO && est > remaining) {
            metrics.shed += 1;
            // shed latency IS recorded — admission churn stays visible in
            // the latency distribution, consistent with expire/fail_request
            metrics.observe_latency(req.enqueued.elapsed());
            let _ = req.reply.send(Err(ServeError::Shed));
            if let Some((tr, start_us)) = armed {
                trace_admit(tr, &req, start_us, 0, 0, shard, Some("Shed"));
            }
            return;
        }
    }
    if let Some(seen) = canon_seen {
        metrics.lints_emitted += crate::analysis::lint(&req.pipeline).len() as u64;
        let (canonical, rewrites) = crate::analysis::canonicalize(req.pipeline.clone());
        metrics.rewrites_applied += rewrites.iter().filter(|r| r.applied).count() as u64;
        if !seen.insert(Signature::of(&canonical).stream_key()) {
            metrics.canonical_cache_hits += 1;
        }
        req.pipeline = canonical;
    }
    if let Some((tr, start_us)) = armed {
        trace_admit(
            tr,
            &req,
            start_us,
            metrics.lints_emitted - lints0,
            metrics.rewrites_applied - rewrites0,
            shard,
            None,
        );
        req.admitted = Instant::now();
    }
    batcher.push(req);
}

/// Record a request's `admit` span (shed check + lint + canonicalize). A
/// shed request's tree terminates here, so its root closes too.
fn trace_admit(
    tr: &Tracer,
    req: &Req,
    start_us: u64,
    lints: u64,
    rewrites: u64,
    shard: u64,
    err: Option<&'static str>,
) {
    let now = tr.now_us();
    tr.record(SpanRecord {
        req: req.trace_id,
        id: 1,
        parent: 0,
        stage: Stage::Admit,
        start_us,
        dur_us: now.saturating_sub(start_us),
        a: lints,
        b: rewrites,
        c: 0,
        err,
    });
    if err.is_some() {
        let enq = tr.us(req.enqueued);
        tr.record(SpanRecord {
            req: req.trace_id,
            id: 0,
            parent: NO_PARENT,
            stage: Stage::Request,
            start_us: enq,
            dur_us: now.saturating_sub(enq),
            a: shard,
            b: 0,
            c: 0,
            err,
        });
    }
}

/// Answer deadline-expired requests (split out by the batcher at pop time).
pub(crate) fn expire(expired: Vec<Req>, metrics: &mut Metrics, tracer: Option<&Tracer>, shard: u64) {
    for req in expired {
        metrics.expired += 1;
        metrics.observe_latency(req.enqueued.elapsed());
        let _ = req.reply.send(Err(ServeError::Expired));
        // expiry kills the request while queued: the error lands on the
        // queue span and the tree terminates
        if let Some(tr) = tracer.filter(|_| req.trace_id != 0) {
            let now = tr.now_us();
            let admitted = tr.us(req.admitted);
            let enq = tr.us(req.enqueued);
            tr.record(SpanRecord {
                req: req.trace_id,
                id: 2,
                parent: 0,
                stage: Stage::Queue,
                start_us: admitted,
                dur_us: now.saturating_sub(admitted),
                a: 0,
                b: 0,
                c: 0,
                err: Some("Expired"),
            });
            tr.record(SpanRecord {
                req: req.trace_id,
                id: 0,
                parent: NO_PARENT,
                stage: Stage::Request,
                start_us: enq,
                dur_us: now.saturating_sub(enq),
                a: shard,
                b: 0,
                c: 0,
                err: Some("Expired"),
            });
        }
    }
}

/// Metrics snapshot for the service thread: refresh the engine-side planner
/// stats, then let [`Metrics::snapshot`] merge in the breaker board — that
/// call is the single seam where breaker state joins the counters.
pub(crate) fn snapshot(
    metrics: &mut Metrics,
    backend: &Backend,
    breakers: &BreakerBoard,
) -> MetricsSnapshot {
    metrics.planner = backend.planner_stats();
    metrics.snapshot(breakers)
}

pub(crate) fn flush(
    batcher: &mut Batcher<ReplyTx>,
    backend: &Backend,
    metrics: &mut Metrics,
    breakers: &mut BreakerBoard,
    faults: &Option<Arc<FaultInjector>>,
    tracer: Option<&Tracer>,
    shard: u64,
) {
    let mut groups = Vec::new();
    for popped in batcher.drain_all(Instant::now()) {
        expire(popped.expired, metrics, tracer, shard);
        if !popped.live.is_empty() {
            groups.push(popped.live);
        }
    }
    if !groups.is_empty() {
        serve_window(groups, backend, metrics, breakers, faults, tracer, shard);
    }
}

fn observe_launch(metrics: &mut Metrics, backend: &Backend) {
    metrics.launches += backend.last_launches() as u64;
    if backend.last_was_fallback() {
        metrics.unfused_fallbacks += 1;
    }
}

/// Successful reply: count completion, record latency and deadline margin.
fn complete_ok(req: &Req, t: Tensor, metrics: &mut Metrics) {
    metrics.completed += 1;
    metrics.observe_latency(req.enqueued.elapsed());
    if let Some(dl) = req.deadline {
        metrics.observe_margin(dl.saturating_duration_since(Instant::now()));
    }
    let _ = req.reply.send(Ok(t));
}

/// Failed reply: count the failure AND record its latency — the
/// slow-failure tail stays visible in the distribution.
fn fail_request(req: &Req, err: ServeError, metrics: &mut Metrics) {
    metrics.failed += 1;
    metrics.observe_latency(req.enqueued.elapsed());
    let _ = req.reply.send(Err(err));
}

/// Convert a launch error into the typed reply, counting contained panics.
fn serve_error(e: &anyhow::Error, metrics: &mut Metrics) -> ServeError {
    if let Some(p) = e.downcast_ref::<exec::LaunchPanic>() {
        metrics.launch_panics += 1;
        ServeError::LaunchPanicked(p.msg.clone())
    } else {
        ServeError::Exec(format!("{e:#}"))
    }
}

/// The typed error's variant name — the `&'static str` recorded on the
/// failing span (failure traces stay allocation-free).
fn err_name(e: &ServeError) -> &'static str {
    match e {
        ServeError::Expired => "Expired",
        ServeError::Shed => "Shed",
        ServeError::LaunchPanicked(_) => "LaunchPanicked",
        ServeError::CircuitOpen { .. } => "CircuitOpen",
        ServeError::BadItem(_) => "BadItem",
        ServeError::Exec(_) => "Exec",
        ServeError::Unavailable(_) => "Unavailable",
    }
}

/// Launch-span payload shared by every rider of one fused launch.
struct LaunchInfo {
    start: Instant,
    dur: Duration,
    elems: u64,
    width: u64,
    threads: u64,
}

/// Close a served (or serve-failed) request's span tree: `queue`, `tier`
/// (with nested `plan` / `launch` when the tier got that far), `reply`, and
/// the `request` root. No-op when tracing is off or the request predates
/// the tracer being armed (`trace_id == 0`).
#[allow(clippy::too_many_arguments)]
fn trace_finish(
    tracer: Option<&Tracer>,
    req: &Req,
    serve_start: Instant,
    tier: u64,
    group_len: u64,
    plan: Option<(Instant, Duration, bool)>,
    launch: Option<&LaunchInfo>,
    reply_t0: Instant,
    shard: u64,
    err: Option<&'static str>,
) {
    let Some(tr) = tracer.filter(|_| req.trace_id != 0) else {
        return;
    };
    let span = |id: u16, parent: u16, stage, start_us: u64, end_us: u64, a, b, c, err| {
        tr.record(SpanRecord {
            req: req.trace_id,
            id,
            parent,
            stage,
            start_us,
            dur_us: end_us.saturating_sub(start_us),
            a,
            b,
            c,
            err,
        });
    };
    let serve_us = tr.us(serve_start);
    let reply_us = tr.us(reply_t0);
    span(2, 0, Stage::Queue, tr.us(req.admitted), serve_us, 0, 0, 0, None);
    if let Some((t0, dur, hit)) = plan {
        let start = tr.us(t0);
        span(4, 3, Stage::Plan, start, start + dur.as_micros() as u64, hit as u64, 0, 0, None);
    }
    if let Some(l) = launch {
        let start = tr.us(l.start);
        let end = start + l.dur.as_micros() as u64;
        span(5, 3, Stage::Launch, start, end, l.elems, l.width, l.threads, err);
    }
    // the error lands on the launch span when a launch ran; otherwise the
    // tier itself is the failing stage (rejected, bad item, whole-pass panic)
    let tier_err = if launch.is_none() { err } else { None };
    span(3, 0, Stage::Tier, serve_us, reply_us, tier, req.trace_verdict, group_len, tier_err);
    let now = tr.now_us();
    span(6, 0, Stage::Reply, reply_us, now, err.is_none() as u64, 0, 0, None);
    span(0, NO_PARENT, Stage::Request, tr.us(req.enqueued), now, shard, 0, 0, err);
}

/// Reject a whole group because its stream's breaker is open.
fn reject_open(
    group: &[Req],
    key: &str,
    metrics: &mut Metrics,
    breakers: &mut BreakerBoard,
    tracer: Option<&Tracer>,
    serve_start: Instant,
    shard: u64,
) {
    if group.is_empty() {
        return;
    }
    breakers.note_rejected(key, group.len());
    for req in group {
        metrics.observe_latency(req.enqueued.elapsed());
        let reply_t0 = Instant::now();
        let _ = req.reply.send(Err(ServeError::CircuitOpen { stream: key.to_string() }));
        trace_finish(
            tracer,
            req,
            serve_start,
            req.trace_verdict,
            group.len() as u64,
            None,
            None,
            reply_t0,
            shard,
            Some("CircuitOpen"),
        );
    }
}

/// The coordinator's scheduling ladder, applied to one window (every group
/// that is ready right now):
///
/// 1. **identical stacked HF** — per group, requests matching the head
///    request (pipeline params-and-all) stack into one bucket launch;
/// 2. **divergent HF** — the merged remainder of ALL groups (param- and
///    signature-divergent company, structured/reduce streams, uncovered
///    buckets) serves in ONE thread-chunked pass;
/// 3. **per-item fallback** — a lone leftover launches alone.
///
/// Each group first passes its stream's circuit breaker, which may cap the
/// tier (demoted streams enter the ladder lower down), admit a single
/// half-open probe, or reject the group outright with a typed error.
pub(crate) fn serve_window(
    groups: Vec<Vec<Req>>,
    backend: &Backend,
    metrics: &mut Metrics,
    breakers: &mut BreakerBoard,
    faults: &Option<Arc<FaultInjector>>,
    tracer: Option<&Tracer>,
    shard: u64,
) {
    let serve_start = Instant::now();
    let mut divergent_pool: Vec<Req> = Vec::new();
    let mut per_item_pool: Vec<Req> = Vec::new();
    for mut group in groups {
        if group.is_empty() {
            continue;
        }
        let key = Signature::of(&group[0].pipeline).stream_key();
        let admission = breakers.admit(&key);
        let verdict = match admission {
            Admission::Serve(ServeTier::Stacked) => trace::TIER_STACKED,
            Admission::Serve(ServeTier::Divergent) => trace::TIER_DIVERGENT,
            Admission::Serve(ServeTier::PerItem) => trace::TIER_PER_ITEM,
            Admission::Probe => trace::TIER_PROBE,
            Admission::Reject => trace::TIER_REJECT,
        };
        for r in &mut group {
            r.trace_verdict = verdict;
        }
        match admission {
            Admission::Serve(ServeTier::Stacked) => {
                divergent_pool.extend(stack_tier(
                    group,
                    backend,
                    metrics,
                    breakers,
                    faults,
                    tracer,
                    serve_start,
                    shard,
                ));
            }
            Admission::Serve(ServeTier::Divergent) => divergent_pool.extend(group),
            Admission::Serve(ServeTier::PerItem) => per_item_pool.extend(group),
            Admission::Probe => {
                // exactly one request probes (per item); company is rejected
                let mut it = group.into_iter();
                if let Some(probe) = it.next() {
                    per_item_pool.push(probe);
                }
                let mut rest: Vec<Req> = it.collect();
                for r in &mut rest {
                    r.trace_verdict = trace::TIER_REJECT;
                }
                reject_open(&rest, &key, metrics, breakers, tracer, serve_start, shard);
            }
            Admission::Reject => {
                reject_open(&group, &key, metrics, breakers, tracer, serve_start, shard)
            }
        }
    }
    if divergent_pool.len() >= 2 {
        execute_divergent(divergent_pool, backend, metrics, breakers, tracer, serve_start, shard);
    } else {
        per_item_pool.append(&mut divergent_pool);
    }
    execute_per_item(
        &per_item_pool,
        backend,
        metrics,
        breakers,
        faults,
        tracer,
        serve_start,
        shard,
    );
}

/// Serve each request of a group on its own (no HF stacking): the ladder's
/// final tier — lone leftovers, breaker-demoted streams, half-open probes.
/// Every launch is panic-isolated.
#[allow(clippy::too_many_arguments)]
fn execute_per_item(
    group: &[Req],
    backend: &Backend,
    metrics: &mut Metrics,
    breakers: &mut BreakerBoard,
    faults: &Option<Arc<FaultInjector>>,
    tracer: Option<&Tracer>,
    serve_start: Instant,
    shard: u64,
) {
    for req in group {
        let key = Signature::of(&req.pipeline).stream_key();
        // plan consult first (cache lookup or compile; either way the plan
        // is cached for the launch below), so plan time and launch time are
        // separable in both the trace and the tier-time breakdown
        let plan_t0 = Instant::now();
        let plan_info = backend.plan_probe(&req.pipeline);
        if let Some((_, d)) = plan_info {
            metrics.tier_times.plan += d.as_micros() as u64;
        }
        let t0 = Instant::now();
        let run = exec::catch_launch(|| {
            if let Some(inj) = faults {
                inj.apply(FaultTier::PerItem, &key)?;
            }
            backend.run(&req.pipeline, &req.item)
        });
        let launch_dur = t0.elapsed();
        metrics.tier_times.per_item += launch_dur.as_micros() as u64;
        let launch = tracer.map(|_| {
            let (width, threads) = backend.launch_shape(&req.pipeline);
            LaunchInfo {
                start: t0,
                dur: launch_dur,
                elems: (req.pipeline.batch * req.pipeline.item_elems()) as u64,
                width,
                threads,
            }
        });
        let plan_span = plan_info.map(|(hit, d)| (plan_t0, d, hit));
        match run {
            Ok(t) => {
                metrics.note_service_cost(1, launch_dur);
                observe_launch(metrics, backend);
                metrics.batched_items += 1;
                breakers.record_success(&key);
                let reply_t0 = Instant::now();
                complete_ok(req, t, metrics);
                trace_finish(
                    tracer,
                    req,
                    serve_start,
                    trace::TIER_PER_ITEM,
                    1,
                    plan_span,
                    launch.as_ref(),
                    reply_t0,
                    shard,
                    None,
                );
            }
            Err(e) => {
                breakers.record_failure(&key);
                let err = serve_error(&e, metrics);
                let name = err_name(&err);
                let reply_t0 = Instant::now();
                fail_request(req, err, metrics);
                trace_finish(
                    tracer,
                    req,
                    serve_start,
                    trace::TIER_PER_ITEM,
                    1,
                    plan_span,
                    launch.as_ref(),
                    reply_t0,
                    shard,
                    Some(name),
                );
            }
        }
    }
}

/// Serve the whole remainder of a scheduling window — mixed params, mixed
/// signatures, mixed chain lengths; dense, structured and reduce streams
/// alike — as ONE divergent-HF pass. Per-item results are bit-equal to
/// per-item serving (the divergent tier's contract); a failing item fails
/// alone and never poisons the window (each item is panic-isolated inside
/// its lane).
fn execute_divergent(
    group: Vec<Req>,
    backend: &Backend,
    metrics: &mut Metrics,
    breakers: &mut BreakerBoard,
    tracer: Option<&Tracer>,
    serve_start: Instant,
    shard: u64,
) {
    let t0 = Instant::now();
    let window: Vec<(&Pipeline, &Tensor)> =
        group.iter().map(|r| (&r.pipeline, &r.item)).collect();
    let out = match exec::catch_launch(|| Ok(backend.run_many(&window))) {
        Ok(out) => out,
        Err(e) => {
            // the pass itself panicked outside any item's isolation: every
            // rider fails, every rider's stream records the failure
            metrics.tier_times.divergent += t0.elapsed().as_micros() as u64;
            let err = serve_error(&e, metrics);
            let name = err_name(&err);
            for req in &group {
                breakers.record_failure(&Signature::of(&req.pipeline).stream_key());
                let reply_t0 = Instant::now();
                fail_request(req, err.clone(), metrics);
                trace_finish(
                    tracer,
                    req,
                    serve_start,
                    trace::TIER_DIVERGENT,
                    group.len() as u64,
                    None,
                    None,
                    reply_t0,
                    shard,
                    Some(name),
                );
            }
            return;
        }
    };
    let pass_dur = t0.elapsed();
    metrics.tier_times.divergent += pass_dur.as_micros() as u64;
    metrics.launches += out.launches as u64;
    metrics.note_service_cost(group.len(), pass_dur);
    // only a genuine divergent pass counts in the tier's metrics — the XLA
    // front door serves signature-homogeneous leftovers per item through
    // the artifact path, and that traffic must not inflate occupancy
    if out.divergent_pass {
        metrics.divergent_windows += 1;
        metrics.divergent_items += group.len() as u64;
        metrics.divergent_work_elems += out.total_work_elems as u64;
        metrics.divergent_padded_elems += out.padded_work_elems as u64;
    }
    for (req, res) in group.iter().zip(out.results) {
        let key = Signature::of(&req.pipeline).stream_key();
        // per-rider launch info: the shared pass is the launch window, the
        // per-request element count and lane width individualize the span
        let launch = tracer.map(|_| LaunchInfo {
            start: t0,
            dur: pass_dur,
            elems: (req.pipeline.batch * req.pipeline.item_elems()) as u64,
            width: backend.launch_shape(&req.pipeline).0,
            threads: out.lanes as u64,
        });
        match res {
            Ok(t) => {
                metrics.batched_items += 1;
                breakers.record_success(&key);
                let reply_t0 = Instant::now();
                complete_ok(req, t, metrics);
                trace_finish(
                    tracer,
                    req,
                    serve_start,
                    trace::TIER_DIVERGENT,
                    window.len() as u64,
                    None,
                    launch.as_ref(),
                    reply_t0,
                    shard,
                    None,
                );
            }
            Err(e) => {
                breakers.record_failure(&key);
                let err = serve_error(&e, metrics);
                let name = err_name(&err);
                let reply_t0 = Instant::now();
                fail_request(req, err, metrics);
                trace_finish(
                    tracer,
                    req,
                    serve_start,
                    trace::TIER_DIVERGENT,
                    window.len() as u64,
                    None,
                    launch.as_ref(),
                    reply_t0,
                    shard,
                    Some(name),
                );
            }
        }
    }
}

/// Tier 1 — identical stacked HF. Validate one same-stream-key group, stack
/// the requests matching the head request (pipeline params-and-all) into a
/// bucket-sized batch (one allocation, one copy per item), run, slice
/// replies back out; return everything this tier could not serve. The
/// leftovers are divergent-tier traffic: param-divergent company (a stacked
/// launch binds ONE param set — company never silently inherits the head's
/// params), structured/reduce streams (their items are shared FRAMES or
/// per-request statistics, not stackable planes), streams whose backend
/// covers no bucket, and lone heads that would launch alone anyway. The
/// stacked launch is panic-isolated; a failure counts ONE breaker event
/// against the stream (the launch failed, not each rider independently).
#[allow(clippy::too_many_arguments)]
fn stack_tier(
    group: Vec<Req>,
    backend: &Backend,
    metrics: &mut Metrics,
    breakers: &mut BreakerBoard,
    faults: &Option<Arc<FaultInjector>>,
    tracer: Option<&Tracer>,
    serve_start: Instant,
    shard: u64,
) -> Vec<Req> {
    let fail_bad_item = |req: &Req, msg: String, metrics: &mut Metrics| {
        // client error: counted as failed, never against the breaker
        let reply_t0 = Instant::now();
        fail_request(req, ServeError::BadItem(msg), metrics);
        trace_finish(
            tracer,
            req,
            serve_start,
            trace::TIER_STACKED,
            1,
            None,
            None,
            reply_t0,
            shard,
            Some("BadItem"),
        );
    };
    if group[0].pipeline.has_structured_boundary() {
        // dtype is checkable up front; geometry is per-frame
        let proto_dtin = group[0].pipeline.dtin;
        let (group, malformed): (Vec<_>, Vec<_>) =
            group.into_iter().partition(|r| r.item.dtype() == proto_dtin);
        for req in &malformed {
            fail_bad_item(
                req,
                format!(
                    "item dtype {} does not match pipeline dtin {}",
                    req.item.dtype(),
                    proto_dtin
                ),
                metrics,
            );
        }
        return group;
    }

    // reject malformed items up front: the batcher groups by pipeline
    // signature only, so one wrong-dtype/shape item would otherwise poison
    // (or panic) the stacked launch for the whole group
    let proto_dtin = group[0].pipeline.dtin;
    let mut item_shape_want = vec![1usize];
    item_shape_want.extend_from_slice(&group[0].pipeline.shape);
    let (group, malformed): (Vec<_>, Vec<_>) = group.into_iter().partition(|r| {
        r.item.dtype() == proto_dtin && r.item.shape() == item_shape_want.as_slice()
    });
    for req in &malformed {
        fail_bad_item(
            req,
            format!(
                "item dtype {} shape {:?} does not match pipeline ({} {:?})",
                req.item.dtype(),
                req.item.shape(),
                proto_dtin,
                item_shape_want
            ),
            metrics,
        );
    }
    if group.is_empty() {
        return group;
    }

    // the batcher groups by the param-AGNOSTIC stream key (same code, one
    // launch — that is what HF wants), but a stacked launch binds ONE param
    // set: stack only the requests whose pipeline (params included) matches
    // the head request
    let head = group[0].pipeline.clone();
    let (group, mut divergent): (Vec<_>, Vec<_>) =
        group.into_iter().partition(|r| r.pipeline == head);

    // a lone head gains nothing from stacking — let it share the window's
    // divergent pass instead of launching alone
    if group.len() < 2 {
        divergent.extend(group);
        return divergent;
    }

    let m = group.len();
    let proto = &group[0].pipeline;
    // pick a bucket the backend can actually serve: prefer the smallest AOT
    // bucket >= m, then the exact group size
    let mut batched = None;
    let mut candidates = vec![m];
    if let Some(b) = hfusion::single_bucket(m, backend.buckets()) {
        candidates.insert(0, b);
    }
    for bucket in candidates {
        // re-batching an already-validated pipeline: same code, new HF width
        let cand = proto.with_batch(bucket);
        if backend.covers(&cand) {
            batched = Some((bucket, cand));
            break;
        }
    }
    let Some((bucket, batched)) = batched else {
        // no stackable bucket: the whole group is divergent-tier traffic
        divergent.extend(group);
        return divergent;
    };

    // stack items into the batch buffer directly (pad planes replicate the
    // last item) — no per-item clone + re-concat copy
    let items: Vec<&Tensor> = group.iter().map(|r| &r.item).collect();
    let input = stack_batch(&items, bucket, &proto.shape);
    let key = Signature::of(proto).stream_key();

    // plan consult before the launch so compile time is attributed to the
    // plan span, not smeared into the stacked-launch time
    let plan_t0 = Instant::now();
    let plan_info = backend.plan_probe(&batched);
    if let Some((_, d)) = plan_info {
        metrics.tier_times.plan += d.as_micros() as u64;
    }
    let plan_span = plan_info.map(|(hit, d)| (plan_t0, d, hit));

    let t0 = Instant::now();
    let run = exec::catch_launch(|| {
        if let Some(inj) = faults {
            inj.apply(FaultTier::Stacked, &key)?;
        }
        backend.run(&batched, &input)
    });
    let launch_dur = t0.elapsed();
    metrics.tier_times.stacked += launch_dur.as_micros() as u64;
    let launch = tracer.map(|_| {
        let (width, threads) = backend.launch_shape(&batched);
        LaunchInfo {
            start: t0,
            dur: launch_dur,
            elems: (batched.batch * batched.item_elems()) as u64,
            width,
            threads,
        }
    });
    match run {
        Ok(out) => {
            metrics.note_service_cost(m, launch_dur);
            observe_launch(metrics, backend);
            metrics.batched_items += m as u64;
            metrics.padded_planes += (bucket - m) as u64;
            breakers.record_success(&key);
            let item_elems: usize = out.len() / bucket;
            let item_shape: Vec<usize> = out.shape()[1..].to_vec();
            for (b, req) in group.iter().enumerate() {
                let t = slice_batch(&out, b, item_elems, &item_shape);
                let reply_t0 = Instant::now();
                complete_ok(req, t, metrics);
                trace_finish(
                    tracer,
                    req,
                    serve_start,
                    trace::TIER_STACKED,
                    m as u64,
                    plan_span,
                    launch.as_ref(),
                    reply_t0,
                    shard,
                    None,
                );
            }
        }
        Err(e) => {
            // one launch, one breaker event — then fail every rider typed
            breakers.record_failure(&key);
            let err = serve_error(&e, metrics);
            let name = err_name(&err);
            for req in &group {
                let reply_t0 = Instant::now();
                fail_request(req, err.clone(), metrics);
                trace_finish(
                    tracer,
                    req,
                    serve_start,
                    trace::TIER_STACKED,
                    m as u64,
                    plan_span,
                    launch.as_ref(),
                    reply_t0,
                    shard,
                    Some(name),
                );
            }
        }
    }
    divergent
}
