//! The coordinator service: bounded ingress, batching loop, fused execution.

use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::{BatchPolicy, Batcher, Metrics, MetricsSnapshot, PendingRequest};
use crate::exec::{slice_batch, stack_batch, DivergentOutcome, Engine, FusedEngine, HostFusedEngine};
use crate::fusion::{hfusion, PlannerStats};
use crate::ops::Pipeline;
use crate::tensor::Tensor;

/// One queued request as the service thread sees it.
type Req = PendingRequest<SyncSender<Result<Tensor, String>>>;

/// Which execution backend the service thread builds — the selection policy
/// now lives in [`crate::exec`] and is shared with [`crate::cv::Context`],
/// so every front door degrades identically.
pub use crate::exec::EngineSelect;

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Artifact directory (defaults to the repo's).
    pub artifact_dir: Option<std::path::PathBuf>,
    /// Ingress queue capacity — submissions beyond this are rejected
    /// (backpressure; the paper's pipelines drop frames rather than lag).
    pub queue_cap: usize,
    pub policy: BatchPolicy,
    pub engine: EngineSelect,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            artifact_dir: None,
            queue_cap: 1024,
            policy: BatchPolicy::default(),
            engine: EngineSelect::default(),
        }
    }
}

enum Msg {
    Request(PendingRequest<SyncSender<Result<Tensor, String>>>),
    Snapshot(SyncSender<MetricsSnapshot>),
    Shutdown,
}

#[derive(Debug, thiserror::Error)]
pub enum SubmitError {
    #[error("coordinator queue full (backpressure)")]
    QueueFull,
    #[error("coordinator stopped")]
    Stopped,
}

/// Handle to a running coordinator. Cloneable across threads; all XLA work
/// happens on the single service thread.
pub struct Service {
    tx: SyncSender<Msg>,
    handle: Option<JoinHandle<()>>,
}

impl Service {
    /// Start the service thread (loads the registry there — the PJRT client
    /// must live on that thread).
    pub fn start(cfg: ServiceConfig) -> Service {
        let (tx, rx) = sync_channel::<Msg>(cfg.queue_cap);
        let handle = std::thread::Builder::new()
            .name("fkl-coordinator".into())
            .spawn(move || service_loop(cfg, rx))
            .expect("spawn coordinator thread");
        Service { tx, handle: Some(handle) }
    }

    /// Submit one item; returns a receiver for the result. Non-blocking:
    /// fails fast under backpressure. Accepts the runtime [`Pipeline`] IR or
    /// a typed chain ([`crate::chain::TypedPipeline`]) — the coordinator is
    /// a chain front door like `cv`/`npp`. Dense pipelines take
    /// `[1, *shape]` items; structured chains (crop/resize reads) take the
    /// shared `[fh, fw, 3]` FRAME as the item. The scheduler auto-tiers
    /// every window: identical requests stack into one HF launch, the
    /// mixed remainder (different params, signatures, chain lengths —
    /// structured and reduce streams included) shares ONE divergent-HF
    /// pass, and a lone leftover serves per item.
    pub fn submit(
        &self,
        pipeline: impl Into<Pipeline>,
        item: Tensor,
    ) -> Result<Receiver<Result<Tensor, String>>, SubmitError> {
        let (rtx, rrx) = sync_channel(1);
        let req =
            PendingRequest { pipeline: pipeline.into(), item, enqueued: Instant::now(), reply: rtx };
        match self.tx.try_send(Msg::Request(req)) {
            Ok(()) => Ok(rrx),
            Err(TrySendError::Full(_)) => Err(SubmitError::QueueFull),
            Err(TrySendError::Disconnected(_)) => Err(SubmitError::Stopped),
        }
    }

    pub fn metrics(&self) -> Option<MetricsSnapshot> {
        let (tx, rx) = sync_channel(1);
        self.tx.send(Msg::Snapshot(tx)).ok()?;
        rx.recv().ok()
    }

    /// Graceful shutdown: drain pending work, then join.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// The service thread's execution backend: the XLA fused engine against the
/// artifact registry, or the everywhere-capable host fused engine.
enum Backend {
    Xla { engine: FusedEngine, buckets: Vec<usize> },
    Host { engine: HostFusedEngine, buckets: Vec<usize> },
}

const DEFAULT_BUCKETS: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];

impl Backend {
    fn buckets(&self) -> &[usize] {
        match self {
            Backend::Xla { buckets, .. } | Backend::Host { buckets, .. } => buckets,
        }
    }

    /// Can this backend serve the pipeline (used to pick an HF bucket)?
    fn covers(&self, p: &Pipeline) -> bool {
        match self {
            Backend::Xla { engine, .. } => engine.plan_for(p).is_ok(),
            // the host engine executes the whole element-wise vocabulary; the
            // one thing it refuses is HF-stacking a lane-structured (3-lane
            // pixel) stream whose items are not a whole number of pixels —
            // stacking would shift lane indices across items, silently
            // changing per-item results (those streams run per item instead)
            Backend::Host { engine, .. } => {
                let plan = engine.plan_for(p);
                p.batch == 1 || plan.group() == 1 || p.item_elems() % plan.group() == 0
            }
        }
    }

    fn run(&self, p: &Pipeline, input: &Tensor) -> Result<Tensor> {
        match self {
            Backend::Xla { engine, .. } => engine.run(p, input),
            Backend::Host { engine, .. } => engine.run(p, input),
        }
    }

    fn last_launches(&self) -> usize {
        match self {
            Backend::Xla { engine, .. } => engine.last_launches(),
            Backend::Host { engine, .. } => engine.last_launches(),
        }
    }

    fn last_was_fallback(&self) -> bool {
        match self {
            Backend::Xla { engine, .. } => engine.last_was_fallback(),
            Backend::Host { .. } => false,
        }
    }

    /// Serve a mixed window in one divergent-HF pass: natively on the host
    /// backend, detected-and-re-routed on the XLA front door.
    fn run_many(&self, window: &[(&Pipeline, &Tensor)]) -> DivergentOutcome {
        match self {
            Backend::Xla { engine, .. } => engine.run_many(window),
            Backend::Host { engine, .. } => engine.run_divergent(window),
        }
    }

    fn planner_stats(&self) -> PlannerStats {
        match self {
            Backend::Xla { engine, .. } => engine.planner_stats(),
            Backend::Host { engine, .. } => PlannerStats {
                host: engine.runs(),
                structured: engine.structured_runs(),
                reduction: engine.reduce_runs(),
                divergent: engine.divergent_runs(),
                ..PlannerStats::default()
            },
        }
    }
}

fn service_loop(cfg: ServiceConfig, rx: Receiver<Msg>) {
    let dir = cfg.artifact_dir.clone().unwrap_or_else(crate::default_artifact_dir);
    let host_backend = || Backend::Host {
        engine: HostFusedEngine::new(),
        buckets: DEFAULT_BUCKETS.to_vec(),
    };
    let backend = match cfg.engine {
        EngineSelect::HostFused => host_backend(),
        // without the pjrt feature there is no XLA to prefer
        EngineSelect::Auto if !cfg!(feature = "pjrt") => host_backend(),
        EngineSelect::Xla | EngineSelect::Auto => match crate::runtime::Registry::load(&dir) {
            Ok(r) => {
                let reg = std::rc::Rc::new(r);
                let buckets = reg.geometry["hf_batches"]
                    .as_usize_vec()
                    .unwrap_or_else(|| DEFAULT_BUCKETS.to_vec());
                Backend::Xla { engine: FusedEngine::new(reg), buckets }
            }
            Err(e) if cfg.engine == EngineSelect::Auto => {
                // degrade to the backend that runs everywhere, visibly
                eprintln!("fkl-coordinator: artifact registry unavailable ({e:#}); \
                           serving with the host fused engine");
                host_backend()
            }
            Err(e) => {
                // pinned-XLA poison: reply to every request with the error
                for msg in rx.iter() {
                    match msg {
                        Msg::Request(r) => {
                            let _ = r.reply.send(Err(format!("registry: {e}")));
                        }
                        Msg::Snapshot(tx) => {
                            let _ = tx.send(MetricsSnapshot::default());
                        }
                        Msg::Shutdown => break,
                    }
                }
                return;
            }
        },
    };
    let mut batcher = Batcher::new(cfg.policy);
    let mut metrics = Metrics::default();

    loop {
        // 1. ingest: wait until something arrives or the oldest group expires
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(Msg::Request(r)) => {
                batcher.push(r);
                // opportunistically drain whatever else is queued
                while let Ok(m) = rx.try_recv() {
                    match m {
                        Msg::Request(r) => batcher.push(r),
                        Msg::Snapshot(tx) => {
                            let _ = tx.send(snapshot(&mut metrics, &backend));
                        }
                        Msg::Shutdown => {
                            flush(&mut batcher, &backend, &mut metrics);
                            return;
                        }
                    }
                }
            }
            Ok(Msg::Snapshot(tx)) => {
                let _ = tx.send(snapshot(&mut metrics, &backend));
            }
            Ok(Msg::Shutdown) => {
                flush(&mut batcher, &backend, &mut metrics);
                return;
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                flush(&mut batcher, &backend, &mut metrics);
                return;
            }
        }

        // 2. launch: collect EVERY ready group into one scheduling window —
        // identical pipelines stack per group (tier 1), and the signature/
        // param-divergent remainder of the WHOLE window shares one
        // divergent-HF pass (tier 2) instead of degrading per item
        let now = Instant::now();
        let mut groups = Vec::new();
        while let Some(group) = batcher.pop_ready(now) {
            groups.push(group);
        }
        if !groups.is_empty() {
            serve_window(groups, &backend, &mut metrics);
        }
    }
}

fn snapshot(metrics: &mut Metrics, backend: &Backend) -> MetricsSnapshot {
    metrics.planner = backend.planner_stats();
    metrics.snapshot()
}

fn flush(
    batcher: &mut Batcher<SyncSender<Result<Tensor, String>>>,
    backend: &Backend,
    metrics: &mut Metrics,
) {
    let groups = batcher.drain_all();
    if !groups.is_empty() {
        serve_window(groups, backend, metrics);
    }
}

fn observe_launch(metrics: &mut Metrics, backend: &Backend) {
    metrics.launches += backend.last_launches() as u64;
    if backend.last_was_fallback() {
        metrics.unfused_fallbacks += 1;
    }
}

/// The coordinator's scheduling ladder, applied to one window (every group
/// that is ready right now):
///
/// 1. **identical stacked HF** — per group, requests matching the head
///    request (pipeline params-and-all) stack into one bucket launch;
/// 2. **divergent HF** — the merged remainder of ALL groups (param- and
///    signature-divergent company, structured/reduce streams, uncovered
///    buckets) serves in ONE thread-chunked pass;
/// 3. **per-item fallback** — a lone leftover launches alone.
fn serve_window(groups: Vec<Vec<Req>>, backend: &Backend, metrics: &mut Metrics) {
    let mut leftovers: Vec<Req> = Vec::new();
    for group in groups {
        leftovers.extend(stack_tier(group, backend, metrics));
    }
    if leftovers.len() >= 2 {
        execute_divergent(leftovers, backend, metrics);
    } else {
        execute_per_item(&leftovers, backend, metrics);
    }
}

/// Serve each request of a group on its own (no HF stacking): the ladder's
/// final tier, for a lone leftover.
fn execute_per_item(
    group: &[PendingRequest<SyncSender<Result<Tensor, String>>>],
    backend: &Backend,
    metrics: &mut Metrics,
) {
    for req in group {
        match backend.run(&req.pipeline, &req.item) {
            Ok(t) => {
                observe_launch(metrics, backend);
                metrics.batched_items += 1;
                metrics.observe_latency(req.enqueued.elapsed());
                let _ = req.reply.send(Ok(t));
            }
            Err(e) => {
                metrics.failed += 1;
                let _ = req.reply.send(Err(format!("{e:#}")));
            }
        }
    }
}

/// Serve the whole remainder of a scheduling window — mixed params, mixed
/// signatures, mixed chain lengths; dense, structured and reduce streams
/// alike — as ONE divergent-HF pass. Per-item results are bit-equal to
/// per-item serving (the divergent tier's contract); a failing item fails
/// alone and never poisons the window.
fn execute_divergent(group: Vec<Req>, backend: &Backend, metrics: &mut Metrics) {
    let window: Vec<(&Pipeline, &Tensor)> =
        group.iter().map(|r| (&r.pipeline, &r.item)).collect();
    let out = backend.run_many(&window);
    metrics.launches += out.launches as u64;
    // only a genuine divergent pass counts in the tier's metrics — the XLA
    // front door serves signature-homogeneous leftovers per item through
    // the artifact path, and that traffic must not inflate occupancy
    if out.divergent_pass {
        metrics.divergent_windows += 1;
        metrics.divergent_items += group.len() as u64;
        metrics.divergent_work_elems += out.total_work_elems as u64;
        metrics.divergent_padded_elems += out.padded_work_elems as u64;
    }
    for (req, res) in group.iter().zip(out.results) {
        match res {
            Ok(t) => {
                metrics.batched_items += 1;
                metrics.observe_latency(req.enqueued.elapsed());
                let _ = req.reply.send(Ok(t));
            }
            Err(e) => {
                metrics.failed += 1;
                let _ = req.reply.send(Err(format!("{e:#}")));
            }
        }
    }
}

/// Tier 1 — identical stacked HF. Validate one same-stream-key group, stack
/// the requests matching the head request (pipeline params-and-all) into a
/// bucket-sized batch (one allocation, one copy per item), run, slice
/// replies back out; return everything this tier could not serve. The
/// leftovers are divergent-tier traffic: param-divergent company (a stacked
/// launch binds ONE param set — company never silently inherits the head's
/// params), structured/reduce streams (their items are shared FRAMES or
/// per-request statistics, not stackable planes), streams whose backend
/// covers no bucket, and lone heads that would launch alone anyway.
fn stack_tier(group: Vec<Req>, backend: &Backend, metrics: &mut Metrics) -> Vec<Req> {
    if group[0].pipeline.has_structured_boundary() {
        // dtype is checkable up front; geometry is per-frame
        let proto_dtin = group[0].pipeline.dtin;
        let (group, malformed): (Vec<_>, Vec<_>) =
            group.into_iter().partition(|r| r.item.dtype() == proto_dtin);
        for req in &malformed {
            metrics.failed += 1;
            let _ = req.reply.send(Err(format!(
                "item dtype {} does not match pipeline dtin {}",
                req.item.dtype(),
                proto_dtin
            )));
        }
        return group;
    }

    // reject malformed items up front: the batcher groups by pipeline
    // signature only, so one wrong-dtype/shape item would otherwise poison
    // (or panic) the stacked launch for the whole group
    let proto_dtin = group[0].pipeline.dtin;
    let mut item_shape_want = vec![1usize];
    item_shape_want.extend_from_slice(&group[0].pipeline.shape);
    let (group, malformed): (Vec<_>, Vec<_>) = group.into_iter().partition(|r| {
        r.item.dtype() == proto_dtin && r.item.shape() == item_shape_want.as_slice()
    });
    for req in &malformed {
        metrics.failed += 1;
        let _ = req.reply.send(Err(format!(
            "item dtype {} shape {:?} does not match pipeline ({} {:?})",
            req.item.dtype(),
            req.item.shape(),
            proto_dtin,
            item_shape_want
        )));
    }
    if group.is_empty() {
        return group;
    }

    // the batcher groups by the param-AGNOSTIC stream key (same code, one
    // launch — that is what HF wants), but a stacked launch binds ONE param
    // set: stack only the requests whose pipeline (params included) matches
    // the head request
    let head = group[0].pipeline.clone();
    let (group, mut divergent): (Vec<_>, Vec<_>) =
        group.into_iter().partition(|r| r.pipeline == head);

    // a lone head gains nothing from stacking — let it share the window's
    // divergent pass instead of launching alone
    if group.len() < 2 {
        divergent.extend(group);
        return divergent;
    }

    let m = group.len();
    let proto = &group[0].pipeline;
    // pick a bucket the backend can actually serve: prefer the smallest AOT
    // bucket >= m, then the exact group size
    let mut batched = None;
    let mut candidates = vec![m];
    if let Some(b) = hfusion::single_bucket(m, backend.buckets()) {
        candidates.insert(0, b);
    }
    for bucket in candidates {
        // re-batching an already-validated pipeline: same code, new HF width
        let cand = proto.with_batch(bucket);
        if backend.covers(&cand) {
            batched = Some((bucket, cand));
            break;
        }
    }
    let Some((bucket, batched)) = batched else {
        // no stackable bucket: the whole group is divergent-tier traffic
        divergent.extend(group);
        return divergent;
    };

    // stack items into the batch buffer directly (pad planes replicate the
    // last item) — no per-item clone + re-concat copy
    let items: Vec<&Tensor> = group.iter().map(|r| &r.item).collect();
    let input = stack_batch(&items, bucket, &proto.shape);

    match backend.run(&batched, &input) {
        Ok(out) => {
            observe_launch(metrics, backend);
            metrics.batched_items += m as u64;
            metrics.padded_planes += (bucket - m) as u64;
            let item_elems: usize = out.len() / bucket;
            let item_shape: Vec<usize> = out.shape()[1..].to_vec();
            for (b, req) in group.iter().enumerate() {
                let t = slice_batch(&out, b, item_elems, &item_shape);
                metrics.observe_latency(req.enqueued.elapsed());
                let _ = req.reply.send(Ok(t));
            }
        }
        Err(e) => {
            metrics.failed += group.len() as u64;
            for req in &group {
                let _ = req.reply.send(Err(format!("{e:#}")));
            }
        }
    }
    divergent
}
