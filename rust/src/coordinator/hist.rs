//! HDR-style log-bucketed histogram backing the latency/margin percentiles.
//!
//! The previous reservoir (fixed 4096 samples, overwrite-oldest) made p999
//! meaningless under sustained load: a 1-in-10k outlier is overwritten long
//! before anyone snapshots, so the tail silently reads as the body. This
//! histogram keeps EVERY observation forever in 64 power-of-√2 buckets
//! (~±41% value resolution — the HDR-histogram trade): counts are exact,
//! quantiles are bucket-resolution, `max` and `mean` are tracked exactly on
//! the side. Recording is two integer ops and an array increment — cheaper
//! than the reservoir it replaces, and the memory is a fixed 64×8 bytes.

/// Bucket count: boundaries at √2^i cover 1us..~2^31.5us (≈51 hours) in 64
/// buckets; larger values clamp into the last bucket.
pub const BUCKETS: usize = 64;

const SQRT2_NUM: u128 = 1_414_214;
const SQRT2_DEN: u128 = 1_000_000;

/// Log-bucketed histogram of microsecond values.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LogHistogram {
    counts: [u64; BUCKETS],
    count: u64,
    sum: u128,
    max: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram { counts: [0; BUCKETS], count: 0, sum: 0, max: 0 }
    }

    /// Bucket index of `v`: `floor(2·log2(v))`, i.e. boundaries at powers
    /// of √2 (0 and 1 share bucket 0).
    pub fn bucket_of(v: u64) -> usize {
        if v < 2 {
            return 0;
        }
        let msb = 63 - v.leading_zeros() as usize;
        let base = 1u64 << msb;
        // the half-step boundary between 2^msb and 2^(msb+1) sits at
        // 2^msb·√2; integer-compare against the √2 rational
        let upper_half = (v as u128) * SQRT2_DEN >= (base as u128) * SQRT2_NUM;
        (2 * msb + upper_half as usize).min(BUCKETS - 1)
    }

    /// Lower bound (us) of bucket `i` — the value a quantile inside that
    /// bucket reports.
    pub fn bucket_floor(i: usize) -> u64 {
        if i == 0 {
            return 0;
        }
        let base = 1u64 << (i / 2);
        if i % 2 == 0 {
            base
        } else {
            ((base as u128 * SQRT2_NUM) / SQRT2_DEN) as u64
        }
    }

    pub fn record(&mut self, v_us: u64) {
        self.counts[Self::bucket_of(v_us)] += 1;
        self.count += 1;
        self.sum += v_us as u128;
        if v_us > self.max {
            self.max = v_us;
        }
    }

    /// Total observations (never capped — the histogram forgets nothing).
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact maximum observed value.
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Exact mean (sum and count are tracked exactly).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Value at quantile `q` (0..=1), at bucket resolution: the floor of the
    /// bucket holding the rank, clamped to the exact max. Matches the
    /// rank rule of `LatencyStats::from_sorted` (`floor((n-1)·q)`).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((self.count - 1) as f64 * q.clamp(0.0, 1.0)).floor() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen > rank {
                return Self::bucket_floor(i).min(self.max);
            }
        }
        self.max
    }

    /// Raw bucket counts (dashboards / JSON export).
    pub fn bucket_counts(&self) -> &[u64; BUCKETS] {
        &self.counts
    }

    /// Fold another histogram into this one. Exact: bucket counts add,
    /// count/sum add, max takes the max — merging N shard histograms then
    /// taking quantiles gives the same answer as one histogram having
    /// recorded all the observations (the merge seam of the sharded
    /// coordinator's metrics).
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_monotone_and_cover_u64() {
        let mut last = 0;
        for v in [0u64, 1, 2, 3, 5, 8, 23, 64, 91, 1000, 1 << 20, 1 << 40, u64::MAX] {
            let b = LogHistogram::bucket_of(v);
            assert!(b >= last, "bucket_of must be monotone (v={v})");
            assert!(b < BUCKETS);
            last = b;
        }
        // every value sits at or above its bucket's floor and below (or at
        // the integer floor of) the next boundary
        for v in [1u64, 7, 45, 46, 50, 1023, 1024, 123_456_789] {
            let b = LogHistogram::bucket_of(v);
            assert!(LogHistogram::bucket_floor(b) <= v, "floor({b}) > {v}");
            if b + 1 < BUCKETS {
                assert!(LogHistogram::bucket_floor(b + 1) >= v, "v={v} beyond its bucket");
            }
        }
    }

    #[test]
    fn bucket_resolution_is_within_sqrt2() {
        // power-of-√2 boundaries: a quantile under-reports by at most ~41%
        // (integer floors distort the tiny buckets below 16us; skip them)
        for i in 8..BUCKETS - 1 {
            let lo = LogHistogram::bucket_floor(i) as f64;
            let hi = LogHistogram::bucket_floor(i + 1) as f64;
            assert!(hi / lo < 1.5, "bucket {i} wider than √2: {lo}..{hi}");
        }
    }

    #[test]
    fn exact_max_mean_count_survive_bucketing() {
        let mut h = LogHistogram::new();
        for v in [10u64, 20, 30, 1_000_000] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.max(), 1_000_000, "max is exact, not bucket-rounded");
        assert!((h.mean() - 250_015.0).abs() < 1e-9, "mean is exact");
    }

    #[test]
    fn tail_quantiles_keep_rare_outliers() {
        // THE regression the histogram exists for: a 1-in-10k outlier must
        // survive 100k observations (the 4096-sample reservoir overwrote it)
        let mut h = LogHistogram::new();
        for i in 0..100_000u64 {
            h.record(if i % 10_000 == 0 { 1_000_000 } else { 50 });
        }
        assert_eq!(h.count(), 100_000, "nothing is ever dropped");
        assert_eq!(h.max(), 1_000_000, "the outlier is still visible");
        // 10 outliers occupy ranks 99990..99999: any quantile whose rank
        // reaches them reports the outlier bucket (q=0.99995 -> rank 99994)
        assert!(h.quantile(0.99995) >= 500_000, "tail={}", h.quantile(0.99995));
        assert!(h.quantile(1.0) >= 500_000);
        // body quantiles stay in the body bucket (50us floor is 45us)
        assert!(h.quantile(0.5) <= 50 && h.quantile(0.5) >= 32);
    }

    #[test]
    fn p999_separates_a_slow_tail_from_the_body() {
        let mut h = LogHistogram::new();
        for i in 0..100_000u64 {
            // 0.2% of requests are 100x slower
            h.record(if i % 500 == 0 { 5_000 } else { 50 });
        }
        assert!(h.quantile(0.999) >= 4_000, "p999={}", h.quantile(0.999));
        assert!(h.quantile(0.99) <= 64, "p99 stays in the body");
    }

    #[test]
    fn merge_equals_recording_everything_in_one_histogram() {
        let (mut a, mut b, mut whole) = (LogHistogram::new(), LogHistogram::new(), LogHistogram::new());
        for i in 0..1000u64 {
            let v = if i % 100 == 0 { 250_000 } else { 40 + i % 17 };
            if i % 2 == 0 { a.record(v) } else { b.record(v) }
            whole.record(v);
        }
        a.merge(&b);
        assert_eq!(a, whole, "bucket-exact merge: same counts, sum, max");
        assert_eq!(a.quantile(0.999), whole.quantile(0.999));
        // merging an empty histogram is the identity
        let before = a.clone();
        a.merge(&LogHistogram::new());
        assert_eq!(a, before);
    }

    #[test]
    fn empty_histogram_reads_zero() {
        let h = LogHistogram::new();
        assert_eq!((h.count(), h.max(), h.quantile(0.999)), (0, 0, 0));
        assert_eq!(h.mean(), 0.0);
    }
}
