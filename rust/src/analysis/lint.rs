//! Typed lint diagnostics over the erased pipeline IR.
//!
//! Every rule has a stable `FKL###` code, a severity, and a stage-index
//! span, with both a human rendering (`Display`) and a machine shape
//! (`Diagnostic::to_json`). The linter never mutates the pipeline — it is a
//! pure read of the IR (pinned by `rust/tests/analysis_props.rs`).
//!
//! Rule table (rewrite-safety classes live in [`super::canon`]):
//!
//! | code   | severity | rule |
//! |--------|----------|------|
//! | FKL001 | warning  | identity op (dead stage) |
//! | FKL002 | warning  | self-cancelling / redundant adjacent pair |
//! | FKL003 | warning  | redundant cast chain (duplicate or lossless round trip) |
//! | FKL004 | warning  | narrowing cast round trip (precision-loss intent) |
//! | FKL005 | warning  | integer write saturation hazard |
//! | FKL006 | warning  | NaN flows into a Min/Max reduce seal |
//! | FKL007 | error    | poisonous parameter (NaN/inf scalar, division by zero) |
//! | FKL008 | info     | tier prediction (who serves, why artifacts refuse) |
//! | FKL009 | info     | bit-changing fold available (never auto-applied) |

use std::fmt;

use crate::jsonlite::Value;
use crate::ops::{IOp, Opcode, Pipeline, ReduceKind};
use crate::tensor::DType;

use super::canon::{identity_of, widens_losslessly, IdentityClass};
use super::tier::predict_tier;

/// Diagnostic severity. `Error` means the chain computes garbage on every
/// input; `Warn` means a likely mistake or silent hazard; `Info` is
/// advisory.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Info,
    Warn,
    Error,
}

impl Severity {
    pub fn name(self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warn => "warning",
            Severity::Error => "error",
        }
    }
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Stable rule identity. The `FKL###` string is the public contract
/// (CLI output, CI greps); the enum is the in-process shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RuleCode {
    /// FKL001: identity op — the stage changes nothing.
    IdentityOp,
    /// FKL002: self-cancelling or redundant adjacent pair.
    RedundantPair,
    /// FKL003: redundant cast chain (duplicate, or lossless round trip).
    RedundantCast,
    /// FKL004: narrowing cast round trip — interior casts are free, so the
    /// truncation the chain appears to ask for never happens.
    NarrowingRoundTrip,
    /// FKL005: computed range exceeds the integer write range (silent
    /// saturation at the boundary).
    SaturationHazard,
    /// FKL006: the body can produce NaN and the pipeline seals with a
    /// Min/Max reduce, whose IEEE fold silently skips NaN elements.
    NanIntoMinMaxReduce,
    /// FKL007: poisonous scalar parameter (NaN, infinity, division by zero).
    PoisonParam,
    /// FKL008: static tier prediction.
    TierPrediction,
    /// FKL009: a bit-changing fold is available (report-only).
    FoldAvailable,
}

impl RuleCode {
    pub fn code(self) -> &'static str {
        match self {
            RuleCode::IdentityOp => "FKL001",
            RuleCode::RedundantPair => "FKL002",
            RuleCode::RedundantCast => "FKL003",
            RuleCode::NarrowingRoundTrip => "FKL004",
            RuleCode::SaturationHazard => "FKL005",
            RuleCode::NanIntoMinMaxReduce => "FKL006",
            RuleCode::PoisonParam => "FKL007",
            RuleCode::TierPrediction => "FKL008",
            RuleCode::FoldAvailable => "FKL009",
        }
    }

    pub fn severity(self) -> Severity {
        match self {
            RuleCode::PoisonParam => Severity::Error,
            RuleCode::TierPrediction | RuleCode::FoldAvailable => Severity::Info,
            _ => Severity::Warn,
        }
    }
}

/// A body-stage span `[start, end)`. Zero-width spans (`start == end`) mark
/// cast positions, which sit BETWEEN stages: `at == i` is the gap before
/// stage `i`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    pub start: usize,
    pub end: usize,
}

impl Span {
    /// The single body stage `i`.
    pub fn stage(i: usize) -> Span {
        Span { start: i, end: i + 1 }
    }

    /// The cast gap before body stage `i`.
    pub fn at(i: usize) -> Span {
        Span { start: i, end: i }
    }
}

impl fmt::Display for Span {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.end.saturating_sub(self.start) {
            0 => write!(f, "cast@{}", self.start),
            1 => write!(f, "stage {}", self.start),
            _ => write!(f, "stages {}..{}", self.start, self.end),
        }
    }
}

/// One lint finding: typed code + severity + span + human message.
#[derive(Debug, Clone, PartialEq)]
pub struct Diagnostic {
    pub code: RuleCode,
    pub severity: Severity,
    pub span: Span,
    pub message: String,
}

impl Diagnostic {
    fn new(code: RuleCode, span: Span, message: String) -> Diagnostic {
        Diagnostic { code, severity: code.severity(), span, message }
    }

    /// Machine shape (the `fkl lint --json` contract).
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("code", Value::str(self.code.code())),
            ("severity", Value::str(self.severity.name())),
            ("start", Value::num(self.span.start as f64)),
            ("end", Value::num(self.span.end as f64)),
            ("message", Value::str(&self.message)),
        ])
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}[{}] {}: {}", self.severity, self.code.code(), self.span, self.message)
    }
}

/// A conservative value interval propagated through the body, used by the
/// saturation and NaN-hazard heuristics. Infinite bounds mean "any finite
/// value of that sign" (float inputs); `nan` tracks whether any element can
/// become NaN.
#[derive(Debug, Clone, Copy)]
struct Interval {
    lo: f64,
    hi: f64,
    nan: bool,
}

impl Interval {
    fn of_dtype(dt: DType) -> Interval {
        match dt {
            DType::U8 => Interval { lo: 0.0, hi: 255.0, nan: false },
            DType::U16 => Interval { lo: 0.0, hi: 65535.0, nan: false },
            DType::I32 => Interval { lo: i32::MIN as f64, hi: i32::MAX as f64, nan: false },
            DType::F32 | DType::F64 => {
                Interval { lo: f64::NEG_INFINITY, hi: f64::INFINITY, nan: false }
            }
        }
    }

    fn abs(self) -> Interval {
        let lo = if self.lo <= 0.0 && self.hi >= 0.0 {
            0.0
        } else {
            self.lo.abs().min(self.hi.abs())
        };
        Interval { lo, hi: self.lo.abs().max(self.hi.abs()), nan: self.nan }
    }

    fn apply(self, op: Opcode, param: f64) -> Interval {
        let Interval { lo, hi, nan } = self;
        match op {
            Opcode::Nop => self,
            Opcode::Add => Interval { lo: lo + param, hi: hi + param, nan: nan || param.is_nan() },
            Opcode::Sub => Interval { lo: lo - param, hi: hi - param, nan: nan || param.is_nan() },
            Opcode::Mul => {
                if param == 0.0 {
                    // the domain is finite values: x * 0 is a signed zero
                    return Interval { lo: 0.0, hi: 0.0, nan };
                }
                let (a, b) = (lo * param, hi * param);
                Interval { lo: a.min(b), hi: a.max(b), nan: nan || param.is_nan() }
            }
            Opcode::Div => {
                if param == 0.0 {
                    // x/0 is ±inf; 0/0 is NaN whenever 0 is in the domain
                    return Interval {
                        lo: f64::NEG_INFINITY,
                        hi: f64::INFINITY,
                        nan: nan || (lo <= 0.0 && hi >= 0.0),
                    };
                }
                let (a, b) = (lo / param, hi / param);
                Interval { lo: a.min(b), hi: a.max(b), nan: nan || param.is_nan() }
            }
            Opcode::Abs => self.abs(),
            Opcode::Neg => Interval { lo: -hi, hi: -lo, nan },
            // IEEE min/max return the non-NaN side, so a NaN input is
            // cleared unless the parameter itself is NaN
            Opcode::Min => {
                Interval { lo: lo.min(param), hi: hi.min(param), nan: nan && param.is_nan() }
            }
            Opcode::Max => {
                Interval { lo: lo.max(param), hi: hi.max(param), nan: nan && param.is_nan() }
            }
            Opcode::Sqrt => {
                let a = self.abs();
                Interval { lo: a.lo.sqrt(), hi: a.hi.sqrt(), nan }
            }
            Opcode::Exp => Interval { lo: lo.exp(), hi: hi.exp(), nan },
            Opcode::Log => {
                let a = self.abs();
                Interval { lo: (a.lo + 1.0).ln(), hi: (a.hi + 1.0).ln(), nan }
            }
            Opcode::Clamp01 => {
                Interval { lo: lo.clamp(0.0, 1.0), hi: hi.clamp(0.0, 1.0), nan }
            }
        }
    }

    fn apply_iop(self, op: &IOp) -> Interval {
        match op {
            IOp::Compute { op, param } => self.apply(*op, *param),
            IOp::ComputeC3 { op, param } => {
                // hull over the three per-lane parameters
                let mut out = self.apply(*op, f64::from(param[0]));
                for &q in &param[1..] {
                    let lane = self.apply(*op, f64::from(q));
                    out = Interval {
                        lo: out.lo.min(lane.lo),
                        hi: out.hi.max(lane.hi),
                        nan: out.nan || lane.nan,
                    };
                }
                out
            }
            // a swizzle moves values between lanes but changes none of them
            IOp::CvtColor => self,
            IOp::Mem(_) => self,
        }
    }
}

/// Lint a pipeline: pure, typed, ordered (per-stage rules first, then pair
/// rules, cast rules, whole-chain hazards, and the tier prediction last).
pub fn lint(p: &Pipeline) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let body = p.body();

    // FKL001 / FKL007 — per-stage scalar rules
    for (i, stage) in body.iter().enumerate() {
        let IOp::Compute { op, param } = stage else { continue };
        let (op, param) = (*op, *param);
        if let Some((class, why)) = identity_of(op, param) {
            let note = if class == IdentityClass::Exact {
                "the canonicalizer removes it"
            } else {
                "removal is not bit-safe, so the canonicalizer only reports it"
            };
            out.push(Diagnostic::new(
                RuleCode::IdentityOp,
                Span::stage(i),
                format!("{}({param}) is an identity: {why} ({note})", op.name()),
            ));
        } else if op.takes_param() {
            let arith = matches!(op, Opcode::Add | Opcode::Sub | Opcode::Mul | Opcode::Div);
            if param.is_nan() && arith {
                out.push(Diagnostic::new(
                    RuleCode::PoisonParam,
                    Span::stage(i),
                    format!("{}(NaN): every element that flows through becomes NaN", op.name()),
                ));
            } else if param.is_infinite() && arith {
                out.push(Diagnostic::new(
                    RuleCode::PoisonParam,
                    Span::stage(i),
                    format!(
                        "{}({param}): a non-finite parameter saturates the whole chain \
                         to infinity (NaN where cancellation hits)",
                        op.name()
                    ),
                ));
            } else if op == Opcode::Div && param == 0.0 {
                out.push(Diagnostic::new(
                    RuleCode::PoisonParam,
                    Span::stage(i),
                    "div(0): every element becomes ±inf, and NaN at zero".to_string(),
                ));
            }
        }
    }

    // FKL002 / FKL009 — adjacent pair rules
    for i in 0..body.len().saturating_sub(1) {
        match (&body[i], &body[i + 1]) {
            (IOp::Compute { op: Opcode::Neg, .. }, IOp::Compute { op: Opcode::Neg, .. }) => {
                out.push(Diagnostic::new(
                    RuleCode::RedundantPair,
                    Span { start: i, end: i + 2 },
                    "neg;neg cancels to nothing (double sign flip)".to_string(),
                ));
            }
            (IOp::Compute { op: Opcode::Abs, .. }, IOp::Compute { op: Opcode::Abs, .. }) => {
                out.push(Diagnostic::new(
                    RuleCode::RedundantPair,
                    Span { start: i, end: i + 2 },
                    "abs;abs: the second abs never sees a negative value".to_string(),
                ));
            }
            (
                IOp::Compute { op: Opcode::Clamp01, .. },
                IOp::Compute { op: Opcode::Clamp01, .. },
            ) => {
                out.push(Diagnostic::new(
                    RuleCode::RedundantPair,
                    Span { start: i, end: i + 2 },
                    "clamp01;clamp01: the second clamp is redundant".to_string(),
                ));
            }
            (IOp::CvtColor, IOp::CvtColor) => {
                out.push(Diagnostic::new(
                    RuleCode::RedundantPair,
                    Span { start: i, end: i + 2 },
                    "cvtcolor;cvtcolor restores the original channel layout".to_string(),
                ));
            }
            (IOp::Compute { op: a, param: pa }, IOp::Compute { op: b, param: pb })
                if (*a == Opcode::Mul && *b == Opcode::Mul)
                    || (*a == Opcode::Add && *b == Opcode::Add) =>
            {
                let folded = if *a == Opcode::Mul { pa * pb } else { pa + pb };
                out.push(Diagnostic::new(
                    RuleCode::FoldAvailable,
                    Span { start: i, end: i + 2 },
                    format!(
                        "{}({pa});{}({pb}) folds to {}({folded}), but one rounding \
                         instead of two changes bits — never auto-applied",
                        a.name(),
                        b.name(),
                        a.name()
                    ),
                ));
            }
            _ => {}
        }
    }

    // FKL003 / FKL004 — cast-trace rules
    let trace = p.cast_trace();
    let mut cur = p.dtin;
    for (k, step) in trace.iter().enumerate() {
        if step.to == cur {
            out.push(Diagnostic::new(
                RuleCode::RedundantCast,
                Span::at(step.at),
                format!("cast to {} is a no-op: the chain is already {}", step.to, cur),
            ));
        } else if k > 0 && trace[k - 1].at == step.at {
            // adjacent casts with no compute op between them: A -> B -> C
            let a = if k >= 2 { trace[k - 2].to } else { p.dtin };
            let b = trace[k - 1].to;
            if step.to == a {
                if widens_losslessly(a, b) {
                    out.push(Diagnostic::new(
                        RuleCode::RedundantCast,
                        Span::at(step.at),
                        format!(
                            "cast {a}->{b}->{a} round-trips losslessly: both casts are \
                             dead (the canonicalizer removes them)"
                        ),
                    ));
                } else {
                    out.push(Diagnostic::new(
                        RuleCode::NarrowingRoundTrip,
                        Span::at(step.at),
                        format!(
                            "cast {a}->{b}->{a} round-trips through a narrower marker \
                             type: interior casts are free, so NO truncation happens at \
                             run time — if truncation to {b} was intended, this chain \
                             does not perform it"
                        ),
                    ));
                }
            }
        }
        cur = step.to;
    }

    // FKL005 / FKL006 — whole-chain range hazards
    let iv = body.iter().fold(Interval::of_dtype(p.dtin), Interval::apply_iop);
    if let Some(max) = p.dtout.saturate_max() {
        let over = iv.hi > max && iv.hi.is_finite();
        let under = iv.lo < 0.0 && iv.lo.is_finite();
        if over || under {
            out.push(Diagnostic::new(
                RuleCode::SaturationHazard,
                Span { start: 0, end: body.len() },
                format!(
                    "computed range [{}, {}] exceeds the {} write range [0, {max}]: \
                     out-of-range values saturate silently at the write boundary",
                    iv.lo, iv.hi, p.dtout
                ),
            ));
        }
    }
    if let Some(spec) = p.reduction() {
        let minmax = (0..spec.stat_count())
            .map(|i| spec.stat(i))
            .find(|k| matches!(k, ReduceKind::Min | ReduceKind::Max));
        if let Some(kind) = minmax {
            if iv.nan {
                out.push(Diagnostic::new(
                    RuleCode::NanIntoMinMaxReduce,
                    Span { start: 0, end: body.len() },
                    format!(
                        "the body can produce NaN and the pipeline seals with a {kind} \
                         reduce: the IEEE fold SKIPS NaN elements, so the statistic \
                         silently reflects only the non-NaN values"
                    ),
                ));
            }
        }
    }

    // FKL008 — tier prediction
    let t = predict_tier(p);
    let msg = match &t.artifact_refusal {
        Some(why) => format!(
            "serves on the {} tier (host accumulator {:?}, lane width {}, \
             {} fused bytes vs {} op-at-a-time, {:.1}x efficiency); \
             artifact tiers refuse: {why}",
            t.tier,
            t.accum,
            t.lane_width,
            t.bytes_fused,
            t.bytes_baseline,
            t.fusion_efficiency()
        ),
        None => format!(
            "dense chain: artifact-tier eligible (registry decides exact/staticloop/\
             interp; host fused fallback, accumulator {:?}, lane width {}, \
             {} fused bytes vs {} op-at-a-time, {:.1}x efficiency)",
            t.accum,
            t.lane_width,
            t.bytes_fused,
            t.bytes_baseline,
            t.fusion_efficiency()
        ),
    };
    out.push(Diagnostic::new(RuleCode::TierPrediction, Span { start: 0, end: body.len() }, msg));

    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{CastStep, MemOp, ReduceAxis, ReduceSpec};

    fn codes(diags: &[Diagnostic]) -> Vec<&'static str> {
        diags.iter().map(|d| d.code.code()).collect()
    }

    #[test]
    fn identity_pair_and_poison_rules_fire_with_codes_and_severities() {
        let p = Pipeline::from_opcodes(
            &[
                (Opcode::Mul, 1.0),
                (Opcode::Neg, 0.0),
                (Opcode::Neg, 0.0),
                (Opcode::Div, 0.0),
            ],
            &[4],
            1,
            DType::F32,
            DType::F32,
        )
        .unwrap();
        let diags = lint(&p);
        assert!(codes(&diags).contains(&"FKL001"));
        assert!(codes(&diags).contains(&"FKL002"));
        assert!(codes(&diags).contains(&"FKL007"));
        let poison = diags.iter().find(|d| d.code == RuleCode::PoisonParam).unwrap();
        assert_eq!(poison.severity, Severity::Error);
        assert_eq!(poison.span, Span::stage(3));
        let rendered = poison.to_string();
        assert!(rendered.starts_with("error[FKL007] stage 3:"), "{rendered}");
    }

    #[test]
    fn fold_suggestions_and_saturation_hazards() {
        let p = Pipeline::from_opcodes(
            &[(Opcode::Mul, 2.0), (Opcode::Mul, 3.0)],
            &[4],
            1,
            DType::U8,
            DType::U8,
        )
        .unwrap();
        let diags = lint(&p);
        let fold = diags.iter().find(|d| d.code == RuleCode::FoldAvailable).unwrap();
        assert_eq!(fold.severity, Severity::Info);
        assert!(fold.message.contains("mul(6)"), "{}", fold.message);
        let sat = diags.iter().find(|d| d.code == RuleCode::SaturationHazard).unwrap();
        assert!(sat.message.contains("255"), "{}", sat.message);
    }

    #[test]
    fn cast_rules_separate_lossless_from_narrowing_round_trips() {
        let base = Pipeline::from_opcodes(
            &[(Opcode::Mul, 2.0)],
            &[4],
            1,
            DType::U8,
            DType::F64,
        )
        .unwrap();
        let lossless = base.clone().with_cast_trace(vec![
            CastStep { at: 0, to: DType::F32 },
            CastStep { at: 0, to: DType::U8 },
        ]);
        let diags = lint(&lossless);
        assert!(codes(&diags).contains(&"FKL003"));
        assert!(!codes(&diags).contains(&"FKL004"));

        let narrowing = Pipeline::from_opcodes(
            &[(Opcode::Mul, 2.0)],
            &[4],
            1,
            DType::F64,
            DType::F64,
        )
        .unwrap()
        .with_cast_trace(vec![
            CastStep { at: 0, to: DType::F32 },
            CastStep { at: 0, to: DType::F64 },
        ]);
        let diags = lint(&narrowing);
        assert!(codes(&diags).contains(&"FKL004"));
    }

    #[test]
    fn nan_hazard_fires_only_for_minmax_reduce_seals() {
        let mk = |spec: ReduceSpec, div: f64| {
            Pipeline::new(
                vec![
                    IOp::Mem(MemOp::Read { dtype: DType::F32 }),
                    IOp::compute(Opcode::Div, div),
                    IOp::Mem(MemOp::Reduce { spec }),
                ],
                vec![4],
                1,
                DType::F32,
                DType::F64,
            )
            .unwrap()
        };
        let max_seal = ReduceSpec::single(ReduceKind::Max, ReduceAxis::Full);
        let mean_seal = ReduceSpec::single(ReduceKind::Mean, ReduceAxis::Full);
        assert!(codes(&lint(&mk(max_seal, 0.0))).contains(&"FKL006"));
        // mean seal: NaN POISONS the sum, it is not skipped — different bug,
        // still FKL007, but no FKL006
        assert!(!codes(&lint(&mk(mean_seal, 0.0))).contains(&"FKL006"));
        // finite divisor: no NaN source at all
        assert!(!codes(&lint(&mk(max_seal, 2.0))).contains(&"FKL006"));
    }

    #[test]
    fn every_lint_run_ends_with_a_tier_prediction() {
        let p = Pipeline::from_opcodes(&[(Opcode::Mul, 2.0)], &[4], 1, DType::U8, DType::F32)
            .unwrap();
        let diags = lint(&p);
        let last = diags.last().unwrap();
        assert_eq!(last.code, RuleCode::TierPrediction);
        assert_eq!(last.severity, Severity::Info);
        assert!(last.message.contains("artifact-tier eligible"), "{}", last.message);
    }
}
