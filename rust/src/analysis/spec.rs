//! Typed parser for textual chain specs (`fkl lint`). The main CLI's
//! builders panic on malformed input (they are demo drivers); the lint
//! subcommand is a front door for ARBITRARY user chains, so every malformed
//! spec must come back as a typed [`SpecError`], never a panic — the same
//! contract ROADMAP item 5's wire-format ingestion will need.
//!
//! Grammar (comma-separated tokens):
//!
//! ```text
//! mul:0.5,add:1.0,cvtcolor,cast:f32,sqrt
//! ```
//!
//! * `name` or `name:param` — a scalar opcode (param defaults to 1.0);
//! * `cvtcolor` — the channel swizzle;
//! * `cast:<dtype>` — a marker-type cast at the current position, recorded
//!   in the pipeline's cast trace for the cast lints.

use crate::ops::{CastStep, IOp, Opcode, Pipeline};
use crate::tensor::DType;

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum SpecError {
    #[error("chain spec is empty")]
    Empty,
    #[error("unknown op '{0}' (expected an opcode name, 'cvtcolor', or 'cast:<dtype>')")]
    UnknownOp(String),
    #[error("op '{op}' has a malformed parameter '{raw}'")]
    BadParam { op: String, raw: String },
    #[error("unknown dtype '{0}' (expected u8|u16|i32|f32|f64)")]
    BadDType(String),
    #[error("malformed shape '{0}' (expected like 60x120)")]
    BadShape(String),
    #[error("pipeline rejected: {0}")]
    Invalid(#[from] crate::ops::PipelineError),
}

fn parse_dtype(s: &str) -> Result<DType, SpecError> {
    DType::parse(s).ok_or_else(|| SpecError::BadDType(s.to_string()))
}

/// Parse a full chain spec into a validated [`Pipeline`] with its cast
/// trace attached.
pub fn parse_chain_spec(
    ops: &str,
    shape: &str,
    batch: usize,
    dtin: &str,
    dtout: &str,
) -> Result<Pipeline, SpecError> {
    let dtin = parse_dtype(dtin)?;
    let dtout = parse_dtype(dtout)?;
    let shape: Vec<usize> = shape
        .split('x')
        .map(|t| t.parse().map_err(|_| SpecError::BadShape(shape.to_string())))
        .collect::<Result<_, _>>()?;
    if ops.trim().is_empty() {
        return Err(SpecError::Empty);
    }

    let mut body = Vec::new();
    let mut casts = Vec::new();
    for token in ops.split(',') {
        let token = token.trim();
        if token == "cvtcolor" {
            body.push(IOp::CvtColor);
            continue;
        }
        let (name, raw) = token.split_once(':').unwrap_or((token, "1.0"));
        if name == "cast" {
            casts.push(CastStep { at: body.len(), to: parse_dtype(raw)? });
            continue;
        }
        let op = Opcode::parse(name).ok_or_else(|| SpecError::UnknownOp(token.to_string()))?;
        let param: f64 = raw
            .parse()
            .map_err(|_| SpecError::BadParam { op: name.to_string(), raw: raw.to_string() })?;
        body.push(IOp::compute(op, param));
    }

    Ok(Pipeline::elementwise(body, shape, batch, dtin, dtout)?.with_cast_trace(casts))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalar_ops_cvtcolor_and_casts() {
        let p = parse_chain_spec("mul:0.5,cast:f32,cvtcolor,sqrt", "4x4x3", 2, "u8", "f32")
            .unwrap();
        assert_eq!(p.body().len(), 3);
        assert_eq!(p.batch, 2);
        assert_eq!(p.cast_trace(), &[CastStep { at: 1, to: DType::F32 }]);
        assert_eq!(p.body()[0], IOp::compute(Opcode::Mul, 0.5));
        assert_eq!(p.body()[1], IOp::CvtColor);
        // a bare scalar op defaults its param to 1.0 like `fkl run`
        assert_eq!(p.body()[2], IOp::compute(Opcode::Sqrt, 1.0));
    }

    #[test]
    fn every_malformed_input_is_a_typed_error() {
        let err = |o: Result<Pipeline, SpecError>| o.unwrap_err();
        assert_eq!(
            err(parse_chain_spec("frobnicate", "4", 1, "u8", "f32")),
            SpecError::UnknownOp("frobnicate".to_string())
        );
        assert_eq!(
            err(parse_chain_spec("mul:abc", "4", 1, "u8", "f32")),
            SpecError::BadParam { op: "mul".to_string(), raw: "abc".to_string() }
        );
        assert_eq!(
            err(parse_chain_spec("mul", "4", 1, "u9", "f32")),
            SpecError::BadDType("u9".to_string())
        );
        assert_eq!(
            err(parse_chain_spec("mul", "4yy", 1, "u8", "f32")),
            SpecError::BadShape("4yy".to_string())
        );
        assert_eq!(err(parse_chain_spec("  ", "4", 1, "u8", "f32")), SpecError::Empty);
        assert_eq!(
            err(parse_chain_spec("cast:bogus", "4", 1, "u8", "f32")),
            SpecError::BadDType("bogus".to_string())
        );
    }
}
