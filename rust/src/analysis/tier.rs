//! Static tier prediction: which rung of the execution ladder serves a
//! pipeline, decided from the IR alone.
//!
//! The runtime ladder discovers this by trying: the planner raises a typed
//! [`PlanError`](crate::fusion::PlanError) and `FusedEngine` re-routes.
//! `predict_tier` mirrors the planner's refusal order exactly — reduction
//! seal first, then structured boundary, then the scalar-chain body
//! requirement — so the prediction is the same fact the user would otherwise
//! learn from a run. Registry coverage (which artifact family hits) is
//! deliberately NOT predicted: it depends on what was compiled, not on the
//! pipeline.

use crate::fusion::{HostAccum, HostPlan};
use crate::ops::{IOp, Pipeline};

/// The ladder rung a pipeline is served on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// Dense scalar chain: artifact-tier eligible (exact > staticloop >
    /// interp, registry-dependent), with the host fused chain tier as the
    /// always-available fallback.
    DenseChain,
    /// C3/CvtColor lane-grouped body: artifact tiers refuse (`NotAChain`);
    /// the host fused engine serves it in the group tier.
    HostGroup,
    /// Crop/resize read or split write: artifact tiers refuse
    /// (`StructuredBoundary`); served by the host structured tier.
    HostStructured,
    /// Reduce terminator: artifact tiers refuse (`Reduction`); served by the
    /// host fold-while-reading tier.
    HostReduce,
}

impl Tier {
    pub fn name(self) -> &'static str {
        match self {
            Tier::DenseChain => "dense-chain",
            Tier::HostGroup => "host-group",
            Tier::HostStructured => "host-structured",
            Tier::HostReduce => "host-reduce",
        }
    }
}

impl std::fmt::Display for Tier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// What `predict_tier` knows before anything runs.
#[derive(Debug, Clone, PartialEq)]
pub struct TierPrediction {
    pub tier: Tier,
    /// Why every artifact tier will refuse this pipeline (`None` when the
    /// chain is artifact-eligible) — the same fact the planner's typed
    /// [`PlanError`](crate::fusion::PlanError) reports at run time.
    pub artifact_refusal: Option<String>,
    /// The host fused engine's accumulator domain for this pipeline:
    /// [`HostAccum::F32`] is the register-resident fast arm, everything else
    /// folds in f64, bit-compatible with the hostref oracle.
    pub accum: HostAccum,
    /// Register-block width of the host fused inner loop — the very value
    /// the compiled plan records ([`HostPlan::vectorization`]): 16 on the
    /// f32 fast arm, 8 on every f64 arm, 8 striped sub-accumulators on the
    /// reduce tier. Predicted statically so lints report the SIMD shape a
    /// run would take without running it.
    pub lane_width: u8,
    /// Bytes one FUSED pass of this pipeline moves (read + written) —
    /// [`HostPlan::bytes_read`] + [`HostPlan::bytes_written`], static from
    /// the IR.
    pub bytes_fused: u64,
    /// Bytes the op-at-a-time baseline would move
    /// ([`Pipeline::baseline_bytes`]): every chain step re-reads and
    /// re-writes its intermediate. `bytes_baseline / bytes_fused` is the
    /// pipeline's predicted fusion efficiency — ≈(k+1)/2× for a dense
    /// same-width chain of k ops.
    pub bytes_baseline: u64,
}

impl TierPrediction {
    /// Predicted fusion efficiency: baseline bytes over fused bytes (1.0
    /// when the fused pass moves nothing — degenerate empty pipelines).
    pub fn fusion_efficiency(&self) -> f64 {
        if self.bytes_fused == 0 {
            1.0
        } else {
            self.bytes_baseline as f64 / self.bytes_fused as f64
        }
    }
}

/// Predict the serving tier of `p` without running it.
pub fn predict_tier(p: &Pipeline) -> TierPrediction {
    let plan = HostPlan::compile(p);
    let accum = plan.accum();
    let lane_width = plan.vectorization();
    let bytes_fused = (plan.bytes_read() + plan.bytes_written()) as u64;
    let bytes_baseline = plan.bytes_baseline() as u64;
    if p.reduction().is_some() {
        let token = p.ops().last().map(IOp::sig_token).unwrap_or_default();
        return TierPrediction {
            tier: Tier::HostReduce,
            artifact_refusal: Some(format!("reduce seal: {token}")),
            accum,
            lane_width,
            bytes_fused,
            bytes_baseline,
        };
    }
    if p.has_structured_boundary() {
        let token = p
            .ops()
            .iter()
            .find(|op| matches!(op, IOp::Mem(m) if m.is_structured()))
            .map(IOp::sig_token)
            .unwrap_or_default();
        return TierPrediction {
            tier: Tier::HostStructured,
            artifact_refusal: Some(format!("structured boundary: {token}")),
            accum,
            lane_width,
            bytes_fused,
            bytes_baseline,
        };
    }
    if let Some(op) = p.body().iter().find(|op| !matches!(op, IOp::Compute { .. })) {
        return TierPrediction {
            tier: Tier::HostGroup,
            artifact_refusal: Some(format!("not a scalar chain: {}", op.sig_token())),
            accum,
            lane_width,
            bytes_fused,
            bytes_baseline,
        };
    }
    TierPrediction {
        tier: Tier::DenseChain,
        artifact_refusal: None,
        accum,
        lane_width,
        bytes_fused,
        bytes_baseline,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{IOp, MemOp, Opcode, Pipeline, ReduceAxis, ReduceKind, ReduceSpec};
    use crate::tensor::{DType, Rect};

    #[test]
    fn predictions_mirror_the_planner_refusal_order() {
        let chain =
            Pipeline::from_opcodes(&[(Opcode::Mul, 2.0)], &[4, 4], 1, DType::U8, DType::F32)
                .unwrap();
        let t = predict_tier(&chain);
        assert_eq!(t.tier, Tier::DenseChain);
        assert_eq!(t.artifact_refusal, None);
        assert_eq!(t.accum, HostAccum::F32, "u8->f32 dense chain rides the fast arm");
        assert_eq!(t.lane_width, 16, "the f32 fast arm blocks 16 lanes");

        let group = Pipeline::elementwise(
            vec![IOp::CvtColor, IOp::compute(Opcode::Mul, 2.0)],
            vec![4, 4, 3],
            1,
            DType::U8,
            DType::F32,
        )
        .unwrap();
        let t = predict_tier(&group);
        assert_eq!(t.tier, Tier::HostGroup);
        assert!(t.artifact_refusal.as_deref().unwrap().contains("cvtcolor"));
        assert_eq!(t.accum, HostAccum::F64, "group bodies fold in f64");
        assert_eq!(t.lane_width, 8, "f64 arms block 8 lanes");

        let structured = Pipeline::new(
            vec![
                IOp::Mem(MemOp::CropRead { rect: Rect::new(0, 0, 8, 8) }),
                IOp::compute(Opcode::Mul, 2.0),
                IOp::Mem(MemOp::Write { dtype: DType::F32 }),
            ],
            vec![8, 8],
            1,
            DType::F32,
            DType::F32,
        )
        .unwrap();
        let t = predict_tier(&structured);
        assert_eq!(t.tier, Tier::HostStructured);
        assert!(t.artifact_refusal.as_deref().unwrap().contains("structured boundary"));

        let spec = ReduceSpec::single(ReduceKind::Mean, ReduceAxis::Full);
        let reduce = Pipeline::new(
            vec![
                IOp::Mem(MemOp::Read { dtype: DType::F32 }),
                IOp::compute(Opcode::Mul, 2.0),
                IOp::Mem(MemOp::Reduce { spec }),
            ],
            vec![4, 4],
            1,
            DType::F32,
            DType::F64,
        )
        .unwrap();
        let t = predict_tier(&reduce);
        assert_eq!(t.tier, Tier::HostReduce);
        assert!(t.artifact_refusal.as_deref().unwrap().contains("reduce seal"));
        assert_eq!(t.lane_width, 8, "the reduce tier stripes 8 sub-accumulators");
    }

    #[test]
    fn predicted_bytes_follow_the_ir_model() {
        // chain-1 u8->f32, 16 elems: fused = 16 read + 64 written = 80;
        // baseline has no intermediates, so efficiency is exactly 1.0
        let k1 = Pipeline::from_opcodes(&[(Opcode::Mul, 2.0)], &[4, 4], 1, DType::U8, DType::F32)
            .unwrap();
        let t1 = predict_tier(&k1);
        assert_eq!(t1.bytes_fused, 80);
        assert_eq!(t1.bytes_baseline, 80);
        assert!((t1.fusion_efficiency() - 1.0).abs() < 1e-12);

        // chain-5: baseline re-materializes 4 intermediates (4 x 64 bytes
        // each way collapses to 4 x 64 extra), 336/80 = 4.2x
        let chain: Vec<(Opcode, f64)> = (0..5).map(|_| (Opcode::Mul, 2.0)).collect();
        let k5 = Pipeline::from_opcodes(&chain, &[4, 4], 1, DType::U8, DType::F32).unwrap();
        let t5 = predict_tier(&k5);
        assert_eq!(t5.bytes_fused, 80, "fused bytes are chain-length invariant");
        assert!((t5.fusion_efficiency() - 4.2).abs() < 1e-12);
    }
}
