//! Static analysis over the erased [`Pipeline`](crate::ops::Pipeline) IR.
//!
//! The paper's C++17 layer statically *rejects* malformed chains (Fig. 10
//! `S_ASSERT_INPUT_OUTPUT`); our typestate builder reproduces that half. This
//! module is the other half kernel-fusion compilers grew on top of rejection:
//! reasoning about the CONTENT of a legal chain before anything runs.
//! Filipovič et al. ("Optimizing CUDA Code By Kernel Fusion") fold and
//! simplify the op sequence before emitting the fused kernel; HFuse
//! statically predicts whether fusion pays off. Here:
//!
//! * [`lint`] walks a pipeline and returns typed, coded diagnostics
//!   ([`Diagnostic`]): dead/identity ops, redundant or narrowing cast
//!   chains, integer-saturation and NaN-propagation hazards, poisonous
//!   parameters, and a tier prediction ([`predict_tier`]) that says which
//!   ladder tier will serve the chain and why the artifact tiers refuse it —
//!   facts that were previously only discoverable by running.
//! * [`canonicalize`] rewrites a pipeline into a normal form, applying ONLY
//!   rewrites proven bit-safe on every IEEE input (identity elimination,
//!   inverse-pair cancellation, cast dedup/collapse); anything that could
//!   change a single output bit — folding `Mul(a);Mul(b)` into `Mul(a*b)`,
//!   dropping `Add(+0.0)` — is reported as a suggestion and never applied.
//!   Canonical pipelines collapse syntactically distinct but equivalent
//!   chains onto one [`Signature`](crate::ops::Signature), so the
//!   coordinator's plan cache and stacking tier see one stream instead of
//!   many (wired in behind [`ServiceConfig::canonicalize`]
//!   [`crate::coordinator::ServiceConfig`]).
//!
//! The bit-safety contract is enforced empirically by the differential fuzz
//! harness (`rust/tests/fuzz_chains.rs`): every random chain is executed raw
//! and canonicalized and the results compared bit-for-bit on the f64
//! accumulator paths, at 1/2/8 threads.

mod canon;
mod lint;
mod spec;
mod tier;

pub use canon::{canonicalize, Rewrite, RewriteKind};
pub use lint::{lint, Diagnostic, RuleCode, Severity, Span};
pub use spec::{parse_chain_spec, SpecError};
pub use tier::{predict_tier, Tier, TierPrediction};
