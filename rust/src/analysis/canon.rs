//! The canonicalizer: normalize a pipeline with ONLY bit-safety-proven
//! rewrites.
//!
//! "Bit-safe" is an IEEE-754 claim, not a real-number claim: a rewrite is
//! applied only when the removed computation returns its input bit-for-bit
//! on EVERY f64 value, including signed zeros and NaN. That is why
//! `Sub(+0.0)` and `Add(-0.0)` are removable (exact identities) while
//! `Add(+0.0)` is not (it flips `-0.0` to `+0.0`), and why `Min(+inf)` is
//! not (IEEE min returns the non-NaN side, so removal changes NaN handling).
//! Bit-CHANGING simplifications — folding `Mul(a);Mul(b)` into `Mul(a*b)`
//! rounds once instead of twice — are emitted as report-only [`Rewrite`]s
//! with `applied: false`, never performed.
//!
//! Cast-trace rewrites are trivially bit-safe (interior casts are marker
//! metadata the executed IR never sees), but stay conservative anyway: only
//! exact duplicates and lossless widening intermediates are collapsed, so a
//! narrowing round-trip like `f64→f32→f64` survives for the linter to flag.

use crate::fusion::HostPlan;
use crate::ops::{CastStep, IOp, Opcode, Pipeline};
use crate::tensor::DType;

use super::lint::Span;

/// How an identity op relates to the bit-safety line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum IdentityClass {
    /// Returns its input bit-for-bit on every IEEE value: removable.
    Exact,
    /// Identity except at `-0.0` (IEEE `+` and `-` with `+0.0`/`-0.0`
    /// normalize the zero sign): report-only.
    SignedZero,
    /// Identity except for NaN inputs (IEEE min/max return the non-NaN
    /// side): report-only.
    NanSkipping,
}

/// Classify `op(param)` as an identity, with the reason.
pub(crate) fn identity_of(op: Opcode, param: f64) -> Option<(IdentityClass, &'static str)> {
    match op {
        Opcode::Nop => Some((IdentityClass::Exact, "nop passes every value through")),
        Opcode::Mul if param == 1.0 => {
            Some((IdentityClass::Exact, "x * 1.0 is x, bit for bit"))
        }
        Opcode::Div if param == 1.0 => {
            Some((IdentityClass::Exact, "x / 1.0 is x, bit for bit"))
        }
        Opcode::Sub if param == 0.0 && param.is_sign_positive() => Some((
            IdentityClass::Exact,
            "x - (+0.0) is x for every value, including -0.0",
        )),
        Opcode::Add if param == 0.0 && param.is_sign_negative() => Some((
            IdentityClass::Exact,
            "x + (-0.0) is x for every value, including -0.0",
        )),
        Opcode::Add if param == 0.0 => Some((
            IdentityClass::SignedZero,
            "x + (+0.0) is x except at -0.0, which IEEE addition flips to +0.0",
        )),
        Opcode::Sub if param == 0.0 => Some((
            IdentityClass::SignedZero,
            "x - (-0.0) is x except at -0.0, which IEEE subtraction flips to +0.0",
        )),
        Opcode::Min if param == f64::INFINITY => Some((
            IdentityClass::NanSkipping,
            "min(x, +inf) is x except for NaN, where IEEE min returns +inf",
        )),
        Opcode::Max if param == f64::NEG_INFINITY => Some((
            IdentityClass::NanSkipping,
            "max(x, -inf) is x except for NaN, where IEEE max returns -inf",
        )),
        Opcode::Min | Opcode::Max if param.is_nan() => Some((
            IdentityClass::Exact,
            "IEEE min/max with a NaN parameter returns x unchanged",
        )),
        _ => None,
    }
}

/// `from` values are all exactly representable in `to` (so a cast through
/// `from` on the way to `to` loses nothing). Note `i32` does NOT widen into
/// `f32` (24-bit mantissa).
pub(crate) fn widens_losslessly(from: DType, to: DType) -> bool {
    use DType::{F32, F64, I32, U16, U8};
    matches!(
        (from, to),
        (U8, U16)
            | (U8, I32)
            | (U8, F32)
            | (U8, F64)
            | (U16, I32)
            | (U16, F32)
            | (U16, F64)
            | (I32, F64)
            | (F32, F64)
    )
}

/// One canonicalization decision: either applied to the returned pipeline or
/// reported as a suggestion the caller may act on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RewriteKind {
    /// Bit-exact identity op removed (`Nop`, `Mul(1.0)`, `Div(1.0)`,
    /// `Sub(+0.0)`, `Add(-0.0)`, `Min/Max(NaN)`).
    RemoveIdentity,
    /// Self-cancelling or idempotent adjacent pair reduced (`Neg;Neg`,
    /// `Abs;Abs`, `Clamp01;Clamp01`, `CvtColor;CvtColor`).
    CancelPair,
    /// Cast to the marker dtype already in effect removed.
    DedupCast,
    /// Lossless widening intermediate cast collapsed into the next cast.
    CollapseCast,
    /// Bit-changing scalar fold (`Mul;Mul`, `Add;Add`) — reported only.
    FoldScalarPair,
    /// Identity whose removal would change `-0.0` or NaN bits — reported
    /// only.
    UnsafeIdentity,
}

/// A rewrite the canonicalizer performed (`applied: true`) or merely
/// proposes (`applied: false`). Spans index the body AS IT WAS when the
/// rewrite fired; earlier removals shift later indices.
#[derive(Debug, Clone, PartialEq)]
pub struct Rewrite {
    pub kind: RewriteKind,
    pub span: Span,
    pub applied: bool,
    pub detail: String,
}

fn rebuild(p: &Pipeline, body: &[IOp]) -> Pipeline {
    let mut ops = Vec::with_capacity(body.len() + 2);
    ops.push(p.ops().first().expect("validated pipeline has a read").clone());
    ops.extend_from_slice(body);
    ops.push(p.ops().last().expect("validated pipeline has a write").clone());
    Pipeline::new(ops, p.shape.clone(), p.batch, p.dtin, p.dtout)
        .expect("canonical rewrites preserve pipeline validity")
}

/// Drop body stage `i`, shifting cast markers that sat after it.
fn remove_stage(body: &mut Vec<IOp>, casts: &mut [CastStep], i: usize) {
    body.remove(i);
    for c in casts.iter_mut() {
        if c.at > i {
            c.at -= 1;
        }
    }
}

/// Canonicalize `p`: apply every bit-safe rewrite to a fixpoint and report
/// everything else as a suggestion. The returned pipeline is bit-equal to
/// `p` on every input (the fuzz harness proves this differentially), and
/// `canonicalize` is idempotent: re-running on the result applies nothing.
pub fn canonicalize(p: Pipeline) -> (Pipeline, Vec<Rewrite>) {
    let mut rewrites = Vec::new();
    let mut body: Vec<IOp> = p.body().to_vec();
    let mut casts: Vec<CastStep> = p.cast_trace().to_vec();
    let accum0 = HostPlan::compile(&p).accum();

    // --- applied rewrites, to a fixpoint (so e.g. `Neg;Nop;Neg` fully
    // cancels once the interior Nop is gone)
    loop {
        let mut changed = false;

        // bit-exact identity removal. The body is never emptied: a pipeline
        // whose whole body is one identity op keeps it as its canonical form.
        let mut i = 0;
        while i < body.len() && body.len() > 1 {
            let exact = match &body[i] {
                IOp::Compute { op, param } => {
                    identity_of(*op, *param).filter(|(c, _)| *c == IdentityClass::Exact)
                }
                _ => None,
            };
            if let Some((_, why)) = exact {
                rewrites.push(Rewrite {
                    kind: RewriteKind::RemoveIdentity,
                    span: Span::stage(i),
                    applied: true,
                    detail: format!("removed {}: {why}", body[i].sig_token()),
                });
                remove_stage(&mut body, &mut casts, i);
                changed = true;
            } else {
                i += 1;
            }
        }

        // adjacent pair cancellation
        let mut i = 0;
        while i + 1 < body.len() {
            match (&body[i], &body[i + 1]) {
                (IOp::Compute { op: Opcode::Neg, .. }, IOp::Compute { op: Opcode::Neg, .. })
                    if body.len() > 2 =>
                {
                    rewrites.push(Rewrite {
                        kind: RewriteKind::CancelPair,
                        span: Span { start: i, end: i + 2 },
                        applied: true,
                        detail: "neg;neg cancels: double sign flip restores every bit".into(),
                    });
                    remove_stage(&mut body, &mut casts, i + 1);
                    remove_stage(&mut body, &mut casts, i);
                    changed = true;
                }
                (IOp::Compute { op: Opcode::Abs, .. }, IOp::Compute { op: Opcode::Abs, .. }) => {
                    rewrites.push(Rewrite {
                        kind: RewriteKind::CancelPair,
                        span: Span { start: i, end: i + 2 },
                        applied: true,
                        detail: "abs;abs: the second abs sees no negative value".into(),
                    });
                    remove_stage(&mut body, &mut casts, i + 1);
                    changed = true;
                }
                (
                    IOp::Compute { op: Opcode::Clamp01, .. },
                    IOp::Compute { op: Opcode::Clamp01, .. },
                ) => {
                    rewrites.push(Rewrite {
                        kind: RewriteKind::CancelPair,
                        span: Span { start: i, end: i + 2 },
                        applied: true,
                        detail: "clamp01;clamp01: the second clamp sees only [0,1] and NaN, \
                                 both of which it returns unchanged"
                            .into(),
                    });
                    remove_stage(&mut body, &mut casts, i + 1);
                    changed = true;
                }
                (IOp::CvtColor, IOp::CvtColor) if body.len() > 2 => {
                    // elementwise this is an exact identity (two swizzles
                    // restore the layout), but removing the pair can turn a
                    // lane-grouped body into a plain chain and move it onto
                    // the f32 fast arm — a different accumulator, different
                    // bits. Only rewrite when the plan's accumulator is
                    // provably unchanged; a blocked pair is reported in the
                    // suggestions pass below.
                    let mut candidate = body.clone();
                    candidate.remove(i + 1);
                    candidate.remove(i);
                    if HostPlan::compile(&rebuild(&p, &candidate)).accum() == accum0 {
                        rewrites.push(Rewrite {
                            kind: RewriteKind::CancelPair,
                            span: Span { start: i, end: i + 2 },
                            applied: true,
                            detail: "cvtcolor;cvtcolor cancels: double swizzle restores \
                                     the layout"
                                .into(),
                        });
                        remove_stage(&mut body, &mut casts, i + 1);
                        remove_stage(&mut body, &mut casts, i);
                        changed = true;
                    } else {
                        i += 1;
                    }
                }
                _ => i += 1,
            }
        }

        if !changed {
            break;
        }
    }

    // --- report-only suggestions, detected on the canonical body
    for i in 0..body.len() {
        if let IOp::Compute { op, param } = body[i] {
            if let Some((class, why)) = identity_of(op, param) {
                if class != IdentityClass::Exact {
                    rewrites.push(Rewrite {
                        kind: RewriteKind::UnsafeIdentity,
                        span: Span::stage(i),
                        applied: false,
                        detail: format!(
                            "{}({param}) is an identity but removal is not bit-safe: {why}",
                            op.name()
                        ),
                    });
                } else if body.len() == 1 {
                    rewrites.push(Rewrite {
                        kind: RewriteKind::RemoveIdentity,
                        span: Span::stage(i),
                        applied: false,
                        detail: format!(
                            "{} is a removable identity but is the whole body: kept \
                             (a pipeline body is never emptied)",
                            body[i].sig_token()
                        ),
                    });
                }
            }
        }
        if i + 1 < body.len() {
            if let (IOp::CvtColor, IOp::CvtColor) = (&body[i], &body[i + 1]) {
                rewrites.push(Rewrite {
                    kind: RewriteKind::CancelPair,
                    span: Span { start: i, end: i + 2 },
                    applied: false,
                    detail: "cvtcolor;cvtcolor cancels, but removal would change the \
                             fused accumulator (f64 group body -> f32 fast arm) or empty \
                             the body: kept for bit-compatibility"
                        .into(),
                });
            }
        }
        if i + 1 < body.len() {
            if let (IOp::Compute { op: a, param: pa }, IOp::Compute { op: b, param: pb }) =
                (&body[i], &body[i + 1])
            {
                let fold = match (a, b) {
                    (Opcode::Mul, Opcode::Mul) => Some(("mul", pa * pb)),
                    (Opcode::Add, Opcode::Add) => Some(("add", pa + pb)),
                    _ => None,
                };
                if let Some((name, folded)) = fold {
                    rewrites.push(Rewrite {
                        kind: RewriteKind::FoldScalarPair,
                        span: Span { start: i, end: i + 2 },
                        applied: false,
                        detail: format!(
                            "{name}({pa});{name}({pb}) folds to {name}({folded}) — one \
                             rounding instead of two changes bits, so it is never applied"
                        ),
                    });
                }
            }
        }
    }

    // --- cast-trace canonicalization. Entries are markers (free at run
    // time); canonical form keeps no cast to the dtype already in effect and
    // no lossless widening stop-over on the way to a further cast.
    let mut canon_casts: Vec<(DType, CastStep)> = Vec::new(); // (dtype before, step)
    'steps: for step in casts {
        loop {
            let cur = canon_casts.last().map(|&(_, s)| s.to).unwrap_or(p.dtin);
            if step.to == cur {
                rewrites.push(Rewrite {
                    kind: RewriteKind::DedupCast,
                    span: Span::at(step.at),
                    applied: true,
                    detail: format!(
                        "cast to {} removed: the chain is already {} here",
                        step.to.name(),
                        cur.name()
                    ),
                });
                continue 'steps;
            }
            if let Some(&(before, last)) = canon_casts.last() {
                if last.at == step.at && widens_losslessly(before, last.to) {
                    canon_casts.pop();
                    rewrites.push(Rewrite {
                        kind: RewriteKind::CollapseCast,
                        span: Span::at(last.at),
                        applied: true,
                        detail: format!(
                            "lossless widening cast {}->{} collapsed into the following \
                             cast to {}",
                            before.name(),
                            last.to.name(),
                            step.to.name()
                        ),
                    });
                    continue;
                }
            }
            canon_casts.push((cur, step));
            continue 'steps;
        }
    }
    // a trailing widening stop-over at the write boundary collapses into the
    // write's own (implied) cast to dtout
    while let Some(&(before, last)) = canon_casts.last() {
        if last.at == body.len() && widens_losslessly(before, last.to) {
            canon_casts.pop();
            rewrites.push(Rewrite {
                kind: RewriteKind::CollapseCast,
                span: Span::at(last.at),
                applied: true,
                detail: format!(
                    "lossless widening cast {}->{} collapsed into the write cast to {}",
                    before.name(),
                    last.to.name(),
                    p.dtout.name()
                ),
            });
        } else {
            break;
        }
    }
    let casts: Vec<CastStep> = canon_casts.into_iter().map(|(_, s)| s).collect();

    (rebuild(&p, &body).with_cast_trace(casts), rewrites)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostref;
    use crate::tensor::Tensor;

    fn chain(body: Vec<IOp>, dtin: DType, dtout: DType) -> Pipeline {
        Pipeline::elementwise(body, vec![2, 2], 1, dtin, dtout).unwrap()
    }

    #[test]
    fn identities_and_inverse_pairs_are_removed_bit_safely() {
        let p = chain(
            vec![
                IOp::compute(Opcode::Mul, 1.0),
                IOp::compute(Opcode::Neg, 0.0),
                IOp::compute(Opcode::Nop, 0.0),
                IOp::compute(Opcode::Neg, 0.0),
                IOp::compute(Opcode::Sub, 0.0),
                IOp::compute(Opcode::Add, 2.0),
            ],
            DType::F32,
            DType::F64,
        );
        let (canon, rewrites) = canonicalize(p.clone());
        assert_eq!(canon.body(), &[IOp::compute(Opcode::Add, 2.0)]);
        assert_eq!(rewrites.iter().filter(|r| r.applied).count(), 4);
        // bit-equality of the rewritten chain, via the oracle
        let x = Tensor::from_f32(&[-1.5, -0.0, 0.25, 3.0], &[1, 2, 2]);
        let (a, b) = (hostref::run_pipeline(&p, &x), hostref::run_pipeline(&canon, &x));
        let (a, b) = (a.to_f64_vec(), b.to_f64_vec());
        assert_eq!(
            a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn signed_zero_and_nan_skipping_identities_are_suggested_not_applied() {
        let p = chain(
            vec![IOp::compute(Opcode::Add, 0.0), IOp::compute(Opcode::Min, f64::INFINITY)],
            DType::F64,
            DType::F64,
        );
        let (canon, rewrites) = canonicalize(p.clone());
        assert_eq!(canon, p, "nothing bit-safe to do");
        let suggested: Vec<_> = rewrites.iter().filter(|r| !r.applied).collect();
        assert_eq!(suggested.len(), 2);
        assert!(suggested.iter().all(|r| r.kind == RewriteKind::UnsafeIdentity));
    }

    #[test]
    fn scalar_folds_are_reported_never_applied() {
        let p = chain(
            vec![IOp::compute(Opcode::Mul, 0.3), IOp::compute(Opcode::Mul, 7.0)],
            DType::F64,
            DType::F64,
        );
        let (canon, rewrites) = canonicalize(p.clone());
        assert_eq!(canon, p);
        assert_eq!(rewrites.len(), 1);
        assert_eq!(rewrites[0].kind, RewriteKind::FoldScalarPair);
        assert!(!rewrites[0].applied);
    }

    #[test]
    fn cvtcolor_pair_removal_is_guarded_by_the_accumulator() {
        let body = vec![IOp::CvtColor, IOp::CvtColor, IOp::compute(Opcode::Mul, 2.0)];
        // u8 -> f32: removing the pair would move the chain onto the f32
        // fast arm — blocked, reported as unapplied
        let p = Pipeline::elementwise(body.clone(), vec![4, 4, 3], 1, DType::U8, DType::F32)
            .unwrap();
        let (canon, rewrites) = canonicalize(p.clone());
        assert_eq!(canon, p);
        assert!(rewrites.iter().any(|r| r.kind == RewriteKind::CancelPair && !r.applied));
        // u8 -> f64: the accumulator is f64 either way — removed
        let p = Pipeline::elementwise(body, vec![4, 4, 3], 1, DType::U8, DType::F64).unwrap();
        let (canon, rewrites) = canonicalize(p);
        assert_eq!(canon.body(), &[IOp::compute(Opcode::Mul, 2.0)]);
        assert!(rewrites.iter().any(|r| r.kind == RewriteKind::CancelPair && r.applied));
    }

    #[test]
    fn cast_traces_dedup_and_collapse_but_keep_narrowing_round_trips() {
        let base = chain(vec![IOp::compute(Opcode::Mul, 2.0)], DType::U8, DType::F64);
        // u8 -> u8 cast: dedup
        let p = base.clone().with_cast_trace(vec![CastStep { at: 0, to: DType::U8 }]);
        let (canon, rewrites) = canonicalize(p);
        assert_eq!(canon.cast_trace(), &[]);
        assert_eq!(rewrites[0].kind, RewriteKind::DedupCast);
        // u8 -> f32 -> f64 widening stop-over at the same position: collapse
        let p = base.clone().with_cast_trace(vec![
            CastStep { at: 1, to: DType::F32 },
            CastStep { at: 1, to: DType::F64 },
        ]);
        let (canon, rewrites) = canonicalize(p);
        assert_eq!(canon.cast_trace(), &[], "u8->f64 at the write boundary is implied");
        assert!(rewrites.iter().any(|r| r.kind == RewriteKind::CollapseCast));
        // f64 -> f32 -> f64 narrowing round trip: kept for the linter
        let base = chain(vec![IOp::compute(Opcode::Mul, 2.0)], DType::F64, DType::F64);
        let p = base.with_cast_trace(vec![
            CastStep { at: 0, to: DType::F32 },
            CastStep { at: 0, to: DType::F64 },
        ]);
        let (canon, rewrites) = canonicalize(p.clone());
        assert_eq!(canon, p);
        assert!(rewrites.is_empty());
    }

    #[test]
    fn canonicalize_is_idempotent_and_keeps_a_lone_identity() {
        let p = chain(vec![IOp::compute(Opcode::Mul, 1.0)], DType::F32, DType::F32);
        let (canon, rewrites) = canonicalize(p.clone());
        assert_eq!(canon, p, "the body is never emptied");
        assert!(rewrites.iter().all(|r| !r.applied));

        let p = chain(
            vec![
                IOp::compute(Opcode::Nop, 0.0),
                IOp::compute(Opcode::Neg, 0.0),
                IOp::compute(Opcode::Neg, 0.0),
                IOp::compute(Opcode::Div, 3.0),
            ],
            DType::F32,
            DType::F64,
        );
        let (once, _) = canonicalize(p);
        let (twice, again) = canonicalize(once.clone());
        assert_eq!(once, twice);
        assert!(again.iter().all(|r| !r.applied), "fixpoint applies nothing");
    }
}
