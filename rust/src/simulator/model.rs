//! The latency-hiding roofline model.

use super::SystemSpec;

/// Shape of one kernel launch for simulation.
#[derive(Debug, Clone, Copy)]
pub struct KernelShape {
    /// Elements processed (threads x coarsening).
    pub elems: f64,
    /// Bytes read + written from DRAM per element.
    pub bytes_per_elem: f64,
    /// Arithmetic instructions per element (1 = one fused mul or add).
    pub instrs_per_elem: f64,
    /// Fraction of the GPU's parallel resources this launch can occupy
    /// (small single-image kernels on big GPUs are <1 — the HF motivation,
    /// paper Fig. 4a).
    pub occupancy: f64,
}

/// Simulation output for one kernel.
#[derive(Debug, Clone, Copy)]
pub struct SimResult {
    pub time_s: f64,
    pub memory_bound: bool,
}

/// Analytical GPU: Table II spec + launch/issue/spill constants.
#[derive(Debug, Clone, Copy)]
pub struct GpuModel {
    pub spec: SystemSpec,
    /// Kernel launch + driver overhead per launch, seconds (CUDA ~5-10us).
    pub launch_overhead_s: f64,
    /// Instruction count per thread beyond which registers spill and the
    /// effective compute rate degrades (paper §VI-D: unrolled template code
    /// eventually spills and the speedup stops growing).
    pub spill_threshold: f64,
    /// Throughput multiplier once spilled.
    pub spill_factor: f64,
}

impl GpuModel {
    pub fn new(spec: SystemSpec) -> GpuModel {
        GpuModel { spec, launch_overhead_s: 6e-6, spill_threshold: 4096.0, spill_factor: 0.35 }
    }

    /// Fused-multiply-add pairing: Mul+Add chains execute as FMA (the paper's
    /// 2x between Mul-Mul and Mul-Add chains, §VI-B). Callers pre-divide
    /// instrs; this model works in issued-instruction units.
    ///
    /// Time of one kernel launch.
    pub fn kernel_time(&self, k: &KernelShape) -> SimResult {
        let occ = k.occupancy.clamp(1e-3, 1.0);
        let bw = self.spec.bandwidth_gbps * 1e9 * occ;
        // 1 "instruction" = 1 flop here; fp32 pipes do 2 flop/FMA so the
        // spec TFLOPS halves for non-FMA chains — handled by the caller via
        // instrs_per_elem; use issue rate = tflops (upper bound).
        let mut flops = self.spec.tflops_fp32 * 1e12 * occ;
        if k.instrs_per_elem > self.spill_threshold {
            flops *= self.spill_factor;
        }
        let mem_t = k.elems * k.bytes_per_elem / bw;
        let cmp_t = k.elems * k.instrs_per_elem / flops;
        let memory_bound = mem_t >= cmp_t;
        // latency hiding: overlap, plus a small serial fraction
        let time = self.launch_overhead_s + mem_t.max(cmp_t) + 0.05 * mem_t.min(cmp_t);
        SimResult { time_s: time, memory_bound }
    }

    /// Unfused chain: n launches of a 1-op kernel (paper Fig. 3A).
    pub fn unfused_chain(&self, k: &KernelShape, n_ops: usize) -> f64 {
        let one = KernelShape { instrs_per_elem: 1.0, ..*k };
        self.kernel_time(&one).time_s * n_ops as f64
    }

    /// Fused chain: one launch with all n ops.
    pub fn fused_chain(&self, k: &KernelShape, n_ops: usize) -> f64 {
        self.kernel_time(&KernelShape { instrs_per_elem: n_ops as f64, ..*k }).time_s
    }

    /// HF: batch B small kernels into one launch. Each small kernel alone
    /// occupies `small_occ`; the batch occupies min(1, B * small_occ).
    pub fn hf_speedup(&self, k: &KernelShape, small_occ: f64, batch: usize) -> f64 {
        let unbatched = {
            let one = KernelShape { occupancy: small_occ, ..*k };
            self.kernel_time(&one).time_s * batch as f64
        };
        let batched = {
            let all = KernelShape {
                elems: k.elems * batch as f64,
                occupancy: (small_occ * batch as f64).min(1.0),
                ..*k
            };
            self.kernel_time(&all).time_s
        };
        unbatched / batched
    }

    /// Combined VF x HF speedup of the paper's Exp. 4/8 workload: batch x
    /// chain-of-n-ops vs one launch per op per batch element.
    pub fn vfhf_speedup(&self, k: &KernelShape, small_occ: f64, batch: usize, n_ops: usize) -> f64 {
        let baseline = {
            let one = KernelShape { occupancy: small_occ, instrs_per_elem: 1.0, ..*k };
            self.kernel_time(&one).time_s * (batch * n_ops) as f64
        };
        let fused = {
            let all = KernelShape {
                elems: k.elems * batch as f64,
                occupancy: (small_occ * batch as f64).min(1.0),
                instrs_per_elem: n_ops as f64,
                ..*k
            };
            self.kernel_time(&all).time_s
        };
        baseline / fused
    }

    /// Fig. 1 sweep: time vs instructions/element at full occupancy.
    pub fn fig1_curve(&self, elems: f64, bytes_per_elem: f64, instr_points: &[f64]) -> Vec<(f64, f64)> {
        instr_points
            .iter()
            .map(|&i| {
                let k = KernelShape {
                    elems,
                    bytes_per_elem,
                    instrs_per_elem: i,
                    occupancy: 1.0,
                };
                (i, self.kernel_time(&k).time_s)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::table_ii_systems;

    fn rtx4090() -> GpuModel {
        GpuModel::new(table_ii_systems()[4])
    }

    #[test]
    fn fig1_knee_is_a_few_hundred_instructions() {
        // paper Fig. 1: RTX 4090, 66M floats, MB until ~260 instructions
        let m = rtx4090();
        let elems = 3840.0 * 2160.0 * 8.0;
        let curve = m.fig1_curve(elems, 8.0, &[1.0, 64.0, 260.0, 2000.0, 4000.0]);
        let t1 = curve[0].1;
        let t260 = curve[2].1;
        let t2000 = curve[3].1;
        let t4000 = curve[4].1;
        // flat in the MB region
        assert!((t260 - t1) / t1 < 0.35, "t1={t1:.6} t260={t260:.6}");
        // linear growth once well into the CB region
        assert!(t4000 / t2000 > 1.6, "CB region should scale: {t2000:.6} -> {t4000:.6}");
    }

    #[test]
    fn kernel_is_mb_below_knee_cb_above() {
        let m = rtx4090();
        let mk = |i: f64| KernelShape {
            elems: 1e8,
            bytes_per_elem: 8.0,
            instrs_per_elem: i,
            occupancy: 1.0,
        };
        assert!(m.kernel_time(&mk(10.0)).memory_bound);
        assert!(!m.kernel_time(&mk(2000.0)).memory_bound);
    }

    #[test]
    fn vf_speedup_scales_with_flop_per_byte() {
        // paper Fig. 22: bigger FLOP/B -> bigger max speedup
        let systems = table_ii_systems();
        let k = KernelShape {
            elems: 60.0 * 120.0,
            bytes_per_elem: 5.0,
            instrs_per_elem: 1.0,
            occupancy: 1.0,
        };
        let mut last = 0.0;
        for s in systems {
            let m = GpuModel::new(s);
            let su = m.vfhf_speedup(&k, 0.02, 50, 2000);
            assert!(su > last, "{}: {su} should exceed {last}", s.name);
            last = su;
        }
        // the biggest GPU lands in the paper's 20k x ballpark (order of mag)
        assert!(last > 3_000.0 && last < 300_000.0, "S5 speedup {last}");
    }

    #[test]
    fn hf_saturates_at_full_occupancy() {
        let m = rtx4090();
        let k = KernelShape {
            elems: 60.0 * 120.0,
            bytes_per_elem: 5.0,
            instrs_per_elem: 4.0,
            occupancy: 1.0,
        };
        let s10 = m.hf_speedup(&k, 0.01, 10);
        let s100 = m.hf_speedup(&k, 0.01, 100);
        let s600 = m.hf_speedup(&k, 0.01, 600);
        assert!(s100 > s10);
        // growth decelerates once the GPU is full (paper Fig. 17)
        assert!((s600 - s100) < (s100 - s10) * 2.0);
    }

    #[test]
    fn spill_caps_the_vf_curve() {
        // paper §VI-D: speedup stops growing for very long unrolled kernels
        let m = rtx4090();
        let k = KernelShape {
            elems: 60.0 * 120.0 * 50.0,
            bytes_per_elem: 2.0,
            instrs_per_elem: 1.0,
            occupancy: 1.0,
        };
        let f_4k = m.fused_chain(&k, 4000);
        let f_8k = m.fused_chain(&k, 8000);
        // after the spill threshold the fused kernel slows super-linearly
        assert!(f_8k / f_4k > 2.0, "spill penalty visible: {f_4k} -> {f_8k}");
    }
}
