//! Analytical GPU simulator — the substitution substrate for the paper's
//! physical testbed (DESIGN.md §3.4).
//!
//! The paper evaluates on five NVIDIA systems (Table II: Jetson Nano Super,
//! Orin AGX, RTX 3060-class PC, Grace-Hopper GH100, RTX 4090 PC). We have no
//! GPUs, so Exp. 8 ("GPU size") and the GPU half of Fig. 1 run on this model
//! instead: a latency-hiding roofline (Volkov) with kernel-launch overhead,
//! warp-issue limits and a register-spill penalty for very long unrolled
//! kernels (the paper's observed speedup ceiling in §VI-D).
//!
//! The model is deliberately simple and fully tested; every experiment that
//! uses it labels its output `simulated`.

mod model;
mod systems;

pub use model::{GpuModel, KernelShape, SimResult};
pub use systems::{table_ii_systems, SystemSpec};
