//! Table II system catalog (paper, page 9).

/// One row of the paper's Table II.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SystemSpec {
    pub name: &'static str,
    pub cpu: &'static str,
    pub gpu: &'static str,
    pub compute_cores: u32,
    /// FP32 TFLOPS.
    pub tflops_fp32: f64,
    /// VRAM GB (shared on Jetson).
    pub vram_gb: f64,
    /// GB/s.
    pub bandwidth_gbps: f64,
}

impl SystemSpec {
    /// FLOP per byte transferred — Table II's last row; the paper's Fig. 22
    /// x-axis and the predictor of combined VF x HF speedup.
    pub fn flop_per_byte(&self) -> f64 {
        self.tflops_fp32 * 1e12 / (self.bandwidth_gbps * 1e9)
    }
}

/// The five systems of Table II.
pub fn table_ii_systems() -> [SystemSpec; 5] {
    [
        SystemSpec {
            name: "S1 Nano Super",
            cpu: "Cortex A78AE",
            gpu: "GA10B",
            compute_cores: 1024,
            tflops_fp32: 1.880,
            vram_gb: 16.0,
            bandwidth_gbps: 102.4,
        },
        SystemSpec {
            name: "S2 Orin AGX",
            cpu: "Cortex A78AE",
            gpu: "GA10B",
            compute_cores: 2048,
            tflops_fp32: 5.325,
            vram_gb: 32.0,
            bandwidth_gbps: 204.8,
        },
        SystemSpec {
            name: "S3 PC (GA106)",
            cpu: "Ryzen 9 7945HX",
            gpu: "GA106",
            compute_cores: 3328,
            tflops_fp32: 7.987,
            vram_gb: 12.0,
            bandwidth_gbps: 288.0,
        },
        SystemSpec {
            name: "S4 Grace-Hopper",
            cpu: "Neoverse V2",
            gpu: "GH100",
            compute_cores: 16384,
            tflops_fp32: 62.08,
            vram_gb: 96.0,
            bandwidth_gbps: 1000.0,
        },
        SystemSpec {
            name: "S5 PC (AD102)",
            cpu: "Ryzen 7 5800X3D",
            gpu: "AD102",
            compute_cores: 18432,
            tflops_fp32: 82.58,
            vram_gb: 24.0,
            bandwidth_gbps: 1008.0,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flop_per_byte_matches_table_ii() {
        let sys = table_ii_systems();
        // paper's last row: 18.36, 26, 27.73, 62.08, 81.93-ish
        let expect = [18.36, 26.0, 27.73, 62.08, 81.92];
        for (s, e) in sys.iter().zip(expect) {
            let got = s.flop_per_byte();
            assert!(
                (got - e).abs() / e < 0.07,
                "{}: FLOP/B {got:.2} vs table {e:.2}",
                s.name
            );
        }
    }

    #[test]
    fn ordering_by_flopb_is_s1_to_s5() {
        let sys = table_ii_systems();
        for w in sys.windows(2) {
            assert!(w[0].flop_per_byte() < w[1].flop_per_byte());
        }
    }
}
