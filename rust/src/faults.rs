//! Deterministic fault injection for the serving stack.
//!
//! Production fault tolerance is unverifiable without a way to *cause*
//! faults on demand: a panic inside a monomorphized loop, an `Err` from one
//! launch of one signature, a backend that dies during construction. This
//! module provides that switch. A [`FaultPlan`] is a list of rules keyed by
//! signature (stream-key substring), execution tier and launch index; an
//! armed [`FaultInjector`] is consulted at every launch site and
//! deterministically forces an `Err` or a panic at exactly the selected
//! launches. With no plan configured the injector is simply absent
//! (`Option::None` at every call site) — zero cost when off.
//!
//! The spec grammar (also accepted from the `FKL_FAULTS` environment
//! variable by the `fkl` CLI):
//!
//! ```text
//! spec  := rule (';' rule)*
//! rule  := field (',' field)*
//! field := 'sig=' SUBSTR | 'tier=' (stacked|divergent|peritem|build|any)
//!        | 'launch=' (K | A..B | '*') | 'action=' (err|panic) | 'count=' N
//! ```
//!
//! `sig` matches when the stream key *contains* the substring (`*` or absent
//! = any signature). `launch` selects by the rule's own 0-based counter of
//! sig+tier-matching launches (`A..B` is half-open), so a rule fires at a
//! reproducible position in the launch sequence regardless of what other
//! rules do. `count` caps total fires. Example — fail the third stacked
//! launch of any u8 stream with a panic:
//!
//! ```text
//! sig=u8,tier=stacked,launch=2,action=panic
//! ```

use std::sync::atomic::{AtomicU64, Ordering};

/// What an injected fault does at the selected launch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Return a typed [`InjectedFault`] error from the launch.
    Error,
    /// Panic inside the launch (exercises the `catch_unwind` isolation).
    Panic,
}

/// Where in the serving ladder a launch is happening when the injector is
/// consulted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultTier {
    /// Tier 1: an identical stacked-HF bucket launch.
    Stacked,
    /// Tier 2: one item of a divergent-HF window (consulted serially in
    /// window order before the lanes spawn, so indices are deterministic).
    Divergent,
    /// Tier 3: a per-item launch.
    PerItem,
    /// Backend construction (exercises the supervisor restart path).
    Build,
    /// Rule wildcard: matches every tier.
    Any,
}

impl FaultTier {
    pub fn name(self) -> &'static str {
        match self {
            FaultTier::Stacked => "stacked",
            FaultTier::Divergent => "divergent",
            FaultTier::PerItem => "peritem",
            FaultTier::Build => "build",
            FaultTier::Any => "any",
        }
    }

    fn parse(s: &str) -> Option<FaultTier> {
        match s {
            "stacked" => Some(FaultTier::Stacked),
            "divergent" => Some(FaultTier::Divergent),
            "peritem" | "per-item" | "per_item" => Some(FaultTier::PerItem),
            "build" => Some(FaultTier::Build),
            "any" | "*" => Some(FaultTier::Any),
            _ => None,
        }
    }

    fn matches(self, at: FaultTier) -> bool {
        self == FaultTier::Any || self == at
    }
}

/// Which launch indices (per rule, counting only sig+tier matches) fire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaunchSel {
    /// Every matching launch.
    Any,
    /// Exactly the K-th matching launch (0-based).
    Index(u64),
    /// The half-open range `A..B` of matching launches.
    Range(u64, u64),
}

impl LaunchSel {
    fn matches(self, i: u64) -> bool {
        match self {
            LaunchSel::Any => true,
            LaunchSel::Index(k) => i == k,
            LaunchSel::Range(a, b) => a <= i && i < b,
        }
    }
}

/// One parsed fault rule.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultRule {
    /// Stream-key substring to match (`None` = any signature).
    pub sig: Option<String>,
    pub tier: FaultTier,
    pub launch: LaunchSel,
    pub action: FaultAction,
    /// Maximum number of fires (`None` = unbounded).
    pub count: Option<u64>,
}

impl Default for FaultRule {
    fn default() -> Self {
        FaultRule {
            sig: None,
            tier: FaultTier::Any,
            launch: LaunchSel::Any,
            action: FaultAction::Error,
            count: None,
        }
    }
}

/// A parsed fault specification: zero or more rules, first match fires.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub rules: Vec<FaultRule>,
}

/// Typed parse failure for a fault spec.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum FaultSpecError {
    #[error("empty rule in fault spec")]
    EmptyRule,
    #[error("malformed field `{0}` (want key=value)")]
    Field(String),
    #[error("unknown field key `{0}` (sig|tier|launch|action|count)")]
    Key(String),
    #[error("bad tier `{0}` (stacked|divergent|peritem|build|any)")]
    Tier(String),
    #[error("bad action `{0}` (err|panic)")]
    Action(String),
    #[error("bad launch selector `{0}` (K, A..B, or *)")]
    Launch(String),
    #[error("bad count `{0}` (positive integer)")]
    Count(String),
}

impl FaultPlan {
    /// Parse the spec grammar. An empty / whitespace-only spec is the empty
    /// plan (injection off).
    pub fn parse(spec: &str) -> Result<FaultPlan, FaultSpecError> {
        let mut rules = Vec::new();
        for rule_src in spec.split(';') {
            let rule_src = rule_src.trim();
            if rule_src.is_empty() {
                continue;
            }
            let mut rule = FaultRule::default();
            let mut saw_field = false;
            for field in rule_src.split(',') {
                let field = field.trim();
                if field.is_empty() {
                    continue;
                }
                saw_field = true;
                let (key, val) = field
                    .split_once('=')
                    .ok_or_else(|| FaultSpecError::Field(field.into()))?;
                match key.trim() {
                    "sig" => {
                        let v = val.trim();
                        rule.sig = if v == "*" { None } else { Some(v.to_string()) };
                    }
                    "tier" => {
                        rule.tier = FaultTier::parse(val.trim())
                            .ok_or_else(|| FaultSpecError::Tier(val.trim().into()))?;
                    }
                    "launch" => rule.launch = parse_launch(val.trim())?,
                    "action" => {
                        rule.action = match val.trim() {
                            "err" | "error" => FaultAction::Error,
                            "panic" => FaultAction::Panic,
                            other => return Err(FaultSpecError::Action(other.into())),
                        };
                    }
                    "count" => {
                        let n: u64 = val
                            .trim()
                            .parse()
                            .map_err(|_| FaultSpecError::Count(val.trim().into()))?;
                        if n == 0 {
                            return Err(FaultSpecError::Count(val.trim().into()));
                        }
                        rule.count = Some(n);
                    }
                    other => return Err(FaultSpecError::Key(other.into())),
                }
            }
            if !saw_field {
                return Err(FaultSpecError::EmptyRule);
            }
            rules.push(rule);
        }
        Ok(FaultPlan { rules })
    }

    /// Read and parse `FKL_FAULTS` (used by the `fkl` CLI; [`crate::coordinator::ServiceConfig`]
    /// deliberately does NOT read the environment — library users arm faults
    /// explicitly). Returns `Ok(None)` when unset or empty.
    pub fn from_env() -> Result<Option<FaultPlan>, FaultSpecError> {
        match std::env::var("FKL_FAULTS") {
            Ok(spec) if !spec.trim().is_empty() => Ok(Some(FaultPlan::parse(&spec)?)),
            _ => Ok(None),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }
}

fn parse_launch(s: &str) -> Result<LaunchSel, FaultSpecError> {
    if s == "*" {
        return Ok(LaunchSel::Any);
    }
    if let Some((a, b)) = s.split_once("..") {
        let a: u64 = a.trim().parse().map_err(|_| FaultSpecError::Launch(s.into()))?;
        let b: u64 = b.trim().parse().map_err(|_| FaultSpecError::Launch(s.into()))?;
        if b <= a {
            return Err(FaultSpecError::Launch(s.into()));
        }
        return Ok(LaunchSel::Range(a, b));
    }
    s.parse().map(LaunchSel::Index).map_err(|_| FaultSpecError::Launch(s.into()))
}

/// The typed error an injected `action=err` fault produces (a panic fault
/// carries the same rendering inside its payload, so both paths are
/// recognizable by the `injected fault` prefix).
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
#[error("injected fault (rule {rule}) at {tier} launch {launch} of `{key}`")]
pub struct InjectedFault {
    /// Index of the rule that fired.
    pub rule: usize,
    /// Tier name at the consult site.
    pub tier: &'static str,
    /// The rule's matching-launch index that fired.
    pub launch: u64,
    /// Stream key of the faulted launch.
    pub key: String,
}

/// An armed fault plan: per-rule match/fire counters over a [`FaultPlan`].
/// Counters are atomic so the injector can be shared (`Arc`) between the
/// service thread and an engine; determinism comes from consulting it in a
/// deterministic order (the coordinator consults serially, and
/// `run_divergent` consults in window order BEFORE spawning lanes).
#[derive(Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
    matched: Vec<AtomicU64>,
    fired: Vec<AtomicU64>,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> FaultInjector {
        let n = plan.rules.len();
        FaultInjector {
            plan,
            matched: (0..n).map(|_| AtomicU64::new(0)).collect(),
            fired: (0..n).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Consult the plan for a launch about to happen at `tier` on stream
    /// `key`. Advances every sig+tier-matching rule's launch counter; the
    /// first rule whose launch selector and count admit this launch fires.
    pub fn check(&self, tier: FaultTier, key: &str) -> Option<(FaultAction, InjectedFault)> {
        for (i, rule) in self.plan.rules.iter().enumerate() {
            let sig_ok = rule.sig.as_deref().is_none_or(|s| key.contains(s));
            if !sig_ok || !rule.tier.matches(tier) {
                continue;
            }
            let idx = self.matched[i].fetch_add(1, Ordering::Relaxed);
            if !rule.launch.matches(idx) {
                continue;
            }
            if let Some(cap) = rule.count {
                if self.fired[i].load(Ordering::Relaxed) >= cap {
                    continue;
                }
            }
            self.fired[i].fetch_add(1, Ordering::Relaxed);
            let info = InjectedFault { rule: i, tier: tier.name(), launch: idx, key: key.into() };
            return Some((rule.action, info));
        }
        None
    }

    /// [`FaultInjector::check`] + trigger: `Ok(())` when no rule selects
    /// this launch, a typed `Err` for `action=err` — and a panic for
    /// `action=panic`, to be contained by the launch site's `catch_unwind`.
    pub fn apply(&self, tier: FaultTier, key: &str) -> anyhow::Result<()> {
        match self.check(tier, key) {
            None => Ok(()),
            Some((action, info)) => trigger(action, info),
        }
    }

    /// Total fires across all rules (observability for tests/CLI).
    pub fn fired(&self) -> u64 {
        self.fired.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }
}

/// Fire a checked fault: typed `Err` or panic per the action.
pub fn trigger(action: FaultAction, info: InjectedFault) -> anyhow::Result<()> {
    match action {
        FaultAction::Error => Err(info.into()),
        FaultAction::Panic => panic!("{info}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_rule() {
        let p = FaultPlan::parse("sig=u8,tier=stacked,launch=2,action=panic,count=1").unwrap();
        assert_eq!(
            p.rules,
            vec![FaultRule {
                sig: Some("u8".into()),
                tier: FaultTier::Stacked,
                launch: LaunchSel::Index(2),
                action: FaultAction::Panic,
                count: Some(1),
            }]
        );
    }

    #[test]
    fn parses_defaults_ranges_and_multiple_rules() {
        let p = FaultPlan::parse("tier=divergent,launch=0..3; action=err").unwrap();
        assert_eq!(p.rules.len(), 2);
        assert_eq!(p.rules[0].launch, LaunchSel::Range(0, 3));
        assert_eq!(p.rules[0].sig, None);
        assert_eq!(p.rules[1].tier, FaultTier::Any);
        assert_eq!(p.rules[1].launch, LaunchSel::Any);
        assert!(FaultPlan::parse("  ").unwrap().is_empty());
    }

    #[test]
    fn rejects_malformed_specs() {
        assert_eq!(FaultPlan::parse("bogus"), Err(FaultSpecError::Field("bogus".into())));
        assert_eq!(FaultPlan::parse("zig=u8"), Err(FaultSpecError::Key("zig".into())));
        assert_eq!(FaultPlan::parse("tier=gpu"), Err(FaultSpecError::Tier("gpu".into())));
        assert_eq!(FaultPlan::parse("action=explode"), Err(FaultSpecError::Action("explode".into())));
        assert_eq!(FaultPlan::parse("launch=5..2"), Err(FaultSpecError::Launch("5..2".into())));
        assert_eq!(FaultPlan::parse("count=0"), Err(FaultSpecError::Count("0".into())));
    }

    #[test]
    fn fires_at_selected_launch_only() {
        let inj = FaultInjector::new(
            FaultPlan::parse("sig=u8,tier=stacked,launch=1,action=err").unwrap(),
        );
        assert!(inj.check(FaultTier::Stacked, "mul|u8->f32|4x4").is_none(), "launch 0");
        // a non-matching signature does not advance the rule's counter
        assert!(inj.check(FaultTier::Stacked, "mul|f32->f32|4x4").is_none());
        assert!(inj.check(FaultTier::Divergent, "mul|u8->f32|4x4").is_none(), "tier gate");
        let (action, info) = inj.check(FaultTier::Stacked, "mul|u8->f32|4x4").unwrap();
        assert_eq!(action, FaultAction::Error);
        assert_eq!((info.launch, info.rule), (1, 0));
        assert!(inj.check(FaultTier::Stacked, "mul|u8->f32|4x4").is_none(), "launch 2");
        assert_eq!(inj.fired(), 1);
    }

    #[test]
    fn count_caps_fires_and_any_tier_matches_everywhere() {
        let inj =
            FaultInjector::new(FaultPlan::parse("tier=any,launch=*,count=2,action=err").unwrap());
        assert!(inj.check(FaultTier::Stacked, "k").is_some());
        assert!(inj.check(FaultTier::Build, "k").is_some());
        assert!(inj.check(FaultTier::PerItem, "k").is_none(), "count exhausted");
        assert_eq!(inj.fired(), 2);
    }

    #[test]
    fn trigger_error_is_typed_and_trigger_panic_panics() {
        let info =
            InjectedFault { rule: 0, tier: "stacked", launch: 3, key: "mul|u8->f32|4".into() };
        let err = trigger(FaultAction::Error, info.clone()).unwrap_err();
        assert_eq!(err.downcast_ref::<InjectedFault>(), Some(&info));
        let caught = std::panic::catch_unwind(|| {
            let _ = trigger(FaultAction::Panic, info);
        });
        let msg = *caught.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("injected fault"), "panic payload carries the marker: {msg}");
    }
}
