//! Element dtypes supported by the op vocabulary (manifest `dtin`/`dtout`).

#[cfg(feature = "pjrt")]
use xla::ElementType;

/// Element type of a [`super::Tensor`]. Matches the Python `DTYPES` table.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    U8,
    U16,
    I32,
    F32,
    F64,
}

impl DType {
    /// Canonical short name used in artifact names and the manifest.
    pub fn name(self) -> &'static str {
        match self {
            DType::U8 => "u8",
            DType::U16 => "u16",
            DType::I32 => "i32",
            DType::F32 => "f32",
            DType::F64 => "f64",
        }
    }

    pub fn parse(s: &str) -> Option<DType> {
        Some(match s {
            "u8" => DType::U8,
            "u16" => DType::U16,
            "i32" => DType::I32,
            "f32" => DType::F32,
            "f64" => DType::F64,
            _ => return None,
        })
    }

    pub fn size_bytes(self) -> usize {
        match self {
            DType::U8 => 1,
            DType::U16 => 2,
            DType::I32 | DType::F32 => 4,
            DType::F64 => 8,
        }
    }

    /// The XLA element type this dtype marshals to.
    #[cfg(feature = "pjrt")]
    pub fn xla(self) -> ElementType {
        match self {
            DType::U8 => ElementType::U8,
            DType::U16 => ElementType::U16,
            DType::I32 => ElementType::S32,
            DType::F32 => ElementType::F32,
            DType::F64 => ElementType::F64,
        }
    }

    /// True if saturating integer store semantics apply at the write boundary.
    pub fn is_int(self) -> bool {
        matches!(self, DType::U8 | DType::U16 | DType::I32)
    }

    /// Saturation ceiling for integer image types (None = plain rounding).
    pub fn saturate_max(self) -> Option<f64> {
        match self {
            DType::U8 => Some(255.0),
            DType::U16 => Some(65535.0),
            _ => None,
        }
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}
